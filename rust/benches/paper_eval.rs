//! End-to-end paper-table benches: times one full U-vs-R benchmark
//! pair (the unit of work behind Tables 10/11 and Figs 10/12) and a
//! Fig. 11 BICG slice, so regressions in harness wall-clock are
//! caught. Uses the stride fallback to stay artifact-independent.

use std::time::Duration;
use uvm_prefetch::eval::runner::{run_benchmark, run_pair, RunOptions};
use uvm_prefetch::util::bench::Bench;

fn main() {
    let mut b = Bench::new().with_min_time(Duration::from_millis(1500));
    println!("== paper_eval (stride fallback, scale 0.25, 1M-inst cap) ==");
    let opts = RunOptions {
        scale: 0.25,
        max_instructions: 1_000_000,
        ..Default::default()
    };

    let insts = 2 * 1_000_000u64;
    b.case("pair: atax U+R (Tables 10/11 unit)", insts, || {
        let p = run_pair("atax", &opts).unwrap();
        p.u.instructions + p.r.instructions
    });

    b.case("fig11 slice: bicg uvmsmart 1M inst", 1_000_000, || {
        run_benchmark("bicg", "uvmsmart", &opts).unwrap().cycles
    });

    b.case("oracle recording+replay: atax", 1_000_000, || {
        run_benchmark("atax", "oracle", &opts).unwrap().cycles
    });
}
