//! PJRT inference benches (needs `make artifacts`): wall-clock of one
//! batched model execution — the quantity the paper's §7.3 latency
//! sensitivity is about. The paper assumes 1 µs/prediction on
//! datacenter hardware (TensorRT-class); we report what the CPU PJRT
//! path actually costs per batch and per prediction, which DESIGN.md
//! §6 compares against the simulated budget.

use std::path::Path;
use std::time::Duration;
use uvm_prefetch::predictor::{PredictorBackend, FeatTok, Window};
use uvm_prefetch::runtime::{Manifest, ModelExecutable, PjrtBackend};
use uvm_prefetch::util::bench::Bench;

fn main() {
    let dir = Path::new("artifacts");
    let Ok(manifest) = Manifest::load(dir) else {
        println!("pjrt_infer: artifacts/ missing — run `make artifacts` first (skipping)");
        return;
    };
    let (name, entry) = manifest
        .resolve("", "atax")
        .or_else(|_| manifest.resolve("shared", ""))
        .expect("no model in manifest");
    println!("== pjrt_infer (model '{name}') ==");
    let exe = ModelExecutable::load(dir, entry).expect("load model");
    let mut backend = PjrtBackend::new(exe, entry.arch.clone());

    let window = |seed: i32| Window {
        tokens: (0..entry.seq_len)
            .map(|i| FeatTok {
                pc_id: (seed + i as i32) % 3,
                page_id: (seed * 7 + i as i32) % 512,
                delta_id: (seed + i as i32) % entry.n_classes as i32,
            })
            .collect(),
    };

    let mut b = Bench::new().with_min_time(Duration::from_millis(1500));
    for batch in [1usize, 4, 8] {
        let windows: Vec<Window> = (0..batch as i32).map(window).collect();
        let label = format!(
            "infer: {batch} windows (exe batch {}) → per-prediction cost",
            entry.batch
        );
        b.case(&label, batch as u64, || backend.predict(&windows).len());
    }
    println!(
        "model mean infer wall: {:.1} µs/call over {} calls (simulated budget: 1 µs/prediction)",
        backend.model.mean_infer_us(),
        backend.model.infer_calls
    );

    // Fine-tune step cost (rare: every 50M instructions in-paper).
    if entry.train_hlo.is_some() {
        use uvm_prefetch::predictor::LabelledWindow;
        let batch: Vec<LabelledWindow> = (0..entry.train_batch as i32)
            .map(|i| LabelledWindow { window: window(i), label: i % entry.n_classes as i32 })
            .collect();
        b.case("finetune: one SGD step (batch 16)", 1, || {
            backend.finetune(&batch).map(|l| l.to_bits()).unwrap_or(0)
        });
    }
}
