//! Micro-benchmarks for the simulator hot path: event engine
//! throughput, interconnect model, device-memory LRU, TLB. These are
//! the L3 components the §Perf pass optimizes — the Fig 10-12 suite
//! runs dozens of full simulations, so simulated-instructions/second
//! is the quantity that gates the whole harness.
//!
//! Results land in the shared `bench_sim/v1` artifact (suite
//! `sim_core`; `$UVM_BENCH_OUT` overrides the `BENCH_sim.json`
//! default) alongside the `prefetchers` suite and the `repro perf`
//! summary.

use std::path::PathBuf;
use std::time::Duration;
use uvm_prefetch::config::ExperimentConfig;
use uvm_prefetch::prefetch::none::NonePrefetcher;
use uvm_prefetch::prefetch::tree::TreePrefetcher;
use uvm_prefetch::sim::device_memory::DeviceMemory;
use uvm_prefetch::sim::gmmu::Tlb;
use uvm_prefetch::sim::interconnect::Interconnect;
use uvm_prefetch::sim::Simulator;
use uvm_prefetch::util::bench::{black_box, write_bench_sim, Bench};
use uvm_prefetch::workloads::WorkloadRegistry;

fn bench_out() -> PathBuf {
    PathBuf::from(std::env::var("UVM_BENCH_OUT").unwrap_or_else(|_| "BENCH_sim.json".into()))
}

fn sim_run(prefetcher: &str, max_insts: u64) -> u64 {
    let exp = ExperimentConfig {
        benchmark: "atax".into(),
        max_instructions: max_insts,
        ..Default::default()
    };
    let wl = WorkloadRegistry::builtin().build("atax", &exp.sim, 1, 0.25).unwrap();
    let pf: Box<dyn uvm_prefetch::prefetch::Prefetcher> = match prefetcher {
        "none" => Box::new(NonePrefetcher),
        _ => Box::new(TreePrefetcher::new(0.5)),
    };
    let m = Simulator::new(&exp, wl, pf, None).run();
    m.instructions
}

fn main() {
    let mut b = Bench::new().with_min_time(Duration::from_millis(1200));
    println!("== sim_core ==");

    // End-to-end simulated-instruction throughput (the headline).
    let insts = sim_run("none", 150_000);
    b.case("sim: atax demand-paging 150k-inst run", insts, || sim_run("none", 150_000));
    let insts = sim_run("tree", 150_000);
    b.case("sim: atax tree-prefetch 150k-inst run", insts, || sim_run("tree", 150_000));

    // Interconnect model.
    b.case("interconnect: 1k transfers", 1000, || {
        let mut link = Interconnect::new(10.63, 100, 10_000);
        for i in 0..1000u64 {
            black_box(link.transfer(i * 50, 4096, i % 3 == 0));
        }
        link.total_bytes()
    });

    // Device-memory admit/touch/evict cycle at capacity.
    b.case("device-memory: admit+touch at capacity (1k pages)", 1000, || {
        let mut dm = DeviceMemory::new(512);
        for p in 0..1000u64 {
            dm.admit(p, p, p % 2 == 0, p);
            dm.touch(p, p + 1);
        }
        dm.occupancy()
    });

    // TLB lookup/insert (64-entry linear scan).
    b.case("tlb: 10k lookups on 64-entry LRU", 10_000, || {
        let mut tlb = Tlb::new(64);
        let mut hits = 0u64;
        for i in 0..10_000u64 {
            let page = i % 96; // 2/3 fit
            if tlb.lookup(page, i) {
                hits += 1;
            } else {
                tlb.insert(page, i);
            }
        }
        hits
    });

    // Workload generation (materialization cost).
    b.case("workload-gen: atax @0.25", 1, || {
        let exp = ExperimentConfig::default();
        WorkloadRegistry::builtin().build("atax", &exp.sim, 1, 0.25).unwrap().total_ops
    });

    let out = bench_out();
    write_bench_sim(&out, "sim_core", b.results()).expect("write bench_sim artifact");
    println!("wrote suite sim_core -> {}", out.display());
}
