//! Kernel-tier GEMM/GEMV benches at the serving shapes of the native
//! model (fc1: `in_dim = seq_len × Σd_emb`, fc2: `hidden → classes`),
//! across every `--precision` tier. Besides the console report, writes
//! `BENCH_gemm.json` (schema `bench_gemm/v1`) at the repo root so
//! `make kernel-bench` leaves a machine-readable artifact next to the
//! other BENCH files.

use std::path::Path;
use std::time::Duration;
use uvm_prefetch::predictor::kernel::{linear_forward_batch, Precision, QuantizedLinear};
use uvm_prefetch::predictor::quant;
use uvm_prefetch::util::bench::{black_box, Bench};
use uvm_prefetch::util::{Json, XorShift64};

fn randvec(rng: &mut XorShift64, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.next_u64() % 2000) as f32 / 1000.0 - 1.0).collect()
}

/// One (m=batch, k=in_dim, n=out_dim) layer shape to sweep.
struct Shape {
    tag: &'static str,
    batch: usize,
    in_dim: usize,
    out_dim: usize,
}

fn main() {
    // The native default config: seq_len 30 × (8+8+16) features into a
    // 64-wide hidden layer, then out to a ~256-delta vocabulary. Batch
    // 1 is the sequential serve path, batch 8 the batcher's flush size.
    let shapes = [
        Shape { tag: "fc1", batch: 1, in_dim: 960, out_dim: 64 },
        Shape { tag: "fc1", batch: 8, in_dim: 960, out_dim: 64 },
        Shape { tag: "fc2", batch: 8, in_dim: 64, out_dim: 257 },
    ];
    let tiers = [Precision::Exact, Precision::Fast, Precision::Int8, Precision::Int4];

    let mut b = Bench::new().with_min_time(Duration::from_millis(400));
    println!("== gemm kernels ==");
    let mut meta: Vec<(String, &'static str, usize, usize, usize)> = Vec::new();

    for s in &shapes {
        let mut rng = XorShift64::new(0x6e33);
        let w = randvec(&mut rng, s.in_dim * s.out_dim);
        let bias = randvec(&mut rng, s.out_dim);
        let xs = randvec(&mut rng, s.in_dim * s.batch);
        let mut out = vec![0.0f32; s.out_dim * s.batch];
        let (scale, packed) = quant::pack_scaled(&w);
        for &tier in &tiers {
            let name =
                format!("{} {}x{}x{} {}", s.tag, s.batch, s.in_dim, s.out_dim, tier.as_str());
            if tier.is_quantized() {
                let q =
                    QuantizedLinear::from_packed(&packed, scale, s.out_dim, s.in_dim, tier)
                        .unwrap();
                b.case(&name, s.batch as u64, || {
                    q.forward_batch(&bias, &xs, &mut out);
                    black_box(out[0])
                });
            } else {
                b.case(&name, s.batch as u64, || {
                    linear_forward_batch(tier, &w, &bias, &xs, &mut out, s.in_dim, s.out_dim);
                    black_box(out[0])
                });
            }
            meta.push((name, tier.as_str(), s.batch, s.in_dim, s.out_dim));
        }
    }

    // bench_gemm/v1: one record per case, with enough shape info to
    // recompute throughput; gflops = 2·m·k·n / mean_ns.
    let cases = b.results().iter().zip(&meta).map(|(r, (name, tier, m, k, n))| {
        let flops = 2.0 * (*m as f64) * (*k as f64) * (*n as f64);
        Json::obj(vec![
            ("name", Json::str(name)),
            ("precision", Json::str(tier)),
            ("m", Json::Num(*m as f64)),
            ("k", Json::Num(*k as f64)),
            ("n", Json::Num(*n as f64)),
            ("mean_ns", Json::Num(r.mean_ns)),
            ("min_ns", Json::Num(r.min_ns)),
            ("gflops", Json::Num(flops / r.mean_ns)),
        ])
    });
    let doc = Json::obj(vec![
        ("schema", Json::str("bench_gemm/v1")),
        ("cases", Json::arr(cases)),
    ]);
    // Anchor on the manifest dir so the artifact lands at the repo
    // root no matter whether cargo or the binary sets the CWD.
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_gemm.json");
    doc.write_file(&path).unwrap();
    println!("wrote {}", path.display());
}
