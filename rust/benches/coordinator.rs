//! Coordinator/serving-path benches: router throughput, batcher,
//! history table, JSON parsing (artifact load path), and the threaded
//! pipeline end to end with a constant backend.

use std::time::Duration;
use uvm_prefetch::config::{BypassMode, RuntimeConfig};
use uvm_prefetch::coordinator::{CoordinatorService, FaultEvent, Router, SpawnOptions};
use uvm_prefetch::predictor::batcher::{Batcher, PendingRequest};
use uvm_prefetch::predictor::history::HistoryTable;
use uvm_prefetch::predictor::{ConstantBackend, DeltaVocab, FeatTok, Window};
use uvm_prefetch::types::AccessOrigin;
use uvm_prefetch::util::bench::{black_box, Bench};
use uvm_prefetch::util::Json;

fn event(page: u64, warp: u16, at: u64, miss: bool) -> FaultEvent {
    FaultEvent {
        at,
        pc: 0x1000,
        page,
        origin: AccessOrigin { sm: warp % 28, warp, cta: 0, tpc: 0, kernel_id: 0 },
        miss,
        tenant: (warp % 2) as u32,
    }
}

fn main() {
    let mut b = Bench::new().with_min_time(Duration::from_millis(800));
    println!("== coordinator ==");

    // Router: cluster + history + window extraction per access.
    b.case("router: 10k accesses (10% misses)", 10_000, || {
        let vocab = DeltaVocab::synthetic((1..=16).collect(), 30);
        let rcfg = RuntimeConfig { bypass: BypassMode::Never, ..Default::default() };
        let mut r = Router::new(vocab, &rcfg);
        let mut windows = 0usize;
        for i in 0..10_000u64 {
            let warp = (i % 16) as u16;
            let out = r.route(&event(1000 * warp as u64 + i / 16, warp, i, i % 10 == 0));
            windows += out.window.is_some() as usize;
        }
        windows
    });

    // History table push path.
    b.case("history: 100k pushes over 64 clusters", 100_000, || {
        let mut h: HistoryTable<u64> = HistoryTable::new(30);
        for i in 0..100_000u64 {
            h.push(i % 64, 0x10, i / 64 * 2, i);
        }
        h.n_clusters()
    });

    // Batcher enqueue/flush.
    b.case("batcher: 10k requests (batch 8)", 10_000, || {
        let mut bt = Batcher::new(8, 2_000);
        let w = Window { tokens: vec![FeatTok { pc_id: 0, page_id: 0, delta_id: 0 }; 30] };
        let mut flushed = 0usize;
        for i in 0..10_000u64 {
            let req = PendingRequest {
                window: w.clone(),
                anchor_page: i,
                enqueued_at: i,
                cluster: 0,
                pc: 0,
            };
            if let Some(batch) = bt.push(req) {
                flushed += batch.len();
            }
        }
        flushed
    });

    // JSON parse (vocab-file-shaped payload) — artifact load path.
    let vocab_json = {
        let deltas: Vec<String> = (0..512).map(|i| (i - 256).to_string()).collect();
        format!(
            "{{\"deltas\":[{}],\"pcs\":[4096,4104,4112],\"page_buckets\":4096,\
             \"dominant_delta\":2,\"convergence\":0.93,\"history_len\":30}}",
            deltas.join(",")
        )
    };
    b.case("json: parse 512-delta vocab file", 1, || {
        black_box(Json::parse(&vocab_json).unwrap())
    });

    // Threaded pipeline end to end (constant backend), single shard
    // vs sharded: the shard axis is the serving-throughput knob.
    for shards in [1usize, 4] {
        b.case(&format!("pipeline: 2k accesses through service ({shards} shard)"), 2_000, || {
            let vocab = DeltaVocab::synthetic(vec![1, 2, 4], 30);
            let rcfg = RuntimeConfig {
                history_len: 30,
                batch_size: 8,
                bypass: BypassMode::Never,
                ..Default::default()
            };
            let backend = Box::new(ConstantBackend { class: 0, n_classes: vocab.n_classes() });
            let sopts = SpawnOptions { shards, max_tenants: 2, ..Default::default() };
            let handle = CoordinatorService::spawn(vocab, backend, &rcfg, &sopts);
            for i in 0..2_000u64 {
                let warp = (i % 8) as u16;
                handle
                    .send(event(1000 * warp as u64 + i / 8, warp, i, i % 4 == 0))
                    .unwrap();
            }
            handle.shutdown().commands.len()
        });
    }
}
