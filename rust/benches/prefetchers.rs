//! Prefetch-policy micro-benchmarks: per-fault decision cost for each
//! policy, plus the ablation of the bypass indicator (DESIGN.md §6 —
//! "ablation benches for the design choices").
//!
//! Results land in the shared `bench_sim/v1` artifact (suite
//! `prefetchers`; `$UVM_BENCH_OUT` overrides the `BENCH_sim.json`
//! default) alongside the `sim_core` suite and the `repro perf`
//! summary.

use std::path::PathBuf;
use std::time::Duration;
use uvm_prefetch::config::{BypassMode, RuntimeConfig};
use uvm_prefetch::prefetch::dl::dl_with_stride_backend;
use uvm_prefetch::prefetch::stride::StridePrefetcher;
use uvm_prefetch::prefetch::tree::TreePrefetcher;
use uvm_prefetch::prefetch::uvmsmart::UvmSmartPrefetcher;
use uvm_prefetch::prefetch::{FaultInfo, MemPressure, Prefetcher};
use uvm_prefetch::types::AccessOrigin;
use uvm_prefetch::util::bench::{black_box, write_bench_sim, Bench};

fn fault(page: u64, warp: u16, now: u64) -> FaultInfo {
    FaultInfo {
        now,
        service_at: now + 66_645,
        pc: 0x1000 + (page % 3) * 8,
        page,
        origin: AccessOrigin { sm: warp % 28, warp, cta: warp as u32, tpc: 0, kernel_id: 0 },
        array_id: 0,
        mem: MemPressure::unpressured(),
    }
}

/// Drive `n` faults with a strided pattern through a policy.
fn drive(p: &mut dyn Prefetcher, n: u64) -> usize {
    let mut total = 0;
    for i in 0..n {
        let warp = (i % 16) as u16;
        let page = 1000 * warp as u64 + (i / 16) * 2;
        let f = fault(page, warp, i * 40);
        total += p.on_fault(&f).requests.len();
        p.on_access(f.origin, f.pc, f.page, false, f.now);
        total += p.drain(i * 40 + 39).len();
    }
    total
}

fn main() {
    let mut b = Bench::new().with_min_time(Duration::from_millis(800));
    println!("== prefetchers (per-fault decision cost) ==");

    b.case("tree: 10k faults", 10_000, || {
        let mut p = TreePrefetcher::new(0.5);
        drive(&mut p, 10_000)
    });

    b.case("uvmsmart: 10k faults", 10_000, || {
        let mut p = UvmSmartPrefetcher::new(0.5, 0.85);
        drive(&mut p, 10_000)
    });

    b.case("stride: 10k faults", 10_000, || {
        let mut p = StridePrefetcher::default();
        drive(&mut p, 10_000)
    });

    // DL policy with the pure-Rust backend: full cluster/history/
    // batcher/vocab path, no PJRT (that cost is in pjrt_infer.rs).
    let mk = |bypass: BypassMode| {
        let rcfg = RuntimeConfig { bypass, history_len: 30, batch_size: 8, ..Default::default() };
        dl_with_stride_backend(&rcfg, (-8i64..=8).filter(|&d| d != 0).collect())
    };
    b.case("dl(stride-backend, bypass=never): 10k faults", 10_000, || {
        let mut p = mk(BypassMode::Never);
        drive(&mut p, 10_000)
    });

    // Ablation: the §6 bypass indicator removes the model call on
    // converged clusters — measure the decision-path saving.
    b.case("dl(stride-backend, bypass=auto):  10k faults", 10_000, || {
        let mut p = mk(BypassMode::Auto);
        drive(&mut p, 10_000)
    });
    b.case("dl(stride-backend, bypass=always):10k faults", 10_000, || {
        let mut p = mk(BypassMode::Always);
        drive(&mut p, 10_000)
    });

    let out = PathBuf::from(
        std::env::var("UVM_BENCH_OUT").unwrap_or_else(|_| "BENCH_sim.json".into()),
    );
    write_bench_sim(&out, "prefetchers", b.results()).expect("write bench_sim artifact");
    println!("wrote suite prefetchers -> {}", out.display());
    black_box(());
}
