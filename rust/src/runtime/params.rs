//! Tensor-store reader: the `*.params.bin` format written by
//! `python/compile/aot.py::save_params` (a deliberately tiny
//! safetensors-like container, shared by test fixtures on both sides).
//!
//! ```text
//! magic   b"UVMT"
//! version u32 le
//! count   u32 le
//! per tensor:
//!   name_len u16 le, name bytes (utf-8)
//!   dtype    u8   (0 = f32, 1 = i32, 2 = int4-packed-f32,
//!                  3 = scaled-int4: f32 le scale, then nibbles)
//!   ndim     u8
//!   dims     u32 le × ndim
//!   nbytes   u64 le
//!   data     nbytes
//! ```
//!
//! int4 tensors store two 4-bit codes per byte and are dequantized to
//! f32 at load — the Table 7 storage story, executed for real. dtype 2
//! is the python/aot fixed [-8, 8] grid; dtype 3 (what the Rust
//! backends' `save(int4)` writes) prefixes a per-tensor power-of-two
//! scale so zero-centred trained weights survive — see
//! [`crate::predictor::quant`].

use crate::predictor::quant;
use anyhow::{bail, Result};
use std::io::Read;
use std::path::Path;

const MAGIC: &[u8; 4] = b"UVMT";

/// Raw per-tensor payload retained from a dtype-3 (scaled-int4) load:
/// the power-of-two scale plus the untouched nibble buffer, so the
/// integer inference tiers (`predictor::kernel::QuantizedLinear`) can
/// run directly on the stored codes without materializing f32 weights.
#[derive(Debug, Clone)]
pub struct QuantPayload {
    pub scale: f32,
    /// Nibble-packed codes, low nibble first (see `predictor::quant`).
    pub packed: Vec<u8>,
}

#[derive(Debug, Clone)]
pub struct NamedTensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
    /// dtype byte as stored (0 f32, 2 int4) — kept for footprint
    /// accounting.
    pub stored_dtype: u8,
    pub stored_bytes: u64,
    /// Present iff the tensor was stored as dtype 3; `data` still
    /// holds the dequantized f32 view for the exact/fast tiers.
    pub quant: Option<QuantPayload>,
}

impl NamedTensor {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct TensorStore {
    pub tensors: Vec<NamedTensor>,
}

fn read_exact<R: Read>(r: &mut R, n: usize) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn u16_le<R: Read>(r: &mut R) -> Result<u16> {
    Ok(u16::from_le_bytes(read_exact(r, 2)?.try_into().unwrap()))
}
fn u32_le<R: Read>(r: &mut R) -> Result<u32> {
    Ok(u32::from_le_bytes(read_exact(r, 4)?.try_into().unwrap()))
}
fn u64_le<R: Read>(r: &mut R) -> Result<u64> {
    Ok(u64::from_le_bytes(read_exact(r, 8)?.try_into().unwrap()))
}

impl TensorStore {
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let magic = read_exact(&mut f, 4)?;
        if magic != MAGIC {
            bail!("{}: bad magic {magic:?}", path.display());
        }
        let version = u32_le(&mut f)?;
        if version != 1 {
            bail!("{}: unsupported version {version}", path.display());
        }
        let count = u32_le(&mut f)? as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = u16_le(&mut f)? as usize;
            let name = String::from_utf8(read_exact(&mut f, name_len)?)?;
            let dtype = read_exact(&mut f, 1)?[0];
            let ndim = read_exact(&mut f, 1)?[0] as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(u32_le(&mut f)? as usize);
            }
            let nbytes = u64_le(&mut f)?;
            let raw = read_exact(&mut f, nbytes as usize)?;
            let numel: usize = dims.iter().product();
            let mut retained = None;
            let data = match dtype {
                0 => {
                    if raw.len() != numel * 4 {
                        bail!("{name}: f32 size mismatch {} vs {numel}", raw.len());
                    }
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect()
                }
                1 => {
                    // i32 stored tensors are converted to f32 (only
                    // used for integer side tables).
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()) as f32)
                        .collect()
                }
                2 => {
                    if raw.len() < numel.div_ceil(2) {
                        bail!("{name}: int4 buffer too small");
                    }
                    quant::unpack(&raw, numel)
                }
                3 => {
                    if raw.len() < 4 + numel.div_ceil(2) {
                        bail!("{name}: scaled-int4 buffer too small");
                    }
                    let scale = f32::from_le_bytes(raw[0..4].try_into().unwrap());
                    retained = Some(QuantPayload { scale, packed: raw[4..].to_vec() });
                    quant::unpack_scaled(&raw[4..], scale, numel)
                }
                d => bail!("{name}: unknown dtype {d}"),
            };
            tensors.push(NamedTensor {
                name,
                dims,
                data,
                stored_dtype: dtype,
                stored_bytes: nbytes,
                quant: retained,
            });
        }
        Ok(Self { tensors })
    }

    /// Total stored bytes (Table 7 accounting).
    pub fn stored_bytes(&self) -> u64 {
        self.tensors.iter().map(|t| t.stored_bytes).sum()
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }
}

/// Test-only writer (mirrors the python writer bit-for-bit) — also
/// used by `predictor::quant` round-trip tests and benches.
pub fn write_store(path: &Path, tensors: &[(String, Vec<usize>, Vec<f32>, u8)]) -> Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&1u32.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, dims, data, dtype) in tensors {
        f.write_all(&(name.len() as u16).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&[*dtype, dims.len() as u8])?;
        for d in dims {
            f.write_all(&(*d as u32).to_le_bytes())?;
        }
        let raw: Vec<u8> = match dtype {
            0 => data.iter().flat_map(|v| v.to_le_bytes()).collect(),
            2 => quant::pack(data),
            3 => {
                let (scale, packed) = quant::pack_scaled(data);
                scale.to_le_bytes().into_iter().chain(packed).collect()
            }
            d => bail!("writer: unsupported dtype {d}"),
        };
        f.write_all(&(raw.len() as u64).to_le_bytes())?;
        f.write_all(&raw)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let dir = crate::util::TestDir::new();
        let p = dir.file("t.bin");
        let data = vec![1.0f32, -2.5, 3.25];
        write_store(&p, &[("w".into(), vec![3], data.clone(), 0)]).unwrap();
        let s = TensorStore::load(&p).unwrap();
        assert_eq!(s.tensors.len(), 1);
        assert_eq!(s.tensors[0].name, "w");
        assert_eq!(s.tensors[0].dims, vec![3]);
        assert_eq!(s.tensors[0].data, data);
    }

    #[test]
    fn int4_dequantizes_with_bounded_error() {
        let dir = crate::util::TestDir::new();
        let p = dir.file("q.bin");
        let data = vec![-8.0f32, -1.1, 0.0, 2.7, 8.0];
        write_store(&p, &[("q".into(), vec![5], data.clone(), 2)]).unwrap();
        let s = TensorStore::load(&p).unwrap();
        let t = &s.tensors[0];
        assert_eq!(t.stored_bytes, 3, "5 nibbles → 3 bytes");
        for (a, b) in data.iter().zip(&t.data) {
            assert!((a - b).abs() <= quant::max_quant_error() + 1e-6);
        }
    }

    #[test]
    fn scaled_int4_preserves_zero_and_small_weights() {
        let dir = crate::util::TestDir::new();
        let p = dir.file("q3.bin");
        // Zero-centred trained-weight shapes the fixed grid destroys.
        let data = vec![0.0f32, 0.07, -0.03, 1.0, -0.52];
        write_store(&p, &[("q".into(), vec![5], data.clone(), 3)]).unwrap();
        let s = TensorStore::load(&p).unwrap();
        let t = &s.tensors[0];
        assert_eq!(t.stored_dtype, 3);
        assert_eq!(t.stored_bytes, 4 + 3, "f32 scale + 5 nibbles → 7 bytes");
        assert_eq!(t.data[0], 0.0, "zero must survive scaled int4");
        for (a, b) in data.iter().zip(&t.data) {
            assert!((a - b).abs() <= 1.0 / 7.0 + 1e-6, "v={a} back={b}");
        }
    }

    #[test]
    fn scaled_int4_retains_raw_codes() {
        let dir = crate::util::TestDir::new();
        let p = dir.file("qr.bin");
        let data = vec![0.0f32, 0.07, -0.03, 1.0, -0.52];
        write_store(
            &p,
            &[("q".into(), vec![5], data.clone(), 3), ("f".into(), vec![5], data.clone(), 0)],
        )
        .unwrap();
        let s = TensorStore::load(&p).unwrap();
        let q = s.tensors[0].quant.as_ref().expect("dtype-3 keeps its raw payload");
        let (scale, packed) = quant::pack_scaled(&data);
        assert_eq!(q.scale, scale);
        assert_eq!(q.packed, packed);
        assert_eq!(quant::unpack_scaled(&q.packed, q.scale, 5), s.tensors[0].data);
        assert!(s.tensors[1].quant.is_none(), "f32 tensors carry no quant payload");
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = crate::util::TestDir::new();
        let p = dir.file("bad.bin");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(TensorStore::load(&p).is_err());
    }

    #[test]
    fn multi_tensor_order_preserved() {
        let dir = crate::util::TestDir::new();
        let p = dir.file("m.bin");
        write_store(
            &p,
            &[
                ("a".into(), vec![2], vec![1.0, 2.0], 0),
                ("b".into(), vec![1, 2], vec![3.0, 4.0], 0),
            ],
        )
        .unwrap();
        let s = TensorStore::load(&p).unwrap();
        assert_eq!(s.tensors[0].name, "a");
        assert_eq!(s.tensors[1].name, "b");
        assert_eq!(s.total_params(), 4);
    }
}
