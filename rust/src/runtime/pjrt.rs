//! PJRT execution: compile HLO text once, keep parameters
//! device-resident, and serve batched inference / fine-tune steps to
//! the coordinator. Selected at runtime by `--backend pjrt`
//! (DESIGN.md §6); the offline-clean alternative with real learning is
//! the native backend in `predictor/native.rs` (`--backend native`).

use crate::predictor::{ClassId, LabelledWindow, PredictorBackend, Window};
use crate::runtime::manifest::ModelEntry;
use crate::runtime::params::TensorStore;
use anyhow::{bail, Result};
use std::path::Path;

/// Thin wrapper over the PJRT CPU client.
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu().map_err(wrap)? })
    }

    /// Load + compile an HLO-text module.
    pub fn compile_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path).map_err(wrap)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(wrap)
    }
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

/// Split a packed f32 vector into per-tensor slices by shape, in
/// order — the inverse of the train module's `concatenate(ravel(p))`
/// (see aot.py::lower_train). Errors on any length mismatch.
fn split_packed<'a>(
    flat: &'a [f32],
    dims_list: &'a [Vec<usize>],
) -> Result<Vec<(&'a [usize], &'a [f32])>> {
    let mut offset = 0usize;
    let mut out = Vec::with_capacity(dims_list.len());
    for dims in dims_list {
        let n: usize = dims.iter().product();
        if offset + n > flat.len() {
            bail!("packed params too short: {} < {}", flat.len(), offset + n);
        }
        out.push((dims.as_slice(), &flat[offset..offset + n]));
        offset += n;
    }
    if offset != flat.len() {
        bail!("packed params length mismatch: {} != {}", offset, flat.len());
    }
    Ok(out)
}

/// A compiled model with device-resident parameters.
///
/// Executable calling convention (fixed by `python/compile/aot.py`):
/// * infer: `(p_0, …, p_{k-1}, tokens i32[B,S,F]) -> (logits f32[B,C],)`
/// * train: `(p_0, …, p_{k-1}, tokens, labels i32[B]) ->
///           (p_0', …, p_{k-1}', loss f32[])`
pub struct ModelExecutable {
    rt: PjrtRuntime,
    infer: xla::PjRtLoadedExecutable,
    train: Option<xla::PjRtLoadedExecutable>,
    /// Parameters as device buffers, in argument order.
    params: Vec<xla::PjRtBuffer>,
    /// Parameter shapes (tensor-store order) for re-splitting the
    /// train step's packed output.
    param_dims: Vec<Vec<usize>>,
    pub batch: usize,
    pub train_batch: usize,
    pub seq_len: usize,
    pub n_features: usize,
    pub n_classes: usize,
    pub stored_param_bytes: u64,
    pub infer_calls: u64,
    pub train_calls: u64,
    pub infer_wall_ns: u64,
}

impl ModelExecutable {
    /// Load a model from the artifacts directory per its manifest
    /// entry.
    pub fn load(dir: &Path, entry: &ModelEntry) -> Result<Self> {
        let rt = PjrtRuntime::cpu()?;
        Self::load_with_runtime(&rt, dir, entry)
    }

    /// Load sharing an existing client (PJRT CPU clients do not
    /// tolerate rapid destroy/re-create churn well — processes that
    /// load several models should share one runtime).
    pub fn load_with_runtime(rt: &PjrtRuntime, dir: &Path, entry: &ModelEntry) -> Result<Self> {
        let infer = rt.compile_hlo_text(&dir.join(&entry.infer_hlo))?;
        let train = match &entry.train_hlo {
            Some(t) => Some(rt.compile_hlo_text(&dir.join(t))?),
            None => None,
        };
        let store = TensorStore::load(&dir.join(&entry.params))?;
        let param_dims: Vec<Vec<usize>> = store.tensors.iter().map(|t| t.dims.clone()).collect();
        if store.tensors.len() != entry.n_params {
            bail!(
                "param count mismatch: store has {}, manifest says {}",
                store.tensors.len(),
                entry.n_params
            );
        }
        let stored_param_bytes = store.stored_bytes();
        let params = store
            .tensors
            .iter()
            .map(|t| {
                rt.client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.dims, None)
                    .map_err(wrap)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            rt: PjrtRuntime { client: rt.client.clone() },
            infer,
            train,
            params,
            param_dims,
            batch: entry.batch,
            train_batch: entry.train_batch,
            seq_len: entry.seq_len,
            n_features: entry.n_features,
            n_classes: entry.n_classes,
            stored_param_bytes,
            infer_calls: 0,
            train_calls: 0,
            infer_wall_ns: 0,
        })
    }

    pub fn has_train(&self) -> bool {
        self.train.is_some()
    }

    /// Run one inference batch. `tokens` is row-major
    /// `[batch, seq_len, n_features]` (short batches are zero-padded
    /// by the caller). Returns the logits `[batch, n_classes]`.
    pub fn infer(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let expect = self.batch * self.seq_len * self.n_features;
        if tokens.len() != expect {
            bail!("tokens len {} != {expect}", tokens.len());
        }
        let t0 = std::time::Instant::now();
        let tok_buf = self
            .rt
            .client
            .buffer_from_host_buffer::<i32>(
                tokens,
                &[self.batch, self.seq_len, self.n_features],
                None,
            )
            .map_err(wrap)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&tok_buf);
        let out = self.infer.execute_b(&args).map_err(wrap)?;
        let lit = out[0][0].to_literal_sync().map_err(wrap)?;
        // aot.py lowers with return_tuple=True → 1-tuple of logits.
        let logits = lit.to_tuple1().map_err(wrap)?.to_vec::<f32>().map_err(wrap)?;
        if logits.len() != self.batch * self.n_classes {
            bail!("logits len {} != {}", logits.len(), self.batch * self.n_classes);
        }
        self.infer_calls += 1;
        self.infer_wall_ns += t0.elapsed().as_nanos() as u64;
        Ok(logits)
    }

    /// One SGD fine-tune step; updates the device-resident parameters
    /// in place and returns the loss.
    pub fn train_step(&mut self, tokens: &[i32], labels: &[i32]) -> Result<f32> {
        let Some(train) = &self.train else { bail!("model has no train executable") };
        let expect = self.train_batch * self.seq_len * self.n_features;
        if tokens.len() != expect || labels.len() != self.train_batch {
            bail!("train shapes: tokens {} labels {}", tokens.len(), labels.len());
        }
        let tok_buf = self
            .rt
            .client
            .buffer_from_host_buffer::<i32>(
                tokens,
                &[self.train_batch, self.seq_len, self.n_features],
                None,
            )
            .map_err(wrap)?;
        let lab_buf = self
            .rt
            .client
            .buffer_from_host_buffer::<i32>(labels, &[self.train_batch], None)
            .map_err(wrap)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&tok_buf);
        args.push(&lab_buf);
        // The train module returns (packed_params f32[N], loss): a
        // 2-tuple, the same tuple arity family the infer path already
        // exercises safely. Split the packed vector by the stored
        // shapes and re-upload per-tensor buffers.
        let out = train.execute_b(&args).map_err(wrap)?;
        let lit = out[0][0].to_literal_sync().map_err(wrap)?;
        let (packed, loss_lit) = lit.to_tuple2().map_err(wrap)?;
        let flat = packed.to_vec::<f32>().map_err(wrap)?;
        let loss = loss_lit.to_vec::<f32>().map_err(wrap)?[0];
        let mut new_params = Vec::with_capacity(self.param_dims.len());
        for (dims, chunk) in split_packed(&flat, &self.param_dims)? {
            new_params.push(
                self.rt.client.buffer_from_host_buffer::<f32>(chunk, dims, None).map_err(wrap)?,
            );
        }
        self.params = new_params;
        self.train_calls += 1;
        Ok(loss)
    }

    /// Mean wall-clock per inference call (perf telemetry).
    pub fn mean_infer_us(&self) -> f64 {
        if self.infer_calls == 0 {
            0.0
        } else {
            self.infer_wall_ns as f64 / self.infer_calls as f64 / 1e3
        }
    }
}

/// [`PredictorBackend`] over a [`ModelExecutable`] — what the DL
/// prefetcher and the coordinator actually call.
pub struct PjrtBackend {
    pub model: ModelExecutable,
    /// Learning rate is baked into the train HLO; kept for reporting.
    pub arch: String,
}

// SAFETY: the `xla` crate's handles are !Send only because the client
// is an `Rc` shared by the executables and buffers. A `PjrtBackend`
// owns its `ModelExecutable`, which owns the runtime (the only client
// `Rc` root) *and* every buffer cloned from it — the whole Rc cluster
// moves between threads as one unit, and the PJRT C API itself is
// thread-safe. The coordinator moves the backend into exactly one
// worker thread and never shares it.
unsafe impl Send for PjrtBackend {}

impl PjrtBackend {
    pub fn new(model: ModelExecutable, arch: String) -> Self {
        Self { model, arch }
    }

    /// Flatten + zero-pad windows into one fixed-shape token batch.
    fn encode_batch(&self, windows: &[Window], b: usize) -> Vec<i32> {
        let (s, f) = (self.model.seq_len, self.model.n_features);
        let mut tokens = vec![0i32; b * s * f];
        for (i, w) in windows.iter().enumerate().take(b) {
            // Right-align shorter windows so the most recent token is
            // always at the end (matches training-time layout).
            let skip = s.saturating_sub(w.tokens.len());
            for (j, t) in w.tokens.iter().rev().take(s).rev().enumerate() {
                let base = (i * s + skip + j) * f;
                tokens[base] = t.pc_id;
                tokens[base + 1] = t.page_id;
                tokens[base + 2] = t.delta_id;
            }
        }
        tokens
    }
}

impl PredictorBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn predict(&mut self, windows: &[Window]) -> Vec<ClassId> {
        let mut out = Vec::with_capacity(windows.len());
        for chunk in windows.chunks(self.model.batch) {
            let tokens = self.encode_batch(chunk, self.model.batch);
            match self.model.infer(&tokens) {
                Ok(logits) => {
                    for row in 0..chunk.len() {
                        let slice =
                            &logits[row * self.model.n_classes..(row + 1) * self.model.n_classes];
                        let argmax = slice
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                            .map(|(i, _)| i as ClassId)
                            .unwrap_or(0);
                        out.push(argmax);
                    }
                }
                Err(e) => {
                    // Inference failure degrades to OOV (no extra
                    // prefetch) rather than killing the run.
                    eprintln!("pjrt inference error: {e}");
                    out.extend(std::iter::repeat(self.model.n_classes as ClassId - 1).take(chunk.len()));
                }
            }
        }
        out
    }

    fn finetune(&mut self, batch: &[LabelledWindow]) -> Option<f64> {
        if !self.model.has_train() || batch.is_empty() {
            return None;
        }
        let b = self.model.train_batch;
        let mut losses = Vec::new();
        for chunk in batch.chunks(b) {
            if chunk.len() < b {
                break; // train HLO has a fixed batch; drop the tail
            }
            let windows: Vec<Window> = chunk.iter().map(|l| l.window.clone()).collect();
            let tokens = self.encode_batch(&windows, b);
            let labels: Vec<i32> = chunk.iter().map(|l| l.label).collect();
            match self.model.train_step(&tokens, &labels) {
                Ok(loss) => losses.push(loss as f64),
                Err(e) => {
                    eprintln!("pjrt finetune error: {e}");
                    return None;
                }
            }
        }
        (!losses.is_empty()).then(|| losses.iter().sum::<f64>() / losses.len() as f64)
    }

    fn n_classes(&self) -> usize {
        self.model.n_classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::FeatTok;

    // Full PJRT round-trip tests live in rust/tests/runtime_pjrt.rs
    // (they need artifacts); here we cover the pure encode logic via a
    // stub-shaped struct.

    #[test]
    fn split_packed_roundtrip() {
        let dims = vec![vec![2, 3], vec![4], vec![1, 1, 1]];
        let flat: Vec<f32> = (0..11).map(|i| i as f32).collect();
        let parts = split_packed(&flat, &dims).unwrap();
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].1, &flat[0..6]);
        assert_eq!(parts[1].1, &flat[6..10]);
        assert_eq!(parts[2].1, &flat[10..11]);
    }

    #[test]
    fn split_packed_rejects_length_mismatch() {
        let dims = vec![vec![2, 2]];
        assert!(split_packed(&[1.0; 3], &dims).is_err(), "too short");
        assert!(split_packed(&[1.0; 5], &dims).is_err(), "too long");
    }

    #[test]
    fn encode_right_aligns_short_windows() {
        // Build a PjrtBackend-shaped encoder by constructing the token
        // layout manually (encode only reads batch/seq/features).
        let w = Window {
            tokens: vec![
                FeatTok { pc_id: 1, page_id: 2, delta_id: 3 },
                FeatTok { pc_id: 4, page_id: 5, delta_id: 6 },
            ],
        };
        // Expected layout for seq=3, feat=3: one zero token then the two.
        let (b, s, f) = (2usize, 3usize, 3usize);
        let mut tokens = vec![0i32; b * s * f];
        let windows = [w];
        for (i, w) in windows.iter().enumerate().take(b) {
            let skip = s.saturating_sub(w.tokens.len());
            for (j, t) in w.tokens.iter().rev().take(s).rev().enumerate() {
                let base = (i * s + skip + j) * f;
                tokens[base] = t.pc_id;
                tokens[base + 1] = t.page_id;
                tokens[base + 2] = t.delta_id;
            }
        }
        assert_eq!(&tokens[0..3], &[0, 0, 0]);
        assert_eq!(&tokens[3..6], &[1, 2, 3]);
        assert_eq!(&tokens[6..9], &[4, 5, 6]);
        assert!(tokens[9..].iter().all(|&t| t == 0), "second row padded");
    }
}
