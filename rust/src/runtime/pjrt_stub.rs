//! Stub PJRT runtime, compiled when the `pjrt` cargo feature is off
//! (the default — the real path in `pjrt.rs` binds to the `xla` crate,
//! which needs a local XLA build that offline/CI environments lack).
//!
//! The stub mirrors the public API of the real module exactly, so
//! every caller (the `repro` binary, the eval runner, benches, tests)
//! compiles unchanged; any attempt to actually load or execute a model
//! fails with a descriptive error. Backend selection is explicit
//! (`--backend stride|native|pjrt`, DESIGN.md §6): only
//! `--backend pjrt` ever reaches this module, and default builds get
//! learned predictions from the pure-Rust native backend
//! ([`crate::predictor::native`], trained by `repro train`) — the
//! stride frequency vote remains the artifact-free floor.

use crate::predictor::{ClassId, LabelledWindow, PredictorBackend, Window};
use crate::runtime::manifest::ModelEntry;
use anyhow::{bail, Result};
use std::path::Path;

const UNAVAILABLE: &str =
    "built without the `pjrt` feature — PJRT execution unavailable; \
     rebuild with `--features pjrt` (needs the xla crate, see DESIGN.md §4), \
     or use `--backend native` (offline-clean learned model, `repro train`) \
     or `--backend stride` (frequency-vote floor) — DESIGN.md §6";

/// Stand-in for the PJRT CPU client wrapper.
pub struct PjrtRuntime {
    _private: (),
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        bail!(UNAVAILABLE)
    }
}

/// Stand-in for a compiled model with device-resident parameters.
/// Field layout mirrors the real `ModelExecutable` so telemetry call
/// sites compile; instances cannot be constructed (loads always fail).
pub struct ModelExecutable {
    pub batch: usize,
    pub train_batch: usize,
    pub seq_len: usize,
    pub n_features: usize,
    pub n_classes: usize,
    pub stored_param_bytes: u64,
    pub infer_calls: u64,
    pub train_calls: u64,
    pub infer_wall_ns: u64,
}

impl ModelExecutable {
    pub fn load(_dir: &Path, _entry: &ModelEntry) -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn load_with_runtime(_rt: &PjrtRuntime, _dir: &Path, _entry: &ModelEntry) -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn has_train(&self) -> bool {
        false
    }

    pub fn infer(&mut self, _tokens: &[i32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    pub fn train_step(&mut self, _tokens: &[i32], _labels: &[i32]) -> Result<f32> {
        bail!(UNAVAILABLE)
    }

    pub fn mean_infer_us(&self) -> f64 {
        0.0
    }
}

/// Stand-in [`PredictorBackend`] over a [`ModelExecutable`].
pub struct PjrtBackend {
    pub model: ModelExecutable,
    pub arch: String,
}

impl PjrtBackend {
    pub fn new(model: ModelExecutable, arch: String) -> Self {
        Self { model, arch }
    }
}

impl PredictorBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt-stub"
    }

    fn predict(&mut self, windows: &[Window]) -> Vec<ClassId> {
        // Unreachable in practice (no ModelExecutable can be built),
        // but degrade to OOV like the real backend does on error.
        vec![self.model.n_classes.saturating_sub(1) as ClassId; windows.len()]
    }

    fn finetune(&mut self, _batch: &[LabelledWindow]) -> Option<f64> {
        None
    }

    fn n_classes(&self) -> usize {
        self.model.n_classes
    }
}
