//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them on the request path —
//! python never runs here.
//!
//! Interchange format is **HLO text** (not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids — see
//! /opt/xla-example/README.md).
//!
//! Artifact layout (written by `make artifacts`):
//! ```text
//! artifacts/
//!   manifest.json            — model registry (this module's entry point)
//!   <model>.infer.hlo.txt    — logits = f(params…, tokens[B,S,3])
//!   <model>.train.hlo.txt    — (params…, loss) = g(params…, tokens, labels)
//!   <model>.params.bin       — tensor store (f32 or int4-packed)
//!   <model>.vocab.json       — delta vocabulary + feature encoders
//! ```
//!
//! The same manifest + tensor-store machinery also registers the
//! pure-Rust native backend's artifacts (`repro train` →
//! `<model>.native.params.bin`, manifest `arch = "native"`, no HLO
//! files); see DESIGN.md §6 for the backend matrix.

pub mod manifest;
pub mod params;
/// Real PJRT bindings (needs the `xla` crate and a local XLA build —
/// see DESIGN.md §4); compiled only with `--features pjrt`.
#[cfg(feature = "pjrt")]
pub mod pjrt;
/// API-compatible stub: loading a model reports that the binary was
/// built without PJRT, and callers degrade to the stride backend.
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use manifest::{Manifest, ModelEntry};
pub use params::{NamedTensor, QuantPayload, TensorStore};
pub use pjrt::{ModelExecutable, PjrtBackend, PjrtRuntime};
