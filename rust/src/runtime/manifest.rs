//! `artifacts/manifest.json` — the registry the coordinator uses to
//! find a model for a benchmark. Schema shared with
//! `python/compile/aot.py::write_manifest`.

use crate::util::Json;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct ModelEntry {
    /// HLO-text inference module (PJRT models). Empty for native
    /// artifacts (`arch = "native"`), whose weights in `params` are
    /// executed in-process by `predictor::native`.
    pub infer_hlo: String,
    pub train_hlo: Option<String>,
    pub params: String,
    pub vocab: String,
    /// Fixed inference batch size the HLO was lowered for.
    pub batch: usize,
    /// Fixed train-step batch size (defaults to `batch`).
    pub train_batch: usize,
    pub seq_len: usize,
    /// Features per token (revised predictor: 3 — PC, page, Δ).
    pub n_features: usize,
    /// Output classes incl. OOV.
    pub n_classes: usize,
    /// Flat parameter tensors, in executable argument order.
    pub n_params: usize,
    /// Architecture tag ("revised", "transformer", …).
    pub arch: String,
}

impl ModelEntry {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("params", Json::str(&self.params)),
            ("vocab", Json::str(&self.vocab)),
            ("batch", Json::Num(self.batch as f64)),
            ("train_batch", Json::Num(self.train_batch as f64)),
            ("seq_len", Json::Num(self.seq_len as f64)),
            ("n_features", Json::Num(self.n_features as f64)),
            ("n_classes", Json::Num(self.n_classes as f64)),
            ("n_params", Json::Num(self.n_params as f64)),
            ("arch", Json::str(&self.arch)),
        ];
        if !self.infer_hlo.is_empty() {
            pairs.push(("infer_hlo", Json::str(&self.infer_hlo)));
        }
        if let Some(t) = &self.train_hlo {
            pairs.push(("train_hlo", Json::str(t)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let s = |k: &str| -> Result<String> {
            Ok(j.req(k)?.as_str().ok_or_else(|| anyhow::anyhow!("{k}: not a string"))?.to_string())
        };
        let n = |k: &str| -> Result<usize> {
            j.req(k)?.as_usize().ok_or_else(|| anyhow::anyhow!("{k}: not a number"))
        };
        Ok(Self {
            // Optional: native entries carry no HLO.
            infer_hlo: j.get("infer_hlo").and_then(Json::as_str).unwrap_or("").to_string(),
            train_hlo: j.get("train_hlo").and_then(Json::as_str).map(|v| v.to_string()),
            params: s("params")?,
            vocab: s("vocab")?,
            batch: n("batch")?,
            train_batch: j.get("train_batch").and_then(Json::as_usize).unwrap_or(n("batch")?),
            seq_len: n("seq_len")?,
            n_features: n("n_features")?,
            n_classes: n("n_classes")?,
            n_params: n("n_params")?,
            arch: j.get("arch").and_then(Json::as_str).unwrap_or("").to_string(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u64,
    /// model key → entry. Keys are benchmark names plus "shared" (the
    /// paper's pretrained-on-5-benchmarks corpus model, §7.1).
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        if !path.exists() {
            anyhow::bail!("cannot read {} — run `make artifacts` first", path.display());
        }
        let j = Json::parse_file(&path)?;
        let mut models = BTreeMap::new();
        for (name, entry) in j
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("models: not an object"))?
        {
            models.insert(name.clone(), ModelEntry::from_json(entry)?);
        }
        Ok(Self { version: j.get("version").and_then(Json::as_u64).unwrap_or(1), models })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(self.version as f64)),
            (
                "models",
                Json::Obj(self.models.iter().map(|(k, v)| (k.clone(), v.to_json())).collect()),
            ),
        ])
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        self.to_json().write_file(&dir.join("manifest.json"))
    }

    /// Resolve a model for `benchmark`: explicit `model` if given,
    /// else the per-benchmark model, else "shared".
    pub fn resolve(&self, model: &str, benchmark: &str) -> Result<(&str, &ModelEntry)> {
        let candidates: Vec<&str> =
            if model.is_empty() { vec![benchmark, "shared"] } else { vec![model] };
        for key in candidates {
            if let Some((k, e)) = self.models.get_key_value(key) {
                return Ok((k.as_str(), e));
            }
        }
        anyhow::bail!(
            "no model for benchmark '{benchmark}' (asked '{model}'); available: {:?}",
            self.models.keys().collect::<Vec<_>>()
        )
    }

    /// Absolute path of an artifact file.
    pub fn path(dir: &Path, rel: &str) -> PathBuf {
        dir.join(rel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(tag: &str) -> ModelEntry {
        ModelEntry {
            infer_hlo: format!("{tag}.infer.hlo.txt"),
            train_hlo: Some(format!("{tag}.train.hlo.txt")),
            params: format!("{tag}.params.bin"),
            vocab: format!("{tag}.vocab.json"),
            batch: 8,
            train_batch: 16,
            seq_len: 30,
            n_features: 3,
            n_classes: 12,
            n_params: 10,
            arch: "revised".into(),
        }
    }

    #[test]
    fn resolve_prefers_benchmark_then_shared() {
        let mut models = BTreeMap::new();
        models.insert("shared".to_string(), entry("shared"));
        models.insert("atax".to_string(), entry("atax"));
        let m = Manifest { version: 1, models };
        assert_eq!(m.resolve("", "atax").unwrap().0, "atax");
        assert_eq!(m.resolve("", "nw").unwrap().0, "shared");
        assert_eq!(m.resolve("shared", "atax").unwrap().0, "shared");
        assert!(m.resolve("missing", "atax").is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = crate::util::TestDir::new();
        let mut models = BTreeMap::new();
        models.insert("shared".to_string(), entry("shared"));
        let m = Manifest { version: 2, models };
        m.save(dir.path()).unwrap();
        let back = Manifest::load(dir.path()).unwrap();
        assert_eq!(back.version, 2);
        let e = &back.models["shared"];
        assert_eq!(e.train_hlo.as_deref(), Some("shared.train.hlo.txt"));
        assert_eq!(e.n_classes, 12);
        assert_eq!(e.arch, "revised");
    }

    #[test]
    fn native_entry_roundtrips_without_hlo() {
        let dir = crate::util::TestDir::new();
        let mut models = BTreeMap::new();
        models.insert(
            "streamtriad".to_string(),
            ModelEntry {
                infer_hlo: String::new(),
                train_hlo: None,
                params: "streamtriad.native.params.bin".into(),
                vocab: "streamtriad.vocab.json".into(),
                batch: 64,
                train_batch: 64,
                seq_len: 30,
                n_features: 3,
                n_classes: 64,
                n_params: 96_000,
                arch: "native".into(),
            },
        );
        let m = Manifest { version: 1, models };
        m.save(dir.path()).unwrap();
        let text = std::fs::read_to_string(dir.path().join("manifest.json")).unwrap();
        assert!(!text.contains("infer_hlo"), "empty HLO field omitted: {text}");
        let back = Manifest::load(dir.path()).unwrap();
        let e = &back.models["streamtriad"];
        assert_eq!(e.arch, "native");
        assert!(e.infer_hlo.is_empty() && e.train_hlo.is_none());
        assert_eq!(e.n_classes, 64);
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let dir = crate::util::TestDir::new();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
