//! Fundamental domain types shared by every subsystem.
//!
//! All address arithmetic in the simulator is done on 4 KB *page
//! numbers* (`PageNum`), matching the paper's prefetch granularity
//! hierarchy: 4 KB page → 64 KB basic block (16 pages) → 2 MB root
//! chunk (512 pages).


/// Simulated GPU core cycles.
pub type Cycle = u64;
/// Logical client of the serving coordinator: one per replayed fault
/// stream (`repro serve --streams N`). The simulator side is
/// single-tenant (tenant 0); the coordinator threads the id through
/// every `FaultEvent`/`PrefetchCommand` so per-tenant state and
/// telemetry never mix.
pub type TenantId = u32;
/// Virtual byte address.
pub type VAddr = u64;
/// 4 KB virtual page number (`vaddr >> 12`).
pub type PageNum = u64;
/// Signed distance between two page numbers — the unit the predictor
/// classifies over (Hashemi et al.'s delta-vocabulary observation).
pub type PageDelta = i64;

/// Bytes per 4 KB page.
pub const PAGE_SIZE: u64 = 4096;
/// log2(PAGE_SIZE).
pub const PAGE_SHIFT: u32 = 12;
/// Pages per 64 KB basic block — the tree prefetcher's unit.
pub const PAGES_PER_BB: u64 = 16;
/// Pages per 2 MB root chunk — the tree prefetcher's top node.
pub const PAGES_PER_ROOT: u64 = 512;

/// Convert a byte address to its 4 KB page number.
#[inline]
pub fn page_of(vaddr: VAddr) -> PageNum {
    vaddr >> PAGE_SHIFT
}

/// First page of the 64 KB basic block containing `page`.
#[inline]
pub fn bb_base(page: PageNum) -> PageNum {
    page & !(PAGES_PER_BB - 1)
}

/// First page of the 2 MB root chunk containing `page`.
#[inline]
pub fn root_base(page: PageNum) -> PageNum {
    page & !(PAGES_PER_ROOT - 1)
}

/// Identifier of a streaming multiprocessor.
pub type SmId = u16;
/// Warp slot within an SM.
pub type WarpId = u16;
/// Cooperative thread array (thread block) id.
pub type CtaId = u32;

/// One coalesced device-memory access as observed by the GMMU — the
/// token unit of the paper's Figure 3. A "memory instruction" in the
/// SM model issues exactly one of these.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemAccess {
    /// Instruction address of the load/store (feature `PC`).
    pub pc: u64,
    /// Virtual byte address touched (already coalesced per warp).
    pub vaddr: VAddr,
    /// Id of the input array the address belongs to (feature `In`),
    /// `u8::MAX` when unknown.
    pub array_id: u8,
    /// True for stores (affects nothing in the timing model today but
    /// is carried in traces for feature parity with the paper).
    pub is_store: bool,
}

/// Where a warp-level operation came from; attached to every access at
/// GMMU arrival so the predictor can cluster on it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AccessOrigin {
    pub sm: SmId,
    pub warp: WarpId,
    pub cta: CtaId,
    /// Texture processing cluster: `sm / 2` on Pascal (GTX 1080Ti).
    pub tpc: u16,
    /// Kernel invocation index within the benchmark.
    pub kernel_id: u16,
}

/// A fully-qualified trace record: what `repro trace-gen` writes and
/// what the python data pipeline consumes (all 13 features of Figure 3
/// are derivable from this record plus its predecessor in the same
/// cluster).
#[derive(Debug, Clone, Copy)]
pub struct TraceRecord {
    pub cycle: Cycle,
    pub pc: u64,
    pub page: PageNum,
    pub sm: SmId,
    pub warp: WarpId,
    pub cta: CtaId,
    pub tpc: u16,
    pub kernel_id: u16,
    pub array_id: u8,
    /// 1 when this access raised a far-fault (page not resident).
    pub miss: u8,
}

/// Side of the CPU-GPU interconnect a page should prefer to live on —
/// the target of a `PreferredLocation` advise (mirrors
/// `cudaMemAdviseSetPreferredLocation`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum PreferredLocation {
    /// Keep the page host-side: device touches fault it over but it is
    /// not pinned on device.
    Host,
    /// Pin the page on device: it is never chosen as an eviction
    /// victim while the hint holds.
    Device,
}

/// Memory-usage hint attached to an `Advise` command — the modeled
/// subset of the `cudaMemAdvise` vocabulary (SNIPPETS.md snippets
/// 1-2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum AdviseHint {
    /// Read-duplicate: the host keeps a zero-cost read-only copy, so
    /// CPU touches never migrate the page back and evicting the device
    /// copy needs no writeback.
    ReadMostly,
    /// Preferred residency side (see [`PreferredLocation`]).
    PreferredLocation(PreferredLocation),
}

/// Outcome classification of a single device-memory access, used for
/// the paper's page-hit-rate metric (Table 10) and the coverage term
/// of unity (Table 11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessOutcome {
    /// Page resident on device — a page hit.
    Hit,
    /// Page in flight (demand fetch or prefetch already migrating);
    /// the warp waits for the arrival instead of raising a new fault.
    Coalesced { prefetched: bool },
    /// Page absent: full far-fault taken.
    FarFault,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_arithmetic() {
        assert_eq!(page_of(0), 0);
        assert_eq!(page_of(4095), 0);
        assert_eq!(page_of(4096), 1);
        assert_eq!(bb_base(17), 16);
        assert_eq!(bb_base(16), 16);
        assert_eq!(bb_base(15), 0);
        assert_eq!(root_base(513), 512);
        assert_eq!(root_base(511), 0);
    }

    #[test]
    fn block_sizes_match_paper() {
        assert_eq!(PAGES_PER_BB * PAGE_SIZE, 64 * 1024); // 64 KB basic block
        assert_eq!(PAGES_PER_ROOT * PAGE_SIZE, 2 * 1024 * 1024); // 2 MB chunk
    }
}
