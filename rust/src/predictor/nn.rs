//! Dense neural-net primitives for the native (pure-Rust) predictor
//! backend: deterministic weight init, linear/ReLU/softmax forward
//! ops (per-sample and batched — [`linear_forward_batch`] answers a
//! whole serving batch in one GEMM-shaped pass, bit-identical to the
//! per-row path), their backward passes, and SGD / Adam parameter
//! updates.
//!
//! Everything operates on flat `f32` slices (row-major matrices) so a
//! whole model lives in one parameter vector — one optimizer state,
//! one gradient buffer, one save/load path through
//! [`crate::runtime::params`]. No SIMD, no threads, no `rand`:
//! same-seed training must be byte-identical across runs (the
//! `rust/tests/native_backend.rs` suite pins this), and the shapes
//! involved (tens of thousands of parameters) keep scalar code fast
//! enough for the simulator's hot path.

use crate::util::XorShift64;

/// Uniform init in `[-bound, bound]` — deterministic for a given RNG
/// state, the standard fan-in-scaled scheme the callers pass in.
pub fn init_uniform(rng: &mut XorShift64, n: usize, bound: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.unit() as f32 * 2.0 - 1.0) * bound).collect()
}

/// `out = W·x + b` for a row-major `[out.len() × x.len()]` matrix.
pub fn linear_forward(w: &[f32], b: &[f32], x: &[f32], out: &mut [f32]) {
    let cols = x.len();
    debug_assert_eq!(w.len(), out.len() * cols);
    debug_assert_eq!(b.len(), out.len());
    for (r, o) in out.iter_mut().enumerate() {
        let row = &w[r * cols..(r + 1) * cols];
        let mut acc = b[r];
        for (wi, xi) in row.iter().zip(x) {
            acc += wi * xi;
        }
        *o = acc;
    }
}

/// Batched `Out = X·Wᵀ + b`: `xs` is a row-major `[n × in_dim]` input
/// matrix, `out` a row-major `[n × out_dim]` output — one GEMM-shaped
/// pass over the whole batch instead of `n` separate
/// [`linear_forward`] calls. Each output element accumulates its dot
/// product in the same order as [`linear_forward`], so the batched
/// path is **bit-identical** to the per-row path (the serving
/// coordinator relies on this: batching must never change a
/// prediction).
pub fn linear_forward_batch(
    w: &[f32],
    b: &[f32],
    xs: &[f32],
    out: &mut [f32],
    in_dim: usize,
    out_dim: usize,
) {
    debug_assert!(in_dim > 0 && out_dim > 0);
    debug_assert_eq!(w.len(), out_dim * in_dim);
    debug_assert_eq!(b.len(), out_dim);
    debug_assert_eq!(xs.len() % in_dim, 0);
    debug_assert_eq!(out.len(), (xs.len() / in_dim) * out_dim);
    for (x, o) in xs.chunks_exact(in_dim).zip(out.chunks_exact_mut(out_dim)) {
        for (r, or) in o.iter_mut().enumerate() {
            let row = &w[r * in_dim..(r + 1) * in_dim];
            let mut acc = b[r];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            *or = acc;
        }
    }
}

/// Backward of [`linear_forward`]: accumulates `dW += dy·xᵀ`,
/// `db += dy`, and — when an input gradient is wanted — `dx += Wᵀ·dy`.
pub fn linear_backward(
    w: &[f32],
    x: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dx: Option<&mut [f32]>,
) {
    let cols = x.len();
    debug_assert_eq!(w.len(), dy.len() * cols);
    for (r, &g) in dy.iter().enumerate() {
        db[r] += g;
        let dw_row = &mut dw[r * cols..(r + 1) * cols];
        for (dwi, xi) in dw_row.iter_mut().zip(x) {
            *dwi += g * xi;
        }
    }
    if let Some(dx) = dx {
        for (r, &g) in dy.iter().enumerate() {
            let row = &w[r * cols..(r + 1) * cols];
            for (dxi, wi) in dx.iter_mut().zip(row) {
                *dxi += g * wi;
            }
        }
    }
}

/// In-place ReLU.
pub fn relu(h: &mut [f32]) {
    for v in h {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Backward of ReLU given the *activated* output `h`: gradient is
/// zeroed wherever the unit was clamped.
pub fn relu_backward(h: &[f32], dh: &mut [f32]) {
    for (d, &a) in dh.iter_mut().zip(h) {
        if a <= 0.0 {
            *d = 0.0;
        }
    }
}

/// Numerically stable softmax in place.
pub fn softmax(z: &mut [f32]) {
    let max = z.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in z.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    if sum > 0.0 {
        for v in z.iter_mut() {
            *v /= sum;
        }
    }
}

/// Cross-entropy loss for `label` given softmax probabilities `p`;
/// also turns `p` into the logits gradient `p - onehot(label)` in
/// place (the usual fused softmax+CE backward).
pub fn cross_entropy_backward(p: &mut [f32], label: usize) -> f32 {
    debug_assert!(label < p.len());
    let loss = -p[label].max(1e-12).ln();
    p[label] -= 1.0;
    loss
}

/// Optimizer family for the native backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptKind {
    /// SGD with 0.9 momentum.
    Sgd,
    /// Adam (β₁ 0.9, β₂ 0.999, ε 1e-8) with bias correction.
    Adam,
}

impl OptKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "sgd" => Self::Sgd,
            "adam" => Self::Adam,
            _ => return None,
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Sgd => "sgd",
            Self::Adam => "adam",
        }
    }
}

const SGD_MOMENTUM: f32 = 0.9;
const ADAM_BETA1: f32 = 0.9;
const ADAM_BETA2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Dense first-order optimizer over one flat parameter vector.
#[derive(Debug, Clone)]
pub struct Optimizer {
    kind: OptKind,
    pub lr: f32,
    /// Momentum (SGD) / first-moment (Adam) buffer.
    m: Vec<f32>,
    /// Second-moment buffer (Adam only).
    v: Vec<f32>,
    t: u64,
}

impl Optimizer {
    pub fn new(kind: OptKind, lr: f32, n_params: usize) -> Self {
        let v = match kind {
            OptKind::Adam => vec![0.0; n_params],
            OptKind::Sgd => Vec::new(),
        };
        Self { kind, lr, m: vec![0.0; n_params], v, t: 0 }
    }

    pub fn kind(&self) -> OptKind {
        self.kind
    }

    /// One update step: `params -= lr · f(grads)`.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(params.len(), self.m.len());
        debug_assert_eq!(params.len(), grads.len());
        self.t += 1;
        match self.kind {
            OptKind::Sgd => {
                for ((p, m), &g) in params.iter_mut().zip(&mut self.m).zip(grads) {
                    *m = SGD_MOMENTUM * *m + g;
                    *p -= self.lr * *m;
                }
            }
            OptKind::Adam => {
                let bc1 = 1.0 - ADAM_BETA1.powi(self.t as i32);
                let bc2 = 1.0 - ADAM_BETA2.powi(self.t as i32);
                for (((p, m), v), &g) in
                    params.iter_mut().zip(&mut self.m).zip(&mut self.v).zip(grads)
                {
                    *m = ADAM_BETA1 * *m + (1.0 - ADAM_BETA1) * g;
                    *v = ADAM_BETA2 * *v + (1.0 - ADAM_BETA2) * g * g;
                    let m_hat = *m / bc1;
                    let v_hat = *v / bc2;
                    *p -= self.lr * m_hat / (v_hat.sqrt() + ADAM_EPS);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_forward_matches_hand_computation() {
        // W = [[1, 2], [3, 4]], b = [10, 20], x = [1, -1].
        let w = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0];
        let mut out = [0.0; 2];
        linear_forward(&w, &b, &[1.0, -1.0], &mut out);
        assert_eq!(out, [9.0, 19.0]);
    }

    #[test]
    fn batched_linear_bit_identical_to_per_row() {
        // Awkward values (no nice binary representations) so any
        // accumulation-order change would show up in the bits.
        let w: Vec<f32> = (0..6).map(|i| (i as f32 * 0.37 - 1.1) / 3.0).collect();
        let b = [0.123f32, -4.56];
        let xs: Vec<f32> = (0..9).map(|i| (i as f32 * 1.7 - 3.3) / 7.0).collect();
        let mut batched = [0.0f32; 6];
        linear_forward_batch(&w, &b, &xs, &mut batched, 3, 2);
        for i in 0..3 {
            let mut one = [0.0f32; 2];
            linear_forward(&w, &b, &xs[i * 3..(i + 1) * 3], &mut one);
            assert_eq!(one[..], batched[i * 2..(i + 1) * 2], "row {i}");
        }
    }

    #[test]
    fn batched_linear_empty_batch_is_noop() {
        let w = [1.0f32; 4];
        let b = [0.0f32; 2];
        let mut out: [f32; 0] = [];
        linear_forward_batch(&w, &b, &[], &mut out, 2, 2);
    }

    #[test]
    fn linear_backward_accumulates_all_three_grads() {
        let w = [1.0, 2.0, 3.0, 4.0];
        let x = [1.0, -1.0];
        let dy = [0.5, -0.25];
        let mut dw = [0.0; 4];
        let mut db = [0.0; 2];
        let mut dx = [0.0; 2];
        linear_backward(&w, &x, &dy, &mut dw, &mut db, Some(&mut dx));
        assert_eq!(db, dy);
        assert_eq!(dw, [0.5, -0.5, -0.25, 0.25]);
        // dx = Wᵀ·dy = [1*0.5 + 3*-0.25, 2*0.5 + 4*-0.25].
        assert_eq!(dx, [-0.25, 0.0]);
    }

    #[test]
    fn softmax_ce_gradient_is_p_minus_onehot() {
        let mut z = [1.0f32, 1.0, 1.0];
        softmax(&mut z);
        for v in z {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
        let mut p = [0.5f32, 0.25, 0.25];
        let loss = cross_entropy_backward(&mut p, 0);
        assert!((loss - 0.5f32.ln().abs()).abs() < 1e-6);
        assert!((p[0] + 0.5).abs() < 1e-6);
        assert!((p[1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn relu_and_backward_mask_agree() {
        let mut h = [-1.0f32, 0.0, 2.0];
        relu(&mut h);
        assert_eq!(h, [0.0, 0.0, 2.0]);
        let mut dh = [1.0f32, 1.0, 1.0];
        relu_backward(&h, &mut dh);
        assert_eq!(dh, [0.0, 0.0, 1.0]);
    }

    /// Both optimizers must drive a 1-D quadratic toward its minimum.
    #[test]
    fn optimizers_descend_a_quadratic() {
        for kind in [OptKind::Sgd, OptKind::Adam] {
            let mut opt = Optimizer::new(kind, 0.05, 1);
            let mut p = [4.0f32];
            for _ in 0..200 {
                let g = [2.0 * p[0]]; // d/dp of p².
                opt.step(&mut p, &g);
            }
            assert!(p[0].abs() < 0.5, "{kind:?} ended at {}", p[0]);
        }
    }

    #[test]
    fn init_is_deterministic_and_bounded() {
        let a = init_uniform(&mut XorShift64::new(7), 64, 0.1);
        let b = init_uniform(&mut XorShift64::new(7), 64, 0.1);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| v.abs() <= 0.1));
        assert!(a.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn optkind_parse_roundtrip() {
        assert_eq!(OptKind::parse("adam"), Some(OptKind::Adam));
        assert_eq!(OptKind::parse("sgd"), Some(OptKind::Sgd));
        assert_eq!(OptKind::parse("rmsprop"), None);
        assert_eq!(OptKind::Adam.as_str(), "adam");
    }
}
