//! Dense neural-net primitives for the pure-Rust predictor backends:
//! deterministic weight init, linear/ReLU/softmax forward ops
//! (per-sample and batched — [`linear_forward_batch`] answers a whole
//! serving batch in one GEMM-shaped pass, bit-identical to the per-row
//! path), layer normalization, tanh-GELU and scaled-dot-product
//! multi-head self-attention (the Transformer reference backend's
//! building blocks, `predictor/transformer.rs`), their backward
//! passes, and SGD / Adam parameter updates. Every backward here is
//! pinned numerically by the central-difference suite in
//! `rust/tests/grad_check.rs`.
//!
//! Everything operates on flat `f32` slices (row-major matrices) so a
//! whole model lives in one parameter vector — one optimizer state,
//! one gradient buffer, one save/load path through
//! [`crate::runtime::params`]. No SIMD, no threads, no `rand`:
//! same-seed training must be byte-identical across runs (the
//! `rust/tests/native_backend.rs` suite pins this), and the shapes
//! involved (tens of thousands of parameters) keep scalar code fast
//! enough for the simulator's hot path.

use crate::util::XorShift64;

/// Uniform init in `[-bound, bound]` — deterministic for a given RNG
/// state, the standard fan-in-scaled scheme the callers pass in.
pub fn init_uniform(rng: &mut XorShift64, n: usize, bound: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.unit() as f32 * 2.0 - 1.0) * bound).collect()
}

/// `out = W·x + b` for a row-major `[out.len() × x.len()]` matrix.
pub fn linear_forward(w: &[f32], b: &[f32], x: &[f32], out: &mut [f32]) {
    let cols = x.len();
    debug_assert_eq!(w.len(), out.len() * cols);
    debug_assert_eq!(b.len(), out.len());
    for (r, o) in out.iter_mut().enumerate() {
        let row = &w[r * cols..(r + 1) * cols];
        let mut acc = b[r];
        for (wi, xi) in row.iter().zip(x) {
            acc += wi * xi;
        }
        *o = acc;
    }
}

/// Batched `Out = X·Wᵀ + b`: `xs` is a row-major `[n × in_dim]` input
/// matrix, `out` a row-major `[n × out_dim]` output — one GEMM-shaped
/// pass over the whole batch instead of `n` separate
/// [`linear_forward`] calls. Each output element accumulates its dot
/// product in the same order as [`linear_forward`], so the batched
/// path is **bit-identical** to the per-row path (the serving
/// coordinator relies on this: batching must never change a
/// prediction).
pub fn linear_forward_batch(
    w: &[f32],
    b: &[f32],
    xs: &[f32],
    out: &mut [f32],
    in_dim: usize,
    out_dim: usize,
) {
    debug_assert!(in_dim > 0 && out_dim > 0);
    debug_assert_eq!(w.len(), out_dim * in_dim);
    debug_assert_eq!(b.len(), out_dim);
    debug_assert_eq!(xs.len() % in_dim, 0);
    debug_assert_eq!(out.len(), (xs.len() / in_dim) * out_dim);
    for (x, o) in xs.chunks_exact(in_dim).zip(out.chunks_exact_mut(out_dim)) {
        for (r, or) in o.iter_mut().enumerate() {
            let row = &w[r * in_dim..(r + 1) * in_dim];
            let mut acc = b[r];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            *or = acc;
        }
    }
}

/// Backward of [`linear_forward`]: accumulates `dW += dy·xᵀ`,
/// `db += dy`, and — when an input gradient is wanted — `dx += Wᵀ·dy`.
pub fn linear_backward(
    w: &[f32],
    x: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dx: Option<&mut [f32]>,
) {
    let cols = x.len();
    debug_assert_eq!(w.len(), dy.len() * cols);
    for (r, &g) in dy.iter().enumerate() {
        db[r] += g;
        let dw_row = &mut dw[r * cols..(r + 1) * cols];
        for (dwi, xi) in dw_row.iter_mut().zip(x) {
            *dwi += g * xi;
        }
    }
    if let Some(dx) = dx {
        for (r, &g) in dy.iter().enumerate() {
            let row = &w[r * cols..(r + 1) * cols];
            for (dxi, wi) in dx.iter_mut().zip(row) {
                *dxi += g * wi;
            }
        }
    }
}

/// In-place ReLU.
pub fn relu(h: &mut [f32]) {
    for v in h {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Backward of ReLU given the *activated* output `h`: gradient is
/// zeroed wherever the unit was clamped.
pub fn relu_backward(h: &[f32], dh: &mut [f32]) {
    for (d, &a) in dh.iter_mut().zip(h) {
        if a <= 0.0 {
            *d = 0.0;
        }
    }
}

/// Numerically stable softmax in place.
pub fn softmax(z: &mut [f32]) {
    let max = z.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in z.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    if sum > 0.0 {
        for v in z.iter_mut() {
            *v /= sum;
        }
    }
}

/// Cross-entropy loss for `label` given softmax probabilities `p`;
/// also turns `p` into the logits gradient `p - onehot(label)` in
/// place (the usual fused softmax+CE backward).
pub fn cross_entropy_backward(p: &mut [f32], label: usize) -> f32 {
    debug_assert!(label < p.len());
    let loss = -p[label].max(1e-12).ln();
    p[label] -= 1.0;
    loss
}

/// Layer-norm variance epsilon (shared by forward and backward).
pub const LN_EPS: f32 = 1e-5;

/// Layer normalization over one row: `out = γ·x̂ + β` with
/// `x̂ = (x − mean) · rstd`. Writes the normalized row into `xhat`
/// (the backward pass needs it) and returns `rstd`.
pub fn layer_norm_forward(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    xhat: &mut [f32],
    out: &mut [f32],
) -> f32 {
    let n = x.len();
    debug_assert!(n > 0);
    debug_assert_eq!(gamma.len(), n);
    debug_assert_eq!(beta.len(), n);
    debug_assert_eq!(xhat.len(), n);
    debug_assert_eq!(out.len(), n);
    let mut mean = 0.0f32;
    for &v in x {
        mean += v;
    }
    mean /= n as f32;
    let mut var = 0.0f32;
    for &v in x {
        let d = v - mean;
        var += d * d;
    }
    var /= n as f32;
    let rstd = 1.0 / (var + LN_EPS).sqrt();
    for i in 0..n {
        xhat[i] = (x[i] - mean) * rstd;
        out[i] = gamma[i] * xhat[i] + beta[i];
    }
    rstd
}

/// Backward of [`layer_norm_forward`]: accumulates `dγ += dy·x̂`,
/// `dβ += dy` and, with `dx̂ = dy·γ`,
/// `dx += rstd · (dx̂ − mean(dx̂) − x̂ · mean(dx̂ ⊙ x̂))`.
#[allow(clippy::too_many_arguments)]
pub fn layer_norm_backward(
    dy: &[f32],
    gamma: &[f32],
    xhat: &[f32],
    rstd: f32,
    dgamma: &mut [f32],
    dbeta: &mut [f32],
    dx: &mut [f32],
) {
    let n = dy.len();
    debug_assert!(n > 0);
    debug_assert_eq!(gamma.len(), n);
    debug_assert_eq!(xhat.len(), n);
    debug_assert_eq!(dgamma.len(), n);
    debug_assert_eq!(dbeta.len(), n);
    debug_assert_eq!(dx.len(), n);
    let inv = 1.0 / n as f32;
    let mut s1 = 0.0f32; // Σ dx̂
    let mut s2 = 0.0f32; // Σ dx̂ ⊙ x̂
    for i in 0..n {
        let dxh = dy[i] * gamma[i];
        s1 += dxh;
        s2 += dxh * xhat[i];
        dgamma[i] += dy[i] * xhat[i];
        dbeta[i] += dy[i];
    }
    for i in 0..n {
        let dxh = dy[i] * gamma[i];
        dx[i] += rstd * (dxh - inv * s1 - xhat[i] * inv * s2);
    }
}

/// `√(2/π)` — the tanh-GELU constant.
const GELU_C: f32 = 0.797_884_56;
const GELU_A: f32 = 0.044_715;

/// Tanh-approximation GELU:
/// `gelu(x) = 0.5·x·(1 + tanh(√(2/π)·(x + 0.044715·x³)))`.
pub fn gelu_forward(x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, &v) in out.iter_mut().zip(x) {
        let u = GELU_C * (v + GELU_A * v * v * v);
        *o = 0.5 * v * (1.0 + u.tanh());
    }
}

/// Backward of [`gelu_forward`] given the *pre-activation* input `x`
/// (unlike ReLU, the GELU derivative is not recoverable from the
/// output alone): accumulates `dx += dy · gelu'(x)`.
pub fn gelu_backward(x: &[f32], dy: &[f32], dx: &mut [f32]) {
    debug_assert_eq!(x.len(), dy.len());
    debug_assert_eq!(x.len(), dx.len());
    for i in 0..x.len() {
        let v = x[i];
        let u = GELU_C * (v + GELU_A * v * v * v);
        let t = u.tanh();
        let du = GELU_C * (1.0 + 3.0 * GELU_A * v * v);
        dx[i] += dy[i] * (0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * du);
    }
}

/// Scaled-dot-product multi-head self-attention over one window.
///
/// `q`, `k`, `v` are row-major `[seq × (n_heads·d_head)]` with head
/// `h` owning columns `h·d_head .. (h+1)·d_head`. Writes the softmaxed
/// attention weights into `attn` (`[n_heads × seq × seq]`, row
/// `(h·seq + i)·seq ..` = query `i`'s distribution over key slots —
/// the map `repro analyze` reads) and the per-head context vectors
/// into `ctx` (`[seq × (n_heads·d_head)]`). Full bidirectional
/// attention: a prefetch history window is an encoder input, not an
/// autoregressive stream, so no causal mask. Scalar, fixed iteration
/// order — bit-deterministic.
#[allow(clippy::too_many_arguments)]
pub fn attention_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    seq: usize,
    n_heads: usize,
    d_head: usize,
    attn: &mut [f32],
    ctx: &mut [f32],
) {
    let d = n_heads * d_head;
    debug_assert!(seq > 0 && n_heads > 0 && d_head > 0);
    debug_assert_eq!(q.len(), seq * d);
    debug_assert_eq!(k.len(), seq * d);
    debug_assert_eq!(v.len(), seq * d);
    debug_assert_eq!(attn.len(), n_heads * seq * seq);
    debug_assert_eq!(ctx.len(), seq * d);
    let scale = 1.0 / (d_head as f32).sqrt();
    ctx.fill(0.0);
    for h in 0..n_heads {
        let off = h * d_head;
        for i in 0..seq {
            let row = &mut attn[(h * seq + i) * seq..(h * seq + i + 1) * seq];
            let qi = &q[i * d + off..i * d + off + d_head];
            for (j, r) in row.iter_mut().enumerate() {
                let kj = &k[j * d + off..j * d + off + d_head];
                let mut acc = 0.0f32;
                for (a, b) in qi.iter().zip(kj) {
                    acc += a * b;
                }
                *r = acc * scale;
            }
            softmax(row);
            let ci = &mut ctx[i * d + off..i * d + off + d_head];
            for (j, &w) in row.iter().enumerate() {
                let vj = &v[j * d + off..j * d + off + d_head];
                for (c, &vv) in ci.iter_mut().zip(vj) {
                    *c += w * vv;
                }
            }
        }
    }
}

/// Backward of [`attention_forward`]: given the cached attention
/// weights `attn` and the context gradient `dctx`, accumulates `dq`,
/// `dk`, `dv`. `da_row` is caller-provided scratch of length `seq`.
///
/// Per head `h`, query `i`: `dA_j = dctxᵢ·v_j`, the softmax backward
/// `dl_j = A_j·(dA_j − Σₖ dA_k·A_k)`, then (folding in the `1/√d`
/// score scale) `dqᵢ += Σ_j dl_j·scale·k_j`, `dk_j += dl_j·scale·qᵢ`,
/// `dv_j += A_j·dctxᵢ`.
#[allow(clippy::too_many_arguments)]
pub fn attention_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    attn: &[f32],
    dctx: &[f32],
    seq: usize,
    n_heads: usize,
    d_head: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    da_row: &mut [f32],
) {
    let d = n_heads * d_head;
    debug_assert_eq!(attn.len(), n_heads * seq * seq);
    debug_assert_eq!(dctx.len(), seq * d);
    debug_assert_eq!(dq.len(), seq * d);
    debug_assert_eq!(dk.len(), seq * d);
    debug_assert_eq!(dv.len(), seq * d);
    debug_assert_eq!(da_row.len(), seq);
    let scale = 1.0 / (d_head as f32).sqrt();
    for h in 0..n_heads {
        let off = h * d_head;
        for i in 0..seq {
            let a_row = &attn[(h * seq + i) * seq..(h * seq + i + 1) * seq];
            let dc = &dctx[i * d + off..i * d + off + d_head];
            for (j, da) in da_row.iter_mut().enumerate() {
                let vj = &v[j * d + off..j * d + off + d_head];
                let mut acc = 0.0f32;
                for (a, b) in dc.iter().zip(vj) {
                    acc += a * b;
                }
                *da = acc;
                let dvj = &mut dv[j * d + off..j * d + off + d_head];
                let w = a_row[j];
                for (x, &y) in dvj.iter_mut().zip(dc) {
                    *x += w * y;
                }
            }
            let mut dot = 0.0f32;
            for j in 0..seq {
                dot += da_row[j] * a_row[j];
            }
            for j in 0..seq {
                da_row[j] = a_row[j] * (da_row[j] - dot) * scale;
            }
            for (j, &s) in da_row.iter().enumerate() {
                let kj = &k[j * d + off..j * d + off + d_head];
                let dqi = &mut dq[i * d + off..i * d + off + d_head];
                for (x, &y) in dqi.iter_mut().zip(kj) {
                    *x += s * y;
                }
                let qi = &q[i * d + off..i * d + off + d_head];
                let dkj = &mut dk[j * d + off..j * d + off + d_head];
                for (x, &y) in dkj.iter_mut().zip(qi) {
                    *x += s * y;
                }
            }
        }
    }
}

/// Optimizer family for the native backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptKind {
    /// SGD with 0.9 momentum.
    Sgd,
    /// Adam (β₁ 0.9, β₂ 0.999, ε 1e-8) with bias correction.
    Adam,
}

impl OptKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "sgd" => Self::Sgd,
            "adam" => Self::Adam,
            _ => return None,
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Sgd => "sgd",
            Self::Adam => "adam",
        }
    }
}

const SGD_MOMENTUM: f32 = 0.9;
const ADAM_BETA1: f32 = 0.9;
const ADAM_BETA2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Dense first-order optimizer over one flat parameter vector.
#[derive(Debug, Clone)]
pub struct Optimizer {
    kind: OptKind,
    pub lr: f32,
    /// Momentum (SGD) / first-moment (Adam) buffer.
    m: Vec<f32>,
    /// Second-moment buffer (Adam only).
    v: Vec<f32>,
    t: u64,
}

impl Optimizer {
    pub fn new(kind: OptKind, lr: f32, n_params: usize) -> Self {
        let v = match kind {
            OptKind::Adam => vec![0.0; n_params],
            OptKind::Sgd => Vec::new(),
        };
        Self { kind, lr, m: vec![0.0; n_params], v, t: 0 }
    }

    pub fn kind(&self) -> OptKind {
        self.kind
    }

    /// One update step: `params -= lr · f(grads)`.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(params.len(), self.m.len());
        debug_assert_eq!(params.len(), grads.len());
        self.t += 1;
        match self.kind {
            OptKind::Sgd => {
                for ((p, m), &g) in params.iter_mut().zip(&mut self.m).zip(grads) {
                    *m = SGD_MOMENTUM * *m + g;
                    *p -= self.lr * *m;
                }
            }
            OptKind::Adam => {
                let bc1 = 1.0 - ADAM_BETA1.powi(self.t as i32);
                let bc2 = 1.0 - ADAM_BETA2.powi(self.t as i32);
                for (((p, m), v), &g) in
                    params.iter_mut().zip(&mut self.m).zip(&mut self.v).zip(grads)
                {
                    *m = ADAM_BETA1 * *m + (1.0 - ADAM_BETA1) * g;
                    *v = ADAM_BETA2 * *v + (1.0 - ADAM_BETA2) * g * g;
                    let m_hat = *m / bc1;
                    let v_hat = *v / bc2;
                    *p -= self.lr * m_hat / (v_hat.sqrt() + ADAM_EPS);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_forward_matches_hand_computation() {
        // W = [[1, 2], [3, 4]], b = [10, 20], x = [1, -1].
        let w = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0];
        let mut out = [0.0; 2];
        linear_forward(&w, &b, &[1.0, -1.0], &mut out);
        assert_eq!(out, [9.0, 19.0]);
    }

    #[test]
    fn batched_linear_bit_identical_to_per_row() {
        // Awkward values (no nice binary representations) so any
        // accumulation-order change would show up in the bits.
        let w: Vec<f32> = (0..6).map(|i| (i as f32 * 0.37 - 1.1) / 3.0).collect();
        let b = [0.123f32, -4.56];
        let xs: Vec<f32> = (0..9).map(|i| (i as f32 * 1.7 - 3.3) / 7.0).collect();
        let mut batched = [0.0f32; 6];
        linear_forward_batch(&w, &b, &xs, &mut batched, 3, 2);
        for i in 0..3 {
            let mut one = [0.0f32; 2];
            linear_forward(&w, &b, &xs[i * 3..(i + 1) * 3], &mut one);
            assert_eq!(one[..], batched[i * 2..(i + 1) * 2], "row {i}");
        }
    }

    #[test]
    fn batched_linear_empty_batch_is_noop() {
        let w = [1.0f32; 4];
        let b = [0.0f32; 2];
        let mut out: [f32; 0] = [];
        linear_forward_batch(&w, &b, &[], &mut out, 2, 2);
    }

    #[test]
    fn linear_backward_accumulates_all_three_grads() {
        let w = [1.0, 2.0, 3.0, 4.0];
        let x = [1.0, -1.0];
        let dy = [0.5, -0.25];
        let mut dw = [0.0; 4];
        let mut db = [0.0; 2];
        let mut dx = [0.0; 2];
        linear_backward(&w, &x, &dy, &mut dw, &mut db, Some(&mut dx));
        assert_eq!(db, dy);
        assert_eq!(dw, [0.5, -0.5, -0.25, 0.25]);
        // dx = Wᵀ·dy = [1*0.5 + 3*-0.25, 2*0.5 + 4*-0.25].
        assert_eq!(dx, [-0.25, 0.0]);
    }

    #[test]
    fn softmax_ce_gradient_is_p_minus_onehot() {
        let mut z = [1.0f32, 1.0, 1.0];
        softmax(&mut z);
        for v in z {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
        let mut p = [0.5f32, 0.25, 0.25];
        let loss = cross_entropy_backward(&mut p, 0);
        assert!((loss - 0.5f32.ln().abs()).abs() < 1e-6);
        assert!((p[0] + 0.5).abs() < 1e-6);
        assert!((p[1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn relu_and_backward_mask_agree() {
        let mut h = [-1.0f32, 0.0, 2.0];
        relu(&mut h);
        assert_eq!(h, [0.0, 0.0, 2.0]);
        let mut dh = [1.0f32, 1.0, 1.0];
        relu_backward(&h, &mut dh);
        assert_eq!(dh, [0.0, 0.0, 1.0]);
    }

    /// Both optimizers must drive a 1-D quadratic toward its minimum.
    #[test]
    fn optimizers_descend_a_quadratic() {
        for kind in [OptKind::Sgd, OptKind::Adam] {
            let mut opt = Optimizer::new(kind, 0.05, 1);
            let mut p = [4.0f32];
            for _ in 0..200 {
                let g = [2.0 * p[0]]; // d/dp of p².
                opt.step(&mut p, &g);
            }
            assert!(p[0].abs() < 0.5, "{kind:?} ended at {}", p[0]);
        }
    }

    #[test]
    fn init_is_deterministic_and_bounded() {
        let a = init_uniform(&mut XorShift64::new(7), 64, 0.1);
        let b = init_uniform(&mut XorShift64::new(7), 64, 0.1);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| v.abs() <= 0.1));
        assert!(a.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn layer_norm_normalizes_and_applies_affine() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let gamma = [2.0f32; 4];
        let beta = [1.0f32; 4];
        let mut xhat = [0.0f32; 4];
        let mut out = [0.0f32; 4];
        let rstd = layer_norm_forward(&x, &gamma, &beta, &mut xhat, &mut out);
        // x̂ has zero mean and (near-)unit variance.
        let mean: f32 = xhat.iter().sum::<f32>() / 4.0;
        let var: f32 = xhat.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6, "xhat mean {mean}");
        assert!((var - 1.0).abs() < 1e-3, "xhat var {var}");
        assert!(rstd > 0.0);
        for i in 0..4 {
            assert!((out[i] - (2.0 * xhat[i] + 1.0)).abs() < 1e-6);
        }
    }

    #[test]
    fn layer_norm_backward_translation_invariant() {
        // d(loss)/dx must sum to ~0 when gamma is uniform: shifting
        // every input by a constant cannot change the normalized row.
        let x = [0.3f32, -1.2, 2.0, 0.7];
        let gamma = [1.5f32; 4];
        let beta = [0.0f32; 4];
        let mut xhat = [0.0f32; 4];
        let mut out = [0.0f32; 4];
        let rstd = layer_norm_forward(&x, &gamma, &beta, &mut xhat, &mut out);
        let dy = [0.5f32, -0.25, 1.0, 0.1];
        let mut dg = [0.0f32; 4];
        let mut db = [0.0f32; 4];
        let mut dx = [0.0f32; 4];
        layer_norm_backward(&dy, &gamma, &xhat, rstd, &mut dg, &mut db, &mut dx);
        assert_eq!(db, dy);
        let s: f32 = dx.iter().sum();
        assert!(s.abs() < 1e-5, "dx sum {s}");
    }

    #[test]
    fn gelu_known_values() {
        let x = [0.0f32, 1.0, -1.0, 3.0];
        let mut y = [0.0f32; 4];
        gelu_forward(&x, &mut y);
        assert_eq!(y[0], 0.0);
        assert!((y[1] - 0.8412).abs() < 1e-3, "gelu(1) = {}", y[1]);
        assert!((y[2] + 0.1588).abs() < 1e-3, "gelu(-1) = {}", y[2]);
        assert!((y[3] - 2.9964).abs() < 1e-3, "gelu(3) = {}", y[3]);
        // Monotone for large |x|: acts like identity / zero.
        let mut dx = [0.0f32; 4];
        gelu_backward(&x, &[1.0; 4], &mut dx);
        assert!((dx[0] - 0.5).abs() < 1e-6, "gelu'(0) = {}", dx[0]);
        assert!(dx[3] > 0.99, "gelu'(3) = {}", dx[3]);
    }

    #[test]
    fn attention_uniform_queries_average_values() {
        // q = 0 ⇒ every score is 0 ⇒ softmax is uniform ⇒ the context
        // is the mean of the values, per head.
        let (seq, heads, dh) = (3usize, 2usize, 2usize);
        let d = heads * dh;
        let q = vec![0.0f32; seq * d];
        let k: Vec<f32> = (0..seq * d).map(|i| i as f32 * 0.1).collect();
        let v: Vec<f32> = (0..seq * d).map(|i| i as f32).collect();
        let mut attn = vec![0.0f32; heads * seq * seq];
        let mut ctx = vec![0.0f32; seq * d];
        attention_forward(&q, &k, &v, seq, heads, dh, &mut attn, &mut ctx);
        for row in attn.chunks_exact(seq) {
            for &w in row {
                assert!((w - 1.0 / seq as f32).abs() < 1e-6, "uniform attention, got {w}");
            }
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
        for c in 0..d {
            let mean: f32 = (0..seq).map(|j| v[j * d + c]).sum::<f32>() / seq as f32;
            for i in 0..seq {
                assert!((ctx[i * d + c] - mean).abs() < 1e-4, "col {c} row {i}");
            }
        }
    }

    #[test]
    fn attention_rows_are_distributions() {
        let (seq, heads, dh) = (4usize, 2usize, 3usize);
        let d = heads * dh;
        let mk = |seed: u64| {
            let mut r = XorShift64::new(seed);
            init_uniform(&mut r, seq * d, 1.0)
        };
        let (q, k, v) = (mk(1), mk(2), mk(3));
        let mut attn = vec![0.0f32; heads * seq * seq];
        let mut ctx = vec![0.0f32; seq * d];
        attention_forward(&q, &k, &v, seq, heads, dh, &mut attn, &mut ctx);
        for row in attn.chunks_exact(seq) {
            assert!(row.iter().all(|&w| (0.0..=1.0).contains(&w)));
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
        // dv for a uniform upstream gradient distributes each query's
        // weight once: Σⱼ dvⱼ per head column equals seq (Σᵢ Σⱼ A[i][j]
        // = seq because every row sums to 1).
        let dctx = vec![1.0f32; seq * d];
        let mut dq = vec![0.0f32; seq * d];
        let mut dk = vec![0.0f32; seq * d];
        let mut dv = vec![0.0f32; seq * d];
        let mut scratch = vec![0.0f32; seq];
        attention_backward(
            &q, &k, &v, &attn, &dctx, seq, heads, dh, &mut dq, &mut dk, &mut dv, &mut scratch,
        );
        for c in 0..d {
            let s: f32 = (0..seq).map(|j| dv[j * d + c]).sum();
            assert!((s - seq as f32).abs() < 1e-4, "col {c}: Σdv = {s}");
        }
    }

    #[test]
    fn optkind_parse_roundtrip() {
        assert_eq!(OptKind::parse("adam"), Some(OptKind::Adam));
        assert_eq!(OptKind::parse("sgd"), Some(OptKind::Sgd));
        assert_eq!(OptKind::parse("rmsprop"), None);
        assert_eq!(OptKind::Adam.as_str(), "adam");
    }
}
