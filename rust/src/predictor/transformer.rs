//! Transformer reference backend — the paper's §5 *unconstrained*
//! predictor, in pure Rust (train + infer, no JAX/XLA).
//!
//! The paper's narrative is two-act: first a full Transformer shows
//! that high prefetch accuracy is reachable at all, then its attention
//! maps are *interpreted* (which history slots do the heads actually
//! look at?) to justify the orders-of-magnitude-cheaper revised model
//! that [`crate::predictor::native`] implements. This module is act
//! one: a pre-LN encoder stack over the same (PC, page bucket, Δ)
//! token windows, serving as the accuracy ceiling every cheaper model
//! is measured against (`repro analyze`, `eval/analyze.rs`).
//!
//! Architecture: per-feature embedding tables (PC / page bucket / Δ)
//! *summed* per position with a learned positional embedding, then
//! `n_layers` pre-LN encoder blocks (LN → multi-head self-attention →
//! residual; LN → FFN with GELU → residual), a final LN on the last
//! slot and a linear head over the delta vocabulary (last class OOV).
//!
//! Everything lives in one flat `f32` parameter vector so the
//! [`Optimizer`] and the [`crate::runtime::params`] tensor store work
//! unchanged; all arithmetic is scalar in a fixed order, so same-seed
//! training is byte-deterministic and batched inference is
//! bit-identical to sequential (`rust/tests/transformer_backend.rs`
//! pins both, `rust/tests/grad_check.rs` pins every backward against
//! central differences).

use crate::predictor::kernel::{self, Precision};
use crate::predictor::nn::{self, OptKind, Optimizer};
use crate::predictor::{
    BackendInfo, ClassId, DeltaVocab, LabelledWindow, PredictorBackend, Window,
};
use crate::runtime::params::{write_store, TensorStore};
use crate::util::XorShift64;
use anyhow::{bail, Result};
use std::path::Path;

/// Hyper-parameters of the Transformer reference model (vocabulary
/// shapes come from the [`DeltaVocab`] it is initialized against).
#[derive(Debug, Clone)]
pub struct TransformerConfig {
    /// Model width (must be divisible by `n_heads`).
    pub d_model: usize,
    pub n_heads: usize,
    /// Encoder blocks.
    pub n_layers: usize,
    /// FFN hidden width.
    pub d_ff: usize,
    pub lr: f32,
    pub optimizer: OptKind,
    /// Weight-init seed (same seed + same data ⇒ identical model).
    pub seed: u64,
}

impl Default for TransformerConfig {
    fn default() -> Self {
        Self {
            d_model: 32,
            n_heads: 4,
            n_layers: 2,
            d_ff: 64,
            lr: 1e-3,
            optimizer: OptKind::Adam,
            seed: 0x5eed,
        }
    }
}

/// Offsets of one encoder block's tensors inside the flat parameter
/// vector. Weight/bias pairs are contiguous (`wq` then `bq`, …) — the
/// backward pass splits one mutable gradient slice per pair.
#[derive(Debug, Clone, Copy)]
struct LayerOff {
    ln1_g: usize,
    ln1_b: usize,
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    ln2_g: usize,
    ln2_b: usize,
    w1: usize,
    w2: usize,
}

/// Forward caches for one window — everything the backward pass and
/// the attention-introspection path (`repro analyze`) need.
#[derive(Debug, Clone)]
struct LayerCache {
    /// LN1 normalized input `[S×D]` + per-row 1/σ.
    xhat1: Vec<f32>,
    rstd1: Vec<f32>,
    /// LN1 output (the QKV projections' input) `[S×D]`.
    y1: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Softmaxed attention weights `[H×S×S]`.
    attn: Vec<f32>,
    /// Per-head context vectors `[S×D]`.
    ctx: Vec<f32>,
    xhat2: Vec<f32>,
    rstd2: Vec<f32>,
    /// LN2 output (the FFN's input) `[S×D]`.
    y2: Vec<f32>,
    /// FFN pre-activation `[S×F]` (GELU backward needs it).
    f1: Vec<f32>,
    /// FFN post-GELU `[S×F]`.
    g: Vec<f32>,
}

#[derive(Debug, Clone)]
struct Fwd {
    layers: Vec<LayerCache>,
    /// Running activation `[S×D]`; starts as the embedded input and
    /// holds the encoder output after `forward`.
    x: Vec<f32>,
    /// Shared projection scratch `[S×D]`.
    t: Vec<f32>,
    /// Final-LN caches (last slot only).
    xhat_f: Vec<f32>,
    rstd_f: f32,
    yf: Vec<f32>,
    logits: Vec<f32>,
}

/// Backward scratch (reused across the samples of a batch).
#[derive(Debug, Clone)]
struct Bwd {
    dx: Vec<f32>,
    dy: Vec<f32>,
    dyf: Vec<f32>,
    dq: Vec<f32>,
    dk: Vec<f32>,
    dv: Vec<f32>,
    dctx: Vec<f32>,
    df1: Vec<f32>,
    dg: Vec<f32>,
    da_row: Vec<f32>,
}

/// Values of the `meta` side tensor: shape facts the weight dims alone
/// cannot recover (head count) or that we pin for validation.
const META_LEN: usize = 4;

/// The Transformer reference model.
///
/// ```
/// use uvm_prefetch::predictor::transformer::{TransformerBackend, TransformerConfig};
/// use uvm_prefetch::predictor::{DeltaVocab, FeatTok, LabelledWindow, PredictorBackend, Window};
///
/// let vocab = DeltaVocab::synthetic(vec![1, 7], 4);
/// let cfg = TransformerConfig { d_model: 8, n_heads: 2, n_layers: 1, d_ff: 16, lr: 0.02,
///                               ..Default::default() };
/// let mut model = TransformerBackend::init(&vocab, &cfg);
/// let window = |d: i32| Window { tokens: vec![FeatTok { pc_id: 0, page_id: 0, delta_id: d }; 4] };
/// let batch: Vec<LabelledWindow> =
///     (0..8).map(|_| LabelledWindow { window: window(1), label: 1 }).collect();
/// for _ in 0..60 {
///     model.finetune(&batch).expect("transformer returns a real loss");
/// }
/// assert_eq!(model.predict(&[window(1)]), vec![1]);
/// ```
#[derive(Debug)]
pub struct TransformerBackend {
    // Shape.
    seq_len: usize,
    n_classes: usize,
    pc_rows: usize,
    page_rows: usize,
    d_model: usize,
    n_heads: usize,
    n_layers: usize,
    d_ff: usize,
    // Flat parameter vector; tensor offsets derived from the shape.
    params: Vec<f32>,
    opt: Optimizer,
    /// Total optimizer steps taken (offline + online).
    pub train_steps: u64,
    /// Kernel tier the projection/FFN GEMMs dispatch through
    /// (exact|fast only — there is no integer plane for this arch).
    precision: Precision,
}

impl TransformerBackend {
    /// Fresh model with seeded-deterministic weights.
    pub fn init(vocab: &DeltaVocab, cfg: &TransformerConfig) -> Self {
        Self::with_shape(
            vocab.history_len.max(1),
            vocab.n_classes(),
            vocab.n_pc_slots(),
            vocab.n_page_buckets(),
            cfg,
        )
    }

    /// Init from explicit table shapes (the load path and tests).
    pub fn with_shape(
        seq_len: usize,
        n_classes: usize,
        pc_rows: usize,
        page_rows: usize,
        cfg: &TransformerConfig,
    ) -> Self {
        assert!(seq_len > 0 && n_classes > 0 && pc_rows > 0 && page_rows > 0);
        assert!(cfg.d_model > 0 && cfg.n_heads > 0 && cfg.n_layers > 0 && cfg.d_ff > 0);
        assert!(
            cfg.d_model % cfg.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            cfg.d_model,
            cfg.n_heads
        );
        let d = cfg.d_model;
        let f = cfg.d_ff;
        let mut rng = XorShift64::new(cfg.seed);
        let xavier = |fan_in: usize, fan_out: usize| (6.0 / (fan_in + fan_out) as f32).sqrt();
        let mut params = Vec::new();
        // Embeddings + positional table, in layout order.
        params.extend(nn::init_uniform(&mut rng, pc_rows * d, 0.1));
        params.extend(nn::init_uniform(&mut rng, page_rows * d, 0.1));
        params.extend(nn::init_uniform(&mut rng, n_classes * d, 0.1));
        params.extend(nn::init_uniform(&mut rng, seq_len * d, 0.1));
        for _ in 0..cfg.n_layers {
            params.extend(vec![1.0; d]); // ln1_g
            params.extend(vec![0.0; d]); // ln1_b
            for _ in 0..3 {
                // wq, wk, wv (each directly followed by its bias).
                params.extend(nn::init_uniform(&mut rng, d * d, xavier(d, d)));
                params.extend(vec![0.0; d]);
            }
            params.extend(nn::init_uniform(&mut rng, d * d, xavier(d, d))); // wo
            params.extend(vec![0.0; d]); // bo
            params.extend(vec![1.0; d]); // ln2_g
            params.extend(vec![0.0; d]); // ln2_b
            params.extend(nn::init_uniform(&mut rng, f * d, xavier(d, f))); // w1
            params.extend(vec![0.0; f]); // b1
            params.extend(nn::init_uniform(&mut rng, d * f, xavier(f, d))); // w2
            params.extend(vec![0.0; d]); // b2
        }
        params.extend(vec![1.0; d]); // lnf_g
        params.extend(vec![0.0; d]); // lnf_b
        params.extend(nn::init_uniform(&mut rng, n_classes * d, xavier(d, n_classes))); // out_w
        params.extend(vec![0.0; n_classes]); // out_b
        let opt = Optimizer::new(cfg.optimizer, cfg.lr, params.len());
        let me = Self {
            seq_len,
            n_classes,
            pc_rows,
            page_rows,
            d_model: d,
            n_heads: cfg.n_heads,
            n_layers: cfg.n_layers,
            d_ff: f,
            params,
            opt,
            train_steps: 0,
            precision: Precision::Exact,
        };
        debug_assert_eq!(me.params.len(), me.total_len());
        me
    }

    // ---- layout -----------------------------------------------------

    fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// `(emb_pc, emb_page, emb_delta, pos)` offsets.
    fn emb_off(&self) -> (usize, usize, usize, usize) {
        let d = self.d_model;
        let o_pc = 0;
        let o_page = o_pc + self.pc_rows * d;
        let o_delta = o_page + self.page_rows * d;
        let o_pos = o_delta + self.n_classes * d;
        (o_pc, o_page, o_delta, o_pos)
    }

    fn emb_len(&self) -> usize {
        (self.pc_rows + self.page_rows + self.n_classes + self.seq_len) * self.d_model
    }

    /// Flat length of one encoder block.
    fn layer_len(&self) -> usize {
        let d = self.d_model;
        let f = self.d_ff;
        // ln1(2d) + 4 × (d² weight + d bias) + ln2(2d) + w1/b1 + w2/b2.
        2 * d + 4 * (d * d + d) + 2 * d + (f * d + f) + (d * f + d)
    }

    fn layer_off(&self, layer: usize) -> LayerOff {
        let d = self.d_model;
        let f = self.d_ff;
        let mut o = self.emb_len() + layer * self.layer_len();
        let mut take = |n: usize| {
            let r = o;
            o += n;
            r
        };
        let ln1_g = take(d);
        let ln1_b = take(d);
        let wq = take(d * d + d); // weight + bias
        let wk = take(d * d + d);
        let wv = take(d * d + d);
        let wo = take(d * d + d);
        let ln2_g = take(d);
        let ln2_b = take(d);
        let w1 = take(f * d + f);
        let w2 = take(d * f + d);
        LayerOff { ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, w2 }
    }

    /// `(lnf_g, lnf_b, out_w, out_b)` offsets.
    fn tail_off(&self) -> (usize, usize, usize, usize) {
        let d = self.d_model;
        let o = self.emb_len() + self.n_layers * self.layer_len();
        (o, o + d, o + 2 * d, o + 2 * d + self.n_classes * d)
    }

    fn total_len(&self) -> usize {
        let (.., o_out_b) = self.tail_off();
        o_out_b + self.n_classes
    }

    // ---- accessors --------------------------------------------------

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Output classes including OOV (inherent mirror of the trait
    /// method, so concrete callers need no trait import).
    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// The flat parameter vector (tests compare models through this).
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Switch the GEMM tier. This arch has no integer weight plane,
    /// so only exact|fast are accepted; the quantized tiers fail with
    /// an error naming the flags to fix.
    pub fn set_precision(&mut self, precision: Precision) -> Result<()> {
        if precision.is_quantized() {
            bail!(
                "--precision {} runs only on --backend native (the transformer serves \
                 exact|fast)",
                precision.as_str()
            );
        }
        self.precision = precision;
        Ok(())
    }

    /// Mutable parameter access — the finite-difference gradient
    /// checks (`rust/tests/grad_check.rs`) perturb single weights
    /// through this; it is not part of the serving surface.
    pub fn params_mut(&mut self) -> &mut [f32] {
        &mut self.params
    }

    /// Analytic FLOPs for one window's forward pass (MAC = 2 flops):
    /// embedding sums, then per block two layer-norms (≈8·D/row), the
    /// four D×D projections, score+context matmuls (2·S²·D each over
    /// all heads), softmax (≈5 flops/weight) and the two FFN matmuls
    /// with tanh-GELU (≈12 flops/unit); finally one layer-norm and the
    /// class head. The `repro analyze` cost table divides this by the
    /// native backend's count to measure the paper's
    /// "orders-of-magnitude cheaper" claim.
    pub fn flops_per_inference(&self) -> u64 {
        let s = self.seq_len as u64;
        let d = self.d_model as u64;
        let f = self.d_ff as u64;
        let c = self.n_classes as u64;
        let h = self.n_heads as u64;
        let ln_row = 8 * d;
        let per_layer = 2 * s * ln_row      // LN1 + LN2
            + 4 * 2 * s * d * d             // q/k/v/o projections
            + 2 * 2 * s * s * d             // scores + context, all heads
            + 5 * h * s * s                 // softmax
            + 2 * 2 * s * d * f             // FFN matmuls
            + 12 * s * f; // GELU
        4 * s * d + self.n_layers as u64 * per_layer + ln_row + 2 * c * d
    }

    // ---- forward ----------------------------------------------------

    fn new_fwd(&self) -> Fwd {
        let (s, d, f) = (self.seq_len, self.d_model, self.d_ff);
        let layer = LayerCache {
            xhat1: vec![0.0; s * d],
            rstd1: vec![0.0; s],
            y1: vec![0.0; s * d],
            q: vec![0.0; s * d],
            k: vec![0.0; s * d],
            v: vec![0.0; s * d],
            attn: vec![0.0; self.n_heads * s * s],
            ctx: vec![0.0; s * d],
            xhat2: vec![0.0; s * d],
            rstd2: vec![0.0; s],
            y2: vec![0.0; s * d],
            f1: vec![0.0; s * f],
            g: vec![0.0; s * f],
        };
        Fwd {
            layers: vec![layer; self.n_layers],
            x: vec![0.0; s * d],
            t: vec![0.0; s * d],
            xhat_f: vec![0.0; d],
            rstd_f: 0.0,
            yf: vec![0.0; d],
            logits: vec![0.0; self.n_classes],
        }
    }

    fn new_bwd(&self) -> Bwd {
        let (s, d, f) = (self.seq_len, self.d_model, self.d_ff);
        Bwd {
            dx: vec![0.0; s * d],
            dy: vec![0.0; s * d],
            dyf: vec![0.0; d],
            dq: vec![0.0; s * d],
            dk: vec![0.0; s * d],
            dv: vec![0.0; s * d],
            dctx: vec![0.0; s * d],
            df1: vec![0.0; s * f],
            dg: vec![0.0; s * f],
            da_row: vec![0.0; s],
        }
    }

    /// Sum the window's token embeddings and the positional table into
    /// the `[S×D]` input. Windows shorter than `seq_len` are
    /// left-padded (pad slots carry only the positional embedding —
    /// the learned "empty slot" marker); longer ones keep the newest
    /// tokens, matching the native backend's rule.
    fn gather(&self, window: &Window, x: &mut [f32]) {
        let d = self.d_model;
        debug_assert_eq!(x.len(), self.seq_len * d);
        x.fill(0.0);
        let (o_pc, o_page, o_delta, o_pos) = self.emb_off();
        for r in 0..self.seq_len {
            let row = &mut x[r * d..(r + 1) * d];
            for (xv, &e) in row.iter_mut().zip(&self.params[o_pos + r * d..o_pos + (r + 1) * d]) {
                *xv += e;
            }
        }
        let toks = &window.tokens[window.tokens.len().saturating_sub(self.seq_len)..];
        let pad = self.seq_len - toks.len();
        for (i, tok) in toks.iter().enumerate() {
            let row = &mut x[(pad + i) * d..(pad + i + 1) * d];
            let pc = (tok.pc_id.max(0) as usize).min(self.pc_rows - 1);
            let page = (tok.page_id.max(0) as usize).min(self.page_rows - 1);
            let delta = (tok.delta_id.max(0) as usize).min(self.n_classes - 1);
            for (xv, &e) in row.iter_mut().zip(&self.params[o_pc + pc * d..][..d]) {
                *xv += e;
            }
            for (xv, &e) in row.iter_mut().zip(&self.params[o_page + page * d..][..d]) {
                *xv += e;
            }
            for (xv, &e) in row.iter_mut().zip(&self.params[o_delta + delta * d..][..d]) {
                *xv += e;
            }
        }
    }

    /// Full cached forward for one window; `fwd.logits` ends as the
    /// class logits and every intermediate the backward pass needs is
    /// cached. Row-local op order is identical to the batched
    /// inference path, so the two are bit-identical.
    fn forward(&self, window: &Window, fwd: &mut Fwd) {
        let (s, d, f) = (self.seq_len, self.d_model, self.d_ff);
        let hd = self.head_dim();
        let p = &self.params;
        // Projection/FFN GEMMs dispatch by tier; `pr` is Exact on
        // every training path (constructors pin it), so gradients and
        // same-seed byte determinism are untouched.
        let pr = self.precision;
        let lin = |w: &[f32], b: &[f32], xs: &[f32], out: &mut [f32], i_dim: usize, o_dim: usize| {
            kernel::linear_forward_batch(pr, w, b, xs, out, i_dim, o_dim)
        };
        self.gather(window, &mut fwd.x);
        for l in 0..self.n_layers {
            let o = self.layer_off(l);
            let c = &mut fwd.layers[l];
            for r in 0..s {
                c.rstd1[r] = nn::layer_norm_forward(
                    &fwd.x[r * d..(r + 1) * d],
                    &p[o.ln1_g..o.ln1_g + d],
                    &p[o.ln1_b..o.ln1_b + d],
                    &mut c.xhat1[r * d..(r + 1) * d],
                    &mut c.y1[r * d..(r + 1) * d],
                );
            }
            lin(&p[o.wq..][..d * d], &p[o.wq + d * d..][..d], &c.y1, &mut c.q, d, d);
            lin(&p[o.wk..][..d * d], &p[o.wk + d * d..][..d], &c.y1, &mut c.k, d, d);
            lin(&p[o.wv..][..d * d], &p[o.wv + d * d..][..d], &c.y1, &mut c.v, d, d);
            nn::attention_forward(&c.q, &c.k, &c.v, s, self.n_heads, hd, &mut c.attn, &mut c.ctx);
            lin(&p[o.wo..][..d * d], &p[o.wo + d * d..][..d], &c.ctx, &mut fwd.t, d, d);
            for (xv, &tv) in fwd.x.iter_mut().zip(fwd.t.iter()) {
                *xv += tv;
            }
            for r in 0..s {
                c.rstd2[r] = nn::layer_norm_forward(
                    &fwd.x[r * d..(r + 1) * d],
                    &p[o.ln2_g..o.ln2_g + d],
                    &p[o.ln2_b..o.ln2_b + d],
                    &mut c.xhat2[r * d..(r + 1) * d],
                    &mut c.y2[r * d..(r + 1) * d],
                );
            }
            lin(&p[o.w1..][..f * d], &p[o.w1 + f * d..][..f], &c.y2, &mut c.f1, d, f);
            nn::gelu_forward(&c.f1, &mut c.g);
            lin(&p[o.w2..][..d * f], &p[o.w2 + d * f..][..d], &c.g, &mut fwd.t, f, d);
            for (xv, &tv) in fwd.x.iter_mut().zip(fwd.t.iter()) {
                *xv += tv;
            }
        }
        let (o_lnf_g, o_lnf_b, o_out_w, o_out_b) = self.tail_off();
        fwd.rstd_f = nn::layer_norm_forward(
            &fwd.x[(s - 1) * d..s * d],
            &p[o_lnf_g..o_lnf_g + d],
            &p[o_lnf_b..o_lnf_b + d],
            &mut fwd.xhat_f,
            &mut fwd.yf,
        );
        nn::linear_forward(
            &p[o_out_w..o_out_w + self.n_classes * d],
            &p[o_out_b..o_out_b + self.n_classes],
            &fwd.yf,
            &mut fwd.logits,
        );
    }

    /// Logits for one window (sequential reference path; the batched
    /// path is pinned against this bit-for-bit).
    pub fn logits_one(&self, window: &Window) -> Vec<f32> {
        let mut fwd = self.new_fwd();
        self.forward(window, &mut fwd);
        fwd.logits
    }

    /// Forward one window and also return its attention maps,
    /// flattened `[n_layers × n_heads × seq × seq]` with row
    /// `((l·H + h)·S + i)·S ..` = query slot `i`'s distribution over
    /// key slots. The introspection hook `repro analyze` builds its
    /// per-head entropy and positional-locality profiles from.
    pub fn attention_one(&self, window: &Window) -> (Vec<f32>, Vec<f32>) {
        let mut fwd = self.new_fwd();
        self.forward(window, &mut fwd);
        let mut maps = Vec::with_capacity(self.n_layers * self.n_heads * self.seq_len * self.seq_len);
        for c in &fwd.layers {
            maps.extend_from_slice(&c.attn);
        }
        (fwd.logits, maps)
    }

    /// Batched inference: gathers every window into one `[n·S × D]`
    /// activation matrix and runs each projection/FFN layer as a
    /// single batched pass over all windows (the precision-tier
    /// dispatch [`kernel::linear_forward_batch`]); attention stays
    /// window-local by construction. Every op is row-local with the same accumulation
    /// order as the sequential path, so the flat `[n × n_classes]`
    /// result is **bit-identical** to concatenating
    /// [`TransformerBackend::logits_one`] over the batch (pinned in
    /// `rust/tests/transformer_backend.rs`).
    pub fn logits_batch(&self, windows: &[Window]) -> Vec<f32> {
        let n = windows.len();
        if n == 0 {
            return Vec::new();
        }
        let (s, d, f) = (self.seq_len, self.d_model, self.d_ff);
        let hd = self.head_dim();
        let rows = n * s;
        let p = &self.params;
        // Same tier dispatch as `forward` — row-local either way, so
        // batched == sequential stays bitwise on every tier.
        let pr = self.precision;
        let lin = |w: &[f32], b: &[f32], xs: &[f32], out: &mut [f32], i_dim: usize, o_dim: usize| {
            kernel::linear_forward_batch(pr, w, b, xs, out, i_dim, o_dim)
        };
        let mut x = vec![0.0f32; rows * d];
        for (w, xw) in windows.iter().zip(x.chunks_exact_mut(s * d)) {
            self.gather(w, xw);
        }
        let mut xhat = vec![0.0f32; d];
        let mut y = vec![0.0f32; rows * d];
        let mut q = vec![0.0f32; rows * d];
        let mut k = vec![0.0f32; rows * d];
        let mut v = vec![0.0f32; rows * d];
        let mut attn = vec![0.0f32; self.n_heads * s * s];
        let mut ctx = vec![0.0f32; rows * d];
        let mut t = vec![0.0f32; rows * d];
        let mut f1 = vec![0.0f32; rows * f];
        let mut g = vec![0.0f32; rows * f];
        for l in 0..self.n_layers {
            let o = self.layer_off(l);
            for r in 0..rows {
                nn::layer_norm_forward(
                    &x[r * d..(r + 1) * d],
                    &p[o.ln1_g..o.ln1_g + d],
                    &p[o.ln1_b..o.ln1_b + d],
                    &mut xhat,
                    &mut y[r * d..(r + 1) * d],
                );
            }
            lin(&p[o.wq..][..d * d], &p[o.wq + d * d..][..d], &y, &mut q, d, d);
            lin(&p[o.wk..][..d * d], &p[o.wk + d * d..][..d], &y, &mut k, d, d);
            lin(&p[o.wv..][..d * d], &p[o.wv + d * d..][..d], &y, &mut v, d, d);
            for wi in 0..n {
                let span = wi * s * d..(wi + 1) * s * d;
                nn::attention_forward(
                    &q[span.clone()],
                    &k[span.clone()],
                    &v[span.clone()],
                    s,
                    self.n_heads,
                    hd,
                    &mut attn,
                    &mut ctx[span],
                );
            }
            lin(&p[o.wo..][..d * d], &p[o.wo + d * d..][..d], &ctx, &mut t, d, d);
            for (xv, &tv) in x.iter_mut().zip(t.iter()) {
                *xv += tv;
            }
            for r in 0..rows {
                nn::layer_norm_forward(
                    &x[r * d..(r + 1) * d],
                    &p[o.ln2_g..o.ln2_g + d],
                    &p[o.ln2_b..o.ln2_b + d],
                    &mut xhat,
                    &mut y[r * d..(r + 1) * d],
                );
            }
            lin(&p[o.w1..][..f * d], &p[o.w1 + f * d..][..f], &y, &mut f1, d, f);
            nn::gelu_forward(&f1, &mut g);
            lin(&p[o.w2..][..d * f], &p[o.w2 + d * f..][..d], &g, &mut t, f, d);
            for (xv, &tv) in x.iter_mut().zip(t.iter()) {
                *xv += tv;
            }
        }
        let (o_lnf_g, o_lnf_b, o_out_w, o_out_b) = self.tail_off();
        let c_out = self.n_classes;
        let mut yf = vec![0.0f32; d];
        let mut logits = vec![0.0f32; n * c_out];
        for wi in 0..n {
            let last = &x[(wi * s + s - 1) * d..(wi * s + s) * d];
            nn::layer_norm_forward(
                last,
                &p[o_lnf_g..o_lnf_g + d],
                &p[o_lnf_b..o_lnf_b + d],
                &mut xhat,
                &mut yf,
            );
            nn::linear_forward(
                &p[o_out_w..o_out_w + c_out * d],
                &p[o_out_b..o_out_b + c_out],
                &yf,
                &mut logits[wi * c_out..(wi + 1) * c_out],
            );
        }
        logits
    }

    /// First maximum wins — the tie-break shared with the native
    /// backend, identical on sequential and batched paths.
    fn argmax(z: &[f32]) -> ClassId {
        let mut best = 0usize;
        for (i, &v) in z.iter().enumerate() {
            if v > z[best] {
                best = i;
            }
        }
        best as ClassId
    }

    /// Top-1 class for one window.
    pub fn predict_one(&self, window: &Window) -> ClassId {
        Self::argmax(&self.logits_one(window))
    }

    /// Top-1 class per window through the batched forward.
    pub fn predict_batch(&self, windows: &[Window]) -> Vec<ClassId> {
        let zs = self.logits_batch(windows);
        zs.chunks_exact(self.n_classes).map(Self::argmax).collect()
    }

    /// Fraction of `data` whose top-1 prediction matches the label.
    pub fn top1_accuracy(&self, data: &[LabelledWindow]) -> f64 {
        if data.is_empty() {
            return 0.0;
        }
        let ws: Vec<Window> = data.iter().map(|lw| lw.window.clone()).collect();
        let hits = self
            .predict_batch(&ws)
            .iter()
            .zip(data)
            .filter(|(p, lw)| **p == lw.label.max(0) as ClassId)
            .count();
        hits as f64 / data.len() as f64
    }

    // ---- backward / training ---------------------------------------

    /// Mean cross-entropy over `batch` and the full flat gradient —
    /// the quantity `rust/tests/grad_check.rs` pins against central
    /// differences. Does **not** update parameters.
    pub fn loss_and_grad(&self, batch: &[LabelledWindow]) -> (f32, Vec<f32>) {
        let mut grads = vec![0.0f32; self.params.len()];
        if batch.is_empty() {
            return (0.0, grads);
        }
        let mut fwd = self.new_fwd();
        let mut bwd = self.new_bwd();
        let mut loss = 0.0f32;
        let mut dlogits = vec![0.0f32; self.n_classes];
        for lw in batch {
            self.forward(&lw.window, &mut fwd);
            dlogits.copy_from_slice(&fwd.logits);
            nn::softmax(&mut dlogits);
            let label = (lw.label.max(0) as usize).min(self.n_classes - 1);
            loss += nn::cross_entropy_backward(&mut dlogits, label);
            self.backward(&lw.window, &fwd, &dlogits, &mut bwd, &mut grads);
        }
        let inv = 1.0 / batch.len() as f32;
        for g in &mut grads {
            *g *= inv;
        }
        (loss * inv, grads)
    }

    /// One optimizer step over `batch`; returns the mean cross-entropy
    /// loss *before* the update.
    pub fn train_batch(&mut self, batch: &[LabelledWindow]) -> f32 {
        if batch.is_empty() {
            return 0.0;
        }
        let (loss, grads) = self.loss_and_grad(batch);
        self.opt.step(&mut self.params, &grads);
        self.train_steps += 1;
        loss
    }

    /// Accumulate one sample's parameter gradients given the cached
    /// forward (`fwd`) and the logits gradient `p − onehot(label)`.
    fn backward(&self, window: &Window, fwd: &Fwd, dlogits: &[f32], bwd: &mut Bwd, grads: &mut [f32]) {
        let (s, d, f) = (self.seq_len, self.d_model, self.d_ff);
        let hd = self.head_dim();
        let c_out = self.n_classes;
        let p = &self.params;
        let (o_lnf_g, _, o_out_w, _) = self.tail_off();
        bwd.dx.fill(0.0);
        bwd.dyf.fill(0.0);
        // Class head.
        {
            let (gw, rest) = grads[o_out_w..].split_at_mut(c_out * d);
            nn::linear_backward(
                &p[o_out_w..o_out_w + c_out * d],
                &fwd.yf,
                dlogits,
                gw,
                &mut rest[..c_out],
                Some(&mut bwd.dyf),
            );
        }
        // Final LN feeds only the last slot.
        {
            let (gg, rest) = grads[o_lnf_g..].split_at_mut(d);
            nn::layer_norm_backward(
                &bwd.dyf,
                &p[o_lnf_g..o_lnf_g + d],
                &fwd.xhat_f,
                fwd.rstd_f,
                gg,
                &mut rest[..d],
                &mut bwd.dx[(s - 1) * d..s * d],
            );
        }
        for l in (0..self.n_layers).rev() {
            let o = self.layer_off(l);
            let c = &fwd.layers[l];
            // FFN half: x_out = x_in + W2·gelu(W1·LN2(x_in)+b1)+b2 —
            // the residual passes dx through; the FFN path adds to it.
            bwd.dg.fill(0.0);
            {
                let (gw, rest) = grads[o.w2..].split_at_mut(d * f);
                for r in 0..s {
                    nn::linear_backward(
                        &p[o.w2..][..d * f],
                        &c.g[r * f..(r + 1) * f],
                        &bwd.dx[r * d..(r + 1) * d],
                        gw,
                        &mut rest[..d],
                        Some(&mut bwd.dg[r * f..(r + 1) * f]),
                    );
                }
            }
            bwd.df1.fill(0.0);
            nn::gelu_backward(&c.f1, &bwd.dg, &mut bwd.df1);
            bwd.dy.fill(0.0);
            {
                let (gw, rest) = grads[o.w1..].split_at_mut(f * d);
                for r in 0..s {
                    nn::linear_backward(
                        &p[o.w1..][..f * d],
                        &c.y2[r * d..(r + 1) * d],
                        &bwd.df1[r * f..(r + 1) * f],
                        gw,
                        &mut rest[..f],
                        Some(&mut bwd.dy[r * d..(r + 1) * d]),
                    );
                }
            }
            {
                let (gg, rest) = grads[o.ln2_g..].split_at_mut(d);
                for r in 0..s {
                    nn::layer_norm_backward(
                        &bwd.dy[r * d..(r + 1) * d],
                        &p[o.ln2_g..o.ln2_g + d],
                        &c.xhat2[r * d..(r + 1) * d],
                        c.rstd2[r],
                        gg,
                        &mut rest[..d],
                        &mut bwd.dx[r * d..(r + 1) * d],
                    );
                }
            }
            // Attention half: x_out = x_in + Wo·ctx + bo.
            bwd.dctx.fill(0.0);
            {
                let (gw, rest) = grads[o.wo..].split_at_mut(d * d);
                for r in 0..s {
                    nn::linear_backward(
                        &p[o.wo..][..d * d],
                        &c.ctx[r * d..(r + 1) * d],
                        &bwd.dx[r * d..(r + 1) * d],
                        gw,
                        &mut rest[..d],
                        Some(&mut bwd.dctx[r * d..(r + 1) * d]),
                    );
                }
            }
            bwd.dq.fill(0.0);
            bwd.dk.fill(0.0);
            bwd.dv.fill(0.0);
            nn::attention_backward(
                &c.q,
                &c.k,
                &c.v,
                &c.attn,
                &bwd.dctx,
                s,
                self.n_heads,
                hd,
                &mut bwd.dq,
                &mut bwd.dk,
                &mut bwd.dv,
                &mut bwd.da_row,
            );
            bwd.dy.fill(0.0);
            for which in 0..3 {
                let w_off = match which {
                    0 => o.wq,
                    1 => o.wk,
                    _ => o.wv,
                };
                let (gw, rest) = grads[w_off..].split_at_mut(d * d);
                for r in 0..s {
                    let dsrc = match which {
                        0 => &bwd.dq[r * d..(r + 1) * d],
                        1 => &bwd.dk[r * d..(r + 1) * d],
                        _ => &bwd.dv[r * d..(r + 1) * d],
                    };
                    nn::linear_backward(
                        &p[w_off..][..d * d],
                        &c.y1[r * d..(r + 1) * d],
                        dsrc,
                        gw,
                        &mut rest[..d],
                        Some(&mut bwd.dy[r * d..(r + 1) * d]),
                    );
                }
            }
            {
                let (gg, rest) = grads[o.ln1_g..].split_at_mut(d);
                for r in 0..s {
                    nn::layer_norm_backward(
                        &bwd.dy[r * d..(r + 1) * d],
                        &p[o.ln1_g..o.ln1_g + d],
                        &c.xhat1[r * d..(r + 1) * d],
                        c.rstd1[r],
                        gg,
                        &mut rest[..d],
                        &mut bwd.dx[r * d..(r + 1) * d],
                    );
                }
            }
        }
        // Scatter into the embedding tables and the positional table
        // (every slot carries the positional embedding; only real
        // tokens carry table rows — mirroring `gather`).
        let (o_pc, o_page, o_delta, o_pos) = self.emb_off();
        for r in 0..s {
            let dxr = &bwd.dx[r * d..(r + 1) * d];
            for (g, &x) in grads[o_pos + r * d..o_pos + (r + 1) * d].iter_mut().zip(dxr) {
                *g += x;
            }
        }
        let toks = &window.tokens[window.tokens.len().saturating_sub(s)..];
        let pad = s - toks.len();
        for (i, tok) in toks.iter().enumerate() {
            let dxr = &bwd.dx[(pad + i) * d..(pad + i + 1) * d];
            let pc = (tok.pc_id.max(0) as usize).min(self.pc_rows - 1);
            let page = (tok.page_id.max(0) as usize).min(self.page_rows - 1);
            let delta = (tok.delta_id.max(0) as usize).min(self.n_classes - 1);
            for (g, &x) in grads[o_pc + pc * d..][..d].iter_mut().zip(dxr) {
                *g += x;
            }
            for (g, &x) in grads[o_page + page * d..][..d].iter_mut().zip(dxr) {
                *g += x;
            }
            for (g, &x) in grads[o_delta + delta * d..][..d].iter_mut().zip(dxr) {
                *g += x;
            }
        }
    }

    // ---- save / load ------------------------------------------------

    /// `(name, rows, cols, offset)` for every trainable tensor, in
    /// flat-vector order. 1-D tensors use `rows == 1`.
    fn tensor_layout(&self) -> Vec<(String, usize, usize, usize)> {
        let d = self.d_model;
        let f = self.d_ff;
        let (o_pc, o_page, o_delta, o_pos) = self.emb_off();
        let mut out = vec![
            ("emb_pc".to_string(), self.pc_rows, d, o_pc),
            ("emb_page".to_string(), self.page_rows, d, o_page),
            ("emb_delta".to_string(), self.n_classes, d, o_delta),
            ("pos".to_string(), self.seq_len, d, o_pos),
        ];
        for l in 0..self.n_layers {
            let o = self.layer_off(l);
            let pre = format!("l{l}.");
            out.push((format!("{pre}ln1_g"), 1, d, o.ln1_g));
            out.push((format!("{pre}ln1_b"), 1, d, o.ln1_b));
            for (name, off, rows, cols) in [
                ("wq", o.wq, d, d),
                ("wk", o.wk, d, d),
                ("wv", o.wv, d, d),
                ("wo", o.wo, d, d),
            ] {
                out.push((format!("{pre}{name}"), rows, cols, off));
                out.push((format!("{pre}b{}", &name[1..]), 1, d, off + rows * cols));
            }
            out.push((format!("{pre}ln2_g"), 1, d, o.ln2_g));
            out.push((format!("{pre}ln2_b"), 1, d, o.ln2_b));
            out.push((format!("{pre}w1"), f, d, o.w1));
            out.push((format!("{pre}b1"), 1, f, o.w1 + f * d));
            out.push((format!("{pre}w2"), d, f, o.w2));
            out.push((format!("{pre}b2"), 1, d, o.w2 + d * f));
        }
        let (o_lnf_g, o_lnf_b, o_out_w, o_out_b) = self.tail_off();
        out.push(("lnf_g".to_string(), 1, d, o_lnf_g));
        out.push(("lnf_b".to_string(), 1, d, o_lnf_b));
        out.push(("out_w".to_string(), self.n_classes, d, o_out_w));
        out.push(("out_b".to_string(), 1, self.n_classes, o_out_b));
        out
    }

    /// Write the weights as a tensor store (`dtype` f32, or int4 when
    /// `int4` — the paper's Table 7 storage mode, lossy; stored as
    /// per-tensor power-of-two-scaled int4 (dtype 3) so zero-centred
    /// trained weights survive — see [`crate::predictor::quant`]). A
    /// small f32 `meta` tensor records
    /// `[n_heads, n_layers, d_ff, seq_len]` — the facts weight dims
    /// alone can't recover — and is never quantized.
    pub fn save(&self, path: &Path, int4: bool) -> Result<()> {
        let dtype = if int4 { 3u8 } else { 0u8 };
        let mut tensors: Vec<(String, Vec<usize>, Vec<f32>, u8)> = self
            .tensor_layout()
            .into_iter()
            .map(|(name, rows, cols, off)| {
                let dims = if rows == 1 { vec![cols] } else { vec![rows, cols] };
                (name, dims, self.params[off..off + rows * cols].to_vec(), dtype)
            })
            .collect();
        tensors.push((
            "meta".to_string(),
            vec![META_LEN],
            vec![
                self.n_heads as f32,
                self.n_layers as f32,
                self.d_ff as f32,
                self.seq_len as f32,
            ],
            0,
        ));
        write_store(path, &tensors)
    }

    /// Load a model saved by [`TransformerBackend::save`]; shapes come
    /// from the tensor dims plus the `meta` tensor, optimizer state
    /// starts fresh from `cfg` (only `optimizer`/`lr` are used).
    pub fn load(path: &Path, cfg: &TransformerConfig) -> Result<Self> {
        let store = TensorStore::load(path)?;
        let find = |name: &str| {
            store
                .tensors
                .iter()
                .find(|t| t.name == name)
                .ok_or_else(|| anyhow::anyhow!("{}: missing tensor '{name}'", path.display()))
        };
        let meta = find("meta")?;
        if meta.numel() != META_LEN {
            bail!("{}: meta tensor must have {META_LEN} entries", path.display());
        }
        let n_heads = meta.data[0] as usize;
        let n_layers = meta.data[1] as usize;
        let d_ff = meta.data[2] as usize;
        let seq_len = meta.data[3] as usize;
        let emb_pc = find("emb_pc")?;
        let emb_page = find("emb_page")?;
        let emb_delta = find("emb_delta")?;
        let dims2 = |t: &crate::runtime::params::NamedTensor| -> Result<(usize, usize)> {
            match t.dims[..] {
                [r, c] => Ok((r, c)),
                _ => bail!("{}: tensor '{}' must be 2-D", path.display(), t.name),
            }
        };
        let (pc_rows, d_model) = dims2(emb_pc)?;
        let (page_rows, d2) = dims2(emb_page)?;
        let (n_classes, d3) = dims2(emb_delta)?;
        if d2 != d_model || d3 != d_model {
            bail!("{}: embedding widths disagree", path.display());
        }
        if n_heads == 0 || n_layers == 0 || d_ff == 0 || seq_len == 0 {
            bail!("{}: corrupt meta tensor {:?}", path.display(), meta.data);
        }
        if d_model % n_heads != 0 {
            bail!("{}: d_model {d_model} not divisible by n_heads {n_heads}", path.display());
        }
        let shape_cfg = TransformerConfig {
            d_model,
            n_heads,
            n_layers,
            d_ff,
            lr: cfg.lr,
            optimizer: cfg.optimizer,
            seed: cfg.seed,
        };
        let mut me = Self::with_shape(seq_len, n_classes, pc_rows, page_rows, &shape_cfg);
        for (name, rows, cols, off) in me.tensor_layout() {
            let t = find(&name)?;
            if t.numel() != rows * cols {
                bail!(
                    "{}: tensor '{name}' has {} values, expected {}",
                    path.display(),
                    t.numel(),
                    rows * cols
                );
            }
            me.params[off..off + rows * cols].copy_from_slice(&t.data);
        }
        Ok(me)
    }
}

impl PredictorBackend for TransformerBackend {
    fn name(&self) -> &'static str {
        "transformer"
    }

    fn predict(&mut self, windows: &[Window]) -> Vec<ClassId> {
        self.predict_batch(windows)
    }

    fn finetune(&mut self, batch: &[LabelledWindow]) -> Option<f64> {
        Some(self.train_batch(batch) as f64)
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn info(&self) -> BackendInfo {
        BackendInfo {
            arch: "transformer",
            n_params: self.n_params(),
            flops_per_inference: self.flops_per_inference(),
            precision: self.precision,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::FeatTok;

    fn tiny_cfg() -> TransformerConfig {
        TransformerConfig {
            d_model: 8,
            n_heads: 2,
            n_layers: 2,
            d_ff: 16,
            lr: 0.02,
            ..Default::default()
        }
    }

    fn window(deltas: &[i32]) -> Window {
        Window {
            tokens: deltas
                .iter()
                .map(|&d| FeatTok { pc_id: 0, page_id: 0, delta_id: d })
                .collect(),
        }
    }

    #[test]
    fn shapes_and_param_count() {
        let m = TransformerBackend::with_shape(4, 3, 5, 7, &tiny_cfg());
        let d = 8;
        let f = 16;
        let emb = (5 + 7 + 3 + 4) * d;
        let layer = 2 * d + 4 * (d * d + d) + 2 * d + (f * d + f) + (d * f + d);
        let tail = 2 * d + 3 * d + 3;
        assert_eq!(m.n_params(), emb + 2 * layer + tail);
        assert_eq!(m.seq_len(), 4);
        assert_eq!(m.n_classes(), 3);
        assert_eq!(m.n_heads(), 2);
        // Layout tensors tile the whole vector exactly once.
        let total: usize = m.tensor_layout().iter().map(|(_, r, c, _)| r * c).sum();
        assert_eq!(total, m.n_params());
        let mut offs: Vec<(usize, usize)> =
            m.tensor_layout().iter().map(|&(_, r, c, o)| (o, r * c)).collect();
        offs.sort();
        let mut cursor = 0;
        for (o, len) in offs {
            assert_eq!(o, cursor, "layout must be gap-free");
            cursor += len;
        }
        assert_eq!(cursor, m.n_params());
    }

    #[test]
    fn same_seed_same_init() {
        let a = TransformerBackend::with_shape(4, 3, 5, 7, &tiny_cfg());
        let b = TransformerBackend::with_shape(4, 3, 5, 7, &tiny_cfg());
        assert_eq!(a.params(), b.params());
    }

    #[test]
    fn training_reduces_loss_on_constant_task() {
        let mut m = TransformerBackend::with_shape(4, 3, 2, 2, &tiny_cfg());
        let batch: Vec<LabelledWindow> = (0..8)
            .map(|_| LabelledWindow { window: window(&[1, 1, 1, 1]), label: 1 })
            .collect();
        let first = m.train_batch(&batch);
        for _ in 0..60 {
            m.train_batch(&batch);
        }
        let last = m.train_batch(&batch);
        assert!(last < first, "loss {first} → {last}");
        assert_eq!(m.predict_one(&window(&[1, 1, 1, 1])), 1);
        assert_eq!(m.train_steps, 62);
    }

    #[test]
    fn short_and_long_windows_handled() {
        let m = TransformerBackend::with_shape(4, 3, 2, 2, &tiny_cfg());
        let c = m.predict_one(&window(&[1]));
        assert!((c as usize) < 3);
        // Over-long windows keep the newest tokens.
        let c2 = m.predict_one(&window(&[0, 0, 0, 2, 2, 2, 2, 2]));
        assert_eq!(c2, m.predict_one(&window(&[2, 2, 2, 2])));
    }

    #[test]
    fn out_of_range_ids_are_clamped() {
        let m = TransformerBackend::with_shape(4, 3, 2, 2, &tiny_cfg());
        let w = Window { tokens: vec![FeatTok { pc_id: -7, page_id: 9999, delta_id: 9999 }; 4] };
        assert!((m.predict_one(&w) as usize) < 3);
    }

    #[test]
    fn batched_forward_bit_identical_to_sequential() {
        let mut m = TransformerBackend::with_shape(4, 3, 5, 7, &tiny_cfg());
        let batch: Vec<LabelledWindow> = (0..6)
            .map(|i| LabelledWindow { window: window(&[i % 3, 1, 2, 0]), label: i % 3 })
            .collect();
        for _ in 0..5 {
            m.train_batch(&batch);
        }
        let windows = vec![
            window(&[1, 1, 1, 1]),
            window(&[2]),
            window(&[0, 1, 2, 0, 1, 2]),
            Window { tokens: vec![FeatTok { pc_id: -3, page_id: 999, delta_id: 999 }; 4] },
        ];
        let batched = m.logits_batch(&windows);
        let sequential: Vec<f32> = windows.iter().flat_map(|w| m.logits_one(w)).collect();
        assert_eq!(batched, sequential, "batched forward diverged from sequential");
        let classes = m.predict_batch(&windows);
        let one_by_one: Vec<ClassId> = windows.iter().map(|w| m.predict_one(w)).collect();
        assert_eq!(classes, one_by_one);
        assert!(m.logits_batch(&[]).is_empty());
    }

    #[test]
    fn attention_maps_are_distributions() {
        let m = TransformerBackend::with_shape(4, 3, 2, 2, &tiny_cfg());
        let (logits, maps) = m.attention_one(&window(&[1, 2, 0, 1]));
        assert_eq!(logits.len(), 3);
        assert_eq!(maps.len(), m.n_layers() * m.n_heads() * 4 * 4);
        for row in maps.chunks_exact(4) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-4, "rows sum to 1: {row:?}");
            assert!(row.iter().all(|&w| (0.0..=1.0).contains(&w)));
        }
    }

    #[test]
    fn save_load_roundtrip_preserves_params() {
        let dir = crate::util::TestDir::new();
        let p = dir.file("m.transformer.params.bin");
        let mut m = TransformerBackend::with_shape(4, 3, 5, 7, &tiny_cfg());
        let batch: Vec<LabelledWindow> =
            (0..4).map(|i| LabelledWindow { window: window(&[i, 1, 2, 0]), label: 2 }).collect();
        m.train_batch(&batch);
        m.save(&p, false).unwrap();
        let back = TransformerBackend::load(&p, &tiny_cfg()).unwrap();
        assert_eq!(back.params(), m.params());
        assert_eq!(back.seq_len(), 4);
        assert_eq!(back.n_heads(), 2);
        assert_eq!(back.n_layers(), 2);
    }

    #[test]
    fn load_rejects_missing_tensor() {
        let dir = crate::util::TestDir::new();
        let p = dir.file("bad.bin");
        write_store(
            &p,
            &[("meta".into(), vec![4], vec![2.0, 2.0, 16.0, 4.0], 0)],
        )
        .unwrap();
        let err = TransformerBackend::load(&p, &tiny_cfg()).unwrap_err().to_string();
        assert!(err.contains("emb_pc"), "{err}");
    }

    #[test]
    fn flops_count_is_positive_and_scales_with_layers() {
        let one = TransformerBackend::with_shape(
            6,
            4,
            2,
            2,
            &TransformerConfig { n_layers: 1, ..tiny_cfg() },
        );
        let two = TransformerBackend::with_shape(6, 4, 2, 2, &tiny_cfg());
        assert!(one.flops_per_inference() > 0);
        assert!(two.flops_per_inference() > one.flops_per_inference());
    }

    #[test]
    fn finetune_returns_real_loss() {
        let mut m = TransformerBackend::with_shape(4, 3, 2, 2, &tiny_cfg());
        let batch = vec![LabelledWindow { window: window(&[0, 1, 2, 0]), label: 0 }];
        let loss = m.finetune(&batch).expect("transformer supports learning");
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(m.train_steps, 1);
    }

    #[test]
    fn fast_tier_tracks_exact_and_quantized_is_rejected() {
        let mut m = TransformerBackend::with_shape(4, 3, 5, 7, &tiny_cfg());
        let batch: Vec<LabelledWindow> = (0..6)
            .map(|i| LabelledWindow { window: window(&[i % 3, 1, 2, 0]), label: i % 3 })
            .collect();
        for _ in 0..5 {
            m.train_batch(&batch);
        }
        let ws = vec![window(&[1, 1, 1, 1]), window(&[2]), window(&[0, 1, 2, 0])];
        let exact = m.logits_batch(&ws);
        m.set_precision(Precision::Fast).unwrap();
        assert_eq!(m.info().precision, Precision::Fast);
        let fast = m.logits_batch(&ws);
        for (f, e) in fast.iter().zip(&exact) {
            assert!((f - e).abs() <= 1e-3, "fast {f} vs exact {e}");
        }
        // Fast keeps batched == sequential bitwise.
        let sequential: Vec<f32> = ws.iter().flat_map(|w| m.logits_one(w)).collect();
        assert_eq!(fast, sequential);
        let err = m.set_precision(Precision::Int4).unwrap_err().to_string();
        assert!(err.contains("--precision int4"), "{err}");
        assert!(err.contains("--backend native"), "{err}");
    }
}
