//! Native pure-Rust learned backend — the paper's §6 *revised
//! predictor* (attention-free distillation of the Transformer),
//! trainable and servable without JAX, XLA or the `pjrt` feature.
//!
//! Architecture, matching `python/compile/model.py::RevisedPredictor`'s
//! embedding+FC path: per-feature embedding tables over the window's
//! (PC id, page bucket, Δ id) tokens, the per-token embeddings
//! concatenated position-wise into one input vector, then two
//! fully-connected layers with a ReLU between and a softmax over the
//! delta vocabulary (the last class is OOV). Training is plain
//! mini-batch SGD/Adam on cross-entropy — [`PredictorBackend::finetune`]
//! runs one step and returns the real loss, so the online fine-tune
//! path (`predictor::finetune`) finally learns in default builds.
//!
//! Weights round-trip through the same tensor-store container as the
//! AOT artifacts ([`crate::runtime::params`]): `repro train` writes
//! `<model>.native.params.bin` plus a manifest entry with
//! `arch = "native"`, and `--backend native` loads it back on the
//! eval/simulate path. Training arithmetic is scalar `f32` in a fixed
//! order, so same-seed training is byte-deterministic
//! (`rust/tests/native_backend.rs` pins this). Inference additionally
//! offers the faster tiers of [`crate::predictor::kernel`] — exact
//! (default, the bit-pinned oracle), fast (blocked f32), and
//! int8/int4 (integer accumulation straight off a dtype-3 store via
//! [`NativeBackend::load_with_precision`]).

use crate::predictor::kernel::{self, Precision, QuantizedLinear};
use crate::predictor::nn::{self, OptKind, Optimizer};
use crate::predictor::{
    BackendInfo, ClassId, DeltaVocab, LabelledWindow, PredictorBackend, Window,
};
use crate::runtime::params::{write_store, TensorStore};
use crate::util::XorShift64;
use anyhow::{bail, Result};
use std::path::Path;

/// Hyper-parameters of the native model (shapes come from the
/// [`DeltaVocab`] it is initialized against).
#[derive(Debug, Clone)]
pub struct NativeConfig {
    /// PC-embedding width.
    pub d_pc: usize,
    /// Page-bucket-embedding width.
    pub d_page: usize,
    /// Delta-embedding width.
    pub d_delta: usize,
    /// Hidden FC width.
    pub hidden: usize,
    /// Learning rate.
    pub lr: f32,
    pub optimizer: OptKind,
    /// Weight-init seed (same seed + same data ⇒ identical model).
    pub seed: u64,
}

impl Default for NativeConfig {
    fn default() -> Self {
        Self {
            d_pc: 8,
            d_page: 8,
            d_delta: 16,
            hidden: 64,
            lr: 1e-3,
            optimizer: OptKind::Adam,
            seed: 0x5eed,
        }
    }
}

/// Canonical tensor names in the `*.native.params.bin` store, in flat
/// parameter-vector order.
const TENSOR_NAMES: [&str; 7] =
    ["emb_pc", "emb_page", "emb_delta", "fc1_w", "fc1_b", "fc2_w", "fc2_b"];

/// The paper's revised predictor as an in-process Rust model.
///
/// ```
/// use uvm_prefetch::predictor::native::{NativeBackend, NativeConfig};
/// use uvm_prefetch::predictor::{DeltaVocab, FeatTok, LabelledWindow, PredictorBackend, Window};
///
/// let vocab = DeltaVocab::synthetic(vec![1, 7], 4);
/// let cfg = NativeConfig { d_pc: 2, d_page: 2, d_delta: 4, hidden: 8, lr: 0.05,
///                          ..Default::default() };
/// let mut model = NativeBackend::init(&vocab, &cfg);
/// let window = |d: i32| Window { tokens: vec![FeatTok { pc_id: 0, page_id: 0, delta_id: d }; 4] };
/// let batch: Vec<LabelledWindow> =
///     (0..8).map(|_| LabelledWindow { window: window(1), label: 1 }).collect();
/// for _ in 0..80 {
///     model.finetune(&batch).expect("native backend returns a real loss");
/// }
/// assert_eq!(model.predict(&[window(1)]), vec![1]);
/// ```
#[derive(Debug)]
pub struct NativeBackend {
    // Shape.
    seq_len: usize,
    n_classes: usize,
    pc_rows: usize,
    page_rows: usize,
    d_pc: usize,
    d_page: usize,
    d_delta: usize,
    hidden: usize,
    in_dim: usize,
    // Flat parameter vector; tensor offsets derived from the shape.
    params: Vec<f32>,
    opt: Optimizer,
    /// Total optimizer steps taken (offline + online).
    pub train_steps: u64,
    /// Kernel tier serving inference (training is always exact).
    precision: Precision,
    /// Integer FC layers, present only on the quantized tiers (built
    /// from the dtype-3 store's raw codes at load).
    qlayers: Option<QuantLayers>,
}

/// The two FC layers as served on the int8/int4 tiers; embeddings and
/// biases stay f32 (they are gathers and adds, not GEMMs).
#[derive(Debug)]
struct QuantLayers {
    fc1: QuantizedLinear,
    fc2: QuantizedLinear,
}

impl NativeBackend {
    /// Fresh model with seeded-deterministic Xavier-uniform weights.
    pub fn init(vocab: &DeltaVocab, cfg: &NativeConfig) -> Self {
        Self::with_shape(
            vocab.history_len.max(1),
            vocab.n_classes(),
            vocab.n_pc_slots(),
            vocab.n_page_buckets(),
            cfg,
        )
    }

    /// Init from explicit table shapes (the load path and tests).
    pub fn with_shape(
        seq_len: usize,
        n_classes: usize,
        pc_rows: usize,
        page_rows: usize,
        cfg: &NativeConfig,
    ) -> Self {
        assert!(seq_len > 0 && n_classes > 0 && pc_rows > 0 && page_rows > 0);
        assert!(cfg.d_pc > 0 && cfg.d_page > 0 && cfg.d_delta > 0 && cfg.hidden > 0);
        let in_dim = seq_len * (cfg.d_pc + cfg.d_page + cfg.d_delta);
        let mut rng = XorShift64::new(cfg.seed);
        let xavier = |fan_in: usize, fan_out: usize| (6.0 / (fan_in + fan_out) as f32).sqrt();
        let mut params = Vec::new();
        params.extend(nn::init_uniform(&mut rng, pc_rows * cfg.d_pc, 0.1));
        params.extend(nn::init_uniform(&mut rng, page_rows * cfg.d_page, 0.1));
        params.extend(nn::init_uniform(&mut rng, n_classes * cfg.d_delta, 0.1));
        params.extend(nn::init_uniform(&mut rng, cfg.hidden * in_dim, xavier(in_dim, cfg.hidden)));
        params.extend(vec![0.0; cfg.hidden]);
        params.extend(nn::init_uniform(
            &mut rng,
            n_classes * cfg.hidden,
            xavier(cfg.hidden, n_classes),
        ));
        params.extend(vec![0.0; n_classes]);
        let opt = Optimizer::new(cfg.optimizer, cfg.lr, params.len());
        Self {
            seq_len,
            n_classes,
            pc_rows,
            page_rows,
            d_pc: cfg.d_pc,
            d_page: cfg.d_page,
            d_delta: cfg.d_delta,
            hidden: cfg.hidden,
            in_dim,
            params,
            opt,
            train_steps: 0,
            precision: Precision::Exact,
            qlayers: None,
        }
    }

    /// Tensor `(offset, rows, cols)` triples in [`TENSOR_NAMES`] order.
    fn layout(&self) -> [(usize, usize, usize); 7] {
        let shapes = [
            (self.pc_rows, self.d_pc),
            (self.page_rows, self.d_page),
            (self.n_classes, self.d_delta),
            (self.hidden, self.in_dim),
            (1, self.hidden),
            (self.n_classes, self.hidden),
            (1, self.n_classes),
        ];
        let mut out = [(0, 0, 0); 7];
        let mut off = 0;
        for (slot, (rows, cols)) in out.iter_mut().zip(shapes) {
            *slot = (off, rows, cols);
            off += rows * cols;
        }
        debug_assert_eq!(off, self.params.len());
        out
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Output classes including OOV (also exposed through the
    /// [`PredictorBackend`] trait; inherent so callers holding a
    /// concrete model need no trait import).
    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }

    /// The flat parameter vector (tests compare models through this).
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Switch the serving tier. Exact/fast always work; the quantized
    /// tiers need the integer plane a quantized load builds — use
    /// [`NativeBackend::load_with_precision`] on an int4 (dtype-3)
    /// checkpoint for those.
    pub fn set_precision(&mut self, precision: Precision) -> Result<()> {
        if precision.is_quantized() && self.qlayers.is_none() {
            bail!(
                "native backend: --precision {} needs an int4 (dtype-3) checkpoint loaded \
                 through load_with_precision; this instance has only f32 weights",
                precision.as_str()
            );
        }
        self.precision = precision;
        Ok(())
    }

    /// Analytic FLOPs for one window's forward pass (MAC = 2 flops):
    /// the two FC layers plus the ReLU — the embedding gather is
    /// copies, not arithmetic. The denominator of `repro analyze`'s
    /// transformer-vs-native cost ratio (the paper's
    /// "orders-of-magnitude cheaper" claim, measured).
    pub fn flops_per_inference(&self) -> u64 {
        (2 * self.in_dim * self.hidden + self.hidden + 2 * self.hidden * self.n_classes) as u64
    }

    /// Gather the window's token embeddings into the input vector
    /// (position-wise concatenation). Windows shorter than `seq_len`
    /// are left-padded with zeros; longer ones keep the newest tokens.
    fn gather(&self, window: &Window, x: &mut [f32]) {
        debug_assert_eq!(x.len(), self.in_dim);
        x.fill(0.0);
        let [(o_pc, ..), (o_page, ..), (o_delta, ..), ..] = self.layout();
        let d_tok = self.d_pc + self.d_page + self.d_delta;
        let toks = &window.tokens[window.tokens.len().saturating_sub(self.seq_len)..];
        let pad = self.seq_len - toks.len();
        for (pos, tok) in toks.iter().enumerate() {
            let base = (pad + pos) * d_tok;
            let pc = (tok.pc_id.max(0) as usize).min(self.pc_rows - 1);
            let page = (tok.page_id.max(0) as usize).min(self.page_rows - 1);
            let delta = (tok.delta_id.max(0) as usize).min(self.n_classes - 1);
            x[base..base + self.d_pc]
                .copy_from_slice(&self.params[o_pc + pc * self.d_pc..][..self.d_pc]);
            x[base + self.d_pc..base + self.d_pc + self.d_page]
                .copy_from_slice(&self.params[o_page + page * self.d_page..][..self.d_page]);
            x[base + self.d_pc + self.d_page..base + d_tok]
                .copy_from_slice(&self.params[o_delta + delta * self.d_delta..][..self.d_delta]);
        }
    }

    /// Forward pass into caller-provided scratch; `z` ends as logits.
    fn forward(&self, window: &Window, x: &mut [f32], h: &mut [f32], z: &mut [f32]) {
        let [_, _, _, (o_w1, ..), (o_b1, ..), (o_w2, ..), (o_b2, ..)] = self.layout();
        self.gather(window, x);
        nn::linear_forward(
            &self.params[o_w1..o_w1 + self.hidden * self.in_dim],
            &self.params[o_b1..o_b1 + self.hidden],
            x,
            h,
        );
        nn::relu(h);
        nn::linear_forward(
            &self.params[o_w2..o_w2 + self.n_classes * self.hidden],
            &self.params[o_b2..o_b2 + self.n_classes],
            h,
            z,
        );
    }

    /// Top-1 class for one window.
    pub fn predict_one(&self, window: &Window) -> ClassId {
        Self::argmax(&self.logits_one(window))
    }

    /// First maximum wins — the tie-break both the sequential and the
    /// batched paths share.
    fn argmax(z: &[f32]) -> ClassId {
        let mut best = 0usize;
        for (i, &v) in z.iter().enumerate() {
            if v > z[best] {
                best = i;
            }
        }
        best as ClassId
    }

    /// Logits for one window (sequential reference path; the batched
    /// path is pinned against this bit-for-bit). On the exact tier
    /// this is the original scratch-buffer loop; the other tiers
    /// route through [`NativeBackend::logits_batch`] with a batch of
    /// one, which keeps batched == sequential trivially true there
    /// too.
    pub fn logits_one(&self, window: &Window) -> Vec<f32> {
        if !self.precision.is_exact() {
            return self.logits_batch(std::slice::from_ref(window));
        }
        let mut x = vec![0.0; self.in_dim];
        let mut h = vec![0.0; self.hidden];
        let mut z = vec![0.0; self.n_classes];
        self.forward(window, &mut x, &mut h, &mut z);
        z
    }

    /// Batched forward: gathers every window into one `[n × in_dim]`
    /// input matrix and runs each FC layer as a single batched GEMM
    /// through the precision-tier dispatch
    /// ([`kernel::linear_forward_batch`], or the integer plane on the
    /// quantized tiers) — no per-window scratch allocations, no
    /// per-window dispatch. Returns the flat
    /// `[n × n_classes]` logits, **bit-identical** to concatenating
    /// [`NativeBackend::logits_one`] over the batch (pinned by
    /// `batched_forward_bit_identical_to_sequential`).
    pub fn logits_batch(&self, windows: &[Window]) -> Vec<f32> {
        let n = windows.len();
        let [_, _, _, (o_w1, ..), (o_b1, ..), (o_w2, ..), (o_b2, ..)] = self.layout();
        let mut xs = vec![0.0f32; n * self.in_dim];
        for (w, x) in windows.iter().zip(xs.chunks_exact_mut(self.in_dim)) {
            self.gather(w, x);
        }
        let mut hs = vec![0.0f32; n * self.hidden];
        let mut zs = vec![0.0f32; n * self.n_classes];
        match (&self.qlayers, self.precision) {
            (Some(q), p) if p.is_quantized() => {
                q.fc1.forward_batch(&self.params[o_b1..o_b1 + self.hidden], &xs, &mut hs);
                nn::relu(&mut hs);
                q.fc2.forward_batch(&self.params[o_b2..o_b2 + self.n_classes], &hs, &mut zs);
            }
            _ => {
                kernel::linear_forward_batch(
                    self.precision,
                    &self.params[o_w1..o_w1 + self.hidden * self.in_dim],
                    &self.params[o_b1..o_b1 + self.hidden],
                    &xs,
                    &mut hs,
                    self.in_dim,
                    self.hidden,
                );
                nn::relu(&mut hs);
                kernel::linear_forward_batch(
                    self.precision,
                    &self.params[o_w2..o_w2 + self.n_classes * self.hidden],
                    &self.params[o_b2..o_b2 + self.n_classes],
                    &hs,
                    &mut zs,
                    self.hidden,
                    self.n_classes,
                );
            }
        }
        zs
    }

    /// Top-1 class per window through the batched forward.
    pub fn predict_batch(&self, windows: &[Window]) -> Vec<ClassId> {
        let zs = self.logits_batch(windows);
        zs.chunks_exact(self.n_classes).map(Self::argmax).collect()
    }

    /// One optimizer step over `batch`; returns the mean cross-entropy
    /// loss *before* the update.
    pub fn train_batch(&mut self, batch: &[LabelledWindow]) -> f32 {
        if batch.is_empty() {
            return 0.0;
        }
        let [(o_pc, ..), (o_page, ..), (o_delta, ..), (o_w1, ..), _, (o_w2, ..), _] =
            self.layout();
        let d_tok = self.d_pc + self.d_page + self.d_delta;
        let mut grads = vec![0.0f32; self.params.len()];
        let mut x = vec![0.0; self.in_dim];
        let mut h = vec![0.0; self.hidden];
        let mut z = vec![0.0; self.n_classes];
        let mut dh = vec![0.0; self.hidden];
        let mut dx = vec![0.0; self.in_dim];
        let mut loss = 0.0f32;
        for lw in batch {
            self.forward(&lw.window, &mut x, &mut h, &mut z);
            nn::softmax(&mut z);
            let label = (lw.label.max(0) as usize).min(self.n_classes - 1);
            loss += nn::cross_entropy_backward(&mut z, label);
            // z now holds d(loss)/d(logits).
            dh.fill(0.0);
            dx.fill(0.0);
            {
                let (gw2, rest) = grads[o_w2..].split_at_mut(self.n_classes * self.hidden);
                nn::linear_backward(
                    &self.params[o_w2..o_w2 + self.n_classes * self.hidden],
                    &h,
                    &z,
                    gw2,
                    &mut rest[..self.n_classes],
                    Some(&mut dh),
                );
            }
            nn::relu_backward(&h, &mut dh);
            {
                let (gw1, rest) = grads[o_w1..].split_at_mut(self.hidden * self.in_dim);
                nn::linear_backward(
                    &self.params[o_w1..o_w1 + self.hidden * self.in_dim],
                    &x,
                    &dh,
                    gw1,
                    &mut rest[..self.hidden],
                    Some(&mut dx),
                );
            }
            // Scatter the input gradient back into the embedding rows
            // the gather read (zero-padded positions carry none).
            let toks = &lw.window.tokens[lw.window.tokens.len().saturating_sub(self.seq_len)..];
            let pad = self.seq_len - toks.len();
            for (pos, tok) in toks.iter().enumerate() {
                let base = (pad + pos) * d_tok;
                let pc = (tok.pc_id.max(0) as usize).min(self.pc_rows - 1);
                let page = (tok.page_id.max(0) as usize).min(self.page_rows - 1);
                let delta = (tok.delta_id.max(0) as usize).min(self.n_classes - 1);
                let scatter = |g: &mut [f32], d: &[f32]| {
                    for (gi, di) in g.iter_mut().zip(d) {
                        *gi += di;
                    }
                };
                scatter(
                    &mut grads[o_pc + pc * self.d_pc..][..self.d_pc],
                    &dx[base..base + self.d_pc],
                );
                scatter(
                    &mut grads[o_page + page * self.d_page..][..self.d_page],
                    &dx[base + self.d_pc..base + self.d_pc + self.d_page],
                );
                scatter(
                    &mut grads[o_delta + delta * self.d_delta..][..self.d_delta],
                    &dx[base + self.d_pc + self.d_page..base + d_tok],
                );
            }
        }
        let inv = 1.0 / batch.len() as f32;
        for g in &mut grads {
            *g *= inv;
        }
        self.opt.step(&mut self.params, &grads);
        self.train_steps += 1;
        loss * inv
    }

    /// Fraction of `data` whose top-1 prediction matches the label.
    pub fn top1_accuracy(&self, data: &[LabelledWindow]) -> f64 {
        if data.is_empty() {
            return 0.0;
        }
        let hits = data
            .iter()
            .filter(|lw| self.predict_one(&lw.window) == lw.label.max(0) as ClassId)
            .count();
        hits as f64 / data.len() as f64
    }

    /// Write the weights as a tensor store (`dtype` f32, or int4 when
    /// `int4` — the paper's Table 7 storage mode, lossy; stored as
    /// per-tensor power-of-two-scaled int4 (dtype 3) so zero-centred
    /// trained weights survive — see [`crate::predictor::quant`]).
    pub fn save(&self, path: &Path, int4: bool) -> Result<()> {
        let dtype = if int4 { 3u8 } else { 0u8 };
        let tensors: Vec<(String, Vec<usize>, Vec<f32>, u8)> = TENSOR_NAMES
            .iter()
            .zip(self.layout())
            .map(|(name, (off, rows, cols))| {
                let dims = if rows == 1 { vec![cols] } else { vec![rows, cols] };
                (name.to_string(), dims, self.params[off..off + rows * cols].to_vec(), dtype)
            })
            .collect();
        write_store(path, &tensors)
    }

    /// Load a model saved by [`NativeBackend::save`]; shapes are
    /// recovered from the tensor dims, optimizer state starts fresh
    /// from `cfg` (only its `optimizer`/`lr` fields are used).
    pub fn load(path: &Path, cfg: &NativeConfig) -> Result<Self> {
        Self::load_with_precision(path, cfg, Precision::Exact)
    }

    /// Load and pin a serving tier in one step. The quantized tiers
    /// require a dtype-3 (scaled-int4) store: the raw codes become
    /// the integer FC plane and are *also* dequantized into the f32
    /// parameter vector (embeddings, biases, and anything that still
    /// wants f32 reads the latter). An f32-only checkpoint fails with
    /// an error naming the flag to fix.
    pub fn load_with_precision(
        path: &Path,
        cfg: &NativeConfig,
        precision: Precision,
    ) -> Result<Self> {
        let store = TensorStore::load(path)?;
        let find = |name: &str| {
            store
                .tensors
                .iter()
                .find(|t| t.name == name)
                .ok_or_else(|| anyhow::anyhow!("{}: missing tensor '{name}'", path.display()))
        };
        let emb_pc = find("emb_pc")?;
        let emb_page = find("emb_page")?;
        let emb_delta = find("emb_delta")?;
        let fc1_w = find("fc1_w")?;
        let fc1_b = find("fc1_b")?;
        let fc2_w = find("fc2_w")?;
        let fc2_b = find("fc2_b")?;
        let dims2 = |t: &crate::runtime::params::NamedTensor| -> Result<(usize, usize)> {
            match t.dims[..] {
                [r, c] => Ok((r, c)),
                _ => bail!("{}: tensor '{}' must be 2-D", path.display(), t.name),
            }
        };
        let (pc_rows, d_pc) = dims2(emb_pc)?;
        let (page_rows, d_page) = dims2(emb_page)?;
        let (n_classes, d_delta) = dims2(emb_delta)?;
        let (hidden, in_dim) = dims2(fc1_w)?;
        let d_tok = d_pc + d_page + d_delta;
        if d_tok == 0 || in_dim % d_tok != 0 {
            bail!("{}: fc1_w dim {in_dim} not a multiple of token dim {d_tok}", path.display());
        }
        let seq_len = in_dim / d_tok;
        let (c2, h2) = dims2(fc2_w)?;
        let biases_ok = fc1_b.numel() == hidden && fc2_b.numel() == n_classes;
        if c2 != n_classes || h2 != hidden || !biases_ok {
            bail!("{}: inconsistent tensor shapes", path.display());
        }
        let total = emb_pc.numel()
            + emb_page.numel()
            + emb_delta.numel()
            + fc1_w.numel()
            + hidden
            + fc2_w.numel()
            + n_classes;
        let mut params = Vec::with_capacity(total);
        for t in [emb_pc, emb_page, emb_delta, fc1_w, fc1_b, fc2_w, fc2_b] {
            params.extend_from_slice(&t.data);
        }
        let opt = Optimizer::new(cfg.optimizer, cfg.lr, params.len());
        let qlayers = if precision.is_quantized() {
            let payload = |t: &crate::runtime::params::NamedTensor| {
                t.quant.as_ref().ok_or_else(|| {
                    anyhow::anyhow!(
                        "{}: tensor '{}' is stored as f32 — --precision {} needs an int4 \
                         (dtype-3) checkpoint; retrain with `repro train` (which writes the \
                         .int4.params.bin sibling) or use --precision exact|fast",
                        path.display(),
                        t.name,
                        precision.as_str()
                    )
                })
            };
            let q1 = payload(fc1_w)?;
            let q2 = payload(fc2_w)?;
            Some(QuantLayers {
                fc1: QuantizedLinear::from_packed(&q1.packed, q1.scale, hidden, in_dim, precision)?,
                fc2: QuantizedLinear::from_packed(
                    &q2.packed,
                    q2.scale,
                    n_classes,
                    hidden,
                    precision,
                )?,
            })
        } else {
            None
        };
        Ok(Self {
            seq_len,
            n_classes,
            pc_rows,
            page_rows,
            d_pc,
            d_page,
            d_delta,
            hidden,
            in_dim,
            params,
            opt,
            train_steps: 0,
            precision,
            qlayers,
        })
    }
}

impl PredictorBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn predict(&mut self, windows: &[Window]) -> Vec<ClassId> {
        self.predict_batch(windows)
    }

    fn finetune(&mut self, batch: &[LabelledWindow]) -> Option<f64> {
        // The quantized tiers serve a frozen integer plane; an f32
        // parameter update would silently diverge from the codes the
        // forward pass actually reads, so learning is disabled there.
        if self.precision.is_quantized() {
            return None;
        }
        Some(self.train_batch(batch) as f64)
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn info(&self) -> BackendInfo {
        BackendInfo {
            arch: "native",
            n_params: self.n_params(),
            flops_per_inference: self.flops_per_inference(),
            precision: self.precision,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::FeatTok;

    fn tiny_cfg() -> NativeConfig {
        NativeConfig { d_pc: 2, d_page: 2, d_delta: 4, hidden: 8, lr: 0.05, ..Default::default() }
    }

    fn window(deltas: &[i32]) -> Window {
        Window {
            tokens: deltas
                .iter()
                .map(|&d| FeatTok { pc_id: 0, page_id: 0, delta_id: d })
                .collect(),
        }
    }

    #[test]
    fn shapes_and_param_count() {
        let m = NativeBackend::with_shape(4, 3, 5, 7, &tiny_cfg());
        // 5*2 + 7*2 + 3*4 + 8*(4*8) + 8 + 3*8 + 3.
        assert_eq!(m.n_params(), 10 + 14 + 12 + 256 + 8 + 24 + 3);
        assert_eq!(m.seq_len(), 4);
        assert_eq!(m.n_classes(), 3);
    }

    #[test]
    fn same_seed_same_init() {
        let a = NativeBackend::with_shape(4, 3, 5, 7, &tiny_cfg());
        let b = NativeBackend::with_shape(4, 3, 5, 7, &tiny_cfg());
        assert_eq!(a.params(), b.params());
    }

    #[test]
    fn training_reduces_loss_on_constant_task() {
        let mut m = NativeBackend::with_shape(4, 3, 2, 2, &tiny_cfg());
        let batch: Vec<LabelledWindow> = (0..8)
            .map(|_| LabelledWindow { window: window(&[1, 1, 1, 1]), label: 1 })
            .collect();
        let first = m.train_batch(&batch);
        for _ in 0..80 {
            m.train_batch(&batch);
        }
        let last = m.train_batch(&batch);
        assert!(last < first, "loss {first} → {last}");
        assert_eq!(m.predict_one(&window(&[1, 1, 1, 1])), 1);
    }

    #[test]
    fn short_windows_are_left_padded() {
        let m = NativeBackend::with_shape(4, 3, 2, 2, &tiny_cfg());
        // Must not panic and must produce a valid class.
        let c = m.predict_one(&window(&[1]));
        assert!((c as usize) < 3);
        // Over-long windows keep the newest tokens.
        let c2 = m.predict_one(&window(&[0, 0, 0, 2, 2, 2, 2, 2]));
        assert_eq!(c2, m.predict_one(&window(&[2, 2, 2, 2])));
    }

    #[test]
    fn out_of_range_ids_are_clamped() {
        let m = NativeBackend::with_shape(4, 3, 2, 2, &tiny_cfg());
        let w = Window { tokens: vec![FeatTok { pc_id: -7, page_id: 9999, delta_id: 9999 }; 4] };
        assert!((m.predict_one(&w) as usize) < 3);
    }

    #[test]
    fn save_load_roundtrip_preserves_params() {
        let dir = crate::util::TestDir::new();
        let p = dir.file("m.native.params.bin");
        let mut m = NativeBackend::with_shape(4, 3, 5, 7, &tiny_cfg());
        let batch: Vec<LabelledWindow> =
            (0..4).map(|i| LabelledWindow { window: window(&[i, 1, 2, 0]), label: 2 }).collect();
        m.train_batch(&batch);
        m.save(&p, false).unwrap();
        let back = NativeBackend::load(&p, &tiny_cfg()).unwrap();
        assert_eq!(back.params(), m.params());
        assert_eq!(back.seq_len(), 4);
        assert_eq!(back.n_classes(), 3);
    }

    #[test]
    fn load_rejects_missing_tensor() {
        let dir = crate::util::TestDir::new();
        let p = dir.file("bad.bin");
        write_store(&p, &[("emb_pc".into(), vec![2, 2], vec![0.0; 4], 0)]).unwrap();
        let err = NativeBackend::load(&p, &tiny_cfg()).unwrap_err().to_string();
        assert!(err.contains("emb_page"), "{err}");
    }

    #[test]
    fn batched_forward_bit_identical_to_sequential() {
        // Trained (non-symmetric) weights + a batch mixing full,
        // short (padded) and out-of-range windows: the batched GEMM
        // must reproduce the sequential logits exactly, bit for bit.
        let mut m = NativeBackend::with_shape(4, 3, 5, 7, &tiny_cfg());
        let batch: Vec<LabelledWindow> = (0..6)
            .map(|i| LabelledWindow { window: window(&[i % 3, 1, 2, 0]), label: i % 3 })
            .collect();
        for _ in 0..10 {
            m.train_batch(&batch);
        }
        let windows = vec![
            window(&[1, 1, 1, 1]),
            window(&[2]),
            window(&[0, 1, 2, 0, 1, 2]),
            Window { tokens: vec![FeatTok { pc_id: -3, page_id: 999, delta_id: 999 }; 4] },
        ];
        let batched = m.logits_batch(&windows);
        assert_eq!(batched.len(), windows.len() * 3);
        let sequential: Vec<f32> =
            windows.iter().flat_map(|w| m.logits_one(w)).collect();
        assert_eq!(batched, sequential, "batched forward diverged from sequential");
        let classes = m.predict_batch(&windows);
        let one_by_one: Vec<ClassId> = windows.iter().map(|w| m.predict_one(w)).collect();
        assert_eq!(classes, one_by_one);
        assert!(m.logits_batch(&[]).is_empty());
    }

    #[test]
    fn quantized_load_serves_from_codes_and_rejects_f32_stores() {
        let dir = crate::util::TestDir::new();
        let pf = dir.file("m.native.params.bin");
        let pq = dir.file("m.native.int4.params.bin");
        let mut m = NativeBackend::with_shape(4, 3, 5, 7, &tiny_cfg());
        let batch: Vec<LabelledWindow> = (0..6)
            .map(|i| LabelledWindow { window: window(&[i % 3, 1, 2, 0]), label: i % 3 })
            .collect();
        for _ in 0..20 {
            m.train_batch(&batch);
        }
        m.save(&pf, false).unwrap();
        m.save(&pq, true).unwrap();
        // f32-only checkpoint + quantized tier → named-flag error.
        let err = NativeBackend::load_with_precision(&pf, &tiny_cfg(), Precision::Int4)
            .unwrap_err()
            .to_string();
        assert!(err.contains("--precision int4"), "{err}");
        // Both quantized tiers load the dtype-3 store and agree bitwise.
        let q8 = NativeBackend::load_with_precision(&pq, &tiny_cfg(), Precision::Int8).unwrap();
        let mut q4 = NativeBackend::load_with_precision(&pq, &tiny_cfg(), Precision::Int4).unwrap();
        assert_eq!(q8.precision(), Precision::Int8);
        let ws = vec![window(&[1, 1, 1, 1]), window(&[2]), window(&[0, 1, 2, 0])];
        let b8 = q8.logits_batch(&ws);
        assert_eq!(b8, q4.logits_batch(&ws), "int8 and int4 read the same codes");
        let sequential: Vec<f32> = ws.iter().flat_map(|w| q4.logits_one(w)).collect();
        assert_eq!(b8, sequential, "quantized batched == sequential");
        // The integer plane is frozen: no online learning.
        assert!(q4.finetune(&batch).is_none());
        assert_eq!(q4.info().precision, Precision::Int4);
    }

    #[test]
    fn finetune_returns_real_loss() {
        let mut m = NativeBackend::with_shape(4, 3, 2, 2, &tiny_cfg());
        let batch = vec![LabelledWindow { window: window(&[0, 1, 2, 0]), label: 0 }];
        let loss = m.finetune(&batch).expect("native supports learning");
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(m.train_steps, 1);
    }
}
