//! Delta vocabulary + feature encoders, loaded from the
//! `*.vocab.json` artifact written by `python/compile/aot.py`.
//!
//! The classification categories are the unique page deltas observed
//! in the training corpus (Hashemi et al.'s observation that unique
//! deltas are orders of magnitude fewer than unique addresses — paper
//! §4). The last class id is the out-of-vocabulary class; PC and page
//! features are encoded exactly as at training time (closed PC table
//! with OOV slot, page → modulo bucket).

use crate::predictor::{FeatTok, Prediction};
use crate::types::{PageDelta, PageNum};
use crate::util::json::{arr_i64, arr_u64, vec_i64, vec_u64};
use crate::util::Json;
use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;

/// JSON schema shared with python (`data.py::Vocab.to_json`).
#[derive(Debug, Clone)]
pub struct VocabFile {
    pub deltas: Vec<i64>,
    pub pcs: Vec<u64>,
    pub page_buckets: u32,
    pub dominant_delta: i64,
    /// Paper §5.4: largest delta count / total samples.
    pub convergence: f64,
    pub history_len: usize,
}

impl VocabFile {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("deltas", arr_i64(&self.deltas)),
            ("pcs", arr_u64(&self.pcs)),
            ("page_buckets", Json::Num(self.page_buckets as f64)),
            ("dominant_delta", Json::Num(self.dominant_delta as f64)),
            ("convergence", Json::Num(self.convergence)),
            ("history_len", Json::Num(self.history_len as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            deltas: vec_i64(j.req("deltas")?)?,
            pcs: vec_u64(j.req("pcs")?)?,
            page_buckets: j.req("page_buckets")?.as_u64().unwrap_or(4096) as u32,
            dominant_delta: j.req("dominant_delta")?.as_i64().unwrap_or(1),
            convergence: j.req("convergence")?.as_f64().unwrap_or(0.0),
            history_len: j.req("history_len")?.as_usize().unwrap_or(30),
        })
    }
}

/// Runtime-side vocabulary with O(1) encode/decode.
///
/// ```
/// use uvm_prefetch::predictor::{DeltaVocab, Prediction};
///
/// let v = DeltaVocab::synthetic(vec![1, 4], 30);
/// assert_eq!(v.n_classes(), 3, "two deltas + the OOV class");
/// assert_eq!(v.encode_delta(4), 1);
/// assert_eq!(v.encode_delta(999), v.oov_class(), "unseen delta");
/// assert_eq!(v.decode(1), Prediction::Delta(4));
/// assert_eq!(v.decode(2), Prediction::Oov);
/// ```
#[derive(Debug, Clone)]
pub struct DeltaVocab {
    deltas: Vec<i64>,
    delta_ids: HashMap<i64, u32>,
    pc_ids: HashMap<u64, u32>,
    n_pcs: u32,
    page_buckets: u32,
    pub dominant_delta: PageDelta,
    pub convergence: f64,
    pub history_len: usize,
}

impl DeltaVocab {
    pub fn from_file(path: &Path) -> Result<Self> {
        let file = VocabFile::from_json(&Json::parse_file(path)?)?;
        Ok(Self::from_parts(file))
    }

    pub fn from_parts(file: VocabFile) -> Self {
        let delta_ids =
            file.deltas.iter().enumerate().map(|(i, &d)| (d, i as u32)).collect();
        let pc_ids = file.pcs.iter().enumerate().map(|(i, &p)| (p, i as u32)).collect();
        Self {
            delta_ids,
            pc_ids,
            n_pcs: file.pcs.len() as u32,
            page_buckets: file.page_buckets.max(1),
            dominant_delta: file.dominant_delta,
            convergence: file.convergence,
            history_len: file.history_len,
            deltas: file.deltas,
        }
    }

    /// Number of output classes including OOV.
    pub fn n_classes(&self) -> usize {
        self.deltas.len() + 1
    }

    /// The OOV class id (`len(deltas)`).
    pub fn oov_class(&self) -> u32 {
        self.deltas.len() as u32
    }

    /// Encode a delta to its class id (OOV when unseen).
    pub fn encode_delta(&self, delta: PageDelta) -> u32 {
        self.delta_ids.get(&delta).copied().unwrap_or(self.oov_class())
    }

    /// Decode a class id back to a prediction.
    pub fn decode(&self, class: u32) -> Prediction {
        match self.deltas.get(class as usize) {
            Some(&d) => Prediction::Delta(d),
            None => Prediction::Oov,
        }
    }

    /// Rows a PC embedding table must have: the closed PC table plus
    /// its OOV slot (the largest id [`DeltaVocab::encode_pc`] emits).
    pub fn n_pc_slots(&self) -> usize {
        self.n_pcs as usize + 1
    }

    /// Rows a page embedding table must have (the modulo-bucket count).
    pub fn n_page_buckets(&self) -> usize {
        self.page_buckets as usize
    }

    /// Encode a PC (last table slot is the PC-OOV bucket).
    pub fn encode_pc(&self, pc: u64) -> i32 {
        self.pc_ids.get(&pc).map(|&i| i as i32).unwrap_or(self.n_pcs as i32)
    }

    /// Encode a page address into its embedding bucket.
    pub fn encode_page(&self, page: PageNum) -> i32 {
        (page % self.page_buckets as u64) as i32
    }

    /// Featurize a raw history token.
    pub fn featurize(&self, tok: &crate::predictor::history::HistoryToken) -> FeatTok {
        FeatTok {
            pc_id: self.encode_pc(tok.pc),
            page_id: self.encode_page(tok.page),
            delta_id: self.encode_delta(tok.delta) as i32,
        }
    }

    /// A trivial vocabulary for tests and the stride backend.
    pub fn synthetic(deltas: Vec<i64>, history_len: usize) -> Self {
        Self::from_parts(VocabFile {
            dominant_delta: deltas.first().copied().unwrap_or(1),
            deltas,
            pcs: vec![],
            page_buckets: 1024,
            convergence: 0.0,
            history_len,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab() -> DeltaVocab {
        DeltaVocab::from_parts(VocabFile {
            deltas: vec![-2, 1, 4],
            pcs: vec![0x10, 0x20],
            page_buckets: 8,
            dominant_delta: 1,
            convergence: 0.7,
            history_len: 30,
        })
    }

    #[test]
    fn delta_roundtrip_and_oov() {
        let v = vocab();
        assert_eq!(v.n_classes(), 4);
        assert_eq!(v.encode_delta(1), 1);
        assert_eq!(v.encode_delta(4), 2);
        assert_eq!(v.encode_delta(999), 3, "unseen → OOV class");
        assert_eq!(v.decode(0), Prediction::Delta(-2));
        assert_eq!(v.decode(3), Prediction::Oov);
        assert_eq!(v.decode(77), Prediction::Oov);
    }

    #[test]
    fn pc_and_page_encoding() {
        let v = vocab();
        assert_eq!(v.encode_pc(0x20), 1);
        assert_eq!(v.encode_pc(0x999), 2, "unseen PC → OOV slot");
        assert_eq!(v.encode_page(9), 1, "modulo bucket");
    }

    #[test]
    fn json_file_roundtrip() {
        let dir = crate::util::TestDir::new();
        let p = dir.file("v.json");
        let file = VocabFile {
            deltas: vec![1, 2],
            pcs: vec![5],
            page_buckets: 16,
            dominant_delta: 1,
            convergence: 0.99,
            history_len: 30,
        };
        file.to_json().write_file(&p).unwrap();
        let v = DeltaVocab::from_file(&p).unwrap();
        assert_eq!(v.n_classes(), 3);
        assert!((v.convergence - 0.99).abs() < 1e-12);
        assert_eq!(v.history_len, 30);
    }

    #[test]
    fn negative_deltas_roundtrip_through_json() {
        let file = VocabFile {
            deltas: vec![-16384, -1, 1, 16384],
            pcs: vec![],
            page_buckets: 4096,
            dominant_delta: -16384,
            convergence: 0.5,
            history_len: 30,
        };
        let back = VocabFile::from_json(&Json::parse(&file.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.deltas, file.deltas);
        assert_eq!(back.dominant_delta, -16384);
    }
}
