//! Online fine-tuning scheduler (paper §7.1: "we fine-tuned this model
//! in each simulation every 50 million instructions to make it become
//! adaptive in different program phases").
//!
//! Labelled windows are harvested for free on the fault path: once the
//! *next* delta of a cluster is observed, the previous full window
//! gains its ground-truth label. A bounded replay buffer keeps the
//! most recent examples; every `interval_insts` retired instructions
//! the scheduler hands a batch to the backend's AOT train-step.

use crate::predictor::{LabelledWindow, Window};

#[derive(Debug)]
pub struct FinetuneScheduler {
    /// Replay buffer (ring, newest wins).
    buffer: Vec<LabelledWindow>,
    capacity: usize,
    write: usize,
    filled: bool,
    interval_insts: u64,
    next_due: u64,
    batch: usize,
    pub rounds: u64,
    pub last_loss: Option<f64>,
}

impl FinetuneScheduler {
    pub fn new(interval_insts: u64, batch: usize, capacity: usize) -> Self {
        assert!(capacity >= batch.max(1));
        Self {
            buffer: Vec::with_capacity(capacity),
            capacity,
            write: 0,
            filled: false,
            interval_insts,
            next_due: interval_insts,
            batch,
            rounds: 0,
            last_loss: None,
        }
    }

    pub fn enabled(&self) -> bool {
        self.interval_insts != 0
    }

    /// Record a labelled example.
    pub fn record(&mut self, window: Window, label: i32) {
        if !self.enabled() {
            return;
        }
        let lw = LabelledWindow { window, label };
        if self.buffer.len() < self.capacity {
            self.buffer.push(lw);
        } else {
            self.buffer[self.write] = lw;
            self.filled = true;
        }
        self.write = (self.write + 1) % self.capacity;
    }

    /// Called with the running instruction counter; returns a training
    /// batch when a round is due and enough examples exist.
    pub fn due(&mut self, instructions: u64) -> Option<Vec<LabelledWindow>> {
        if !self.enabled() || instructions < self.next_due {
            return None;
        }
        self.next_due = instructions + self.interval_insts;
        if self.buffer.len() < self.batch {
            return None;
        }
        self.rounds += 1;
        // Most recent `batch` examples (newest program phase).
        let n = self.buffer.len();
        let start = if self.filled { self.write } else { 0 };
        let batch: Vec<LabelledWindow> = (0..self.batch)
            .map(|i| self.buffer[(start + n - self.batch + i) % n].clone())
            .collect();
        Some(batch)
    }

    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::FeatTok;

    fn w(tag: i32) -> Window {
        Window { tokens: vec![FeatTok { pc_id: tag, page_id: 0, delta_id: 0 }] }
    }

    #[test]
    fn disabled_scheduler_is_inert() {
        let mut s = FinetuneScheduler::new(0, 4, 16);
        s.record(w(1), 0);
        assert_eq!(s.buffered(), 0);
        assert!(s.due(1_000_000).is_none());
    }

    #[test]
    fn fires_on_interval_with_enough_examples() {
        let mut s = FinetuneScheduler::new(100, 2, 8);
        s.record(w(1), 1);
        assert!(s.due(100).is_none(), "only one example buffered");
        s.record(w(2), 2);
        assert!(s.due(150).is_none(), "interval already consumed at 100");
        // Next due at 200.
        let batch = s.due(200).expect("due");
        assert_eq!(batch.len(), 2);
        assert_eq!(s.rounds, 1);
    }

    #[test]
    fn batch_takes_most_recent_examples() {
        let mut s = FinetuneScheduler::new(10, 2, 4);
        for i in 0..6 {
            s.record(w(i), i);
        }
        let batch = s.due(10).unwrap();
        let tags: Vec<i32> = batch.iter().map(|b| b.label).collect();
        assert_eq!(tags, vec![4, 5], "newest two survive the ring");
    }
}
