//! One backend factory for every entry point.
//!
//! `repro simulate/eval` (the `dl` policy), `repro serve`, and the
//! report tooling all used to carry their own copy of the
//! manifest-load / model-key / arch-guard / class-count dance — three
//! slightly different spellings that could (and did) drift. A
//! [`BackendSpec`] is the single resolver: build one from the CLI axes
//! (or a [`RuntimeConfig`]), call [`BackendSpec::resolve`], and get the
//! `(vocab, backend, name)` triple every caller needs. The
//! both-direction arch guards (an in-process loader rejecting a pjrt
//! artifact, the pjrt loader rejecting an in-process artifact) and the
//! precision validity table ([`kernel::ensure_supported`]) live here
//! and nowhere else, and every error names the CLI flag that fixes it.

use crate::config::{PredictorBackendKind, RuntimeConfig};
use crate::predictor::kernel::{self, Precision};
use crate::predictor::{
    ConstantBackend, DeltaVocab, NativeBackend, NativeConfig, PredictorBackend, StrideBackend,
    TransformerBackend, TransformerConfig,
};
use crate::runtime::{Manifest, ModelExecutable, PjrtBackend};
use anyhow::Result;
use std::path::Path;

/// Everything needed to materialize a servable predictor backend.
#[derive(Debug, Clone)]
pub struct BackendSpec {
    /// Which backend (and, for artifact-backed kinds, where its
    /// artifacts live and which model key to resolve).
    pub kind: PredictorBackendKind,
    /// Kernel tier the instance will serve with (`--precision`).
    pub precision: Precision,
    /// Sliding-window length for the artifact-free vocabularies
    /// (stride, constant).
    pub history_len: usize,
    /// Benchmark whose model to resolve for artifact-backed kinds.
    pub benchmark: String,
    /// Log/error prefix naming the entry point ("dl", "serve", …).
    pub who: &'static str,
}

impl BackendSpec {
    /// Spec for a simulator/server runtime config (the `dl` policy and
    /// `repro serve` both carry their axes in a [`RuntimeConfig`]).
    pub fn from_runtime(rcfg: &RuntimeConfig, benchmark: &str, who: &'static str) -> Self {
        Self {
            kind: rcfg.backend.clone(),
            precision: rcfg.precision,
            history_len: rcfg.history_len,
            benchmark: benchmark.to_string(),
            who,
        }
    }

    /// Arch tag of the configured kind — the string
    /// [`kernel::ensure_supported`] and the report tables key on.
    pub fn arch(&self) -> &'static str {
        match &self.kind {
            PredictorBackendKind::Stride => "stride",
            PredictorBackendKind::Constant(_) => "constant",
            PredictorBackendKind::Native { .. } => "native",
            PredictorBackendKind::Transformer { .. } => "transformer",
            PredictorBackendKind::Pjrt { .. } => "pjrt",
        }
    }

    /// Materialize the backend: validate the (arch, precision) pair,
    /// load artifacts where the kind needs them (guarding the arch in
    /// both directions), and return `(vocab, backend, name)`.
    pub fn resolve(&self) -> Result<(DeltaVocab, Box<dyn PredictorBackend>, &'static str)> {
        kernel::ensure_supported(self.arch(), self.precision)?;
        Ok(match &self.kind {
            PredictorBackendKind::Stride => {
                // The shared artifact-free vocab + vote backend (the
                // stride backend only votes over observed ids).
                let (vocab, backend) = StrideBackend::with_default_vocab(self.history_len);
                (vocab, Box::new(backend), "stride")
            }
            PredictorBackendKind::Constant(d) => {
                let vocab = DeltaVocab::synthetic(vec![*d], self.history_len);
                (vocab, Box::new(ConstantBackend { class: 0, n_classes: 2 }), "constant")
            }
            PredictorBackendKind::Native { artifacts, model } => {
                let (vocab, backend) = load_model_backend(
                    artifacts,
                    model,
                    &self.benchmark,
                    "native",
                    self.precision,
                    self.who,
                )?;
                (vocab, backend, "native")
            }
            PredictorBackendKind::Transformer { artifacts, model } => {
                let (vocab, backend) = load_model_backend(
                    artifacts,
                    model,
                    &self.benchmark,
                    "transformer",
                    self.precision,
                    self.who,
                )?;
                (vocab, backend, "transformer")
            }
            PredictorBackendKind::Pjrt { artifacts, model } => {
                let dir = Path::new(artifacts);
                let manifest = Manifest::load(dir)?;
                let (key, entry) = manifest.resolve(model, &self.benchmark)?;
                if entry.arch == "native" || entry.arch == "transformer" {
                    anyhow::bail!(
                        "{}: model '{key}' is an in-process artifact (arch={}) — run with \
                         --backend {} instead of pjrt",
                        self.who,
                        entry.arch,
                        entry.arch
                    );
                }
                let vocab = DeltaVocab::from_file(&dir.join(&entry.vocab))?;
                let exe = ModelExecutable::load(dir, entry)?;
                eprintln!(
                    "{}: loaded model '{key}' (arch={}, batch={}, classes={})",
                    self.who, entry.arch, entry.batch, entry.n_classes
                );
                (vocab, Box::new(PjrtBackend::new(exe, entry.arch.clone())), "pjrt")
            }
        })
    }
}

/// Load an in-process learned backend (`arch` = "native" |
/// "transformer") from an artifacts manifest: resolve the model key,
/// guard the arch both directions, load the weights at the requested
/// kernel tier, and validate the class count against the vocabulary.
/// Quantized tiers prefer the `<model>.int4.params.bin` sibling store
/// (written by `repro train` alongside the f32 weights) and fall back
/// to the main store, whose loader rejects f32-only tensors with an
/// error naming `--precision`. `who` prefixes the log/error lines
/// ("dl", "serve").
pub fn load_model_backend(
    artifacts: &str,
    model: &str,
    benchmark: &str,
    arch: &str,
    precision: Precision,
    who: &str,
) -> Result<(DeltaVocab, Box<dyn PredictorBackend>)> {
    kernel::ensure_supported(arch, precision)?;
    let dir = Path::new(artifacts);
    let manifest = Manifest::load(dir).map_err(|e| {
        anyhow::anyhow!(
            "{who} --backend {arch}: {e}; train a model first \
             (`repro train --arch {arch} --workload …`)"
        )
    })?;
    let (key, entry) = manifest.resolve(model, benchmark)?;
    if entry.arch != arch {
        anyhow::bail!(
            "model '{key}' has arch '{}' — not a {arch} model; use --backend {} for these \
             artifacts",
            entry.arch,
            match entry.arch.as_str() {
                "native" | "transformer" => entry.arch.as_str(),
                _ => "pjrt",
            }
        );
    }
    let vocab = DeltaVocab::from_file(&dir.join(&entry.vocab))?;
    let params = quantized_sibling(dir, &entry.params, precision);
    let backend: Box<dyn PredictorBackend> = match arch {
        "native" => {
            let m = if precision.is_quantized() {
                NativeBackend::load_with_precision(&params, &NativeConfig::default(), precision)?
            } else {
                let mut m = NativeBackend::load(&params, &NativeConfig::default())?;
                m.set_precision(precision)?;
                m
            };
            eprintln!(
                "{who}: loaded native model '{key}' ({} params, seq={}, classes={}, \
                 precision={})",
                m.n_params(),
                m.seq_len(),
                m.n_classes(),
                precision.as_str()
            );
            Box::new(m)
        }
        "transformer" => {
            let mut m = TransformerBackend::load(&params, &TransformerConfig::default())?;
            m.set_precision(precision)?;
            eprintln!(
                "{who}: loaded transformer model '{key}' ({} params, seq={}, {} layer(s) × {} \
                 head(s), classes={}, precision={})",
                m.n_params(),
                m.seq_len(),
                m.n_layers(),
                m.n_heads(),
                m.n_classes(),
                precision.as_str()
            );
            Box::new(m)
        }
        other => anyhow::bail!("load_model_backend: unsupported arch '{other}'"),
    };
    anyhow::ensure!(
        backend.n_classes() == vocab.n_classes(),
        "model '{key}': params have {} classes but the vocab has {}",
        backend.n_classes(),
        vocab.n_classes()
    );
    Ok((vocab, backend))
}

/// Resolve the params path for a tier: quantized tiers prefer the
/// dtype-3 sibling store next to the f32 one when it exists.
fn quantized_sibling(dir: &Path, params: &str, precision: Precision) -> std::path::PathBuf {
    if precision.is_quantized() {
        if let Some(stem) = params.strip_suffix(".params.bin") {
            let sibling = dir.join(format!("{stem}.int4.params.bin"));
            if sibling.exists() {
                return sibling;
            }
        }
    }
    dir.join(params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TestDir;

    fn spec(kind: PredictorBackendKind, precision: Precision) -> BackendSpec {
        BackendSpec {
            kind,
            precision,
            history_len: 8,
            benchmark: "addvectors".to_string(),
            who: "test",
        }
    }

    #[test]
    fn stride_and_constant_resolve_without_artifacts() {
        let (vocab, backend, name) =
            spec(PredictorBackendKind::Stride, Precision::Exact).resolve().unwrap();
        assert_eq!(name, "stride");
        assert_eq!(backend.n_classes(), vocab.n_classes());

        let (vocab, backend, name) =
            spec(PredictorBackendKind::Constant(3), Precision::Exact).resolve().unwrap();
        assert_eq!(name, "constant");
        assert_eq!(backend.n_classes(), 2);
        assert_eq!(vocab.n_classes(), 2);
    }

    #[test]
    fn precision_table_guards_before_artifact_load() {
        // pjrt rejects every non-exact tier by name, before touching
        // the (absent) artifacts directory.
        let kind = PredictorBackendKind::Pjrt {
            artifacts: "/nonexistent".to_string(),
            model: String::new(),
        };
        let err = spec(kind, Precision::Fast).resolve().unwrap_err().to_string();
        assert!(err.contains("--precision fast"), "{err}");
        assert!(err.contains("pjrt"), "{err}");

        // transformer serves exact|fast only.
        let kind = PredictorBackendKind::Transformer {
            artifacts: "/nonexistent".to_string(),
            model: String::new(),
        };
        let err = spec(kind, Precision::Int8).resolve().unwrap_err().to_string();
        assert!(err.contains("--precision int8"), "{err}");
        assert!(err.contains("--backend native"), "{err}");
    }

    #[test]
    fn missing_artifacts_name_the_training_command() {
        let dir = TestDir::new();
        let kind = PredictorBackendKind::Native {
            artifacts: dir.path().to_string_lossy().into_owned(),
            model: String::new(),
        };
        let err = spec(kind, Precision::Exact).resolve().unwrap_err().to_string();
        assert!(err.contains("repro train --arch native"), "{err}");
    }

    #[test]
    fn quantized_sibling_prefers_int4_store_when_present() {
        let dir = TestDir::new();
        let main = "m.native.params.bin";
        std::fs::write(dir.path().join("m.native.int4.params.bin"), b"x").unwrap();
        let p = quantized_sibling(dir.path(), main, Precision::Int4);
        assert!(p.to_string_lossy().ends_with("m.native.int4.params.bin"));
        // Exact/fast tiers keep the f32 store even when the sibling
        // exists (bit-pinned path must not silently requantize).
        let p = quantized_sibling(dir.path(), main, Precision::Exact);
        assert!(p.to_string_lossy().ends_with("m.native.params.bin"));
        // No sibling → fall back to the main store (whose loader
        // rejects f32-only tensors with a named-flag error).
        std::fs::remove_file(dir.path().join("m.native.int4.params.bin")).unwrap();
        let p = quantized_sibling(dir.path(), main, Precision::Int8);
        assert!(p.to_string_lossy().ends_with("m.native.params.bin"));
    }
}
