//! Predictor engine: glue between raw history windows and a
//! [`PredictorBackend`] — featurization, batch prediction, and the
//! delta-vocabulary decode back to concrete pages.

use crate::predictor::history::HistoryToken;
use crate::predictor::{
    ClassId, DeltaVocab, LabelledWindow, Prediction, PredictorBackend, Window,
};

/// Featurize a raw token window using the vocabulary.
pub fn featurize_window(vocab: &DeltaVocab, tokens: &[HistoryToken]) -> Window {
    Window { tokens: tokens.iter().map(|t| vocab.featurize(t)).collect() }
}

/// Engine = backend + vocab.
///
/// ```
/// use uvm_prefetch::predictor::{
///     DeltaVocab, FeatTok, Prediction, PredictorEngine, StrideBackend, Window,
/// };
///
/// let vocab = DeltaVocab::synthetic(vec![2], 4);
/// let backend = StrideBackend::new(vocab.n_classes(), 4);
/// let mut engine = PredictorEngine::new(Box::new(backend), vocab);
/// // Four tokens whose delta id 0 maps back to delta +2.
/// let w = Window { tokens: vec![FeatTok { pc_id: 0, page_id: 0, delta_id: 0 }; 4] };
/// assert_eq!(engine.predict(&[w]), vec![Prediction::Delta(2)]);
/// assert_eq!(engine.backend_name(), "stride-backend");
/// ```
pub struct PredictorEngine {
    backend: Box<dyn PredictorBackend>,
    pub vocab: DeltaVocab,
}

impl PredictorEngine {
    pub fn new(backend: Box<dyn PredictorBackend>, vocab: DeltaVocab) -> Self {
        Self { backend, vocab }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Predict the next delta for each window.
    pub fn predict(&mut self, windows: &[Window]) -> Vec<Prediction> {
        if windows.is_empty() {
            return Vec::new();
        }
        let classes = self.backend.predict(windows);
        debug_assert_eq!(classes.len(), windows.len());
        classes.into_iter().map(|c| self.vocab.decode(c)).collect()
    }

    /// One online fine-tune round; returns loss when supported.
    pub fn finetune(&mut self, batch: &[LabelledWindow]) -> Option<f64> {
        self.backend.finetune(batch)
    }
}

/// Pure-Rust fallback backend: majority vote over the window's recent
/// delta ids (a frequency predictor — degenerates to the stride
/// predictor on regular streams). Lets the full DL pipeline run
/// without artifacts; tests and CI use it.
#[derive(Debug)]
pub struct StrideBackend {
    n_classes: usize,
    /// Vote over the last `lookback` tokens of the window.
    lookback: usize,
}

impl StrideBackend {
    pub fn new(n_classes: usize, lookback: usize) -> Self {
        assert!(lookback > 0);
        Self { n_classes, lookback }
    }

    /// The default artifact-free serving pair: a synthetic vocabulary
    /// covering small strides (±1..±8) plus common row strides, and a
    /// stride backend voting over it. The single source of truth for
    /// `--backend stride` — the eval runner and `repro serve` must
    /// measure the same vocabulary.
    pub fn with_default_vocab(history_len: usize) -> (DeltaVocab, StrideBackend) {
        let deltas: Vec<i64> =
            (-8i64..=8).filter(|&d| d != 0).chain([16, 32, 64, 128, 256, 512, 1024]).collect();
        let vocab = DeltaVocab::synthetic(deltas, history_len);
        let backend = StrideBackend::new(vocab.n_classes(), history_len);
        (vocab, backend)
    }
}

impl PredictorBackend for StrideBackend {
    fn name(&self) -> &'static str {
        "stride-backend"
    }

    fn predict(&mut self, windows: &[Window]) -> Vec<ClassId> {
        windows
            .iter()
            .map(|w| {
                let tail = &w.tokens[w.tokens.len().saturating_sub(self.lookback)..];
                // Majority delta id; ties broken toward the most
                // recent occurrence.
                let mut best: Option<(i32, usize)> = None;
                for (i, t) in tail.iter().enumerate() {
                    let count = tail.iter().filter(|u| u.delta_id == t.delta_id).count();
                    match best {
                        Some((_, bc)) if bc > count => {}
                        Some((bd, bc)) if bc == count && bd == t.delta_id => {}
                        _ => best = Some((t.delta_id, count)),
                        // Later equal counts overwrite → recency bias.
                    }
                    let _ = i;
                }
                best.map(|(d, _)| d as ClassId).unwrap_or(0)
            })
            .collect()
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::FeatTok;

    fn window(delta_ids: &[i32]) -> Window {
        Window {
            tokens: delta_ids
                .iter()
                .map(|&d| FeatTok { pc_id: 0, page_id: 0, delta_id: d })
                .collect(),
        }
    }

    #[test]
    fn stride_backend_majority_vote() {
        let mut b = StrideBackend::new(8, 8);
        let out = b.predict(&[window(&[1, 1, 1, 2]), window(&[3, 3, 2, 2, 2])]);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn engine_decodes_through_vocab() {
        let vocab = DeltaVocab::synthetic(vec![5, -1], 4);
        let mut engine =
            PredictorEngine::new(Box::new(StrideBackend::new(vocab.n_classes(), 4)), vocab);
        let preds = engine.predict(&[window(&[1, 1, 0, 1])]);
        assert_eq!(preds, vec![Prediction::Delta(-1)]);
        // Class 2 = OOV in a 2-delta vocab.
        let preds = engine.predict(&[window(&[2, 2, 2, 2])]);
        assert_eq!(preds, vec![Prediction::Oov]);
    }

    #[test]
    fn featurize_window_maps_all_tokens() {
        let vocab = DeltaVocab::synthetic(vec![1], 2);
        let toks = vec![
            HistoryToken { pc: 0xdead, page: 5, delta: 1 },
            HistoryToken { pc: 0xbeef, page: 6, delta: 99 },
        ];
        let w = featurize_window(&vocab, &toks);
        assert_eq!(w.tokens.len(), 2);
        assert_eq!(w.tokens[0].delta_id, 0);
        assert_eq!(w.tokens[1].delta_id, 1, "unseen delta → OOV id");
    }

    #[test]
    fn empty_predict_is_empty() {
        let vocab = DeltaVocab::synthetic(vec![1], 4);
        let mut engine =
            PredictorEngine::new(Box::new(StrideBackend::new(2, 4)), vocab);
        assert!(engine.predict(&[]).is_empty());
    }
}
