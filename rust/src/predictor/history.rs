//! Per-cluster sliding history windows and delta-convergence tracking.
//!
//! Each cluster keeps the last `history_len` (PC, page, Δ) tokens.
//! The paper's *delta convergence* — "the ratio of the largest number
//! of address delta to the total size of the output vocabulary"
//! (§5.4, Fig. 6) — is tracked online per cluster and drives the
//! bypass indicator (§6 item 5).

use crate::types::{Cycle, PageDelta, PageNum};
use std::collections::{HashMap, VecDeque};

/// Raw history token before featurization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistoryToken {
    pub pc: u64,
    pub page: PageNum,
    pub delta: PageDelta,
}

/// State of one cluster's stream.
#[derive(Debug)]
pub struct ClusterHistory {
    /// Ring of the last `capacity` tokens. VecDeque: the push path
    /// runs once per GMMU access — `Vec::remove(0)` was the hottest
    /// line of the coordinator benches (see DESIGN.md §7 Perf).
    window: VecDeque<HistoryToken>,
    capacity: usize,
    last_page: Option<PageNum>,
    /// delta → occurrences (convergence statistics).
    delta_counts: HashMap<PageDelta, u64>,
    total_deltas: u64,
    pub last_update: Cycle,
}

impl ClusterHistory {
    pub fn new(capacity: usize) -> Self {
        Self {
            window: VecDeque::with_capacity(capacity + 1),
            capacity,
            last_page: None,
            delta_counts: HashMap::new(),
            total_deltas: 0,
            last_update: 0,
        }
    }

    /// Record an access; returns the token pushed (None for the very
    /// first access of the cluster — no delta exists yet).
    pub fn push(&mut self, pc: u64, page: PageNum, now: Cycle) -> Option<HistoryToken> {
        self.last_update = now;
        let last = self.last_page.replace(page);
        let delta = page as i64 - last? as i64;
        let tok = HistoryToken { pc, page, delta };
        if self.window.len() == self.capacity {
            self.window.pop_front();
        }
        self.window.push_back(tok);
        *self.delta_counts.entry(delta).or_insert(0) += 1;
        self.total_deltas += 1;
        Some(tok)
    }

    /// Full window if the cluster has accumulated enough history.
    /// The deque is kept contiguous (pop+push never wraps a deque
    /// whose spare capacity ≥ 1), so this is O(1) in steady state.
    pub fn full_window(&mut self) -> Option<&[HistoryToken]> {
        if self.window.len() != self.capacity {
            return None;
        }
        Some(self.window.make_contiguous())
    }

    /// Most frequent delta and its convergence ratio.
    pub fn dominant_delta(&self) -> Option<(PageDelta, f64)> {
        let (&delta, &count) = self.delta_counts.iter().max_by_key(|&(d, c)| (*c, *d))?;
        Some((delta, count as f64 / self.total_deltas as f64))
    }

    pub fn len(&self) -> usize {
        self.window.len()
    }

    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }
}

/// All clusters' histories.
#[derive(Debug)]
pub struct HistoryTable<K: std::hash::Hash + Eq + Copy> {
    clusters: HashMap<K, ClusterHistory>,
    capacity: usize,
}

impl<K: std::hash::Hash + Eq + Copy> HistoryTable<K> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self { clusters: HashMap::new(), capacity }
    }

    pub fn push(&mut self, key: K, pc: u64, page: PageNum, now: Cycle) -> Option<HistoryToken> {
        self.clusters.entry(key).or_insert_with(|| ClusterHistory::new(self.capacity)).push(
            pc, page, now,
        )
    }

    pub fn get(&self, key: &K) -> Option<&ClusterHistory> {
        self.clusters.get(key)
    }

    pub fn get_mut(&mut self, key: &K) -> Option<&mut ClusterHistory> {
        self.clusters.get_mut(key)
    }

    pub fn n_clusters(&self) -> usize {
        self.clusters.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_access_has_no_delta() {
        let mut h = ClusterHistory::new(4);
        assert!(h.push(0x10, 100, 0).is_none());
        let tok = h.push(0x10, 102, 1).unwrap();
        assert_eq!(tok.delta, 2);
    }

    #[test]
    fn window_slides_at_capacity() {
        let mut h = ClusterHistory::new(3);
        for (i, p) in [10u64, 11, 12, 13, 20].iter().enumerate() {
            h.push(0, *p, i as u64);
        }
        let w = h.full_window().expect("full");
        assert_eq!(w.len(), 3);
        assert_eq!(w[2].delta, 7, "newest token is the 13→20 jump");
        assert_eq!(w[0].delta, 1);
    }

    #[test]
    fn convergence_tracks_dominant_delta() {
        let mut h = ClusterHistory::new(8);
        h.push(0, 0, 0);
        for i in 1..=9u64 {
            h.push(0, i, i); // delta 1 × 9
        }
        h.push(0, 100, 10); // delta 91 × 1
        let (d, conv) = h.dominant_delta().unwrap();
        assert_eq!(d, 1);
        assert!((conv - 0.9).abs() < 1e-9, "conv = {conv}");
    }

    #[test]
    fn table_isolates_clusters() {
        let mut t: HistoryTable<u32> = HistoryTable::new(2);
        t.push(1, 0, 10, 0);
        t.push(2, 0, 99, 0);
        t.push(1, 0, 11, 1);
        assert_eq!(t.n_clusters(), 2);
        assert_eq!(t.get(&1).unwrap().len(), 1, "one delta in cluster 1");
        assert!(t.get(&2).unwrap().is_empty(), "cluster 2 still has no delta");
    }
}
