//! The learned predictor's deployment path (Layer 3 side).
//!
//! This is the paper's §6 "revised predictor" as it would ship inside
//! a UVM runtime: far-fault streams are **clustered** by (SM id, warp
//! id), each cluster keeps a **sliding window** of the last 30
//! (PC, page, Δpage) tokens, ready windows are **dynamically batched**
//! and pushed through the AOT-compiled model (PJRT), and the top-1
//! class is mapped back through the **delta vocabulary** to a concrete
//! prefetch candidate. A **bypass indicator** short-circuits clusters
//! whose delta distribution has converged (paper §5.3/§6 item 5), and
//! an **online fine-tune** scheduler periodically replays labelled
//! windows through the AOT train-step (paper §7.1, every 50 M
//! instructions).

pub mod batcher;
pub mod cluster;
pub mod engine;
pub mod factory;
pub mod finetune;
pub mod history;
pub mod kernel;
pub mod native;
pub mod nn;
pub mod quant;
pub mod transformer;
pub mod vocab;

pub use cluster::{ClusterBy, ClusterKey};
pub use engine::{PredictorEngine, StrideBackend};
pub use factory::BackendSpec;
pub use history::HistoryToken;
pub use kernel::Precision;
pub use native::{NativeBackend, NativeConfig};
pub use transformer::{TransformerBackend, TransformerConfig};
pub use vocab::DeltaVocab;

use crate::types::PageDelta;

/// One featurized token as fed to the model: ids into the embedding
/// tables built at training time (see `python/compile/data.py` —
/// `FEAT_PC`, `FEAT_PAGE`, `FEAT_DELTA`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FeatTok {
    pub pc_id: i32,
    pub page_id: i32,
    pub delta_id: i32,
}

/// A model-ready window of `history_len` featurized tokens.
#[derive(Debug, Clone)]
pub struct Window {
    pub tokens: Vec<FeatTok>,
}

/// A labelled window for online fine-tuning.
#[derive(Debug, Clone)]
pub struct LabelledWindow {
    pub window: Window,
    /// Class id of the observed next delta.
    pub label: i32,
}

/// What a backend returns per window: a class id over the delta
/// vocabulary (the vocabulary's last class is OOV).
pub type ClassId = u32;

/// Inference/learning backend. Implementations: [`StrideBackend`]
/// (pure-Rust frequency vote, the floor), [`NativeBackend`] (pure-Rust
/// revised model with real training — the `--backend native` path),
/// [`TransformerBackend`] (the pure-Rust Transformer reference model —
/// `--backend transformer`), `ConstantBackend` (tests), and
/// [`crate::runtime::PjrtBackend`] (the AOT-compiled model,
/// `--backend pjrt`).
pub trait PredictorBackend: Send {
    fn name(&self) -> &'static str;

    /// Top-1 class per window. Must return exactly
    /// `windows.len()` entries.
    fn predict(&mut self, windows: &[Window]) -> Vec<ClassId>;

    /// One online fine-tune step over labelled windows; returns the
    /// training loss if the backend supports learning.
    fn finetune(&mut self, _batch: &[LabelledWindow]) -> Option<f64> {
        None
    }

    /// Number of delta classes (incl. OOV) this backend emits.
    fn n_classes(&self) -> usize;

    /// Introspection for report tables (`repro train` / `repro
    /// analyze`) — replaces per-arch downcasting. The default covers
    /// parameterless backends (stride, constant, the pjrt stub).
    fn info(&self) -> BackendInfo {
        BackendInfo {
            arch: self.name(),
            n_params: 0,
            flops_per_inference: 0,
            precision: Precision::Exact,
        }
    }
}

/// What [`PredictorBackend::info`] answers: enough for the train /
/// analyze report tables and the serving logs, uniformly across
/// arches.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BackendInfo {
    pub arch: &'static str,
    pub n_params: usize,
    pub flops_per_inference: u64,
    /// Kernel tier this instance serves with (see
    /// [`kernel::Precision`]).
    pub precision: Precision,
}

/// Always predicts the same class — test + ablation backend.
#[derive(Debug)]
pub struct ConstantBackend {
    pub class: ClassId,
    pub n_classes: usize,
}

impl PredictorBackend for ConstantBackend {
    fn name(&self) -> &'static str {
        "constant"
    }

    fn predict(&mut self, windows: &[Window]) -> Vec<ClassId> {
        vec![self.class; windows.len()]
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }
}

/// A concrete prediction after vocab mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Prediction {
    Delta(PageDelta),
    /// Model answered with the out-of-vocabulary class: suppress the
    /// extra prefetch (fall back to basic-block-only, the paper's
    /// floor behaviour).
    Oov,
}
