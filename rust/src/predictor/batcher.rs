//! Dynamic batching of inference requests.
//!
//! Far-faults arrive one at a time; the PJRT executable is compiled
//! for a fixed batch shape. The batcher accumulates ready windows and
//! flushes when (a) the batch is full, or (b) the oldest pending
//! request exceeds `flush_cycles` of age — bounding the timeliness
//! penalty that §5.2 warns about. Partial batches are padded by the
//! backend.

use crate::predictor::Window;
use crate::types::{Cycle, PageNum};

/// A queued inference request: the window plus everything needed to
/// turn the answer into a prefetch.
#[derive(Debug, Clone)]
pub struct PendingRequest {
    pub window: Window,
    /// Faulting page the predicted delta is applied to.
    pub anchor_page: PageNum,
    pub enqueued_at: Cycle,
    /// Requesting cluster (raw [`crate::predictor::ClusterKey`] bits)
    /// and faulting PC — carried through the batch so the telemetry
    /// post-mortem can attribute each answer back to the access stream
    /// that asked (0 when the caller does not track attribution).
    pub cluster: u64,
    pub pc: u64,
}

#[derive(Debug)]
pub struct Batcher {
    pending: Vec<PendingRequest>,
    batch_size: usize,
    flush_cycles: Cycle,
    pub batches_flushed: u64,
    pub requests_seen: u64,
}

impl Batcher {
    pub fn new(batch_size: usize, flush_cycles: Cycle) -> Self {
        assert!(batch_size > 0);
        Self {
            pending: Vec::with_capacity(batch_size),
            batch_size,
            flush_cycles,
            batches_flushed: 0,
            requests_seen: 0,
        }
    }

    /// Enqueue a request; returns a full batch if this push filled it.
    pub fn push(&mut self, req: PendingRequest) -> Option<Vec<PendingRequest>> {
        self.requests_seen += 1;
        self.pending.push(req);
        (self.pending.len() >= self.batch_size).then(|| self.take())
    }

    /// Flush a partial batch whose oldest entry has aged out.
    pub fn poll(&mut self, now: Cycle) -> Option<Vec<PendingRequest>> {
        let oldest = self.pending.first()?.enqueued_at;
        (now.saturating_sub(oldest) >= self.flush_cycles).then(|| self.take())
    }

    /// Unconditional flush (end of run).
    pub fn flush(&mut self) -> Option<Vec<PendingRequest>> {
        (!self.pending.is_empty()).then(|| self.take())
    }

    fn take(&mut self) -> Vec<PendingRequest> {
        self.batches_flushed += 1;
        std::mem::take(&mut self.pending)
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::FeatTok;

    fn req(at: Cycle) -> PendingRequest {
        PendingRequest {
            window: Window { tokens: vec![FeatTok { pc_id: 0, page_id: 0, delta_id: 0 }] },
            anchor_page: 7,
            enqueued_at: at,
            cluster: 0,
            pc: 0,
        }
    }

    #[test]
    fn flushes_when_full() {
        let mut b = Batcher::new(2, 1000);
        assert!(b.push(req(0)).is_none());
        let batch = b.push(req(1)).expect("full");
        assert_eq!(batch.len(), 2);
        assert!(b.is_empty());
        assert_eq!(b.batches_flushed, 1);
    }

    #[test]
    fn poll_flushes_aged_partials() {
        let mut b = Batcher::new(8, 100);
        b.push(req(50));
        assert!(b.poll(100).is_none(), "49 cycles old: keep waiting");
        let batch = b.poll(151).expect("aged out");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn poll_on_empty_is_none() {
        let mut b = Batcher::new(4, 10);
        assert!(b.poll(1_000_000).is_none());
    }

    #[test]
    fn explicit_flush_drains() {
        let mut b = Batcher::new(4, 10);
        b.push(req(0));
        b.push(req(1));
        assert_eq!(b.flush().unwrap().len(), 2);
        assert!(b.flush().is_none());
    }
}
