//! Trace clustering (paper §5.1, Table 2).
//!
//! The paper evaluates clustering the fault stream by PC, kernel id,
//! SM id, CTA id and warp id, finds SM id best (concurrent warps mix
//! at the GMMU and destroy PC-order information), and the revised
//! predictor (§6 item 1) uses **SM id + warp id**. All variants are
//! implemented so the Table 2 experiment can be regenerated from the
//! same machinery the runtime uses.

use crate::types::AccessOrigin;

/// Which feature(s) partition the fault stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterBy {
    Pc,
    KernelId,
    Sm,
    Cta,
    Warp,
    /// The revised predictor's choice (paper §6 item 1).
    SmWarp,
}

/// Opaque cluster key (hashable, cheap to copy).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClusterKey(pub u64);

impl ClusterBy {
    /// Compute the cluster key for an access.
    pub fn key(self, origin: &AccessOrigin, pc: u64) -> ClusterKey {
        let k = match self {
            ClusterBy::Pc => pc,
            ClusterBy::KernelId => origin.kernel_id as u64,
            ClusterBy::Sm => origin.sm as u64,
            ClusterBy::Cta => origin.cta as u64,
            ClusterBy::Warp => origin.warp as u64,
            // Disjoint ranges: sm in high bits, warp in low bits.
            ClusterBy::SmWarp => ((origin.sm as u64) << 32) | origin.warp as u64,
        };
        ClusterKey(k)
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "pc" => ClusterBy::Pc,
            "kernel_id" | "kernel" => ClusterBy::KernelId,
            "sm" => ClusterBy::Sm,
            "cta" => ClusterBy::Cta,
            "warp" => ClusterBy::Warp,
            "sm_warp" | "smwarp" => ClusterBy::SmWarp,
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn origin(sm: u16, warp: u16, cta: u32) -> AccessOrigin {
        AccessOrigin { sm, warp, cta, tpc: sm / 2, kernel_id: 3 }
    }

    #[test]
    fn sm_warp_keys_are_disjoint() {
        let a = ClusterBy::SmWarp.key(&origin(1, 2, 0), 0);
        let b = ClusterBy::SmWarp.key(&origin(2, 1, 0), 0);
        let c = ClusterBy::SmWarp.key(&origin(1, 2, 9), 0);
        assert_ne!(a, b);
        assert_eq!(a, c, "cta does not affect sm_warp key");
    }

    #[test]
    fn each_mode_uses_its_feature() {
        let o = origin(5, 7, 11);
        assert_eq!(ClusterBy::Pc.key(&o, 0x40).0, 0x40);
        assert_eq!(ClusterBy::KernelId.key(&o, 0).0, 3);
        assert_eq!(ClusterBy::Sm.key(&o, 0).0, 5);
        assert_eq!(ClusterBy::Cta.key(&o, 0).0, 11);
        assert_eq!(ClusterBy::Warp.key(&o, 0).0, 7);
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["pc", "kernel_id", "sm", "cta", "warp", "sm_warp"] {
            assert!(ClusterBy::parse(s).is_some(), "{s}");
        }
        assert!(ClusterBy::parse("bogus").is_none());
    }
}
