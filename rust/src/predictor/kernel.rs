//! Precision-tiered GEMM/GEMV kernels for the in-process backends.
//!
//! The repo pins two hard invariants on the model plane: same-seed
//! training is byte-deterministic, and batched inference is
//! bit-identical to sequential inference. Both hinge on the scalar
//! fixed-order loops in [`crate::predictor::nn`] — so that path is
//! kept verbatim here as the **exact** tier (the bit-pinned oracle)
//! and everything faster is opt-in per run via `--precision`:
//!
//! * **exact** — delegates to `nn::linear_forward_batch` unchanged.
//!   The default everywhere determinism is pinned (golden gate,
//!   training, grad checks).
//! * **fast** — a register-blocked f32 microkernel: two output rows
//!   retire per pass over the activation vector, each row carrying
//!   eight independent partial sums. Reassociating the reduction
//!   breaks the sequential FP dependency chain the exact loop imposes,
//!   which is what lets LLVM vectorize it on stable Rust. A
//!   `std::simd` variant of the same microkernel sits behind the
//!   off-by-default `simd` cargo feature (nightly only); results stay
//!   row-local either way, so batched == sequential still holds
//!   bitwise *within* the fast tier.
//! * **int8 / int4** — integer-accumulate inference directly on the
//!   dtype-3 scaled-int4 tensor store ([`crate::predictor::quant`]),
//!   without materializing f32 weights: per-tensor power-of-two weight
//!   scale, per-row dynamic absmax activation quantization to i8, i32
//!   accumulation, one f32 rescale per output. The int8 tier expands
//!   the 4-bit codes to one signed byte each at load (trades 2x
//!   footprint for a branch-free inner loop); the int4 tier reads the
//!   packed nibbles in place. Both tiers see the *same* codes, so
//!   their outputs are identical — int4 is the storage-true path,
//!   int8 the speed-true one.
//!
//! Fast and quantized tiers are inference-only; the factory and the
//! CLI reject them on training paths (`repro train`, grad checks).

use crate::predictor::nn;
use anyhow::{bail, ensure, Result};

/// The `--precision` axis: which kernel tier answers inference.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Scalar fixed-order oracle — bit-pinned, the only tier allowed
    /// on training paths.
    #[default]
    Exact,
    /// Register-blocked/vectorized f32 kernels (inference only).
    Fast,
    /// Integer-accumulate on dtype-3 codes, pre-expanded to i8.
    Int8,
    /// Integer-accumulate on dtype-3 codes, packed nibbles in place.
    Int4,
}

impl Precision {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "exact" => Some(Self::Exact),
            "fast" => Some(Self::Fast),
            "int8" => Some(Self::Int8),
            "int4" => Some(Self::Int4),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Exact => "exact",
            Self::Fast => "fast",
            Self::Int8 => "int8",
            Self::Int4 => "int4",
        }
    }

    pub fn is_exact(&self) -> bool {
        *self == Self::Exact
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, Self::Int8 | Self::Int4)
    }
}

/// Batched dense layer dispatch: `out[i] = W · xs[i] + b` for each of
/// the `xs.len() / in_dim` row-major activation rows. The exact tier
/// is byte-for-byte `nn::linear_forward_batch`; every other tier runs
/// the fast f32 microkernel (quantized models route their integer
/// layers through [`QuantizedLinear`] instead and only fall through
/// here for layers that stayed f32).
pub fn linear_forward_batch(
    precision: Precision,
    w: &[f32],
    b: &[f32],
    xs: &[f32],
    out: &mut [f32],
    in_dim: usize,
    out_dim: usize,
) {
    if precision.is_exact() {
        nn::linear_forward_batch(w, b, xs, out, in_dim, out_dim);
        return;
    }
    debug_assert_eq!(w.len(), in_dim * out_dim);
    debug_assert_eq!(b.len(), out_dim);
    debug_assert_eq!(xs.len() % in_dim.max(1), 0);
    debug_assert_eq!(out.len() % out_dim.max(1), 0);
    for (x, o) in xs.chunks_exact(in_dim).zip(out.chunks_exact_mut(out_dim)) {
        linear_row_fast(w, b, x, o, in_dim);
    }
}

/// One activation row through the fast microkernel.
fn linear_row_fast(w: &[f32], b: &[f32], x: &[f32], o: &mut [f32], in_dim: usize) {
    let out_dim = o.len();
    let mut r = 0;
    // 2×8 register block: two weight rows share one streamed pass
    // over `x`, so the activation row is loaded once per pair.
    while r + 2 <= out_dim {
        let row0 = &w[r * in_dim..(r + 1) * in_dim];
        let row1 = &w[(r + 1) * in_dim..(r + 2) * in_dim];
        let (s0, s1) = dot2_fast(row0, row1, x);
        o[r] = s0 + b[r];
        o[r + 1] = s1 + b[r + 1];
        r += 2;
    }
    if r < out_dim {
        let row = &w[r * in_dim..(r + 1) * in_dim];
        o[r] = dot_fast(row, x) + b[r];
    }
}

const LANES: usize = 8;

/// Reassociated dot product: eight independent partial sums over the
/// 8-wide chunks, scalar tail. Breaking the FP dependency chain is
/// what unlocks auto-vectorization; it also means results differ from
/// the exact tier at the last-ulp level (the tolerance tests state
/// the bound).
#[cfg(not(feature = "simd"))]
#[inline]
pub fn dot_fast(row: &[f32], x: &[f32]) -> f32 {
    debug_assert_eq!(row.len(), x.len());
    let rc = row.chunks_exact(LANES);
    let xc = x.chunks_exact(LANES);
    let (rt, xt) = (rc.remainder(), xc.remainder());
    let mut acc = [0.0f32; LANES];
    for (rk, xk) in rc.zip(xc) {
        for l in 0..LANES {
            acc[l] += rk[l] * xk[l];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for (ri, xi) in rt.iter().zip(xt) {
        s += ri * xi;
    }
    s
}

/// `std::simd` variant of [`dot_fast`] (nightly, `--features simd`).
#[cfg(feature = "simd")]
#[inline]
pub fn dot_fast(row: &[f32], x: &[f32]) -> f32 {
    use std::simd::{f32x8, num::SimdFloat};
    debug_assert_eq!(row.len(), x.len());
    let rc = row.chunks_exact(LANES);
    let xc = x.chunks_exact(LANES);
    let (rt, xt) = (rc.remainder(), xc.remainder());
    let mut acc = f32x8::splat(0.0);
    for (rk, xk) in rc.zip(xc) {
        acc += f32x8::from_slice(rk) * f32x8::from_slice(xk);
    }
    let mut s = acc.reduce_sum();
    for (ri, xi) in rt.iter().zip(xt) {
        s += ri * xi;
    }
    s
}

/// Two weight rows against one activation vector — the 2×8 microkernel
/// body. `x` is read once per 8-chunk and feeds both rows' lane
/// accumulators.
#[cfg(not(feature = "simd"))]
#[inline]
fn dot2_fast(r0: &[f32], r1: &[f32], x: &[f32]) -> (f32, f32) {
    debug_assert_eq!(r0.len(), x.len());
    debug_assert_eq!(r1.len(), x.len());
    let c0 = r0.chunks_exact(LANES);
    let c1 = r1.chunks_exact(LANES);
    let cx = x.chunks_exact(LANES);
    let (t0, t1, tx) = (c0.remainder(), c1.remainder(), cx.remainder());
    let mut a0 = [0.0f32; LANES];
    let mut a1 = [0.0f32; LANES];
    for ((k0, k1), kx) in c0.zip(c1).zip(cx) {
        for l in 0..LANES {
            let xv = kx[l];
            a0[l] += k0[l] * xv;
            a1[l] += k1[l] * xv;
        }
    }
    let (mut s0, mut s1) = (a0.iter().sum::<f32>(), a1.iter().sum::<f32>());
    for ((v0, v1), xv) in t0.iter().zip(t1).zip(tx) {
        s0 += v0 * xv;
        s1 += v1 * xv;
    }
    (s0, s1)
}

#[cfg(feature = "simd")]
#[inline]
fn dot2_fast(r0: &[f32], r1: &[f32], x: &[f32]) -> (f32, f32) {
    use std::simd::{f32x8, num::SimdFloat};
    debug_assert_eq!(r0.len(), x.len());
    debug_assert_eq!(r1.len(), x.len());
    let c0 = r0.chunks_exact(LANES);
    let c1 = r1.chunks_exact(LANES);
    let cx = x.chunks_exact(LANES);
    let (t0, t1, tx) = (c0.remainder(), c1.remainder(), cx.remainder());
    let mut a0 = f32x8::splat(0.0);
    let mut a1 = f32x8::splat(0.0);
    for ((k0, k1), kx) in c0.zip(c1).zip(cx) {
        let xv = f32x8::from_slice(kx);
        a0 += f32x8::from_slice(k0) * xv;
        a1 += f32x8::from_slice(k1) * xv;
    }
    let (mut s0, mut s1) = (a0.reduce_sum(), a1.reduce_sum());
    for ((v0, v1), xv) in t0.iter().zip(t1).zip(tx) {
        s0 += v0 * xv;
        s1 += v1 * xv;
    }
    (s0, s1)
}

/// The weight plane of one quantized dense layer, exactly as stored.
#[derive(Debug, Clone)]
enum QuantWeights {
    /// dtype-3 codes re-signed and pre-expanded to one byte each
    /// (−7..7; the int8 tier).
    I8(Vec<i8>),
    /// Raw dtype-3 nibble buffer, low nibble first (the int4 tier).
    /// Codes are unpacked by flat element index, so rows need no
    /// byte alignment.
    Packed(Vec<u8>),
}

/// One dense layer served straight off the dtype-3 quantized store —
/// the f32 weights are never materialized.
///
/// Numerics: `out[r] = (Σ_i w_code[r,i]·x_q[i]) · w_scale · x_scale
/// + bias[r]`, where `x_q` is the activation row quantized to i8
/// against its own absmax (`x_scale = absmax/127`) and the sum is an
/// i32 accumulation. Each output row depends only on its own
/// activation row and order-independent integer adds, so
/// `logits_batch == logits_one` holds *exactly* on these tiers. A
/// zero activation row or an all-zero weight tensor degenerates to
/// `out = bias`.
#[derive(Debug, Clone)]
pub struct QuantizedLinear {
    out_dim: usize,
    in_dim: usize,
    /// Per-tensor power-of-two weight scale (0.0 = all-zero tensor).
    w_scale: f32,
    weights: QuantWeights,
}

impl QuantizedLinear {
    /// Build from a dtype-3 payload (`scale`, nibble-packed codes) as
    /// retained by [`crate::runtime::params::TensorStore`].
    pub fn from_packed(
        packed: &[u8],
        w_scale: f32,
        out_dim: usize,
        in_dim: usize,
        precision: Precision,
    ) -> Result<Self> {
        ensure!(
            precision.is_quantized(),
            "QuantizedLinear: precision '{}' is not a quantized tier",
            precision.as_str()
        );
        let numel = out_dim * in_dim;
        ensure!(
            packed.len() * 2 >= numel,
            "QuantizedLinear: {} nibbles < {out_dim}x{in_dim} weights",
            packed.len() * 2
        );
        // i32 accumulator headroom: |code| ≤ 8, |x_q| ≤ 127.
        ensure!(
            in_dim as u64 * 8 * 127 <= i32::MAX as u64,
            "QuantizedLinear: in_dim {in_dim} overflows the i32 accumulator"
        );
        let weights = match precision {
            Precision::Int8 => {
                let codes = (0..numel)
                    .map(|i| {
                        let b = packed[i / 2];
                        let code = if i % 2 == 0 { b & 0x0F } else { b >> 4 };
                        (code as i32 - 8) as i8
                    })
                    .collect();
                QuantWeights::I8(codes)
            }
            Precision::Int4 => QuantWeights::Packed(packed[..numel.div_ceil(2)].to_vec()),
            _ => unreachable!(),
        };
        Ok(Self { out_dim, in_dim, w_scale, weights })
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Bytes held by the weight plane (footprint accounting).
    pub fn weight_bytes(&self) -> usize {
        match &self.weights {
            QuantWeights::I8(v) => v.len(),
            QuantWeights::Packed(v) => v.len(),
        }
    }

    /// Batched forward: one activation row per `in_dim` chunk of `xs`.
    pub fn forward_batch(&self, bias: &[f32], xs: &[f32], out: &mut [f32]) {
        debug_assert_eq!(bias.len(), self.out_dim);
        debug_assert_eq!(xs.len() % self.in_dim.max(1), 0);
        debug_assert_eq!(out.len() % self.out_dim.max(1), 0);
        let mut xq = vec![0i8; self.in_dim];
        for (x, o) in xs.chunks_exact(self.in_dim).zip(out.chunks_exact_mut(self.out_dim)) {
            let absmax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            if absmax == 0.0 || self.w_scale == 0.0 {
                o.copy_from_slice(bias);
                continue;
            }
            let inv = 127.0 / absmax;
            for (q, &v) in xq.iter_mut().zip(x) {
                *q = (v * inv).round().clamp(-127.0, 127.0) as i8;
            }
            let rescale = self.w_scale * (absmax / 127.0);
            match &self.weights {
                QuantWeights::I8(w) => {
                    for (r, or) in o.iter_mut().enumerate() {
                        let row = &w[r * self.in_dim..(r + 1) * self.in_dim];
                        let mut acc = 0i32;
                        for (wi, xi) in row.iter().zip(&xq) {
                            acc += *wi as i32 * *xi as i32;
                        }
                        *or = acc as f32 * rescale + bias[r];
                    }
                }
                QuantWeights::Packed(bytes) => {
                    for (r, or) in o.iter_mut().enumerate() {
                        let base = r * self.in_dim;
                        let mut acc = 0i32;
                        for (ci, xi) in xq.iter().enumerate() {
                            let i = base + ci;
                            let b = bytes[i / 2];
                            let code = if i % 2 == 0 { b & 0x0F } else { b >> 4 };
                            acc += (code as i32 - 8) * *xi as i32;
                        }
                        *or = acc as f32 * rescale + bias[r];
                    }
                }
            }
        }
    }

    /// Single-row forward.
    pub fn forward_one(&self, bias: &[f32], x: &[f32], out: &mut [f32]) {
        self.forward_batch(bias, x, out)
    }
}

/// Validate a (backend arch, precision) pair; the single home of the
/// "who may run what" table. Error messages name the CLI flag to fix.
pub fn ensure_supported(arch: &str, precision: Precision) -> Result<()> {
    match (arch, precision) {
        (_, Precision::Exact) => Ok(()),
        ("native", _) => Ok(()),
        ("transformer", Precision::Fast) => Ok(()),
        ("transformer", p) => bail!(
            "--precision {} runs only on --backend native (the transformer serves exact|fast)",
            p.as_str()
        ),
        ("pjrt", p) => bail!(
            "--backend pjrt: --precision {} is not supported on the pjrt path — the AOT \
             executable fixes its own arithmetic; use --precision exact",
            p.as_str()
        ),
        // Kernel-free backends (stride, constant) ignore the axis.
        _ => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::quant;
    use crate::util::XorShift64;

    fn randvec(rng: &mut XorShift64, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.next_u64() % 2000) as f32 / 1000.0 - 1.0).collect()
    }

    #[test]
    fn precision_parse_roundtrip() {
        for p in [Precision::Exact, Precision::Fast, Precision::Int8, Precision::Int4] {
            assert_eq!(Precision::parse(p.as_str()), Some(p));
        }
        assert_eq!(Precision::parse("turbo"), None);
        assert!(Precision::Exact.is_exact());
        assert!(Precision::Int4.is_quantized());
        assert!(!Precision::Fast.is_quantized());
    }

    #[test]
    fn exact_tier_is_the_nn_oracle_bitwise() {
        let mut rng = XorShift64::new(7);
        let (in_dim, out_dim, batch) = (37, 11, 3);
        let w = randvec(&mut rng, in_dim * out_dim);
        let b = randvec(&mut rng, out_dim);
        let xs = randvec(&mut rng, in_dim * batch);
        let mut got = vec![0.0f32; out_dim * batch];
        let mut want = vec![0.0f32; out_dim * batch];
        linear_forward_batch(Precision::Exact, &w, &b, &xs, &mut got, in_dim, out_dim);
        nn::linear_forward_batch(&w, &b, &xs, &mut want, in_dim, out_dim);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn fast_matches_exact_within_tolerance_on_odd_shapes() {
        // Odd shapes: 1×1, sub-lane K, non-multiple-of-8 K, odd M/N.
        for &(in_dim, out_dim, batch) in
            &[(1, 1, 1), (3, 5, 2), (7, 2, 1), (8, 8, 4), (13, 17, 3), (129, 9, 5)]
        {
            let mut rng = XorShift64::new(in_dim as u64 * 31 + out_dim as u64);
            let w = randvec(&mut rng, in_dim * out_dim);
            let b = randvec(&mut rng, out_dim);
            let xs = randvec(&mut rng, in_dim * batch);
            let mut fast = vec![0.0f32; out_dim * batch];
            let mut exact = vec![0.0f32; out_dim * batch];
            linear_forward_batch(Precision::Fast, &w, &b, &xs, &mut fast, in_dim, out_dim);
            linear_forward_batch(Precision::Exact, &w, &b, &xs, &mut exact, in_dim, out_dim);
            let tol = 1e-5 * (in_dim as f32 + 1.0);
            for (f, e) in fast.iter().zip(&exact) {
                assert!((f - e).abs() <= tol, "{in_dim}x{out_dim}: fast {f} vs exact {e}");
            }
        }
    }

    #[test]
    fn fast_tier_handles_empty_batch() {
        let w = vec![1.0f32; 12];
        let b = vec![0.0f32; 3];
        let mut out = [0.0f32; 0];
        linear_forward_batch(Precision::Fast, &w, &b, &[], &mut out, 4, 3);
    }

    #[test]
    fn quantized_tiers_agree_and_track_f32() {
        let mut rng = XorShift64::new(99);
        let (in_dim, out_dim) = (24, 6);
        let w = randvec(&mut rng, in_dim * out_dim);
        let b = randvec(&mut rng, out_dim);
        let x = randvec(&mut rng, in_dim);
        let (scale, packed) = quant::pack_scaled(&w);
        let l8 = QuantizedLinear::from_packed(&packed, scale, out_dim, in_dim, Precision::Int8)
            .unwrap();
        let l4 = QuantizedLinear::from_packed(&packed, scale, out_dim, in_dim, Precision::Int4)
            .unwrap();
        let mut o8 = vec![0.0f32; out_dim];
        let mut o4 = vec![0.0f32; out_dim];
        l8.forward_one(&b, &x, &mut o8);
        l4.forward_one(&b, &x, &mut o4);
        // Same codes, same accumulation — the tiers are bit-identical.
        for (a, c) in o8.iter().zip(&o4) {
            assert_eq!(a.to_bits(), c.to_bits());
        }
        // And both track the f32 layer within the quantization budget.
        let mut of = vec![0.0f32; out_dim];
        nn::linear_forward(&w, &b, &x, &mut of);
        let budget = (scale / 2.0 + 0.02) * in_dim as f32;
        for (q, f) in o8.iter().zip(&of) {
            assert!((q - f).abs() <= budget, "quant {q} vs f32 {f} (budget {budget})");
        }
    }

    #[test]
    fn quantized_batch_is_bitwise_one_at_a_time() {
        let mut rng = XorShift64::new(123);
        let (in_dim, out_dim, batch) = (15, 7, 4);
        let w = randvec(&mut rng, in_dim * out_dim);
        let b = randvec(&mut rng, out_dim);
        let xs = randvec(&mut rng, in_dim * batch);
        let (scale, packed) = quant::pack_scaled(&w);
        let l = QuantizedLinear::from_packed(&packed, scale, out_dim, in_dim, Precision::Int4)
            .unwrap();
        let mut batched = vec![0.0f32; out_dim * batch];
        l.forward_batch(&b, &xs, &mut batched);
        for (i, x) in xs.chunks_exact(in_dim).enumerate() {
            let mut one = vec![0.0f32; out_dim];
            l.forward_one(&b, x, &mut one);
            for (a, c) in one.iter().zip(&batched[i * out_dim..(i + 1) * out_dim]) {
                assert_eq!(a.to_bits(), c.to_bits());
            }
        }
    }

    #[test]
    fn quantized_zero_row_and_zero_tensor_degenerate_to_bias() {
        let b = vec![0.5f32, -1.5];
        let (scale, packed) = quant::pack_scaled(&[0.0f32; 6]);
        let l = QuantizedLinear::from_packed(&packed, scale, 2, 3, Precision::Int8).unwrap();
        let mut out = vec![0.0f32; 2];
        l.forward_one(&b, &[1.0, 2.0, 3.0], &mut out);
        assert_eq!(out, b);
        let (s2, p2) = quant::pack_scaled(&[1.0f32; 6]);
        let l2 = QuantizedLinear::from_packed(&p2, s2, 2, 3, Precision::Int8).unwrap();
        l2.forward_one(&b, &[0.0, 0.0, 0.0], &mut out);
        assert_eq!(out, b);
    }

    #[test]
    fn precision_support_table() {
        assert!(ensure_supported("native", Precision::Int4).is_ok());
        assert!(ensure_supported("transformer", Precision::Fast).is_ok());
        assert!(ensure_supported("pjrt", Precision::Exact).is_ok());
        let e = ensure_supported("transformer", Precision::Int8).unwrap_err().to_string();
        assert!(e.contains("--precision int8"), "{e}");
        let e = ensure_supported("pjrt", Precision::Fast).unwrap_err().to_string();
        assert!(e.contains("--precision"), "{e}");
    }
}
