//! 4-bit quantization (paper §6, Tables 6/7).
//!
//! The paper clamps weights and activations to [-8, +8] during
//! training and observes that 4 bits then suffice for storage —
//! "memory consumption of the revised predictor could theoretically
//! be one-eighth of the previous one". The python side trains with
//! the clamp and writes both f32 and int4-packed parameter stores;
//! this module is the Rust decode path plus the footprint accounting
//! used to regenerate Table 7.
//!
//! Scheme: symmetric uniform quantization over [-8, 8] with 16 levels,
//! step = 16/15; two codes per byte, low nibble first.

pub const QUANT_LO: f32 = -8.0;
pub const QUANT_HI: f32 = 8.0;
pub const QUANT_LEVELS: u32 = 16;
/// Quantization step (16 range / 15 intervals).
pub const QUANT_STEP: f32 = (QUANT_HI - QUANT_LO) / (QUANT_LEVELS - 1) as f32;

/// Quantize one value to a 4-bit code.
#[inline]
pub fn quantize(x: f32) -> u8 {
    let clamped = x.clamp(QUANT_LO, QUANT_HI);
    (((clamped - QUANT_LO) / QUANT_STEP).round() as u32).min(QUANT_LEVELS - 1) as u8
}

/// Dequantize a 4-bit code.
#[inline]
pub fn dequantize(code: u8) -> f32 {
    QUANT_LO + (code & 0x0F) as f32 * QUANT_STEP
}

/// Pack a float slice into nibbles (low nibble first; odd lengths pad
/// the final high nibble with code 0).
pub fn pack(values: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len().div_ceil(2));
    for pair in values.chunks(2) {
        let lo = quantize(pair[0]);
        let hi = pair.get(1).map(|&v| quantize(v)).unwrap_or(0);
        out.push(lo | (hi << 4));
    }
    out
}

/// Unpack `n` values from a nibble-packed buffer.
pub fn unpack(bytes: &[u8], n: usize) -> Vec<f32> {
    assert!(bytes.len() * 2 >= n, "buffer too short: {} nibbles < {n}", bytes.len() * 2);
    (0..n)
        .map(|i| {
            let b = bytes[i / 2];
            let code = if i % 2 == 0 { b & 0x0F } else { b >> 4 };
            dequantize(code)
        })
        .collect()
}

/// Worst-case absolute reconstruction error inside the clamp range.
pub fn max_quant_error() -> f32 {
    QUANT_STEP / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_within_half_step() {
        for i in 0..1000 {
            let x = -8.0 + 16.0 * (i as f32 / 999.0);
            let err = (dequantize(quantize(x)) - x).abs();
            assert!(err <= max_quant_error() + 1e-6, "x={x} err={err}");
        }
    }

    #[test]
    fn clamps_out_of_range() {
        assert_eq!(quantize(100.0), 15);
        assert_eq!(quantize(-100.0), 0);
        assert!((dequantize(quantize(100.0)) - 8.0).abs() < 1e-6);
    }

    #[test]
    fn pack_unpack_roundtrip_odd_length() {
        let vals = [-8.0f32, -3.2, 0.0, 4.7, 8.0];
        let packed = pack(&vals);
        assert_eq!(packed.len(), 3, "5 values → 3 bytes");
        let back = unpack(&packed, vals.len());
        for (a, b) in vals.iter().zip(&back) {
            assert!((a - b).abs() <= max_quant_error() + 1e-6);
        }
    }

    #[test]
    fn endpoints_are_exact() {
        assert_eq!(dequantize(quantize(-8.0)), -8.0);
        assert_eq!(dequantize(quantize(8.0)), 8.0);
    }
}
