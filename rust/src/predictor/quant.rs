//! 4-bit quantization (paper §6, Tables 6/7).
//!
//! The paper clamps weights and activations to [-8, +8] during
//! training and observes that 4 bits then suffice for storage —
//! "memory consumption of the revised predictor could theoretically
//! be one-eighth of the previous one". The python side trains with
//! the clamp and writes both f32 and int4-packed parameter stores;
//! this module is the Rust decode path plus the footprint accounting
//! used to regenerate Table 7.
//!
//! Two schemes:
//!
//! * **Fixed grid** (store dtype 2, the python/aot format): symmetric
//!   uniform over [-8, 8] with 16 levels, step = 16/15; two codes per
//!   byte, low nibble first. The grid has **no representable zero**
//!   (nearest levels ±0.533) — fine for the paper's clamp-trained
//!   weights, destructive for small zero-centred ones.
//! * **Per-tensor scaled** (store dtype 3, what the Rust backends'
//!   `save(int4)` writes): signed codes −8..7 times a per-tensor
//!   **power-of-two** scale (smallest `2^m` with `7·2^m ≥ absmax`).
//!   Zero is exact (code 8), error ≤ scale/2 ≤ absmax/7, and because
//!   the scale is a power of two every dequantized value is exactly
//!   representable — quantization is a *projection*, so
//!   save→load→save round trips are bit-idempotent (the transformer /
//!   native int4 tests pin this).

pub const QUANT_LO: f32 = -8.0;
pub const QUANT_HI: f32 = 8.0;
pub const QUANT_LEVELS: u32 = 16;
/// Quantization step (16 range / 15 intervals).
pub const QUANT_STEP: f32 = (QUANT_HI - QUANT_LO) / (QUANT_LEVELS - 1) as f32;

/// Quantize one value to a 4-bit code.
#[inline]
pub fn quantize(x: f32) -> u8 {
    let clamped = x.clamp(QUANT_LO, QUANT_HI);
    (((clamped - QUANT_LO) / QUANT_STEP).round() as u32).min(QUANT_LEVELS - 1) as u8
}

/// Dequantize a 4-bit code.
#[inline]
pub fn dequantize(code: u8) -> f32 {
    QUANT_LO + (code & 0x0F) as f32 * QUANT_STEP
}

/// Pack a float slice into nibbles (low nibble first; odd lengths pad
/// the final high nibble with code 0).
pub fn pack(values: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len().div_ceil(2));
    for pair in values.chunks(2) {
        let lo = quantize(pair[0]);
        let hi = pair.get(1).map(|&v| quantize(v)).unwrap_or(0);
        out.push(lo | (hi << 4));
    }
    out
}

/// Unpack `n` values from a nibble-packed buffer.
pub fn unpack(bytes: &[u8], n: usize) -> Vec<f32> {
    assert!(bytes.len() * 2 >= n, "buffer too short: {} nibbles < {n}", bytes.len() * 2);
    (0..n)
        .map(|i| {
            let b = bytes[i / 2];
            let code = if i % 2 == 0 { b & 0x0F } else { b >> 4 };
            dequantize(code)
        })
        .collect()
}

/// Worst-case absolute reconstruction error inside the clamp range.
pub fn max_quant_error() -> f32 {
    QUANT_STEP / 2.0
}

/// Per-tensor power-of-two scale for the dtype-3 scheme: the smallest
/// `2^m` with `7·2^m ≥ absmax` (0.0 for an all-zero tensor). A
/// power of two keeps `code·scale` exact in f32, which is what makes
/// requantization idempotent.
pub fn pow2_scale(values: &[f32]) -> f32 {
    let absmax = values.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if absmax == 0.0 || !absmax.is_finite() {
        return 0.0;
    }
    let mut s = 1.0f32;
    while 7.0 * s < absmax {
        s *= 2.0;
    }
    while s * 0.5 >= f32::MIN_POSITIVE && 7.0 * (s * 0.5) >= absmax {
        s *= 0.5;
    }
    s
}

/// Quantize one value to a scaled-int4 code (0..15; 8 = exact zero).
#[inline]
pub fn quantize_scaled(x: f32, scale: f32) -> u8 {
    if scale <= 0.0 {
        return 8;
    }
    (((x / scale).round() as i32).clamp(-7, 7) + 8) as u8
}

/// Dequantize a scaled-int4 code.
#[inline]
pub fn dequantize_scaled(code: u8, scale: f32) -> f32 {
    ((code & 0x0F) as i32 - 8) as f32 * scale
}

/// Pack a float slice under the per-tensor scaled scheme; returns the
/// scale and the nibble buffer (low nibble first; odd lengths pad the
/// final high nibble with the zero code 8).
pub fn pack_scaled(values: &[f32]) -> (f32, Vec<u8>) {
    let scale = pow2_scale(values);
    let mut out = Vec::with_capacity(values.len().div_ceil(2));
    for pair in values.chunks(2) {
        let lo = quantize_scaled(pair[0], scale);
        let hi = pair.get(1).map(|&v| quantize_scaled(v, scale)).unwrap_or(8);
        out.push(lo | (hi << 4));
    }
    (scale, out)
}

/// Unpack `n` values from a scaled-int4 nibble buffer.
pub fn unpack_scaled(bytes: &[u8], scale: f32, n: usize) -> Vec<f32> {
    assert!(bytes.len() * 2 >= n, "buffer too short: {} nibbles < {n}", bytes.len() * 2);
    (0..n)
        .map(|i| {
            let b = bytes[i / 2];
            let code = if i % 2 == 0 { b & 0x0F } else { b >> 4 };
            dequantize_scaled(code, scale)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_within_half_step() {
        for i in 0..1000 {
            let x = -8.0 + 16.0 * (i as f32 / 999.0);
            let err = (dequantize(quantize(x)) - x).abs();
            assert!(err <= max_quant_error() + 1e-6, "x={x} err={err}");
        }
    }

    #[test]
    fn clamps_out_of_range() {
        assert_eq!(quantize(100.0), 15);
        assert_eq!(quantize(-100.0), 0);
        assert!((dequantize(quantize(100.0)) - 8.0).abs() < 1e-6);
    }

    #[test]
    fn pack_unpack_roundtrip_odd_length() {
        let vals = [-8.0f32, -3.2, 0.0, 4.7, 8.0];
        let packed = pack(&vals);
        assert_eq!(packed.len(), 3, "5 values → 3 bytes");
        let back = unpack(&packed, vals.len());
        for (a, b) in vals.iter().zip(&back) {
            assert!((a - b).abs() <= max_quant_error() + 1e-6);
        }
    }

    #[test]
    fn endpoints_are_exact() {
        assert_eq!(dequantize(quantize(-8.0)), -8.0);
        assert_eq!(dequantize(quantize(8.0)), 8.0);
    }

    #[test]
    fn scaled_scheme_represents_zero_and_small_weights() {
        // The fixed grid's fatal flaw for trained weights: no zero.
        assert!(dequantize(quantize(0.0)).abs() > 0.5);
        // The scaled scheme keeps zero exact and small weights alive.
        let vals = [0.0f32, 0.05, -0.05, 0.1, -0.02, 0.531];
        let (scale, packed) = pack_scaled(&vals);
        let back = unpack_scaled(&packed, scale, vals.len());
        assert_eq!(back[0], 0.0, "zero must be exact");
        for (a, b) in vals.iter().zip(&back) {
            assert!((a - b).abs() <= scale / 2.0 + 1e-9, "v={a} back={b} scale={scale}");
        }
        // Error bound: scale/2 ≤ absmax/7.
        assert!(scale <= 2.0 * 0.531 / 7.0, "scale {scale} too coarse");
    }

    #[test]
    fn scaled_roundtrip_is_idempotent() {
        let vals: Vec<f32> = (0..101).map(|i| ((i as f32) * 0.731).sin() * 1.3).collect();
        let (s1, p1) = pack_scaled(&vals);
        let q1 = unpack_scaled(&p1, s1, vals.len());
        let (s2, p2) = pack_scaled(&q1);
        let q2 = unpack_scaled(&p2, s2, q1.len());
        assert_eq!(q1, q2, "requantization must be a fixed point");
    }

    #[test]
    fn scaled_all_zero_tensor() {
        let (scale, packed) = pack_scaled(&[0.0f32; 5]);
        assert_eq!(scale, 0.0);
        assert_eq!(unpack_scaled(&packed, scale, 5), vec![0.0; 5]);
    }
}
