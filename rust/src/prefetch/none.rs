//! Demand paging only — no prefetching. The lower bound every policy
//! is implicitly compared against (pure on-demand migration, paper
//! §2.1).

use super::{FaultInfo, PrefetchDecision, Prefetcher};

#[derive(Debug, Default)]
pub struct NonePrefetcher;

impl Prefetcher for NonePrefetcher {
    fn name(&self) -> &'static str {
        "none"
    }

    fn on_fault_into(&mut self, _fault: &FaultInfo, _out: &mut PrefetchDecision) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prefetch::MemPressure;
    use crate::types::AccessOrigin;

    #[test]
    fn never_prefetches() {
        let mut p = NonePrefetcher;
        let d = p.on_fault(&FaultInfo {
            now: 0,
            service_at: 100,
            pc: 0,
            page: 1,
            origin: AccessOrigin { sm: 0, warp: 0, cta: 0, tpc: 0, kernel_id: 0 },
            array_id: 0,
            mem: MemPressure::unpressured(),
        });
        assert!(d.requests.is_empty());
    }
}
