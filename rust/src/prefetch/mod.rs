//! Prefetch policies.
//!
//! The simulator raises a [`FaultInfo`] for every far-fault; the active
//! [`Prefetcher`] answers with a [`PrefetchDecision`] — the set of
//! extra pages to migrate and when each transfer may start (learned
//! predictors pay a prediction latency, paper §7.3).
//!
//! Implementations:
//! * [`none::NonePrefetcher`] — demand paging only (lower bound).
//! * [`tree::TreePrefetcher`] — NVIDIA's tree-based neighborhood
//!   prefetcher (paper Fig. 2, Ganguly et al. ISCA'19).
//! * [`uvmsmart::UvmSmartPrefetcher`] — the UVMSmart baseline "U":
//!   tree prefetching + adaptive delayed-migration/pinning hooks.
//! * [`stride::StridePrefetcher`] — sequential next-block policy.
//! * [`dl::DlPrefetcher`] — the paper's contribution "R": basic-block
//!   prefetch + top-1 predicted page from the learned model.
//! * [`oracle::OraclePrefetcher`] — replay-based ideal prefetcher
//!   (unity = 1 reference point).

pub mod dl;
pub mod none;
pub mod oracle;
pub mod stride;
pub mod tree;
pub mod uvmsmart;

use crate::types::{AccessOrigin, Cycle, PageNum};

/// Device-memory occupancy at fault time. Threaded through every
/// [`FaultInfo`] so policies can throttle their issue width near
/// capacity — under oversubscription every speculative page evicts a
/// live one, and a pressure-blind prefetcher thrashes (arXiv:2204.02974).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemPressure {
    /// Pages currently known to the device (resident or in flight).
    pub occupancy: u64,
    /// Device capacity in page frames.
    pub capacity: u64,
}

impl MemPressure {
    pub fn at(occupancy: u64, capacity: u64) -> Self {
        Self { occupancy, capacity }
    }

    /// "No pressure" placeholder for unit tests and benches.
    pub fn unpressured() -> Self {
        Self { occupancy: 0, capacity: u64::MAX }
    }

    /// Occupancy as a fraction of capacity.
    pub fn fraction(&self) -> f64 {
        if self.capacity == 0 {
            1.0
        } else {
            self.occupancy as f64 / self.capacity as f64
        }
    }

    /// True once occupancy has reached `threshold` (a fraction).
    pub fn above(&self, threshold: f64) -> bool {
        self.fraction() >= threshold
    }
}

/// A far-fault as presented to the prefetcher.
#[derive(Debug, Clone, Copy)]
pub struct FaultInfo {
    /// Cycle the access reached the GMMU.
    pub now: Cycle,
    /// Cycle the host-side fault service completes (now + walk +
    /// 45 µs); transfers triggered by this fault start no earlier.
    pub service_at: Cycle,
    pub pc: u64,
    pub page: PageNum,
    pub origin: AccessOrigin,
    pub array_id: u8,
    /// Device occupancy when the fault was raised (post-admit of the
    /// demanded page) — the pressure signal for issue-width throttling.
    pub mem: MemPressure,
}

/// One page the prefetcher wants migrated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefetchRequest {
    pub page: PageNum,
    /// The transfer may not start before this cycle (models prediction
    /// latency; 0-latency policies use the fault service time).
    pub earliest_start: Cycle,
}

impl PrefetchRequest {
    pub fn at(page: PageNum, earliest_start: Cycle) -> Self {
        Self { page, earliest_start }
    }
}

/// One page the prefetcher declares dead and wants given back —
/// freed without writeback (the discard half of the command
/// vocabulary; `UvmDiscardAsync` modeled when `lazy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DiscardRequest {
    pub page: PageNum,
    /// Lazy discards only mark the page; the frame is reclaimed when
    /// admission pressure needs it, and a demand touch cancels the
    /// mark. Eager (`false`) discards free the frame immediately.
    pub lazy: bool,
}

/// Response to a single fault.
#[derive(Debug, Clone, Default)]
pub struct PrefetchDecision {
    pub requests: Vec<PrefetchRequest>,
    /// Predicted-dead pages to hand back (see [`DiscardRequest`]).
    /// Empty for every policy except `dl` under memory pressure.
    pub discards: Vec<DiscardRequest>,
}

impl PrefetchDecision {
    /// Empty both lists keeping their capacity — the engine reuses one
    /// decision buffer across the whole fault loop.
    pub fn clear(&mut self) {
        self.requests.clear();
        self.discards.clear();
    }
}

/// Telemetry exported by learned policies (merged into
/// [`crate::sim::Metrics`] at the end of a run).
#[derive(Debug, Clone, Default)]
pub struct PrefetchTelemetry {
    pub predictions: u64,
    pub prediction_batches: u64,
    pub bypass_predictions: u64,
    pub oov_predictions: u64,
    pub finetune_rounds: u64,
}

/// A prefetching policy. Implementations must be deterministic, and
/// `Send` so a whole simulation cell (workload + policy + simulator)
/// can run as a self-contained job on a sweep worker thread.
pub trait Prefetcher: Send {
    fn name(&self) -> &'static str;

    /// Called on every far-fault (page absent, migration initiated).
    /// Writes this fault's requests/discards into `out`, which the
    /// caller has cleared (implementations may rely on it arriving
    /// empty — the delegating policies post-filter what they appended).
    /// The engine reuses one buffer across the whole fault loop, so
    /// the hot path allocates nothing once its capacity has warmed up.
    fn on_fault_into(&mut self, fault: &FaultInfo, out: &mut PrefetchDecision);

    /// Allocating convenience wrapper around [`Prefetcher::on_fault_into`]
    /// (unit tests and benches; the engine uses the buffered form).
    fn on_fault(&mut self, fault: &FaultInfo) -> PrefetchDecision {
        let mut out = PrefetchDecision::default();
        self.on_fault_into(fault, &mut out);
        out
    }

    /// Called on every device-memory access *after* outcome
    /// classification — feedback for learning/adaptive policies.
    /// `hit` is true when the page was resident.
    fn on_access(&mut self, _origin: AccessOrigin, _pc: u64, _page: PageNum, _hit: bool, _now: Cycle) {}

    /// Called when the simulator evicts a page (oversubscription).
    fn on_evict(&mut self, _page: PageNum) {}

    /// Append prefetch requests that matured asynchronously (batched
    /// predictions completing after their flush) to `out`. Called once
    /// per simulator event; must be cheap when there is nothing to do
    /// (the default does nothing).
    fn drain_into(&mut self, _now: Cycle, _out: &mut Vec<PrefetchRequest>) {}

    /// Allocating convenience wrapper around [`Prefetcher::drain_into`].
    fn drain(&mut self, now: Cycle) -> Vec<PrefetchRequest> {
        let mut out = Vec::new();
        self.drain_into(now, &mut out);
        out
    }

    /// Called with the retired-instruction counter after each memory
    /// event — drives the online fine-tune schedule (paper §7.1).
    fn on_retired(&mut self, _instructions: u64) {}

    /// End-of-run hook (flush outstanding state, report stats).
    fn finish(&mut self, _now: Cycle) {}

    /// Learned-policy telemetry (default: all zeros).
    fn telemetry(&self) -> PrefetchTelemetry {
        PrefetchTelemetry::default()
    }

    /// Arm structured-telemetry collection (DESIGN.md §13). Only the
    /// engine calls this, and only when a `--telemetry` sink is
    /// attached — policies that record nothing ignore it, and a policy
    /// that does record must keep the disabled path allocation-free
    /// (telemetry-off byte-identity is gated by `tests/ab_identity.rs`).
    fn set_telemetry_enabled(&mut self, _on: bool) {}

    /// Drain the inference-batch lifecycle events recorded since the
    /// last call (empty unless telemetry is enabled and the policy
    /// batches predictions).
    fn take_batch_events(&mut self) -> Vec<crate::telemetry::BatchEvent> {
        Vec::new()
    }

    /// Hand over the per-(cluster, PC-bucket) prediction post-mortem
    /// (None unless telemetry is enabled and the policy predicts).
    fn take_postmortem(&mut self) -> Option<crate::telemetry::Postmortem> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_constructor() {
        let r = PrefetchRequest::at(42, 100);
        assert_eq!(r.page, 42);
        assert_eq!(r.earliest_start, 100);
    }

    #[test]
    fn decision_clear_empties_but_keeps_capacity() {
        let mut d = PrefetchDecision::default();
        d.requests.push(PrefetchRequest::at(1, 0));
        d.discards.push(DiscardRequest { page: 2, lazy: true });
        let cap = (d.requests.capacity(), d.discards.capacity());
        d.clear();
        assert!(d.requests.is_empty() && d.discards.is_empty());
        assert_eq!((d.requests.capacity(), d.discards.capacity()), cap);
    }

    #[test]
    fn mem_pressure_fraction_and_threshold() {
        let m = MemPressure::at(90, 100);
        assert!((m.fraction() - 0.9).abs() < 1e-12);
        assert!(m.above(0.85));
        assert!(!m.above(0.95));
        assert!(!MemPressure::unpressured().above(0.5));
        assert!(MemPressure::at(1, 0).above(0.99), "zero capacity counts as full");
    }
}
