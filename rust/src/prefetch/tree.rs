//! The tree-based neighborhood prefetcher NVIDIA ships in the CUDA
//! driver, as reverse-engineered by Ganguly et al. (ISCA'19) and
//! described in the paper's §2.2 / Figure 2:
//!
//! * A managed allocation is split into 2 MB chunks; each chunk is a
//!   full binary tree over its 32 × 64 KB *basic blocks* (16 pages).
//! * A far-fault migrates the whole 64 KB basic block of the faulted
//!   page.
//! * The runtime tracks, per non-leaf node, how much of the node's
//!   span is valid on-device. Whenever a node becomes **more than
//!   50 %** valid, the *remaining* invalid pages of that node are
//!   scheduled as further prefetch candidates — so a half-touched
//!   2 MB chunk snowballs into a full-chunk migration (the Fig. 11
//!   bandwidth spike the paper dissects).

use super::{FaultInfo, PrefetchDecision, Prefetcher, PrefetchRequest};
use crate::types::{bb_base, root_base, Cycle, PageNum, PAGES_PER_BB, PAGES_PER_ROOT};
use std::collections::HashMap;

/// Drop every request outside the faulted page's 64 KB basic block,
/// returning how many were dropped — the conservative-mode primitive
/// shared by the tree throttle and UVMSmart's promotion suppression.
pub(crate) fn retain_basic_block(requests: &mut Vec<PrefetchRequest>, page: PageNum) -> u64 {
    let bb = bb_base(page);
    let before = requests.len();
    requests.retain(|r| r.page >= bb && r.page < bb + PAGES_PER_BB);
    (before - requests.len()) as u64
}

/// Per-2MB-chunk valid-page bitmap (512 pages = 8 × u64).
#[derive(Debug, Clone, Default)]
struct ChunkState {
    valid: [u64; 8],
}

impl ChunkState {
    #[inline]
    fn is_valid(&self, idx: u64) -> bool {
        self.valid[(idx / 64) as usize] >> (idx % 64) & 1 == 1
    }

    #[inline]
    fn set_valid(&mut self, idx: u64) {
        self.valid[(idx / 64) as usize] |= 1 << (idx % 64);
    }

    /// Count valid pages within `[lo, lo + span)`.
    fn count(&self, lo: u64, span: u64) -> u64 {
        (lo..lo + span).filter(|&i| self.is_valid(i)).count() as u64
    }
}

#[derive(Debug)]
pub struct TreePrefetcher {
    /// root page of 2MB chunk → valid bitmap.
    chunks: HashMap<PageNum, ChunkState>,
    /// Promotion threshold (paper: 0.5).
    threshold: f64,
    /// Occupancy fraction above which promotion cascades are dropped
    /// (issue-width throttle). `None` — the default — is the stock
    /// driver behaviour: NVIDIA's tree prefetcher is not
    /// pressure-aware, which is exactly why it thrashes under
    /// oversubscription (the baseline the oversub sweep measures).
    pressure_throttle: Option<f64>,
    /// Promotion pages dropped by the throttle.
    pub throttled: u64,
}

impl TreePrefetcher {
    pub fn new(threshold: f64) -> Self {
        Self { chunks: HashMap::new(), threshold, pressure_throttle: None, throttled: 0 }
    }

    /// Enable the near-capacity throttle: above `frac` occupancy the
    /// policy migrates only the faulted basic block (like UVMSmart's
    /// conservative mode), never a promotion cascade.
    pub fn with_pressure_throttle(mut self, frac: f64) -> Self {
        self.pressure_throttle = Some(frac);
        self
    }

    /// Mark pages valid and append the promotion cascade to `out`:
    /// walk from the faulted basic block up toward the 2 MB root; at
    /// each level, if the enclosing node is now > threshold valid,
    /// schedule its remaining invalid pages (and keep walking up).
    fn fault_block_into(&mut self, page: PageNum, at: Cycle, out: &mut Vec<PrefetchRequest>) {
        let root = root_base(page);
        let chunk = self.chunks.entry(root).or_default();

        // Leaf: migrate the whole 64 KB basic block.
        let bb = bb_base(page) - root;
        for i in bb..bb + PAGES_PER_BB {
            if !chunk.is_valid(i) {
                chunk.set_valid(i);
                out.push(PrefetchRequest::at(root + i, at));
            }
        }

        // Climb: node spans double from 2 basic blocks (128 KB) up to
        // the full 512-page chunk (2 MB).
        let mut span = PAGES_PER_BB * 2;
        while span <= PAGES_PER_ROOT {
            let node_lo = bb / span * span;
            let valid = chunk.count(node_lo, span);
            if (valid as f64) > self.threshold * span as f64 && valid < span {
                for i in node_lo..node_lo + span {
                    if !chunk.is_valid(i) {
                        chunk.set_valid(i);
                        out.push(PrefetchRequest::at(root + i, at));
                    }
                }
            }
            span *= 2;
        }
    }
}

impl Prefetcher for TreePrefetcher {
    fn name(&self) -> &'static str {
        "tree"
    }

    fn on_fault_into(&mut self, fault: &FaultInfo, out: &mut PrefetchDecision) {
        self.fault_block_into(fault.page, fault.service_at, &mut out.requests);
        if let Some(thr) = self.pressure_throttle {
            if fault.mem.above(thr) {
                // Keep only the faulted basic block; promoted pages
                // stay marked valid in the bitmap (the driver believes
                // them handled), mirroring UVMSmart's conservative
                // mode. Retaining over the whole buffer is sound
                // because it arrives empty (trait contract).
                self.throttled += retain_basic_block(&mut out.requests, fault.page);
            }
        }
    }

    fn on_evict(&mut self, page: PageNum) {
        // The driver decrements node counters on eviction so chunks can
        // be re-promoted later.
        let root = root_base(page);
        if let Some(chunk) = self.chunks.get_mut(&root) {
            let idx = page - root;
            chunk.valid[(idx / 64) as usize] &= !(1 << (idx % 64));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prefetch::MemPressure;
    use crate::types::AccessOrigin;

    fn fault(page: PageNum) -> FaultInfo {
        FaultInfo {
            now: 0,
            service_at: 10,
            pc: 0,
            page,
            origin: AccessOrigin { sm: 0, warp: 0, cta: 0, tpc: 0, kernel_id: 0 },
            array_id: 0,
            mem: MemPressure::unpressured(),
        }
    }

    #[test]
    fn first_fault_prefetches_whole_basic_block() {
        let mut t = TreePrefetcher::new(0.5);
        let d = t.on_fault(&fault(5));
        // Pages 0..16 of the chunk — including the faulted page (the
        // block migrates as one transaction).
        assert_eq!(d.requests.len(), 16);
        let pages: Vec<u64> = d.requests.iter().map(|r| r.page).collect();
        assert_eq!(pages, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn second_block_in_node_triggers_promotion() {
        let mut t = TreePrefetcher::new(0.5);
        t.on_fault(&fault(5)); // bb 0 valid: node(128KB) at 50% — not >50%
        let d = t.on_fault(&fault(40)); // bb 2 (pages 32..48)
        // bb2 migrates (16 pages). Node pages 32..64 is then 50%... the
        // enclosing 128KB node [32,64) holds bbs 2,3: 16/32 = 50%, not
        // promoted. But node [0,64) (256KB) holds bbs 0..4: 32/64 = 50%,
        // not promoted either. So exactly 16 pages.
        assert_eq!(d.requests.len(), 16);
        // Faulting into bb 1 now makes [0,32) 100% (after leaf) and the
        // 64-page node 48/64 = 75% > 50% ⇒ promote remaining 16 pages,
        // then the 128-page node is 64/128 = 50%, stop.
        let d = t.on_fault(&fault(17));
        assert_eq!(d.requests.len(), 16 + 16, "leaf block + promoted sibling");
    }

    #[test]
    fn promotion_cascades_to_full_chunk() {
        let mut t = TreePrefetcher::new(0.5);
        // Touch every *even* basic block of the 2MB chunk: exactly 50%
        // valid at every tree level, so nothing promotes (the paper's
        // threshold is strictly "more than 50%").
        let mut total = 0;
        for bb in 0..16 {
            total += t.on_fault(&fault(bb * 32)).requests.len(); // blocks 0,2,4,…,30
        }
        assert_eq!(total, 16 * 16, "no promotion at exactly 50%");
        // One more block tips every ancestor over 50% in turn: the
        // cascade snowballs the whole 2MB chunk (§2.2 / Fig. 11 spike).
        total += t.on_fault(&fault(16)).requests.len(); // block 1
        assert_eq!(total as u64, PAGES_PER_ROOT, "full chunk resident after cascade");
    }

    #[test]
    fn pressure_throttle_drops_promotions_near_capacity() {
        let mut t = TreePrefetcher::new(0.5).with_pressure_throttle(0.9);
        t.on_fault(&fault(5)); // bb 0
        t.on_fault(&fault(40)); // bb 2
        // Unthrottled this fault would add the [48, 64) promotion (see
        // `second_block_in_node_triggers_promotion`); at 95 % occupancy
        // only the faulted basic block survives.
        let mut f = fault(17);
        f.mem = MemPressure::at(95, 100);
        let d = t.on_fault(&f);
        assert_eq!(d.requests.len(), 16, "leaf block only under pressure");
        assert!(d.requests.iter().all(|r| r.page >= 16 && r.page < 32));
        assert_eq!(t.throttled, 16);
    }

    #[test]
    fn default_tree_ignores_pressure() {
        let mut t = TreePrefetcher::new(0.5);
        t.on_fault(&fault(5));
        t.on_fault(&fault(40));
        let mut f = fault(17);
        f.mem = MemPressure::at(100, 100);
        let d = t.on_fault(&f);
        assert_eq!(d.requests.len(), 32, "stock driver promotes regardless of pressure");
    }

    #[test]
    fn eviction_clears_valid_bit() {
        let mut t = TreePrefetcher::new(0.5);
        t.on_fault(&fault(0));
        t.on_evict(3);
        // Re-faulting page 3's block prefetches only the cleared page.
        let d = t.on_fault(&fault(3));
        assert_eq!(d.requests.len(), 1);
        assert_eq!(d.requests[0].page, 3);
    }

    #[test]
    fn distinct_chunks_are_independent() {
        let mut t = TreePrefetcher::new(0.5);
        let d1 = t.on_fault(&fault(0));
        let d2 = t.on_fault(&fault(PAGES_PER_ROOT * 7 + 3));
        assert_eq!(d1.requests.len(), 16);
        assert_eq!(d2.requests.len(), 16);
        assert!(d2.requests.iter().all(|r| r.page >= PAGES_PER_ROOT * 7));
    }
}
