//! Classic per-cluster stride prefetcher: detects a repeating page
//! delta per (SM, warp) stream and prefetches `degree` pages ahead.
//! Serves two roles: a comparison policy, and the pure-Rust fallback
//! backend for the DL prefetcher when no artifacts are available.

use super::{FaultInfo, PrefetchDecision, Prefetcher, PrefetchRequest};
use crate::types::{PageDelta, PageNum};
use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
struct StreamState {
    last_page: Option<PageNum>,
    last_delta: Option<PageDelta>,
    /// Consecutive confirmations of `last_delta`.
    confidence: u8,
}

#[derive(Debug)]
pub struct StridePrefetcher {
    streams: HashMap<(u16, u16), StreamState>,
    /// Prefetch this many strides ahead once confident.
    degree: usize,
    /// Confirmations required before prefetching.
    min_confidence: u8,
}

impl StridePrefetcher {
    pub fn new(degree: usize, min_confidence: u8) -> Self {
        Self { streams: HashMap::new(), degree, min_confidence }
    }

    /// Observe a page in a stream; returns the confirmed stride if any.
    fn observe(&mut self, sm: u16, warp: u16, page: PageNum) -> Option<PageDelta> {
        let s = self.streams.entry((sm, warp)).or_default();
        if let Some(last) = s.last_page {
            let delta = page as i64 - last as i64;
            if Some(delta) == s.last_delta {
                s.confidence = s.confidence.saturating_add(1);
            } else {
                s.last_delta = Some(delta);
                s.confidence = 1;
            }
        }
        s.last_page = Some(page);
        if s.confidence >= self.min_confidence && s.last_delta != Some(0) {
            s.last_delta
        } else {
            None
        }
    }
}

impl Default for StridePrefetcher {
    fn default() -> Self {
        Self::new(4, 2)
    }
}

impl Prefetcher for StridePrefetcher {
    fn name(&self) -> &'static str {
        "stride"
    }

    fn on_fault_into(&mut self, fault: &FaultInfo, out: &mut PrefetchDecision) {
        let stride = self.observe(fault.origin.sm, fault.origin.warp, fault.page);
        if let Some(d) = stride {
            let mut p = fault.page as i64;
            for _ in 0..self.degree {
                p += d;
                if p >= 0 {
                    out.requests.push(PrefetchRequest::at(p as PageNum, fault.service_at));
                }
            }
        }
    }

    fn on_access(&mut self, origin: crate::types::AccessOrigin, _pc: u64, page: PageNum, hit: bool, _now: u64) {
        // Keep the stride model trained on hits too (faults alone skip
        // the intra-block steps).
        if hit {
            self.observe(origin.sm, origin.warp, page);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prefetch::MemPressure;
    use crate::types::AccessOrigin;

    fn fault(page: PageNum) -> FaultInfo {
        FaultInfo {
            now: 0,
            service_at: 10,
            pc: 0,
            page,
            origin: AccessOrigin { sm: 0, warp: 0, cta: 0, tpc: 0, kernel_id: 0 },
            array_id: 0,
            mem: MemPressure::unpressured(),
        }
    }

    #[test]
    fn learns_constant_stride() {
        let mut s = StridePrefetcher::new(2, 2);
        assert!(s.on_fault(&fault(10)).requests.is_empty(), "cold");
        assert!(s.on_fault(&fault(12)).requests.is_empty(), "one confirmation");
        let d = s.on_fault(&fault(14));
        assert_eq!(
            d.requests.iter().map(|r| r.page).collect::<Vec<_>>(),
            vec![16, 18],
            "two strides ahead"
        );
    }

    #[test]
    fn stride_change_resets_confidence() {
        let mut s = StridePrefetcher::new(2, 2);
        s.on_fault(&fault(10));
        s.on_fault(&fault(12));
        s.on_fault(&fault(14));
        assert!(s.on_fault(&fault(100)).requests.is_empty(), "new delta, confidence reset");
    }

    #[test]
    fn negative_strides_supported() {
        let mut s = StridePrefetcher::new(1, 2);
        s.on_fault(&fault(100));
        s.on_fault(&fault(96));
        let d = s.on_fault(&fault(92));
        assert_eq!(d.requests[0].page, 88);
    }

    #[test]
    fn streams_are_per_warp() {
        let mut s = StridePrefetcher::new(1, 2);
        let mut f = fault(10);
        s.on_fault(&f);
        f.origin.warp = 1;
        f.page = 500;
        assert!(s.on_fault(&f).requests.is_empty(), "different warp = fresh stream");
    }
}
