//! Replay-based ideal prefetcher — the "perfect prefetcher" reference
//! point of Table 11 (unity = 1.0).
//!
//! A recording run captures the order in which pages are first
//! demanded; the oracle run then prefetches, on every fault, the next
//! `lookahead` not-yet-issued pages of that exact sequence. Every
//! prefetch is used (accuracy → 1), every future miss is anticipated
//! (coverage → 1), and with enough lookahead pages arrive before
//! demand (hit rate → 1).

use super::{FaultInfo, PrefetchDecision, Prefetcher, PrefetchRequest};
use crate::types::PageNum;
use std::collections::HashSet;

#[derive(Debug)]
pub struct OraclePrefetcher {
    /// First-touch page order from the recording run.
    future: Vec<PageNum>,
    cursor: usize,
    issued: HashSet<PageNum>,
    lookahead: usize,
}

impl OraclePrefetcher {
    pub fn new(first_touch_order: Vec<PageNum>, lookahead: usize) -> Self {
        Self { future: first_touch_order, cursor: 0, issued: HashSet::new(), lookahead }
    }
}

impl Prefetcher for OraclePrefetcher {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn on_fault_into(&mut self, fault: &FaultInfo, out: &mut PrefetchDecision) {
        // Advance the cursor past the faulting page (we are "here" in
        // the recorded order) and emit the next `lookahead` pages.
        if let Some(pos) = self.future[self.cursor..].iter().position(|&p| p == fault.page) {
            self.cursor += pos + 1;
        }
        self.issued.insert(fault.page);
        // Bound by pages pushed *here*, not `out.requests.len()` — the
        // lookahead budget is per-fault regardless of buffer contents.
        let mut pushed = 0;
        let mut i = self.cursor;
        while pushed < self.lookahead && i < self.future.len() {
            let p = self.future[i];
            if self.issued.insert(p) {
                out.requests.push(PrefetchRequest::at(p, fault.service_at));
                pushed += 1;
            }
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prefetch::MemPressure;
    use crate::types::AccessOrigin;

    fn fault(page: PageNum) -> FaultInfo {
        FaultInfo {
            now: 0,
            service_at: 10,
            pc: 0,
            page,
            origin: AccessOrigin { sm: 0, warp: 0, cta: 0, tpc: 0, kernel_id: 0 },
            array_id: 0,
            mem: MemPressure::unpressured(),
        }
    }

    #[test]
    fn prefetches_exactly_the_future() {
        let mut o = OraclePrefetcher::new(vec![1, 2, 3, 4, 5], 2);
        let d = o.on_fault(&fault(1));
        assert_eq!(d.requests.iter().map(|r| r.page).collect::<Vec<_>>(), vec![2, 3]);
        // Pages 2,3 now arrive before demand; the next fault is 4.
        let d = o.on_fault(&fault(4));
        assert_eq!(d.requests.iter().map(|r| r.page).collect::<Vec<_>>(), vec![5]);
    }

    #[test]
    fn never_reissues_a_page() {
        let mut o = OraclePrefetcher::new(vec![1, 2, 2, 3], 3);
        let d = o.on_fault(&fault(1));
        let pages: Vec<_> = d.requests.iter().map(|r| r.page).collect();
        assert_eq!(pages, vec![2, 3], "duplicate 2 skipped");
    }
}
