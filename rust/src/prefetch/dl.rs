//! The paper's solution ("R" in Tables 10/11): deep-learning-driven
//! page prefetching.
//!
//! Per §4/§6, on every far-fault the runtime
//! 1. migrates the faulting 64 KB basic block (same floor as the tree
//!    prefetcher, at most 16 pages per fault), and
//! 2. asks the learned predictor for the top-1 next page delta over
//!    the cluster's 30-token history and additionally migrates
//!    `fault_page + delta`.
//!
//! Predictions cost `prediction_latency_cycles` (§7.3, default 1 µs ≈
//! 1500 cycles) and are dynamically batched. Which model answers is
//! the `--backend` axis ([`crate::config::PredictorBackendKind`], see
//! DESIGN.md §6): the stride frequency vote, the native in-process
//! learned model (`repro train`), or the AOT PJRT executable. Clusters
//! whose delta distribution has converged bypass the model entirely
//! and emit the dominant delta (§6 item 5). Online fine-tuning replays
//! recent labelled windows through the backend's train step every N
//! instructions (§7.1) — a real gradient step (with a real loss) on
//! the native backend.

use super::{
    DiscardRequest, FaultInfo, PrefetchDecision, Prefetcher, PrefetchRequest, PrefetchTelemetry,
};
use crate::config::{BypassMode, RuntimeConfig};
use crate::predictor::batcher::{Batcher, PendingRequest};
use crate::predictor::engine::featurize_window;
use crate::predictor::finetune::FinetuneScheduler;
use crate::predictor::history::HistoryTable;
use crate::predictor::{ClusterBy, ClusterKey, PredictorEngine, Prediction, Window};
use crate::telemetry::{pc_bucket, BatchEvent, Postmortem};
use crate::types::{bb_base, Cycle, PageNum, PAGES_PER_BB};
use std::collections::HashMap;

/// Latency of a bypassed (attention-free) prediction: the embedding +
/// FC path only; an order of magnitude below the full model (§5.4 —
/// the attention module is "the main source of complexity").
const BYPASS_LATENCY_DIV: u64 = 10;

/// Issue width of the block prefetch when the device is near capacity:
/// a quarter basic block (4 pages) instead of the full 64 KB block.
const THROTTLED_SPAN: u64 = PAGES_PER_BB / 4;

/// Delta-distribution convergence a cluster must reach before its
/// previous basic block is declared dead under pressure: a strongly
/// forward-streaming cluster (dominant delta > 0) will not revisit the
/// block it just left, so the pages can be handed back lazily instead
/// of waiting for the eviction policy to guess.
const DISCARD_CONVERGENCE: f64 = 0.75;

/// Cap on stored inference-batch lifecycle events when telemetry is
/// armed (the span-ring discipline of DESIGN.md §13: bounded
/// collections, drop-newest past the cap).
const BATCH_EVENT_CAP: usize = 1 << 16;

pub struct DlPrefetcher {
    engine: PredictorEngine,
    cluster_by: ClusterBy,
    history: HistoryTable<ClusterKey>,
    /// Last *full* window per cluster, pending its ground-truth label.
    last_window: HashMap<ClusterKey, Window>,
    /// Basic block of each cluster's previous fault — the candidate
    /// for a lazy discard once the cluster streams past it.
    last_bb: HashMap<ClusterKey, PageNum>,
    batcher: Batcher,
    finetune: FinetuneScheduler,
    latency: Cycle,
    bypass_mode: BypassMode,
    bypass_convergence: f64,
    /// Occupancy fraction above which the block prefetch shrinks to a
    /// quarter block (the learned prediction still issues — it is the
    /// high-value transfer worth an eviction).
    pressure_threshold: f64,
    /// Prediction prefetches waiting to be drained by the simulator.
    matured: Vec<PrefetchRequest>,
    telemetry: PrefetchTelemetry,
    finetune_losses: Vec<f64>,
    /// Structured-telemetry arm switch (DESIGN.md §13). Off (the
    /// default) every field below stays empty and no per-fault work or
    /// allocation happens — the byte-identity anchor.
    telemetry_on: bool,
    /// Inference-batch lifecycle events, drained by the engine's sink.
    batch_events: Vec<BatchEvent>,
    /// Per-cluster outstanding top-1 prediction awaiting its ground
    /// truth: (anchor page, predicted delta, PC bucket).
    last_pred: HashMap<ClusterKey, (PageNum, i64, u64)>,
    /// Per-(cluster, PC-bucket) accuracy attribution.
    postmortem: Postmortem,
}

impl DlPrefetcher {
    pub fn new(engine: PredictorEngine, rcfg: &RuntimeConfig) -> Self {
        let history_len = engine.vocab.history_len.max(1);
        Self {
            engine,
            cluster_by: ClusterBy::SmWarp,
            history: HistoryTable::new(history_len),
            last_window: HashMap::new(),
            last_bb: HashMap::new(),
            batcher: Batcher::new(rcfg.batch_size, rcfg.batch_flush_cycles),
            finetune: FinetuneScheduler::new(
                rcfg.finetune_interval_insts,
                rcfg.finetune_batch,
                rcfg.finetune_batch * 4,
            ),
            latency: rcfg.prediction_latency_cycles,
            bypass_mode: rcfg.bypass,
            bypass_convergence: rcfg.bypass_convergence,
            pressure_threshold: rcfg.pressure_threshold,
            matured: Vec::new(),
            telemetry: PrefetchTelemetry::default(),
            finetune_losses: Vec::new(),
            telemetry_on: false,
            batch_events: Vec::new(),
            last_pred: HashMap::new(),
            postmortem: Postmortem::default(),
        }
    }

    pub fn with_cluster_by(mut self, by: ClusterBy) -> Self {
        self.cluster_by = by;
        self
    }

    pub fn finetune_losses(&self) -> &[f64] {
        &self.finetune_losses
    }

    /// Run inference on a flushed batch; stamp results with the
    /// prediction latency.
    fn run_batch(&mut self, batch: Vec<PendingRequest>, now: Cycle) {
        let windows: Vec<Window> = batch.iter().map(|r| r.window.clone()).collect();
        let preds = self.engine.predict(&windows);
        self.telemetry.prediction_batches += 1;
        self.telemetry.predictions += preds.len() as u64;
        let ready = now + self.latency;
        // Batch lifecycle span (telemetry only): FIFO batcher → the
        // first request is the oldest enqueue.
        let enqueued_at = batch.first().map(|r| r.enqueued_at).unwrap_or(now);
        let size = batch.len() as u32;
        let mut oov = 0u32;
        for (pred, req) in preds.into_iter().zip(batch) {
            match pred {
                Prediction::Delta(d) => {
                    if self.telemetry_on {
                        self.last_pred.insert(
                            ClusterKey(req.cluster),
                            (req.anchor_page, d, pc_bucket(req.pc)),
                        );
                    }
                    let target = req.anchor_page as i64 + d;
                    if target >= 0 && d != 0 {
                        self.matured.push(PrefetchRequest::at(target as PageNum, ready));
                    }
                }
                Prediction::Oov => {
                    self.telemetry.oov_predictions += 1;
                    oov += 1;
                    if self.telemetry_on {
                        self.postmortem.record_oov(req.cluster, pc_bucket(req.pc));
                    }
                }
            }
        }
        if self.telemetry_on && self.batch_events.len() < BATCH_EVENT_CAP {
            self.batch_events.push(BatchEvent {
                enqueued_at,
                run_at: now,
                ready_at: ready,
                size,
                oov,
            });
        }
    }
}

impl Prefetcher for DlPrefetcher {
    fn name(&self) -> &'static str {
        "dl"
    }

    /// Every GMMU access extends the cluster history — the paper's
    /// predictor is trained on (and windows over) the full access
    /// stream, not just the fault stream (Figure 3 carries a Hit/Miss
    /// feature precisely because hits are part of the sequence).
    fn on_access(
        &mut self,
        origin: crate::types::AccessOrigin,
        pc: u64,
        page: PageNum,
        _hit: bool,
        now: Cycle,
    ) {
        let key = self.cluster_by.key(&origin, pc);
        // Telemetry post-mortem: this access is the cluster's ground
        // truth for its outstanding top-1 prediction. The anchor's own
        // fault access is skipped (a prediction is about the *next*
        // access); the realized delta is measured from the anchor, the
        // same frame the predicted delta was expressed in.
        if self.telemetry_on {
            if let Some(&(anchor, d, pcb)) = self.last_pred.get(&key) {
                if page != anchor {
                    self.last_pred.remove(&key);
                    let realized = page as i64 - anchor as i64;
                    self.postmortem.record(key.0, pcb, realized == d);
                }
            }
        }
        // Harvest the ground-truth label for the cluster's previous
        // full window *before* pushing the new token.
        let tok = self.history.push(key, pc, page, now);
        if let Some(tok) = tok {
            if self.finetune.enabled() {
                if let Some(prev) = self.last_window.remove(&key) {
                    let label = self.engine.vocab.encode_delta(tok.delta) as i32;
                    self.finetune.record(prev, label);
                }
                if let Some(window_toks) =
                    self.history.get_mut(&key).and_then(|c| c.full_window())
                {
                    let window = featurize_window(&self.engine.vocab, window_toks);
                    self.last_window.insert(key, window);
                }
            }
        }
    }

    fn on_fault_into(&mut self, fault: &FaultInfo, out: &mut PrefetchDecision) {
        let key = self.cluster_by.key(&fault.origin, fault.pc);

        // Floor behaviour: migrate the faulting basic block (§4 — "we
        // keep prefetching its basic block, the same as the
        // tree-based"); at most 15 + 1 extra pages per fault.
        // The predictor sits on the fault-service path (§7.1: "our
        // revised predictor is situated at the UVM backend"): the
        // runtime's prefetch decision — block *and* predicted page —
        // is made after inference completes, so every prefetch this
        // fault triggers is delayed by the prediction overhead. This
        // is what makes the policy latency-sensitive (Fig. 10: 1.10×
        // at 1 µs decaying to 0.90× at 10 µs); only the demanded page
        // itself rides the hardware fault path unaffected.
        let decision_at = fault.service_at + self.latency;
        let bb = bb_base(fault.page);
        let prev_bb = self.last_bb.insert(key, bb);
        let under_pressure = fault.mem.above(self.pressure_threshold);
        // Near capacity every speculative page evicts a live one, so
        // the block floor shrinks to the faulted quarter block; the
        // top-1 predicted page below still issues at full priority.
        let (lo, hi) = if under_pressure {
            let q = fault.page & !(THROTTLED_SPAN - 1);
            (q, q + THROTTLED_SPAN)
        } else {
            (bb, bb + PAGES_PER_BB)
        };
        out.requests.extend(
            (lo..hi).filter(|&p| p != fault.page).map(|p| PrefetchRequest::at(p, decision_at)),
        );

        // Predicted-dead block: once a converged forward-streaming
        // cluster advances to a new basic block under pressure, the
        // block it just left is dead weight — hand it back lazily so
        // the next admissions reclaim free frames instead of evicting
        // live pages. Unpressured runs emit nothing (the ratio-1.0
        // byte-identity anchor).
        if let Some(prev) = prev_bb {
            if under_pressure && prev < bb {
                let streaming = self
                    .history
                    .get(&key)
                    .and_then(|c| c.dominant_delta())
                    .is_some_and(|(d, conv)| d > 0 && conv >= DISCARD_CONVERGENCE);
                if streaming {
                    out.discards.extend(
                        (prev..prev + PAGES_PER_BB)
                            .filter(|&pg| pg != fault.page)
                            .map(|pg| DiscardRequest { page: pg, lazy: true }),
                    );
                }
            }
        }

        // Top-1 prediction for the +1 page, over the cluster's access
        // history window (the fault itself enters the history via the
        // engine's subsequent on_access call).
        let Some(cluster) = self.history.get_mut(&key) else {
            return;
        };
        if let Some(window_toks) = cluster.full_window() {
            let window = featurize_window(&self.engine.vocab, window_toks);
            let cluster = self.history.get(&key).expect("present");
            let bypass = match self.bypass_mode {
                BypassMode::Always => true,
                BypassMode::Never => false,
                BypassMode::Auto => cluster
                    .dominant_delta()
                    .map(|(_, conv)| conv >= self.bypass_convergence)
                    .unwrap_or(false),
            };
            if bypass {
                // Attention-free path: the decision is an order of
                // magnitude cheaper (§5.4 — attention dominates cost).
                if let Some((d, _)) = cluster.dominant_delta() {
                    let target = fault.page as i64 + d;
                    if target >= 0 && d != 0 {
                        self.telemetry.bypass_predictions += 1;
                        if self.telemetry_on {
                            self.last_pred.insert(key, (fault.page, d, pc_bucket(fault.pc)));
                        }
                        out.requests.push(PrefetchRequest::at(
                            target as PageNum,
                            fault.service_at + self.latency / BYPASS_LATENCY_DIV,
                        ));
                    }
                }
            } else {
                let full = self.batcher.push(PendingRequest {
                    window,
                    anchor_page: fault.page,
                    enqueued_at: fault.now,
                    cluster: key.0,
                    pc: fault.pc,
                });
                if let Some(batch) = full {
                    self.run_batch(batch, fault.now);
                }
            }
        }
    }

    fn drain_into(&mut self, now: Cycle, out: &mut Vec<PrefetchRequest>) {
        if let Some(batch) = self.batcher.poll(now) {
            self.run_batch(batch, now);
        }
        out.append(&mut self.matured);
    }

    fn on_retired(&mut self, instructions: u64) {
        if let Some(batch) = self.finetune.due(instructions) {
            if let Some(loss) = self.engine.finetune(&batch) {
                self.finetune_losses.push(loss);
            }
            self.telemetry.finetune_rounds = self.finetune.rounds;
        }
    }

    fn finish(&mut self, now: Cycle) {
        if let Some(batch) = self.batcher.flush() {
            self.run_batch(batch, now);
        }
    }

    fn telemetry(&self) -> PrefetchTelemetry {
        self.telemetry.clone()
    }

    fn set_telemetry_enabled(&mut self, on: bool) {
        self.telemetry_on = on;
    }

    fn take_batch_events(&mut self) -> Vec<BatchEvent> {
        std::mem::take(&mut self.batch_events)
    }

    fn take_postmortem(&mut self) -> Option<Postmortem> {
        if self.postmortem.is_empty() {
            None
        } else {
            Some(std::mem::take(&mut self.postmortem))
        }
    }
}

/// Construct a DL prefetcher over the pure-Rust stride backend (no
/// artifacts required) — the degraded mode and the test double.
pub fn dl_with_stride_backend(rcfg: &RuntimeConfig, deltas: Vec<i64>) -> DlPrefetcher {
    use crate::predictor::{DeltaVocab, StrideBackend};
    let vocab = DeltaVocab::synthetic(deltas, rcfg.history_len);
    let backend = StrideBackend::new(vocab.n_classes(), rcfg.history_len);
    DlPrefetcher::new(PredictorEngine::new(Box::new(backend), vocab), rcfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::{ConstantBackend, DeltaVocab, PredictorEngine};
    use crate::prefetch::MemPressure;
    use crate::types::AccessOrigin;

    fn origin() -> AccessOrigin {
        AccessOrigin { sm: 0, warp: 0, cta: 0, tpc: 0, kernel_id: 0 }
    }

    fn fault(page: PageNum, now: Cycle) -> FaultInfo {
        FaultInfo {
            now,
            service_at: now + 100,
            pc: 0x30,
            page,
            origin: origin(),
            array_id: 0,
            mem: MemPressure::unpressured(),
        }
    }

    fn small_cfg() -> RuntimeConfig {
        RuntimeConfig {
            history_len: 3,
            batch_size: 2,
            batch_flush_cycles: 500,
            prediction_latency_cycles: 1000,
            bypass: BypassMode::Never,
            ..Default::default()
        }
    }

    fn dl(cfg: &RuntimeConfig, class: u32, deltas: Vec<i64>) -> DlPrefetcher {
        let vocab = DeltaVocab::synthetic(deltas, cfg.history_len);
        let n = vocab.n_classes();
        DlPrefetcher::new(
            PredictorEngine::new(Box::new(ConstantBackend { class, n_classes: n }), vocab),
            cfg,
        )
    }

    /// Simulate the engine's event order for one faulting access:
    /// on_fault, then on_access.
    fn fault_access(p: &mut DlPrefetcher, page: PageNum, now: Cycle) -> PrefetchDecision {
        let d = p.on_fault(&fault(page, now));
        p.on_access(origin(), 0x30, page, false, now);
        d
    }

    fn hit_access(p: &mut DlPrefetcher, page: PageNum, now: Cycle) {
        p.on_access(origin(), 0x30, page, true, now);
    }

    #[test]
    fn always_prefetches_basic_block() {
        let cfg = small_cfg();
        let mut p = dl(&cfg, 0, vec![1]);
        let d = fault_access(&mut p, 5, 0);
        assert_eq!(d.requests.len(), 15, "the block minus the faulted page");
        assert!(d.requests.iter().all(|r| r.page < 16 && r.page != 5));
        // Block prefetches wait for the prediction decision:
        // service_at (100) + latency (1000).
        assert!(d.requests.iter().all(|r| r.earliest_start == 1100));
    }

    #[test]
    fn throttles_block_width_near_capacity() {
        let cfg = small_cfg(); // pressure_threshold default 0.85
        let mut p = dl(&cfg, 0, vec![1]);
        let mut f = fault(5, 0);
        f.mem = MemPressure::at(99, 100);
        let d = p.on_fault(&f);
        assert_eq!(d.requests.len(), 3, "quarter block minus the faulted page");
        assert!(d.requests.iter().all(|r| r.page >= 4 && r.page < 8 && r.page != 5));
    }

    #[test]
    fn discards_previous_block_under_pressure_when_streaming() {
        let cfg = small_cfg();
        let mut p = dl(&cfg, 0, vec![1]);
        // Converge the cluster on delta +1; unpressured faults never
        // emit discards (the ratio-1.0 byte-identity anchor).
        for i in 0..6u64 {
            let d = fault_access(&mut p, i, i * 10);
            assert!(d.discards.is_empty(), "no pressure, no discard");
        }
        // Cross into the next basic block under pressure: the block
        // just left (pages 0..16) is predicted dead — lazy discards.
        let mut f = fault(16, 100);
        f.mem = MemPressure::at(99, 100);
        let d = p.on_fault(&f);
        assert_eq!(d.discards.len(), 16, "{:?}", d.discards);
        assert!(d.discards.iter().all(|r| r.lazy && r.page < 16));
        // Same block again: no bb advance, no new discards.
        let mut f = fault(17, 110);
        f.mem = MemPressure::at(99, 100);
        assert!(p.on_fault(&f).discards.is_empty());
    }

    #[test]
    fn history_builds_from_hits_too() {
        let cfg = small_cfg();
        let mut p = dl(&cfg, 0, vec![7]); // always predicts delta 7
        // Three hits fill the 3-token history without any fault.
        for (i, page) in [0u64, 1, 2, 3].iter().enumerate() {
            hit_access(&mut p, *page, i as u64 * 10);
        }
        // Two faults now have full windows → fills the batch of 2.
        fault_access(&mut p, 4, 40);
        fault_access(&mut p, 5, 41);
        let drained = p.drain(41);
        let mut pages: Vec<u64> = drained.iter().map(|r| r.page).collect();
        pages.sort();
        assert_eq!(pages, vec![11, 12], "anchors 4 and 5, both +7");
        assert_eq!(p.telemetry().predictions, 2);
        assert_eq!(p.telemetry().prediction_batches, 1);
    }

    #[test]
    fn prediction_stamped_with_latency() {
        let cfg = small_cfg();
        let mut p = dl(&cfg, 0, vec![7]);
        for (i, page) in [0u64, 1, 2, 3].iter().enumerate() {
            fault_access(&mut p, *page, i as u64 * 10);
        }
        fault_access(&mut p, 4, 40);
        fault_access(&mut p, 5, 41); // fills the batch of 2 at t=41
        let drained = p.drain(41);
        assert_eq!(drained.len(), 2);
        assert!(drained.iter().all(|r| r.earliest_start == 41 + 1000), "{drained:?}");
    }

    #[test]
    fn aged_partial_batch_flushes_on_drain() {
        let cfg = small_cfg();
        let mut p = dl(&cfg, 0, vec![2]);
        for (i, page) in [0u64, 1, 2, 3].iter().enumerate() {
            fault_access(&mut p, *page, i as u64);
        }
        fault_access(&mut p, 4, 4);
        assert!(p.drain(5).is_empty(), "batch not full, not aged");
        let drained = p.drain(5 + 600);
        assert_eq!(drained.len(), 1, "aged partial flushed");
        assert_eq!(drained[0].page, 4 + 2);
    }

    #[test]
    fn oov_prediction_suppresses_extra_prefetch() {
        let cfg = small_cfg();
        // Class 1 = OOV for a single-delta vocab.
        let mut p = dl(&cfg, 1, vec![5]);
        for (i, page) in [0u64, 1, 2, 3, 4, 5].iter().enumerate() {
            fault_access(&mut p, *page, i as u64);
        }
        let drained = p.drain(1_000);
        assert!(drained.is_empty(), "OOV → no prediction prefetch");
        assert!(p.telemetry().oov_predictions >= 2);
    }

    #[test]
    fn bypass_emits_dominant_delta_with_cheap_latency() {
        let mut cfg = small_cfg();
        cfg.bypass = BypassMode::Auto;
        cfg.bypass_convergence = 0.9;
        let mut p = dl(&cfg, 0, vec![1]);
        for i in 0..6u64 {
            fault_access(&mut p, i, i * 10);
        }
        assert!(p.telemetry().bypass_predictions >= 1);
        let d = p.on_fault(&fault(100, 100));
        // service_at (200) + latency/10 (100).
        let pred = d.requests.iter().find(|r| r.page == 101 && r.earliest_start == 300);
        assert!(pred.is_some(), "bypass prediction at service + latency/10: {:?}", d.requests);
    }

    #[test]
    fn finish_flushes_outstanding_batch() {
        let cfg = small_cfg();
        let mut p = dl(&cfg, 0, vec![3]);
        for (i, page) in [0u64, 1, 2, 3].iter().enumerate() {
            fault_access(&mut p, *page, i as u64);
        }
        fault_access(&mut p, 9, 40);
        p.finish(50);
        let drained = p.drain(50);
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].page, 12);
    }

    #[test]
    fn postmortem_attributes_predictions_when_armed() {
        let cfg = small_cfg(); // batch of 2, history 3
        let mut p = dl(&cfg, 0, vec![7]); // always predicts delta +7
        p.set_telemetry_enabled(true);
        for (i, page) in [0u64, 1, 2, 3].iter().enumerate() {
            hit_access(&mut p, *page, i as u64 * 10);
        }
        fault_access(&mut p, 4, 40);
        // Fills the batch: run_batch records the outstanding prediction
        // (anchor 5, delta +7); the anchor's own fault access must NOT
        // resolve it.
        fault_access(&mut p, 5, 41);
        assert!(p.take_postmortem().is_none(), "anchor access is not ground truth");
        // The cluster's next access (12 = 5 + 7) resolves it: correct.
        hit_access(&mut p, 12, 50);
        let pm = p.take_postmortem().expect("one resolved prediction");
        let cell = pm.cells[&(0, 0x30)]; // SmWarp key 0, pc bucket 0x30
        assert_eq!((cell.predictions, cell.correct, cell.oov), (1, 1, 0));
        let evs = p.take_batch_events();
        assert_eq!(evs.len(), 1);
        assert_eq!((evs[0].enqueued_at, evs[0].run_at, evs[0].ready_at), (40, 41, 1041));
        assert_eq!((evs[0].size, evs[0].oov), (2, 0));
        assert!(p.take_batch_events().is_empty(), "drained");
    }

    #[test]
    fn postmortem_stays_empty_when_disarmed() {
        let cfg = small_cfg();
        let mut p = dl(&cfg, 0, vec![7]);
        for (i, page) in [0u64, 1, 2, 3].iter().enumerate() {
            hit_access(&mut p, *page, i as u64 * 10);
        }
        fault_access(&mut p, 4, 40);
        fault_access(&mut p, 5, 41);
        hit_access(&mut p, 12, 50);
        assert!(p.take_postmortem().is_none());
        assert!(p.take_batch_events().is_empty());
        assert!(p.last_pred.is_empty(), "no tracking state accrues when off");
    }

    #[test]
    fn finetune_labels_harvested_from_access_stream() {
        let mut cfg = small_cfg();
        cfg.finetune_interval_insts = 100;
        cfg.finetune_batch = 2;
        let mut p = dl(&cfg, 0, vec![1, 2]);
        for i in 0..10u64 {
            hit_access(&mut p, i, i);
        }
        // Labels exist; the stride backend does not implement
        // finetune, so rounds trigger but no loss is recorded.
        p.on_retired(100);
        assert!(p.finetune_losses().is_empty());
    }
}
