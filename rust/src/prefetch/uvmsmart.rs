//! The UVMSmart baseline "U" (Ganguly et al., DATE'21 — the paper's
//! state-of-the-art comparator, §7.1).
//!
//! UVMSmart's runtime combines (1) a detection engine over interconnect
//! traffic, (2) a dynamic policy engine, and (3) adaptive switching
//! between delayed page migration (soft pinning) and remote zero-copy
//! pinning. Under the paper's evaluation regime — **no memory
//! oversubscription** — the adaptive machinery idles and the active
//! data-movement policy is the tree-based neighborhood prefetcher;
//! that is exactly what the paper's "U" rows measure.
//!
//! We therefore implement "U" as the tree prefetcher plus the
//! delayed-migration hook: when the device is under memory pressure —
//! judged from the *true* occupancy signal the simulator threads
//! through every [`FaultInfo`] — the policy suppresses tree
//! *promotions* and falls back to basic-block-only prefetching:
//! UVMSmart's "switch to conservative policy on thrash detection"
//! behaviour, exercised by `repro eval oversub`.

use super::tree::{retain_basic_block, TreePrefetcher};
use super::{FaultInfo, PrefetchDecision, Prefetcher};
use crate::types::PageNum;

#[derive(Debug)]
pub struct UvmSmartPrefetcher {
    tree: TreePrefetcher,
    /// Above this occupancy fraction, suppress tree promotion.
    pressure_threshold: f64,
    /// Evictions observed in the current window (thrash detector).
    recent_evictions: u64,
    pub promotions_suppressed: u64,
}

impl UvmSmartPrefetcher {
    pub fn new(tree_threshold: f64, pressure_threshold: f64) -> Self {
        Self {
            tree: TreePrefetcher::new(tree_threshold),
            pressure_threshold,
            recent_evictions: 0,
            promotions_suppressed: 0,
        }
    }
}

impl Prefetcher for UvmSmartPrefetcher {
    fn name(&self) -> &'static str {
        "uvmsmart"
    }

    fn on_fault_into(&mut self, fault: &FaultInfo, out: &mut PrefetchDecision) {
        self.tree.on_fault_into(fault, out);
        if fault.mem.above(self.pressure_threshold) || self.recent_evictions > 0 {
            // Conservative mode: keep only the faulted basic block.
            // The buffer arrives empty (trait contract), so the retain
            // filters exactly what the tree just appended.
            self.promotions_suppressed += retain_basic_block(&mut out.requests, fault.page);
        }
    }

    fn on_evict(&mut self, page: PageNum) {
        self.tree.on_evict(page);
        self.recent_evictions += 1;
    }

    fn on_access(&mut self, _o: crate::types::AccessOrigin, _pc: u64, _p: PageNum, hit: bool, _now: u64) {
        // Decay the thrash detector on quiet (all-hit) traffic.
        if hit && self.recent_evictions > 0 {
            self.recent_evictions -= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prefetch::MemPressure;
    use crate::types::AccessOrigin;

    fn fault(page: PageNum, mem: MemPressure) -> FaultInfo {
        FaultInfo {
            now: 0,
            service_at: 10,
            pc: 0,
            page,
            origin: AccessOrigin { sm: 0, warp: 0, cta: 0, tpc: 0, kernel_id: 0 },
            array_id: 0,
            mem,
        }
    }

    #[test]
    fn behaves_like_tree_when_unpressured() {
        let mut u = UvmSmartPrefetcher::new(0.5, 0.85);
        let d = u.on_fault(&fault(5, MemPressure::unpressured()));
        assert_eq!(d.requests.len(), 16, "whole basic block, like the tree");
        assert_eq!(u.promotions_suppressed, 0);
    }

    #[test]
    fn suppresses_promotion_under_occupancy_pressure() {
        let mut u = UvmSmartPrefetcher::new(0.5, 0.85);
        let hot = MemPressure::at(95, 100);
        u.on_fault(&fault(5, hot)); // bb 0
        u.on_fault(&fault(40, hot)); // bb 2
        // Unpressured this fault would also promote [48, 64).
        let d = u.on_fault(&fault(17, hot));
        assert_eq!(d.requests.len(), 16, "basic block only");
        assert!(d.requests.iter().all(|r| r.page >= 16 && r.page < 32));
        assert_eq!(u.promotions_suppressed, 16);
    }

    #[test]
    fn thrash_detector_suppresses_and_decays_on_hits() {
        let mut u = UvmSmartPrefetcher::new(0.5, 0.85);
        let quiet = MemPressure::unpressured();
        u.on_fault(&fault(5, quiet));
        u.on_fault(&fault(40, quiet));
        u.on_evict(100); // page 100's bit is unset — pure thrash signal
        let d = u.on_fault(&fault(17, quiet));
        assert_eq!(d.requests.len(), 16, "eviction marks thrash: block only");
        assert_eq!(u.promotions_suppressed, 16);
        let origin = AccessOrigin { sm: 0, warp: 0, cta: 0, tpc: 0, kernel_id: 0 };
        u.on_access(origin, 0, 3, true, 0);
        assert_eq!(u.recent_evictions, 0, "decayed after quiet traffic");
    }
}
