//! The UVMSmart baseline "U" (Ganguly et al., DATE'21 — the paper's
//! state-of-the-art comparator, §7.1).
//!
//! UVMSmart's runtime combines (1) a detection engine over interconnect
//! traffic, (2) a dynamic policy engine, and (3) adaptive switching
//! between delayed page migration (soft pinning) and remote zero-copy
//! pinning. Under the paper's evaluation regime — **no memory
//! oversubscription** — the adaptive machinery idles and the active
//! data-movement policy is the tree-based neighborhood prefetcher;
//! that is exactly what the paper's "U" rows measure.
//!
//! We therefore implement "U" as the tree prefetcher plus the
//! delayed-migration hook: when the device is under memory pressure
//! (occupancy above `pressure_threshold`), the policy suppresses tree
//! *promotions* and falls back to basic-block-only prefetching —
//! UVMSmart's "switch to conservative policy on thrash detection"
//! behaviour, exercised by the oversubscription example.

use super::tree::TreePrefetcher;
use super::{FaultInfo, PrefetchDecision, Prefetcher, PrefetchRequest};
use crate::types::{bb_base, PageNum, PAGES_PER_BB};

#[derive(Debug)]
pub struct UvmSmartPrefetcher {
    tree: TreePrefetcher,
    /// Pages currently believed resident (tracked from our own
    /// requests + faults − evictions) to estimate pressure.
    resident_estimate: i64,
    capacity_pages: i64,
    /// Above this occupancy fraction, suppress tree promotion.
    pressure_threshold: f64,
    /// Evictions observed in the current window (thrash detector).
    recent_evictions: u64,
    pub promotions_suppressed: u64,
}

impl UvmSmartPrefetcher {
    pub fn new(tree_threshold: f64, capacity_pages: u64, pressure_threshold: f64) -> Self {
        Self {
            tree: TreePrefetcher::new(tree_threshold),
            resident_estimate: 0,
            capacity_pages: capacity_pages as i64,
            pressure_threshold,
            recent_evictions: 0,
            promotions_suppressed: 0,
        }
    }

    fn under_pressure(&self) -> bool {
        self.resident_estimate as f64 >= self.pressure_threshold * self.capacity_pages as f64
            || self.recent_evictions > 0
    }
}

impl Prefetcher for UvmSmartPrefetcher {
    fn name(&self) -> &'static str {
        "uvmsmart"
    }

    fn on_fault(&mut self, fault: &FaultInfo) -> PrefetchDecision {
        let mut decision = self.tree.on_fault(fault);
        self.resident_estimate += 1; // demand page
        if self.under_pressure() {
            // Conservative mode: keep only the faulted basic block.
            let bb = bb_base(fault.page);
            let before = decision.requests.len();
            decision
                .requests
                .retain(|r: &PrefetchRequest| r.page >= bb && r.page < bb + PAGES_PER_BB);
            self.promotions_suppressed += (before - decision.requests.len()) as u64;
        }
        self.resident_estimate += decision.requests.len() as i64;
        decision
    }

    fn on_evict(&mut self, page: PageNum) {
        self.tree.on_evict(page);
        self.resident_estimate -= 1;
        self.recent_evictions += 1;
    }

    fn on_access(&mut self, _o: crate::types::AccessOrigin, _pc: u64, _p: PageNum, hit: bool, _now: u64) {
        // Decay the thrash detector on quiet (all-hit) traffic.
        if hit && self.recent_evictions > 0 {
            self.recent_evictions -= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::AccessOrigin;

    fn fault(page: PageNum) -> FaultInfo {
        FaultInfo {
            now: 0,
            service_at: 10,
            pc: 0,
            page,
            origin: AccessOrigin { sm: 0, warp: 0, cta: 0, tpc: 0, kernel_id: 0 },
            array_id: 0,
        }
    }

    #[test]
    fn behaves_like_tree_when_unpressured() {
        let mut u = UvmSmartPrefetcher::new(0.5, 1_000_000, 0.8);
        let d = u.on_fault(&fault(5));
        assert_eq!(d.requests.len(), 16, "whole basic block, like the tree");
        assert_eq!(u.promotions_suppressed, 0);
    }

    #[test]
    fn suppresses_promotion_under_pressure() {
        // Tiny capacity: pressure hits immediately.
        let mut u = UvmSmartPrefetcher::new(0.5, 16, 0.5);
        u.on_fault(&fault(0)); // fills estimate to 17 ≥ 0.5*16
        let d = u.on_fault(&fault(40)); // bb 2
        assert!(d.requests.len() <= 16, "no promotion beyond the block");
        // All requests stay within the faulted basic block.
        assert!(d.requests.iter().all(|r| r.page >= 32 && r.page < 48));
    }

    #[test]
    fn eviction_marks_thrash_and_decays_on_hits() {
        let mut u = UvmSmartPrefetcher::new(0.5, 1_000_000, 0.99);
        u.on_evict(3);
        assert!(u.under_pressure());
        let origin = AccessOrigin { sm: 0, warp: 0, cta: 0, tpc: 0, kernel_id: 0 };
        u.on_access(origin, 0, 3, true, 0);
        assert!(!u.under_pressure(), "decayed after quiet traffic");
    }
}
