//! Configuration system: simulator (Table 9), workload scaling, and
//! runtime/predictor knobs. Configs serialize to JSON via the in-tree
//! [`crate::util::Json`] module (see `configs/` and the
//! `repro simulate --config` flag); every field has a default so
//! partial config files work.

mod runtime_config;
mod sim_config;

pub use runtime_config::{BypassMode, PredictorBackendKind, RuntimeConfig};
pub use sim_config::SimConfig;

use crate::util::Json;
use anyhow::Result;

/// Top-level experiment description: one simulated benchmark run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub sim: SimConfig,
    pub runtime: RuntimeConfig,
    /// Benchmark name: any name registered in
    /// [`crate::workloads::WorkloadRegistry`] — the built-in dense and
    /// irregular generators, or an ingested `trace:<name>` workload
    /// when a trace directory is supplied.
    pub benchmark: String,
    /// Stop after this many simulated instructions (0 = run the
    /// workload to completion).
    pub max_instructions: u64,
    /// RNG seed for the workload's input-dependent components.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            sim: SimConfig::default(),
            runtime: RuntimeConfig::default(),
            benchmark: "addvectors".to_string(),
            max_instructions: 2_000_000,
            seed: 0x5eed,
        }
    }
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("benchmark", Json::str(&self.benchmark)),
            ("max_instructions", Json::Num(self.max_instructions as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("sim", self.sim.to_json()),
            ("runtime", self.runtime.to_json()),
        ])
    }

    /// Build from JSON; missing fields keep their defaults.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(b) = j.get("benchmark").and_then(Json::as_str) {
            cfg.benchmark = b.to_string();
        }
        if let Some(v) = j.get("max_instructions").and_then(Json::as_u64) {
            cfg.max_instructions = v;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_u64) {
            cfg.seed = v;
        }
        if let Some(s) = j.get("sim") {
            cfg.sim = SimConfig::from_json(s)?;
        }
        if let Some(r) = j.get("runtime") {
            cfg.runtime = RuntimeConfig::from_json(r)?;
        }
        Ok(cfg)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_through_json() {
        let cfg = ExperimentConfig::default();
        let text = cfg.to_json().to_string();
        let back = ExperimentConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.benchmark, cfg.benchmark);
        assert_eq!(back.sim.n_sms, cfg.sim.n_sms);
        assert_eq!(back.runtime.prediction_latency_cycles, cfg.runtime.prediction_latency_cycles);
        assert_eq!(back.runtime.backend, cfg.runtime.backend);
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let j = Json::parse(r#"{"benchmark":"nw","sim":{"n_sms":4}}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.benchmark, "nw");
        assert_eq!(cfg.sim.n_sms, 4);
        assert_eq!(cfg.sim.warps_per_sm, 64, "untouched field keeps default");
    }
}
