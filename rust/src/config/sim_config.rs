//! Simulator parameters — a direct transcription of the paper's
//! Table 9 (GPGPU-Sim UVMSmart configuration, GTX 1080Ti Pascal-like).

use crate::util::Json;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Streaming multiprocessors (Table 9: 28 SMs @ 1481 MHz).
    pub n_sms: u16,
    /// Warp contexts per SM (Table 9: max 64 warps per SM).
    pub warps_per_sm: u16,
    /// Threads per warp (Table 9: 32).
    pub threads_per_warp: u16,
    /// Core clock in MHz — used to convert the µs-denominated
    /// latencies (far fault, prediction overhead) into cycles.
    pub clock_mhz: u64,
    /// GMMU page-table-walk latency in core cycles (Table 9: 100).
    pub page_walk_cycles: u64,
    /// Device DRAM access latency in core cycles (Table 9: 100).
    pub dram_cycles: u64,
    /// Remote zero-copy access latency in core cycles (Table 9: 200).
    pub zero_copy_cycles: u64,
    /// Far-fault handling latency in microseconds (Table 9: 45 µs) —
    /// covers host interrupt, host page-table walk and fault service
    /// setup, before the page transfer itself starts.
    pub far_fault_us: f64,
    /// CPU-GPU interconnect one-way bandwidth in GB/s.
    /// Table 9: PCIe 3.0 x16, 8 GT/s/lane ⇒ ~15.75 GB/s effective.
    pub pcie_gbps: f64,
    /// Interconnect propagation latency in core cycles (Table 9: 100).
    pub pcie_latency_cycles: u64,
    /// Device memory capacity in bytes. Paper §7.1 evaluates with
    /// "device memory size larger than the benchmarks' working set";
    /// the default (1 GiB simulated) keeps us un-oversubscribed for
    /// every scaled workload. The oversubscription example shrinks it.
    pub device_mem_bytes: u64,
    /// Last-level GMMU TLB entries per SM (page-granularity, LRU).
    pub tlb_entries: usize,
    /// PCIe usage histogram bucket width in cycles (Figure 11 series).
    pub pcie_bucket_cycles: u64,
    /// Oversubscription as *resident fraction of the workload
    /// footprint*: 1.0 (default) disables it and keeps
    /// `device_mem_bytes`; r < 1.0 caps device capacity to
    /// `ceil(r × footprint_pages)` frames, resolved by the simulator
    /// once the generated workload is in hand. Valid domain (0, 1].
    pub oversub_ratio: f64,
    /// Victim-selection policy under memory pressure — one of
    /// [`crate::sim::eviction::ALL_EVICTION_POLICIES`]
    /// ("lru" | "random" | "freq" | "prefetch-aware" | "learned").
    pub eviction_policy: String,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            n_sms: 28,
            warps_per_sm: 64,
            threads_per_warp: 32,
            clock_mhz: 1481,
            page_walk_cycles: 100,
            dram_cycles: 100,
            zero_copy_cycles: 200,
            far_fault_us: 45.0,
            pcie_gbps: 15.75,
            pcie_latency_cycles: 100,
            device_mem_bytes: 1 << 30,
            tlb_entries: 64,
            pcie_bucket_cycles: 10_000,
            oversub_ratio: 1.0,
            eviction_policy: "lru".to_string(),
        }
    }
}

impl SimConfig {
    /// Far-fault latency in core cycles (45 µs @ 1481 MHz ≈ 66 645).
    pub fn far_fault_cycles(&self) -> u64 {
        (self.far_fault_us * self.clock_mhz as f64).round() as u64
    }

    /// Interconnect bandwidth in bytes per core cycle
    /// (15.75 GB/s @ 1481 MHz ≈ 10.63 B/cycle).
    pub fn pcie_bytes_per_cycle(&self) -> f64 {
        self.pcie_gbps * 1e9 / (self.clock_mhz as f64 * 1e6)
    }

    /// Convert microseconds to core cycles.
    pub fn us_to_cycles(&self, us: f64) -> u64 {
        (us * self.clock_mhz as f64).round() as u64
    }

    /// Device memory capacity in 4 KB page frames.
    pub fn device_mem_pages(&self) -> u64 {
        self.device_mem_bytes / crate::types::PAGE_SIZE
    }

    /// Device capacity in page frames for a workload with the given
    /// footprint: `oversub_ratio` < 1.0 caps residency to that
    /// fraction of the footprint; 1.0 keeps the configured capacity
    /// (the baseline regime — byte-identical to a plain run). The
    /// footprint fraction is additionally clamped to the configured
    /// device size, so a ratio just below 1.0 can never grant *more*
    /// frames than its own baseline when the footprint exceeds device
    /// memory.
    pub fn effective_capacity_pages(&self, footprint_pages: u64) -> u64 {
        if self.oversub_ratio >= 1.0 {
            self.device_mem_pages()
        } else {
            ((footprint_pages as f64 * self.oversub_ratio).ceil() as u64)
                .min(self.device_mem_pages())
                .max(1)
        }
    }

    /// Reject configs the simulator cannot honour: `oversub_ratio`
    /// outside (0, 1] (the flag is a resident *fraction*, not a
    /// multiplier) or an unknown eviction-policy name.
    pub fn validate(&self) -> Result<()> {
        if !(self.oversub_ratio > 0.0 && self.oversub_ratio <= 1.0) {
            anyhow::bail!(
                "oversub_ratio must be in (0, 1] — it is the resident fraction of the \
                 workload footprint (1.0 = no oversubscription); got {}",
                self.oversub_ratio
            );
        }
        crate::sim::eviction::build(&self.eviction_policy, 0)?;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_sms", Json::Num(self.n_sms as f64)),
            ("warps_per_sm", Json::Num(self.warps_per_sm as f64)),
            ("threads_per_warp", Json::Num(self.threads_per_warp as f64)),
            ("clock_mhz", Json::Num(self.clock_mhz as f64)),
            ("page_walk_cycles", Json::Num(self.page_walk_cycles as f64)),
            ("dram_cycles", Json::Num(self.dram_cycles as f64)),
            ("zero_copy_cycles", Json::Num(self.zero_copy_cycles as f64)),
            ("far_fault_us", Json::Num(self.far_fault_us)),
            ("pcie_gbps", Json::Num(self.pcie_gbps)),
            ("pcie_latency_cycles", Json::Num(self.pcie_latency_cycles as f64)),
            ("device_mem_bytes", Json::Num(self.device_mem_bytes as f64)),
            ("tlb_entries", Json::Num(self.tlb_entries as f64)),
            ("pcie_bucket_cycles", Json::Num(self.pcie_bucket_cycles as f64)),
            ("oversub_ratio", Json::Num(self.oversub_ratio)),
            ("eviction_policy", Json::str(&self.eviction_policy)),
        ])
    }

    /// Missing fields keep their defaults.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = Self::default();
        macro_rules! num {
            ($field:ident, $ty:ty) => {
                if let Some(v) = j.get(stringify!($field)).and_then(Json::as_f64) {
                    c.$field = v as $ty;
                }
            };
        }
        num!(n_sms, u16);
        num!(warps_per_sm, u16);
        num!(threads_per_warp, u16);
        num!(clock_mhz, u64);
        num!(page_walk_cycles, u64);
        num!(dram_cycles, u64);
        num!(zero_copy_cycles, u64);
        num!(far_fault_us, f64);
        num!(pcie_gbps, f64);
        num!(pcie_latency_cycles, u64);
        num!(device_mem_bytes, u64);
        num!(tlb_entries, usize);
        num!(pcie_bucket_cycles, u64);
        num!(oversub_ratio, f64);
        if let Some(s) = j.get("eviction_policy").and_then(Json::as_str) {
            c.eviction_policy = s.to_string();
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table9_derived_constants() {
        let c = SimConfig::default();
        // 45 µs at 1481 MHz.
        assert_eq!(c.far_fault_cycles(), 66_645);
        // ~10.6 bytes/cycle over PCIe 3.0 x16.
        let bpc = c.pcie_bytes_per_cycle();
        assert!((bpc - 10.63).abs() < 0.05, "bpc = {bpc}");
        // 1 µs prediction overhead ≈ the paper's "1500 cycles".
        assert_eq!(c.us_to_cycles(1.0), 1481);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = SimConfig::default();
        c.n_sms = 4;
        c.pcie_gbps = 31.5;
        c.oversub_ratio = 0.5;
        c.eviction_policy = "freq".to_string();
        let back = SimConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.n_sms, 4);
        assert!((back.pcie_gbps - 31.5).abs() < 1e-12);
        assert_eq!(back.tlb_entries, 64);
        assert!((back.oversub_ratio - 0.5).abs() < 1e-12);
        assert_eq!(back.eviction_policy, "freq");
    }

    #[test]
    fn oversub_validation_and_capacity_resolution() {
        let mut c = SimConfig::default();
        assert!(c.validate().is_ok(), "defaults are valid");
        assert_eq!(c.effective_capacity_pages(10_000), c.device_mem_pages(), "1.0 = baseline");
        c.oversub_ratio = 0.5;
        assert_eq!(c.effective_capacity_pages(10_000), 5_000);
        assert_eq!(c.effective_capacity_pages(1), 1, "capacity floor of one frame");
        // Footprint beyond device memory: the fraction clamps to the
        // device size instead of exceeding the ratio-1.0 baseline.
        c.oversub_ratio = 0.75;
        assert_eq!(
            c.effective_capacity_pages(600_000),
            c.device_mem_pages(),
            "capacity never exceeds the configured device size"
        );
        for bad in [0.0, -0.25, 1.5, f64::NAN] {
            c.oversub_ratio = bad;
            assert!(c.validate().is_err(), "ratio {bad} must be rejected");
        }
        c.oversub_ratio = 0.5;
        c.eviction_policy = "bogus".to_string();
        assert!(c.validate().is_err(), "unknown eviction policy rejected");
    }
}
