//! Runtime / prefetcher configuration — which prefetching policy is
//! active and how the learned predictor is deployed (paper §6, §7.1,
//! §7.3).

use crate::predictor::kernel::Precision;
use crate::util::Json;
use anyhow::Result;

/// Which backend produces page-delta predictions for the DL prefetcher.
#[derive(Debug, Clone, PartialEq)]
pub enum PredictorBackendKind {
    /// AOT-compiled JAX model executed through PJRT (`artifacts/`).
    Pjrt {
        /// Directory holding `manifest.json`, `*.hlo.txt`,
        /// `*.params.bin`, `*.vocab.json`.
        artifacts: String,
        /// Model key in the manifest ("shared" or a benchmark name).
        /// Empty ⇒ prefer the per-benchmark model, fall back to
        /// "shared" (the paper's pretrained-on-5-benchmarks corpus).
        model: String,
    },
    /// Pure-Rust learned backend (the paper's §6 revised model,
    /// trained offline by `repro train` — see DESIGN.md §6): embedding
    /// tables + FC stack loaded from a `*.native.params.bin` tensor
    /// store referenced by the artifacts manifest (`arch = "native"`).
    Native {
        /// Directory holding `manifest.json`, `*.native.params.bin`,
        /// `*.vocab.json`.
        artifacts: String,
        /// Model key in the manifest; empty ⇒ per-benchmark, then
        /// "shared".
        model: String,
    },
    /// Pure-Rust Transformer reference model (the paper's §5
    /// unconstrained predictor, trained offline by
    /// `repro train --arch transformer`): embedding + positional
    /// tables and encoder blocks loaded from a
    /// `*.transformer.params.bin` tensor store referenced by the
    /// artifacts manifest (`arch = "transformer"`).
    Transformer {
        /// Directory holding `manifest.json`,
        /// `*.transformer.params.bin`, `*.vocab.json`.
        artifacts: String,
        /// Model key in the manifest; empty ⇒ per-benchmark, then
        /// "shared".
        model: String,
    },
    /// Pure-Rust majority/stride fallback (no artifacts needed). Used
    /// by tests and as a degraded mode when artifacts are missing.
    Stride,
    /// Always predict the given delta (unit tests / ablation).
    Constant(i64),
}

impl PredictorBackendKind {
    fn to_json(&self) -> Json {
        match self {
            Self::Pjrt { artifacts, model } => Json::obj(vec![
                ("kind", Json::str("pjrt")),
                ("artifacts", Json::str(artifacts)),
                ("model", Json::str(model)),
            ]),
            Self::Native { artifacts, model } => Json::obj(vec![
                ("kind", Json::str("native")),
                ("artifacts", Json::str(artifacts)),
                ("model", Json::str(model)),
            ]),
            Self::Transformer { artifacts, model } => Json::obj(vec![
                ("kind", Json::str("transformer")),
                ("artifacts", Json::str(artifacts)),
                ("model", Json::str(model)),
            ]),
            Self::Stride => Json::obj(vec![("kind", Json::str("stride"))]),
            Self::Constant(d) => Json::obj(vec![
                ("kind", Json::str("constant")),
                ("delta", Json::Num(*d as f64)),
            ]),
        }
    }

    fn from_json(j: &Json) -> Result<Self> {
        match j.req("kind")?.as_str() {
            Some("pjrt") => Ok(Self::Pjrt {
                artifacts: j.get("artifacts").and_then(Json::as_str).unwrap_or("artifacts").into(),
                model: j.get("model").and_then(Json::as_str).unwrap_or("").into(),
            }),
            Some("native") => Ok(Self::Native {
                artifacts: j.get("artifacts").and_then(Json::as_str).unwrap_or("artifacts").into(),
                model: j.get("model").and_then(Json::as_str).unwrap_or("").into(),
            }),
            Some("transformer") => Ok(Self::Transformer {
                artifacts: j.get("artifacts").and_then(Json::as_str).unwrap_or("artifacts").into(),
                model: j.get("model").and_then(Json::as_str).unwrap_or("").into(),
            }),
            Some("stride") => Ok(Self::Stride),
            Some("constant") => {
                Ok(Self::Constant(j.get("delta").and_then(Json::as_i64).unwrap_or(1)))
            }
            other => anyhow::bail!("unknown backend kind {other:?}"),
        }
    }
}

/// Bypass policy (paper §6 item 5: "1 indicator to decide whether to
/// bypass the attention module according to the page convergence").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BypassMode {
    /// Never bypass — always run the model.
    Never,
    /// Bypass when the cluster's observed delta convergence exceeds
    /// `bypass_convergence` (emit the dominant delta directly).
    Auto,
    /// Always bypass (the ATAX/BICG/MVT degenerate case).
    Always,
}

impl BypassMode {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "never" => Self::Never,
            "auto" => Self::Auto,
            "always" => Self::Always,
            _ => return None,
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Never => "never",
            Self::Auto => "auto",
            Self::Always => "always",
        }
    }
}

#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    /// Active prefetch policy: "none" | "tree" | "uvmsmart" | "dl" |
    /// "oracle" | "stride".
    pub prefetcher: String,
    /// Prediction overhead in core cycles (paper §7.3: 1 µs ⇒ ~1500
    /// cycles at 1481 MHz; swept over 1/2/5/10 µs for Figure 10).
    pub prediction_latency_cycles: u64,
    /// Sequence length fed to the predictor (paper: 30).
    pub history_len: usize,
    /// Prediction distance (paper Table 3; runtime uses 1).
    pub prediction_distance: usize,
    /// Max windows per PJRT inference batch (coordinator batching).
    pub batch_size: usize,
    /// Flush a partial batch once its oldest request is this many
    /// cycles old (keeps timeliness under low fault rates).
    pub batch_flush_cycles: u64,
    /// Delta-convergence threshold for [`BypassMode::Auto`].
    pub bypass_convergence: f64,
    pub bypass: BypassMode,
    /// Fine-tune the model online every N simulated instructions
    /// (paper §7.1: every 50 M instructions; scaled down by default).
    /// 0 disables online fine-tuning.
    pub finetune_interval_insts: u64,
    /// Number of labelled windows replayed per fine-tune round.
    pub finetune_batch: usize,
    pub backend: PredictorBackendKind,
    /// Inference kernel tier for the in-process backends
    /// (`--precision exact|fast|int8|int4`, see
    /// [`crate::predictor::kernel`]). Exact is the default everywhere
    /// determinism is pinned; the faster tiers are inference-only and
    /// validated per backend by `predictor::factory`.
    pub precision: Precision,
    /// Tree prefetcher: promote a node once its valid fraction
    /// exceeds this (paper §2.2: 50%).
    pub tree_threshold: f64,
    /// Cap on prefetch pages issued per fault by any policy (the
    /// paper's §4: one basic block + top-1 page = 16 pages for DL;
    /// the tree policy may go up to a 2 MB node).
    pub max_prefetch_pages_dl: usize,
    /// Device-occupancy fraction above which pressure-aware policies
    /// (uvmsmart, dl) throttle their prefetch issue width — every
    /// speculative page evicts a live one once memory is full
    /// (arXiv:2204.02974). The stock tree policy has no config hook
    /// for this on purpose (NVIDIA's driver is not pressure-aware —
    /// that is the thrashing baseline); experiments can opt a tree in
    /// via `TreePrefetcher::with_pressure_throttle`.
    pub pressure_threshold: f64,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        Self {
            prefetcher: "tree".to_string(),
            prediction_latency_cycles: 1481, // 1 µs
            history_len: 30,
            prediction_distance: 1,
            batch_size: 8,
            batch_flush_cycles: 2_000,
            bypass_convergence: 0.9,
            bypass: BypassMode::Auto,
            finetune_interval_insts: 0,
            finetune_batch: 64,
            backend: PredictorBackendKind::Stride,
            precision: Precision::Exact,
            tree_threshold: 0.5,
            max_prefetch_pages_dl: 16,
            pressure_threshold: 0.85,
        }
    }
}

impl RuntimeConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("prefetcher", Json::str(&self.prefetcher)),
            ("prediction_latency_cycles", Json::Num(self.prediction_latency_cycles as f64)),
            ("history_len", Json::Num(self.history_len as f64)),
            ("prediction_distance", Json::Num(self.prediction_distance as f64)),
            ("batch_size", Json::Num(self.batch_size as f64)),
            ("batch_flush_cycles", Json::Num(self.batch_flush_cycles as f64)),
            ("bypass_convergence", Json::Num(self.bypass_convergence)),
            ("bypass", Json::str(self.bypass.as_str())),
            ("finetune_interval_insts", Json::Num(self.finetune_interval_insts as f64)),
            ("finetune_batch", Json::Num(self.finetune_batch as f64)),
            ("backend", self.backend.to_json()),
            ("precision", Json::str(self.precision.as_str())),
            ("tree_threshold", Json::Num(self.tree_threshold)),
            ("max_prefetch_pages_dl", Json::Num(self.max_prefetch_pages_dl as f64)),
            ("pressure_threshold", Json::Num(self.pressure_threshold)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = Self::default();
        if let Some(v) = j.get("prefetcher").and_then(Json::as_str) {
            c.prefetcher = v.to_string();
        }
        macro_rules! num {
            ($field:ident, $ty:ty) => {
                if let Some(v) = j.get(stringify!($field)).and_then(Json::as_f64) {
                    c.$field = v as $ty;
                }
            };
        }
        num!(prediction_latency_cycles, u64);
        num!(history_len, usize);
        num!(prediction_distance, usize);
        num!(batch_size, usize);
        num!(batch_flush_cycles, u64);
        num!(bypass_convergence, f64);
        num!(finetune_interval_insts, u64);
        num!(finetune_batch, usize);
        num!(tree_threshold, f64);
        num!(max_prefetch_pages_dl, usize);
        num!(pressure_threshold, f64);
        if let Some(b) = j.get("bypass").and_then(Json::as_str) {
            c.bypass = BypassMode::parse(b)
                .ok_or_else(|| anyhow::anyhow!("bad bypass mode '{b}'"))?;
        }
        if let Some(b) = j.get("backend") {
            c.backend = PredictorBackendKind::from_json(b)?;
        }
        if let Some(p) = j.get("precision").and_then(Json::as_str) {
            c.precision = Precision::parse(p).ok_or_else(|| {
                anyhow::anyhow!("bad precision '{p}' (expected exact | fast | int8 | int4)")
            })?;
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_json_roundtrip() {
        let cfg = RuntimeConfig {
            backend: PredictorBackendKind::Pjrt {
                artifacts: "artifacts".into(),
                model: "shared".into(),
            },
            bypass: BypassMode::Always,
            ..Default::default()
        };
        let back =
            RuntimeConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.backend, cfg.backend);
        assert_eq!(back.bypass, BypassMode::Always);
    }

    #[test]
    fn native_backend_kind_json_roundtrip() {
        let cfg = RuntimeConfig {
            backend: PredictorBackendKind::Native {
                artifacts: "models".into(),
                model: "streamtriad".into(),
            },
            ..Default::default()
        };
        let back =
            RuntimeConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.backend, cfg.backend);
    }

    #[test]
    fn transformer_backend_kind_json_roundtrip() {
        let cfg = RuntimeConfig {
            backend: PredictorBackendKind::Transformer {
                artifacts: "models".into(),
                model: "streamtriad".into(),
            },
            ..Default::default()
        };
        let back =
            RuntimeConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.backend, cfg.backend);
    }

    #[test]
    fn bypass_parse() {
        assert_eq!(BypassMode::parse("auto"), Some(BypassMode::Auto));
        assert_eq!(BypassMode::parse("bogus"), None);
    }

    #[test]
    fn precision_json_roundtrip_and_default() {
        let cfg = RuntimeConfig { precision: Precision::Int4, ..Default::default() };
        let back =
            RuntimeConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.precision, Precision::Int4);
        // Absent field → exact (old configs keep their meaning).
        let old = RuntimeConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(old.precision, Precision::Exact);
        let err =
            RuntimeConfig::from_json(&Json::parse("{\"precision\": \"turbo\"}").unwrap())
                .unwrap_err()
                .to_string();
        assert!(err.contains("turbo"), "{err}");
    }
}
