//! Report output: aligned markdown tables on stdout plus CSV files
//! under `results/` for downstream plotting.

use std::io::Write;
use std::path::Path;

/// A simple table accumulator.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render as github markdown with aligned columns.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n### {}\n\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// Format a float with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment_and_csv() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["short".into(), "1.00".into()]);
        t.row(vec!["a-much-longer-name".into(), "2.50".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a-much-longer-name | 2.50  |"));

        let dir = crate::util::TestDir::new();
        let p = dir.file("out/demo.csv");
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("name,value"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
