//! `repro train` — offline training of the pure-Rust predictor
//! backends from simulator-generated access streams (no JAX, no PJRT).
//!
//! Pipeline, mirroring the paper's data flow (§4/§7.1) entirely in
//! Rust: run the workload under demand paging and record every
//! GMMU-visible access per (SM, warp) cluster; build the delta
//! vocabulary and closed PC table from the observed stream (Hashemi's
//! observation that unique deltas are few — §4); slide a
//! `history_len`-token window over each cluster to harvest labelled
//! examples (label = next delta's class); train the selected
//! architecture (`--arch native` → [`NativeBackend`], the paper's
//! revised model; `--arch transformer` → [`TransformerBackend`], the
//! unconstrained reference model) with mini-batch SGD/Adam; and write
//! the weights, vocabulary and a manifest entry (`arch = "native"` or
//! `"transformer"`) so the matching `--backend` serves the model on
//! the eval path. The held-out report carries parameter-count and
//! FLOPs-per-inference columns for every arch, so the paper's
//! "orders of magnitude lower cost" claim is a measured number.
//!
//! Everything is seeded-deterministic: the workload seed comes from
//! [`crate::eval::runner::workload_seed`] (the same function the eval
//! sweep uses, so the model trains on exactly the distribution it is
//! later evaluated on), cluster streams are iterated in sorted key
//! order, and shuffling uses a seeded Fisher–Yates — training the same
//! workload twice produces byte-identical artifacts.

use crate::eval::runner::RunOptions;
use crate::predictor::engine::featurize_window;
use crate::predictor::vocab::VocabFile;
use crate::predictor::{
    ClusterBy, ClusterKey, DeltaVocab, HistoryToken, LabelledWindow, NativeBackend, NativeConfig,
    PredictorBackend, StrideBackend, TransformerBackend, TransformerConfig, Window,
};
use crate::prefetch::{FaultInfo, PrefetchDecision, Prefetcher};
use crate::runtime::{Manifest, ModelEntry};
use crate::sim::Simulator;
use crate::types::{AccessOrigin, Cycle, PageNum};
use crate::util::XorShift64;
use anyhow::Result;
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Offline-trainable model architecture (`repro train --arch …`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelArch {
    /// The paper's §6 revised (attention-free) model.
    Native,
    /// The paper's §5 unconstrained Transformer reference model.
    Transformer,
}

impl ModelArch {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "native" => Self::Native,
            "transformer" => Self::Transformer,
            _ => return None,
        })
    }

    /// The manifest `arch` tag / `--backend` name for this arch.
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Native => "native",
            Self::Transformer => "transformer",
        }
    }
}

/// A trained offline model of either architecture behind one
/// interface — what [`train_model`] produces and `repro analyze`
/// compares.
#[derive(Debug)]
pub enum TrainedModel {
    Native(NativeBackend),
    Transformer(TransformerBackend),
}

impl TrainedModel {
    pub fn arch(&self) -> ModelArch {
        match self {
            Self::Native(_) => ModelArch::Native,
            Self::Transformer(_) => ModelArch::Transformer,
        }
    }

    /// One optimizer step; returns the mean cross-entropy before it.
    pub fn train_batch(&mut self, batch: &[LabelledWindow]) -> f32 {
        match self {
            Self::Native(m) => m.train_batch(batch),
            Self::Transformer(m) => m.train_batch(batch),
        }
    }

    pub fn top1_accuracy(&self, data: &[LabelledWindow]) -> f64 {
        match self {
            Self::Native(m) => m.top1_accuracy(data),
            Self::Transformer(m) => m.top1_accuracy(data),
        }
    }

    /// Batched top-1 predictions (the serving-shaped path).
    pub fn predict_batch(&self, windows: &[Window]) -> Vec<crate::predictor::ClassId> {
        match self {
            Self::Native(m) => m.predict_batch(windows),
            Self::Transformer(m) => m.predict_batch(windows),
        }
    }

    /// Uniform introspection ([`PredictorBackend::info`]) — the train
    /// and analyze report tables read this instead of downcasting per
    /// arch.
    pub fn info(&self) -> crate::predictor::BackendInfo {
        match self {
            Self::Native(m) => m.info(),
            Self::Transformer(m) => m.info(),
        }
    }

    pub fn n_params(&self) -> usize {
        self.info().n_params
    }

    pub fn flops_per_inference(&self) -> u64 {
        self.info().flops_per_inference
    }

    /// Write the weights as a tensor store (f32, or int4 when `int4`).
    pub fn save(&self, path: &std::path::Path, int4: bool) -> Result<()> {
        match self {
            Self::Native(m) => m.save(path, int4),
            Self::Transformer(m) => m.save(path, int4),
        }
    }

    /// The transformer inside, when this is one (`repro analyze`'s
    /// attention-introspection hook).
    pub fn as_transformer(&self) -> Option<&TransformerBackend> {
        match self {
            Self::Transformer(m) => Some(m),
            Self::Native(_) => None,
        }
    }
}

/// Everything `repro train` can tune.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub benchmark: String,
    /// Artifacts directory (params + vocab + manifest live here).
    pub out: PathBuf,
    pub epochs: usize,
    /// Mini-batch size.
    pub batch: usize,
    /// Cap on harvested labelled windows (0 = unlimited); larger
    /// corpora are subsampled deterministically with a fixed stride.
    pub max_windows: usize,
    /// Window length (the paper's 30).
    pub history_len: usize,
    /// Output classes including OOV (vocabulary = the most frequent
    /// `classes − 1` deltas).
    pub classes: usize,
    /// Closed PC-table size (the encoder adds one OOV slot).
    pub pcs: usize,
    pub page_buckets: u32,
    /// Store weights int4-packed (paper Table 7; lossy).
    pub int4: bool,
    /// Which architecture to train.
    pub arch: ModelArch,
    pub native: NativeConfig,
    pub transformer: TransformerConfig,
    /// Workload regime: `scale`, `max_instructions` and `seed` are
    /// honoured; the backend/artifact fields are ignored.
    pub run: RunOptions,
}

impl Default for TrainOptions {
    fn default() -> Self {
        Self {
            benchmark: "streamtriad".to_string(),
            out: PathBuf::from("artifacts"),
            epochs: 3,
            batch: 64,
            max_windows: 40_000,
            history_len: 30,
            classes: 64,
            pcs: 256,
            page_buckets: 4096,
            int4: false,
            arch: ModelArch::Native,
            native: NativeConfig::default(),
            transformer: TransformerConfig::default(),
            run: RunOptions::default(),
        }
    }
}

/// What one training run measured (printed by `repro train`, asserted
/// by tests).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub benchmark: String,
    /// The trained architecture's manifest tag ("native" |
    /// "transformer").
    pub arch: String,
    pub n_train: usize,
    pub n_eval: usize,
    pub n_classes: usize,
    pub n_params: usize,
    /// Analytic FLOPs for one window's forward pass — the measured
    /// side of the paper's "orders of magnitude lower cost" claim.
    pub flops_per_inference: u64,
    /// Mean cross-entropy of the first / last epoch.
    pub first_epoch_loss: f64,
    pub last_epoch_loss: f64,
    /// Held-out top-1 accuracy of the trained model…
    pub model_top1: f64,
    /// …versus the frequency-vote [`StrideBackend`] on the same split.
    pub stride_top1: f64,
    pub params_path: PathBuf,
    pub vocab_path: PathBuf,
}

/// Records every GMMU access as a per-cluster (PC, page, Δ) token —
/// demand paging only, so the harvested stream is the workload's own
/// access order.
struct AccessCollector {
    streams: Arc<Mutex<BTreeMap<ClusterKey, Vec<HistoryToken>>>>,
    last_page: HashMap<ClusterKey, PageNum>,
    cluster_by: ClusterBy,
}

impl Prefetcher for AccessCollector {
    fn name(&self) -> &'static str {
        "train-collector"
    }

    fn on_fault_into(&mut self, _fault: &FaultInfo, _out: &mut PrefetchDecision) {}

    fn on_access(&mut self, origin: AccessOrigin, pc: u64, page: PageNum, _hit: bool, _now: Cycle) {
        let key = self.cluster_by.key(&origin, pc);
        if let Some(prev) = self.last_page.insert(key, page) {
            let delta = page as i64 - prev as i64;
            self.streams
                .lock()
                .expect("train stream lock")
                .entry(key)
                .or_default()
                .push(HistoryToken { pc, page, delta });
        }
    }
}

/// Run the benchmark once and return its per-cluster token streams in
/// sorted cluster order (determinism).
pub fn harvest_streams(opts: &TrainOptions) -> Result<BTreeMap<ClusterKey, Vec<HistoryToken>>> {
    let exp = opts.run.experiment(&opts.benchmark, "none")?;
    exp.sim.validate()?;
    let wl = opts.run.registry()?.build(&opts.benchmark, &exp.sim, exp.seed, opts.run.scale)?;
    let streams = Arc::new(Mutex::new(BTreeMap::new()));
    let collector = AccessCollector {
        streams: streams.clone(),
        last_page: HashMap::new(),
        cluster_by: ClusterBy::SmWarp,
    };
    let _ = Simulator::new(&exp, wl, Box::new(collector), None).run();
    Ok(Arc::try_unwrap(streams)
        .map_err(|_| anyhow::anyhow!("training stream still shared"))?
        .into_inner()
        .expect("train stream lock"))
}

/// Build the training vocabulary from the harvested streams: the most
/// frequent `classes − 1` deltas (ties toward the smaller delta) and
/// the most frequent `pcs` program counters.
pub fn build_vocab(
    streams: &BTreeMap<ClusterKey, Vec<HistoryToken>>,
    opts: &TrainOptions,
) -> VocabFile {
    let mut delta_counts: HashMap<i64, u64> = HashMap::new();
    let mut pc_counts: HashMap<u64, u64> = HashMap::new();
    for toks in streams.values() {
        for t in toks {
            *delta_counts.entry(t.delta).or_insert(0) += 1;
            *pc_counts.entry(t.pc).or_insert(0) += 1;
        }
    }
    let total: u64 = delta_counts.values().sum();
    let mut by_freq: Vec<(i64, u64)> = delta_counts.into_iter().collect();
    by_freq.sort_by_key(|&(d, c)| (std::cmp::Reverse(c), d));
    let dominant = by_freq.first().map(|&(d, _)| d).unwrap_or(1);
    let convergence = by_freq
        .first()
        .map(|&(_, c)| if total == 0 { 0.0 } else { c as f64 / total as f64 })
        .unwrap_or(0.0);
    let deltas: Vec<i64> =
        by_freq.iter().take(opts.classes.saturating_sub(1)).map(|&(d, _)| d).collect();
    let mut pcs_by_freq: Vec<(u64, u64)> = pc_counts.into_iter().collect();
    pcs_by_freq.sort_by_key(|&(p, c)| (std::cmp::Reverse(c), p));
    let pcs: Vec<u64> = pcs_by_freq.iter().take(opts.pcs).map(|&(p, _)| p).collect();
    VocabFile {
        deltas,
        pcs,
        page_buckets: opts.page_buckets.max(1),
        dominant_delta: dominant,
        convergence,
        history_len: opts.history_len,
    }
}

/// Slide a `history_len` window over every cluster stream; the label
/// is the class of the delta immediately after the window. Corpora
/// larger than `max` are thinned with a fixed stride (deterministic).
pub fn labelled_windows(
    vocab: &DeltaVocab,
    streams: &BTreeMap<ClusterKey, Vec<HistoryToken>>,
    max: usize,
) -> Vec<LabelledWindow> {
    let s = vocab.history_len.max(1);
    let total: usize = streams.values().map(|t| t.len().saturating_sub(s)).sum();
    let stride = if max == 0 { 1 } else { total.div_ceil(max.max(1)).max(1) };
    let mut out = Vec::with_capacity(total.div_ceil(stride));
    let mut idx = 0usize;
    for toks in streams.values() {
        for i in 0..toks.len().saturating_sub(s) {
            if idx % stride == 0 {
                out.push(LabelledWindow {
                    window: featurize_window(vocab, &toks[i..i + s]),
                    label: vocab.encode_delta(toks[i + s].delta) as i32,
                });
            }
            idx += 1;
        }
    }
    out
}

/// Validate the corpus options, harvest the benchmark's access
/// streams and build the (vocab file, runtime vocab, labelled
/// windows) corpus — the shared front half of [`train_model`] and
/// `repro analyze` (`eval/analyze.rs`).
pub fn prepare_corpus(
    opts: &TrainOptions,
) -> Result<(VocabFile, DeltaVocab, Vec<LabelledWindow>)> {
    anyhow::ensure!(opts.history_len > 0, "--history-len must be > 0");
    anyhow::ensure!(opts.classes >= 2, "--classes must be >= 2 (one delta + OOV)");
    anyhow::ensure!(opts.epochs > 0 && opts.batch > 0, "--epochs and --batch must be > 0");
    let streams = harvest_streams(opts)?;
    let file = build_vocab(&streams, opts);
    anyhow::ensure!(
        !file.deltas.is_empty(),
        "benchmark '{}' produced no page deltas to learn from",
        opts.benchmark
    );
    let vocab = DeltaVocab::from_parts(file.clone());
    let all = labelled_windows(&vocab, &streams, opts.max_windows);
    anyhow::ensure!(
        !all.is_empty(),
        "benchmark '{}' produced no full {}-token windows — lower --history-len or raise \
         --max-instructions",
        opts.benchmark,
        opts.history_len
    );
    Ok((file, vocab, all))
}

/// Interleaved train/held-out split: every 10th window held out, so
/// the eval slice covers all program phases instead of only the tail.
/// Tiny corpora fall back to in-sample evaluation.
pub fn split_windows(all: Vec<LabelledWindow>) -> (Vec<LabelledWindow>, Vec<LabelledWindow>) {
    let mut train: Vec<LabelledWindow> = Vec::with_capacity(all.len());
    let mut eval: Vec<LabelledWindow> = Vec::with_capacity(all.len() / 10 + 1);
    for (i, lw) in all.into_iter().enumerate() {
        if i % 10 == 9 {
            eval.push(lw);
        } else {
            train.push(lw);
        }
    }
    if eval.is_empty() {
        eval = train.clone(); // tiny corpora: report in-sample accuracy
    }
    (train, eval)
}

/// Held-out top-1 of the frequency-vote [`StrideBackend`] — the floor
/// every learned arch is compared against.
pub fn stride_top1(vocab: &DeltaVocab, history_len: usize, eval: &[LabelledWindow]) -> f64 {
    if eval.is_empty() {
        return 0.0;
    }
    let eval_windows: Vec<Window> = eval.iter().map(|lw| lw.window.clone()).collect();
    let mut stride = StrideBackend::new(vocab.n_classes(), history_len);
    let hits = stride
        .predict(&eval_windows)
        .iter()
        .zip(eval)
        .filter(|(p, lw)| **p == lw.label.max(0) as u32)
        .count();
    hits as f64 / eval.len() as f64
}

/// Seeded-deterministic mini-batch fit of `opts.arch` on an
/// already-split corpus; returns the model and the (first, last)
/// epoch mean losses. Shared by [`train_model`] and
/// `repro analyze` (which fits both archs on the *same* corpus).
pub fn fit_model(
    opts: &TrainOptions,
    vocab: &DeltaVocab,
    train: &[LabelledWindow],
) -> (TrainedModel, f64, f64) {
    let mut model = match opts.arch {
        ModelArch::Native => TrainedModel::Native(NativeBackend::init(vocab, &opts.native)),
        ModelArch::Transformer => {
            TrainedModel::Transformer(TransformerBackend::init(vocab, &opts.transformer))
        }
    };
    let seed = match opts.arch {
        ModelArch::Native => opts.native.seed,
        ModelArch::Transformer => opts.transformer.seed,
    };
    let mut rng = XorShift64::new(seed ^ 0x7452_4149); // ^"tRAI"
    let mut order: Vec<usize> = (0..train.len()).collect();
    let (mut first_loss, mut last_loss) = (0.0f64, 0.0f64);
    for epoch in 0..opts.epochs {
        for i in (1..order.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            order.swap(i, j);
        }
        let mut sum = 0.0f64;
        let mut steps = 0u64;
        let mut batch: Vec<LabelledWindow> = Vec::with_capacity(opts.batch);
        for &i in &order {
            batch.push(train[i].clone());
            if batch.len() == opts.batch {
                sum += model.train_batch(&batch) as f64;
                steps += 1;
                batch.clear();
            }
        }
        if !batch.is_empty() {
            sum += model.train_batch(&batch) as f64;
            steps += 1;
        }
        let mean = sum / steps.max(1) as f64;
        if epoch == 0 {
            first_loss = mean;
        }
        last_loss = mean;
        eprintln!(
            "train[{}/{}] epoch {}/{}: loss {mean:.4} ({} windows, {} classes)",
            opts.benchmark,
            opts.arch.as_str(),
            epoch + 1,
            opts.epochs,
            train.len(),
            vocab.n_classes()
        );
    }
    (model, first_loss, last_loss)
}

/// The whole offline pipeline for `opts.arch`: harvest → vocab →
/// windows → train → evaluate → save artifacts (params + vocab +
/// manifest entry with the matching `arch` tag).
pub fn train_model(opts: &TrainOptions) -> Result<TrainReport> {
    let (file, vocab, all) = prepare_corpus(opts)?;
    let (train, eval) = split_windows(all);
    let (model, first_loss, last_loss) = fit_model(opts, &vocab, &train);

    let model_top1 = model.top1_accuracy(&eval);
    let stride_top1 = stride_top1(&vocab, opts.history_len, &eval);
    let arch = opts.arch.as_str();

    std::fs::create_dir_all(&opts.out)?;
    let params_rel = format!("{}.{arch}.params.bin", opts.benchmark);
    let vocab_rel = format!("{}.vocab.json", opts.benchmark);
    let params_path = opts.out.join(&params_rel);
    let vocab_path = opts.out.join(&vocab_rel);
    model.save(&params_path, opts.int4)?;
    // Always write the dtype-3 sibling store next to the registered
    // one: the quantized serving tiers (`--precision int8|int4`) read
    // integer codes straight off it instead of requantizing f32 at
    // load time (the factory prefers it whenever it exists).
    let int4_rel = format!("{}.{arch}.int4.params.bin", opts.benchmark);
    model.save(&opts.out.join(&int4_rel), true)?;
    file.to_json().write_file(&vocab_path)?;
    let mut manifest =
        Manifest::load(&opts.out).unwrap_or(Manifest { version: 1, models: BTreeMap::new() });
    if let Some(old) = manifest.models.get(&opts.benchmark) {
        if old.arch != arch {
            // Anything that is not an in-process arch (e.g. the python
            // AOT's "revised") is served by --backend pjrt.
            let gone = match old.arch.as_str() {
                "native" | "transformer" => old.arch.as_str(),
                _ => "pjrt",
            };
            eprintln!(
                "train[{}]: WARNING — replacing existing '{}' manifest entry with arch={arch} \
                 (its files stay on disk but are deregistered; --backend {gone} will no longer \
                 resolve this key)",
                opts.benchmark, old.arch
            );
        }
    }
    manifest.models.insert(
        opts.benchmark.clone(),
        ModelEntry {
            infer_hlo: String::new(),
            train_hlo: None,
            params: params_rel,
            vocab: vocab_rel,
            batch: opts.batch,
            train_batch: opts.batch,
            seq_len: opts.history_len,
            n_features: 3,
            n_classes: vocab.n_classes(),
            n_params: model.n_params(),
            arch: arch.to_string(),
        },
    );
    manifest.save(&opts.out)?;

    let info = model.info();
    Ok(TrainReport {
        benchmark: opts.benchmark.clone(),
        arch: arch.to_string(),
        n_train: train.len(),
        n_eval: eval.len(),
        n_classes: vocab.n_classes(),
        n_params: info.n_params,
        flops_per_inference: info.flops_per_inference,
        first_epoch_loss: first_loss,
        last_epoch_loss: last_loss,
        model_top1,
        stride_top1,
        params_path,
        vocab_path,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::runner::run_benchmark;

    fn tiny_opts(out: PathBuf) -> TrainOptions {
        TrainOptions {
            benchmark: "streamtriad".into(),
            out,
            epochs: 4,
            batch: 32,
            max_windows: 2_000,
            history_len: 6,
            classes: 16,
            pcs: 64,
            page_buckets: 256,
            native: NativeConfig {
                d_pc: 2,
                d_page: 2,
                d_delta: 8,
                hidden: 16,
                lr: 0.01,
                ..Default::default()
            },
            transformer: TransformerConfig {
                d_model: 8,
                n_heads: 2,
                n_layers: 1,
                d_ff: 16,
                lr: 0.01,
                ..Default::default()
            },
            run: RunOptions { scale: 0.1, max_instructions: 0, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_train_writes_loadable_artifacts() {
        let dir = crate::util::TestDir::new();
        let opts = tiny_opts(dir.path().to_path_buf());
        let r = train_model(&opts).unwrap();
        assert!(r.n_train > 0 && r.n_eval > 0);
        assert!(r.first_epoch_loss.is_finite() && r.last_epoch_loss.is_finite());
        assert!(
            r.last_epoch_loss <= r.first_epoch_loss + 1e-9,
            "loss should not increase: {} → {}",
            r.first_epoch_loss,
            r.last_epoch_loss
        );

        let manifest = Manifest::load(dir.path()).unwrap();
        let (key, entry) = manifest.resolve("", "streamtriad").unwrap();
        assert_eq!(key, "streamtriad");
        assert_eq!(entry.arch, "native");
        assert_eq!(entry.seq_len, 6);
        let m = NativeBackend::load(&dir.path().join(&entry.params), &NativeConfig::default())
            .unwrap();
        assert_eq!(m.n_params(), r.n_params);

        // The trained artifact must serve end-to-end through the dl
        // prefetcher (`--backend native` shape).
        let run = RunOptions {
            scale: 0.1,
            max_instructions: 30_000,
            artifacts: dir.path().to_string_lossy().into_owned(),
            backend: "native".into(),
            ..Default::default()
        };
        let metrics = run_benchmark("streamtriad", "dl", &run).unwrap();
        assert!(metrics.mem_accesses > 0);

        // Training always leaves a dtype-3 sibling store, and the
        // quantized tiers serve end-to-end from its integer codes.
        let sibling = dir.path().join("streamtriad.native.int4.params.bin");
        assert!(sibling.exists(), "missing {}", sibling.display());
        let run_q =
            RunOptions { precision: crate::predictor::Precision::Int4, ..run };
        let metrics = run_benchmark("streamtriad", "dl", &run_q).unwrap();
        assert!(metrics.mem_accesses > 0);
    }

    #[test]
    fn same_seed_training_is_byte_deterministic() {
        let dir_a = crate::util::TestDir::new();
        let dir_b = crate::util::TestDir::new();
        let mut a = tiny_opts(dir_a.path().to_path_buf());
        let mut b = tiny_opts(dir_b.path().to_path_buf());
        a.epochs = 2;
        b.epochs = 2;
        let ra = train_model(&a).unwrap();
        let rb = train_model(&b).unwrap();
        assert_eq!(ra.last_epoch_loss, rb.last_epoch_loss);
        let bytes_a = std::fs::read(&ra.params_path).unwrap();
        let bytes_b = std::fs::read(&rb.params_path).unwrap();
        assert_eq!(bytes_a, bytes_b, "same seed must save identical weights");
    }

    #[test]
    fn transformer_arch_trains_and_registers_in_manifest() {
        let dir = crate::util::TestDir::new();
        let mut opts = tiny_opts(dir.path().to_path_buf());
        opts.arch = ModelArch::Transformer;
        opts.epochs = 2;
        opts.max_windows = 600;
        let r = train_model(&opts).unwrap();
        assert_eq!(r.arch, "transformer");
        assert!(r.n_params > 0 && r.flops_per_inference > 0);
        assert!(
            r.params_path.to_string_lossy().contains(".transformer.params.bin"),
            "{}",
            r.params_path.display()
        );

        let manifest = Manifest::load(dir.path()).unwrap();
        let (_, entry) = manifest.resolve("", "streamtriad").unwrap();
        assert_eq!(entry.arch, "transformer");
        assert_eq!(entry.n_params, r.n_params);
        let m = TransformerBackend::load(
            &dir.path().join(&entry.params),
            &TransformerConfig::default(),
        )
        .unwrap();
        assert_eq!(m.n_params(), r.n_params);

        // The artifact serves end-to-end through the dl prefetcher
        // (`--backend transformer` shape).
        let run = RunOptions {
            scale: 0.1,
            max_instructions: 30_000,
            artifacts: dir.path().to_string_lossy().into_owned(),
            backend: "transformer".into(),
            ..Default::default()
        };
        let metrics = run_benchmark("streamtriad", "dl", &run).unwrap();
        assert!(metrics.mem_accesses > 0);
    }

    #[test]
    fn vocab_keeps_most_frequent_deltas() {
        let mut streams: BTreeMap<ClusterKey, Vec<HistoryToken>> = BTreeMap::new();
        let toks: Vec<HistoryToken> = [1i64, 1, 1, 2, 2, 7]
            .iter()
            .map(|&d| HistoryToken { pc: 0x10, page: 0, delta: d })
            .collect();
        streams.insert(ClusterKey(0), toks);
        let mut opts = TrainOptions::default();
        opts.classes = 3; // two deltas + OOV
        let v = build_vocab(&streams, &opts);
        assert_eq!(v.deltas, vec![1, 2], "7 falls out of the vocabulary");
        assert_eq!(v.dominant_delta, 1);
        assert!((v.convergence - 0.5).abs() < 1e-12);
    }

    #[test]
    fn windows_are_thinned_deterministically() {
        let mut streams: BTreeMap<ClusterKey, Vec<HistoryToken>> = BTreeMap::new();
        let toks: Vec<HistoryToken> =
            (0..40).map(|i| HistoryToken { pc: 0, page: i, delta: 1 }).collect();
        streams.insert(ClusterKey(0), toks);
        let vocab = DeltaVocab::synthetic(vec![1], 4);
        let all = labelled_windows(&vocab, &streams, 0);
        assert_eq!(all.len(), 36);
        let thinned = labelled_windows(&vocab, &streams, 10);
        assert!(thinned.len() <= 10 && !thinned.is_empty(), "{}", thinned.len());
        let again = labelled_windows(&vocab, &streams, 10);
        assert_eq!(thinned.len(), again.len());
    }
}
