//! Parallel sweep executor for the paper-eval harness.
//!
//! A *cell* is one `(benchmark, prefetcher)` simulation — the unit of
//! Tables 10/11 and Figures 10/12. Cells are fully self-contained:
//! each worker thread builds its own workload, prefetcher and
//! simulator from a plain-data [`CellSpec`] (`Send + Sync`), so no
//! predictor state is ever shared across cells. Per-cell workload
//! seeds come from [`crate::eval::runner::workload_seed`], a pure function of
//! `(base seed, benchmark)`, which makes parallel execution
//! bit-identical to serial execution regardless of scheduling order —
//! the `rust/tests/determinism.rs` suite asserts exactly that.
//!
//! Scheduling is work-stealing in the simplest possible form: workers
//! race on an atomic cursor over the cell list, so a thread that
//! finishes a cheap streaming cell immediately steals the next pending
//! cell from the slower ones (the benchmark suite is heavily skewed:
//! the matvec column sweeps cost several times a streaming kernel).
//! Results are re-ordered by cell index before they are merged into
//! the [`Table`](crate::eval::report::Table) machinery.

use crate::eval::runner::{run_benchmark_instrumented, RunOptions};
use crate::sim::Metrics;
use crate::util::Json;
use crate::workloads::source_tag;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Every policy of the full paper sweep (`repro eval summary`).
pub const SWEEP_PREFETCHERS: &[&str] = &["none", "stride", "tree", "uvmsmart", "oracle", "dl"];

/// One self-contained simulation cell (plain data, `Send + Sync`).
#[derive(Debug, Clone)]
pub struct CellSpec {
    pub benchmark: String,
    pub prefetcher: String,
    pub opts: RunOptions,
    /// Optional prediction-latency override in µs (the Fig. 10 sweep).
    pub prediction_us: Option<f64>,
    /// Optional oversubscription ratio (resident fraction of the
    /// workload footprint — the `repro eval oversub` axis).
    pub oversub_ratio: Option<f64>,
    /// Optional eviction-policy override (defaults to the config's
    /// "lru" when unset).
    pub eviction: Option<String>,
}

impl CellSpec {
    pub fn new(benchmark: &str, prefetcher: &str, opts: &RunOptions) -> Self {
        Self {
            benchmark: benchmark.to_string(),
            prefetcher: prefetcher.to_string(),
            opts: opts.clone(),
            prediction_us: None,
            oversub_ratio: None,
            eviction: None,
        }
    }

    pub fn with_prediction_us(mut self, us: f64) -> Self {
        self.prediction_us = Some(us);
        self
    }

    pub fn with_oversub(mut self, ratio: f64, eviction: &str) -> Self {
        self.oversub_ratio = Some(ratio);
        self.eviction = Some(eviction.to_string());
        self
    }

    /// Run the cell to completion on the calling thread.
    pub fn run(&self) -> anyhow::Result<Metrics> {
        self.run_with_telemetry(None)
    }

    /// Run the cell with an optional structured-telemetry output path.
    /// Same tweak stack as [`CellSpec::run`] — the telemetry-identity
    /// suite (`tests/ab_identity.rs`) leans on that: an instrumented
    /// cell differs from its plain twin by the sink alone.
    pub fn run_with_telemetry(&self, telemetry: Option<&Path>) -> anyhow::Result<Metrics> {
        let us = self.prediction_us;
        let ratio = self.oversub_ratio;
        let eviction = self.eviction.clone();
        run_benchmark_instrumented(
            &self.benchmark,
            &self.prefetcher,
            &self.opts,
            move |mut e| {
                if let Some(us) = us {
                    e.runtime.prediction_latency_cycles = e.sim.us_to_cycles(us);
                }
                if let Some(r) = ratio {
                    e.sim.oversub_ratio = r;
                }
                if let Some(ev) = eviction {
                    e.sim.eviction_policy = ev;
                }
                e
            },
            None,
            telemetry,
        )
    }
}

/// A finished cell with its wall-clock cost.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub benchmark: String,
    pub prefetcher: String,
    /// Effective predictor backend of the cell's `dl` policy
    /// ("stride" | "native" | "pjrt") — recorded even for cells whose
    /// policy never consults a predictor, so grids stay homogeneous.
    pub backend: String,
    /// Where the workload came from: `"builtin"` (generator) or
    /// `"trace"` (ingested via `repro trace ingest`) — derived from
    /// the benchmark name's `trace:` convention
    /// ([`crate::workloads::source_tag`]).
    pub source: String,
    pub metrics: Metrics,
    pub wall: Duration,
}

/// A finished sweep: results in cell order plus timing telemetry.
#[derive(Debug)]
pub struct SweepOutcome {
    pub cells: Vec<CellResult>,
    /// Wall-clock of the whole sweep (parallel elapsed time).
    pub wall: Duration,
    pub threads: usize,
}

impl SweepOutcome {
    /// Serial-execution estimate: the sum of per-cell wall times (what
    /// one thread running the same cells back-to-back would cost).
    pub fn serial_wall(&self) -> Duration {
        self.cells.iter().map(|c| c.wall).sum()
    }

    /// Measured speedup of the parallel sweep over the serial estimate.
    pub fn speedup_vs_serial(&self) -> f64 {
        let par = self.wall.as_secs_f64();
        if par <= 0.0 {
            0.0
        } else {
            self.serial_wall().as_secs_f64() / par
        }
    }

    /// All results for one policy, in benchmark order of appearance.
    pub fn by_prefetcher(&self, prefetcher: &str) -> Vec<&CellResult> {
        self.cells.iter().filter(|c| c.prefetcher == prefetcher).collect()
    }
}

/// Worker-thread count: `UVM_SWEEP_THREADS` overrides, else the
/// machine's available parallelism.
pub fn default_threads() -> usize {
    std::env::var("UVM_SWEEP_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// The full registry × [`SWEEP_PREFETCHERS`] grid behind `repro eval
/// summary`: every registered workload source (dense, irregular, and —
/// when `opts.trace_dir` is set — ingested traces), in registration
/// order.
///
/// Cell-ordering invariant, shared by every grid builder (this one and
/// [`crate::eval::oversub::OversubGrid::cells`], which adds outer
/// ratio/eviction axes): **the benchmark axis varies fastest**. The
/// work-stealing cursor hands adjacent cells to different workers, so
/// benchmark-innermost order has concurrent workers materializing
/// *different* workloads; any benchmark-outer order would build every
/// policy cell of the same heavy workload (conv2d/srad materialize
/// hundreds of MB of warp ops each) at once. Peak memory stays at
/// roughly one copy of each big workload instead of `threads` copies
/// of the biggest.
pub fn full_sweep_cells(opts: &RunOptions) -> anyhow::Result<Vec<CellSpec>> {
    let registry = opts.registry()?;
    let benches: Vec<String> = registry.all().iter().map(|b| b.to_string()).collect();
    Ok(sweep_cells(&benches, SWEEP_PREFETCHERS, opts))
}

/// Policy-major grid over an explicit benchmark list (the
/// `--backend native` path restricts the list to trained models —
/// see [`crate::eval::runner::backend_benchmarks`]).
pub fn sweep_cells(
    benchmarks: &[String],
    prefetchers: &[&str],
    opts: &RunOptions,
) -> Vec<CellSpec> {
    prefetchers
        .iter()
        .flat_map(|p| benchmarks.iter().map(move |b| CellSpec::new(b, p, opts)))
        .collect()
}

/// Run `cells` on `threads` workers (1 = the serial path, same code).
/// The first cell error stops workers from *starting* further cells
/// (in-flight cells finish) and is returned after the pool drains;
/// results come back in cell order, independent of which worker ran
/// what.
pub fn sweep(cells: &[CellSpec], threads: usize) -> anyhow::Result<SweepOutcome> {
    let threads = threads.max(1).min(cells.len().max(1));
    let t0 = Instant::now();
    let next = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let (tx, rx) = mpsc::channel::<(usize, anyhow::Result<Metrics>, Duration)>();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            let failed = &failed;
            s.spawn(move || loop {
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let c0 = Instant::now();
                let res = cells[i].run();
                if res.is_err() {
                    failed.store(true, Ordering::Relaxed);
                }
                if tx.send((i, res, c0.elapsed())).is_err() {
                    break;
                }
            });
        }
    });
    drop(tx);

    let mut slots: Vec<Option<(anyhow::Result<Metrics>, Duration)>> =
        (0..cells.len()).map(|_| None).collect();
    for (i, res, wall) in rx {
        slots[i] = Some((res, wall));
    }
    // Surface the actual cell failure (if any) before complaining
    // about cells that were skipped because of it.
    for (spec, slot) in cells.iter().zip(&slots) {
        if let Some((Err(e), _)) = slot {
            anyhow::bail!("{}/{}: {e}", spec.benchmark, spec.prefetcher);
        }
    }
    let mut out = Vec::with_capacity(cells.len());
    for (spec, slot) in cells.iter().zip(slots) {
        let (res, wall) = slot.ok_or_else(|| {
            anyhow::anyhow!("cell {}/{} never ran", spec.benchmark, spec.prefetcher)
        })?;
        let metrics =
            res.map_err(|e| anyhow::anyhow!("{}/{}: {e}", spec.benchmark, spec.prefetcher))?;
        out.push(CellResult {
            benchmark: spec.benchmark.clone(),
            prefetcher: spec.prefetcher.clone(),
            backend: spec.opts.backend_name().to_string(),
            source: source_tag(&spec.benchmark).to_string(),
            metrics,
            wall,
        });
    }
    Ok(SweepOutcome { cells: out, wall: t0.elapsed(), threads })
}

/// Machine-readable sweep telemetry (`BENCH_eval.json` schema v1):
/// per-cell wall-clock + headline metrics, total sweep wall, and the
/// measured speedup over the serial estimate — the perf trajectory
/// record tracked from PR 1 onward.
pub fn bench_eval_json(o: &SweepOutcome) -> Json {
    let cells = o.cells.iter().map(|c| {
        Json::obj(vec![
            ("benchmark", Json::str(&c.benchmark)),
            ("prefetcher", Json::str(&c.prefetcher)),
            ("backend", Json::str(&c.backend)),
            ("source", Json::str(&c.source)),
            ("wall_ms", Json::Num(c.wall.as_secs_f64() * 1e3)),
            ("instructions", Json::Num(c.metrics.instructions as f64)),
            ("cycles", Json::Num(c.metrics.cycles as f64)),
            ("ipc", Json::Num(c.metrics.ipc())),
            ("page_hit_rate", Json::Num(c.metrics.page_hit_rate())),
            ("far_faults", Json::Num(c.metrics.far_faults as f64)),
            ("pcie_bytes", Json::Num(c.metrics.pcie_bytes() as f64)),
            ("unity", Json::Num(c.metrics.unity())),
        ])
    });
    Json::obj(vec![
        ("schema", Json::str("bench_eval/v1")),
        ("threads", Json::Num(o.threads as f64)),
        ("n_cells", Json::Num(o.cells.len() as f64)),
        ("total_wall_ms", Json::Num(o.wall.as_secs_f64() * 1e3)),
        ("serial_wall_ms_estimate", Json::Num(o.serial_wall().as_secs_f64() * 1e3)),
        ("speedup_vs_serial_estimate", Json::Num(o.speedup_vs_serial())),
        ("cells", Json::arr(cells)),
    ])
}

/// Write `BENCH_eval.json` for a finished sweep.
pub fn write_bench_eval(o: &SweepOutcome, path: &Path) -> anyhow::Result<()> {
    bench_eval_json(o).write_file(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> RunOptions {
        RunOptions { scale: 0.05, max_instructions: 30_000, ..Default::default() }
    }

    #[test]
    fn sweep_preserves_cell_order() {
        let opts = tiny();
        let cells = vec![
            CellSpec::new("addvectors", "none", &opts),
            CellSpec::new("atax", "tree", &opts),
            CellSpec::new("addvectors", "tree", &opts),
        ];
        let o = sweep(&cells, 3).unwrap();
        let order: Vec<(String, String)> =
            o.cells.iter().map(|c| (c.benchmark.clone(), c.prefetcher.clone())).collect();
        assert_eq!(
            order,
            vec![
                ("addvectors".into(), "none".into()),
                ("atax".into(), "tree".into()),
                ("addvectors".into(), "tree".into()),
            ]
        );
        assert!(o.cells.iter().all(|c| c.metrics.instructions > 0));
    }

    #[test]
    fn sweep_surfaces_cell_errors() {
        let opts = tiny();
        let cells = vec![
            CellSpec::new("addvectors", "none", &opts),
            CellSpec::new("addvectors", "bogus-policy", &opts),
        ];
        let err = sweep(&cells, 2).unwrap_err().to_string();
        assert!(err.contains("bogus-policy"), "{err}");
    }

    #[test]
    fn full_grid_is_registry_by_6() {
        let cells = full_sweep_cells(&tiny()).unwrap();
        assert_eq!(cells.len(), 14 * 6, "11 dense + 3 irregular, 6 policies");
    }

    #[test]
    fn bench_json_has_schema_and_cells() {
        let opts = tiny();
        let o = sweep(&[CellSpec::new("addvectors", "none", &opts)], 1).unwrap();
        let j = bench_eval_json(&o);
        assert_eq!(j.get("schema").and_then(Json::as_str), Some("bench_eval/v1"));
        assert_eq!(j.get("cells").and_then(Json::as_arr).map(|a| a.len()), Some(1));
        assert!(j.get("speedup_vs_serial_estimate").and_then(Json::as_f64).is_some());
        let cell = &j.get("cells").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(cell.get("backend").and_then(Json::as_str), Some("stride"));
        assert_eq!(cell.get("source").and_then(Json::as_str), Some("builtin"));
    }
}
