//! `repro eval oversub` — the oversubscription sweep.
//!
//! The paper's main evaluation runs with device memory comfortably
//! above the working set (§7.1); its companion work (arXiv:2204.02974)
//! and GPUVM (arXiv:2411.05309) show that prefetching quality is
//! really decided under *memory pressure*, where every speculative
//! page evicts a live one. This axis drives the work-stealing sweep
//! executor over
//!
//! ```text
//! {workloads} × {prefetch policies} × {memory ratios} × {eviction policies}
//! ```
//!
//! where a *memory ratio* is the resident fraction of the workload
//! footprint (`SimConfig::oversub_ratio`). At ratio 1.0 nothing ever
//! evicts, so only the `lru` eviction column runs there — and those
//! cells are byte-identical to the corresponding `repro eval summary`
//! cells (asserted by `rust/tests/oversub.rs`), anchoring the sweep to
//! the paper-regime numbers.
//!
//! Output: an aggregate table (hit rate, evictions, thrash ratio, and
//! PCIe traffic normalized to the ratio-1.0 baseline of the same
//! prefetcher), a per-cell CSV, and `BENCH_oversub.json`
//! (schema `bench_oversub/v2` — v2 adds the advise/discard columns,
//! the learned-eviction cells and the {0.375, 0.25} heavy-pressure
//! ratios).
//!
//! Caveat — instruction-capped runs: the ratio is a fraction of the
//! workload's *full* footprint, but a capped run (the paper-regime
//! default) only touches the pages its measurement window reaches. If
//! the window covers less than `ratio × footprint` pages, a pressure
//! cell never fills the device and measures nothing; the sweep prints
//! a loud warning when that happens. For guaranteed pressure, run to
//! completion (`--max-instructions 0`) or lower `--ratios`.

use crate::eval::report::{f, Table};
use crate::eval::runner::RunOptions;
use crate::eval::sweep::{self, CellSpec, SweepOutcome};
use crate::sim::eviction::{ALL_EVICTION_POLICIES, REFAULT_HORIZON_CYCLES};
use crate::sim::Metrics;
use crate::util::Json;
use crate::workloads::WorkloadRegistry;
use std::path::Path;

/// Default memory-ratio axis: baseline, mild, heavy and severe
/// pressure. The 0.375/0.25 tail is where eviction-policy quality
/// separates (arXiv:2204.02974 evaluates down to 50% of 75% — the
/// same territory).
pub const OVERSUB_RATIOS: &[f64] = &[1.0, 0.75, 0.5, 0.375, 0.25];

/// Default prefetch-policy axis (oracle and the bare stride comparison
/// are omitted: the oracle's recording pass doubles every cell's cost
/// and neither changes the pressure story).
pub const OVERSUB_PREFETCHERS: &[&str] = &["none", "tree", "uvmsmart", "dl"];

/// The sweep grid; every axis can be narrowed from the CLI.
#[derive(Debug, Clone)]
pub struct OversubGrid {
    pub benchmarks: Vec<String>,
    pub prefetchers: Vec<String>,
    pub ratios: Vec<f64>,
    pub evictions: Vec<String>,
}

impl Default for OversubGrid {
    /// Every built-in workload source (dense + irregular — the
    /// nightly grid covers the irregular trio by construction) ×
    /// default policy/ratio/eviction axes.
    fn default() -> Self {
        Self {
            benchmarks: WorkloadRegistry::builtin()
                .all()
                .iter()
                .map(|s| s.to_string())
                .collect(),
            prefetchers: OVERSUB_PREFETCHERS.iter().map(|s| s.to_string()).collect(),
            ratios: OVERSUB_RATIOS.to_vec(),
            evictions: ALL_EVICTION_POLICIES.iter().map(|s| s.to_string()).collect(),
        }
    }
}

impl OversubGrid {
    /// Flatten the grid into sweep cells, benchmark-innermost so
    /// adjacent cells (taken by different workers) hit different
    /// workloads — the same peak-memory argument as
    /// [`sweep::full_sweep_cells`]. The eviction axis is degenerate at
    /// ratio 1.0 (nothing evicts), so only `lru` runs there.
    pub fn cells(&self, opts: &RunOptions) -> Vec<CellSpec> {
        let lru_only = vec!["lru".to_string()];
        let mut out = Vec::new();
        for &ratio in &self.ratios {
            let evictions = if ratio >= 1.0 { &lru_only } else { &self.evictions };
            for eviction in evictions {
                for p in &self.prefetchers {
                    for b in &self.benchmarks {
                        out.push(CellSpec::new(b, p, opts).with_oversub(ratio, eviction));
                    }
                }
            }
        }
        out
    }
}

/// Machine-readable sweep telemetry (`BENCH_oversub.json` schema v2):
/// one record per cell with its grid coordinates, pressure counters
/// (including the advise/discard verbs) and wall-clock, plus
/// sweep-level timing and the learned policy's refault horizon.
pub fn bench_oversub_json(specs: &[CellSpec], o: &SweepOutcome) -> Json {
    let cells = specs.iter().zip(&o.cells).map(|(s, c)| {
        Json::obj(vec![
            ("benchmark", Json::str(&c.benchmark)),
            ("prefetcher", Json::str(&c.prefetcher)),
            ("ratio", Json::Num(s.oversub_ratio.unwrap_or(1.0))),
            ("eviction", Json::str(s.eviction.as_deref().unwrap_or("lru"))),
            ("wall_ms", Json::Num(c.wall.as_secs_f64() * 1e3)),
            ("instructions", Json::Num(c.metrics.instructions as f64)),
            ("cycles", Json::Num(c.metrics.cycles as f64)),
            ("page_hit_rate", Json::Num(c.metrics.page_hit_rate())),
            ("far_faults", Json::Num(c.metrics.far_faults as f64)),
            ("evictions", Json::Num(c.metrics.evictions as f64)),
            ("refaults", Json::Num(c.metrics.refaults as f64)),
            ("thrash_ratio", Json::Num(c.metrics.thrash_ratio())),
            ("evicted_unused_prefetches", Json::Num(c.metrics.evicted_unused_prefetches as f64)),
            ("advised_pages", Json::Num(c.metrics.advised_pages as f64)),
            ("discards", Json::Num(c.metrics.discards as f64)),
            ("lazy_discard_reclaims", Json::Num(c.metrics.lazy_discard_reclaims as f64)),
            ("pcie_bytes", Json::Num(c.metrics.pcie_bytes() as f64)),
            ("capacity_pages", Json::Num(c.metrics.capacity_pages as f64)),
            ("footprint_pages", Json::Num(c.metrics.footprint_pages as f64)),
        ])
    });
    Json::obj(vec![
        ("schema", Json::str("bench_oversub/v2")),
        ("refault_horizon_cycles", Json::Num(REFAULT_HORIZON_CYCLES as f64)),
        ("threads", Json::Num(o.threads as f64)),
        ("n_cells", Json::Num(o.cells.len() as f64)),
        ("total_wall_ms", Json::Num(o.wall.as_secs_f64() * 1e3)),
        ("serial_wall_ms_estimate", Json::Num(o.serial_wall().as_secs_f64() * 1e3)),
        ("cells", Json::arr(cells)),
    ])
}

/// A pressure cell (ratio < 1.0) that never evicted measured nothing
/// about the eviction policy under test. That happens when the
/// instruction window never filled the capped device — or when the
/// prefetcher's discard commands kept freeing frames ahead of
/// pressure, so capacity was recycled without the policy ever picking
/// a victim. Both cases warn: a discard-only cell is still silent on
/// eviction quality.
pub fn cell_is_vacuous(oversub_ratio: Option<f64>, m: &Metrics) -> bool {
    oversub_ratio.is_some_and(|r| r < 1.0) && m.evictions == 0
}

/// Run the grid through the parallel sweep executor; write the
/// per-cell CSV and `BENCH_oversub.json`; return the aggregate table.
pub fn oversub(opts: &RunOptions, out: &Path, grid: &OversubGrid) -> anyhow::Result<Table> {
    // The native backend only serves benchmarks with a trained model;
    // narrow the grid (loudly) instead of failing mid-sweep.
    let mut grid = grid.clone();
    grid.benchmarks = crate::eval::runner::backend_benchmarks(opts, &grid.benchmarks)?;
    let grid = &grid;
    let specs = grid.cells(opts);
    let threads = sweep::default_threads();
    eprintln!("eval oversub: running {} cells on {threads} threads…", specs.len());
    let outcome = sweep::sweep(&specs, threads)?;
    let bench = bench_oversub_json(&specs, &outcome);
    bench.write_file(&out.join("BENCH_oversub.json"))?;
    // CWD copy, like BENCH_eval.json — the per-PR perf record.
    // Best-effort: an unwritable CWD must not fail the sweep.
    if let Err(e) = bench.write_file(Path::new("BENCH_oversub.json")) {
        eprintln!("eval oversub: could not write ./BENCH_oversub.json: {e}");
    }
    eprintln!(
        "eval oversub: {} cells in {:.1} s on {} threads (serial estimate {:.1} s)",
        outcome.cells.len(),
        outcome.wall.as_secs_f64(),
        outcome.threads,
        outcome.serial_wall().as_secs_f64(),
    );
    // A pressure cell whose instruction window never filled the capped
    // device measures nothing — say so loudly instead of letting a
    // vacuous sweep pose as data (see the module-docs caveat).
    let vacuous = specs
        .iter()
        .zip(&outcome.cells)
        .filter(|(s, c)| cell_is_vacuous(s.oversub_ratio, &c.metrics))
        .count();
    if vacuous > 0 {
        eprintln!(
            "eval oversub: WARNING — {vacuous} pressure cell(s) (ratio < 1.0) saw zero \
             evictions: either the instruction cap covered less than the capped footprint \
             fraction, or discard commands freed every frame before eviction pressure \
             built (discard traffic masks the eviction-policy signal). Lower --ratios, \
             raise --max-instructions, or pass --max-instructions 0."
        );
    }

    // Per-cell CSV for downstream plotting.
    let mut detail = Table::new(
        "Oversubscription sweep — per cell",
        &[
            "benchmark", "prefetcher", "ratio", "eviction", "hit_rate", "far_faults",
            "evictions", "refaults", "thrash", "pcie_bytes",
        ],
    );
    for (s, c) in specs.iter().zip(&outcome.cells) {
        detail.row(vec![
            c.benchmark.clone(),
            c.prefetcher.clone(),
            f(s.oversub_ratio.unwrap_or(1.0), 2),
            s.eviction.clone().unwrap_or_else(|| "lru".into()),
            f(c.metrics.page_hit_rate(), 6),
            c.metrics.far_faults.to_string(),
            c.metrics.evictions.to_string(),
            c.metrics.refaults.to_string(),
            f(c.metrics.thrash_ratio(), 4),
            c.metrics.pcie_bytes().to_string(),
        ]);
    }
    detail.write_csv(&out.join("oversub_cells.csv"))?;

    // Aggregate over benchmarks per (ratio, eviction, prefetcher), with
    // PCIe traffic normalized to the same prefetcher's ratio-1.0 total.
    let mut t = Table::new(
        "Oversubscription — hit rate / evictions / thrash / PCIe vs memory ratio",
        &["ratio", "eviction", "prefetcher", "hit_rate", "evictions", "thrash", "pcie_bytes", "pcie_vs_full"],
    );
    let group_pcie = |ratio: f64, eviction: &str, prefetcher: &str| -> u64 {
        specs
            .iter()
            .zip(&outcome.cells)
            .filter(|(s, c)| {
                s.oversub_ratio == Some(ratio)
                    && s.eviction.as_deref() == Some(eviction)
                    && c.prefetcher == prefetcher
            })
            .map(|(_, c)| c.metrics.pcie_bytes())
            .sum()
    };
    for &ratio in &grid.ratios {
        let lru_only = vec!["lru".to_string()];
        let evictions = if ratio >= 1.0 { &lru_only } else { &grid.evictions };
        for eviction in evictions {
            for p in &grid.prefetchers {
                let group: Vec<&crate::sim::Metrics> = specs
                    .iter()
                    .zip(&outcome.cells)
                    .filter(|(s, c)| {
                        s.oversub_ratio == Some(ratio)
                            && s.eviction.as_deref() == Some(eviction.as_str())
                            && c.prefetcher == *p
                    })
                    .map(|(_, c)| &c.metrics)
                    .collect();
                if group.is_empty() {
                    continue;
                }
                let n = group.len() as f64;
                let hit = group.iter().map(|m| m.page_hit_rate()).sum::<f64>() / n;
                let evictions_total: u64 = group.iter().map(|m| m.evictions).sum();
                let refaults: u64 = group.iter().map(|m| m.refaults).sum();
                let faults: u64 = group.iter().map(|m| m.far_faults).sum();
                let thrash = if faults == 0 { 0.0 } else { refaults as f64 / faults as f64 };
                let pcie: u64 = group.iter().map(|m| m.pcie_bytes()).sum();
                let baseline = group_pcie(1.0, "lru", p);
                let vs_full = if baseline == 0 {
                    "—".to_string()
                } else {
                    f(pcie as f64 / baseline as f64, 3)
                };
                t.row(vec![
                    f(ratio, 2),
                    eviction.clone(),
                    p.clone(),
                    f(hit, 4),
                    evictions_total.to_string(),
                    f(thrash, 4),
                    pcie.to_string(),
                    vs_full,
                ]);
            }
        }
    }
    t.write_csv(&out.join("oversub.csv"))?;
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> RunOptions {
        RunOptions { scale: 0.05, max_instructions: 30_000, ..Default::default() }
    }

    #[test]
    fn default_grid_shape() {
        let grid = OversubGrid::default();
        let cells = grid.cells(&tiny());
        // ratio 1.0 → 1 eviction × 4 prefetchers × 14 benchmarks = 56;
        // ratios 0.75, 0.5, 0.375 and 0.25 → 5 evictions × 4 × 14 =
        // 280 each.
        assert_eq!(cells.len(), 56 + 280 + 280 + 280 + 280);
        assert!(cells
            .iter()
            .filter(|c| c.oversub_ratio == Some(1.0))
            .all(|c| c.eviction.as_deref() == Some("lru")));
        // The learned policy rides the default grid at every pressure
        // ratio.
        for &r in &[0.75, 0.5, 0.375, 0.25] {
            assert!(cells.iter().any(|c| {
                c.oversub_ratio == Some(r) && c.eviction.as_deref() == Some("learned")
            }));
        }
    }

    #[test]
    fn vacuous_cells_are_flagged_even_when_discards_fired() {
        let quiet = Metrics::default();
        // Ratio-1.0 cells never evict by construction — not vacuous.
        assert!(!cell_is_vacuous(Some(1.0), &quiet));
        assert!(!cell_is_vacuous(None, &quiet));
        // A capped cell with no evictions measured nothing.
        assert!(cell_is_vacuous(Some(0.5), &quiet));
        // Discard-only recycling still masks the eviction signal.
        let discard_only = Metrics { discards: 100, lazy_discard_reclaims: 40, ..quiet.clone() };
        assert!(cell_is_vacuous(Some(0.25), &discard_only));
        // One real eviction is a real measurement.
        let evicting = Metrics { evictions: 1, ..quiet };
        assert!(!cell_is_vacuous(Some(0.25), &evicting));
    }

    #[test]
    fn bench_json_schema_and_coordinates() {
        let opts = tiny();
        let grid = OversubGrid {
            benchmarks: vec!["addvectors".into()],
            prefetchers: vec!["tree".into()],
            ratios: vec![0.5],
            evictions: vec!["prefetch-aware".into()],
        };
        let specs = grid.cells(&opts);
        assert_eq!(specs.len(), 1);
        let outcome = sweep::sweep(&specs, 1).unwrap();
        let j = bench_oversub_json(&specs, &outcome);
        assert_eq!(j.get("schema").and_then(Json::as_str), Some("bench_oversub/v2"));
        assert_eq!(
            j.get("refault_horizon_cycles").and_then(Json::as_u64),
            Some(REFAULT_HORIZON_CYCLES)
        );
        let cells = j.get("cells").and_then(Json::as_arr).unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].get("eviction").and_then(Json::as_str), Some("prefetch-aware"));
        assert_eq!(cells[0].get("ratio").and_then(Json::as_f64), Some(0.5));
        assert!(cells[0].get("capacity_pages").and_then(Json::as_u64).unwrap() > 0);
        // v2 advise/discard columns are present on every cell.
        for col in ["advised_pages", "discards", "lazy_discard_reclaims"] {
            assert!(cells[0].get(col).and_then(Json::as_u64).is_some(), "missing {col}");
        }
    }
}
