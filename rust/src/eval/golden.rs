//! CI golden-metrics regression gate (`repro golden check|update`).
//!
//! `check` re-runs a small fixed grid — a 3-workload subset of
//! `repro eval summary` plus a slice of the `repro eval oversub` axis —
//! at tiny scale, and compares hit-rate / accuracy / coverage and the
//! pressure counters against `ci/golden_metrics.json` with tolerances.
//! Drift fails the build; intentional changes are committed by
//! re-pinning with `repro golden update` (or `make golden-update`) and
//! reviewing the diff.
//!
//! A golden file with `"bootstrap": true` has no pinned numbers yet
//! (e.g. the gate was introduced on a machine without a toolchain).
//! Bootstrap mode is still a gate: the grid runs **twice** and any
//! nondeterminism fails the build; the measured values are printed so
//! a maintainer can pin them with one `repro golden update` run.

use crate::eval::runner::RunOptions;
use crate::eval::sweep::{self, CellSpec};
use crate::sim::Metrics;
use crate::util::Json;
use std::path::Path;

pub const GOLDEN_SCHEMA: &str = "golden_metrics/v1";

/// 3-workload subset: one streaming, one matvec-sweep, one staged
/// kernel — cheap but covers the pattern families.
const GOLDEN_BENCHMARKS: &[&str] = &["addvectors", "atax", "pathfinder"];
const GOLDEN_PREFETCHERS: &[&str] = &["none", "tree", "uvmsmart", "dl"];
const GOLDEN_OVERSUB_PREFETCHERS: &[&str] = &["tree", "dl"];
const GOLDEN_OVERSUB_EVICTIONS: &[&str] = &["lru", "prefetch-aware"];
const GOLDEN_RATIO: f64 = 0.5;

/// Default tolerances written by `update` (and used when the golden
/// file omits them): quality ratios may drift by this absolute amount,
/// integer counters must match exactly.
const DEFAULT_FLOAT_ABS_TOL: f64 = 0.005;
const DEFAULT_INT_REL_TOL: f64 = 0.0;

/// Fixed eval-smoke regime (mirrors `make eval-smoke`), independent of
/// CLI defaults so the goldens never move with them silently. The
/// predictor backend is pinned to `stride` explicitly: training a
/// native model (or pointing `--artifacts` anywhere) must never move
/// these cells — the gate stays backend-stable by construction.
fn golden_opts() -> RunOptions {
    RunOptions {
        scale: 0.25,
        max_instructions: 200_000,
        backend: "stride".into(),
        ..Default::default()
    }
}

/// The gated cell grid, in a stable order.
pub fn golden_cells() -> Vec<CellSpec> {
    let opts = golden_opts();
    let mut cells = Vec::new();
    for p in GOLDEN_PREFETCHERS {
        for b in GOLDEN_BENCHMARKS {
            cells.push(CellSpec::new(b, p, &opts));
        }
    }
    for ev in GOLDEN_OVERSUB_EVICTIONS {
        for p in GOLDEN_OVERSUB_PREFETCHERS {
            for b in GOLDEN_BENCHMARKS {
                cells.push(CellSpec::new(b, p, &opts).with_oversub(GOLDEN_RATIO, ev));
            }
        }
    }
    cells
}

/// Stable key for one cell: `bench/prefetcher[/rX.XX/eviction]`.
pub fn cell_key(c: &CellSpec) -> String {
    match (c.oversub_ratio, &c.eviction) {
        (Some(r), Some(e)) => format!("{}/{}/r{:.2}/{}", c.benchmark, c.prefetcher, r, e),
        _ => format!("{}/{}", c.benchmark, c.prefetcher),
    }
}

/// The gated metric slice of one cell.
fn metrics_json(m: &Metrics) -> Json {
    Json::obj(vec![
        ("page_hit_rate", Json::Num(m.page_hit_rate())),
        ("accuracy", Json::Num(m.accuracy())),
        ("coverage", Json::Num(m.coverage())),
        ("far_faults", Json::Num(m.far_faults as f64)),
        ("evictions", Json::Num(m.evictions as f64)),
        ("refaults", Json::Num(m.refaults as f64)),
        ("instructions", Json::Num(m.instructions as f64)),
    ])
}

/// Which keys of [`metrics_json`] are float ratios (tolerance-compared)
/// vs exact integer counters.
const FLOAT_KEYS: &[&str] = &["page_hit_rate", "accuracy", "coverage"];
const INT_KEYS: &[&str] = &["far_faults", "evictions", "refaults", "instructions"];

/// Run the golden grid through the parallel sweep executor.
pub fn measure() -> anyhow::Result<Vec<(String, Metrics)>> {
    let cells = golden_cells();
    let outcome = sweep::sweep(&cells, sweep::default_threads())?;
    Ok(cells
        .iter()
        .zip(outcome.cells)
        .map(|(spec, res)| (cell_key(spec), res.metrics))
        .collect())
}

/// Re-pin the goldens from a fresh run.
pub fn update(path: &Path) -> anyhow::Result<()> {
    let measured = measure()?;
    let cells: std::collections::BTreeMap<String, Json> =
        measured.iter().map(|(k, m)| (k.clone(), metrics_json(m))).collect();
    Json::obj(vec![
        ("schema", Json::str(GOLDEN_SCHEMA)),
        ("bootstrap", Json::Bool(false)),
        ("float_abs_tol", Json::Num(DEFAULT_FLOAT_ABS_TOL)),
        ("int_rel_tol", Json::Num(DEFAULT_INT_REL_TOL)),
        ("cells", Json::Obj(cells)),
    ])
    .write_file(path)?;
    println!("golden: pinned {} cells to {}", measured.len(), path.display());
    Ok(())
}

/// Compare one measured cell against its golden record. Returns the
/// list of drift descriptions (empty = clean).
fn compare_cell(key: &str, golden: &Json, m: &Metrics, float_tol: f64, int_rel_tol: f64) -> Vec<String> {
    let measured = metrics_json(m);
    let mut drifts = Vec::new();
    for k in FLOAT_KEYS {
        let (Some(g), Some(v)) = (
            golden.get(k).and_then(Json::as_f64),
            measured.get(k).and_then(Json::as_f64),
        ) else {
            drifts.push(format!("{key}: golden field '{k}' missing"));
            continue;
        };
        if (g - v).abs() > float_tol {
            drifts.push(format!("{key}: {k} = {v:.6}, golden {g:.6} (tol ±{float_tol})"));
        }
    }
    for k in INT_KEYS {
        let (Some(g), Some(v)) = (
            golden.get(k).and_then(Json::as_f64),
            measured.get(k).and_then(Json::as_f64),
        ) else {
            drifts.push(format!("{key}: golden field '{k}' missing"));
            continue;
        };
        let limit = g.abs() * int_rel_tol;
        if (g - v).abs() > limit {
            drifts.push(format!("{key}: {k} = {v}, golden {g} (rel tol {int_rel_tol})"));
        }
    }
    drifts
}

/// Gate: compare a fresh run against the committed goldens; any drift
/// is an error. Bootstrap files gate determinism instead (see module
/// docs).
pub fn check(path: &Path) -> anyhow::Result<()> {
    let golden = Json::parse_file(path)?;
    match golden.get("schema").and_then(Json::as_str) {
        Some(GOLDEN_SCHEMA) => {}
        other => anyhow::bail!("{}: unsupported golden schema {other:?}", path.display()),
    }
    if golden.get("bootstrap").and_then(Json::as_bool).unwrap_or(false) {
        eprintln!(
            "golden: {} is in BOOTSTRAP mode — no pinned numbers yet. \
             Gating determinism instead (double run must match bit-for-bit).",
            path.display()
        );
        let a = measure()?;
        let b = measure()?;
        for ((key, ma), (_, mb)) in a.iter().zip(&b) {
            if ma != mb {
                anyhow::bail!("golden bootstrap: {key} is nondeterministic across runs");
            }
        }
        println!("golden: bootstrap determinism gate OK ({} cells). Candidates:", a.len());
        for (key, m) in &a {
            println!(
                "  {key}: hit={:.6} acc={:.6} cov={:.6} faults={} evict={} refault={}",
                m.page_hit_rate(),
                m.accuracy(),
                m.coverage(),
                m.far_faults,
                m.evictions,
                m.refaults,
            );
        }
        println!("golden: pin them with `repro golden update --path {}`", path.display());
        return Ok(());
    }

    let float_tol =
        golden.get("float_abs_tol").and_then(Json::as_f64).unwrap_or(DEFAULT_FLOAT_ABS_TOL);
    let int_rel_tol =
        golden.get("int_rel_tol").and_then(Json::as_f64).unwrap_or(DEFAULT_INT_REL_TOL);
    let cells = golden.req("cells")?;
    let measured = measure()?;
    let mut failures = Vec::new();
    for (key, m) in &measured {
        match cells.get(key) {
            None => failures.push(format!("{key}: missing from goldens (run `repro golden update`)")),
            Some(g) => failures.extend(compare_cell(key, g, m, float_tol, int_rel_tol)),
        }
    }
    // Stale golden keys (grid shrank) are drift too.
    if let Some(obj) = cells.as_obj() {
        for key in obj.keys() {
            if !measured.iter().any(|(k, _)| k == key) {
                failures.push(format!("{key}: golden cell no longer measured"));
            }
        }
    }
    if !failures.is_empty() {
        anyhow::bail!(
            "golden gate FAILED — {} drift(s):\n  {}",
            failures.len(),
            failures.join("\n  ")
        );
    }
    println!("golden: gate OK ({} cells within tolerance)", measured.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_shape_and_keys() {
        let cells = golden_cells();
        // 4 prefetchers × 3 benchmarks + 2 evictions × 2 prefetchers × 3.
        assert_eq!(cells.len(), 12 + 12);
        assert_eq!(cell_key(&cells[0]), "addvectors/none");
        let last = cells.last().unwrap();
        assert_eq!(cell_key(last), "pathfinder/dl/r0.50/prefetch-aware");
    }

    #[test]
    fn compare_detects_drift_and_accepts_tolerance() {
        let m = Metrics {
            mem_accesses: 100,
            page_hits: 50,
            far_faults: 50,
            instructions: 1_000,
            ..Default::default()
        };
        let exact = metrics_json(&m);
        assert!(compare_cell("k", &exact, &m, 0.005, 0.0).is_empty(), "self-compare clean");

        // Drift the hit rate beyond tolerance.
        let mut drifted = m.clone();
        drifted.page_hits = 60;
        let drifts = compare_cell("k", &exact, &drifted, 0.005, 0.0);
        assert!(drifts.iter().any(|d| d.contains("page_hit_rate")), "{drifts:?}");

        // Integer drift within a relative tolerance passes.
        let mut faults = m.clone();
        faults.far_faults = 51;
        assert!(!compare_cell("k", &exact, &faults, 0.5, 0.0).is_empty(), "exact mode trips");
        let only_int: Vec<String> = compare_cell("k", &exact, &faults, 0.5, 0.05)
            .into_iter()
            .filter(|d| d.contains("far_faults"))
            .collect();
        assert!(only_int.is_empty(), "2% drift inside 5% tolerance");
    }

    #[test]
    fn missing_golden_field_is_drift() {
        let m = Metrics::default();
        let partial = Json::obj(vec![("page_hit_rate", Json::Num(0.0))]);
        let drifts = compare_cell("k", &partial, &m, 0.005, 0.0);
        assert!(drifts.iter().any(|d| d.contains("accuracy")), "{drifts:?}");
    }
}
