//! `repro analyze` — the model-introspection subsystem: reproduce the
//! paper's act-two argument *as a measurement*.
//!
//! The paper first shows an unconstrained Transformer reaches high
//! prefetch accuracy, then inspects its attention to learn that each
//! head concentrates on a few fixed history slots — the insight that
//! justifies replacing attention with the far cheaper revised model.
//! This module executes that comparison end to end on one benchmark's
//! harvested corpus: train **both** archs on the *same*
//! deterministically-split corpus and seed, extract per-head attention
//! maps over held-out windows, reduce them to per-head **entropy** and
//! **positional-locality profiles** (mean attention mass per history
//! slot from the prediction-feeding query), and emit a
//! transformer-vs-native comparison table — held-out top-1, parameter
//! count, analytic FLOPs per inference, train/infer wall time, and the
//! per-tensor int4 quantization error (the Table 7 storage story) — as
//! `BENCH_compare.json` (schema `bench_compare/v1`).
//!
//! For a fixed seed the accuracy numbers, FLOPs/params ratios and
//! head profiles are deterministic; only the wall-clock fields vary
//! run to run (`rust/tests/transformer_backend.rs` pins this).

use crate::eval::report::Table;
use crate::eval::train::{self, ModelArch, TrainOptions, TrainedModel};
use crate::predictor::{
    DeltaVocab, LabelledWindow, NativeBackend, NativeConfig, Precision, TransformerBackend,
    TransformerConfig, Window,
};
use crate::runtime::params::TensorStore;
use crate::util::Json;
use anyhow::Result;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Everything `repro analyze` can tune.
#[derive(Debug, Clone)]
pub struct AnalyzeOptions {
    /// Corpus + training regime shared by both arms (`arch` inside is
    /// overridden per arm — both get trained).
    pub train: TrainOptions,
    /// Output directory: `BENCH_compare.json` plus both arms' f32 and
    /// int4 checkpoints (`<bench>.analyze.<arch>[.int4].params.bin`).
    pub out: PathBuf,
    /// Cap on held-out windows sampled for the attention statistics
    /// (the first N of the deterministic split — no RNG involved).
    pub max_maps: usize,
}

impl Default for AnalyzeOptions {
    fn default() -> Self {
        Self { train: TrainOptions::default(), out: PathBuf::from("results"), max_maps: 256 }
    }
}

/// Per-tensor int4 reconstruction error, measured through the real
/// tensor-store round trip (write f32 + write int4 → load both →
/// diff), not a formula.
#[derive(Debug, Clone)]
pub struct QuantError {
    pub tensor: String,
    pub max_err: f64,
    pub mean_err: f64,
}

/// One arm (arch) of the comparison.
#[derive(Debug, Clone)]
pub struct ModelArm {
    pub arch: String,
    /// Held-out top-1 accuracy.
    pub top1: f64,
    /// Held-out top-1 when serving from the int4 checkpoint — the
    /// quantized-inference accuracy column (native serves the integer
    /// tier directly; the transformer dequantizes the int4 store).
    pub int4_top1: f64,
    pub n_params: usize,
    pub flops_per_inference: u64,
    pub first_epoch_loss: f64,
    pub last_epoch_loss: f64,
    /// Offline training wall time (non-deterministic run to run).
    pub train_ms: f64,
    /// Batched inference wall per held-out window (non-deterministic).
    pub infer_us_per_window: f64,
    pub quant: Vec<QuantError>,
}

/// One bucket of the offline prediction post-mortem: held-out windows
/// grouped by their newest token's PC id, with each arm's top-1 over
/// the group. Large gaps localize *where* the cheap model loses (or
/// matches) the transformer — the offline twin of the simulator-side
/// telemetry post-mortem, which scores per (cluster, PC bucket) online
/// (DESIGN.md §13).
#[derive(Debug, Clone)]
pub struct PostmortemBucket {
    pub pc_id: i32,
    pub n: usize,
    pub native_top1: f64,
    pub transformer_top1: f64,
}

impl PostmortemBucket {
    /// transformer − native held-out top-1 over this bucket.
    pub fn gap(&self) -> f64 {
        self.transformer_top1 - self.native_top1
    }
}

/// One attention head's profile over the held-out sample: how spread
/// its attention is (entropy, in nats — `ln(seq_len)` = uniform) and
/// where it looks (mean attention mass per history slot from the
/// newest-slot query; slot `seq_len − 1` is the most recent token).
#[derive(Debug, Clone)]
pub struct HeadProfile {
    pub layer: usize,
    pub head: usize,
    pub entropy: f64,
    /// Slot receiving the largest mean attention mass.
    pub top_slot: usize,
    pub locality: Vec<f64>,
}

/// What one `repro analyze` run measured.
#[derive(Debug, Clone)]
pub struct AnalyzeReport {
    pub benchmark: String,
    pub seed: u64,
    pub history_len: usize,
    pub n_train: usize,
    pub n_eval: usize,
    pub n_classes: usize,
    /// Frequency-vote floor on the same held-out split.
    pub stride_top1: f64,
    pub native: ModelArm,
    pub transformer: ModelArm,
    /// transformer ÷ native — the paper's cost-gap headline numbers.
    pub params_ratio: f64,
    pub flops_ratio: f64,
    /// Per-PC accuracy buckets, most divergent first.
    pub postmortem: Vec<PostmortemBucket>,
    pub heads: Vec<HeadProfile>,
    /// Held-out windows the attention statistics averaged over.
    pub maps_windows: usize,
}

/// Train both archs on one benchmark's corpus and compare them; write
/// checkpoints + `BENCH_compare.json` under `opts.out` (and a CWD
/// copy, like the other `BENCH_*.json` telemetry files).
pub fn analyze(opts: &AnalyzeOptions) -> Result<AnalyzeReport> {
    let t = &opts.train;
    let (_file, vocab, all) = train::prepare_corpus(t)?;
    let (train_set, eval_set) = train::split_windows(all);
    let stride_top1 = train::stride_top1(&vocab, t.history_len, &eval_set);
    std::fs::create_dir_all(&opts.out)?;

    let (native_model, native, native_preds) =
        fit_arm(opts, &vocab, &train_set, &eval_set, ModelArch::Native)?;
    drop(native_model);
    let (trans_model, transformer, trans_preds) =
        fit_arm(opts, &vocab, &train_set, &eval_set, ModelArch::Transformer)?;
    let tm = trans_model.as_transformer().expect("transformer arm yields a transformer");
    let (heads, maps_windows) = attention_profiles(tm, &eval_set, opts.max_maps);
    let postmortem = prediction_postmortem(&eval_set, &native_preds, &trans_preds);

    let report = AnalyzeReport {
        benchmark: t.benchmark.clone(),
        seed: t.run.seed,
        history_len: t.history_len,
        n_train: train_set.len(),
        n_eval: eval_set.len(),
        n_classes: vocab.n_classes(),
        stride_top1,
        params_ratio: transformer.n_params as f64 / native.n_params.max(1) as f64,
        flops_ratio: transformer.flops_per_inference as f64
            / native.flops_per_inference.max(1) as f64,
        native,
        transformer,
        postmortem,
        heads,
        maps_windows,
    };
    write_bench_compare(&report, &opts.out.join("BENCH_compare.json"))?;
    // CWD copy, like BENCH_eval.json — the per-PR model-cost record.
    if let Err(e) = write_bench_compare(&report, Path::new("BENCH_compare.json")) {
        eprintln!("analyze: could not write ./BENCH_compare.json: {e}");
    }
    Ok(report)
}

/// Train one arm on the shared split, measure it, and round-trip its
/// checkpoint through the tensor store in both f32 and int4.
fn fit_arm(
    opts: &AnalyzeOptions,
    vocab: &DeltaVocab,
    train_set: &[LabelledWindow],
    eval_set: &[LabelledWindow],
    arch: ModelArch,
) -> Result<(TrainedModel, ModelArm, Vec<u32>)> {
    let mut topts = opts.train.clone();
    topts.arch = arch;
    let t0 = Instant::now();
    let (model, first_epoch_loss, last_epoch_loss) = train::fit_model(&topts, vocab, train_set);
    let train_ms = t0.elapsed().as_secs_f64() * 1e3;
    let ws: Vec<Window> = eval_set.iter().map(|lw| lw.window.clone()).collect();
    let t1 = Instant::now();
    let preds = model.predict_batch(&ws);
    let infer_us_per_window = t1.elapsed().as_secs_f64() * 1e6 / ws.len().max(1) as f64;
    let hits = preds
        .iter()
        .zip(eval_set)
        .filter(|(p, lw)| **p == lw.label.max(0) as u32)
        .count();
    let top1 = hits as f64 / eval_set.len().max(1) as f64;

    let name = arch.as_str();
    let p32 = opts.out.join(format!("{}.analyze.{name}.params.bin", topts.benchmark));
    let p4 = opts.out.join(format!("{}.analyze.{name}.int4.params.bin", topts.benchmark));
    model.save(&p32, false)?;
    model.save(&p4, true)?;
    let quant = quant_errors(&p32, &p4)?;
    let int4_top1 = int4_checkpoint_top1(&p4, arch, &ws, eval_set)?;

    let info = model.info();
    let arm = ModelArm {
        arch: name.to_string(),
        top1,
        int4_top1,
        n_params: info.n_params,
        flops_per_inference: info.flops_per_inference,
        first_epoch_loss,
        last_epoch_loss,
        train_ms,
        infer_us_per_window,
        quant,
    };
    Ok((model, arm, preds))
}

/// Group the held-out split by each window's newest-token PC id and
/// score both arms' predictions per group; buckets come back most
/// divergent first (ties broken by PC id, so the order is
/// deterministic for a fixed seed).
fn prediction_postmortem(
    eval_set: &[LabelledWindow],
    native: &[u32],
    transformer: &[u32],
) -> Vec<PostmortemBucket> {
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<i32, (usize, usize, usize)> = BTreeMap::new();
    for (i, lw) in eval_set.iter().enumerate() {
        let pc = lw.window.tokens.last().map(|t| t.pc_id).unwrap_or(-1);
        let label = lw.label.max(0) as u32;
        let e = groups.entry(pc).or_default();
        e.0 += 1;
        e.1 += (native.get(i) == Some(&label)) as usize;
        e.2 += (transformer.get(i) == Some(&label)) as usize;
    }
    let mut out: Vec<PostmortemBucket> = groups
        .into_iter()
        .map(|(pc_id, (n, native_hits, trans_hits))| PostmortemBucket {
            pc_id,
            n,
            native_top1: native_hits as f64 / n.max(1) as f64,
            transformer_top1: trans_hits as f64 / n.max(1) as f64,
        })
        .collect();
    out.sort_by(|a, b| {
        b.gap()
            .abs()
            .partial_cmp(&a.gap().abs())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.pc_id.cmp(&b.pc_id))
    });
    out
}

/// Held-out top-1 of the int4 checkpoint: the native arm serves the
/// integer-accumulate tier straight off the dtype-3 codes
/// (`--precision int4`'s real serving path); the transformer arm,
/// which has no quantized tier, dequantizes the store to f32.
fn int4_checkpoint_top1(
    p4: &Path,
    arch: ModelArch,
    ws: &[Window],
    eval_set: &[LabelledWindow],
) -> Result<f64> {
    let preds = match arch {
        ModelArch::Native => {
            NativeBackend::load_with_precision(p4, &NativeConfig::default(), Precision::Int4)?
                .predict_batch(ws)
        }
        ModelArch::Transformer => {
            TransformerBackend::load(p4, &TransformerConfig::default())?.predict_batch(ws)
        }
    };
    let hits = preds
        .iter()
        .zip(eval_set)
        .filter(|(p, lw)| **p == lw.label.max(0) as u32)
        .count();
    Ok(hits as f64 / eval_set.len().max(1) as f64)
}

/// Per-tensor |f32 − dequant(int4)| statistics between the two saved
/// checkpoints of one model.
fn quant_errors(p32: &Path, p4: &Path) -> Result<Vec<QuantError>> {
    let full = TensorStore::load(p32)?;
    let quantized = TensorStore::load(p4)?;
    let mut out = Vec::with_capacity(full.tensors.len());
    for t in &full.tensors {
        let Some(q) = quantized.tensors.iter().find(|q| q.name == t.name) else {
            anyhow::bail!("{}: tensor '{}' missing from int4 store", p4.display(), t.name);
        };
        anyhow::ensure!(q.numel() == t.numel(), "tensor '{}' shape mismatch", t.name);
        let (mut max_err, mut sum) = (0.0f64, 0.0f64);
        for (a, b) in t.data.iter().zip(&q.data) {
            let e = (a - b).abs() as f64;
            max_err = max_err.max(e);
            sum += e;
        }
        out.push(QuantError {
            tensor: t.name.clone(),
            max_err,
            mean_err: sum / t.numel().max(1) as f64,
        });
    }
    Ok(out)
}

/// Reduce the transformer's attention maps over (up to `cap`) held-out
/// windows to per-head mean entropy and a positional-locality profile,
/// both taken from the newest-slot query row — the one whose output
/// feeds the prediction.
fn attention_profiles(
    m: &TransformerBackend,
    eval_set: &[LabelledWindow],
    cap: usize,
) -> (Vec<HeadProfile>, usize) {
    let s = m.seq_len();
    let heads_per = m.n_heads();
    let layers = m.n_layers();
    let n = eval_set.len().min(cap.max(1));
    let mut loc = vec![0.0f64; layers * heads_per * s];
    let mut ent = vec![0.0f64; layers * heads_per];
    for lw in &eval_set[..n] {
        let (_, maps) = m.attention_one(&lw.window);
        for l in 0..layers {
            for h in 0..heads_per {
                let row = &maps[((l * heads_per + h) * s + (s - 1)) * s..][..s];
                let mut e = 0.0f64;
                for (j, &w) in row.iter().enumerate() {
                    let w = w as f64;
                    loc[(l * heads_per + h) * s + j] += w;
                    if w > 0.0 {
                        e -= w * w.ln();
                    }
                }
                ent[l * heads_per + h] += e;
            }
        }
    }
    let mut heads = Vec::with_capacity(layers * heads_per);
    for l in 0..layers {
        for h in 0..heads_per {
            let locality: Vec<f64> =
                (0..s).map(|j| loc[(l * heads_per + h) * s + j] / n as f64).collect();
            let mut top_slot = 0usize;
            for (j, &v) in locality.iter().enumerate() {
                if v > locality[top_slot] {
                    top_slot = j;
                }
            }
            heads.push(HeadProfile {
                layer: l,
                head: h,
                entropy: ent[l * heads_per + h] / n as f64,
                top_slot,
                locality,
            });
        }
    }
    (heads, n)
}

fn arm_json(a: &ModelArm) -> Json {
    Json::obj(vec![
        ("arch", Json::str(&a.arch)),
        ("top1", Json::Num(a.top1)),
        ("int4_top1", Json::Num(a.int4_top1)),
        ("n_params", Json::Num(a.n_params as f64)),
        ("flops_per_inference", Json::Num(a.flops_per_inference as f64)),
        ("first_epoch_loss", Json::Num(a.first_epoch_loss)),
        ("last_epoch_loss", Json::Num(a.last_epoch_loss)),
        ("train_ms", Json::Num(a.train_ms)),
        ("infer_us_per_window", Json::Num(a.infer_us_per_window)),
        (
            "quant_int4",
            Json::arr(a.quant.iter().map(|q| {
                Json::obj(vec![
                    ("tensor", Json::str(&q.tensor)),
                    ("max_err", Json::Num(q.max_err)),
                    ("mean_err", Json::Num(q.mean_err)),
                ])
            })),
        ),
    ])
}

impl AnalyzeReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str("bench_compare/v1")),
            ("benchmark", Json::str(&self.benchmark)),
            ("seed", Json::Num(self.seed as f64)),
            ("history_len", Json::Num(self.history_len as f64)),
            ("n_train", Json::Num(self.n_train as f64)),
            ("n_eval", Json::Num(self.n_eval as f64)),
            ("n_classes", Json::Num(self.n_classes as f64)),
            ("stride_top1", Json::Num(self.stride_top1)),
            ("native", arm_json(&self.native)),
            ("transformer", arm_json(&self.transformer)),
            ("params_ratio", Json::Num(self.params_ratio)),
            ("flops_ratio", Json::Num(self.flops_ratio)),
            (
                "postmortem",
                Json::arr(self.postmortem.iter().map(|b| {
                    Json::obj(vec![
                        ("pc_id", Json::Num(b.pc_id as f64)),
                        ("n", Json::Num(b.n as f64)),
                        ("native_top1", Json::Num(b.native_top1)),
                        ("transformer_top1", Json::Num(b.transformer_top1)),
                        ("gap", Json::Num(b.gap())),
                    ])
                })),
            ),
            ("maps_windows", Json::Num(self.maps_windows as f64)),
            (
                "heads",
                Json::arr(self.heads.iter().map(|hp| {
                    Json::obj(vec![
                        ("layer", Json::Num(hp.layer as f64)),
                        ("head", Json::Num(hp.head as f64)),
                        ("entropy", Json::Num(hp.entropy)),
                        ("top_slot", Json::Num(hp.top_slot as f64)),
                        ("locality", Json::arr(hp.locality.iter().map(|&v| Json::Num(v)))),
                    ])
                })),
            ),
        ])
    }

    /// The stdout comparison table (`repro analyze`).
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Transformer vs native — {} ({} held-out windows, stride floor {:.2}%)",
                self.benchmark,
                self.n_eval,
                self.stride_top1 * 100.0
            ),
            &[
                "arch",
                "top-1 %",
                "int4 top-1 %",
                "params",
                "FLOPs/inf",
                "train ms",
                "infer µs/win",
                "loss",
            ],
        );
        for a in [&self.native, &self.transformer] {
            t.row(vec![
                a.arch.clone(),
                format!("{:.2}", a.top1 * 100.0),
                format!("{:.2}", a.int4_top1 * 100.0),
                a.n_params.to_string(),
                a.flops_per_inference.to_string(),
                format!("{:.1}", a.train_ms),
                format!("{:.2}", a.infer_us_per_window),
                format!("{:.3}→{:.3}", a.first_epoch_loss, a.last_epoch_loss),
            ]);
        }
        t.row(vec![
            "t/n ratio".into(),
            String::new(),
            String::new(),
            format!("{:.1}×", self.params_ratio),
            format!("{:.1}×", self.flops_ratio),
            String::new(),
            String::new(),
            String::new(),
        ]);
        t
    }

    /// The per-head interpretability table (`repro analyze`).
    pub fn heads_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Attention locality — {} ({} windows; slot {} = newest; uniform entropy {:.2})",
                self.benchmark,
                self.maps_windows,
                self.history_len.saturating_sub(1),
                (self.history_len.max(1) as f64).ln()
            ),
            &["layer", "head", "entropy", "top slot", "top-3 slots (mass)"],
        );
        for hp in &self.heads {
            let mut ranked: Vec<(usize, f64)> =
                hp.locality.iter().copied().enumerate().collect();
            ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
            let top3: Vec<String> =
                ranked.iter().take(3).map(|(j, m)| format!("{j}({m:.2})")).collect();
            t.row(vec![
                hp.layer.to_string(),
                hp.head.to_string(),
                format!("{:.3}", hp.entropy),
                hp.top_slot.to_string(),
                top3.join(" "),
            ]);
        }
        t
    }

    /// Per-PC-bucket prediction post-mortem: where the two arms diverge most.
    ///
    /// Buckets are keyed by the newest token's `pc_id` and sorted by |gap|, so the
    /// first rows are the access contexts where picking one architecture over the
    /// other actually changes what gets prefetched. Capped at 12 rows — the tail
    /// is in the `postmortem` array of `BENCH_compare.json`.
    pub fn postmortem_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Prediction post-mortem — {} ({} held-out windows, {} PC buckets)",
                self.benchmark,
                self.n_eval,
                self.postmortem.len()
            ),
            &["pc id", "windows", "native %", "transformer %", "gap"],
        );
        for b in self.postmortem.iter().take(12) {
            t.row(vec![
                b.pc_id.to_string(),
                b.n.to_string(),
                format!("{:.2}", b.native_top1 * 100.0),
                format!("{:.2}", b.transformer_top1 * 100.0),
                format!("{:+.2}", b.gap() * 100.0),
            ]);
        }
        t
    }
}

/// Write `BENCH_compare.json` (schema `bench_compare/v1`).
pub fn write_bench_compare(r: &AnalyzeReport, path: &Path) -> Result<()> {
    r.to_json().write_file(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::runner::RunOptions;
    use crate::predictor::{NativeConfig, TransformerConfig};

    fn tiny_opts(out: PathBuf) -> AnalyzeOptions {
        AnalyzeOptions {
            train: TrainOptions {
                benchmark: "streamtriad".into(),
                out: out.clone(),
                epochs: 2,
                batch: 32,
                max_windows: 600,
                history_len: 6,
                classes: 16,
                pcs: 64,
                page_buckets: 256,
                native: NativeConfig {
                    d_pc: 2,
                    d_page: 2,
                    d_delta: 8,
                    hidden: 16,
                    lr: 0.01,
                    ..Default::default()
                },
                transformer: TransformerConfig {
                    d_model: 8,
                    n_heads: 2,
                    n_layers: 1,
                    d_ff: 16,
                    lr: 0.01,
                    ..Default::default()
                },
                run: RunOptions { scale: 0.1, max_instructions: 0, ..Default::default() },
                ..Default::default()
            },
            out,
            max_maps: 64,
        }
    }

    #[test]
    fn analyze_writes_populated_bench_compare() {
        let dir = crate::util::TestDir::new();
        let opts = tiny_opts(dir.path().to_path_buf());
        let r = analyze(&opts).unwrap();
        assert!(r.n_eval > 0 && r.maps_windows > 0);
        assert!(r.flops_ratio > 1.0, "transformer must cost more FLOPs: {}", r.flops_ratio);
        assert_eq!(r.heads.len(), 2, "1 layer × 2 heads");
        for hp in &r.heads {
            let mass: f64 = hp.locality.iter().sum();
            assert!((mass - 1.0).abs() < 1e-3, "locality sums to 1, got {mass}");
            assert!(hp.entropy >= 0.0 && hp.entropy <= (6f64).ln() + 1e-4);
            assert!(hp.top_slot < 6);
        }
        // Both arms carry per-tensor int4 quant errors within the
        // scheme's half-step bound.
        for arm in [&r.native, &r.transformer] {
            assert!(!arm.quant.is_empty());
            for q in &arm.quant {
                assert!(q.max_err <= crate::predictor::quant::max_quant_error() as f64 + 1e-5);
            }
            assert!((0.0..=1.0).contains(&arm.int4_top1), "{}", arm.int4_top1);
        }
        let j = Json::parse_file(&dir.path().join("BENCH_compare.json")).unwrap();
        assert_eq!(j.req("schema").unwrap().as_str(), Some("bench_compare/v1"));
        assert!(j.req("flops_ratio").unwrap().as_f64().unwrap() > 1.0);
        // Both arms carry the quantized-inference accuracy column.
        for arm in ["native", "transformer"] {
            assert!(j.req(arm).unwrap().req("int4_top1").unwrap().as_f64().is_some(), "{arm}");
        }
        let heads = j.req("heads").unwrap().as_arr().unwrap();
        assert_eq!(heads.len(), 2);
        // Post-mortem buckets partition the eval set and survive serialization.
        let bucket_total: usize = r.postmortem.iter().map(|b| b.n).sum();
        assert_eq!(bucket_total, r.n_eval, "post-mortem buckets must partition eval windows");
        for b in &r.postmortem {
            assert!((0.0..=1.0).contains(&b.native_top1));
            assert!((0.0..=1.0).contains(&b.transformer_top1));
        }
        let pm = j.req("postmortem").unwrap().as_arr().unwrap();
        assert_eq!(pm.len(), r.postmortem.len());
        // Tables render without panicking and carry both arch rows.
        let table = r.to_table().to_markdown();
        assert!(table.contains("native") && table.contains("transformer"));
        assert!(!r.heads_table().to_markdown().is_empty());
        assert!(!r.postmortem_table().to_markdown().is_empty());
    }

    #[test]
    fn analyze_is_deterministic_for_fixed_seed() {
        let dir_a = crate::util::TestDir::new();
        let dir_b = crate::util::TestDir::new();
        let ra = analyze(&tiny_opts(dir_a.path().to_path_buf())).unwrap();
        let rb = analyze(&tiny_opts(dir_b.path().to_path_buf())).unwrap();
        assert_eq!(ra.native.top1, rb.native.top1);
        assert_eq!(ra.native.int4_top1, rb.native.int4_top1);
        assert_eq!(ra.transformer.top1, rb.transformer.top1);
        assert_eq!(ra.flops_ratio, rb.flops_ratio);
        for (a, b) in ra.heads.iter().zip(&rb.heads) {
            assert_eq!(a.entropy, b.entropy, "head entropy must be deterministic");
            assert_eq!(a.locality, b.locality, "locality profile must be deterministic");
            assert_eq!(a.top_slot, b.top_slot);
        }
        for (a, b) in ra.postmortem.iter().zip(&rb.postmortem) {
            assert_eq!((a.pc_id, a.n), (b.pc_id, b.n));
            assert_eq!(a.native_top1, b.native_top1);
            assert_eq!(a.transformer_top1, b.transformer_top1);
        }
    }
}
