//! `repro perf` — the simulator-throughput harness.
//!
//! Runs a pinned microbench matrix over the hot-path subsystems
//! (fault loop, eviction churn at ratio 0.25, TLB shootdown storm)
//! plus a set of end-to-end representative sweep cells, and records
//! everything in `BENCH_sim.json` (schema `bench_sim/v1`, shared with
//! the cargo benches — see [`crate::util::bench::write_bench_sim`]).
//! The end-to-end rows report **cells/sec**, the number the oversub
//! sweep's wall-time scales with; that is the tracked speedup metric
//! of the frame-table refactor (DESIGN.md §12).
//!
//! `--check <baseline.json>` compares against a committed baseline
//! with a generous 2x tolerance (CI runners are noisy) and is
//! **warn-only**: regressions print loudly but never fail the build.
//! A baseline carrying `"bootstrap": true` — or a missing file —
//! prints the measured candidates and the `--update` pin command
//! instead of judging anything (the `repro golden` bootstrap pattern).

use crate::eval::runner::RunOptions;
use crate::eval::sweep::CellSpec;
use crate::sim::device_memory::{DeviceMemory, SmSet};
use crate::sim::eviction;
use crate::sim::gmmu::Gmmu;
use crate::util::bench::{
    black_box, merge_bench_sim_section, write_bench_sim, Bench, BenchResult,
};
use crate::util::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Baseline artifact schema (`ci/perf_baseline.json`).
pub const PERF_BASELINE_SCHEMA: &str = "perf_baseline/v1";

/// Regression tolerance: warn only when throughput falls below
/// `baseline / 2` (shared CI runners jitter far more than a dedicated
/// box; a 2x floor still catches an accidental O(n) → O(n²)).
pub const CHECK_TOLERANCE: f64 = 2.0;

/// Pages driven through the allocation-free fault loop per iteration.
const FAULT_PAGES: u64 = 1 << 14;
/// Distinct pages of the churn bench; capacity is a quarter of this
/// (the oversub grid's heaviest ratio).
const CHURN_DISTINCT: u64 = 4096;
const CHURN_OPS: u64 = 16_384;
/// Fill + masked-shootdown rounds per storm iteration.
const STORM_OPS: u64 = 8192;
/// SM count of the storm (paper Table 9 scale).
const STORM_SMS: usize = 30;

#[derive(Debug, Clone)]
pub struct PerfOptions {
    /// Short measurement windows and a smaller end-to-end set — the
    /// PR-CI variant (`make perf-smoke`).
    pub smoke: bool,
    /// Where `BENCH_sim.json` goes (merged read-modify-write).
    pub out: PathBuf,
    /// Baseline to compare against (warn-only), if any.
    pub check: Option<PathBuf>,
    /// Rewrite the `--check` baseline with the measured numbers.
    pub update: bool,
}

/// One measured subsystem: stable baseline key + bench result.
struct Subsystem {
    key: &'static str,
    result: BenchResult,
}

/// The pinned microbench matrix. Every case drives the real simulator
/// structures (no mocks) with a deterministic synthetic stream, so
/// run-to-run variance is scheduling noise only.
fn run_subsystems(smoke: bool) -> Vec<Subsystem> {
    let min_time = Duration::from_millis(if smoke { 60 } else { 400 });
    let mut b = Bench::new().with_min_time(min_time);
    let mut out = Vec::new();

    // 1. Fault loop: state-probe + admit + touch of fresh pages with
    //    zero eviction pressure — the dense frame table's alloc path.
    let r = b
        .case("fault_loop/admit+touch 16k fresh pages", FAULT_PAGES, || {
            let mut m = DeviceMemory::new(FAULT_PAGES + 8);
            for p in 0..FAULT_PAGES {
                black_box(m.state(p, p));
                m.admit(p, p, p % 4 == 0, p);
                m.touch(p, p);
            }
            m.occupancy()
        })
        .clone();
    out.push(Subsystem { key: "fault_loop", result: r });

    // 2. Eviction churn at ratio 0.25: every revisit refaults, every
    //    admit picks a victim — the intrusive LRU's steady state.
    let r = b
        .case("eviction_churn/lru ratio 0.25", CHURN_OPS, || {
            let policy = eviction::build("lru", 7).expect("lru builds");
            let mut m = DeviceMemory::with_policy(CHURN_DISTINCT / 4, policy);
            for i in 0..CHURN_OPS {
                let p = i % CHURN_DISTINCT;
                if m.state(p, i).is_some() {
                    m.touch(p, i);
                } else {
                    black_box(m.admit(p, i, false, i).len());
                }
            }
            m.evictions
        })
        .clone();
    out.push(Subsystem { key: "eviction_churn", result: r });

    // 3. The fault loop again with the telemetry sink armed (path-less
    //    SimTelemetry, the `--telemetry` observer of DESIGN.md §13):
    //    the same admit+touch stream plus the per-fault hooks the
    //    engine adds. Comparing this row against `fault_loop` is the
    //    tracked evidence that telemetry-on stays near telemetry-off.
    let r = b
        .case("fault_loop_telemetry/admit+touch+spans 16k", FAULT_PAGES, || {
            use crate::telemetry::{FaultSpan, SimTelemetry};
            let mut m = DeviceMemory::new(FAULT_PAGES + 8);
            let mut tel = SimTelemetry::new(None, "perf", 1024);
            for p in 0..FAULT_PAGES {
                black_box(m.state(p, p));
                m.admit(p, p, p % 4 == 0, p);
                m.touch(p, p);
                tel.on_access(p, false);
                tel.on_fault(FaultSpan {
                    at: p,
                    service_at: p,
                    start: p,
                    arrival: p,
                    page: p,
                    pc: 0x10,
                    sm: 0,
                    refault: false,
                });
                tel.set_occupancy(p, p + 1);
            }
            black_box(tel.unresolved());
            m.occupancy()
        })
        .clone();
    out.push(Subsystem { key: "fault_loop_telemetry", result: r });

    // 4. TLB shootdown storm: translate-miss, fill, then a masked
    //    shootdown of exactly the filling SM — the path that replaced
    //    the per-eviction all-SM retain sweep.
    let r = b
        .case("tlb_shootdown/masked storm 30 SMs", STORM_OPS, || {
            let mut g = Gmmu::new(STORM_SMS, 64);
            for i in 0..STORM_OPS {
                let sm = (i % STORM_SMS as u64) as usize;
                if g.translate(sm, i, i, 100) > 0 {
                    g.fill(sm, i, i);
                }
                let mut mask = SmSet::default();
                mask.insert(sm);
                g.shootdown_masked(i, &mask);
            }
            g.misses()
        })
        .clone();
    out.push(Subsystem { key: "tlb_shootdown", result: r });

    out
}

/// End-to-end representative cells: the dense + irregular pair the
/// byte-identity suite also anchors on, at the grid's heaviest
/// pressure ratio plus one unpressured anchor.
fn end_to_end_cells(smoke: bool) -> Vec<CellSpec> {
    let opts = RunOptions {
        scale: 0.05,
        max_instructions: if smoke { 20_000 } else { 60_000 },
        ..Default::default()
    };
    let pairs: &[(&str, &str, f64)] = if smoke {
        &[("addvectors", "tree", 0.25), ("spmv", "none", 0.25)]
    } else {
        &[
            ("addvectors", "none", 0.25),
            ("addvectors", "tree", 0.25),
            ("spmv", "none", 0.25),
            ("spmv", "tree", 0.25),
            ("addvectors", "tree", 1.0),
        ]
    };
    pairs
        .iter()
        .map(|&(b, p, ratio)| CellSpec::new(b, p, &opts).with_oversub(ratio, "lru"))
        .collect()
}

/// Measured end-to-end throughput.
struct EndToEnd {
    names: Vec<String>,
    wall: Duration,
    cells_per_sec: f64,
}

fn run_end_to_end(smoke: bool) -> anyhow::Result<EndToEnd> {
    let cells = end_to_end_cells(smoke);
    let names: Vec<String> = cells
        .iter()
        .map(|c| {
            format!(
                "{}/{}@{:.2}",
                c.benchmark,
                c.prefetcher,
                c.oversub_ratio.unwrap_or(1.0)
            )
        })
        .collect();
    let t0 = Instant::now();
    for cell in &cells {
        let m = cell.run()?;
        black_box(m.cycles);
    }
    let wall = t0.elapsed();
    let cells_per_sec = cells.len() as f64 / wall.as_secs_f64().max(1e-9);
    println!(
        "{:<44} {:>12} cells  wall {:>9.2} ms  {:>10.2} cells/s",
        "end_to_end/oversub representative",
        cells.len(),
        wall.as_secs_f64() * 1e3,
        cells_per_sec
    );
    Ok(EndToEnd { names, wall, cells_per_sec })
}

fn subsystems_json(subs: &[Subsystem]) -> Json {
    let mut m = BTreeMap::new();
    for s in subs {
        let per_sec = if s.result.mean_ns > 0.0 {
            s.result.items as f64 / (s.result.mean_ns / 1e9)
        } else {
            0.0
        };
        m.insert(
            s.key.to_string(),
            Json::obj(vec![
                ("case", Json::str(&s.result.name)),
                ("mean_ns", Json::num(s.result.mean_ns)),
                ("min_ns", Json::num(s.result.min_ns)),
                ("items", Json::num(s.result.items as f64)),
                ("ns_per_item", Json::num(s.result.mean_ns / s.result.items.max(1) as f64)),
                ("items_per_sec", Json::num(per_sec)),
            ]),
        );
    }
    Json::Obj(m)
}

/// Compare measured throughputs against a baseline document. Returns
/// warning lines (empty = within tolerance); pure so the verdict logic
/// is unit-testable without timing anything.
fn check_verdicts(baseline: &Json, cells_per_sec: f64, subs: &[(String, f64)]) -> Vec<String> {
    let mut warnings = Vec::new();
    let floor = |base: f64| base / CHECK_TOLERANCE;
    if let Some(base) = baseline.get("cells_per_sec").and_then(Json::as_f64) {
        if cells_per_sec < floor(base) {
            warnings.push(format!(
                "end_to_end cells/sec regressed: {cells_per_sec:.2} < {:.2} \
                 (baseline {base:.2} / {CHECK_TOLERANCE}x tolerance)",
                floor(base)
            ));
        }
    }
    if let Some(Json::Obj(base_subs)) = baseline.get("subsystems") {
        for (key, per_sec) in subs {
            if let Some(base) = base_subs.get(key).and_then(Json::as_f64) {
                if *per_sec < floor(base) {
                    warnings.push(format!(
                        "{key} items/sec regressed: {per_sec:.0} < {:.0} \
                         (baseline {base:.0} / {CHECK_TOLERANCE}x tolerance)",
                        floor(base)
                    ));
                }
            }
        }
    }
    warnings
}

fn baseline_json(cells_per_sec: f64, subs: &[(String, f64)]) -> Json {
    let mut m = BTreeMap::new();
    for (key, per_sec) in subs {
        m.insert(key.clone(), Json::num(*per_sec));
    }
    Json::obj(vec![
        ("schema", Json::str(PERF_BASELINE_SCHEMA)),
        ("bootstrap", Json::Bool(false)),
        ("cells_per_sec", Json::num(cells_per_sec)),
        ("subsystems", Json::Obj(m)),
    ])
}

fn apply_check(
    path: &Path,
    update: bool,
    cells_per_sec: f64,
    subs: &[(String, f64)],
) -> anyhow::Result<()> {
    if update {
        baseline_json(cells_per_sec, subs).write_file(path)?;
        eprintln!("perf: baseline pinned at {}", path.display());
        return Ok(());
    }
    let doc = match Json::parse_file(path) {
        Ok(d) => d,
        Err(_) => {
            eprintln!(
                "perf: no baseline at {} — bootstrap mode. Measured candidates: \
                 cells/sec {cells_per_sec:.2}; pin with `repro perf --check {} --update`.",
                path.display(),
                path.display()
            );
            return Ok(());
        }
    };
    if doc.get("bootstrap").and_then(Json::as_bool).unwrap_or(false) {
        eprintln!(
            "perf: baseline {} is in bootstrap mode. Measured candidates: cells/sec \
             {cells_per_sec:.2}; pin real numbers with `repro perf --check {} --update`.",
            path.display(),
            path.display()
        );
        return Ok(());
    }
    let warnings = check_verdicts(&doc, cells_per_sec, subs);
    if warnings.is_empty() {
        eprintln!("perf: within {CHECK_TOLERANCE}x of baseline {} — OK", path.display());
    } else {
        for w in &warnings {
            eprintln!("perf: WARNING — {w}");
        }
        eprintln!(
            "perf: {} regression warning(s) vs {} (warn-only: the build stays green; \
             re-pin with --update if the new level is expected)",
            warnings.len(),
            path.display()
        );
    }
    Ok(())
}

/// Entry point for `repro perf`.
pub fn perf(opts: &PerfOptions) -> anyhow::Result<()> {
    eprintln!(
        "perf: running pinned microbench matrix{}…",
        if opts.smoke { " (smoke)" } else { "" }
    );
    let subs = run_subsystems(opts.smoke);
    let e2e = run_end_to_end(opts.smoke)?;

    let results: Vec<BenchResult> = subs.iter().map(|s| s.result.clone()).collect();
    write_bench_sim(&opts.out, "perf_subsystems", &results)?;
    let perf_section = Json::obj(vec![
        ("smoke", Json::Bool(opts.smoke)),
        ("subsystems", subsystems_json(&subs)),
        (
            "end_to_end",
            Json::obj(vec![
                ("cells", Json::num(e2e.names.len() as f64)),
                ("cell_names", Json::arr(e2e.names.iter().map(|n| Json::str(n)))),
                ("wall_ms", Json::num(e2e.wall.as_secs_f64() * 1e3)),
                ("cells_per_sec", Json::num(e2e.cells_per_sec)),
            ]),
        ),
    ]);
    merge_bench_sim_section(&opts.out, "perf", perf_section)?;
    eprintln!("perf: wrote {}", opts.out.display());

    if let Some(check) = &opts.check {
        let sub_rates: Vec<(String, f64)> = subs
            .iter()
            .map(|s| {
                let per_sec = if s.result.mean_ns > 0.0 {
                    s.result.items as f64 / (s.result.mean_ns / 1e9)
                } else {
                    0.0
                };
                (s.key.to_string(), per_sec)
            })
            .collect();
        apply_check(check, opts.update, e2e.cells_per_sec, &sub_rates)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_sets_are_pinned() {
        let full = end_to_end_cells(false);
        let smoke = end_to_end_cells(true);
        assert_eq!(full.len(), 5);
        assert_eq!(smoke.len(), 2);
        assert!(smoke.len() < full.len());
        // Dense + irregular coverage and the heavy-pressure ratio.
        for cells in [&full, &smoke] {
            assert!(cells.iter().any(|c| c.benchmark == "addvectors"));
            assert!(cells.iter().any(|c| c.benchmark == "spmv"));
            assert!(cells.iter().all(|c| c.eviction.as_deref() == Some("lru")));
            assert!(cells.iter().any(|c| c.oversub_ratio == Some(0.25)));
        }
        // The full set keeps one unpressured anchor cell.
        assert!(full.iter().any(|c| c.oversub_ratio == Some(1.0)));
    }

    #[test]
    fn check_verdicts_use_2x_tolerance() {
        let base = baseline_json(100.0, &[("fault_loop".to_string(), 1_000_000.0)]);
        // Half the baseline is exactly the floor — still OK.
        assert!(check_verdicts(&base, 50.0, &[("fault_loop".into(), 500_000.0)]).is_empty());
        // Below the floor warns, once per regressed series.
        let w = check_verdicts(&base, 49.0, &[("fault_loop".into(), 400_000.0)]);
        assert_eq!(w.len(), 2, "{w:?}");
        assert!(w[0].contains("cells/sec"));
        assert!(w[1].contains("fault_loop"));
        // Unknown subsystem keys are ignored (baseline may lag).
        assert!(check_verdicts(&base, 100.0, &[("brand_new".into(), 1.0)]).is_empty());
    }

    #[test]
    fn baseline_round_trips_off_bootstrap() {
        let j = baseline_json(42.0, &[("tlb_shootdown".to_string(), 7.0)]);
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(PERF_BASELINE_SCHEMA));
        assert_eq!(j.get("bootstrap").and_then(Json::as_bool), Some(false));
        assert_eq!(j.get("cells_per_sec").and_then(Json::as_f64), Some(42.0));
        assert_eq!(
            j.get("subsystems").unwrap().get("tlb_shootdown").and_then(Json::as_f64),
            Some(7.0)
        );
    }
}
