//! Experiment runner: build a workload + prefetcher and simulate.

use crate::config::{ExperimentConfig, PredictorBackendKind, RuntimeConfig};
use crate::predictor::{BackendSpec, Precision, PredictorEngine};
use crate::prefetch::dl::DlPrefetcher;
use crate::prefetch::none::NonePrefetcher;
use crate::prefetch::oracle::OraclePrefetcher;
use crate::prefetch::stride::StridePrefetcher;
use crate::prefetch::tree::TreePrefetcher;
use crate::prefetch::uvmsmart::UvmSmartPrefetcher;
use crate::prefetch::{FaultInfo, PrefetchDecision, Prefetcher};
use crate::runtime::Manifest;
use crate::sim::{Metrics, Simulator, TraceWriter};
use crate::types::PageNum;
use crate::workloads::WorkloadRegistry;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Knobs shared by all eval entry points.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Workload scale factor (1.0 = paper-shaped sizes).
    pub scale: f64,
    /// Instruction cap per run (0 = to completion).
    pub max_instructions: u64,
    /// Artifacts directory for the DL policy ("" = the backend's
    /// default: none for stride, `artifacts/` for native/pjrt).
    pub artifacts: String,
    /// Model key override ("" = per-benchmark, then shared).
    pub model: String,
    pub seed: u64,
    /// Predictor backend for the `dl` policy: `"stride"` | `"native"`
    /// | `"transformer"` | `"pjrt"` | `""` (legacy auto: pjrt when
    /// `artifacts` is set, stride otherwise). Unknown names are
    /// rejected by [`RunOptions::backend_kind`].
    pub backend: String,
    /// Kernel tier for inference (`--precision exact | fast | int8 |
    /// int4`). `exact` is the bit-pinned default; the other tiers are
    /// inference-only and validated per backend by
    /// [`crate::predictor::kernel::ensure_supported`].
    pub precision: Precision,
    /// Directory of ingested traces (`repro trace ingest --trace-dir`).
    /// "" = built-in sources only; otherwise the manifest's `trace:*`
    /// entries register alongside the built-ins (see
    /// [`RunOptions::registry`]).
    pub trace_dir: String,
    /// Explicit benchmark selection (`--benchmarks a,b,…`). Empty =
    /// each axis's default grid; names are validated against the
    /// registry before any cell runs.
    pub benchmarks: Vec<String>,
}

impl Default for RunOptions {
    fn default() -> Self {
        // Paper-regime defaults: working sets several times larger
        // than the measurement window (fixed instruction budget, §7.1
        // Table 10), so runs are *partial sweeps* — the regime where
        // aggressive neighborhood prefetching over-fetches beyond the
        // window (U accuracy < 1) and learned prefetching pays off.
        Self {
            scale: 4.0,
            max_instructions: 2_000_000,
            artifacts: String::new(),
            model: String::new(),
            seed: 0x5eed,
            backend: String::new(),
            precision: Precision::Exact,
            trace_dir: String::new(),
            benchmarks: Vec::new(),
        }
    }
}

/// Deterministic per-cell workload seed: a stable FNV-1a hash of the
/// benchmark name folded into the base seed through a splitmix64
/// finalizer. Every policy over the same benchmark sees the *identical*
/// generated workload (the Tables 10/11 U-vs-R comparison requires it),
/// while distinct benchmarks draw independent streams — and the value
/// depends on nothing but `(base, benchmark)`, so serial and parallel
/// sweeps agree bit-for-bit.
pub fn workload_seed(base: u64, benchmark: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in benchmark.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut z = base ^ h;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl RunOptions {
    /// Resolve the `--backend` axis to a [`PredictorBackendKind`];
    /// unknown names are rejected (the CLI surfaces this error before
    /// any cell runs).
    pub fn backend_kind(&self) -> anyhow::Result<PredictorBackendKind> {
        let dir = || {
            if self.artifacts.is_empty() { "artifacts".to_string() } else { self.artifacts.clone() }
        };
        Ok(match self.backend.as_str() {
            "" => {
                if self.artifacts.is_empty() {
                    PredictorBackendKind::Stride
                } else {
                    PredictorBackendKind::Pjrt {
                        artifacts: self.artifacts.clone(),
                        model: self.model.clone(),
                    }
                }
            }
            "stride" => PredictorBackendKind::Stride,
            "native" => {
                PredictorBackendKind::Native { artifacts: dir(), model: self.model.clone() }
            }
            "transformer" => {
                PredictorBackendKind::Transformer { artifacts: dir(), model: self.model.clone() }
            }
            "pjrt" => PredictorBackendKind::Pjrt { artifacts: dir(), model: self.model.clone() },
            other => anyhow::bail!(
                "unknown backend '{other}' (expected stride | native | transformer | pjrt)"
            ),
        })
    }

    /// Effective backend name (resolves the legacy `""` auto mode) —
    /// the tag `BENCH_eval.json` records per cell.
    pub fn backend_name(&self) -> &'static str {
        match self.backend.as_str() {
            "stride" => "stride",
            "native" => "native",
            "transformer" => "transformer",
            "pjrt" => "pjrt",
            _ => {
                if self.artifacts.is_empty() {
                    "stride"
                } else {
                    "pjrt"
                }
            }
        }
    }

    /// The workload registry these options see: every built-in source,
    /// plus the ingested traces under `--trace-dir` when one is set.
    pub fn registry(&self) -> anyhow::Result<WorkloadRegistry> {
        if self.trace_dir.is_empty() {
            Ok(WorkloadRegistry::builtin())
        } else {
            WorkloadRegistry::with_trace_dir(Path::new(&self.trace_dir))
        }
    }

    pub fn experiment(
        &self,
        benchmark: &str,
        prefetcher: &str,
    ) -> anyhow::Result<ExperimentConfig> {
        let mut exp = ExperimentConfig::default();
        exp.benchmark = benchmark.to_string();
        exp.max_instructions = self.max_instructions;
        exp.seed = workload_seed(self.seed, benchmark);
        exp.runtime.prefetcher = prefetcher.to_string();
        exp.runtime.backend = self.backend_kind()?;
        exp.runtime.precision = self.precision;
        Ok(exp)
    }
}

/// Restrict `benchmarks` to the ones the configured backend can serve:
/// the in-process learned backends (native, transformer) need a
/// trained manifest entry of the matching arch per benchmark (or a
/// "shared" model); every other backend covers the full suite.
/// Skipped benchmarks are reported loudly rather than silently
/// degraded — the failure mode this backend axis exists to kill.
pub fn backend_benchmarks(
    opts: &RunOptions,
    benchmarks: &[String],
) -> anyhow::Result<Vec<String>> {
    let (artifacts, model, arch) = match opts.backend_kind()? {
        PredictorBackendKind::Native { artifacts, model } => (artifacts, model, "native"),
        PredictorBackendKind::Transformer { artifacts, model } => {
            (artifacts, model, "transformer")
        }
        _ => return Ok(benchmarks.to_vec()),
    };
    let manifest = Manifest::load(Path::new(&artifacts)).map_err(|e| {
        anyhow::anyhow!(
            "--backend {arch}: {e}; train a model first (`repro train --arch {arch} --workload …`)"
        )
    })?;
    // A benchmark is covered only when its resolved entry actually has
    // the requested arch — a mixed-arch artifacts dir (e.g. a pjrt
    // "shared" fallback) must not smuggle uncovered benchmarks past
    // the filter only to fail mid-sweep.
    let (keep, skip): (Vec<String>, Vec<String>) = benchmarks.iter().cloned().partition(|b| {
        manifest.resolve(&model, b).map(|(_, e)| e.arch == arch).unwrap_or(false)
    });
    if keep.is_empty() {
        anyhow::bail!(
            "--backend {arch}: no trained model covers any requested benchmark; available \
             models: {:?}",
            manifest.models.keys().collect::<Vec<_>>()
        );
    }
    if !skip.is_empty() {
        eprintln!(
            "eval: {arch} backend has no model for {} benchmark(s) [{}] — those cells are \
             skipped; train them with `repro train --arch {arch} --benchmarks <name> …`",
            skip.len(),
            skip.join(", ")
        );
    }
    Ok(keep)
}

/// Records the far-fault page order (for the oracle's replay). The
/// shared handle is `Arc<Mutex<…>>` so the recording pass stays
/// entirely inside one sweep cell while the policy remains `Send`;
/// the lock is uncontended (one simulator thread ever touches it).
struct RecordingPrefetcher {
    order: Arc<Mutex<Vec<PageNum>>>,
}

impl Prefetcher for RecordingPrefetcher {
    fn name(&self) -> &'static str {
        "recording"
    }
    fn on_fault_into(&mut self, fault: &FaultInfo, _out: &mut PrefetchDecision) {
        self.order.lock().expect("recording order lock").push(fault.page);
    }
}

/// Build the DL prefetcher per the configured backend. All manifest /
/// arch / precision resolution lives in the one factory
/// ([`crate::predictor::factory`]) shared with `repro serve`.
pub fn build_dl_prefetcher(
    rcfg: &RuntimeConfig,
    benchmark: &str,
) -> anyhow::Result<DlPrefetcher> {
    let (vocab, backend, _) = BackendSpec::from_runtime(rcfg, benchmark, "dl").resolve()?;
    Ok(DlPrefetcher::new(PredictorEngine::new(backend, vocab), rcfg))
}

/// Build any prefetcher by name. `scale` feeds the oracle's recording
/// pass, which regenerates the workload from `registry` (the config
/// struct has no scale field — `RunOptions` carries it, and each cell
/// passes its own value, so concurrent cells never share state).
pub fn build_prefetcher(
    exp: &ExperimentConfig,
    scale: f64,
    registry: &WorkloadRegistry,
) -> anyhow::Result<Box<dyn Prefetcher>> {
    let rcfg = &exp.runtime;
    Ok(match rcfg.prefetcher.as_str() {
        "none" => Box::new(NonePrefetcher),
        "tree" => Box::new(TreePrefetcher::new(rcfg.tree_threshold)),
        "uvmsmart" => Box::new(UvmSmartPrefetcher::new(
            rcfg.tree_threshold,
            rcfg.pressure_threshold,
        )),
        "stride" => Box::new(StridePrefetcher::default()),
        "dl" => Box::new(build_dl_prefetcher(rcfg, &exp.benchmark)?),
        "oracle" => {
            // Recording pass first (same workload, demand paging).
            let order = Arc::new(Mutex::new(Vec::new()));
            let wl = registry.build(&exp.benchmark, &exp.sim, exp.seed, scale)?;
            let rec = RecordingPrefetcher { order: order.clone() };
            let _ = Simulator::new(exp, wl, Box::new(rec), None).run();
            let order = Arc::try_unwrap(order)
                .map_err(|_| anyhow::anyhow!("order still shared"))?
                .into_inner()
                .expect("recording order lock");
            Box::new(OraclePrefetcher::new(order, 64))
        }
        other => anyhow::bail!("unknown prefetcher '{other}'"),
    })
}

/// Run one benchmark under one policy.
pub fn run_benchmark(
    benchmark: &str,
    prefetcher: &str,
    opts: &RunOptions,
) -> anyhow::Result<Metrics> {
    run_benchmark_with(benchmark, prefetcher, opts, |e| e, None)
}

/// Run with a config tweak (latency sweeps etc.) and optional trace
/// output.
pub fn run_benchmark_with(
    benchmark: &str,
    prefetcher: &str,
    opts: &RunOptions,
    tweak: impl FnOnce(ExperimentConfig) -> ExperimentConfig,
    trace: Option<TraceWriter>,
) -> anyhow::Result<Metrics> {
    run_benchmark_instrumented(benchmark, prefetcher, opts, tweak, trace, None)
}

/// Full-control entry point: everything `run_benchmark_with` offers,
/// plus an optional structured-telemetry output path (`repro simulate
/// --telemetry`, DESIGN.md §13). The telemetry sink is attached before
/// the run so fault spans, rollups, and the prefetcher's post-mortem
/// all cover the whole simulation.
pub fn run_benchmark_instrumented(
    benchmark: &str,
    prefetcher: &str,
    opts: &RunOptions,
    tweak: impl FnOnce(ExperimentConfig) -> ExperimentConfig,
    trace: Option<TraceWriter>,
    telemetry: Option<&Path>,
) -> anyhow::Result<Metrics> {
    let exp = tweak(opts.experiment(benchmark, prefetcher)?);
    exp.sim.validate()?;
    let registry = opts.registry()?;
    let wl = registry.build(benchmark, &exp.sim, exp.seed, opts.scale)?;
    let pf = build_prefetcher(&exp, opts.scale, &registry)?;
    let mut sim = Simulator::new(&exp, wl, pf, trace);
    if let Some(path) = telemetry {
        sim.attach_telemetry(Some(path.to_path_buf()), benchmark);
    }
    Ok(sim.run())
}

/// U-vs-R pair for one benchmark (the unit of Tables 10/11, Fig 12).
#[derive(Debug, Clone)]
pub struct BenchPair {
    pub name: String,
    pub u: Metrics,
    pub r: Metrics,
}

pub fn run_pair(benchmark: &str, opts: &RunOptions) -> anyhow::Result<BenchPair> {
    let u = run_benchmark(benchmark, "uvmsmart", opts)?;
    let r = run_benchmark(benchmark, "dl", opts)?;
    Ok(BenchPair { name: benchmark.to_string(), u, r })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> RunOptions {
        // Small enough to finish in <1 s, but run to *completion* so
        // post-migration hits dominate (a 100 k-instruction cap would
        // end the run while every page is still in flight).
        RunOptions { scale: 0.1, max_instructions: 0, ..Default::default() }
    }

    #[test]
    fn tree_beats_demand_paging_on_page_walks() {
        // ATAX's sweeps walk hundreds of pages per warp — the regime
        // neighborhood prefetching targets. (Streaming kernels at tiny
        // scales give each warp <1 page, where no prefetcher can help.)
        let opts = quick();
        let none = run_benchmark("atax", "none", &opts).unwrap();
        let tree = run_benchmark("atax", "tree", &opts).unwrap();
        assert!(
            tree.page_hit_rate() > none.page_hit_rate(),
            "tree {} !> none {}",
            tree.page_hit_rate(),
            none.page_hit_rate()
        );
        assert!(
            tree.far_faults < none.far_faults,
            "block migration must eliminate faults: {} !< {}",
            tree.far_faults,
            none.far_faults
        );
    }

    #[test]
    fn dl_with_stride_fallback_runs() {
        let opts = quick();
        let m = run_benchmark("atax", "dl", &opts).unwrap();
        assert!(m.mem_accesses > 0);
        assert!(m.predictions + m.bypass_predictions > 0, "some predictions happened");
    }

    #[test]
    fn oracle_approaches_unity_one() {
        let opts = quick();
        let m = run_benchmark("atax", "oracle", &opts).unwrap();
        assert!(m.accuracy() > 0.9, "oracle accuracy {}", m.accuracy());
        assert!(m.unity() > 0.8, "oracle unity {}", m.unity());
    }

    #[test]
    fn unknown_prefetcher_rejected() {
        let opts = quick();
        assert!(run_benchmark("addvectors", "bogus", &opts).is_err());
    }

    #[test]
    fn backend_axis_resolves_and_rejects() {
        let mut opts = quick();
        assert_eq!(opts.backend_kind().unwrap(), PredictorBackendKind::Stride);
        assert_eq!(opts.backend_name(), "stride");

        opts.artifacts = "artifacts".into();
        assert!(matches!(opts.backend_kind().unwrap(), PredictorBackendKind::Pjrt { .. }));
        assert_eq!(opts.backend_name(), "pjrt", "legacy auto mode");

        opts.backend = "stride".into();
        assert_eq!(opts.backend_kind().unwrap(), PredictorBackendKind::Stride);

        opts.backend = "native".into();
        let PredictorBackendKind::Native { artifacts, .. } = opts.backend_kind().unwrap() else {
            panic!("expected native kind");
        };
        assert_eq!(artifacts, "artifacts");
        assert_eq!(opts.backend_name(), "native");

        opts.backend = "transformer".into();
        let PredictorBackendKind::Transformer { artifacts, .. } = opts.backend_kind().unwrap()
        else {
            panic!("expected transformer kind");
        };
        assert_eq!(artifacts, "artifacts");
        assert_eq!(opts.backend_name(), "transformer");

        opts.backend = "bogus".into();
        let err = opts.backend_kind().unwrap_err().to_string();
        assert!(err.contains("stride | native | transformer | pjrt"), "{err}");
        // The error reaches run_benchmark callers too.
        assert!(run_benchmark("addvectors", "dl", &opts).is_err());
    }

    #[test]
    fn native_backend_without_artifacts_fails_loudly() {
        let dir = crate::util::TestDir::new();
        let opts = RunOptions {
            backend: "native".into(),
            artifacts: dir.path().to_string_lossy().into_owned(),
            ..quick()
        };
        let err = run_benchmark("addvectors", "dl", &opts).unwrap_err().to_string();
        assert!(err.contains("repro train"), "{err}");
    }

    #[test]
    fn transformer_backend_without_artifacts_fails_loudly() {
        let dir = crate::util::TestDir::new();
        let opts = RunOptions {
            backend: "transformer".into(),
            artifacts: dir.path().to_string_lossy().into_owned(),
            ..quick()
        };
        let err = run_benchmark("addvectors", "dl", &opts).unwrap_err().to_string();
        assert!(err.contains("repro train --arch transformer"), "{err}");
    }

    #[test]
    fn backend_benchmarks_passes_through_for_stride() {
        let opts = quick();
        let benches = vec!["atax".to_string(), "nw".to_string()];
        assert_eq!(backend_benchmarks(&opts, &benches).unwrap(), benches);
    }
}
