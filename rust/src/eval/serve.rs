//! `repro serve` — the serving load generator: replay N interleaved
//! per-benchmark fault streams (one tenant each) through the sharded
//! coordinator ([`crate::coordinator`]) and report serving telemetry
//! as `BENCH_serve.json` (schema `bench_serve/v1`).
//!
//! Each tenant's stream is harvested deterministically by running its
//! benchmark once under demand paging with a trace writer, then
//! replaying the trace as [`FaultEvent`]s from a dedicated producer
//! thread — so `--streams 4` really is four concurrent clients
//! hammering the same pipeline, the shape the ROADMAP's
//! production-service north star cares about. Per-tenant command
//! *content* is deterministic for a given seed and independent of
//! `--shards` (the shard-determinism test in `rust/tests/serve.rs`
//! pins this); throughput, batch sizes and latency percentiles are the
//! run's measurement.

use crate::config::{BypassMode, ExperimentConfig, RuntimeConfig};
use crate::coordinator::{CoordinatorService, FaultEvent, PrefetchCommand, SpawnOptions};
use crate::eval::runner::{workload_seed, RunOptions};
use crate::predictor::{BackendSpec, DeltaVocab, PredictorBackend};
use crate::prefetch::none::NonePrefetcher;
use crate::sim::{Simulator, TraceWriter, TRACE_HEADER};
use crate::telemetry::export::{prometheus_text, snapshot_json};
use crate::types::{AccessOrigin, TenantId};
use crate::util::{HistSummary, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Knobs for one load-generator run.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Benchmarks to replay; tenant `i` replays `benchmarks[i % len]`.
    pub benchmarks: Vec<String>,
    /// Number of concurrent tenant streams (≥ 1).
    pub streams: usize,
    /// Number of router shards (≥ 1).
    pub shards: usize,
    /// Cap on replayed misses per stream (0 = no cap).
    pub max_faults: usize,
    /// Bypass policy for the serving pipeline. Defaults to `Never` so
    /// the load generator actually measures the batched model path
    /// (under `Auto`, regular streams converge and skip the model).
    pub bypass: BypassMode,
    /// Live metrics export prefix (`--metrics-out PREFIX`): while the
    /// replay runs, `PREFIX.prom` is rewritten with the Prometheus
    /// text exposition and one cumulative snapshot line is appended to
    /// `PREFIX.jsonl` per tick (DESIGN.md §13). `None` = no exporter.
    pub metrics_out: Option<PathBuf>,
    /// Backend/artifacts/seed/scale axes (shared with the eval CLI).
    pub run: RunOptions,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            benchmarks: vec!["addvectors".to_string()],
            streams: 1,
            shards: 1,
            max_faults: 20_000,
            bypass: BypassMode::Never,
            metrics_out: None,
            run: RunOptions { scale: 0.1, ..Default::default() },
        }
    }
}

/// Per-tenant slice of the serving report.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub tenant: TenantId,
    pub benchmark: String,
    pub accesses: usize,
    pub misses: usize,
    pub commands: u64,
    pub migrates: u64,
    pub predicted: u64,
    /// Predicted pages that later showed up in this tenant's realized
    /// fault stream (the serving-side accuracy numerator — see
    /// [`crate::coordinator::stats::TenantStats::note_fault_page`]).
    pub prediction_hits: u64,
    /// `Advise` commands (memory hints) emitted for this tenant.
    pub advises: u64,
    /// `Discard` commands emitted for this tenant.
    pub discards: u64,
    pub latency_us: HistSummary,
}

/// What one load-generator run measured (`BENCH_serve.json` body).
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub backend: String,
    /// Kernel tier the model served with (`--precision`).
    pub precision: String,
    pub streams: usize,
    pub shards: usize,
    pub benchmarks: Vec<String>,
    pub accesses: usize,
    pub misses: usize,
    pub commands: usize,
    pub dropped_commands: u64,
    pub wall_ms: f64,
    /// Replayed misses per wall millisecond — the headline throughput.
    pub faults_per_ms: f64,
    pub accesses_per_ms: f64,
    pub batches: u64,
    /// Mean inference batch size (windows per model call).
    pub mean_batch: f64,
    pub batch_sizes: HistSummary,
    pub batch_latency_us: HistSummary,
    /// Aggregate end-to-end fault→command latency.
    pub latency_us: HistSummary,
    pub tenants: Vec<TenantReport>,
}

/// Resolve the `--backend` axis to a servable (vocab, backend) pair —
/// a thin shim over the one factory ([`crate::predictor::factory`])
/// shared with the `dl` policy. `benchmark` picks the model for
/// artifact-backed kinds (the first replayed benchmark —
/// multi-benchmark runs share one model, like the paper's pretrained
/// "shared" deployment).
pub fn build_serve_backend(
    run: &RunOptions,
    benchmark: &str,
    rcfg: &RuntimeConfig,
) -> Result<(DeltaVocab, Box<dyn PredictorBackend>, &'static str)> {
    BackendSpec {
        kind: run.backend_kind()?,
        precision: run.precision,
        history_len: rcfg.history_len,
        benchmark: benchmark.to_string(),
        who: "serve",
    }
    .resolve()
}

/// Removes the file on drop — the trace temp file must not outlive the
/// run even when reading or parsing fails mid-way.
struct TempFile(PathBuf);

impl Drop for TempFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

/// Parse one trace-CSV data row into a tenant-tagged [`FaultEvent`].
/// Every column access is bounds-checked; errors name the column.
fn parse_trace_line(line: &str, tenant: TenantId) -> Result<FaultEvent> {
    let cols: Vec<&str> = line.split(',').collect();
    if cols.len() < 10 {
        bail!("expected 10 columns (\"{TRACE_HEADER}\"), got {}", cols.len());
    }
    let num = |i: usize, name: &str| -> Result<u64> {
        cols[i]
            .trim()
            .parse::<u64>()
            .map_err(|e| anyhow!("column {i} ({name}) '{}': {e}", cols[i]))
    };
    let miss = match cols[9].trim() {
        "1" => true,
        "0" => false,
        other => bail!("column 9 (miss) must be 0 or 1, got '{other}'"),
    };
    Ok(FaultEvent {
        at: num(0, "cycle")?,
        pc: num(1, "pc")?,
        page: num(2, "page")?,
        origin: AccessOrigin {
            sm: num(3, "sm")? as u16,
            warp: num(4, "warp")? as u16,
            cta: num(5, "cta")? as u32,
            tpc: num(6, "tpc")? as u16,
            kernel_id: num(7, "kernel_id")? as u16,
        },
        miss,
        tenant,
    })
}

/// Read a trace CSV back as a tenant's replayable event stream,
/// stopping after `max_faults` misses (0 = unlimited). Parse errors
/// carry the file path and 1-based line number.
pub fn replay_trace_csv(
    path: &Path,
    tenant: TenantId,
    max_faults: usize,
) -> Result<(Vec<FaultEvent>, usize)> {
    let text = std::fs::read_to_string(path).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    let mut lines = text.lines().enumerate();
    match lines.next() {
        Some((_, header)) if header == TRACE_HEADER => {}
        Some((_, header)) => bail!(
            "{} line 1: expected trace header \"{TRACE_HEADER}\", got \"{header}\"",
            path.display()
        ),
        None => bail!("{}: empty trace file", path.display()),
    }
    let mut events = Vec::new();
    let mut misses = 0usize;
    for (idx, line) in lines {
        if line.is_empty() {
            continue;
        }
        let ev = parse_trace_line(line, tenant)
            .map_err(|e| anyhow!("{} line {}: {e}", path.display(), idx + 1))?;
        misses += ev.miss as usize;
        events.push(ev);
        if max_faults > 0 && misses >= max_faults {
            break;
        }
    }
    Ok((events, misses))
}

/// Harvest tenant `i`'s fault stream: run its benchmark once under
/// demand paging with a trace writer, replay the CSV, and clean the
/// temp file up whatever happens.
fn tenant_stream(
    opts: &ServeOptions,
    tenant: usize,
    benchmark: &str,
) -> Result<(Vec<FaultEvent>, usize)> {
    let exp = ExperimentConfig {
        benchmark: benchmark.to_string(),
        max_instructions: opts.run.max_instructions,
        // Distinct tenants replaying the same benchmark draw
        // independent workload instances (same-tenant reruns stay
        // byte-identical).
        seed: workload_seed(opts.run.seed.wrapping_add(tenant as u64), benchmark),
        ..Default::default()
    };
    let wl = opts.run.registry()?.build(benchmark, &exp.sim, exp.seed, opts.run.scale)?;
    // (pid, sequence, tenant) triple: concurrent `run()` calls in one
    // process (parallel tests) must not collide on a temp path.
    static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = TempFile(
        std::env::temp_dir()
            .join(format!("uvm-serve-{}-{seq}-{tenant}.csv", std::process::id())),
    );
    let limit = if opts.max_faults == 0 { 0 } else { opts.max_faults as u64 * 8 };
    let writer = TraceWriter::create(&tmp.0, limit)?;
    let _ = Simulator::new(&exp, wl, Box::new(NonePrefetcher), Some(writer)).run();
    replay_trace_csv(&tmp.0, tenant as TenantId, opts.max_faults)
        .with_context(|| format!("tenant {tenant} ({benchmark})"))
}

/// Run the load generator: harvest every tenant's stream, replay them
/// concurrently through the sharded coordinator, and measure.
pub fn run(opts: &ServeOptions) -> Result<ServeReport> {
    anyhow::ensure!(opts.streams >= 1, "serve: --streams must be ≥ 1");
    anyhow::ensure!(opts.shards >= 1, "serve: --shards must be ≥ 1");
    anyhow::ensure!(!opts.benchmarks.is_empty(), "serve: need at least one benchmark");
    let rcfg = RuntimeConfig {
        bypass: opts.bypass,
        precision: opts.run.precision,
        ..Default::default()
    };
    let (vocab, backend, backend_name) =
        build_serve_backend(&opts.run, &opts.benchmarks[0], &rcfg)?;

    // Harvest each tenant's stream up front so the measured window
    // contains only serving work.
    let mut streams: Vec<(String, Vec<FaultEvent>, usize)> = Vec::with_capacity(opts.streams);
    for tenant in 0..opts.streams {
        let benchmark = &opts.benchmarks[tenant % opts.benchmarks.len()];
        let (events, misses) = tenant_stream(opts, tenant, benchmark)?;
        eprintln!(
            "serve: tenant {tenant} ({benchmark}): {} accesses, {misses} misses",
            events.len()
        );
        streams.push((benchmark.clone(), events, misses));
    }
    let per_tenant: Vec<(String, usize, usize)> =
        streams.iter().map(|(b, e, m)| (b.clone(), e.len(), *m)).collect();
    let accesses: usize = streams.iter().map(|(_, e, _)| e.len()).sum();
    let misses: usize = streams.iter().map(|(_, _, m)| m).sum();

    let sopts = SpawnOptions {
        shards: opts.shards,
        max_tenants: opts.streams,
        ..Default::default()
    };
    let mut handle = CoordinatorService::spawn(vocab, backend, &rcfg, &sopts);

    // Live metrics exporter: a sidecar thread snapshots the shared
    // [`CoordinatorStats`] every ~50 ms — `PREFIX.prom` is rewritten
    // in place (scrape-file shape), `PREFIX.jsonl` grows one
    // cumulative snapshot per tick. A final pair is always written
    // after the replay drains, so even sub-tick runs export once.
    let exporter_stop = Arc::new(AtomicBool::new(false));
    let exporter = opts.metrics_out.as_ref().map(|prefix| {
        let stats = handle.stats.clone();
        let stop = exporter_stop.clone();
        let prom_path = PathBuf::from(format!("{}.prom", prefix.display()));
        let jsonl_path = PathBuf::from(format!("{}.jsonl", prefix.display()));
        let t0 = std::time::Instant::now();
        std::thread::spawn(move || -> Result<()> {
            let mut jsonl = std::fs::File::create(&jsonl_path)
                .map_err(|e| anyhow!("{}: {e}", jsonl_path.display()))?;
            loop {
                let done = stop.load(Ordering::Relaxed);
                let elapsed = t0.elapsed().as_millis().min(u64::MAX as u128) as u64;
                std::fs::write(&prom_path, prometheus_text(&stats, elapsed))
                    .map_err(|e| anyhow!("{}: {e}", prom_path.display()))?;
                writeln!(jsonl, "{}", snapshot_json(&stats, elapsed).to_string())
                    .map_err(|e| anyhow!("{}: {e}", jsonl_path.display()))?;
                if done {
                    return Ok(());
                }
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        })
    });

    // Drain commands concurrently — a run can emit far more commands
    // than the channel bound, and nothing else consumes them.
    let (dummy_tx, dummy_rx) = std::sync::mpsc::sync_channel(1);
    drop(dummy_tx);
    let commands_rx = std::mem::replace(&mut handle.commands_rx, dummy_rx);
    let drainer = std::thread::spawn(move || {
        let mut cmds: Vec<PrefetchCommand> = Vec::new();
        while let Ok(c) = commands_rx.recv() {
            cmds.push(c);
        }
        cmds
    });

    // One producer thread per tenant, all replaying concurrently.
    let t0 = std::time::Instant::now();
    let mut producers = Vec::with_capacity(opts.streams);
    for (_, events, _) in std::mem::take(&mut streams) {
        let sender = handle.sender();
        producers.push(std::thread::spawn(move || {
            for ev in events {
                if sender.send(ev).is_err() {
                    break;
                }
            }
        }));
    }
    for p in producers {
        p.join().map_err(|_| anyhow!("serve: producer thread panicked"))?;
    }
    let shutdown = handle.shutdown();
    let commands = drainer.join().map_err(|_| anyhow!("serve: drainer thread panicked"))?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    exporter_stop.store(true, Ordering::Relaxed);
    if let Some(t) = exporter {
        t.join().map_err(|_| anyhow!("serve: metrics exporter thread panicked"))??;
    }

    let stats = &shutdown.stats;
    let mut tenants = Vec::with_capacity(opts.streams);
    for (t, (benchmark, t_accesses, t_misses)) in per_tenant.into_iter().enumerate() {
        let ts = stats.tenant(t as TenantId);
        tenants.push(TenantReport {
            tenant: t as TenantId,
            benchmark,
            accesses: t_accesses,
            misses: t_misses,
            commands: ts.commands.load(Ordering::Relaxed),
            migrates: ts.migrates.load(Ordering::Relaxed),
            predicted: ts.predicted.load(Ordering::Relaxed),
            prediction_hits: ts.pred_hits.load(Ordering::Relaxed),
            advises: ts.advises.load(Ordering::Relaxed),
            discards: ts.discards.load(Ordering::Relaxed),
            latency_us: ts.latency_us.summary(),
        });
    }

    Ok(ServeReport {
        backend: backend_name.to_string(),
        precision: opts.run.precision.as_str().to_string(),
        streams: opts.streams,
        shards: opts.shards,
        benchmarks: opts.benchmarks.clone(),
        accesses,
        misses,
        commands: commands.len(),
        dropped_commands: shutdown.dropped_commands,
        wall_ms,
        faults_per_ms: misses as f64 / wall_ms.max(1e-9),
        accesses_per_ms: accesses as f64 / wall_ms.max(1e-9),
        batches: stats.batches.load(Ordering::Relaxed),
        mean_batch: stats.mean_batch(),
        batch_sizes: stats.batch_sizes.summary(),
        batch_latency_us: stats.batch_latency_us.summary(),
        latency_us: stats.latency_summary(),
        tenants,
    })
}

/// `BENCH_serve.json` (schema `bench_serve/v1`).
pub fn bench_serve_json(r: &ServeReport) -> Json {
    Json::obj(vec![
        ("schema", Json::str("bench_serve/v1")),
        ("backend", Json::str(&r.backend)),
        ("precision", Json::str(&r.precision)),
        ("streams", Json::Num(r.streams as f64)),
        ("shards", Json::Num(r.shards as f64)),
        ("benchmarks", Json::arr(r.benchmarks.iter().map(|b| Json::str(b)))),
        ("accesses", Json::Num(r.accesses as f64)),
        ("misses", Json::Num(r.misses as f64)),
        ("commands", Json::Num(r.commands as f64)),
        ("dropped_commands", Json::Num(r.dropped_commands as f64)),
        ("wall_ms", Json::Num(r.wall_ms)),
        ("faults_per_ms", Json::Num(r.faults_per_ms)),
        ("accesses_per_ms", Json::Num(r.accesses_per_ms)),
        ("batches", Json::Num(r.batches as f64)),
        ("mean_batch", Json::Num(r.mean_batch)),
        ("batch_sizes", r.batch_sizes.to_json()),
        ("batch_latency_us", r.batch_latency_us.to_json()),
        ("latency_us", r.latency_us.to_json()),
        (
            "tenants",
            Json::arr(r.tenants.iter().map(|t| {
                Json::obj(vec![
                    ("tenant", Json::Num(t.tenant as f64)),
                    ("benchmark", Json::str(&t.benchmark)),
                    ("accesses", Json::Num(t.accesses as f64)),
                    ("misses", Json::Num(t.misses as f64)),
                    ("commands", Json::Num(t.commands as f64)),
                    ("migrates", Json::Num(t.migrates as f64)),
                    ("predicted", Json::Num(t.predicted as f64)),
                    ("prediction_hits", Json::Num(t.prediction_hits as f64)),
                    ("advises", Json::Num(t.advises as f64)),
                    ("discards", Json::Num(t.discards as f64)),
                    ("latency_us", t.latency_us.to_json()),
                ])
            })),
        ),
    ])
}

/// Write `BENCH_serve.json` for a finished run.
pub fn write_bench_serve(r: &ServeReport, path: &Path) -> Result<()> {
    bench_serve_json(r).write_file(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TestDir;

    #[test]
    fn parse_trace_line_roundtrips_a_writer_row() {
        let ev = parse_trace_line("12,32,7,1,2,3,0,0,1,1", 5).unwrap();
        assert_eq!(ev.at, 12);
        assert_eq!(ev.pc, 32);
        assert_eq!(ev.page, 7);
        assert_eq!(ev.origin.sm, 1);
        assert_eq!(ev.origin.warp, 2);
        assert!(ev.miss);
        assert_eq!(ev.tenant, 5);
    }

    #[test]
    fn short_line_errors_instead_of_panicking() {
        let err = parse_trace_line("1,2,3", 0).unwrap_err().to_string();
        assert!(err.contains("expected 10 columns"), "{err}");
        let err = parse_trace_line("", 0).unwrap_err().to_string();
        assert!(err.contains("got 1"), "{err}");
    }

    #[test]
    fn bad_miss_flag_and_bad_numbers_name_the_column() {
        let err = parse_trace_line("1,2,3,4,5,6,7,8,9,maybe", 0).unwrap_err().to_string();
        assert!(err.contains("column 9 (miss)"), "{err}");
        let err = parse_trace_line("x,2,3,4,5,6,7,8,9,1", 0).unwrap_err().to_string();
        assert!(err.contains("column 0 (cycle)"), "{err}");
    }

    #[test]
    fn replay_attaches_line_numbers_and_caps_misses() {
        let dir = TestDir::new();
        let p = dir.file("t.csv");
        std::fs::write(
            &p,
            format!("{TRACE_HEADER}\n1,2,3,4,5,6,7,8,9,1\n2,2,4,4,5,6,7,8,9,0\ncorrupt\n"),
        )
        .unwrap();
        let err = replay_trace_csv(&p, 0, 0).unwrap_err().to_string();
        assert!(err.contains("line 4"), "{err}");

        // A miss cap stops before the corrupt tail is ever read.
        let (events, misses) = replay_trace_csv(&p, 3, 1).unwrap();
        assert_eq!(misses, 1);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].tenant, 3);
    }

    #[test]
    fn replay_rejects_missing_header_and_missing_file() {
        let dir = TestDir::new();
        let p = dir.file("bad.csv");
        std::fs::write(&p, "1,2,3,4,5,6,7,8,9,1\n").unwrap();
        let err = replay_trace_csv(&p, 0, 0).unwrap_err().to_string();
        assert!(err.contains("expected trace header"), "{err}");
        let err = replay_trace_csv(&dir.file("absent.csv"), 0, 0).unwrap_err().to_string();
        assert!(err.contains("absent.csv"), "{err}");
    }

    #[test]
    fn serve_options_validate() {
        let bad = ServeOptions { streams: 0, ..Default::default() };
        assert!(run(&bad).is_err());
        let bad = ServeOptions { benchmarks: vec![], ..Default::default() };
        assert!(run(&bad).is_err());
    }
}
