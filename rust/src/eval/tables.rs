//! Regeneration of the paper's system-level tables and figures.
//!
//! Each function prints the paper-shaped rows and writes a CSV under
//! `out/`. The paper's own numbers are quoted in doc comments so
//! DESIGN.md §5 can record paper-vs-measured side by side.

use crate::eval::report::{f, Table};
use crate::eval::runner::{backend_benchmarks, run_pair, BenchPair, RunOptions};
use crate::eval::sweep::{self, CellSpec};
use crate::util::geomean;
use std::path::Path;

/// The benchmark axis of a sweep: every registered workload source
/// (dense + irregular + ingested traces when `--trace-dir` is set), or
/// the explicit `--benchmarks` selection validated against the
/// registry — then narrowed to the trained models when an in-process
/// learned backend is selected.
fn grid_benchmarks(opts: &RunOptions) -> anyhow::Result<Vec<String>> {
    let registry = opts.registry()?;
    let all: Vec<String> = if opts.benchmarks.is_empty() {
        registry.all().iter().map(|b| b.to_string()).collect()
    } else {
        for b in &opts.benchmarks {
            if registry.get(b).is_none() {
                return Err(registry.unknown(b));
            }
        }
        opts.benchmarks.clone()
    };
    backend_benchmarks(opts, &all)
}

/// U-vs-R pairs for every benchmark, computed as one parallel sweep
/// over the 11 × {uvmsmart, dl} cell grid. Policy-major cell order
/// (all U cells, then all R cells) keeps concurrent workers on
/// *different* benchmarks, bounding peak workload memory.
fn bench_pairs(opts: &RunOptions) -> anyhow::Result<Vec<BenchPair>> {
    let benches = grid_benchmarks(opts)?;
    let cells = sweep::sweep_cells(&benches, &["uvmsmart", "dl"], opts);
    let threads = sweep::default_threads();
    eprintln!("eval: running {} cells on {threads} threads…", cells.len());
    let outcome = sweep::sweep(&cells, threads)?;
    Ok(pairs_from(&outcome))
}

/// Zip a sweep's `uvmsmart` and `dl` cells into U-vs-R pairs. Both
/// policy slices come back in the grid's benchmark order (the sweep
/// preserves cell order), so pairing is positional.
fn pairs_from(outcome: &sweep::SweepOutcome) -> Vec<BenchPair> {
    let u_cells = outcome.by_prefetcher("uvmsmart");
    let r_cells = outcome.by_prefetcher("dl");
    debug_assert_eq!(u_cells.len(), r_cells.len());
    u_cells
        .iter()
        .zip(&r_cells)
        .map(|(u, r)| {
            debug_assert_eq!(u.benchmark, r.benchmark);
            BenchPair {
                name: u.benchmark.clone(),
                u: u.metrics.clone(),
                r: r.metrics.clone(),
            }
        })
        .collect()
}

/// **Table 10** — page hit rate, UVMSmart (U) vs revised predictor
/// (R). Paper: U mean 0.76, R mean 0.89; e.g. Pathfinder 0.588→0.995.
pub fn table10(opts: &RunOptions, out: &Path) -> anyhow::Result<Table> {
    let pairs = bench_pairs(opts)?;
    let mut t = Table::new(
        "Table 10 — page hit rate (U = UVMSmart, R = revised predictor)",
        &["benchmark", "hit_u", "hit_r", "simulated_inst"],
    );
    for p in &pairs {
        t.row(vec![
            p.name.clone(),
            f(p.u.page_hit_rate(), 6),
            f(p.r.page_hit_rate(), 6),
            p.u.instructions.to_string(),
        ]);
    }
    let mu: Vec<f64> = pairs.iter().map(|p| p.u.page_hit_rate()).collect();
    let mr: Vec<f64> = pairs.iter().map(|p| p.r.page_hit_rate()).collect();
    t.row(vec![
        "MEAN".into(),
        f(mu.iter().sum::<f64>() / mu.len() as f64, 4),
        f(mr.iter().sum::<f64>() / mr.len() as f64, 4),
        String::new(),
    ]);
    t.write_csv(&out.join("table10.csv"))?;
    Ok(t)
}

/// **Table 11** — accuracy / coverage / hit / unity per policy.
/// Paper: U avg unity 0.85, R avg 0.90 (ideal 1.0); U coverage 1.0
/// everywhere, U accuracy avg 0.79, R accuracy avg 0.885.
pub fn table11(opts: &RunOptions, out: &Path) -> anyhow::Result<Table> {
    let pairs = bench_pairs(opts)?;
    let mut t = Table::new(
        "Table 11 — unity (cbrt(Acc × Cov × Hit))",
        &["benchmark", "prefetcher", "acc", "cov", "hit", "unity"],
    );
    for p in &pairs {
        for (tag, m) in [("U", &p.u), ("R", &p.r)] {
            t.row(vec![
                p.name.clone(),
                tag.into(),
                f(m.accuracy(), 2),
                f(m.coverage(), 2),
                f(m.page_hit_rate(), 2),
                f(m.unity(), 2),
            ]);
        }
    }
    let avg = |sel: &dyn Fn(&BenchPair) -> f64| -> f64 {
        pairs.iter().map(sel).sum::<f64>() / pairs.len() as f64
    };
    t.row(vec![
        "AVERAGE".into(),
        "U".into(),
        f(avg(&|p| p.u.accuracy()), 3),
        f(avg(&|p| p.u.coverage()), 3),
        f(avg(&|p| p.u.page_hit_rate()), 3),
        f(avg(&|p| p.u.unity()), 3),
    ]);
    t.row(vec![
        "AVERAGE".into(),
        "R".into(),
        f(avg(&|p| p.r.accuracy()), 3),
        f(avg(&|p| p.r.coverage()), 3),
        f(avg(&|p| p.r.page_hit_rate()), 3),
        f(avg(&|p| p.r.unity()), 3),
    ]);
    t.write_csv(&out.join("table11.csv"))?;
    Ok(t)
}

/// **Figure 10** — normalized IPC (R / U) under prediction overheads
/// of 1, 2, 5 and 10 µs. Paper averages: 1.10×, 1.06×, 1.00×, 0.90×.
pub fn fig10(opts: &RunOptions, out: &Path) -> anyhow::Result<Table> {
    let latencies_us = [1.0, 2.0, 5.0, 10.0];
    let mut t = Table::new(
        "Figure 10 — normalized IPC vs prediction overhead (R / U)",
        &["benchmark", "1us", "2us", "5us", "10us"],
    );
    // One parallel sweep over (1 baseline + 4 latency points) × the
    // benchmark grid, in wave-major order (all baselines, then all
    // 1 µs cells, …) so concurrent workers stay on different
    // benchmarks (peak memory).
    let benches = grid_benchmarks(opts)?;
    let n = benches.len();
    let mut specs: Vec<CellSpec> = benches
        .iter()
        .map(|b| CellSpec::new(b, "uvmsmart", opts))
        .collect();
    for &us in &latencies_us {
        specs.extend(
            benches
                .iter()
                .map(|b| CellSpec::new(b, "dl", opts).with_prediction_us(us)),
        );
    }
    let outcome = sweep::sweep(&specs, sweep::default_threads())?;
    let mut per_lat: Vec<Vec<f64>> = vec![Vec::new(); latencies_us.len()];
    for (bi, b) in benches.iter().enumerate() {
        let u = &outcome.cells[bi].metrics;
        let mut cells = vec![b.to_string()];
        for i in 0..latencies_us.len() {
            let r = &outcome.cells[(i + 1) * n + bi].metrics;
            let norm = r.ipc() / u.ipc();
            per_lat[i].push(norm);
            cells.push(f(norm, 3));
        }
        t.row(cells);
    }
    let mut cells = vec!["AVERAGE".to_string()];
    for v in &per_lat {
        cells.push(f(v.iter().sum::<f64>() / v.len() as f64, 3));
    }
    t.row(cells);
    t.write_csv(&out.join("fig10.csv"))?;
    Ok(t)
}

/// **Figure 11** — PCIe bandwidth timeline for BICG under both
/// policies. Paper: UVMSmart spikes to ~15 GB/s and takes 528 k
/// cycles for the 2 M-instruction slice; the revised predictor stays
/// low and finishes in 392 k cycles.
pub fn fig11(opts: &RunOptions, out: &Path) -> anyhow::Result<Table> {
    // This figure is pinned to BICG; under `--backend native` it can
    // only run when a bicg (or shared) native model exists. Skip
    // loudly instead of aborting `repro eval all` midway.
    if !grid_benchmarks(opts)?.iter().any(|b| b == "bicg") {
        eprintln!(
            "fig11: skipped — the native backend has no model for 'bicg' \
             (train one with `repro train --benchmarks bicg`)"
        );
        let t = Table::new(
            "Figure 11 — skipped (no native model for bicg)",
            &["bucket_start_cycle", "gbps_u", "gbps_r"],
        );
        t.write_csv(&out.join("fig11.csv"))?;
        return Ok(t);
    }
    let mut o = opts.clone();
    if o.max_instructions == 0 || o.max_instructions > 2_000_000 {
        o.max_instructions = 2_000_000; // the paper's slice
    }
    let pair = run_pair("bicg", &o)?;
    let mut t = Table::new(
        "Figure 11 — BICG PCIe usage timeline (GB/s per bucket)",
        &["bucket_start_cycle", "gbps_u", "gbps_r"],
    );
    let clock_hz = 1481e6;
    let to_gbps = |bytes: u64, bucket_cycles: u64| -> f64 {
        bytes as f64 / (bucket_cycles as f64 / clock_hz) / 1e9
    };
    let n = pair.u.pcie_series.len().max(pair.r.pcie_series.len());
    for i in 0..n {
        let (c, bu) = pair.u.pcie_series.get(i).copied().unwrap_or((
            i as u64 * pair.u.pcie_bucket_cycles,
            0,
        ));
        let br = pair.r.pcie_series.get(i).map(|&(_, b)| b).unwrap_or(0);
        t.row(vec![
            c.to_string(),
            f(to_gbps(bu, pair.u.pcie_bucket_cycles), 3),
            f(to_gbps(br, pair.u.pcie_bucket_cycles), 3),
        ]);
    }
    eprintln!(
        "fig11: bicg cycles U={} R={} (paper: 528244 vs 392440)",
        pair.u.cycles, pair.r.cycles
    );
    t.write_csv(&out.join("fig11.csv"))?;
    Ok(t)
}

/// **Figure 12** — normalized PCIe usage (R / U) per benchmark.
/// Paper: geomean reduction 11.05 %.
pub fn fig12(opts: &RunOptions, out: &Path) -> anyhow::Result<Table> {
    let pairs = bench_pairs(opts)?;
    let mut t = Table::new(
        "Figure 12 — normalized PCIe traffic (R / U)",
        &["benchmark", "bytes_u", "bytes_r", "normalized"],
    );
    let mut norms = Vec::new();
    for p in &pairs {
        let norm = p.r.pcie_bytes() as f64 / p.u.pcie_bytes() as f64;
        norms.push(norm);
        t.row(vec![
            p.name.clone(),
            p.u.pcie_bytes().to_string(),
            p.r.pcie_bytes().to_string(),
            f(norm, 3),
        ]);
    }
    t.row(vec!["GEOMEAN".into(), String::new(), String::new(), f(geomean(&norms), 3)]);
    t.write_csv(&out.join("fig12.csv"))?;
    Ok(t)
}

/// **Headline summary** (§7.4/§7.5/§7.6): IPC +10.89 % geomean, hit
/// rate 89.02 % vs 76.10 %, PCIe −11.05 %, unity 0.90 vs 0.85.
///
/// Runs the registry's workload × 6-policy grid as one parallel sweep and
/// writes `BENCH_eval.json` (per-cell wall-clock, total sweep time,
/// speedup vs the serial estimate) next to the CSVs and at the
/// workspace root, so the perf trajectory is tracked per PR.
pub fn summary(opts: &RunOptions, out: &Path) -> anyhow::Result<Table> {
    let benches = grid_benchmarks(opts)?;
    let cells = sweep::sweep_cells(&benches, sweep::SWEEP_PREFETCHERS, opts);
    let threads = sweep::default_threads();
    eprintln!("eval summary: running {} cells on {threads} threads…", cells.len());
    let outcome = sweep::sweep(&cells, threads)?;
    sweep::write_bench_eval(&outcome, &out.join("BENCH_eval.json"))?;
    // Also drop a copy in the process CWD (the workspace root when run
    // via `make`/`cargo run`) — the per-PR perf-trajectory record.
    // Best-effort: an unwritable CWD must not fail the sweep.
    if let Err(e) = sweep::write_bench_eval(&outcome, Path::new("BENCH_eval.json")) {
        eprintln!("eval summary: could not write ./BENCH_eval.json: {e}");
    }
    eprintln!(
        "eval summary: {} cells in {:.1} s on {} threads (serial estimate {:.1} s, speedup {:.2}×)",
        outcome.cells.len(),
        outcome.wall.as_secs_f64(),
        outcome.threads,
        outcome.serial_wall().as_secs_f64(),
        outcome.speedup_vs_serial(),
    );

    let pairs = pairs_from(&outcome);
    let ipc_ratio: Vec<f64> = pairs.iter().map(|p| p.r.ipc() / p.u.ipc()).collect();
    let pcie_ratio: Vec<f64> =
        pairs.iter().map(|p| p.r.pcie_bytes() as f64 / p.u.pcie_bytes() as f64).collect();
    let hit_u: Vec<f64> = pairs.iter().map(|p| p.u.page_hit_rate()).collect();
    let hit_r: Vec<f64> = pairs.iter().map(|p| p.r.page_hit_rate()).collect();
    let unity_u: Vec<f64> = pairs.iter().map(|p| p.u.unity()).collect();
    let unity_r: Vec<f64> = pairs.iter().map(|p| p.r.unity()).collect();
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;

    let mut t = Table::new(
        "Headline summary — paper vs this reproduction",
        &["metric", "paper", "measured"],
    );
    t.row(vec![
        "IPC improvement (geomean)".into(),
        "+10.89%".into(),
        format!("{:+.2}%", (geomean(&ipc_ratio) - 1.0) * 100.0),
    ]);
    t.row(vec![
        "page hit rate U → R (mean)".into(),
        "76.10% → 89.02%".into(),
        format!("{:.2}% → {:.2}%", mean(&hit_u) * 100.0, mean(&hit_r) * 100.0),
    ]);
    t.row(vec![
        "PCIe traffic change (geomean)".into(),
        "-11.05%".into(),
        format!("{:+.2}%", (geomean(&pcie_ratio) - 1.0) * 100.0),
    ]);
    t.row(vec![
        "unity U / R (mean)".into(),
        "0.85 / 0.90".into(),
        format!("{:.2} / {:.2}", mean(&unity_u), mean(&unity_r)),
    ]);
    t.row(vec![
        "sweep wall (parallel)".into(),
        "—".into(),
        format!("{:.1} s on {} threads", outcome.wall.as_secs_f64(), outcome.threads),
    ]);
    t.row(vec![
        "sweep speedup vs serial (est.)".into(),
        "—".into(),
        format!("{:.2}×", outcome.speedup_vs_serial()),
    ]);
    t.write_csv(&out.join("summary.csv"))?;
    Ok(t)
}

/// **Backend pairs** — the U-vs-R comparison at a glance for the
/// configured predictor backend (`repro eval pairs [--backend …]`):
/// per-benchmark hit rate, accuracy, unity and the normalized IPC,
/// tagged with the backend that produced the predictions. This is the
/// quickest way to compare `--backend stride` against a freshly
/// trained `--backend native` model (README "Training the native
/// model").
pub fn pairs(opts: &RunOptions, out: &Path) -> anyhow::Result<Table> {
    let pairs = bench_pairs(opts)?;
    let mut t = Table::new(
        &format!(
            "U-vs-R pairs — dl backend '{}' ({} benchmark(s))",
            opts.backend_name(),
            pairs.len()
        ),
        &["benchmark", "hit_u", "hit_r", "acc_u", "acc_r", "unity_u", "unity_r", "ipc_r_over_u"],
    );
    let mut ipc_norms = Vec::with_capacity(pairs.len());
    for p in &pairs {
        let norm = p.r.ipc() / p.u.ipc();
        ipc_norms.push(norm);
        t.row(vec![
            p.name.clone(),
            f(p.u.page_hit_rate(), 4),
            f(p.r.page_hit_rate(), 4),
            f(p.u.accuracy(), 4),
            f(p.r.accuracy(), 4),
            f(p.u.unity(), 4),
            f(p.r.unity(), 4),
            f(norm, 3),
        ]);
    }
    let mean = |sel: &dyn Fn(&BenchPair) -> f64| -> f64 {
        pairs.iter().map(sel).sum::<f64>() / pairs.len() as f64
    };
    t.row(vec![
        "MEAN".into(),
        f(mean(&|p| p.u.page_hit_rate()), 4),
        f(mean(&|p| p.r.page_hit_rate()), 4),
        f(mean(&|p| p.u.accuracy()), 4),
        f(mean(&|p| p.r.accuracy()), 4),
        f(mean(&|p| p.u.unity()), 4),
        f(mean(&|p| p.r.unity()), 4),
        f(geomean(&ipc_norms), 3),
    ]);
    t.write_csv(&out.join("pairs.csv"))?;
    Ok(t)
}
