//! # uvm-prefetch
//!
//! Reproduction of *"Deep Learning based Data Prefetching in CPU-GPU
//! Unified Virtual Memory"* (Long, Gong, Zhou, Zhang — JPDC 2022) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: a
//!   discrete-event GPU-UVM simulator ([`sim`]), a workload registry
//!   of benchmark access-pattern generators — the paper's dense suite
//!   plus irregular graph/sparse/join kernels — and ingested kernel
//!   traces replayed as workloads ([`workloads`]), the tree-based /
//!   UVMSmart baselines and the DL-driven prefetcher ([`prefetch`]),
//!   the deployment path for the learned predictor — clustering,
//!   history windows, dynamic batching, vocab mapping, online
//!   fine-tuning ([`predictor`]) — and a sharded multi-tenant serving
//!   front with cross-stream batched inference ([`coordinator`]).
//! * **Layer 2 (python/compile/model.py)** — the JAX predictor zoo
//!   (full Transformer, revised HLSH predictor, MLP/LSTM/CNN/FC
//!   baselines), AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels/hlsh.py)** — the paper's HLSH
//!   attention (Algorithm 1) as a Pallas kernel, verified against a
//!   pure-jnp oracle.
//!
//! Python runs only at build time (`make artifacts`); the request path
//! is pure Rust executing the AOT HLO through PJRT ([`runtime`]).
//!
//! See `DESIGN.md` (repo root) for the module inventory, the
//! per-table/figure experiment index, and the paper-vs-measured notes.

// `--features simd` swaps the fast kernel tier's inner loops to
// `std::simd` (nightly only); the stable default compiles the portable
// scalar form instead (predictor/kernel.rs).
#![cfg_attr(feature = "simd", feature(portable_simd))]
// Crate-wide lint posture for `clippy -- -D warnings` (CI): the three
// allows below are deliberate idioms, not oversights — the in-tree
// `Json` serializer exposes an inherent `to_string` (no Display on
// purpose: serialization is not display), the workload builders take
// flat argument lists mirroring the CUDA-kernel signatures they
// transcribe, and configs are built by mutating `::default()` so every
// field keeps its documented default unless overridden.
#![allow(clippy::inherent_to_string)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::field_reassign_with_default)]

pub mod config;
pub mod coordinator;
pub mod eval;
pub mod predictor;
pub mod prefetch;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod types;
pub mod util;
pub mod workloads;
