//! # uvm-prefetch
//!
//! Reproduction of *"Deep Learning based Data Prefetching in CPU-GPU
//! Unified Virtual Memory"* (Long, Gong, Zhou, Zhang — JPDC 2022) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: a
//!   discrete-event GPU-UVM simulator ([`sim`]), eleven benchmark
//!   access-pattern workloads ([`workloads`]), the tree-based /
//!   UVMSmart baselines and the DL-driven prefetcher ([`prefetch`]),
//!   the deployment path for the learned predictor — clustering,
//!   history windows, dynamic batching, vocab mapping, online
//!   fine-tuning ([`predictor`]) — and an async serving front
//!   ([`coordinator`]).
//! * **Layer 2 (python/compile/model.py)** — the JAX predictor zoo
//!   (full Transformer, revised HLSH predictor, MLP/LSTM/CNN/FC
//!   baselines), AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels/hlsh.py)** — the paper's HLSH
//!   attention (Algorithm 1) as a Pallas kernel, verified against a
//!   pure-jnp oracle.
//!
//! Python runs only at build time (`make artifacts`); the request path
//! is pure Rust executing the AOT HLO through PJRT ([`runtime`]).
//!
//! See `DESIGN.md` for the full inventory and the per-table/figure
//! experiment index, and `EXPERIMENTS.md` for paper-vs-measured.

pub mod config;
pub mod coordinator;
pub mod eval;
pub mod predictor;
pub mod prefetch;
pub mod runtime;
pub mod sim;
pub mod types;
pub mod util;
pub mod workloads;
