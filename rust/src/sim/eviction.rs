//! Pluggable eviction policies for [`super::device_memory::DeviceMemory`].
//!
//! Under oversubscription every admit may displace a live page, so the
//! *choice of victim* becomes a first-order knob (the companion work
//! "An Intelligent Framework for Oversubscription Management in
//! CPU-GPU Unified Memory", arXiv:2204.02974, and GPUVM,
//! arXiv:2411.05309). The policy owns only its victim-selection index;
//! residency truth stays in `DeviceMemory`, which drives the policy
//! through the `on_admit` / `on_touch` / `on_remove` hooks and asks it
//! for victims via `pick_victim`.
//!
//! Implementations:
//! * [`LruPolicy`] — least-recently-touched victim. This is the
//!   pre-refactor `DeviceMemory` behaviour, byte-identical: same
//!   `(last_touch, page)` BTreeSet index, same in-order scan that
//!   skips in-flight pages (`tests::lru_reproduces_prerefactor_trace`
//!   pins the recorded eviction sequence).
//! * [`RandomPolicy`] — uniform random victim from a seeded
//!   deterministic RNG; the no-information baseline.
//! * [`FreqPolicy`] — least-frequently-touched victim (LFU), ties
//!   broken by page number; counts reset on eviction.
//! * [`PrefetchAwarePolicy`] — preferentially evicts prefetched pages
//!   that were never demanded (speculative bytes nobody has used yet),
//!   in LRU order; falls back to plain LRU once no unused prefetch is
//!   evictable — the 2204.02974 insight that wrong prefetches, not
//!   demand pages, should absorb the oversubscription penalty.
//!
//! All policies are deterministic for a fixed seed, and `Send` so a
//! whole simulation cell can run on a sweep worker thread.

use crate::sim::device_memory::PageInfo;
use crate::types::{Cycle, PageNum};
use crate::util::XorShift64;
use std::collections::{BTreeSet, HashMap};

/// Canonical policy names accepted by [`build`] (the
/// `SimConfig::eviction_policy` / `repro eval oversub` axis).
pub const ALL_EVICTION_POLICIES: &[&str] = &["lru", "random", "freq", "prefetch-aware"];

/// Victim-selection strategy plugged into `DeviceMemory`.
///
/// The hooks mirror the memory's state transitions exactly once each,
/// so a policy can maintain any index it likes. `pick_victim` must
/// only return pages that are evictable *now* (resident by lazy
/// promotion — in-flight pages are never evicted), or `None` to make
/// the memory over-commit rather than deadlock.
pub trait EvictionPolicy: Send + std::fmt::Debug {
    fn name(&self) -> &'static str;

    /// A page entered device memory (migration scheduled at `now`).
    fn on_admit(&mut self, page: PageNum, now: Cycle, via_prefetch: bool);

    /// A demand touch moved the page's `last_touch` from `prev` to
    /// `now`.
    fn on_touch(&mut self, page: PageNum, prev: Cycle, now: Cycle);

    /// The page was evicted; `info` is its final bookkeeping state.
    fn on_remove(&mut self, page: PageNum, info: &PageInfo);

    /// Choose the next victim among `pages` that are evictable at
    /// `now` (see [`PageInfo::evictable`]).
    fn pick_victim(&mut self, pages: &HashMap<PageNum, PageInfo>, now: Cycle) -> Option<PageNum>;
}

/// Build a policy by name. `seed` feeds stochastic policies so runs
/// stay bit-reproducible (the oversub determinism tests rely on it).
pub fn build(name: &str, seed: u64) -> anyhow::Result<Box<dyn EvictionPolicy>> {
    Ok(match name {
        "lru" => Box::new(LruPolicy::default()),
        "random" => Box::new(RandomPolicy::new(seed)),
        "freq" => Box::new(FreqPolicy::default()),
        "prefetch-aware" => Box::new(PrefetchAwarePolicy::default()),
        other => anyhow::bail!(
            "unknown eviction policy '{other}' (expected one of {ALL_EVICTION_POLICIES:?})"
        ),
    })
}

fn evictable_in(pages: &HashMap<PageNum, PageInfo>, page: PageNum, now: Cycle) -> bool {
    pages.get(&page).is_some_and(|i| i.evictable(now))
}

/// Least-recently-used — the pre-refactor `DeviceMemory` behaviour.
#[derive(Debug, Default)]
pub struct LruPolicy {
    /// `(last_touch, page)`, kept in sync with the memory's
    /// `last_touch` bookkeeping — identical to the old inline index.
    lru: BTreeSet<(Cycle, PageNum)>,
}

impl EvictionPolicy for LruPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn on_admit(&mut self, page: PageNum, now: Cycle, _via_prefetch: bool) {
        self.lru.insert((now, page));
    }

    fn on_touch(&mut self, page: PageNum, prev: Cycle, now: Cycle) {
        self.lru.remove(&(prev, page));
        self.lru.insert((now, page));
    }

    fn on_remove(&mut self, page: PageNum, info: &PageInfo) {
        self.lru.remove(&(info.last_touch, page));
    }

    fn pick_victim(&mut self, pages: &HashMap<PageNum, PageInfo>, now: Cycle) -> Option<PageNum> {
        self.lru
            .iter()
            .copied()
            .find(|&(_, p)| evictable_in(pages, p, now))
            .map(|(_, p)| p)
    }
}

/// Uniform random victim (deterministic for a fixed seed).
#[derive(Debug)]
pub struct RandomPolicy {
    rng: XorShift64,
    /// Resident-set members with O(1) swap-removal.
    members: Vec<PageNum>,
    pos: HashMap<PageNum, usize>,
}

impl RandomPolicy {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: XorShift64::new(seed ^ 0xE71C_7ED0_5EED_0B0E),
            members: Vec::new(),
            pos: HashMap::new(),
        }
    }
}

impl EvictionPolicy for RandomPolicy {
    fn name(&self) -> &'static str {
        "random"
    }

    fn on_admit(&mut self, page: PageNum, _now: Cycle, _via_prefetch: bool) {
        self.pos.insert(page, self.members.len());
        self.members.push(page);
    }

    fn on_touch(&mut self, _page: PageNum, _prev: Cycle, _now: Cycle) {}

    fn on_remove(&mut self, page: PageNum, _info: &PageInfo) {
        if let Some(i) = self.pos.remove(&page) {
            let last = self.members.pop().expect("member list not empty");
            if last != page {
                self.members[i] = last;
                self.pos.insert(last, i);
            }
        }
    }

    fn pick_victim(&mut self, pages: &HashMap<PageNum, PageInfo>, now: Cycle) -> Option<PageNum> {
        if self.members.is_empty() {
            return None;
        }
        // A few random probes (in-flight pages are rare), then a
        // deterministic sweep from a random start so the pick always
        // terminates even when almost everything is in flight.
        let n = self.members.len() as u64;
        for _ in 0..16 {
            let p = self.members[self.rng.below(n) as usize];
            if evictable_in(pages, p, now) {
                return Some(p);
            }
        }
        let start = self.rng.below(n) as usize;
        (0..self.members.len())
            .map(|k| self.members[(start + k) % self.members.len()])
            .find(|&p| evictable_in(pages, p, now))
    }
}

/// Least-frequently-touched victim (LFU); ties broken by page number.
#[derive(Debug, Default)]
pub struct FreqPolicy {
    counts: HashMap<PageNum, u64>,
    /// `(touch_count, page)` — the min entry is the victim candidate.
    index: BTreeSet<(u64, PageNum)>,
}

impl EvictionPolicy for FreqPolicy {
    fn name(&self) -> &'static str {
        "freq"
    }

    fn on_admit(&mut self, page: PageNum, _now: Cycle, _via_prefetch: bool) {
        self.counts.insert(page, 1);
        self.index.insert((1, page));
    }

    fn on_touch(&mut self, page: PageNum, _prev: Cycle, _now: Cycle) {
        if let Some(c) = self.counts.get_mut(&page) {
            self.index.remove(&(*c, page));
            *c += 1;
            self.index.insert((*c, page));
        }
    }

    fn on_remove(&mut self, page: PageNum, _info: &PageInfo) {
        if let Some(c) = self.counts.remove(&page) {
            self.index.remove(&(c, page));
        }
    }

    fn pick_victim(&mut self, pages: &HashMap<PageNum, PageInfo>, now: Cycle) -> Option<PageNum> {
        self.index
            .iter()
            .copied()
            .find(|&(_, p)| evictable_in(pages, p, now))
            .map(|(_, p)| p)
    }
}

/// Evict never-demanded prefetched pages first (LRU order among them),
/// then fall back to plain LRU over everything else.
#[derive(Debug, Default)]
pub struct PrefetchAwarePolicy {
    /// Prefetched copies not yet demanded — the preferred victims.
    unused: BTreeSet<(Cycle, PageNum)>,
    /// Demand pages and demanded prefetches, LRU order.
    lru: BTreeSet<(Cycle, PageNum)>,
}

impl EvictionPolicy for PrefetchAwarePolicy {
    fn name(&self) -> &'static str {
        "prefetch-aware"
    }

    fn on_admit(&mut self, page: PageNum, now: Cycle, via_prefetch: bool) {
        if via_prefetch {
            self.unused.insert((now, page));
        } else {
            self.lru.insert((now, page));
        }
    }

    fn on_touch(&mut self, page: PageNum, prev: Cycle, now: Cycle) {
        // First demand touch of a prefetched copy graduates it out of
        // the preferred-victim set.
        if !self.unused.remove(&(prev, page)) {
            self.lru.remove(&(prev, page));
        }
        self.lru.insert((now, page));
    }

    fn on_remove(&mut self, page: PageNum, info: &PageInfo) {
        let key = (info.last_touch, page);
        if !self.unused.remove(&key) {
            self.lru.remove(&key);
        }
    }

    fn pick_victim(&mut self, pages: &HashMap<PageNum, PageInfo>, now: Cycle) -> Option<PageNum> {
        self.unused
            .iter()
            .chain(self.lru.iter())
            .copied()
            .find(|&(_, p)| evictable_in(pages, p, now))
            .map(|(_, p)| p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device_memory::DeviceMemory;

    #[test]
    fn build_accepts_all_canonical_names_and_rejects_unknown() {
        for name in ALL_EVICTION_POLICIES {
            let p = build(name, 7).unwrap();
            assert_eq!(p.name(), *name);
        }
        assert!(build("bogus", 7).is_err());
    }

    /// The pre-refactor LRU eviction sequence on a recorded trace
    /// (hand-derived from the old inline `evict_lru`: scan
    /// `(last_touch, page)` order, skip in-flight pages). The default
    /// `DeviceMemory` must reproduce it exactly.
    #[test]
    fn lru_reproduces_prerefactor_trace() {
        let mut m = DeviceMemory::new(3);
        assert!(m.admit(1, 0, false, 0).is_empty());
        assert!(m.admit(2, 1, true, 1).is_empty());
        assert!(m.admit(3, 2, false, 2).is_empty());
        m.touch(1, 3); // LRU order now: 2@1, 3@2, 1@3
        assert_eq!(m.admit(4, 10, false, 4), vec![2], "page 2 least recent");
        assert_eq!(m.evicted_unused_prefetches, 1, "2 was an unused prefetch");
        m.touch(3, 5); // order: 1@3, 4@4, 3@5
        assert_eq!(m.admit(5, 20, false, 6), vec![1]);
        // Page 4 is still migrating (arrival 10 > now 7) — skipped.
        assert_eq!(m.admit(6, 30, false, 7), vec![3]);
        assert_eq!(m.evictions, 3);
        assert_eq!(m.evicted_unused_prefetches, 1);
    }

    #[test]
    fn random_is_deterministic_for_a_seed_and_picks_members() {
        let run = |seed: u64| -> Vec<Vec<PageNum>> {
            let mut m = DeviceMemory::with_policy(2, build("random", seed).unwrap());
            let mut evs = Vec::new();
            for p in 0..8u64 {
                evs.push(m.admit(p, p, false, p));
            }
            evs
        };
        assert_eq!(run(42), run(42), "same seed, same victim sequence");
        let evicted: Vec<PageNum> = run(42).into_iter().flatten().collect();
        assert_eq!(evicted.len(), 6, "8 admits into 2 frames evict 6");
        assert!(evicted.iter().all(|&p| p < 8));
    }

    #[test]
    fn freq_evicts_least_frequently_touched() {
        let mut m = DeviceMemory::with_policy(2, build("freq", 0).unwrap());
        m.admit(10, 0, false, 0);
        m.admit(20, 1, false, 1);
        m.touch(10, 2);
        m.touch(10, 3);
        m.touch(20, 4); // counts: 10 → 3, 20 → 2; LRU would evict 10.
        assert_eq!(m.admit(30, 5, false, 5), vec![20], "least-touched loses");
    }

    #[test]
    fn prefetch_aware_prefers_unused_prefetch_over_older_demand_page() {
        let mut m = DeviceMemory::with_policy(2, build("prefetch-aware", 0).unwrap());
        m.admit(1, 0, false, 0); // demand page, oldest — the LRU victim
        m.admit(2, 5, true, 5); // unused prefetch, newer
        assert_eq!(m.admit(3, 6, false, 6), vec![2], "unused prefetch absorbs the eviction");
        // Once demanded, a prefetched page is protected like any other.
        let mut m = DeviceMemory::with_policy(2, build("prefetch-aware", 0).unwrap());
        m.admit(1, 0, false, 0);
        m.admit(2, 5, true, 5);
        m.touch(2, 7); // prefetch used → graduates to the LRU set
        assert_eq!(m.admit(3, 8, false, 8), vec![1], "plain LRU fallback");
    }

    #[test]
    fn all_policies_skip_inflight_pages() {
        for name in ALL_EVICTION_POLICIES {
            let mut m = DeviceMemory::with_policy(1, build(name, 3).unwrap());
            m.admit(1, 1000, false, 0); // still migrating at now=5
            let ev = m.admit(2, 1005, false, 5);
            assert!(ev.is_empty(), "{name}: in-flight page evicted");
            assert_eq!(m.occupancy(), 2, "{name}: over-commit instead");
        }
    }
}
