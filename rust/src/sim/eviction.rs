//! Pluggable eviction policies for [`super::device_memory::DeviceMemory`].
//!
//! Under oversubscription every admit may displace a live page, so the
//! *choice of victim* becomes a first-order knob (the companion work
//! "An Intelligent Framework for Oversubscription Management in
//! CPU-GPU Unified Memory", arXiv:2204.02974, and GPUVM,
//! arXiv:2411.05309). The policy owns only its victim-selection index;
//! residency truth stays in `DeviceMemory`, which drives the policy
//! through the `on_admit` / `on_touch` / `on_remove` hooks and asks it
//! for victims via `pick_victim`.
//!
//! The hooks are frame-indexed (DESIGN.md §12): the memory hands each
//! policy the [`FrameIdx`] of the affected frame-table slot, so a
//! policy keeps its metadata in flat per-frame vectors and its victim
//! ordering in intrusive doubly-linked lists ([`SortedList`]) instead
//! of `BTreeSet`/`HashMap` — same victim sequences (pinned by the
//! recorded-trace tests below and `tests/eviction_props.rs`), no
//! per-touch tree rebalancing or hashing.
//!
//! Implementations:
//! * [`LruPolicy`] — least-recently-touched victim, byte-identical to
//!   the original inline `(last_touch, page)` BTreeSet index: the
//!   intrusive list is kept sorted by that same key, and the pick
//!   scans it in order skipping in-flight pages
//!   (`tests::lru_reproduces_prerefactor_trace` pins the recorded
//!   eviction sequence).
//! * [`RandomPolicy`] — uniform random victim from a seeded
//!   deterministic RNG; the no-information baseline.
//! * [`FreqPolicy`] — least-frequently-touched victim (LFU), ties
//!   broken by page number; counts reset on eviction.
//! * [`PrefetchAwarePolicy`] — preferentially evicts prefetched pages
//!   that were never demanded (speculative bytes nobody has used yet),
//!   in LRU order; falls back to plain LRU once no unused prefetch is
//!   evictable — the 2204.02974 insight that wrong prefetches, not
//!   demand pages, should absorb the oversubscription penalty.
//! * [`LearnedPolicy`] — a logistic scorer over per-page features
//!   (age, touch count, unused-prefetch flag, reuse gap), trained
//!   online from eviction outcomes: a victim that refaults within
//!   [`REFAULT_HORIZON_CYCLES`] was a mispredicted eviction. The
//!   2204.02974 framework distilled to the signals our hooks already
//!   observe.
//!
//! All policies are deterministic for a fixed seed, and `Send` so a
//! whole simulation cell can run on a sweep worker thread.

use crate::sim::device_memory::{Frame, FrameIdx, PageInfo};
use crate::types::{Cycle, PageNum};
use crate::util::XorShift64;
use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};

/// Canonical policy names accepted by [`build`] (the
/// `SimConfig::eviction_policy` / `repro eval oversub` axis).
pub const ALL_EVICTION_POLICIES: &[&str] = &["lru", "random", "freq", "prefetch-aware", "learned"];

/// Outcome horizon for [`LearnedPolicy`]'s online updates: an evicted
/// page that comes back within this many cycles counts as a
/// mispredicted eviction (label 0); one that stays out past it was a
/// good victim (label 1). Exported so BENCH_oversub.json can record
/// the horizon the learned cells were trained under.
pub const REFAULT_HORIZON_CYCLES: u64 = 500_000;

/// Intrusive-list terminator.
const NIL: FrameIdx = u32::MAX;

/// Victim-selection strategy plugged into `DeviceMemory`.
///
/// The hooks mirror the memory's state transitions exactly once each,
/// so a policy can maintain any index it likes; every hook names both
/// the frame slot and the page it holds. `pick_victim` receives the
/// whole frame table (free slots included — a policy only ever
/// indexes it with frames it was admitted) and must only return
/// frames that are evictable *now* (resident by lazy promotion —
/// in-flight pages are never evicted), or `None` to make the memory
/// over-commit rather than deadlock.
pub trait EvictionPolicy: Send + std::fmt::Debug {
    fn name(&self) -> &'static str;

    /// A page entered device memory in frame `frame` (migration
    /// scheduled at `now`).
    fn on_admit(&mut self, frame: FrameIdx, page: PageNum, now: Cycle, via_prefetch: bool);

    /// A demand touch moved the page's `last_touch` from `prev` to
    /// `now`.
    fn on_touch(&mut self, frame: FrameIdx, page: PageNum, prev: Cycle, now: Cycle);

    /// The page left the frame (evicted or discarded); `info` is its
    /// final bookkeeping state. The frame may be reused by a
    /// subsequent `on_admit`.
    fn on_remove(&mut self, frame: FrameIdx, page: PageNum, info: &PageInfo);

    /// Choose the next victim frame among those evictable at `now`
    /// (see [`Frame::evictable`]).
    fn pick_victim(&mut self, frames: &[Frame], now: Cycle) -> Option<FrameIdx>;
}

/// Build a policy by name. `seed` feeds stochastic policies so runs
/// stay bit-reproducible (the oversub determinism tests rely on it).
pub fn build(name: &str, seed: u64) -> anyhow::Result<Box<dyn EvictionPolicy>> {
    Ok(match name {
        "lru" => Box::new(LruPolicy::default()),
        "random" => Box::new(RandomPolicy::new(seed)),
        "freq" => Box::new(FreqPolicy::default()),
        "prefetch-aware" => Box::new(PrefetchAwarePolicy::default()),
        "learned" => Box::new(LearnedPolicy::new(seed)),
        other => anyhow::bail!(
            "unknown eviction policy '{other}' (expected one of {ALL_EVICTION_POLICIES:?})"
        ),
    })
}

/// An intrusive doubly-linked list over frame slots kept sorted by
/// `(stamp, page)` ascending — the exact iteration order of the
/// `BTreeSet<(Cycle, PageNum)>` indexes it replaces, at O(1) amortized
/// per update: stamps arrive in near-sorted event order, so the
/// backward walk from the tail almost always stops immediately.
/// (Stamps are *not* strictly monotone — the MSHR-merge path touches
/// pages with their future arrival cycle — which is why this is a
/// sorted insert and not a plain queue.)
#[derive(Debug)]
struct SortedList {
    stamp: Vec<Cycle>,
    page: Vec<PageNum>,
    prev: Vec<FrameIdx>,
    next: Vec<FrameIdx>,
    linked: Vec<bool>,
    head: FrameIdx,
    tail: FrameIdx,
}

impl Default for SortedList {
    fn default() -> Self {
        SortedList {
            stamp: Vec::new(),
            page: Vec::new(),
            prev: Vec::new(),
            next: Vec::new(),
            linked: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }
}

impl SortedList {
    fn ensure(&mut self, f: FrameIdx) {
        let need = f as usize + 1;
        if self.linked.len() < need {
            self.stamp.resize(need, 0);
            self.page.resize(need, 0);
            self.prev.resize(need, NIL);
            self.next.resize(need, NIL);
            self.linked.resize(need, false);
        }
    }

    fn insert(&mut self, f: FrameIdx, stamp: Cycle, page: PageNum) {
        self.ensure(f);
        let i = f as usize;
        debug_assert!(!self.linked[i], "frame {f} already linked");
        self.stamp[i] = stamp;
        self.page[i] = page;
        let mut cur = self.tail;
        while cur != NIL {
            let c = cur as usize;
            if (self.stamp[c], self.page[c]) > (stamp, page) {
                cur = self.prev[c];
            } else {
                break;
            }
        }
        let next = if cur == NIL { self.head } else { self.next[cur as usize] };
        self.prev[i] = cur;
        self.next[i] = next;
        self.linked[i] = true;
        if cur == NIL {
            self.head = f;
        } else {
            self.next[cur as usize] = f;
        }
        if next == NIL {
            self.tail = f;
        } else {
            self.prev[next as usize] = f;
        }
    }

    /// Unlink `f`; `false` when it was not a member (mirrors
    /// `BTreeSet::remove`, which the two-set policies branch on).
    fn remove(&mut self, f: FrameIdx) -> bool {
        let i = f as usize;
        if i >= self.linked.len() || !self.linked[i] {
            return false;
        }
        let (p, n) = (self.prev[i], self.next[i]);
        if p == NIL {
            self.head = n;
        } else {
            self.next[p as usize] = n;
        }
        if n == NIL {
            self.tail = p;
        } else {
            self.prev[n as usize] = p;
        }
        self.linked[i] = false;
        true
    }

    /// First member (in `(stamp, page)` order) that is evictable now.
    fn pick(&self, frames: &[Frame], now: Cycle) -> Option<FrameIdx> {
        let mut cur = self.head;
        while cur != NIL {
            if frames[cur as usize].evictable(now) {
                return Some(cur);
            }
            cur = self.next[cur as usize];
        }
        None
    }
}

/// Least-recently-used — the pre-refactor `DeviceMemory` behaviour.
#[derive(Debug, Default)]
pub struct LruPolicy {
    /// Sorted by `(last_touch, page)`, kept in sync with the memory's
    /// `last_touch` bookkeeping — identical order to the old inline
    /// BTreeSet index.
    lru: SortedList,
}

impl EvictionPolicy for LruPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn on_admit(&mut self, frame: FrameIdx, page: PageNum, now: Cycle, _via_prefetch: bool) {
        self.lru.insert(frame, now, page);
    }

    fn on_touch(&mut self, frame: FrameIdx, page: PageNum, _prev: Cycle, now: Cycle) {
        self.lru.remove(frame);
        self.lru.insert(frame, now, page);
    }

    fn on_remove(&mut self, frame: FrameIdx, _page: PageNum, _info: &PageInfo) {
        self.lru.remove(frame);
    }

    fn pick_victim(&mut self, frames: &[Frame], now: Cycle) -> Option<FrameIdx> {
        self.lru.pick(frames, now)
    }
}

/// Uniform random victim (deterministic for a fixed seed).
#[derive(Debug)]
pub struct RandomPolicy {
    rng: XorShift64,
    /// Resident frames in admission order with O(1) swap-removal —
    /// the same positional structure (and hence the same RNG-indexed
    /// picks) as the old page-keyed member list.
    members: Vec<FrameIdx>,
    /// Frame → index in `members` (`NIL` when absent).
    pos: Vec<u32>,
}

impl RandomPolicy {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: XorShift64::new(seed ^ 0xE71C_7ED0_5EED_0B0E),
            members: Vec::new(),
            pos: Vec::new(),
        }
    }
}

impl EvictionPolicy for RandomPolicy {
    fn name(&self) -> &'static str {
        "random"
    }

    fn on_admit(&mut self, frame: FrameIdx, _page: PageNum, _now: Cycle, _via_prefetch: bool) {
        if self.pos.len() <= frame as usize {
            self.pos.resize(frame as usize + 1, NIL);
        }
        self.pos[frame as usize] = self.members.len() as u32;
        self.members.push(frame);
    }

    fn on_touch(&mut self, _frame: FrameIdx, _page: PageNum, _prev: Cycle, _now: Cycle) {}

    fn on_remove(&mut self, frame: FrameIdx, _page: PageNum, _info: &PageInfo) {
        let i = self.pos[frame as usize];
        if i == NIL {
            return;
        }
        self.pos[frame as usize] = NIL;
        let last = self.members.pop().expect("member list not empty");
        if last != frame {
            self.members[i as usize] = last;
            self.pos[last as usize] = i;
        }
    }

    fn pick_victim(&mut self, frames: &[Frame], now: Cycle) -> Option<FrameIdx> {
        if self.members.is_empty() {
            return None;
        }
        // A few random probes (in-flight pages are rare), then a
        // deterministic sweep from a random start so the pick always
        // terminates even when almost everything is in flight.
        let n = self.members.len() as u64;
        for _ in 0..16 {
            let f = self.members[self.rng.below(n) as usize];
            if frames[f as usize].evictable(now) {
                return Some(f);
            }
        }
        let start = self.rng.below(n) as usize;
        (0..self.members.len())
            .map(|k| self.members[(start + k) % self.members.len()])
            .find(|&f| frames[f as usize].evictable(now))
    }
}

/// Least-frequently-touched victim (LFU); ties broken by page number.
#[derive(Debug, Default)]
pub struct FreqPolicy {
    /// Per-frame touch counts (0 = frame untracked).
    counts: Vec<u64>,
    /// `(touch_count, page, frame)` — the min entry is the victim
    /// candidate. Pages are unique members, so the trailing frame
    /// index never participates in ordering; it just lets the pick
    /// return a frame without a page→frame lookup. (Kept as a BTreeSet
    /// rather than an intrusive list: a touch moves the entry across
    /// the whole count cohort, which an intrusive list would have to
    /// walk — O(log n) rebalancing beats an O(cohort) scan here.)
    index: BTreeSet<(u64, PageNum, FrameIdx)>,
}

impl EvictionPolicy for FreqPolicy {
    fn name(&self) -> &'static str {
        "freq"
    }

    fn on_admit(&mut self, frame: FrameIdx, page: PageNum, _now: Cycle, _via_prefetch: bool) {
        if self.counts.len() <= frame as usize {
            self.counts.resize(frame as usize + 1, 0);
        }
        self.counts[frame as usize] = 1;
        self.index.insert((1, page, frame));
    }

    fn on_touch(&mut self, frame: FrameIdx, page: PageNum, _prev: Cycle, _now: Cycle) {
        let c = self.counts[frame as usize];
        if c > 0 {
            self.index.remove(&(c, page, frame));
            self.counts[frame as usize] = c + 1;
            self.index.insert((c + 1, page, frame));
        }
    }

    fn on_remove(&mut self, frame: FrameIdx, page: PageNum, _info: &PageInfo) {
        let c = self.counts[frame as usize];
        if c > 0 {
            self.index.remove(&(c, page, frame));
            self.counts[frame as usize] = 0;
        }
    }

    fn pick_victim(&mut self, frames: &[Frame], now: Cycle) -> Option<FrameIdx> {
        self.index
            .iter()
            .find(|&&(_, _, f)| frames[f as usize].evictable(now))
            .map(|&(_, _, f)| f)
    }
}

/// Evict never-demanded prefetched pages first (LRU order among them),
/// then fall back to plain LRU over everything else.
#[derive(Debug, Default)]
pub struct PrefetchAwarePolicy {
    /// Prefetched copies not yet demanded — the preferred victims.
    unused: SortedList,
    /// Demand pages and demanded prefetches, LRU order.
    lru: SortedList,
}

impl EvictionPolicy for PrefetchAwarePolicy {
    fn name(&self) -> &'static str {
        "prefetch-aware"
    }

    fn on_admit(&mut self, frame: FrameIdx, page: PageNum, now: Cycle, via_prefetch: bool) {
        if via_prefetch {
            self.unused.insert(frame, now, page);
        } else {
            self.lru.insert(frame, now, page);
        }
    }

    fn on_touch(&mut self, frame: FrameIdx, page: PageNum, _prev: Cycle, now: Cycle) {
        // First demand touch of a prefetched copy graduates it out of
        // the preferred-victim set.
        if !self.unused.remove(frame) {
            self.lru.remove(frame);
        }
        self.lru.insert(frame, now, page);
    }

    fn on_remove(&mut self, frame: FrameIdx, _page: PageNum, _info: &PageInfo) {
        if !self.unused.remove(frame) {
            self.lru.remove(frame);
        }
    }

    fn pick_victim(&mut self, frames: &[Frame], now: Cycle) -> Option<FrameIdx> {
        self.unused.pick(frames, now).or_else(|| self.lru.pick(frames, now))
    }
}

/// Number of per-page features the learned scorer sees.
const N_FEATURES: usize = 5;
/// Online-SGD step size for the logistic update.
const LEARNED_LR: f64 = 0.05;

/// Per-page observation state feeding [`LearnedPolicy`]'s features.
#[derive(Debug, Clone, Copy, Default)]
struct Track {
    last_touch: Cycle,
    touches: u64,
    via_prefetch: bool,
    /// Demanded at least once since admission.
    used: bool,
    /// Cycles between the last two touches (0 until two touches).
    last_gap: u64,
}

/// `log2(1 + x)` — compresses cycle/count magnitudes into a few units.
fn log2_1p(x: u64) -> f64 {
    (x as f64 + 1.0).log2()
}

/// Logistic eviction scorer (arXiv:2204.02974 distilled to the hook
/// vocabulary): victim = argmax of `w · x` over evictable pages, where
/// `x` is per-page features and `w` starts from an informed prior
/// (old + rarely-touched + unused-prefetch pages look evictable) and
/// is refined online. After each eviction the policy watches for the
/// victim's return: a refault within [`REFAULT_HORIZON_CYCLES`]
/// trains the scorer *down* on that feature vector (the page was
/// live), staying out trains it *up*. Pure integer/f64 arithmetic with
/// a page-ordered member index, so runs are bit-deterministic for a
/// seed; the seed is accepted for interface parity but unused (no
/// stochastic component).
#[derive(Debug)]
pub struct LearnedPolicy {
    w: [f64; N_FEATURES],
    /// Per-frame observation state (valid while `members` maps the
    /// frame's page to it).
    tracks: Vec<Track>,
    /// Page-ordered member index — iterated for victim selection, so
    /// ties break toward the smallest page deterministically (the same
    /// argmax order as the old page-keyed track map).
    members: BTreeMap<PageNum, FrameIdx>,
    /// Victim just returned by `pick_victim`, consumed by the matching
    /// `on_remove` (features frozen at decision time).
    last_pick: Option<(PageNum, [f64; N_FEATURES], Cycle)>,
    /// Evictions awaiting an outcome: page → (evicted_at, features).
    /// Keyed lookup only — never iterated.
    pending: HashMap<PageNum, (Cycle, [f64; N_FEATURES])>,
    /// Eviction order, for horizon expiry of `pending` entries.
    queue: VecDeque<(Cycle, PageNum)>,
}

impl LearnedPolicy {
    pub fn new(_seed: u64) -> Self {
        Self {
            // Prior: age helps (LRU), touch count protects (LFU),
            // unused prefetches are prime victims (prefetch-aware),
            // long reuse gaps mildly help. Sensible before any
            // outcome has been observed.
            w: [1.0, -0.5, 1.0, 0.25, 0.0],
            tracks: Vec::new(),
            members: BTreeMap::new(),
            last_pick: None,
            pending: HashMap::new(),
            queue: VecDeque::new(),
        }
    }

    /// Current feature weights `[age, touches, unused-prefetch,
    /// reuse-gap, bias]` — telemetry/test hook.
    pub fn weights(&self) -> [f64; N_FEATURES] {
        self.w
    }

    fn featurize(t: &Track, now: Cycle) -> [f64; N_FEATURES] {
        [
            log2_1p(now.saturating_sub(t.last_touch)) / 32.0,
            log2_1p(t.touches) / 8.0,
            if t.via_prefetch && !t.used { 1.0 } else { 0.0 },
            log2_1p(t.last_gap) / 32.0,
            1.0,
        ]
    }

    /// One logistic-regression step toward `good` (1 = the eviction
    /// held up, 0 = the victim refaulted inside the horizon).
    fn update(&mut self, x: &[f64; N_FEATURES], good: f64) {
        let z: f64 = self.w.iter().zip(x).map(|(w, f)| w * f).sum();
        let p = 1.0 / (1.0 + (-z).exp());
        for (w, f) in self.w.iter_mut().zip(x) {
            *w += LEARNED_LR * (good - p) * f;
        }
    }

    /// Flush outcomes older than the horizon: victims that never came
    /// back were good evictions.
    fn settle(&mut self, now: Cycle) {
        while let Some(&(at, page)) = self.queue.front() {
            if now.saturating_sub(at) <= REFAULT_HORIZON_CYCLES {
                break;
            }
            self.queue.pop_front();
            // Train only if this entry is still the live outcome for
            // the page (it may have refaulted and been re-evicted,
            // leaving a fresher pending record).
            if let Some(&(pend_at, x)) = self.pending.get(&page) {
                if pend_at == at {
                    self.pending.remove(&page);
                    self.update(&x, 1.0);
                }
            }
        }
    }
}

impl EvictionPolicy for LearnedPolicy {
    fn name(&self) -> &'static str {
        "learned"
    }

    fn on_admit(&mut self, frame: FrameIdx, page: PageNum, now: Cycle, via_prefetch: bool) {
        self.settle(now);
        if let Some((evicted_at, x)) = self.pending.remove(&page) {
            if now.saturating_sub(evicted_at) <= REFAULT_HORIZON_CYCLES {
                self.update(&x, 0.0); // refault inside the horizon: mispredict
            }
        }
        if self.tracks.len() <= frame as usize {
            self.tracks.resize(frame as usize + 1, Track::default());
        }
        self.tracks[frame as usize] =
            Track { last_touch: now, touches: 1, via_prefetch, used: false, last_gap: 0 };
        self.members.insert(page, frame);
    }

    fn on_touch(&mut self, frame: FrameIdx, _page: PageNum, _prev: Cycle, now: Cycle) {
        if let Some(t) = self.tracks.get_mut(frame as usize) {
            t.last_gap = now.saturating_sub(t.last_touch);
            t.last_touch = now;
            t.touches += 1;
            t.used = true;
        }
    }

    fn on_remove(&mut self, _frame: FrameIdx, page: PageNum, _info: &PageInfo) {
        self.members.remove(&page);
        if let Some((picked, x, at)) = self.last_pick.take() {
            if picked == page {
                self.pending.insert(page, (at, x));
                self.queue.push_back((at, page));
            } else {
                // External removal (e.g. a discard) — not our pick;
                // keep the pending decision for its own on_remove.
                self.last_pick = Some((picked, x, at));
            }
        }
    }

    fn pick_victim(&mut self, frames: &[Frame], now: Cycle) -> Option<FrameIdx> {
        let mut best_score = f64::NEG_INFINITY;
        let mut best: Option<(PageNum, FrameIdx, [f64; N_FEATURES])> = None;
        for (&page, &f) in &self.members {
            if !frames[f as usize].evictable(now) {
                continue;
            }
            let x = Self::featurize(&self.tracks[f as usize], now);
            let score: f64 = self.w.iter().zip(&x).map(|(w, f)| w * f).sum();
            if score > best_score {
                best_score = score;
                best = Some((page, f, x));
            }
        }
        let (page, f, x) = best?;
        self.last_pick = Some((page, x, now));
        Some(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device_memory::{DeviceMemory, EvictedPage};

    fn pages(ev: &[EvictedPage]) -> Vec<PageNum> {
        ev.iter().map(|e| e.page).collect()
    }

    #[test]
    fn build_accepts_all_canonical_names_and_rejects_unknown() {
        for name in ALL_EVICTION_POLICIES {
            let p = build(name, 7).unwrap();
            assert_eq!(p.name(), *name);
        }
        assert!(build("bogus", 7).is_err());
    }

    /// The pre-refactor LRU eviction sequence on a recorded trace
    /// (hand-derived from the old inline `evict_lru`: scan
    /// `(last_touch, page)` order, skip in-flight pages). The default
    /// `DeviceMemory` must reproduce it exactly.
    #[test]
    fn lru_reproduces_prerefactor_trace() {
        let mut m = DeviceMemory::new(3);
        assert!(m.admit(1, 0, false, 0).is_empty());
        assert!(m.admit(2, 1, true, 1).is_empty());
        assert!(m.admit(3, 2, false, 2).is_empty());
        m.touch(1, 3); // LRU order now: 2@1, 3@2, 1@3
        assert_eq!(pages(m.admit(4, 10, false, 4)), vec![2], "page 2 least recent");
        assert_eq!(m.evicted_unused_prefetches, 1, "2 was an unused prefetch");
        m.touch(3, 5); // order: 1@3, 4@4, 3@5
        assert_eq!(pages(m.admit(5, 20, false, 6)), vec![1]);
        // Page 4 is still migrating (arrival 10 > now 7) — skipped.
        assert_eq!(pages(m.admit(6, 30, false, 7)), vec![3]);
        assert_eq!(m.evictions, 3);
        assert_eq!(m.evicted_unused_prefetches, 1);
    }

    #[test]
    fn random_is_deterministic_for_a_seed_and_picks_members() {
        let run = |seed: u64| -> Vec<Vec<PageNum>> {
            let mut m = DeviceMemory::with_policy(2, build("random", seed).unwrap());
            let mut evs = Vec::new();
            for p in 0..8u64 {
                evs.push(pages(m.admit(p, p, false, p)));
            }
            evs
        };
        assert_eq!(run(42), run(42), "same seed, same victim sequence");
        let evicted: Vec<PageNum> = run(42).into_iter().flatten().collect();
        assert_eq!(evicted.len(), 6, "8 admits into 2 frames evict 6");
        assert!(evicted.iter().all(|&p| p < 8));
    }

    #[test]
    fn freq_evicts_least_frequently_touched() {
        let mut m = DeviceMemory::with_policy(2, build("freq", 0).unwrap());
        m.admit(10, 0, false, 0);
        m.admit(20, 1, false, 1);
        m.touch(10, 2);
        m.touch(10, 3);
        m.touch(20, 4); // counts: 10 → 3, 20 → 2; LRU would evict 10.
        assert_eq!(pages(m.admit(30, 5, false, 5)), vec![20], "least-touched loses");
    }

    #[test]
    fn prefetch_aware_prefers_unused_prefetch_over_older_demand_page() {
        let mut m = DeviceMemory::with_policy(2, build("prefetch-aware", 0).unwrap());
        m.admit(1, 0, false, 0); // demand page, oldest — the LRU victim
        m.admit(2, 5, true, 5); // unused prefetch, newer
        assert_eq!(pages(m.admit(3, 6, false, 6)), vec![2], "unused prefetch absorbs the eviction");
        // Once demanded, a prefetched page is protected like any other.
        let mut m = DeviceMemory::with_policy(2, build("prefetch-aware", 0).unwrap());
        m.admit(1, 0, false, 0);
        m.admit(2, 5, true, 5);
        m.touch(2, 7); // prefetch used → graduates to the LRU set
        assert_eq!(pages(m.admit(3, 8, false, 8)), vec![1], "plain LRU fallback");
    }

    /// Recorded-trace pin for the learned policy (mirror of
    /// `lru_reproduces_prerefactor_trace`): with the untrained prior
    /// `w = [1, -0.5, 1, 0.25, 0]` the hand-computed scores produce
    /// the eviction sequence [2], [1], [3].
    #[test]
    fn learned_reproduces_recorded_trace() {
        let mut m = DeviceMemory::with_policy(3, build("learned", 7).unwrap());
        assert!(m.admit(1, 0, false, 0).is_empty());
        assert!(m.admit(2, 1, true, 1).is_empty());
        assert!(m.admit(3, 2, false, 2).is_empty());
        m.touch(1, 3);
        // At now=4: page 2 is an unused prefetch (f2 = 1 → score 1.0);
        // pages 1 and 3 score ≈ −0.052 and ≈ −0.013.
        assert_eq!(pages(m.admit(4, 10, false, 4)), vec![2], "unused prefetch dominates");
        assert_eq!(m.evicted_unused_prefetches, 1);
        m.touch(3, 5);
        // At now=6: page 4 still migrating (arrival 10); page 1's age
        // term (touched at 3) beats page 3's (touched at 5).
        assert_eq!(pages(m.admit(5, 20, false, 6)), vec![1]);
        // At now=7 only page 3 is evictable (4 and 5 in flight).
        assert_eq!(pages(m.admit(6, 30, false, 7)), vec![3]);
        assert_eq!(m.evictions, 3);
    }

    /// The online update: a victim that refaults inside the horizon
    /// pushes its features' weights down; one that stays out pushes
    /// them up. Stale queue entries (page re-evicted after a refault)
    /// must not train. Drives the raw policy with hand-built frames
    /// (frame 0 hosts page 10 across its whole lifecycle).
    #[test]
    fn learned_updates_weights_from_refault_outcome() {
        use crate::sim::device_memory::{Frame, PageInfo, PageState};
        let info = |last_touch: Cycle, via_prefetch: bool| PageInfo {
            state: PageState::Resident,
            via_prefetch,
            prefetch_used: false,
            last_touch,
            read_mostly: false,
            pinned: false,
            lazy_discard: false,
        };
        let mut p = LearnedPolicy::new(0);
        let w0 = p.weights();

        // Evict an unused prefetch...
        p.on_admit(0, 10, 0, true);
        let frames = vec![Frame::for_tests(10, info(0, true))];
        assert_eq!(p.pick_victim(&frames, 5), Some(0));
        p.on_remove(0, 10, &info(0, true));
        assert_eq!(p.weights(), w0, "no update until the outcome is known");

        // ...and see it refault within the horizon: mispredict, the
        // unused-prefetch weight drops.
        p.on_admit(0, 10, 100, false);
        let w1 = p.weights();
        assert!(w1[2] < w0[2], "refault trains the driving feature down");

        // Evict it again (now a demand page), then let the horizon
        // expire: good eviction, the bias weight rises. The stale
        // first queue entry for page 10 must be skipped.
        let frames = vec![Frame::for_tests(10, info(100, false))];
        assert_eq!(p.pick_victim(&frames, 101), Some(0));
        p.on_remove(0, 10, &info(100, false));
        p.on_admit(1, 20, 101 + REFAULT_HORIZON_CYCLES + 1, false);
        assert!(p.weights()[4] > w1[4], "surviving the horizon trains toward evict");
    }

    #[test]
    fn all_policies_skip_inflight_pages() {
        for name in ALL_EVICTION_POLICIES {
            let mut m = DeviceMemory::with_policy(1, build(name, 3).unwrap());
            m.admit(1, 1000, false, 0); // still migrating at now=5
            let ev = m.admit(2, 1005, false, 5);
            assert!(ev.is_empty(), "{name}: in-flight page evicted");
            assert_eq!(m.occupancy(), 2, "{name}: over-commit instead");
        }
    }
}
