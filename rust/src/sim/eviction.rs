//! Pluggable eviction policies for [`super::device_memory::DeviceMemory`].
//!
//! Under oversubscription every admit may displace a live page, so the
//! *choice of victim* becomes a first-order knob (the companion work
//! "An Intelligent Framework for Oversubscription Management in
//! CPU-GPU Unified Memory", arXiv:2204.02974, and GPUVM,
//! arXiv:2411.05309). The policy owns only its victim-selection index;
//! residency truth stays in `DeviceMemory`, which drives the policy
//! through the `on_admit` / `on_touch` / `on_remove` hooks and asks it
//! for victims via `pick_victim`.
//!
//! Implementations:
//! * [`LruPolicy`] — least-recently-touched victim. This is the
//!   pre-refactor `DeviceMemory` behaviour, byte-identical: same
//!   `(last_touch, page)` BTreeSet index, same in-order scan that
//!   skips in-flight pages (`tests::lru_reproduces_prerefactor_trace`
//!   pins the recorded eviction sequence).
//! * [`RandomPolicy`] — uniform random victim from a seeded
//!   deterministic RNG; the no-information baseline.
//! * [`FreqPolicy`] — least-frequently-touched victim (LFU), ties
//!   broken by page number; counts reset on eviction.
//! * [`PrefetchAwarePolicy`] — preferentially evicts prefetched pages
//!   that were never demanded (speculative bytes nobody has used yet),
//!   in LRU order; falls back to plain LRU once no unused prefetch is
//!   evictable — the 2204.02974 insight that wrong prefetches, not
//!   demand pages, should absorb the oversubscription penalty.
//! * [`LearnedPolicy`] — a logistic scorer over per-page features
//!   (age, touch count, unused-prefetch flag, reuse gap), trained
//!   online from eviction outcomes: a victim that refaults within
//!   [`REFAULT_HORIZON_CYCLES`] was a mispredicted eviction. The
//!   2204.02974 framework distilled to the signals our hooks already
//!   observe.
//!
//! All policies are deterministic for a fixed seed, and `Send` so a
//! whole simulation cell can run on a sweep worker thread.

use crate::sim::device_memory::PageInfo;
use crate::types::{Cycle, PageNum};
use crate::util::XorShift64;
use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};

/// Canonical policy names accepted by [`build`] (the
/// `SimConfig::eviction_policy` / `repro eval oversub` axis).
pub const ALL_EVICTION_POLICIES: &[&str] = &["lru", "random", "freq", "prefetch-aware", "learned"];

/// Outcome horizon for [`LearnedPolicy`]'s online updates: an evicted
/// page that comes back within this many cycles counts as a
/// mispredicted eviction (label 0); one that stays out past it was a
/// good victim (label 1). Exported so BENCH_oversub.json can record
/// the horizon the learned cells were trained under.
pub const REFAULT_HORIZON_CYCLES: u64 = 500_000;

/// Victim-selection strategy plugged into `DeviceMemory`.
///
/// The hooks mirror the memory's state transitions exactly once each,
/// so a policy can maintain any index it likes. `pick_victim` must
/// only return pages that are evictable *now* (resident by lazy
/// promotion — in-flight pages are never evicted), or `None` to make
/// the memory over-commit rather than deadlock.
pub trait EvictionPolicy: Send + std::fmt::Debug {
    fn name(&self) -> &'static str;

    /// A page entered device memory (migration scheduled at `now`).
    fn on_admit(&mut self, page: PageNum, now: Cycle, via_prefetch: bool);

    /// A demand touch moved the page's `last_touch` from `prev` to
    /// `now`.
    fn on_touch(&mut self, page: PageNum, prev: Cycle, now: Cycle);

    /// The page was evicted; `info` is its final bookkeeping state.
    fn on_remove(&mut self, page: PageNum, info: &PageInfo);

    /// Choose the next victim among `pages` that are evictable at
    /// `now` (see [`PageInfo::evictable`]).
    fn pick_victim(&mut self, pages: &HashMap<PageNum, PageInfo>, now: Cycle) -> Option<PageNum>;
}

/// Build a policy by name. `seed` feeds stochastic policies so runs
/// stay bit-reproducible (the oversub determinism tests rely on it).
pub fn build(name: &str, seed: u64) -> anyhow::Result<Box<dyn EvictionPolicy>> {
    Ok(match name {
        "lru" => Box::new(LruPolicy::default()),
        "random" => Box::new(RandomPolicy::new(seed)),
        "freq" => Box::new(FreqPolicy::default()),
        "prefetch-aware" => Box::new(PrefetchAwarePolicy::default()),
        "learned" => Box::new(LearnedPolicy::new(seed)),
        other => anyhow::bail!(
            "unknown eviction policy '{other}' (expected one of {ALL_EVICTION_POLICIES:?})"
        ),
    })
}

fn evictable_in(pages: &HashMap<PageNum, PageInfo>, page: PageNum, now: Cycle) -> bool {
    pages.get(&page).is_some_and(|i| i.evictable(now))
}

/// Least-recently-used — the pre-refactor `DeviceMemory` behaviour.
#[derive(Debug, Default)]
pub struct LruPolicy {
    /// `(last_touch, page)`, kept in sync with the memory's
    /// `last_touch` bookkeeping — identical to the old inline index.
    lru: BTreeSet<(Cycle, PageNum)>,
}

impl EvictionPolicy for LruPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn on_admit(&mut self, page: PageNum, now: Cycle, _via_prefetch: bool) {
        self.lru.insert((now, page));
    }

    fn on_touch(&mut self, page: PageNum, prev: Cycle, now: Cycle) {
        self.lru.remove(&(prev, page));
        self.lru.insert((now, page));
    }

    fn on_remove(&mut self, page: PageNum, info: &PageInfo) {
        self.lru.remove(&(info.last_touch, page));
    }

    fn pick_victim(&mut self, pages: &HashMap<PageNum, PageInfo>, now: Cycle) -> Option<PageNum> {
        self.lru
            .iter()
            .copied()
            .find(|&(_, p)| evictable_in(pages, p, now))
            .map(|(_, p)| p)
    }
}

/// Uniform random victim (deterministic for a fixed seed).
#[derive(Debug)]
pub struct RandomPolicy {
    rng: XorShift64,
    /// Resident-set members with O(1) swap-removal.
    members: Vec<PageNum>,
    pos: HashMap<PageNum, usize>,
}

impl RandomPolicy {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: XorShift64::new(seed ^ 0xE71C_7ED0_5EED_0B0E),
            members: Vec::new(),
            pos: HashMap::new(),
        }
    }
}

impl EvictionPolicy for RandomPolicy {
    fn name(&self) -> &'static str {
        "random"
    }

    fn on_admit(&mut self, page: PageNum, _now: Cycle, _via_prefetch: bool) {
        self.pos.insert(page, self.members.len());
        self.members.push(page);
    }

    fn on_touch(&mut self, _page: PageNum, _prev: Cycle, _now: Cycle) {}

    fn on_remove(&mut self, page: PageNum, _info: &PageInfo) {
        if let Some(i) = self.pos.remove(&page) {
            let last = self.members.pop().expect("member list not empty");
            if last != page {
                self.members[i] = last;
                self.pos.insert(last, i);
            }
        }
    }

    fn pick_victim(&mut self, pages: &HashMap<PageNum, PageInfo>, now: Cycle) -> Option<PageNum> {
        if self.members.is_empty() {
            return None;
        }
        // A few random probes (in-flight pages are rare), then a
        // deterministic sweep from a random start so the pick always
        // terminates even when almost everything is in flight.
        let n = self.members.len() as u64;
        for _ in 0..16 {
            let p = self.members[self.rng.below(n) as usize];
            if evictable_in(pages, p, now) {
                return Some(p);
            }
        }
        let start = self.rng.below(n) as usize;
        (0..self.members.len())
            .map(|k| self.members[(start + k) % self.members.len()])
            .find(|&p| evictable_in(pages, p, now))
    }
}

/// Least-frequently-touched victim (LFU); ties broken by page number.
#[derive(Debug, Default)]
pub struct FreqPolicy {
    counts: HashMap<PageNum, u64>,
    /// `(touch_count, page)` — the min entry is the victim candidate.
    index: BTreeSet<(u64, PageNum)>,
}

impl EvictionPolicy for FreqPolicy {
    fn name(&self) -> &'static str {
        "freq"
    }

    fn on_admit(&mut self, page: PageNum, _now: Cycle, _via_prefetch: bool) {
        self.counts.insert(page, 1);
        self.index.insert((1, page));
    }

    fn on_touch(&mut self, page: PageNum, _prev: Cycle, _now: Cycle) {
        if let Some(c) = self.counts.get_mut(&page) {
            self.index.remove(&(*c, page));
            *c += 1;
            self.index.insert((*c, page));
        }
    }

    fn on_remove(&mut self, page: PageNum, _info: &PageInfo) {
        if let Some(c) = self.counts.remove(&page) {
            self.index.remove(&(c, page));
        }
    }

    fn pick_victim(&mut self, pages: &HashMap<PageNum, PageInfo>, now: Cycle) -> Option<PageNum> {
        self.index
            .iter()
            .copied()
            .find(|&(_, p)| evictable_in(pages, p, now))
            .map(|(_, p)| p)
    }
}

/// Evict never-demanded prefetched pages first (LRU order among them),
/// then fall back to plain LRU over everything else.
#[derive(Debug, Default)]
pub struct PrefetchAwarePolicy {
    /// Prefetched copies not yet demanded — the preferred victims.
    unused: BTreeSet<(Cycle, PageNum)>,
    /// Demand pages and demanded prefetches, LRU order.
    lru: BTreeSet<(Cycle, PageNum)>,
}

impl EvictionPolicy for PrefetchAwarePolicy {
    fn name(&self) -> &'static str {
        "prefetch-aware"
    }

    fn on_admit(&mut self, page: PageNum, now: Cycle, via_prefetch: bool) {
        if via_prefetch {
            self.unused.insert((now, page));
        } else {
            self.lru.insert((now, page));
        }
    }

    fn on_touch(&mut self, page: PageNum, prev: Cycle, now: Cycle) {
        // First demand touch of a prefetched copy graduates it out of
        // the preferred-victim set.
        if !self.unused.remove(&(prev, page)) {
            self.lru.remove(&(prev, page));
        }
        self.lru.insert((now, page));
    }

    fn on_remove(&mut self, page: PageNum, info: &PageInfo) {
        let key = (info.last_touch, page);
        if !self.unused.remove(&key) {
            self.lru.remove(&key);
        }
    }

    fn pick_victim(&mut self, pages: &HashMap<PageNum, PageInfo>, now: Cycle) -> Option<PageNum> {
        self.unused
            .iter()
            .chain(self.lru.iter())
            .copied()
            .find(|&(_, p)| evictable_in(pages, p, now))
            .map(|(_, p)| p)
    }
}

/// Number of per-page features the learned scorer sees.
const N_FEATURES: usize = 5;
/// Online-SGD step size for the logistic update.
const LEARNED_LR: f64 = 0.05;

/// Per-page observation state feeding [`LearnedPolicy`]'s features.
#[derive(Debug, Clone, Copy)]
struct Track {
    last_touch: Cycle,
    touches: u64,
    via_prefetch: bool,
    /// Demanded at least once since admission.
    used: bool,
    /// Cycles between the last two touches (0 until two touches).
    last_gap: u64,
}

/// `log2(1 + x)` — compresses cycle/count magnitudes into a few units.
fn log2_1p(x: u64) -> f64 {
    (x as f64 + 1.0).log2()
}

/// Logistic eviction scorer (arXiv:2204.02974 distilled to the hook
/// vocabulary): victim = argmax of `w · x` over evictable pages, where
/// `x` is per-page features and `w` starts from an informed prior
/// (old + rarely-touched + unused-prefetch pages look evictable) and
/// is refined online. After each eviction the policy watches for the
/// victim's return: a refault within [`REFAULT_HORIZON_CYCLES`]
/// trains the scorer *down* on that feature vector (the page was
/// live), staying out trains it *up*. Pure integer/f64 arithmetic over
/// a `BTreeMap` index, so runs are bit-deterministic for a seed; the
/// seed is accepted for interface parity but unused (no stochastic
/// component).
#[derive(Debug)]
pub struct LearnedPolicy {
    w: [f64; N_FEATURES],
    /// Page-ordered member index — iterated for victim selection, so
    /// ties break toward the smallest page deterministically.
    tracks: BTreeMap<PageNum, Track>,
    /// Victim just returned by `pick_victim`, consumed by the matching
    /// `on_remove` (features frozen at decision time).
    last_pick: Option<(PageNum, [f64; N_FEATURES], Cycle)>,
    /// Evictions awaiting an outcome: page → (evicted_at, features).
    /// Keyed lookup only — never iterated.
    pending: HashMap<PageNum, (Cycle, [f64; N_FEATURES])>,
    /// Eviction order, for horizon expiry of `pending` entries.
    queue: VecDeque<(Cycle, PageNum)>,
}

impl LearnedPolicy {
    pub fn new(_seed: u64) -> Self {
        Self {
            // Prior: age helps (LRU), touch count protects (LFU),
            // unused prefetches are prime victims (prefetch-aware),
            // long reuse gaps mildly help. Sensible before any
            // outcome has been observed.
            w: [1.0, -0.5, 1.0, 0.25, 0.0],
            tracks: BTreeMap::new(),
            last_pick: None,
            pending: HashMap::new(),
            queue: VecDeque::new(),
        }
    }

    /// Current feature weights `[age, touches, unused-prefetch,
    /// reuse-gap, bias]` — telemetry/test hook.
    pub fn weights(&self) -> [f64; N_FEATURES] {
        self.w
    }

    fn featurize(t: &Track, now: Cycle) -> [f64; N_FEATURES] {
        [
            log2_1p(now.saturating_sub(t.last_touch)) / 32.0,
            log2_1p(t.touches) / 8.0,
            if t.via_prefetch && !t.used { 1.0 } else { 0.0 },
            log2_1p(t.last_gap) / 32.0,
            1.0,
        ]
    }

    /// One logistic-regression step toward `good` (1 = the eviction
    /// held up, 0 = the victim refaulted inside the horizon).
    fn update(&mut self, x: &[f64; N_FEATURES], good: f64) {
        let z: f64 = self.w.iter().zip(x).map(|(w, f)| w * f).sum();
        let p = 1.0 / (1.0 + (-z).exp());
        for (w, f) in self.w.iter_mut().zip(x) {
            *w += LEARNED_LR * (good - p) * f;
        }
    }

    /// Flush outcomes older than the horizon: victims that never came
    /// back were good evictions.
    fn settle(&mut self, now: Cycle) {
        while let Some(&(at, page)) = self.queue.front() {
            if now.saturating_sub(at) <= REFAULT_HORIZON_CYCLES {
                break;
            }
            self.queue.pop_front();
            // Train only if this entry is still the live outcome for
            // the page (it may have refaulted and been re-evicted,
            // leaving a fresher pending record).
            if let Some(&(pend_at, x)) = self.pending.get(&page) {
                if pend_at == at {
                    self.pending.remove(&page);
                    self.update(&x, 1.0);
                }
            }
        }
    }
}

impl EvictionPolicy for LearnedPolicy {
    fn name(&self) -> &'static str {
        "learned"
    }

    fn on_admit(&mut self, page: PageNum, now: Cycle, via_prefetch: bool) {
        self.settle(now);
        if let Some((evicted_at, x)) = self.pending.remove(&page) {
            if now.saturating_sub(evicted_at) <= REFAULT_HORIZON_CYCLES {
                self.update(&x, 0.0); // refault inside the horizon: mispredict
            }
        }
        self.tracks.insert(
            page,
            Track { last_touch: now, touches: 1, via_prefetch, used: false, last_gap: 0 },
        );
    }

    fn on_touch(&mut self, page: PageNum, _prev: Cycle, now: Cycle) {
        if let Some(t) = self.tracks.get_mut(&page) {
            t.last_gap = now.saturating_sub(t.last_touch);
            t.last_touch = now;
            t.touches += 1;
            t.used = true;
        }
    }

    fn on_remove(&mut self, page: PageNum, _info: &PageInfo) {
        self.tracks.remove(&page);
        if let Some((picked, x, at)) = self.last_pick.take() {
            if picked == page {
                self.pending.insert(page, (at, x));
                self.queue.push_back((at, page));
            } else {
                // External removal (e.g. a discard) — not our pick;
                // keep the pending decision for its own on_remove.
                self.last_pick = Some((picked, x, at));
            }
        }
    }

    fn pick_victim(&mut self, pages: &HashMap<PageNum, PageInfo>, now: Cycle) -> Option<PageNum> {
        let mut best_score = f64::NEG_INFINITY;
        let mut best: Option<(PageNum, [f64; N_FEATURES])> = None;
        for (&page, track) in &self.tracks {
            if !evictable_in(pages, page, now) {
                continue;
            }
            let x = Self::featurize(track, now);
            let score: f64 = self.w.iter().zip(&x).map(|(w, f)| w * f).sum();
            if score > best_score {
                best_score = score;
                best = Some((page, x));
            }
        }
        let (page, x) = best?;
        self.last_pick = Some((page, x, now));
        Some(page)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device_memory::DeviceMemory;

    #[test]
    fn build_accepts_all_canonical_names_and_rejects_unknown() {
        for name in ALL_EVICTION_POLICIES {
            let p = build(name, 7).unwrap();
            assert_eq!(p.name(), *name);
        }
        assert!(build("bogus", 7).is_err());
    }

    /// The pre-refactor LRU eviction sequence on a recorded trace
    /// (hand-derived from the old inline `evict_lru`: scan
    /// `(last_touch, page)` order, skip in-flight pages). The default
    /// `DeviceMemory` must reproduce it exactly.
    #[test]
    fn lru_reproduces_prerefactor_trace() {
        let mut m = DeviceMemory::new(3);
        assert!(m.admit(1, 0, false, 0).is_empty());
        assert!(m.admit(2, 1, true, 1).is_empty());
        assert!(m.admit(3, 2, false, 2).is_empty());
        m.touch(1, 3); // LRU order now: 2@1, 3@2, 1@3
        assert_eq!(m.admit(4, 10, false, 4), vec![2], "page 2 least recent");
        assert_eq!(m.evicted_unused_prefetches, 1, "2 was an unused prefetch");
        m.touch(3, 5); // order: 1@3, 4@4, 3@5
        assert_eq!(m.admit(5, 20, false, 6), vec![1]);
        // Page 4 is still migrating (arrival 10 > now 7) — skipped.
        assert_eq!(m.admit(6, 30, false, 7), vec![3]);
        assert_eq!(m.evictions, 3);
        assert_eq!(m.evicted_unused_prefetches, 1);
    }

    #[test]
    fn random_is_deterministic_for_a_seed_and_picks_members() {
        let run = |seed: u64| -> Vec<Vec<PageNum>> {
            let mut m = DeviceMemory::with_policy(2, build("random", seed).unwrap());
            let mut evs = Vec::new();
            for p in 0..8u64 {
                evs.push(m.admit(p, p, false, p));
            }
            evs
        };
        assert_eq!(run(42), run(42), "same seed, same victim sequence");
        let evicted: Vec<PageNum> = run(42).into_iter().flatten().collect();
        assert_eq!(evicted.len(), 6, "8 admits into 2 frames evict 6");
        assert!(evicted.iter().all(|&p| p < 8));
    }

    #[test]
    fn freq_evicts_least_frequently_touched() {
        let mut m = DeviceMemory::with_policy(2, build("freq", 0).unwrap());
        m.admit(10, 0, false, 0);
        m.admit(20, 1, false, 1);
        m.touch(10, 2);
        m.touch(10, 3);
        m.touch(20, 4); // counts: 10 → 3, 20 → 2; LRU would evict 10.
        assert_eq!(m.admit(30, 5, false, 5), vec![20], "least-touched loses");
    }

    #[test]
    fn prefetch_aware_prefers_unused_prefetch_over_older_demand_page() {
        let mut m = DeviceMemory::with_policy(2, build("prefetch-aware", 0).unwrap());
        m.admit(1, 0, false, 0); // demand page, oldest — the LRU victim
        m.admit(2, 5, true, 5); // unused prefetch, newer
        assert_eq!(m.admit(3, 6, false, 6), vec![2], "unused prefetch absorbs the eviction");
        // Once demanded, a prefetched page is protected like any other.
        let mut m = DeviceMemory::with_policy(2, build("prefetch-aware", 0).unwrap());
        m.admit(1, 0, false, 0);
        m.admit(2, 5, true, 5);
        m.touch(2, 7); // prefetch used → graduates to the LRU set
        assert_eq!(m.admit(3, 8, false, 8), vec![1], "plain LRU fallback");
    }

    /// Recorded-trace pin for the learned policy (mirror of
    /// `lru_reproduces_prerefactor_trace`): with the untrained prior
    /// `w = [1, -0.5, 1, 0.25, 0]` the hand-computed scores produce
    /// the eviction sequence [2], [1], [3].
    #[test]
    fn learned_reproduces_recorded_trace() {
        let mut m = DeviceMemory::with_policy(3, build("learned", 7).unwrap());
        assert!(m.admit(1, 0, false, 0).is_empty());
        assert!(m.admit(2, 1, true, 1).is_empty());
        assert!(m.admit(3, 2, false, 2).is_empty());
        m.touch(1, 3);
        // At now=4: page 2 is an unused prefetch (f2 = 1 → score 1.0);
        // pages 1 and 3 score ≈ −0.052 and ≈ −0.013.
        assert_eq!(m.admit(4, 10, false, 4), vec![2], "unused prefetch dominates");
        assert_eq!(m.evicted_unused_prefetches, 1);
        m.touch(3, 5);
        // At now=6: page 4 still migrating (arrival 10); page 1's age
        // term (touched at 3) beats page 3's (touched at 5).
        assert_eq!(m.admit(5, 20, false, 6), vec![1]);
        // At now=7 only page 3 is evictable (4 and 5 in flight).
        assert_eq!(m.admit(6, 30, false, 7), vec![3]);
        assert_eq!(m.evictions, 3);
    }

    /// The online update: a victim that refaults inside the horizon
    /// pushes its features' weights down; one that stays out pushes
    /// them up. Stale queue entries (page re-evicted after a refault)
    /// must not train.
    #[test]
    fn learned_updates_weights_from_refault_outcome() {
        use crate::sim::device_memory::{PageInfo, PageState};
        let info = |last_touch: Cycle, via_prefetch: bool| PageInfo {
            state: PageState::Resident,
            via_prefetch,
            prefetch_used: false,
            last_touch,
            read_mostly: false,
            pinned: false,
            lazy_discard: false,
        };
        let mut p = LearnedPolicy::new(0);
        let w0 = p.weights();

        // Evict an unused prefetch...
        p.on_admit(10, 0, true);
        let pages: HashMap<PageNum, PageInfo> = [(10, info(0, true))].into_iter().collect();
        assert_eq!(p.pick_victim(&pages, 5), Some(10));
        p.on_remove(10, &pages[&10]);
        assert_eq!(p.weights(), w0, "no update until the outcome is known");

        // ...and see it refault within the horizon: mispredict, the
        // unused-prefetch weight drops.
        p.on_admit(10, 100, false);
        let w1 = p.weights();
        assert!(w1[2] < w0[2], "refault trains the driving feature down");

        // Evict it again (now a demand page), then let the horizon
        // expire: good eviction, the bias weight rises. The stale
        // first queue entry for page 10 must be skipped.
        let pages: HashMap<PageNum, PageInfo> = [(10, info(100, false))].into_iter().collect();
        assert_eq!(p.pick_victim(&pages, 101), Some(10));
        p.on_remove(10, &pages[&10]);
        p.on_admit(20, 101 + REFAULT_HORIZON_CYCLES + 1, false);
        assert!(p.weights()[4] > w1[4], "surviving the horizon trains toward evict");
    }

    #[test]
    fn all_policies_skip_inflight_pages() {
        for name in ALL_EVICTION_POLICIES {
            let mut m = DeviceMemory::with_policy(1, build(name, 3).unwrap());
            m.admit(1, 1000, false, 0); // still migrating at now=5
            let ev = m.admit(2, 1005, false, 5);
            assert!(ev.is_empty(), "{name}: in-flight page evicted");
            assert_eq!(m.occupancy(), 2, "{name}: over-commit instead");
        }
    }
}
