//! Device-memory residency tracking: a dense frame table with a
//! free-list allocator, page→frame translation through a two-level
//! sparse index, migration state, pluggable eviction (see
//! [`crate::sim::eviction`]), and the per-page bookkeeping behind the
//! paper's accuracy / coverage / hit-rate metrics.
//!
//! Hot-path layout (DESIGN.md §12): [`PageInfo`] lives in [`Frame`]
//! slots of a `Vec` addressed by small integer [`FrameIdx`]es, so the
//! fault loop touches one cache line per page instead of probing a
//! `HashMap`. `PageMap` resolves page numbers to slots through a
//! chunked direct-mapped index on the dense-footprint path (a
//! `HashMap` catches far outliers from ingested traces). Lazy-discard
//! marks form a sorted intrusive doubly-linked list threaded through
//! the frames, and each frame carries the set of SMs whose TLB may
//! hold a translation, so eviction shoots down only those TLBs instead
//! of scanning every SM.

use crate::sim::eviction::{EvictionPolicy, LruPolicy};
use crate::types::{AdviseHint, Cycle, PageNum, PreferredLocation};
use std::collections::HashMap;

/// Frame-table slot index. `u32` keeps policy side-tables compact;
/// device capacities are page counts in the millions at most.
pub type FrameIdx = u32;

/// Intrusive-list terminator / "no frame" sentinel.
const NIL: FrameIdx = u32::MAX;

/// Migration state of a page known to the device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageState {
    /// In device memory, usable.
    Resident,
    /// Transfer scheduled; page usable at `arrival`.
    Migrating { arrival: Cycle },
}

/// Per-page bookkeeping.
#[derive(Debug, Clone, Copy)]
pub struct PageInfo {
    pub state: PageState,
    /// True when the current copy arrived via prefetch (not demand).
    pub via_prefetch: bool,
    /// The current prefetched copy has been demanded at least once
    /// (feeds prefetcher *accuracy*).
    pub prefetch_used: bool,
    pub last_touch: Cycle,
    /// `cudaMemAdviseSetReadMostly` modeled: the host keeps a
    /// read-only duplicate, so dropping this copy needs no writeback
    /// and CPU reads never migrate the page back.
    pub read_mostly: bool,
    /// `SetPreferredLocation(Device)` modeled: never an eviction
    /// victim while set.
    pub pinned: bool,
    /// Marked by a lazy discard (`UvmDiscardAsync` modeled): the copy
    /// is reclaimed only when admission needs a frame; a demand touch
    /// cancels the mark (the death prediction was wrong).
    pub lazy_discard: bool,
}

impl PageInfo {
    /// Resident by `now` under lazy promotion and not pinned — the
    /// only pages an eviction policy may target (in-flight pages are
    /// never evicted).
    pub fn evictable(&self, now: Cycle) -> bool {
        !self.pinned
            && match self.state {
                PageState::Resident => true,
                PageState::Migrating { arrival } => arrival <= now,
            }
    }
}

/// The set of SMs whose TLB may hold a translation for a page —
/// captured per frame so an eviction invalidates only those TLBs.
/// The mask is a *superset*: a TLB capacity eviction drops the entry
/// without telling the device, and a stale bit only costs one no-op
/// invalidate. SM ids ≥ 128 saturate to "all SMs" (no configuration
/// in the repo comes close; the bound keeps the mask one word pair).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SmSet {
    bits: u128,
    all: bool,
}

impl SmSet {
    pub fn insert(&mut self, sm: usize) {
        if sm >= 128 {
            self.all = true;
        } else {
            self.bits |= 1u128 << sm;
        }
    }

    pub fn is_empty(&self) -> bool {
        !self.all && self.bits == 0
    }

    /// Saturated masks lost track of individual SMs — the caller must
    /// fall back to a full shootdown.
    pub fn saturated(&self) -> bool {
        self.all
    }

    /// Iterate the individually tracked SM ids (ascending). Empty when
    /// [`SmSet::saturated`] — check that first.
    pub fn sms(&self) -> SmBits {
        SmBits(self.bits)
    }
}

/// Ascending set-bit iterator over an [`SmSet`] mask.
#[derive(Debug, Clone)]
pub struct SmBits(u128);

impl Iterator for SmBits {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        if self.0 == 0 {
            return None;
        }
        let i = self.0.trailing_zeros() as usize;
        self.0 &= self.0 - 1;
        Some(i)
    }
}

/// A page dropped by [`DeviceMemory::admit`] (eviction or reclaimed
/// lazy mark), carrying the TLB mask the engine needs for a targeted
/// shootdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvictedPage {
    pub page: PageNum,
    pub tlb: SmSet,
    /// The dropped copy arrived via prefetch and was never demanded —
    /// the telemetry sink's `evicted_unused` outcome tag (mirrors the
    /// `evicted_unused_prefetches` counter for eager evictions).
    pub unused_prefetch: bool,
    /// Dropped by reclaiming a lazy-discard mark rather than by the
    /// eviction policy (the `discarded` outcome tag).
    pub lazy_reclaim: bool,
}

/// One frame-table slot: the resident page's bookkeeping plus the
/// intrusive lazy-discard links and the TLB presence mask. Freed
/// slots stay in the `Vec` on a LIFO free list and are never visible
/// to eviction policies (every `on_remove` precedes the free).
#[derive(Debug, Clone, Copy)]
pub struct Frame {
    page: PageNum,
    info: PageInfo,
    in_use: bool,
    /// Lazy-discard mark time; the list below is ordered by
    /// `(lazy_at, page)` — exactly the old `BTreeSet<(Cycle, PageNum)>`
    /// iteration order.
    lazy_at: Cycle,
    lazy_prev: FrameIdx,
    lazy_next: FrameIdx,
    lazy_linked: bool,
    tlb: SmSet,
}

impl Frame {
    fn vacant() -> Self {
        Frame {
            page: 0,
            info: PageInfo {
                state: PageState::Resident,
                via_prefetch: false,
                prefetch_used: false,
                last_touch: 0,
                read_mostly: false,
                pinned: false,
                lazy_discard: false,
            },
            in_use: false,
            lazy_at: 0,
            lazy_prev: NIL,
            lazy_next: NIL,
            lazy_linked: false,
            tlb: SmSet::default(),
        }
    }

    pub fn page(&self) -> PageNum {
        self.page
    }

    pub fn info(&self) -> &PageInfo {
        &self.info
    }

    /// See [`PageInfo::evictable`].
    pub fn evictable(&self, now: Cycle) -> bool {
        self.in_use && self.info.evictable(now)
    }

    /// Bare frame for driving a policy without a [`DeviceMemory`]
    /// (unit tests of raw policy objects).
    #[cfg(test)]
    pub(crate) fn for_tests(page: PageNum, info: PageInfo) -> Self {
        Frame { page, info, in_use: true, ..Frame::vacant() }
    }
}

/// Frame-slot values stored in [`PageMap`]: a valid [`FrameIdx`], or
/// one of two vacancy sentinels. `VACANT_DROPPED` distinguishes "was
/// resident once and left" from "never seen" — the refault signal the
/// engine used to keep in a separate `HashSet`. Slots never return to
/// `VACANT`, matching that set's accumulate-forever semantics.
const VACANT: u32 = u32::MAX;
const VACANT_DROPPED: u32 = u32::MAX - 1;

/// Pages per direct-mapped chunk of the page→frame index.
const CHUNK_PAGES: u64 = 4096;
/// Maximum chunk span the dense directory may cover (1 TiB of address
/// space at 4 KiB pages) — footprints beyond it spill to `outliers`.
const MAX_CHUNK_SPAN: u64 = 1 << 16;

/// Two-level page→frame index. The workload footprint is contiguous
/// for the builtin generators, so nearly every lookup is two array
/// indexes; ingested traces with far-flung mappings fall back to the
/// `outliers` map. A chunk refused dense coverage is refused forever
/// (the span only grows), so the dense-range-first lookup is sound.
#[derive(Debug, Default)]
struct PageMap {
    /// First chunk index covered by `dir` (meaningless while empty).
    base: u64,
    dir: Vec<Option<Box<[u32]>>>,
    outliers: HashMap<PageNum, u32>,
}

impl PageMap {
    fn get(&self, page: PageNum) -> u32 {
        let chunk = page / CHUNK_PAGES;
        if !self.dir.is_empty() && chunk >= self.base {
            if let Some(slot) = self.dir.get((chunk - self.base) as usize) {
                return match slot {
                    Some(c) => c[(page % CHUNK_PAGES) as usize],
                    None => VACANT,
                };
            }
        }
        self.outliers.get(&page).copied().unwrap_or(VACANT)
    }

    fn set(&mut self, page: PageNum, val: u32) {
        let chunk = page / CHUNK_PAGES;
        if self.dir.is_empty() {
            self.base = chunk;
            self.dir.push(None);
        } else if chunk < self.base {
            let grow = self.base - chunk;
            if self.dir.len() as u64 + grow > MAX_CHUNK_SPAN {
                self.outliers.insert(page, val);
                return;
            }
            self.dir.splice(0..0, std::iter::repeat_with(|| None).take(grow as usize));
            self.base = chunk;
        } else if chunk - self.base >= self.dir.len() as u64 {
            let end = chunk - self.base + 1;
            if end > MAX_CHUNK_SPAN {
                self.outliers.insert(page, val);
                return;
            }
            self.dir.resize_with(end as usize, || None);
        }
        let slot = &mut self.dir[(chunk - self.base) as usize];
        let c = slot.get_or_insert_with(|| vec![VACANT; CHUNK_PAGES as usize].into_boxed_slice());
        c[(page % CHUNK_PAGES) as usize] = val;
    }
}

/// Device memory: a bounded table of page frames with pluggable
/// eviction ([`LruPolicy`] by default — the paper's baseline).
///
/// Residency flips lazily: a `Migrating` page whose arrival has passed
/// is promoted to `Resident` at the next query, so no event is needed
/// at arrival time.
#[derive(Debug)]
pub struct DeviceMemory {
    capacity_pages: u64,
    frames: Vec<Frame>,
    /// LIFO free list of frame slots — a just-evicted frame is the
    /// next one reused, while its line is still hot.
    free: Vec<FrameIdx>,
    live: u64,
    map: PageMap,
    policy: Box<dyn EvictionPolicy>,
    /// Lazy-discard marks as an intrusive list over the frames in mark
    /// order — reclaimed oldest-first when admission needs a frame,
    /// before the eviction policy is asked. Touch-cancel and page
    /// departure unlink eagerly, so every linked entry is live.
    lazy_head: FrameIdx,
    lazy_tail: FrameIdx,
    /// Reused output buffer for [`DeviceMemory::admit`] — the fault
    /// loop allocates nothing per eviction.
    evicted_buf: Vec<EvictedPage>,
    /// Number of prefetched copies that were evicted before ever being
    /// demanded (wasted transfers — hurts accuracy).
    pub evicted_unused_prefetches: u64,
    pub evictions: u64,
    /// Pages dropped by discard commands (eager + reclaimed lazy) —
    /// freed without writeback, charged no interconnect traffic, and
    /// *not* counted as evictions.
    pub discards: u64,
    /// Subset of `discards` that were lazy marks reclaimed at
    /// admission pressure.
    pub lazy_discard_reclaims: u64,
    /// Pages newly marked read-mostly by an advise.
    pub advised_read_mostly: u64,
    /// Read-mostly copies dropped (evicted or discarded) — each one a
    /// writeback the host duplicate made unnecessary.
    pub read_mostly_drops: u64,
}

impl DeviceMemory {
    pub fn new(capacity_pages: u64) -> Self {
        Self::with_policy(capacity_pages, Box::new(LruPolicy::default()))
    }

    pub fn with_policy(capacity_pages: u64, policy: Box<dyn EvictionPolicy>) -> Self {
        assert!(capacity_pages > 0);
        Self {
            capacity_pages,
            frames: Vec::new(),
            free: Vec::new(),
            live: 0,
            map: PageMap::default(),
            policy,
            lazy_head: NIL,
            lazy_tail: NIL,
            evicted_buf: Vec::new(),
            evicted_unused_prefetches: 0,
            evictions: 0,
            discards: 0,
            lazy_discard_reclaims: 0,
            advised_read_mostly: 0,
            read_mostly_drops: 0,
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn occupancy(&self) -> u64 {
        self.live
    }

    pub fn capacity(&self) -> u64 {
        self.capacity_pages
    }

    fn frame_of(&self, page: PageNum) -> Option<FrameIdx> {
        let slot = self.map.get(page);
        (slot < VACANT_DROPPED).then_some(slot)
    }

    /// The page was dropped (evicted or discarded) at some point in
    /// this run and is not currently resident — the engine's refault
    /// signal.
    pub fn was_dropped(&self, page: PageNum) -> bool {
        self.map.get(page) == VACANT_DROPPED
    }

    /// Current state of a page after lazy promotion at time `now`.
    pub fn state(&mut self, page: PageNum, now: Cycle) -> Option<PageState> {
        let f = self.frame_of(page)?;
        let info = &mut self.frames[f as usize].info;
        if let PageState::Migrating { arrival } = info.state {
            if arrival <= now {
                info.state = PageState::Resident;
            }
        }
        Some(info.state)
    }

    pub fn info(&self, page: PageNum) -> Option<&PageInfo> {
        self.frame_of(page).map(|f| &self.frames[f as usize].info)
    }

    /// Record that SM `sm` filled a TLB entry for `page` — the engine
    /// calls this beside every `Gmmu::fill`, keeping the per-frame
    /// shootdown mask a superset of the TLBs that hold the page.
    pub fn note_tlb_fill(&mut self, page: PageNum, sm: usize) {
        if let Some(f) = self.frame_of(page) {
            self.frames[f as usize].tlb.insert(sm);
        }
    }

    /// Record a demand touch (updates the eviction policy's index +
    /// prefetch-use accounting). Returns `true` when this is the first
    /// demand touch of a prefetched copy (the prefetch "hit").
    pub fn touch(&mut self, page: PageNum, now: Cycle) -> bool {
        let Some(f) = self.frame_of(page) else { return false };
        let (prev, first_use, cancel) = {
            let info = &mut self.frames[f as usize].info;
            let prev = info.last_touch;
            info.last_touch = now;
            // A demand touch disproves a lazy-discard death prediction
            // — cancel the mark (and unlink it eagerly).
            let cancel = info.lazy_discard;
            info.lazy_discard = false;
            let first_use = info.via_prefetch && !info.prefetch_used;
            if first_use {
                info.prefetch_used = true;
            }
            (prev, first_use, cancel)
        };
        if cancel {
            self.lazy_unlink(f);
        }
        self.policy.on_touch(f, page, prev, now);
        first_use
    }

    /// Admit a page that is starting migration. Evicts policy-chosen
    /// pages if at capacity. Returns the evicted pages (resident only —
    /// in-flight pages are never evicted) with their TLB shootdown
    /// masks; the slice borrows an internal reuse buffer valid until
    /// the next `admit`.
    pub fn admit(
        &mut self,
        page: PageNum,
        arrival: Cycle,
        via_prefetch: bool,
        now: Cycle,
    ) -> &[EvictedPage] {
        debug_assert!(self.frame_of(page).is_none(), "admit of already-known page {page}");
        self.evicted_buf.clear();
        while self.live >= self.capacity_pages {
            // Lazy-discard marks absorb the pressure first: reclaiming
            // a predicted-dead copy is free, so the policy only picks
            // a victim once no mark is reclaimable.
            if let Some(e) = self.reclaim_lazy(now) {
                self.evicted_buf.push(e);
                continue;
            }
            match self.evict_one(now) {
                Some(e) => self.evicted_buf.push(e),
                None => break, // everything in flight; over-commit rather than deadlock
            }
        }
        let info = PageInfo {
            state: PageState::Migrating { arrival },
            via_prefetch,
            prefetch_used: false,
            last_touch: now,
            read_mostly: false,
            pinned: false,
            lazy_discard: false,
        };
        let f = self.alloc_frame(page, info);
        self.map.set(page, f);
        self.live += 1;
        self.policy.on_admit(f, page, now, via_prefetch);
        &self.evicted_buf
    }

    /// Apply a memory-usage hint to every *known* page in `pages`
    /// (advice on unknown pages is a no-op, as in CUDA). Returns how
    /// many pages the hint reached.
    pub fn advise(&mut self, pages: &[PageNum], hint: AdviseHint) -> u64 {
        let mut reached = 0;
        for &p in pages {
            let Some(f) = self.frame_of(p) else { continue };
            let info = &mut self.frames[f as usize].info;
            match hint {
                AdviseHint::ReadMostly => {
                    if !info.read_mostly {
                        info.read_mostly = true;
                        self.advised_read_mostly += 1;
                    }
                }
                AdviseHint::PreferredLocation(PreferredLocation::Device) => info.pinned = true,
                AdviseHint::PreferredLocation(PreferredLocation::Host) => info.pinned = false,
            }
            reached += 1;
        }
        reached
    }

    /// Eagerly drop a page the producer declared dead: frees the frame
    /// immediately, with no writeback and no interconnect traffic.
    /// Refused (`None`) for unknown, in-flight, or pinned pages;
    /// otherwise returns the TLB shootdown mask for the dropped copy.
    pub fn discard(&mut self, page: PageNum, now: Cycle) -> Option<SmSet> {
        let f = self.frame_of(page)?;
        let fr = &self.frames[f as usize];
        if !fr.info.evictable(now) {
            return None;
        }
        let (info, tlb) = (fr.info, fr.tlb);
        self.policy.on_remove(f, page, &info);
        self.discards += 1;
        if info.read_mostly {
            self.read_mostly_drops += 1;
        }
        self.release(f);
        Some(tlb)
    }

    /// Mark a page for lazy discard: the frame is reclaimed only when
    /// admission pressure needs it (oldest mark first), and a demand
    /// touch before then cancels the mark. Returns `false` for unknown
    /// or already-marked pages.
    pub fn discard_lazy(&mut self, page: PageNum, now: Cycle) -> bool {
        let Some(f) = self.frame_of(page) else { return false };
        if self.frames[f as usize].info.lazy_discard {
            return false;
        }
        self.frames[f as usize].info.lazy_discard = true;
        self.lazy_link(f, now);
        true
    }

    /// Reclaim the oldest lazy-discard mark that is evictable at
    /// `now`. Every linked mark is live (cancel/departure unlink
    /// eagerly), so this is a head-first walk that skips in-flight
    /// pages — the same scan order as the old stale-tolerant BTreeSet.
    fn reclaim_lazy(&mut self, now: Cycle) -> Option<EvictedPage> {
        let mut cur = self.lazy_head;
        while cur != NIL {
            let fr = &self.frames[cur as usize];
            if fr.info.evictable(now) {
                break;
            }
            cur = fr.lazy_next;
        }
        if cur == NIL {
            return None;
        }
        let fr = &self.frames[cur as usize];
        let (page, info, tlb) = (fr.page, fr.info, fr.tlb);
        self.policy.on_remove(cur, page, &info);
        self.discards += 1;
        self.lazy_discard_reclaims += 1;
        if info.read_mostly {
            self.read_mostly_drops += 1;
        }
        self.release(cur);
        Some(EvictedPage {
            page,
            tlb,
            unused_prefetch: info.via_prefetch && !info.prefetch_used,
            lazy_reclaim: true,
        })
    }

    /// Evict the policy's victim among pages resident by `now`.
    fn evict_one(&mut self, now: Cycle) -> Option<EvictedPage> {
        let victim = self.policy.pick_victim(&self.frames, now)?;
        let fr = &self.frames[victim as usize];
        debug_assert!(fr.in_use, "policy picked a free frame");
        let (page, info, tlb) = (fr.page, fr.info, fr.tlb);
        self.policy.on_remove(victim, page, &info);
        if info.via_prefetch && !info.prefetch_used {
            self.evicted_unused_prefetches += 1;
        }
        if info.read_mostly {
            self.read_mostly_drops += 1;
        }
        self.evictions += 1;
        self.release(victim);
        Some(EvictedPage {
            page,
            tlb,
            unused_prefetch: info.via_prefetch && !info.prefetch_used,
            lazy_reclaim: false,
        })
    }

    /// Take a frame off the free list (or grow the table) and reset
    /// its per-frame state — including the TLB mask, which must not
    /// leak from the previous tenant.
    fn alloc_frame(&mut self, page: PageNum, info: PageInfo) -> FrameIdx {
        let f = match self.free.pop() {
            Some(f) => f,
            None => {
                self.frames.push(Frame::vacant());
                (self.frames.len() - 1) as FrameIdx
            }
        };
        let fr = &mut self.frames[f as usize];
        debug_assert!(!fr.in_use && !fr.lazy_linked);
        *fr = Frame::vacant();
        fr.page = page;
        fr.info = info;
        fr.in_use = true;
        f
    }

    /// Return a frame to the free list, recording the vacated page as
    /// dropped (the refault signal). Callers run `policy.on_remove`
    /// and counter updates first.
    fn release(&mut self, f: FrameIdx) {
        self.lazy_unlink(f);
        let page = self.frames[f as usize].page;
        self.frames[f as usize].in_use = false;
        self.map.set(page, VACANT_DROPPED);
        self.live -= 1;
        self.free.push(f);
    }

    /// Insert frame `f` into the lazy-mark list keeping `(at, page)`
    /// ascending. Marks arrive in near-sorted order (event time), so
    /// the backward walk from the tail is amortized O(1).
    fn lazy_link(&mut self, f: FrameIdx, at: Cycle) {
        debug_assert!(!self.frames[f as usize].lazy_linked);
        let page = self.frames[f as usize].page;
        let mut cur = self.lazy_tail;
        while cur != NIL {
            let c = &self.frames[cur as usize];
            if (c.lazy_at, c.page) > (at, page) {
                cur = c.lazy_prev;
            } else {
                break;
            }
        }
        let next = if cur == NIL { self.lazy_head } else { self.frames[cur as usize].lazy_next };
        {
            let fr = &mut self.frames[f as usize];
            fr.lazy_at = at;
            fr.lazy_prev = cur;
            fr.lazy_next = next;
            fr.lazy_linked = true;
        }
        if cur == NIL {
            self.lazy_head = f;
        } else {
            self.frames[cur as usize].lazy_next = f;
        }
        if next == NIL {
            self.lazy_tail = f;
        } else {
            self.frames[next as usize].lazy_prev = f;
        }
    }

    fn lazy_unlink(&mut self, f: FrameIdx) {
        if !self.frames[f as usize].lazy_linked {
            return;
        }
        let (prev, next) = {
            let fr = &mut self.frames[f as usize];
            let (p, n) = (fr.lazy_prev, fr.lazy_next);
            fr.lazy_prev = NIL;
            fr.lazy_next = NIL;
            fr.lazy_linked = false;
            (p, n)
        };
        if prev == NIL {
            self.lazy_head = next;
        } else {
            self.frames[prev as usize].lazy_next = next;
        }
        if next == NIL {
            self.lazy_tail = prev;
        } else {
            self.frames[next as usize].lazy_prev = prev;
        }
    }

    /// All pages currently known (resident or in flight). Test helper.
    pub fn known_pages(&self) -> impl Iterator<Item = PageNum> + '_ {
        self.frames.iter().filter(|f| f.in_use).map(|f| f.page)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Evicted pages only — most assertions care about the sequence,
    /// not the TLB masks.
    fn pages(ev: &[EvictedPage]) -> Vec<PageNum> {
        ev.iter().map(|e| e.page).collect()
    }

    #[test]
    fn lazy_promotion() {
        let mut m = DeviceMemory::new(16);
        m.admit(7, 100, false, 0);
        assert_eq!(m.state(7, 50), Some(PageState::Migrating { arrival: 100 }));
        assert_eq!(m.state(7, 100), Some(PageState::Resident));
        assert_eq!(m.state(8, 0), None);
    }

    #[test]
    fn prefetch_use_counted_once() {
        let mut m = DeviceMemory::new(16);
        m.admit(3, 0, true, 0);
        assert!(m.touch(3, 10), "first demand touch of prefetched page");
        assert!(!m.touch(3, 20), "second touch not counted");
    }

    #[test]
    fn eviction_is_lru_and_counts_unused_prefetch() {
        let mut m = DeviceMemory::new(2);
        assert_eq!(m.policy_name(), "lru", "default policy is the paper's LRU");
        m.admit(1, 0, true, 0);
        m.admit(2, 0, false, 1);
        m.touch(1, 5); // 2 is now LRU... but 1 was touched later
        let evicted = pages(m.admit(3, 10, false, 10));
        assert_eq!(evicted, vec![2], "page 2 least recently used");
        // Page 1 was a *used* prefetch, page 2 demand — no unused count.
        assert_eq!(m.evicted_unused_prefetches, 0);
        let evicted = pages(m.admit(4, 11, false, 11));
        // Next victim is page 1? No: touched at 5; page 3 admitted at 10.
        assert_eq!(evicted, vec![1]);
    }

    #[test]
    fn unused_prefetch_eviction_counted() {
        let mut m = DeviceMemory::new(1);
        m.admit(1, 0, true, 0);
        let ev = pages(m.admit(2, 5, false, 5));
        assert_eq!(ev, vec![1]);
        assert_eq!(m.evicted_unused_prefetches, 1);
    }

    #[test]
    fn inflight_pages_not_evicted() {
        let mut m = DeviceMemory::new(1);
        m.admit(1, 1000, false, 0); // still migrating at now=5
        let ev = m.admit(2, 1005, false, 5).to_vec();
        assert!(ev.is_empty(), "in-flight page must not be evicted; over-commit");
        assert_eq!(m.occupancy(), 2);
    }

    #[test]
    fn read_mostly_duplicate_survives_touches_and_counts_free_drops() {
        use crate::types::AdviseHint;
        let mut m = DeviceMemory::new(2);
        m.admit(1, 0, false, 0);
        m.admit(2, 1, false, 1);
        // Advice reaches known pages only; unknown page 9 is a no-op.
        assert_eq!(m.advise(&[1, 9], AdviseHint::ReadMostly), 1);
        assert_eq!(m.advised_read_mostly, 1);
        // The hint is metadata: the page stays resident and touchable,
        // and repeated advise+touch cycles don't migrate anything.
        m.touch(1, 5);
        assert_eq!(m.advise(&[1], AdviseHint::ReadMostly), 1);
        assert_eq!(m.advised_read_mostly, 1, "already read-mostly: not re-counted");
        m.touch(1, 6);
        assert!(m.info(1).is_some_and(|i| i.read_mostly));
        assert_eq!(m.state(1, 6), Some(PageState::Resident));
        // Evicting the read-mostly copy is a free drop (host duplicate
        // is current — no writeback).
        m.touch(2, 7); // page 1 (touched at 6) is now LRU
        assert_eq!(pages(m.admit(3, 10, false, 8)), vec![1]);
        assert_eq!(m.read_mostly_drops, 1);
    }

    #[test]
    fn preferred_location_device_pins_against_eviction() {
        use crate::types::{AdviseHint, PreferredLocation};
        let mut m = DeviceMemory::new(2);
        m.admit(1, 0, false, 0);
        m.admit(2, 1, false, 1);
        m.advise(&[1], AdviseHint::PreferredLocation(PreferredLocation::Device));
        // Page 1 is the LRU victim but pinned — page 2 absorbs it.
        assert_eq!(pages(m.admit(3, 5, false, 5)), vec![2]);
        // Host advice unpins: page 1 is evictable again.
        m.advise(&[1], AdviseHint::PreferredLocation(PreferredLocation::Host));
        assert_eq!(pages(m.admit(4, 10, false, 10)), vec![1]);
    }

    #[test]
    fn eager_discard_frees_without_eviction_and_never_resurrects() {
        let mut m = DeviceMemory::new(4);
        m.admit(1, 0, false, 0);
        m.admit(2, 100, false, 1); // in flight until 100
        assert!(m.discard(1, 5).is_some(), "resident page discards");
        assert!(m.discard(1, 6).is_none(), "already gone");
        assert!(m.discard(2, 6).is_none(), "in-flight page refuses discard");
        assert!(m.discard(9, 6).is_none(), "unknown page refuses discard");
        assert_eq!(m.discards, 1);
        assert_eq!(m.evictions, 0, "discard is not an eviction");
        assert!(m.info(1).is_none(), "discard never resurrects");
        assert!(!m.known_pages().any(|p| p == 1));
        assert_eq!(m.occupancy(), 1);
        assert!(m.was_dropped(1), "discarded page counts as dropped (refault signal)");
        assert!(!m.was_dropped(2), "resident page is not dropped");
        assert!(!m.was_dropped(9), "never-seen page is not dropped");
    }

    #[test]
    fn lazy_discard_defers_in_mark_order_and_touch_cancels() {
        let mut m = DeviceMemory::new(3);
        m.admit(1, 0, false, 0);
        m.admit(2, 1, false, 1);
        m.admit(3, 2, false, 2);
        // Mark 3 then 1: nothing is freed until admission pressure.
        assert!(m.discard_lazy(3, 4));
        assert!(!m.discard_lazy(3, 5), "already marked");
        assert!(m.discard_lazy(1, 5));
        assert_eq!(m.occupancy(), 3);
        assert_eq!(m.discards, 0);
        // First pressure reclaims the oldest mark (page 3), not the
        // LRU victim (page 1 was admitted first).
        assert_eq!(pages(m.admit(4, 10, false, 6)), vec![3]);
        assert_eq!((m.discards, m.lazy_discard_reclaims, m.evictions), (1, 1, 0));
        // A demand touch cancels page 1's mark — the next pressure
        // falls through to the policy, which picks LRU victim 2.
        m.touch(1, 7);
        assert_eq!(pages(m.admit(5, 20, false, 8)), vec![2]);
        assert_eq!((m.discards, m.lazy_discard_reclaims, m.evictions), (1, 1, 1));
    }

    #[test]
    fn eviction_reports_noted_tlb_fills_and_frame_reuse_resets_mask() {
        let mut m = DeviceMemory::new(1);
        m.admit(1, 0, false, 0);
        m.note_tlb_fill(1, 3);
        m.note_tlb_fill(1, 7);
        m.note_tlb_fill(9, 0); // unknown page: no-op
        let ev = m.admit(2, 5, false, 5).to_vec();
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].page, 1);
        assert!(!ev[0].tlb.saturated());
        assert_eq!(ev[0].tlb.sms().collect::<Vec<_>>(), vec![3, 7]);
        // Page 2 reused page 1's frame — its mask must start empty.
        let ev = m.admit(3, 10, false, 10).to_vec();
        assert_eq!(ev[0].page, 2);
        assert!(ev[0].tlb.is_empty(), "frame reuse must reset the TLB mask");
    }

    #[test]
    fn smset_saturates_past_128_sms() {
        let mut s = SmSet::default();
        s.insert(5);
        assert!(!s.saturated());
        s.insert(200);
        assert!(s.saturated(), "sm ids past the mask width saturate to all");
        assert!(!s.is_empty());
    }

    #[test]
    fn page_map_handles_far_outliers_and_sparse_chunks() {
        // A footprint far wider than MAX_CHUNK_SPAN chunks forces the
        // second page into the outlier map; both stay addressable and
        // both record drops.
        let mut m = DeviceMemory::new(4);
        let mid = 5 * CHUNK_PAGES + 3;
        let far = (MAX_CHUNK_SPAN + 10) * CHUNK_PAGES;
        m.admit(mid, 0, false, 0);
        m.admit(far, 1, false, 1);
        assert_eq!(m.state(far, 1), Some(PageState::Resident));
        assert_eq!(m.occupancy(), 2);
        assert!(m.discard(far, 2).is_some());
        assert!(m.was_dropped(far), "outlier drops are tracked too");
        assert!(m.state(far, 3).is_none());
        // Re-admit of an outlier works and clears nothing else.
        m.admit(far, 4, false, 4);
        assert_eq!(m.state(far, 4), Some(PageState::Resident));
        assert_eq!(m.occupancy(), 2);
        // Growing the dense directory downward (page 0 sits below the
        // first-admitted chunk) keeps earlier entries addressable.
        m.admit(0, 5, false, 5);
        assert_eq!(m.state(0, 5), Some(PageState::Resident));
        assert_eq!(m.state(mid, 5), Some(PageState::Resident));
    }
}
