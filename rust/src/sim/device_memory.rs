//! Device-memory residency tracking: page frames, migration state,
//! pluggable eviction (see [`crate::sim::eviction`]), and the per-page
//! bookkeeping behind the paper's accuracy / coverage / hit-rate
//! metrics.

use crate::sim::eviction::{EvictionPolicy, LruPolicy};
use crate::types::{Cycle, PageNum};
use std::collections::HashMap;

/// Migration state of a page known to the device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageState {
    /// In device memory, usable.
    Resident,
    /// Transfer scheduled; page usable at `arrival`.
    Migrating { arrival: Cycle },
}

/// Per-page bookkeeping.
#[derive(Debug, Clone, Copy)]
pub struct PageInfo {
    pub state: PageState,
    /// True when the current copy arrived via prefetch (not demand).
    pub via_prefetch: bool,
    /// The current prefetched copy has been demanded at least once
    /// (feeds prefetcher *accuracy*).
    pub prefetch_used: bool,
    pub last_touch: Cycle,
}

impl PageInfo {
    /// Resident by `now` under lazy promotion — the only pages an
    /// eviction policy may target (in-flight pages are never evicted).
    pub fn evictable(&self, now: Cycle) -> bool {
        match self.state {
            PageState::Resident => true,
            PageState::Migrating { arrival } => arrival <= now,
        }
    }
}

/// Device memory: a bounded set of page frames with pluggable
/// eviction ([`LruPolicy`] by default — the paper's baseline).
///
/// Residency flips lazily: a `Migrating` page whose arrival has passed
/// is promoted to `Resident` at the next query, so no event is needed
/// at arrival time.
#[derive(Debug)]
pub struct DeviceMemory {
    capacity_pages: u64,
    pages: HashMap<PageNum, PageInfo>,
    policy: Box<dyn EvictionPolicy>,
    /// Number of prefetched copies that were evicted before ever being
    /// demanded (wasted transfers — hurts accuracy).
    pub evicted_unused_prefetches: u64,
    pub evictions: u64,
}

impl DeviceMemory {
    pub fn new(capacity_pages: u64) -> Self {
        Self::with_policy(capacity_pages, Box::new(LruPolicy::default()))
    }

    pub fn with_policy(capacity_pages: u64, policy: Box<dyn EvictionPolicy>) -> Self {
        assert!(capacity_pages > 0);
        Self {
            capacity_pages,
            pages: HashMap::new(),
            policy,
            evicted_unused_prefetches: 0,
            evictions: 0,
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn occupancy(&self) -> u64 {
        self.pages.len() as u64
    }

    pub fn capacity(&self) -> u64 {
        self.capacity_pages
    }

    /// Current state of a page after lazy promotion at time `now`.
    pub fn state(&mut self, page: PageNum, now: Cycle) -> Option<PageState> {
        let info = self.pages.get_mut(&page)?;
        if let PageState::Migrating { arrival } = info.state {
            if arrival <= now {
                info.state = PageState::Resident;
            }
        }
        Some(info.state)
    }

    pub fn info(&self, page: PageNum) -> Option<&PageInfo> {
        self.pages.get(&page)
    }

    /// Record a demand touch (updates the eviction policy's index +
    /// prefetch-use accounting). Returns `true` when this is the first
    /// demand touch of a prefetched copy (the prefetch "hit").
    pub fn touch(&mut self, page: PageNum, now: Cycle) -> bool {
        let (prev, first_use) = {
            let Some(info) = self.pages.get_mut(&page) else { return false };
            let prev = info.last_touch;
            info.last_touch = now;
            let first_use = info.via_prefetch && !info.prefetch_used;
            if first_use {
                info.prefetch_used = true;
            }
            (prev, first_use)
        };
        self.policy.on_touch(page, prev, now);
        first_use
    }

    /// Admit a page that is starting migration. Evicts policy-chosen
    /// pages if at capacity. Returns the evicted pages (resident only —
    /// in-flight pages are never evicted).
    pub fn admit(&mut self, page: PageNum, arrival: Cycle, via_prefetch: bool, now: Cycle) -> Vec<PageNum> {
        debug_assert!(!self.pages.contains_key(&page), "admit of already-known page {page}");
        let mut evicted = Vec::new();
        while self.pages.len() as u64 >= self.capacity_pages {
            match self.evict_one(now) {
                Some(p) => evicted.push(p),
                None => break, // everything in flight; over-commit rather than deadlock
            }
        }
        self.pages.insert(
            page,
            PageInfo { state: PageState::Migrating { arrival }, via_prefetch, prefetch_used: false, last_touch: now },
        );
        self.policy.on_admit(page, now, via_prefetch);
        evicted
    }

    /// Evict the policy's victim among pages resident by `now`.
    fn evict_one(&mut self, now: Cycle) -> Option<PageNum> {
        let victim = self.policy.pick_victim(&self.pages, now)?;
        let info = self.pages.remove(&victim).expect("policy picked an unknown page");
        self.policy.on_remove(victim, &info);
        if info.via_prefetch && !info.prefetch_used {
            self.evicted_unused_prefetches += 1;
        }
        self.evictions += 1;
        Some(victim)
    }

    /// All pages currently known (resident or in flight). Test helper.
    pub fn known_pages(&self) -> impl Iterator<Item = PageNum> + '_ {
        self.pages.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lazy_promotion() {
        let mut m = DeviceMemory::new(16);
        m.admit(7, 100, false, 0);
        assert_eq!(m.state(7, 50), Some(PageState::Migrating { arrival: 100 }));
        assert_eq!(m.state(7, 100), Some(PageState::Resident));
        assert_eq!(m.state(8, 0), None);
    }

    #[test]
    fn prefetch_use_counted_once() {
        let mut m = DeviceMemory::new(16);
        m.admit(3, 0, true, 0);
        assert!(m.touch(3, 10), "first demand touch of prefetched page");
        assert!(!m.touch(3, 20), "second touch not counted");
    }

    #[test]
    fn eviction_is_lru_and_counts_unused_prefetch() {
        let mut m = DeviceMemory::new(2);
        assert_eq!(m.policy_name(), "lru", "default policy is the paper's LRU");
        m.admit(1, 0, true, 0);
        m.admit(2, 0, false, 1);
        m.touch(1, 5); // 2 is now LRU... but 1 was touched later
        let evicted = m.admit(3, 10, false, 10);
        assert_eq!(evicted, vec![2], "page 2 least recently used");
        // Page 1 was a *used* prefetch, page 2 demand — no unused count.
        assert_eq!(m.evicted_unused_prefetches, 0);
        let evicted = m.admit(4, 11, false, 11);
        // Next victim is page 1? No: touched at 5; page 3 admitted at 10.
        assert_eq!(evicted, vec![1]);
    }

    #[test]
    fn unused_prefetch_eviction_counted() {
        let mut m = DeviceMemory::new(1);
        m.admit(1, 0, true, 0);
        let ev = m.admit(2, 5, false, 5);
        assert_eq!(ev, vec![1]);
        assert_eq!(m.evicted_unused_prefetches, 1);
    }

    #[test]
    fn inflight_pages_not_evicted() {
        let mut m = DeviceMemory::new(1);
        m.admit(1, 1000, false, 0); // still migrating at now=5
        let ev = m.admit(2, 1005, false, 5);
        assert!(ev.is_empty(), "in-flight page must not be evicted; over-commit");
        assert_eq!(m.occupancy(), 2);
    }
}
