//! Device-memory residency tracking: page frames, migration state,
//! pluggable eviction (see [`crate::sim::eviction`]), and the per-page
//! bookkeeping behind the paper's accuracy / coverage / hit-rate
//! metrics.

use crate::sim::eviction::{EvictionPolicy, LruPolicy};
use crate::types::{AdviseHint, Cycle, PageNum, PreferredLocation};
use std::collections::{BTreeSet, HashMap};

/// Migration state of a page known to the device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageState {
    /// In device memory, usable.
    Resident,
    /// Transfer scheduled; page usable at `arrival`.
    Migrating { arrival: Cycle },
}

/// Per-page bookkeeping.
#[derive(Debug, Clone, Copy)]
pub struct PageInfo {
    pub state: PageState,
    /// True when the current copy arrived via prefetch (not demand).
    pub via_prefetch: bool,
    /// The current prefetched copy has been demanded at least once
    /// (feeds prefetcher *accuracy*).
    pub prefetch_used: bool,
    pub last_touch: Cycle,
    /// `cudaMemAdviseSetReadMostly` modeled: the host keeps a
    /// read-only duplicate, so dropping this copy needs no writeback
    /// and CPU reads never migrate the page back.
    pub read_mostly: bool,
    /// `SetPreferredLocation(Device)` modeled: never an eviction
    /// victim while set.
    pub pinned: bool,
    /// Marked by a lazy discard (`UvmDiscardAsync` modeled): the copy
    /// is reclaimed only when admission needs a frame; a demand touch
    /// cancels the mark (the death prediction was wrong).
    pub lazy_discard: bool,
}

impl PageInfo {
    /// Resident by `now` under lazy promotion and not pinned — the
    /// only pages an eviction policy may target (in-flight pages are
    /// never evicted).
    pub fn evictable(&self, now: Cycle) -> bool {
        !self.pinned
            && match self.state {
                PageState::Resident => true,
                PageState::Migrating { arrival } => arrival <= now,
            }
    }
}

/// Device memory: a bounded set of page frames with pluggable
/// eviction ([`LruPolicy`] by default — the paper's baseline).
///
/// Residency flips lazily: a `Migrating` page whose arrival has passed
/// is promoted to `Resident` at the next query, so no event is needed
/// at arrival time.
#[derive(Debug)]
pub struct DeviceMemory {
    capacity_pages: u64,
    pages: HashMap<PageNum, PageInfo>,
    policy: Box<dyn EvictionPolicy>,
    /// Lazy-discard marks in mark order — reclaimed oldest-first when
    /// admission needs a frame, before the eviction policy is asked.
    /// Entries go stale when a touch cancels the mark or the page
    /// leaves; they are skipped and dropped at reclaim time.
    lazy_marks: BTreeSet<(Cycle, PageNum)>,
    /// Number of prefetched copies that were evicted before ever being
    /// demanded (wasted transfers — hurts accuracy).
    pub evicted_unused_prefetches: u64,
    pub evictions: u64,
    /// Pages dropped by discard commands (eager + reclaimed lazy) —
    /// freed without writeback, charged no interconnect traffic, and
    /// *not* counted as evictions.
    pub discards: u64,
    /// Subset of `discards` that were lazy marks reclaimed at
    /// admission pressure.
    pub lazy_discard_reclaims: u64,
    /// Pages newly marked read-mostly by an advise.
    pub advised_read_mostly: u64,
    /// Read-mostly copies dropped (evicted or discarded) — each one a
    /// writeback the host duplicate made unnecessary.
    pub read_mostly_drops: u64,
}

impl DeviceMemory {
    pub fn new(capacity_pages: u64) -> Self {
        Self::with_policy(capacity_pages, Box::new(LruPolicy::default()))
    }

    pub fn with_policy(capacity_pages: u64, policy: Box<dyn EvictionPolicy>) -> Self {
        assert!(capacity_pages > 0);
        Self {
            capacity_pages,
            pages: HashMap::new(),
            policy,
            lazy_marks: BTreeSet::new(),
            evicted_unused_prefetches: 0,
            evictions: 0,
            discards: 0,
            lazy_discard_reclaims: 0,
            advised_read_mostly: 0,
            read_mostly_drops: 0,
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn occupancy(&self) -> u64 {
        self.pages.len() as u64
    }

    pub fn capacity(&self) -> u64 {
        self.capacity_pages
    }

    /// Current state of a page after lazy promotion at time `now`.
    pub fn state(&mut self, page: PageNum, now: Cycle) -> Option<PageState> {
        let info = self.pages.get_mut(&page)?;
        if let PageState::Migrating { arrival } = info.state {
            if arrival <= now {
                info.state = PageState::Resident;
            }
        }
        Some(info.state)
    }

    pub fn info(&self, page: PageNum) -> Option<&PageInfo> {
        self.pages.get(&page)
    }

    /// Record a demand touch (updates the eviction policy's index +
    /// prefetch-use accounting). Returns `true` when this is the first
    /// demand touch of a prefetched copy (the prefetch "hit").
    pub fn touch(&mut self, page: PageNum, now: Cycle) -> bool {
        let (prev, first_use) = {
            let Some(info) = self.pages.get_mut(&page) else { return false };
            let prev = info.last_touch;
            info.last_touch = now;
            // A demand touch disproves a lazy-discard death prediction
            // — cancel the mark (its index entry goes stale).
            info.lazy_discard = false;
            let first_use = info.via_prefetch && !info.prefetch_used;
            if first_use {
                info.prefetch_used = true;
            }
            (prev, first_use)
        };
        self.policy.on_touch(page, prev, now);
        first_use
    }

    /// Admit a page that is starting migration. Evicts policy-chosen
    /// pages if at capacity. Returns the evicted pages (resident only —
    /// in-flight pages are never evicted).
    pub fn admit(&mut self, page: PageNum, arrival: Cycle, via_prefetch: bool, now: Cycle) -> Vec<PageNum> {
        debug_assert!(!self.pages.contains_key(&page), "admit of already-known page {page}");
        let mut evicted = Vec::new();
        while self.pages.len() as u64 >= self.capacity_pages {
            // Lazy-discard marks absorb the pressure first: reclaiming
            // a predicted-dead copy is free, so the policy only picks
            // a victim once no mark is reclaimable.
            if let Some(p) = self.reclaim_lazy(now) {
                evicted.push(p);
                continue;
            }
            match self.evict_one(now) {
                Some(p) => evicted.push(p),
                None => break, // everything in flight; over-commit rather than deadlock
            }
        }
        self.pages.insert(
            page,
            PageInfo {
                state: PageState::Migrating { arrival },
                via_prefetch,
                prefetch_used: false,
                last_touch: now,
                read_mostly: false,
                pinned: false,
                lazy_discard: false,
            },
        );
        self.policy.on_admit(page, now, via_prefetch);
        evicted
    }

    /// Apply a memory-usage hint to every *known* page in `pages`
    /// (advice on unknown pages is a no-op, as in CUDA). Returns how
    /// many pages the hint reached.
    pub fn advise(&mut self, pages: &[PageNum], hint: AdviseHint) -> u64 {
        let mut reached = 0;
        for &p in pages {
            let Some(info) = self.pages.get_mut(&p) else { continue };
            match hint {
                AdviseHint::ReadMostly => {
                    if !info.read_mostly {
                        info.read_mostly = true;
                        self.advised_read_mostly += 1;
                    }
                }
                AdviseHint::PreferredLocation(PreferredLocation::Device) => info.pinned = true,
                AdviseHint::PreferredLocation(PreferredLocation::Host) => info.pinned = false,
            }
            reached += 1;
        }
        reached
    }

    /// Eagerly drop a page the producer declared dead: frees the frame
    /// immediately, with no writeback and no interconnect traffic.
    /// Refused (`false`) for unknown, in-flight, or pinned pages.
    pub fn discard(&mut self, page: PageNum, now: Cycle) -> bool {
        if !self.pages.get(&page).is_some_and(|i| i.evictable(now)) {
            return false;
        }
        let info = self.pages.remove(&page).expect("checked above");
        self.policy.on_remove(page, &info);
        self.discards += 1;
        if info.read_mostly {
            self.read_mostly_drops += 1;
        }
        true
    }

    /// Mark a page for lazy discard: the frame is reclaimed only when
    /// admission pressure needs it (oldest mark first), and a demand
    /// touch before then cancels the mark. Returns `false` for unknown
    /// or already-marked pages.
    pub fn discard_lazy(&mut self, page: PageNum, now: Cycle) -> bool {
        let Some(info) = self.pages.get_mut(&page) else { return false };
        if info.lazy_discard {
            return false;
        }
        info.lazy_discard = true;
        self.lazy_marks.insert((now, page));
        true
    }

    /// Reclaim the oldest still-valid lazy-discard mark that is
    /// evictable at `now`, dropping stale index entries on the way.
    fn reclaim_lazy(&mut self, now: Cycle) -> Option<PageNum> {
        let mut stale = Vec::new();
        let mut hit = None;
        for &(at, page) in &self.lazy_marks {
            match self.pages.get(&page) {
                Some(i) if i.lazy_discard => {
                    if i.evictable(now) {
                        hit = Some((at, page));
                        break;
                    }
                }
                _ => stale.push((at, page)), // canceled or departed
            }
        }
        for k in stale {
            self.lazy_marks.remove(&k);
        }
        let (at, page) = hit?;
        self.lazy_marks.remove(&(at, page));
        let info = self.pages.remove(&page).expect("marked page is known");
        self.policy.on_remove(page, &info);
        self.discards += 1;
        self.lazy_discard_reclaims += 1;
        if info.read_mostly {
            self.read_mostly_drops += 1;
        }
        Some(page)
    }

    /// Evict the policy's victim among pages resident by `now`.
    fn evict_one(&mut self, now: Cycle) -> Option<PageNum> {
        let victim = self.policy.pick_victim(&self.pages, now)?;
        let info = self.pages.remove(&victim).expect("policy picked an unknown page");
        self.policy.on_remove(victim, &info);
        if info.via_prefetch && !info.prefetch_used {
            self.evicted_unused_prefetches += 1;
        }
        if info.read_mostly {
            self.read_mostly_drops += 1;
        }
        self.evictions += 1;
        Some(victim)
    }

    /// All pages currently known (resident or in flight). Test helper.
    pub fn known_pages(&self) -> impl Iterator<Item = PageNum> + '_ {
        self.pages.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lazy_promotion() {
        let mut m = DeviceMemory::new(16);
        m.admit(7, 100, false, 0);
        assert_eq!(m.state(7, 50), Some(PageState::Migrating { arrival: 100 }));
        assert_eq!(m.state(7, 100), Some(PageState::Resident));
        assert_eq!(m.state(8, 0), None);
    }

    #[test]
    fn prefetch_use_counted_once() {
        let mut m = DeviceMemory::new(16);
        m.admit(3, 0, true, 0);
        assert!(m.touch(3, 10), "first demand touch of prefetched page");
        assert!(!m.touch(3, 20), "second touch not counted");
    }

    #[test]
    fn eviction_is_lru_and_counts_unused_prefetch() {
        let mut m = DeviceMemory::new(2);
        assert_eq!(m.policy_name(), "lru", "default policy is the paper's LRU");
        m.admit(1, 0, true, 0);
        m.admit(2, 0, false, 1);
        m.touch(1, 5); // 2 is now LRU... but 1 was touched later
        let evicted = m.admit(3, 10, false, 10);
        assert_eq!(evicted, vec![2], "page 2 least recently used");
        // Page 1 was a *used* prefetch, page 2 demand — no unused count.
        assert_eq!(m.evicted_unused_prefetches, 0);
        let evicted = m.admit(4, 11, false, 11);
        // Next victim is page 1? No: touched at 5; page 3 admitted at 10.
        assert_eq!(evicted, vec![1]);
    }

    #[test]
    fn unused_prefetch_eviction_counted() {
        let mut m = DeviceMemory::new(1);
        m.admit(1, 0, true, 0);
        let ev = m.admit(2, 5, false, 5);
        assert_eq!(ev, vec![1]);
        assert_eq!(m.evicted_unused_prefetches, 1);
    }

    #[test]
    fn inflight_pages_not_evicted() {
        let mut m = DeviceMemory::new(1);
        m.admit(1, 1000, false, 0); // still migrating at now=5
        let ev = m.admit(2, 1005, false, 5);
        assert!(ev.is_empty(), "in-flight page must not be evicted; over-commit");
        assert_eq!(m.occupancy(), 2);
    }

    #[test]
    fn read_mostly_duplicate_survives_touches_and_counts_free_drops() {
        use crate::types::AdviseHint;
        let mut m = DeviceMemory::new(2);
        m.admit(1, 0, false, 0);
        m.admit(2, 1, false, 1);
        // Advice reaches known pages only; unknown page 9 is a no-op.
        assert_eq!(m.advise(&[1, 9], AdviseHint::ReadMostly), 1);
        assert_eq!(m.advised_read_mostly, 1);
        // The hint is metadata: the page stays resident and touchable,
        // and repeated advise+touch cycles don't migrate anything.
        m.touch(1, 5);
        assert_eq!(m.advise(&[1], AdviseHint::ReadMostly), 1);
        assert_eq!(m.advised_read_mostly, 1, "already read-mostly: not re-counted");
        m.touch(1, 6);
        assert!(m.info(1).is_some_and(|i| i.read_mostly));
        assert_eq!(m.state(1, 6), Some(PageState::Resident));
        // Evicting the read-mostly copy is a free drop (host duplicate
        // is current — no writeback).
        m.touch(2, 7); // page 1 (touched at 6) is now LRU
        assert_eq!(m.admit(3, 10, false, 8), vec![1]);
        assert_eq!(m.read_mostly_drops, 1);
    }

    #[test]
    fn preferred_location_device_pins_against_eviction() {
        use crate::types::{AdviseHint, PreferredLocation};
        let mut m = DeviceMemory::new(2);
        m.admit(1, 0, false, 0);
        m.admit(2, 1, false, 1);
        m.advise(&[1], AdviseHint::PreferredLocation(PreferredLocation::Device));
        // Page 1 is the LRU victim but pinned — page 2 absorbs it.
        assert_eq!(m.admit(3, 5, false, 5), vec![2]);
        // Host advice unpins: page 1 is evictable again.
        m.advise(&[1], AdviseHint::PreferredLocation(PreferredLocation::Host));
        assert_eq!(m.admit(4, 10, false, 10), vec![1]);
    }

    #[test]
    fn eager_discard_frees_without_eviction_and_never_resurrects() {
        let mut m = DeviceMemory::new(4);
        m.admit(1, 0, false, 0);
        m.admit(2, 100, false, 1); // in flight until 100
        assert!(m.discard(1, 5), "resident page discards");
        assert!(!m.discard(1, 6), "already gone");
        assert!(!m.discard(2, 6), "in-flight page refuses discard");
        assert!(!m.discard(9, 6), "unknown page refuses discard");
        assert_eq!(m.discards, 1);
        assert_eq!(m.evictions, 0, "discard is not an eviction");
        assert!(m.info(1).is_none(), "discard never resurrects");
        assert!(!m.known_pages().any(|p| p == 1));
        assert_eq!(m.occupancy(), 1);
    }

    #[test]
    fn lazy_discard_defers_in_mark_order_and_touch_cancels() {
        let mut m = DeviceMemory::new(3);
        m.admit(1, 0, false, 0);
        m.admit(2, 1, false, 1);
        m.admit(3, 2, false, 2);
        // Mark 3 then 1: nothing is freed until admission pressure.
        assert!(m.discard_lazy(3, 4));
        assert!(!m.discard_lazy(3, 5), "already marked");
        assert!(m.discard_lazy(1, 5));
        assert_eq!(m.occupancy(), 3);
        assert_eq!(m.discards, 0);
        // First pressure reclaims the oldest mark (page 3), not the
        // LRU victim (page 1 was admitted first).
        assert_eq!(m.admit(4, 10, false, 6), vec![3]);
        assert_eq!((m.discards, m.lazy_discard_reclaims, m.evictions), (1, 1, 0));
        // A demand touch cancels page 1's mark — the next pressure
        // falls through to the policy, which picks LRU victim 2.
        m.touch(1, 7);
        assert_eq!(m.admit(5, 20, false, 8), vec![2]);
        assert_eq!((m.discards, m.lazy_discard_reclaims, m.evictions), (1, 1, 1));
    }
}
