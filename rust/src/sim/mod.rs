//! The GPU-UVM timing simulator (GPGPU-Sim/UVMSmart substitute — see
//! DESIGN.md §2 for why this substitution preserves the paper's
//! evaluation semantics).

pub mod device_memory;
pub mod engine;
pub mod eviction;
pub mod gmmu;
pub mod interconnect;
pub mod metrics;
pub mod sm;
pub mod trace;

pub use engine::Simulator;
pub use eviction::{EvictionPolicy, ALL_EVICTION_POLICIES};
pub use metrics::Metrics;
pub use trace::{TraceWriter, TRACE_HEADER};
