//! Trace emission: `repro trace-gen` writes one CSV per benchmark with
//! every GMMU-visible access — the training corpus for the python
//! pipeline (all 13 features of the paper's Figure 3 are derivable
//! from these columns plus the per-cluster predecessor record).

use crate::types::TraceRecord;
use std::io::{BufWriter, Write};
use std::path::Path;

pub const TRACE_HEADER: &str = "cycle,pc,page,sm,warp,cta,tpc,kernel_id,array_id,miss";

/// Buffered CSV trace writer.
pub struct TraceWriter {
    out: BufWriter<std::fs::File>,
    pub records: u64,
    /// Optional cap: stop writing after this many records (keeps the
    /// corpus bounded on long simulations). 0 = unlimited.
    pub limit: u64,
}

impl TraceWriter {
    pub fn create(path: &Path, limit: u64) -> anyhow::Result<Self> {
        let file = std::fs::File::create(path)?;
        let mut out = BufWriter::with_capacity(1 << 20, file);
        writeln!(out, "{TRACE_HEADER}")?;
        Ok(Self { out, records: 0, limit })
    }

    #[inline]
    pub fn write(&mut self, r: &TraceRecord) -> anyhow::Result<()> {
        if self.limit != 0 && self.records >= self.limit {
            return Ok(());
        }
        writeln!(
            self.out,
            "{},{},{},{},{},{},{},{},{},{}",
            r.cycle, r.pc, r.page, r.sm, r.warp, r.cta, r.tpc, r.kernel_id, r.array_id, r.miss
        )?;
        self.records += 1;
        Ok(())
    }

    pub fn finish(mut self) -> anyhow::Result<u64> {
        self.out.flush()?;
        Ok(self.records)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::TraceRecord;

    fn rec(cycle: u64) -> TraceRecord {
        TraceRecord {
            cycle,
            pc: 0x20,
            page: 7,
            sm: 1,
            warp: 2,
            cta: 3,
            tpc: 0,
            kernel_id: 0,
            array_id: 1,
            miss: 1,
        }
    }

    #[test]
    fn writes_header_and_rows() {
        let dir = crate::util::TestDir::new();
        let path = dir.file("t.csv");
        let mut w = TraceWriter::create(&path, 0).unwrap();
        w.write(&rec(1)).unwrap();
        w.write(&rec(2)).unwrap();
        assert_eq!(w.finish().unwrap(), 2);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], TRACE_HEADER);
        assert_eq!(lines.len(), 3);
        assert!(lines[1].starts_with("1,32,7,1,2,3,0,0,1,1"));
    }

    #[test]
    fn limit_caps_records() {
        let dir = crate::util::TestDir::new();
        let path = dir.file("t.csv");
        let mut w = TraceWriter::create(&path, 1).unwrap();
        w.write(&rec(1)).unwrap();
        w.write(&rec(2)).unwrap();
        assert_eq!(w.finish().unwrap(), 1);
    }
}
