//! The discrete-event UVM simulator.
//!
//! Three event kinds drive the model:
//!
//! * `Dispatch(sm)` — the SM picks the oldest ready warp (GTO), runs
//!   its compute burst at 1 instruction/cycle, and schedules the
//!   warp's memory instruction.
//! * `MemIssue(sm, warp, op)` — the access reaches the GMMU: TLB →
//!   page walk → residency check → hit / MSHR-merge / far-fault, with
//!   the far-fault path invoking the active prefetch policy and the
//!   interconnect model.
//! * `Wake(sm, warp)` — the access completed; the warp re-enters the
//!   ready pool.
//!
//! All latency constants come from [`crate::config::SimConfig`]
//! (paper Table 9). Event ties are broken by insertion order, so runs
//! are bit-deterministic.

use crate::config::{ExperimentConfig, SimConfig};
use crate::prefetch::{
    DiscardRequest, FaultInfo, MemPressure, PrefetchDecision, Prefetcher, PrefetchRequest,
};
use crate::sim::device_memory::{DeviceMemory, PageState};
use crate::sim::eviction;
use crate::sim::gmmu::Gmmu;
use crate::sim::interconnect::Interconnect;
use crate::sim::metrics::Metrics;
use crate::sim::sm::{SmState, WarpOp};
use crate::sim::trace::TraceWriter;
use crate::telemetry::{FaultSpan, PrefetchOutcome, SimTelemetry};
use crate::types::{page_of, AccessOrigin, Cycle, TraceRecord, PAGE_SIZE};
use crate::workloads::WorkloadInstance;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

#[derive(Debug)]
enum EventKind {
    Dispatch { sm: u16 },
    MemIssue { sm: u16, warp: u16, op: WarpOp },
    Wake { sm: u16, warp: u16 },
}

/// Heap entry: (time, seq) ordering, min-first.
struct Event {
    at: Cycle,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

pub struct Simulator {
    cfg: SimConfig,
    sms: Vec<SmState>,
    device: DeviceMemory,
    gmmu: Gmmu,
    link: Interconnect,
    prefetcher: Box<dyn Prefetcher>,
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
    now: Cycle,
    pub metrics: Metrics,
    trace: Option<TraceWriter>,
    max_instructions: u64,
    stopping: bool,
    far_fault_cycles: Cycle,
    /// Scratch buffer handed to [`Prefetcher::on_fault_into`] — reused
    /// across faults so the steady-state fault loop allocates nothing.
    decision_buf: PrefetchDecision,
    /// Scratch buffer for [`Prefetcher::drain_into`], reused likewise.
    drain_buf: Vec<PrefetchRequest>,
    /// Structured-telemetry sink (DESIGN.md §13). `None` (the default)
    /// keeps every hook below to a single pointer-null check — the
    /// telemetry-off path stays byte-identical and allocation-free
    /// (gated by `tests/ab_identity.rs`). The sink is strictly an
    /// observer: nothing it records feeds back into scheduling.
    telemetry: Option<Box<SimTelemetry>>,
}

impl Simulator {
    pub fn new(
        exp: &ExperimentConfig,
        workload: WorkloadInstance,
        prefetcher: Box<dyn Prefetcher>,
        trace: Option<TraceWriter>,
    ) -> Self {
        let cfg = exp.sim.clone();
        // Oversubscription resolves here, where the generated workload
        // is in hand: `oversub_ratio` < 1.0 caps residency to that
        // fraction of the workload's page footprint (DESIGN.md §2).
        let (capacity_pages, footprint_pages) = if cfg.oversub_ratio < 1.0 {
            let fp = workload.footprint_pages();
            (cfg.effective_capacity_pages(fp), fp)
        } else {
            (cfg.device_mem_pages(), 0)
        };
        let mut sms: Vec<SmState> =
            (0..cfg.n_sms).map(|_| SmState::new(cfg.warps_per_sm as usize)).collect();
        for task in workload.tasks {
            sms[task.sm as usize].load_warp(task.warp, crate::sim::sm::WarpProgram::new(task.ops));
        }
        let device = DeviceMemory::with_policy(
            capacity_pages,
            eviction::build(&cfg.eviction_policy, exp.seed)
                .expect("eviction policy name is validated upstream (SimConfig::validate)"),
        );
        let gmmu = Gmmu::new(cfg.n_sms as usize, cfg.tlb_entries);
        let link = Interconnect::new(
            cfg.pcie_bytes_per_cycle(),
            cfg.pcie_latency_cycles,
            cfg.pcie_bucket_cycles,
        );
        let far_fault_cycles = cfg.far_fault_cycles();
        let mut sim = Self {
            cfg,
            sms,
            device,
            gmmu,
            link,
            prefetcher,
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0,
            metrics: Metrics::default(),
            trace,
            max_instructions: exp.max_instructions,
            stopping: false,
            far_fault_cycles,
            decision_buf: PrefetchDecision::default(),
            drain_buf: Vec::new(),
            telemetry: None,
        };
        sim.metrics.pcie_bucket_cycles = sim.cfg.pcie_bucket_cycles;
        sim.metrics.capacity_pages = capacity_pages;
        sim.metrics.footprint_pages = footprint_pages;
        for sm in 0..sim.sms.len() as u16 {
            sim.schedule(0, EventKind::Dispatch { sm });
            sim.sms[sm as usize].dispatch_at = Some(0);
        }
        sim
    }

    fn schedule(&mut self, at: Cycle, kind: EventKind) {
        self.seq += 1;
        self.heap.push(Reverse(Event { at, seq: self.seq, kind }));
    }

    /// Arm the structured-telemetry sink (`repro simulate --telemetry`,
    /// DESIGN.md §13). Must be called before [`Simulator::run`]. The
    /// prefetcher is notified so it can start recording batch events
    /// and prediction post-mortems; with `path == None` the sink
    /// accumulates in memory but writes nothing (perf-harness mode).
    pub fn attach_telemetry(&mut self, path: Option<std::path::PathBuf>, benchmark: &str) {
        let sink = SimTelemetry::new(path, benchmark, self.link.bucket_cycles());
        self.prefetcher.set_telemetry_enabled(true);
        self.telemetry = Some(Box::new(sink));
    }

    /// Run to completion (or to `max_instructions`). Returns final metrics.
    pub fn run(mut self) -> Metrics {
        while let Some(Reverse(ev)) = self.heap.pop() {
            self.now = self.now.max(ev.at);
            match ev.kind {
                EventKind::Dispatch { sm } => self.on_dispatch(ev.at, sm),
                EventKind::MemIssue { sm, warp, op } => self.on_mem_issue(ev.at, sm, warp, &op),
                EventKind::Wake { sm, warp } => self.on_wake(ev.at, sm, warp),
            }
            if self.stopping {
                break;
            }
            self.drain_prefetcher();
        }
        self.prefetcher.finish(self.now);
        self.drain_prefetcher();
        let tel = self.prefetcher.telemetry();
        self.metrics.predictions = tel.predictions;
        self.metrics.prediction_batches = tel.prediction_batches;
        self.metrics.bypass_predictions = tel.bypass_predictions;
        self.metrics.oov_predictions = tel.oov_predictions;
        self.metrics.finetune_rounds = tel.finetune_rounds;
        self.metrics.cycles = self.now;
        self.metrics.bytes_demand = self.link.bytes_demand;
        self.metrics.bytes_prefetch = self.link.bytes_prefetch;
        self.metrics.pcie_series = self.link.bandwidth_series();
        self.metrics.tlb_hits = self.gmmu.hits();
        self.metrics.tlb_misses = self.gmmu.misses();
        self.metrics.evictions = self.device.evictions;
        self.metrics.evicted_unused_prefetches = self.device.evicted_unused_prefetches;
        self.metrics.discards = self.device.discards;
        self.metrics.lazy_discard_reclaims = self.device.lazy_discard_reclaims;
        self.metrics.advised_pages = self.device.advised_read_mostly;
        if let Some(mut tel) = self.telemetry.take() {
            tel.set_batches(self.prefetcher.take_batch_events());
            tel.set_postmortem(self.prefetcher.take_postmortem());
            if let Err(e) = tel.write(&self.metrics) {
                eprintln!("telemetry: write failed: {e}");
            }
        }
        if let Some(t) = self.trace.take() {
            let _ = t.finish();
        }
        self.metrics
    }

    /// Collect matured asynchronous prefetches (batched predictions)
    /// through the reusable drain buffer and apply them.
    fn drain_prefetcher(&mut self) {
        let mut drained = std::mem::take(&mut self.drain_buf);
        drained.clear();
        self.prefetcher.drain_into(self.now, &mut drained);
        if !drained.is_empty() {
            self.apply_prefetches(&drained, self.now);
        }
        self.drain_buf = drained;
    }

    fn on_dispatch(&mut self, t: Cycle, sm: u16) {
        let smi = sm as usize;
        self.sms[smi].dispatch_at = None;
        loop {
            let Some(warp) = self.sms[smi].pop_ready() else { return };
            match self.sms[smi].programs[warp as usize].next_op() {
                None => {
                    self.sms[smi].retire(warp);
                    continue;
                }
                Some(op) => {
                    let issued = op.compute as u64 + 1;
                    self.metrics.instructions += issued;
                    if self.max_instructions != 0 && self.metrics.instructions >= self.max_instructions {
                        self.stopping = true;
                    }
                    // compute burst at 1 IPC, memory instruction issues
                    // at the end of the burst.
                    let issue_at = t + op.compute as Cycle;
                    self.sms[smi].mark_waiting(warp);
                    self.schedule(issue_at, EventKind::MemIssue { sm, warp, op });
                    // SM is free again the cycle after the mem issue.
                    let next = issue_at + 1;
                    self.sms[smi].dispatch_at = Some(next);
                    self.schedule(next, EventKind::Dispatch { sm });
                    return;
                }
            }
        }
    }

    fn on_wake(&mut self, t: Cycle, sm: u16, warp: u16) {
        let smi = sm as usize;
        self.sms[smi].wake(warp);
        if self.sms[smi].dispatch_at.is_none() {
            self.sms[smi].dispatch_at = Some(t);
            self.schedule(t, EventKind::Dispatch { sm });
        }
    }

    fn on_mem_issue(&mut self, t: Cycle, sm: u16, warp: u16, op: &WarpOp) {
        let page = page_of(op.access.vaddr);
        let origin = AccessOrigin {
            sm,
            warp,
            cta: op.cta,
            tpc: sm / 2,
            kernel_id: op.kernel_id,
        };

        // Address translation. A TLB hit means the translation is
        // cached — the page is guaranteed resident (entries are only
        // installed for resident pages and shot down on eviction), the
        // access never reaches the GMMU, and it is invisible to the
        // trace, the metrics, and the prefetcher. This TLB filtering
        // is what shapes the paper's GMMU traces (§5.1): repeated
        // same-page accesses and TLB-hot vectors vanish, leaving the
        // page-transition stream the predictors learn.
        let walk = self.gmmu.translate(sm as usize, page, t, self.cfg.page_walk_cycles);
        if walk == 0 {
            // Fast path. The LRU is deliberately NOT refreshed here:
            // TLB-covered pages are by definition hot, the BTreeSet
            // update is the per-access hot spot (§Perf), and if the
            // LRU does evict a TLB-resident page under oversubscription
            // the shootdown simply forces the next access onto the
            // walk path — correct, marginally pessimistic.
            self.prefetcher.on_retired(self.metrics.instructions);
            self.schedule(t + self.cfg.dram_cycles, EventKind::Wake { sm, warp });
            return;
        }
        self.metrics.mem_accesses += 1;
        let t_eff = t + walk;

        let state = self.device.state(page, t_eff);
        let (done, miss) = match state {
            Some(PageState::Resident) => {
                self.metrics.page_hits += 1;
                let first_use = self.device.touch(page, t_eff);
                if first_use {
                    self.metrics.prefetch_used += 1;
                }
                if let Some(tel) = self.telemetry.as_deref_mut() {
                    tel.on_access(t_eff, true);
                    if first_use {
                        tel.resolve_prefetch(page, t_eff, PrefetchOutcome::Used);
                    }
                }
                self.gmmu.fill(sm as usize, page, t_eff);
                // Record the fill on the frame so the eventual eviction
                // shoots down only this SM's TLB (masked shootdown,
                // DESIGN.md §12) instead of sweeping every SM.
                self.device.note_tlb_fill(page, sm as usize);
                self.prefetcher.on_access(origin, op.access.pc, page, true, t);
                (t_eff + self.cfg.dram_cycles, 0u8)
            }
            Some(PageState::Migrating { arrival }) => {
                // MSHR merge: wait on the in-flight transfer.
                self.metrics.coalesced += 1;
                let first_use = self.device.touch(page, arrival);
                if first_use {
                    self.metrics.prefetch_used += 1;
                }
                if let Some(tel) = self.telemetry.as_deref_mut() {
                    tel.on_access(t_eff, false);
                    if first_use {
                        // Demand arrived while the prefetch was still
                        // in flight: counted as used, tagged late.
                        tel.resolve_prefetch(page, arrival, PrefetchOutcome::Late);
                    }
                }
                self.prefetcher.on_access(origin, op.access.pc, page, false, t);
                (arrival.max(t_eff) + self.cfg.dram_cycles, 1u8)
            }
            None => {
                // Far-fault: host-side service + page transfer.
                self.metrics.far_faults += 1;
                let was_dropped = self.device.was_dropped(page);
                if was_dropped {
                    // The page left the device at least once (eviction
                    // or discard) — this fault is a *refault*, the
                    // thrash-ratio numerator under oversubscription.
                    self.metrics.refaults += 1;
                }
                let service_at = t_eff + self.far_fault_cycles;
                let xfer = self.link.transfer(service_at, PAGE_SIZE, false);
                for ev in self.device.admit(page, xfer.arrival, false, t_eff) {
                    self.gmmu.shootdown_masked(ev.page, &ev.tlb);
                    self.prefetcher.on_evict(ev.page);
                    if let Some(tel) = self.telemetry.as_deref_mut() {
                        if ev.lazy_reclaim {
                            tel.resolve_prefetch(ev.page, t_eff, PrefetchOutcome::Discarded);
                            tel.on_discard(t_eff, 1);
                        } else {
                            if ev.unused_prefetch {
                                let o = PrefetchOutcome::EvictedUnused;
                                tel.resolve_prefetch(ev.page, t_eff, o);
                            }
                            tel.on_eviction(t_eff);
                        }
                    }
                }
                self.device.touch(page, t_eff);
                let fault = FaultInfo {
                    now: t,
                    service_at,
                    pc: op.access.pc,
                    page,
                    origin,
                    array_id: op.access.array_id,
                    mem: MemPressure::at(self.device.occupancy(), self.device.capacity()),
                };
                // Reuse one decision buffer across all faults (taken
                // out of `self` so the prefetcher borrow is disjoint).
                let mut decision = std::mem::take(&mut self.decision_buf);
                decision.clear();
                self.prefetcher.on_fault_into(&fault, &mut decision);
                self.apply_prefetches(&decision.requests, t_eff);
                self.apply_discards(&decision.discards, t_eff);
                self.decision_buf = decision;
                if let Some(tel) = self.telemetry.as_deref_mut() {
                    tel.on_access(t_eff, false);
                    tel.on_fault(FaultSpan {
                        at: t_eff,
                        service_at,
                        start: xfer.start,
                        arrival: xfer.arrival,
                        page,
                        pc: op.access.pc,
                        sm,
                        refault: was_dropped,
                    });
                    tel.set_occupancy(t_eff, self.device.occupancy());
                }
                self.prefetcher.on_access(origin, op.access.pc, page, false, t);
                (xfer.arrival + self.cfg.dram_cycles, 1u8)
            }
        };

        if let Some(tw) = self.trace.as_mut() {
            let _ = tw.write(&TraceRecord {
                cycle: t,
                pc: op.access.pc,
                page,
                sm,
                warp,
                cta: op.cta,
                tpc: origin.tpc,
                kernel_id: op.kernel_id,
                array_id: op.access.array_id,
                miss,
            });
        }

        self.prefetcher.on_retired(self.metrics.instructions);
        self.schedule(done, EventKind::Wake { sm, warp });
    }

    /// Schedule migrations for prefetch requests; pages already known
    /// (resident or in flight) are deduplicated away.
    fn apply_prefetches(&mut self, requests: &[PrefetchRequest], now: Cycle) {
        for r in requests {
            if self.device.state(r.page, now).is_some() {
                continue;
            }
            let start = r.earliest_start.max(now);
            let xfer = self.link.transfer(start, PAGE_SIZE, true);
            for ev in self.device.admit(r.page, xfer.arrival, true, now) {
                self.gmmu.shootdown_masked(ev.page, &ev.tlb);
                self.prefetcher.on_evict(ev.page);
                if let Some(tel) = self.telemetry.as_deref_mut() {
                    if ev.lazy_reclaim {
                        tel.resolve_prefetch(ev.page, now, PrefetchOutcome::Discarded);
                        tel.on_discard(now, 1);
                    } else {
                        if ev.unused_prefetch {
                            tel.resolve_prefetch(ev.page, now, PrefetchOutcome::EvictedUnused);
                        }
                        tel.on_eviction(now);
                    }
                }
            }
            self.metrics.prefetch_transfers += 1;
            if let Some(tel) = self.telemetry.as_deref_mut() {
                tel.on_prefetch_issued(r.page, now, xfer.start, xfer.arrival);
            }
        }
    }

    /// Apply discard requests from the prefetch decision. Eager
    /// discards free the frame immediately — no writeback, no
    /// interconnect transfer — and a later return of the page counts
    /// as a refault (the discard predicted it dead). Lazy discards
    /// only mark the page; reclaims happen inside
    /// [`DeviceMemory::admit`] at pressure and surface through the
    /// same evicted-pages bookkeeping as evictions.
    fn apply_discards(&mut self, discards: &[DiscardRequest], now: Cycle) {
        for d in discards {
            if d.lazy {
                self.device.discard_lazy(d.page, now);
            } else if let Some(tlb) = self.device.discard(d.page, now) {
                self.gmmu.shootdown_masked(d.page, &tlb);
                self.prefetcher.on_evict(d.page);
                if let Some(tel) = self.telemetry.as_deref_mut() {
                    // Only unresolved (never-touched) prefetches are
                    // still in the sink's open set, so this tags
                    // exactly the prefetched-then-discarded pages.
                    tel.resolve_prefetch(d.page, now, PrefetchOutcome::Discarded);
                    tel.on_discard(now, 1);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prefetch::none::NonePrefetcher;
    use crate::types::MemAccess;
    use crate::workloads::{WarpTask, WorkloadInstance};

    fn tiny_config() -> ExperimentConfig {
        let mut exp = ExperimentConfig::default();
        exp.sim.n_sms = 2;
        exp.sim.warps_per_sm = 4;
        exp.max_instructions = 0;
        exp
    }

    fn seq_task(sm: u16, warp: u16, pages: &[u64]) -> WarpTask {
        let ops = pages
            .iter()
            .map(|&p| WarpOp {
                compute: 3,
                access: MemAccess { pc: 0x10, vaddr: p * 4096, array_id: 0, is_store: false },
                cta: 0,
                kernel_id: 0,
            })
            .collect();
        WarpTask { sm, warp, ops }
    }

    #[test]
    fn demand_paging_counts_faults_and_hits() {
        let exp = tiny_config();
        let wl = WorkloadInstance {
            name: "test".into(),
            tasks: vec![seq_task(0, 0, &[1, 1, 1, 2])],
            total_ops: 4,
        };
        let m = Simulator::new(&exp, wl, Box::new(NonePrefetcher::default()), None).run();
        // GMMU-visible accesses only: page 1 walks twice (the first
        // touch faults, the replayed walk after arrival installs the
        // TLB entry and hits) — touches 3 is then a pure TLB hit and
        // never reaches the GMMU. Page 2 faults once.
        assert_eq!(m.mem_accesses, 3);
        assert_eq!(m.far_faults, 2, "pages 1 and 2 each fault once");
        assert_eq!(m.page_hits, 1, "one GMMU-visible re-walk of page 1");
        assert_eq!(m.instructions, 16);
        assert!(m.cycles > exp.sim.far_fault_cycles(), "fault latency dominates");
    }

    #[test]
    fn mshr_merges_concurrent_faults_to_same_page() {
        let exp = tiny_config();
        // Two warps on the same SM touch the same cold page.
        let wl = WorkloadInstance {
            name: "test".into(),
            tasks: vec![seq_task(0, 0, &[5]), seq_task(0, 1, &[5])],
            total_ops: 2,
        };
        let m = Simulator::new(&exp, wl, Box::new(NonePrefetcher::default()), None).run();
        assert_eq!(m.far_faults, 1, "second access merges into the MSHR");
        assert_eq!(m.coalesced, 1);
        assert_eq!(m.pcie_bytes(), PAGE_SIZE, "page transferred once");
    }

    #[test]
    fn latency_hiding_with_multiple_warps() {
        // One warp's fault should not stall the other warp's compute.
        let exp = tiny_config();
        let wl_serial = WorkloadInstance {
            name: "a".into(),
            tasks: vec![seq_task(0, 0, &[1, 2, 3, 4])],
            total_ops: 4,
        };
        let m1 = Simulator::new(&exp, wl_serial, Box::new(NonePrefetcher::default()), None).run();
        let wl_parallel = WorkloadInstance {
            name: "b".into(),
            tasks: vec![seq_task(0, 0, &[1, 2]), seq_task(0, 1, &[3, 4])],
            total_ops: 4,
        };
        let m2 = Simulator::new(&exp, wl_parallel, Box::new(NonePrefetcher::default()), None).run();
        assert!(
            m2.cycles < m1.cycles,
            "two warps overlap faults: {} !< {}",
            m2.cycles,
            m1.cycles
        );
    }

    #[test]
    fn max_instructions_stops_early() {
        let mut exp = tiny_config();
        exp.max_instructions = 8;
        let wl = WorkloadInstance {
            name: "test".into(),
            tasks: vec![seq_task(0, 0, &[1, 2, 3, 4, 5, 6, 7, 8])],
            total_ops: 8,
        };
        let m = Simulator::new(&exp, wl, Box::new(NonePrefetcher::default()), None).run();
        assert!(m.instructions >= 8 && m.instructions <= 12, "stopped near the cap: {}", m.instructions);
    }

    /// Test prefetcher that eagerly discards the page two behind every
    /// fault — a stand-in for the dl policy's dead-block prediction.
    #[derive(Debug, Default)]
    struct DiscardingPrefetcher;

    impl Prefetcher for DiscardingPrefetcher {
        fn name(&self) -> &'static str {
            "discarding"
        }

        fn on_fault_into(&mut self, fault: &FaultInfo, out: &mut PrefetchDecision) {
            if let Some(p) = fault.page.checked_sub(2) {
                out.discards.push(DiscardRequest { page: p, lazy: false });
            }
        }
    }

    #[test]
    fn eager_discards_free_frames_without_interconnect_traffic() {
        let exp = tiny_config();
        let wl = WorkloadInstance {
            name: "t".into(),
            tasks: vec![seq_task(0, 0, &[1, 2, 3, 4, 5, 6])],
            total_ops: 6,
        };
        let m = Simulator::new(&exp, wl, Box::new(DiscardingPrefetcher), None).run();
        assert_eq!(m.far_faults, 6);
        assert_eq!(m.discards, 4, "pages 1-4 discarded two faults behind");
        assert_eq!(m.evictions, 0, "discards are not evictions");
        // The no-writeback accounting: only the six demand transfers
        // are charged to the interconnect; discards move no bytes.
        assert_eq!(m.pcie_bytes(), 6 * PAGE_SIZE);
    }

    #[test]
    fn telemetry_sink_observes_without_perturbing() {
        use crate::util::{Json, TestDir};
        let exp = tiny_config();
        let mk = || WorkloadInstance {
            name: "t".into(),
            tasks: vec![seq_task(0, 0, &[1, 2, 1, 3, 2, 4]), seq_task(1, 0, &[9, 8, 9, 7])],
            total_ops: 10,
        };
        let plain = Simulator::new(&exp, mk(), Box::new(NonePrefetcher::default()), None).run();
        let dir = TestDir::new();
        let out = dir.file("tel.json");
        let mut sim = Simulator::new(&exp, mk(), Box::new(NonePrefetcher::default()), None);
        sim.attach_telemetry(Some(out.clone()), "tiny");
        let observed = sim.run();
        assert_eq!(plain, observed, "the sink is an observer, not a participant");
        let doc = Json::parse_file(&out).expect("sink wrote a parseable document");
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some("telemetry/v1"));
        let series = doc.get("series").expect("series block");
        let total = |key: &str| -> u64 {
            series.get(key).and_then(Json::as_arr).map_or(0, |pts| {
                pts.iter().map(|p| p.as_arr().unwrap()[1].as_u64().unwrap()).sum()
            })
        };
        assert_eq!(total("accesses"), plain.mem_accesses);
        assert_eq!(total("hits"), plain.page_hits);
        assert_eq!(total("faults"), plain.far_faults);
        let n_fault_events = doc
            .get("traceEvents")
            .and_then(Json::as_arr)
            .map_or(0, |evs| {
                evs.iter()
                    .filter(|e| {
                        matches!(e.get("name").and_then(Json::as_str), Some("fault" | "refault"))
                    })
                    .count() as u64
            });
        assert_eq!(n_fault_events, plain.far_faults, "one span per far-fault");
    }

    #[test]
    fn deterministic_across_runs() {
        let exp = tiny_config();
        let mk = || WorkloadInstance {
            name: "t".into(),
            tasks: vec![seq_task(0, 0, &[1, 9, 2, 8]), seq_task(1, 0, &[3, 7, 4, 6])],
            total_ops: 8,
        };
        let m1 = Simulator::new(&exp, mk(), Box::new(NonePrefetcher::default()), None).run();
        let m2 = Simulator::new(&exp, mk(), Box::new(NonePrefetcher::default()), None).run();
        assert_eq!(m1.cycles, m2.cycles);
        assert_eq!(m1.instructions, m2.instructions);
        assert_eq!(m1.far_faults, m2.far_faults);
    }
}
