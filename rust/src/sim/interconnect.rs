//! CPU-GPU interconnect model: a single serialized full-duplex-agnostic
//! link with fixed propagation latency and finite bandwidth
//! (Table 9: PCIe 3.0 x16 ≈ 15.75 GB/s, 100-cycle latency).
//!
//! Transfers are FIFO: a transfer requested at `t` starts at
//! `max(t, busy_until)` and occupies the link for `bytes / bandwidth`
//! cycles. This is exactly the effect the paper dissects in §7.5
//! (Fig. 11): when the tree prefetcher floods the link, subsequent
//! far-faults queue behind the pending pages.
//!
//! The model also keeps a time-bucketed byte histogram so the Figure 11
//! bandwidth timeline can be regenerated.

use crate::telemetry::Rollup;
use crate::types::Cycle;

#[derive(Debug, Clone)]
pub struct Interconnect {
    bytes_per_cycle: f64,
    latency: Cycle,
    /// Link occupied until this cycle.
    busy_until: Cycle,
    /// Total bytes moved host→device (demand + prefetch).
    pub bytes_demand: u64,
    pub bytes_prefetch: u64,
    /// Per-bucket transferred bytes (Fig. 11 series) — the original
    /// one-off byte histogram, now the shared [`Rollup`] accumulator
    /// (same spread arithmetic; `pcie_series` stays byte-identical,
    /// pinned by the A/B gate).
    buckets: Rollup,
}

/// Result of scheduling one transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transfer {
    /// When the link started serving this transfer.
    pub start: Cycle,
    /// When the last byte left the link.
    pub link_done: Cycle,
    /// When the page is usable on the device (`link_done + latency`).
    pub arrival: Cycle,
}

impl Interconnect {
    pub fn new(bytes_per_cycle: f64, latency: Cycle, bucket_cycles: Cycle) -> Self {
        assert!(bytes_per_cycle > 0.0);
        Self {
            bytes_per_cycle,
            latency,
            busy_until: 0,
            bytes_demand: 0,
            bytes_prefetch: 0,
            buckets: Rollup::new(bucket_cycles),
        }
    }

    /// Schedule a host→device transfer of `bytes` requested at `t`.
    pub fn transfer(&mut self, t: Cycle, bytes: u64, is_prefetch: bool) -> Transfer {
        let start = t.max(self.busy_until);
        let duration = (bytes as f64 / self.bytes_per_cycle).ceil() as Cycle;
        let link_done = start + duration.max(1);
        self.busy_until = link_done;
        if is_prefetch {
            self.bytes_prefetch += bytes;
        } else {
            self.bytes_demand += bytes;
        }
        self.buckets.spread(start, link_done, bytes);
        Transfer { start, link_done, arrival: link_done + self.latency }
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_demand + self.bytes_prefetch
    }

    pub fn busy_until(&self) -> Cycle {
        self.busy_until
    }

    /// (bucket start cycle, bytes) series for the Fig. 11 timeline.
    pub fn bandwidth_series(&self) -> Vec<(Cycle, u64)> {
        self.buckets.series()
    }

    pub fn bucket_cycles(&self) -> Cycle {
        self.buckets.bucket_cycles()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> Interconnect {
        // 10 bytes/cycle, 100-cycle latency, 1000-cycle buckets.
        Interconnect::new(10.0, 100, 1000)
    }

    #[test]
    fn single_transfer_timing() {
        let mut l = link();
        let t = l.transfer(50, 4096, false);
        assert_eq!(t.start, 50);
        assert_eq!(t.link_done, 50 + 410); // ceil(4096/10)
        assert_eq!(t.arrival, t.link_done + 100);
        assert_eq!(l.bytes_demand, 4096);
    }

    #[test]
    fn fifo_queueing_serializes() {
        let mut l = link();
        let a = l.transfer(0, 4096, false);
        let b = l.transfer(0, 4096, true);
        assert_eq!(b.start, a.link_done, "second transfer queues behind first");
        assert!(b.arrival > a.arrival);
        assert_eq!(l.bytes_prefetch, 4096);
    }

    #[test]
    fn idle_gap_is_not_charged() {
        let mut l = link();
        let a = l.transfer(0, 100, false);
        let b = l.transfer(a.link_done + 10_000, 100, false);
        assert_eq!(b.start, a.link_done + 10_000);
    }

    #[test]
    fn bucket_totals_match_bytes() {
        let mut l = link();
        l.transfer(0, 4096, false);
        l.transfer(0, 12_345, true);
        let total: u64 = l.bandwidth_series().iter().map(|&(_, b)| b).sum();
        assert_eq!(total, l.total_bytes());
    }

    #[test]
    fn zero_length_transfer_still_occupies_one_cycle() {
        let mut l = link();
        let t = l.transfer(5, 0, false);
        assert_eq!(t.link_done, 6);
    }
}
