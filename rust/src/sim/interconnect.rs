//! CPU-GPU interconnect model: a single serialized full-duplex-agnostic
//! link with fixed propagation latency and finite bandwidth
//! (Table 9: PCIe 3.0 x16 ≈ 15.75 GB/s, 100-cycle latency).
//!
//! Transfers are FIFO: a transfer requested at `t` starts at
//! `max(t, busy_until)` and occupies the link for `bytes / bandwidth`
//! cycles. This is exactly the effect the paper dissects in §7.5
//! (Fig. 11): when the tree prefetcher floods the link, subsequent
//! far-faults queue behind the pending pages.
//!
//! The model also keeps a time-bucketed byte histogram so the Figure 11
//! bandwidth timeline can be regenerated.

use crate::types::Cycle;

#[derive(Debug, Clone)]
pub struct Interconnect {
    bytes_per_cycle: f64,
    latency: Cycle,
    bucket_cycles: Cycle,
    /// Link occupied until this cycle.
    busy_until: Cycle,
    /// Total bytes moved host→device (demand + prefetch).
    pub bytes_demand: u64,
    pub bytes_prefetch: u64,
    /// Per-bucket transferred bytes (Fig. 11 series).
    buckets: Vec<u64>,
}

/// Result of scheduling one transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transfer {
    /// When the link started serving this transfer.
    pub start: Cycle,
    /// When the last byte left the link.
    pub link_done: Cycle,
    /// When the page is usable on the device (`link_done + latency`).
    pub arrival: Cycle,
}

impl Interconnect {
    pub fn new(bytes_per_cycle: f64, latency: Cycle, bucket_cycles: Cycle) -> Self {
        assert!(bytes_per_cycle > 0.0);
        assert!(bucket_cycles > 0);
        Self {
            bytes_per_cycle,
            latency,
            bucket_cycles,
            busy_until: 0,
            bytes_demand: 0,
            bytes_prefetch: 0,
            buckets: Vec::new(),
        }
    }

    /// Schedule a host→device transfer of `bytes` requested at `t`.
    pub fn transfer(&mut self, t: Cycle, bytes: u64, is_prefetch: bool) -> Transfer {
        let start = t.max(self.busy_until);
        let duration = (bytes as f64 / self.bytes_per_cycle).ceil() as Cycle;
        let link_done = start + duration.max(1);
        self.busy_until = link_done;
        if is_prefetch {
            self.bytes_prefetch += bytes;
        } else {
            self.bytes_demand += bytes;
        }
        self.record_buckets(start, link_done, bytes);
        Transfer { start, link_done, arrival: link_done + self.latency }
    }

    /// Spread `bytes` uniformly over the buckets spanned by
    /// `[start, done)`.
    fn record_buckets(&mut self, start: Cycle, done: Cycle, bytes: u64) {
        let first = (start / self.bucket_cycles) as usize;
        let last = ((done.saturating_sub(1)) / self.bucket_cycles) as usize;
        if self.buckets.len() <= last {
            self.buckets.resize(last + 1, 0);
        }
        let n = (last - first + 1) as u64;
        for b in first..=last {
            self.buckets[b] += bytes / n;
        }
        // Remainder goes to the first bucket (keeps totals exact).
        self.buckets[first] += bytes % n;
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_demand + self.bytes_prefetch
    }

    pub fn busy_until(&self) -> Cycle {
        self.busy_until
    }

    /// (bucket start cycle, bytes) series for the Fig. 11 timeline.
    pub fn bandwidth_series(&self) -> Vec<(Cycle, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, &b)| (i as Cycle * self.bucket_cycles, b))
            .collect()
    }

    pub fn bucket_cycles(&self) -> Cycle {
        self.bucket_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> Interconnect {
        // 10 bytes/cycle, 100-cycle latency, 1000-cycle buckets.
        Interconnect::new(10.0, 100, 1000)
    }

    #[test]
    fn single_transfer_timing() {
        let mut l = link();
        let t = l.transfer(50, 4096, false);
        assert_eq!(t.start, 50);
        assert_eq!(t.link_done, 50 + 410); // ceil(4096/10)
        assert_eq!(t.arrival, t.link_done + 100);
        assert_eq!(l.bytes_demand, 4096);
    }

    #[test]
    fn fifo_queueing_serializes() {
        let mut l = link();
        let a = l.transfer(0, 4096, false);
        let b = l.transfer(0, 4096, true);
        assert_eq!(b.start, a.link_done, "second transfer queues behind first");
        assert!(b.arrival > a.arrival);
        assert_eq!(l.bytes_prefetch, 4096);
    }

    #[test]
    fn idle_gap_is_not_charged() {
        let mut l = link();
        let a = l.transfer(0, 100, false);
        let b = l.transfer(a.link_done + 10_000, 100, false);
        assert_eq!(b.start, a.link_done + 10_000);
    }

    #[test]
    fn bucket_totals_match_bytes() {
        let mut l = link();
        l.transfer(0, 4096, false);
        l.transfer(0, 12_345, true);
        let total: u64 = l.bandwidth_series().iter().map(|&(_, b)| b).sum();
        assert_eq!(total, l.total_bytes());
    }

    #[test]
    fn zero_length_transfer_still_occupies_one_cycle() {
        let mut l = link();
        let t = l.transfer(5, 0, false);
        assert_eq!(t.link_done, 6);
    }
}
