//! GPU memory-management unit front end: per-SM last-level TLBs.
//!
//! A TLB hit skips the 100-cycle page-table walk (Table 9). The TLB
//! caches translations for *resident* pages only; a far-fault
//! invalidates nothing (the entry never existed) and an eviction
//! shoots down the page's stale translations. Eviction-time shootdown
//! is masked: the engine records which SMs filled an entry for each
//! frame ([`SmSet`], DESIGN.md §12), so [`Gmmu::shootdown_masked`]
//! visits only those TLBs instead of scanning every SM per eviction.

use crate::sim::device_memory::SmSet;
use crate::types::{Cycle, PageNum};

/// A small fully-associative LRU TLB (64 entries by default — linear
/// scan is faster than hashing at this size).
#[derive(Debug, Clone)]
pub struct Tlb {
    entries: Vec<(PageNum, Cycle)>,
    capacity: usize,
    pub hits: u64,
    pub misses: u64,
}

impl Tlb {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self { entries: Vec::with_capacity(capacity), capacity, hits: 0, misses: 0 }
    }

    /// Look up a translation; counts hit/miss and refreshes LRU stamp.
    pub fn lookup(&mut self, page: PageNum, now: Cycle) -> bool {
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == page) {
            e.1 = now;
            self.hits += 1;
            true
        } else {
            self.misses += 1;
            false
        }
    }

    /// Install a translation (after a successful walk of a resident
    /// page), evicting the LRU entry if full.
    ///
    /// Caller contract: the page is **not** already present. A fill
    /// only ever follows a [`Tlb::lookup`] miss in the same event, so
    /// absence is already proven — re-scanning `entries` here (as this
    /// method once did) paid a second full linear pass on every fill
    /// for nothing. Enforced in debug builds.
    pub fn insert(&mut self, page: PageNum, now: Cycle) {
        debug_assert!(
            !self.entries.iter().any(|e| e.0 == page),
            "TLB fill of already-present page {page} — a fill must follow a lookup miss"
        );
        if self.entries.len() >= self.capacity {
            let (idx, _) =
                self.entries.iter().enumerate().min_by_key(|(_, e)| e.1).expect("non-empty");
            self.entries.swap_remove(idx);
        }
        self.entries.push((page, now));
    }

    /// Shoot down a translation (page migrated away).
    pub fn invalidate(&mut self, page: PageNum) {
        self.entries.retain(|e| e.0 != page);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The GMMU: one TLB per SM plus far-fault MSHR statistics. The MSHR
/// merge itself is represented by `DeviceMemory`'s `Migrating` state
/// (a second fault to an in-flight page waits on the same transfer).
#[derive(Debug)]
pub struct Gmmu {
    tlbs: Vec<Tlb>,
}

impl Gmmu {
    pub fn new(n_sms: usize, tlb_entries: usize) -> Self {
        Self { tlbs: (0..n_sms).map(|_| Tlb::new(tlb_entries)).collect() }
    }

    /// Translate on SM `sm`; returns the extra latency (0 on TLB hit,
    /// `walk_cycles` on miss).
    pub fn translate(&mut self, sm: usize, page: PageNum, now: Cycle, walk_cycles: Cycle) -> Cycle {
        if self.tlbs[sm].lookup(page, now) {
            0
        } else {
            walk_cycles
        }
    }

    /// Install after a successful walk (resident page).
    pub fn fill(&mut self, sm: usize, page: PageNum, now: Cycle) {
        self.tlbs[sm].insert(page, now);
    }

    /// Global shootdown on eviction.
    pub fn shootdown(&mut self, page: PageNum) {
        for t in &mut self.tlbs {
            t.invalidate(page);
        }
    }

    /// Targeted shootdown: invalidate only the SMs in `mask` — the
    /// frame's recorded fill set, a superset of the TLBs actually
    /// holding the page, so every skipped SM would have been a no-op
    /// `retain` scan. Falls back to the full sweep when the mask
    /// saturated (SM ids past the mask width).
    pub fn shootdown_masked(&mut self, page: PageNum, mask: &SmSet) {
        if mask.saturated() {
            self.shootdown(page);
            return;
        }
        for sm in mask.sms() {
            if let Some(t) = self.tlbs.get_mut(sm) {
                t.invalidate(page);
            }
        }
    }

    pub fn hits(&self) -> u64 {
        self.tlbs.iter().map(|t| t.hits).sum()
    }

    pub fn misses(&self) -> u64 {
        self.tlbs.iter().map(|t| t.misses).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_eviction() {
        let mut t = Tlb::new(2);
        t.insert(1, 0);
        t.insert(2, 1);
        assert!(t.lookup(1, 2)); // refresh 1 → 2 is LRU
        t.insert(3, 3);
        assert!(!t.lookup(2, 4), "LRU entry evicted");
        assert!(t.lookup(1, 5));
        assert!(t.lookup(3, 6));
    }

    /// Victim choice is by numerically-smallest stamp (`min_by_key`
    /// takes the *first* minimum in scan order on ties) and removal is
    /// `swap_remove`. Pinned under "wraparound" stamps — a tiny stamp
    /// after huge ones is simply oldest — so the scan-free insert path
    /// can rely on the exact ordering staying put.
    #[test]
    fn lru_victim_order_pinned_under_wraparound_stamps() {
        let mut t = Tlb::new(3);
        t.insert(1, u64::MAX - 1); // late-cycle stamps...
        t.insert(2, u64::MAX);
        t.insert(3, 5); // ...then a numerically tiny ("wrapped") one
        t.insert(4, 7);
        assert!(!t.lookup(3, 8), "numerically-smallest stamp is the victim");
        assert!(t.lookup(1, 9));
        assert!(t.lookup(2, 10));
        assert!(t.lookup(4, 11));
        // Tie on the minimum stamp: the first entry in scan order loses.
        let mut t = Tlb::new(2);
        t.insert(10, 3);
        t.insert(20, 3);
        t.insert(30, 4);
        assert!(!t.lookup(10, 5), "first minimum in scan order evicted");
        assert!(t.lookup(20, 6));
        assert!(t.lookup(30, 7));
    }

    #[test]
    fn masked_shootdown_invalidates_only_listed_sms() {
        let mut g = Gmmu::new(3, 4);
        for sm in 0..3 {
            g.fill(sm, 9, 0);
        }
        let mut mask = SmSet::default();
        mask.insert(0);
        mask.insert(2);
        g.shootdown_masked(9, &mask);
        assert_eq!(g.translate(0, 9, 1, 100), 100, "masked SM invalidated");
        assert_eq!(g.translate(1, 9, 1, 100), 0, "unlisted SM keeps its entry");
        assert_eq!(g.translate(2, 9, 1, 100), 100);
        // A saturated mask falls back to the full sweep.
        let mut g = Gmmu::new(2, 4);
        g.fill(0, 9, 0);
        g.fill(1, 9, 0);
        let mut sat = SmSet::default();
        sat.insert(200); // past the mask width → saturates
        g.shootdown_masked(9, &sat);
        assert_eq!(g.translate(0, 9, 1, 100), 100);
        assert_eq!(g.translate(1, 9, 1, 100), 100);
    }

    #[test]
    fn gmmu_translate_and_shootdown() {
        let mut g = Gmmu::new(2, 4);
        assert_eq!(g.translate(0, 9, 0, 100), 100, "cold miss pays walk");
        g.fill(0, 9, 0);
        assert_eq!(g.translate(0, 9, 1, 100), 0, "hit after fill");
        assert_eq!(g.translate(1, 9, 1, 100), 100, "TLBs are per-SM");
        g.shootdown(9);
        assert_eq!(g.translate(0, 9, 2, 100), 100, "shootdown removes entry");
    }
}
