//! GPU memory-management unit front end: per-SM last-level TLBs.
//!
//! A TLB hit skips the 100-cycle page-table walk (Table 9). The TLB
//! caches translations for *resident* pages only; a far-fault
//! invalidates nothing (the entry never existed) and an eviction
//! invalidates the page's entry in every TLB, as the driver shoots
//! down stale translations on migration.

use crate::types::{Cycle, PageNum};

/// A small fully-associative LRU TLB (64 entries by default — linear
/// scan is faster than hashing at this size).
#[derive(Debug, Clone)]
pub struct Tlb {
    entries: Vec<(PageNum, Cycle)>,
    capacity: usize,
    pub hits: u64,
    pub misses: u64,
}

impl Tlb {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self { entries: Vec::with_capacity(capacity), capacity, hits: 0, misses: 0 }
    }

    /// Look up a translation; counts hit/miss and refreshes LRU stamp.
    pub fn lookup(&mut self, page: PageNum, now: Cycle) -> bool {
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == page) {
            e.1 = now;
            self.hits += 1;
            true
        } else {
            self.misses += 1;
            false
        }
    }

    /// Install a translation (after a successful walk of a resident
    /// page), evicting the LRU entry if full.
    pub fn insert(&mut self, page: PageNum, now: Cycle) {
        if self.entries.iter().any(|e| e.0 == page) {
            return;
        }
        if self.entries.len() >= self.capacity {
            let (idx, _) =
                self.entries.iter().enumerate().min_by_key(|(_, e)| e.1).expect("non-empty");
            self.entries.swap_remove(idx);
        }
        self.entries.push((page, now));
    }

    /// Shoot down a translation (page migrated away).
    pub fn invalidate(&mut self, page: PageNum) {
        self.entries.retain(|e| e.0 != page);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The GMMU: one TLB per SM plus far-fault MSHR statistics. The MSHR
/// merge itself is represented by `DeviceMemory`'s `Migrating` state
/// (a second fault to an in-flight page waits on the same transfer).
#[derive(Debug)]
pub struct Gmmu {
    tlbs: Vec<Tlb>,
}

impl Gmmu {
    pub fn new(n_sms: usize, tlb_entries: usize) -> Self {
        Self { tlbs: (0..n_sms).map(|_| Tlb::new(tlb_entries)).collect() }
    }

    /// Translate on SM `sm`; returns the extra latency (0 on TLB hit,
    /// `walk_cycles` on miss).
    pub fn translate(&mut self, sm: usize, page: PageNum, now: Cycle, walk_cycles: Cycle) -> Cycle {
        if self.tlbs[sm].lookup(page, now) {
            0
        } else {
            walk_cycles
        }
    }

    /// Install after a successful walk (resident page).
    pub fn fill(&mut self, sm: usize, page: PageNum, now: Cycle) {
        self.tlbs[sm].insert(page, now);
    }

    /// Global shootdown on eviction.
    pub fn shootdown(&mut self, page: PageNum) {
        for t in &mut self.tlbs {
            t.invalidate(page);
        }
    }

    pub fn hits(&self) -> u64 {
        self.tlbs.iter().map(|t| t.hits).sum()
    }

    pub fn misses(&self) -> u64 {
        self.tlbs.iter().map(|t| t.misses).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_eviction() {
        let mut t = Tlb::new(2);
        t.insert(1, 0);
        t.insert(2, 1);
        assert!(t.lookup(1, 2)); // refresh 1 → 2 is LRU
        t.insert(3, 3);
        assert!(!t.lookup(2, 4), "LRU entry evicted");
        assert!(t.lookup(1, 5));
        assert!(t.lookup(3, 6));
    }

    #[test]
    fn duplicate_insert_is_noop() {
        let mut t = Tlb::new(2);
        t.insert(1, 0);
        t.insert(1, 5);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn gmmu_translate_and_shootdown() {
        let mut g = Gmmu::new(2, 4);
        assert_eq!(g.translate(0, 9, 0, 100), 100, "cold miss pays walk");
        g.fill(0, 9, 0);
        assert_eq!(g.translate(0, 9, 1, 100), 0, "hit after fill");
        assert_eq!(g.translate(1, 9, 1, 100), 100, "TLBs are per-SM");
        g.shootdown(9);
        assert_eq!(g.translate(0, 9, 2, 100), 100, "shootdown removes entry");
    }
}
