//! Run metrics: IPC, page hit rate, interconnect traffic, prefetcher
//! accuracy / coverage, and the paper's composite "unity" metric
//! (§7.6, Eq. 1):
//!
//! ```text
//! Unity := cbrt(Accuracy * Coverage * Page_hit_rate)
//! ```
//!
//! Operational definitions (chosen to match the paper's Table 11
//! semantics — see DESIGN.md §2):
//!
//! * **Page hit rate** — fraction of device-memory accesses that find
//!   their page *resident* (arrived) on device. In-flight pages count
//!   as misses: the demanded page was not "available at the GPU side".
//! * **Accuracy** — fraction of prefetch *transfers* whose page is
//!   demanded at least once before eviction ("prefetched memory chunks
//!   that end up being used", Bhatia et al.).
//! * **Coverage** — fraction of demanded pages whose arrival was
//!   anticipated. Every demanded page reaches the device either via a
//!   prefetch (covered) or via its own far-fault (not covered), so
//!   coverage = used_prefetches / (used_prefetches + far_faults).
//!   The tree prefetcher migrates whole blocks/nodes, so nearly every
//!   demanded page rides a block transaction → coverage ≈ 1.0 (every
//!   "U" row of Table 11); a learned policy's coverage tracks how many
//!   future pages its predictions actually anticipated.

use crate::types::Cycle;

#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Metrics {
    // --- SM side ---
    pub instructions: u64,
    pub cycles: Cycle,
    pub mem_accesses: u64,
    pub page_hits: u64,
    /// Access waited on an in-flight transfer (MSHR merge).
    pub coalesced: u64,
    pub far_faults: u64,
    pub tlb_hits: u64,
    pub tlb_misses: u64,
    // --- prefetcher quality ---
    pub prefetch_transfers: u64,
    pub prefetch_used: u64,
    // --- interconnect ---
    pub bytes_demand: u64,
    pub bytes_prefetch: u64,
    /// (bucket start cycle, bytes) — Fig. 11 series.
    pub pcie_series: Vec<(Cycle, u64)>,
    pub pcie_bucket_cycles: Cycle,
    // --- memory pressure ---
    pub evictions: u64,
    pub evicted_unused_prefetches: u64,
    /// Far-faults on pages that had been resident and were evicted —
    /// the thrash signal under oversubscription.
    pub refaults: u64,
    /// Device capacity in page frames the run actually used (after
    /// `oversub_ratio` resolution against the workload footprint).
    pub capacity_pages: u64,
    /// Distinct pages the workload touches; only computed (non-zero)
    /// for oversubscribed runs (`oversub_ratio` < 1.0).
    pub footprint_pages: u64,
    /// Pages dropped by discard commands (eager + reclaimed lazy) —
    /// freed with no writeback and no interconnect traffic.
    pub discards: u64,
    /// Subset of `discards`: lazy marks reclaimed at admission
    /// pressure (`UvmDiscardAsync`-style deferral).
    pub lazy_discard_reclaims: u64,
    /// Pages newly marked read-mostly by advise commands.
    pub advised_pages: u64,
    // --- predictor telemetry (DL policy only) ---
    pub predictions: u64,
    pub prediction_batches: u64,
    pub bypass_predictions: u64,
    pub oov_predictions: u64,
    pub finetune_rounds: u64,
}

impl Metrics {
    /// Aggregate IPC across all SMs (instructions per core cycle).
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.instructions as f64 / self.cycles as f64
        }
    }

    /// Device-memory page hit rate (Table 10).
    pub fn page_hit_rate(&self) -> f64 {
        if self.mem_accesses == 0 {
            0.0
        } else {
            self.page_hits as f64 / self.mem_accesses as f64
        }
    }

    /// Prefetcher accuracy (Table 11 "Acc.").
    pub fn accuracy(&self) -> f64 {
        if self.prefetch_transfers == 0 {
            // A policy that never prefetches is vacuously precise; the
            // paper's ideal column uses 1.0 for this degenerate case.
            1.0
        } else {
            self.prefetch_used as f64 / self.prefetch_transfers as f64
        }
    }

    /// Prefetcher coverage (Table 11 "Cov."): anticipated page
    /// arrivals over all page arrivals.
    pub fn coverage(&self) -> f64 {
        let demanded = self.prefetch_used + self.far_faults;
        if demanded == 0 {
            1.0
        } else {
            self.prefetch_used as f64 / demanded as f64
        }
    }

    /// Composite metric (Eq. 1).
    pub fn unity(&self) -> f64 {
        (self.accuracy() * self.coverage() * self.page_hit_rate()).cbrt()
    }

    /// Total host→device traffic in bytes (Fig. 12 numerator).
    pub fn pcie_bytes(&self) -> u64 {
        self.bytes_demand + self.bytes_prefetch
    }

    /// Fraction of far-faults that re-fetch a previously evicted page
    /// (0 when the run never faults). 1.0 means the device is purely
    /// cycling its own evictions — full thrash.
    pub fn thrash_ratio(&self) -> f64 {
        if self.far_faults == 0 {
            0.0
        } else {
            self.refaults as f64 / self.far_faults as f64
        }
    }

    /// Average PCIe bandwidth in GB/s given the core clock.
    pub fn pcie_avg_gbps(&self, clock_mhz: u64) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        let seconds = self.cycles as f64 / (clock_mhz as f64 * 1e6);
        self.pcie_bytes() as f64 / 1e9 / seconds
    }

    /// One-line human summary (used by `repro simulate`).
    pub fn summary(&self) -> String {
        format!(
            "inst={} cycles={} ipc={:.4} accesses={} hit={:.4} faults={} coalesced={} \
             pf_xfers={} acc={:.4} cov={:.4} unity={:.4} bytes={} evict={} refault={} \
             thrash={:.4} discard={} lazy_reclaim={} advised={}",
            self.instructions,
            self.cycles,
            self.ipc(),
            self.mem_accesses,
            self.page_hit_rate(),
            self.far_faults,
            self.coalesced,
            self.prefetch_transfers,
            self.accuracy(),
            self.coverage(),
            self.unity(),
            self.pcie_bytes(),
            self.evictions,
            self.refaults,
            self.thrash_ratio(),
            self.discards,
            self.lazy_discard_reclaims,
            self.advised_pages,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unity_is_cbrt_of_product() {
        let m = Metrics {
            mem_accesses: 100,
            page_hits: 50,
            prefetch_transfers: 10,
            prefetch_used: 5,
            far_faults: 5,
            ..Default::default()
        };
        // acc = 5/10, cov = 5/(5+5), hit = 50/100.
        let expected = (0.5f64 * 0.5 * 0.5).cbrt();
        assert!((m.unity() - expected).abs() < 1e-12);
        assert!((m.unity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn perfect_prefetcher_unity_is_one() {
        let m = Metrics {
            mem_accesses: 10,
            page_hits: 10,
            prefetch_transfers: 4,
            prefetch_used: 4,
            far_faults: 0,
            ..Default::default()
        };
        assert!((m.unity() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_counters_do_not_nan() {
        let m = Metrics::default();
        assert_eq!(m.ipc(), 0.0);
        assert_eq!(m.page_hit_rate(), 0.0);
        assert_eq!(m.accuracy(), 1.0);
        assert_eq!(m.coverage(), 1.0);
        assert!(!m.unity().is_nan());
        assert_eq!(m.thrash_ratio(), 0.0);
    }

    #[test]
    fn thrash_ratio_is_refaults_over_faults() {
        let m = Metrics { far_faults: 8, refaults: 2, ..Default::default() };
        assert!((m.thrash_ratio() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn summary_reports_discard_and_advise_counters() {
        let m = Metrics {
            discards: 7,
            lazy_discard_reclaims: 3,
            advised_pages: 11,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("discard=7"), "{s}");
        assert!(s.contains("lazy_reclaim=3"), "{s}");
        assert!(s.contains("advised=11"), "{s}");
    }
}
