//! Streaming-multiprocessor model.
//!
//! Each SM holds up to 64 warp contexts (Table 9) and issues **one
//! instruction per cycle** from the ready pool under a GTO
//! (greedy-then-oldest) policy: the current warp runs until its next
//! memory operation, then the SM switches to the oldest ready warp
//! while the access is serviced. Memory latency is therefore hidden
//! exactly when other warps have compute to issue — the mechanism the
//! paper's IPC numbers hinge on (a 45 µs far-fault stalls a warp for
//! ~66 k cycles; with 64 warps the SM starves only when *all* of them
//! are waiting on pages).

use crate::types::{CtaId, Cycle, MemAccess};
use std::collections::VecDeque;

/// One warp-level step: `compute` arithmetic instructions followed by
/// a single coalesced memory instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WarpOp {
    pub compute: u32,
    pub access: MemAccess,
    pub cta: CtaId,
    pub kernel_id: u16,
}

/// A warp's instruction stream (materialized by the workload
/// generator; see `workloads/`).
#[derive(Debug)]
pub struct WarpProgram {
    ops: std::vec::IntoIter<WarpOp>,
    /// Total instructions issued by this warp so far.
    pub issued: u64,
}

impl WarpProgram {
    pub fn new(ops: Vec<WarpOp>) -> Self {
        Self { ops: ops.into_iter(), issued: 0 }
    }

    pub fn empty() -> Self {
        Self::new(Vec::new())
    }

    #[inline]
    pub fn next_op(&mut self) -> Option<WarpOp> {
        self.ops.next()
    }

    pub fn remaining_hint(&self) -> usize {
        self.ops.len()
    }
}

/// Scheduling state of one warp slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WarpState {
    Ready,
    WaitingMem,
    Done,
}

/// Per-SM scheduler state.
#[derive(Debug)]
pub struct SmState {
    pub programs: Vec<WarpProgram>,
    pub states: Vec<WarpState>,
    /// Ready warps, oldest first (GTO tie-break).
    pub ready: VecDeque<u16>,
    /// The SM has a dispatch event in flight at this cycle (dedup).
    pub dispatch_at: Option<Cycle>,
    pub live_warps: usize,
}

impl SmState {
    pub fn new(n_warps: usize) -> Self {
        Self {
            programs: (0..n_warps).map(|_| WarpProgram::empty()).collect(),
            states: vec![WarpState::Done; n_warps],
            ready: VecDeque::new(),
            dispatch_at: None,
            live_warps: 0,
        }
    }

    /// Install a program on a warp slot and mark it ready.
    pub fn load_warp(&mut self, warp: u16, program: WarpProgram) {
        let w = warp as usize;
        if program.remaining_hint() == 0 {
            self.states[w] = WarpState::Done;
            return;
        }
        self.programs[w] = program;
        self.states[w] = WarpState::Ready;
        self.ready.push_back(warp);
        self.live_warps += 1;
    }

    /// Oldest ready warp, if any.
    pub fn pop_ready(&mut self) -> Option<u16> {
        self.ready.pop_front()
    }

    pub fn mark_waiting(&mut self, warp: u16) {
        self.states[warp as usize] = WarpState::WaitingMem;
    }

    /// Memory completed: warp becomes ready again.
    pub fn wake(&mut self, warp: u16) {
        debug_assert_eq!(self.states[warp as usize], WarpState::WaitingMem);
        self.states[warp as usize] = WarpState::Ready;
        self.ready.push_back(warp);
    }

    /// Warp ran out of instructions.
    pub fn retire(&mut self, warp: u16) {
        self.states[warp as usize] = WarpState::Done;
        self.live_warps -= 1;
    }

    pub fn all_done(&self) -> bool {
        self.live_warps == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::MemAccess;

    fn op(vaddr: u64) -> WarpOp {
        WarpOp {
            compute: 2,
            access: MemAccess { pc: 0x100, vaddr, array_id: 0, is_store: false },
            cta: 0,
            kernel_id: 0,
        }
    }

    #[test]
    fn load_and_retire_lifecycle() {
        let mut sm = SmState::new(4);
        sm.load_warp(1, WarpProgram::new(vec![op(0)]));
        assert_eq!(sm.live_warps, 1);
        assert_eq!(sm.pop_ready(), Some(1));
        sm.mark_waiting(1);
        sm.wake(1);
        assert_eq!(sm.pop_ready(), Some(1));
        sm.retire(1);
        assert!(sm.all_done());
    }

    #[test]
    fn empty_program_is_immediately_done() {
        let mut sm = SmState::new(2);
        sm.load_warp(0, WarpProgram::empty());
        assert!(sm.all_done());
        assert_eq!(sm.pop_ready(), None);
    }

    #[test]
    fn ready_queue_is_fifo_oldest_first() {
        let mut sm = SmState::new(4);
        for w in 0..3 {
            sm.load_warp(w, WarpProgram::new(vec![op(w as u64 * 4096)]));
        }
        assert_eq!(sm.pop_ready(), Some(0));
        assert_eq!(sm.pop_ready(), Some(1));
        assert_eq!(sm.pop_ready(), Some(2));
    }
}
