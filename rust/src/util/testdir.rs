//! RAII temporary directory (stand-in for the `tempfile` crate, which
//! is unavailable in the offline build).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique directory under the system temp dir, removed on drop.
#[derive(Debug)]
pub struct TestDir {
    path: PathBuf,
}

impl TestDir {
    pub fn new() -> Self {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "uvm-prefetch-{}-{}-{n}",
            std::process::id(),
            std::thread::current().name().unwrap_or("t").replace("::", "-"),
        ));
        std::fs::create_dir_all(&path).expect("create test dir");
        Self { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn file(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Default for TestDir {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for TestDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let p;
        {
            let d = TestDir::new();
            p = d.path().to_path_buf();
            std::fs::write(d.file("x.txt"), "hi").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists(), "removed on drop");
    }

    #[test]
    fn unique_across_instances() {
        let a = TestDir::new();
        let b = TestDir::new();
        assert_ne!(a.path(), b.path());
    }
}
