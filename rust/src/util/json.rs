//! Minimal JSON parser + serializer.
//!
//! The offline build environment provides no serde facade, so the
//! artifact interchange files (`manifest.json`, `*.vocab.json`,
//! `benchmarks.json`, experiment configs) are handled by this
//! self-contained implementation. Scope: the full JSON grammar minus
//! exotic number forms; numbers are kept as f64 (exact for the
//! magnitudes this repo stores — page numbers < 2^53).

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------- constructors ----------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn num<T: Into<f64>>(v: T) -> Json {
        Json::Num(v.into())
    }

    // ---------- accessors ----------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name (for required fields).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|f| *f >= 0.0).map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---------- serialization ----------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---------- parsing ----------
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_string())?;
        Ok(())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number '{s}': {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']', got {other:?} at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}', got {other:?} at byte {}", self.i),
            }
        }
    }
}

/// Helper: array of i64s → Json.
pub fn arr_i64(values: &[i64]) -> Json {
    Json::Arr(values.iter().map(|&v| Json::Num(v as f64)).collect())
}

/// Helper: array of u64s → Json.
pub fn arr_u64(values: &[u64]) -> Json {
    Json::Arr(values.iter().map(|&v| Json::Num(v as f64)).collect())
}

/// Helper: Json array → Vec<i64>.
pub fn vec_i64(j: &Json) -> Result<Vec<i64>> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("expected array"))?
        .iter()
        .map(|v| v.as_i64().ok_or_else(|| anyhow::anyhow!("expected int")))
        .collect()
}

/// Helper: Json array → Vec<u64>.
pub fn vec_u64(j: &Json) -> Result<Vec<u64>> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("expected array"))?
        .iter()
        .map(|v| v.as_u64().ok_or_else(|| anyhow::anyhow!("expected uint")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let j = Json::obj(vec![
            ("name", Json::str("atax")),
            ("deltas", arr_i64(&[-4, 0, 16384])),
            ("conv", Json::Num(0.9926)),
            ("ok", Json::Bool(true)),
            ("nested", Json::obj(vec![("x", Json::Null)])),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parses_whitespace_and_escapes() {
        let j = Json::parse(" { \"a\\n\\\"b\" : [ 1 , -2.5e1 , null ] } ").unwrap();
        let key = "a\n\"b";
        let arr = j.get(key).unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[1].as_f64(), Some(-25.0));
        assert_eq!(arr[2], Json::Null);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse("\"\\u00e9\"").unwrap();
        assert_eq!(j.as_str(), Some("é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn integers_serialized_without_decimal_point() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(-3.0).to_string(), "-3");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn large_page_numbers_roundtrip_exactly() {
        let page: u64 = (1 << 52) + 12345;
        let j = Json::Num(page as f64);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.as_u64(), Some(page));
    }

    #[test]
    fn file_roundtrip() {
        let path = std::env::temp_dir().join(format!("uvm-json-{}.json", std::process::id()));
        let j = Json::obj(vec![("v", Json::num(1.0))]);
        j.write_file(&path).unwrap();
        let back = Json::parse_file(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(back, j);
    }
}
