//! Hand-rolled micro-benchmark harness (criterion is unavailable in
//! the offline build). Warms up, runs timed batches until a minimum
//! measurement window is reached, and reports mean/min wall time with
//! throughput.
//!
//! Results can be persisted to a `bench_sim/v1` JSON artifact
//! ([`write_bench_sim`]): one file holding every suite's cases plus
//! the `repro perf` summary, merged read-modify-write so the cargo
//! benches and the perf subcommand share `BENCH_sim.json`.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

/// Schema tag of the shared benchmark artifact.
pub const BENCH_SIM_SCHEMA: &str = "bench_sim/v1";

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub min_ns: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items: u64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let thr = if self.items > 0 {
            let per_sec = self.items as f64 / (self.mean_ns / 1e9);
            format!("  {:>12.0} items/s", per_sec)
        } else {
            String::new()
        };
        format!(
            "{:<44} {:>12} iters  mean {:>12}  min {:>12}{}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            thr
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner: fixed warm-up, then batches until `min_time`.
pub struct Bench {
    pub min_time: Duration,
    pub max_iters: u64,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new() -> Self {
        Self { min_time: Duration::from_millis(800), max_iters: u64::MAX, results: Vec::new() }
    }

    pub fn with_min_time(mut self, d: Duration) -> Self {
        self.min_time = d;
        self
    }

    /// Run one case. `f` is called once per iteration; its return
    /// value is black-boxed.
    pub fn case<T, F: FnMut() -> T>(&mut self, name: &str, items: u64, mut f: F) -> &BenchResult {
        // Warm-up: a few calls, also measures a rough per-iter cost.
        let warm = Instant::now();
        black_box(f());
        black_box(f());
        let rough = warm.elapsed().as_nanos().max(1) as u64 / 2;

        let mut total_ns: u128 = 0;
        let mut iters: u64 = 0;
        let mut min_ns = f64::INFINITY;
        // Batch size targets ~10ms per measurement.
        let batch = (10_000_000 / rough).clamp(1, 1_000_000);
        while total_ns < self.min_time.as_nanos() && iters < self.max_iters {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            let dt = t0.elapsed().as_nanos();
            total_ns += dt;
            iters += batch;
            min_ns = min_ns.min(dt as f64 / batch as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: total_ns as f64 / iters as f64,
            min_ns,
            items,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

/// Optimization barrier (stable-rust approximation).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Load an existing `bench_sim/v1` artifact as a mutable object map;
/// anything unreadable or off-schema starts a fresh document.
fn load_bench_sim(path: &Path) -> BTreeMap<String, Json> {
    match Json::parse_file(path) {
        Ok(Json::Obj(m)) if m.get("schema").and_then(Json::as_str) == Some(BENCH_SIM_SCHEMA) => m,
        _ => BTreeMap::new(),
    }
}

/// Insert or replace one top-level section (e.g. `perf`) of the
/// artifact, preserving every other section on disk.
pub fn merge_bench_sim_section(path: &Path, key: &str, value: Json) -> anyhow::Result<()> {
    let mut root = load_bench_sim(path);
    root.insert("schema".into(), Json::str(BENCH_SIM_SCHEMA));
    root.insert(key.into(), value);
    Json::Obj(root).write_file(path)
}

/// Persist one suite's results under `suites.<suite>.cases`,
/// read-modify-write: other suites (and the `perf` section) written by
/// earlier invocations survive.
pub fn write_bench_sim(path: &Path, suite: &str, results: &[BenchResult]) -> anyhow::Result<()> {
    let mut root = load_bench_sim(path);
    root.insert("schema".into(), Json::str(BENCH_SIM_SCHEMA));
    let mut suites = match root.remove("suites") {
        Some(Json::Obj(m)) => m,
        _ => BTreeMap::new(),
    };
    let cases = Json::arr(results.iter().map(|r| {
        let per_sec =
            if r.mean_ns > 0.0 { r.items as f64 / (r.mean_ns / 1e9) } else { 0.0 };
        Json::obj(vec![
            ("name", Json::str(&r.name)),
            ("iters", Json::num(r.iters as f64)),
            ("mean_ns", Json::num(r.mean_ns)),
            ("min_ns", Json::num(r.min_ns)),
            ("items", Json::num(r.items as f64)),
            ("items_per_sec", Json::num(per_sec)),
        ])
    }));
    suites.insert(suite.into(), Json::obj(vec![("cases", cases)]));
    root.insert("suites".into(), Json::Obj(suites));
    Json::Obj(root).write_file(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::new().with_min_time(Duration::from_millis(5));
        let r = b.case("noop-ish", 1, || 1 + 1).clone();
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns * 1.5 + 1.0);
    }

    #[test]
    fn formats_ranges() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }

    #[test]
    fn bench_sim_merge_preserves_other_suites_and_sections() {
        let path =
            std::env::temp_dir().join(format!("bench_sim_merge_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let r = BenchResult {
            name: "case-a".into(),
            iters: 10,
            mean_ns: 100.0,
            min_ns: 90.0,
            items: 5,
        };
        write_bench_sim(&path, "sim_core", std::slice::from_ref(&r)).unwrap();
        merge_bench_sim_section(&path, "perf", Json::obj(vec![("x", Json::num(1.0))])).unwrap();
        write_bench_sim(&path, "prefetchers", &[r]).unwrap();
        let doc = Json::parse_file(&path).unwrap();
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(BENCH_SIM_SCHEMA));
        let suites = doc.get("suites").unwrap();
        for suite in ["sim_core", "prefetchers"] {
            let cases = suites.get(suite).unwrap().get("cases").and_then(Json::as_arr).unwrap();
            assert_eq!(cases.len(), 1);
            assert_eq!(cases[0].get("name").and_then(Json::as_str), Some("case-a"));
            let per_sec = cases[0].get("items_per_sec").and_then(Json::as_f64).unwrap();
            assert!((per_sec - 5.0e7).abs() < 1.0, "5 items / 100ns = 5e7/s: {per_sec}");
        }
        assert!(doc.get("perf").is_some(), "perf section survives suite rewrites");
        let _ = std::fs::remove_file(&path);
    }
}
