//! Hand-rolled micro-benchmark harness (criterion is unavailable in
//! the offline build). Warms up, runs timed batches until a minimum
//! measurement window is reached, and reports mean/min wall time with
//! throughput.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub min_ns: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items: u64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let thr = if self.items > 0 {
            let per_sec = self.items as f64 / (self.mean_ns / 1e9);
            format!("  {:>12.0} items/s", per_sec)
        } else {
            String::new()
        };
        format!(
            "{:<44} {:>12} iters  mean {:>12}  min {:>12}{}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            thr
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner: fixed warm-up, then batches until `min_time`.
pub struct Bench {
    pub min_time: Duration,
    pub max_iters: u64,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new() -> Self {
        Self { min_time: Duration::from_millis(800), max_iters: u64::MAX, results: Vec::new() }
    }

    pub fn with_min_time(mut self, d: Duration) -> Self {
        self.min_time = d;
        self
    }

    /// Run one case. `f` is called once per iteration; its return
    /// value is black-boxed.
    pub fn case<T, F: FnMut() -> T>(&mut self, name: &str, items: u64, mut f: F) -> &BenchResult {
        // Warm-up: a few calls, also measures a rough per-iter cost.
        let warm = Instant::now();
        black_box(f());
        black_box(f());
        let rough = warm.elapsed().as_nanos().max(1) as u64 / 2;

        let mut total_ns: u128 = 0;
        let mut iters: u64 = 0;
        let mut min_ns = f64::INFINITY;
        // Batch size targets ~10ms per measurement.
        let batch = (10_000_000 / rough).clamp(1, 1_000_000);
        while total_ns < self.min_time.as_nanos() && iters < self.max_iters {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            let dt = t0.elapsed().as_nanos();
            total_ns += dt;
            iters += batch;
            min_ns = min_ns.min(dt as f64 / batch as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: total_ns as f64 / iters as f64,
            min_ns,
            items,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

/// Optimization barrier (stable-rust approximation).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::new().with_min_time(Duration::from_millis(5));
        let r = b.case("noop-ish", 1, || 1 + 1).clone();
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns * 1.5 + 1.0);
    }

    #[test]
    fn formats_ranges() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }
}
