//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, repeated
//! flags, and positional arguments; `parse` consumes `std::env::args`
//! style vectors so it is unit-testable.

use anyhow::{bail, Result};
use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.entry(name.to_string()).or_default().push(argv[i + 1].clone());
                    i += 1;
                } else {
                    // boolean flag
                    out.flags.entry(name.to_string()).or_default().push("true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.flags.get(name).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{name}: {e}")),
        }
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.u64(name, default as u64)? as usize)
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{name}: {e}")),
        }
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// First positional or error with usage text.
    pub fn positional0(&self, usage: &str) -> Result<&str> {
        match self.positional.first() {
            Some(p) => Ok(p.as_str()),
            None => bail!("missing argument\nusage: {usage}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flag_forms() {
        let a = Args::parse(&argv("cmd --x 1 --y=2 --flag --z 3.5")).unwrap();
        assert_eq!(a.positional, vec!["cmd"]);
        assert_eq!(a.u64("x", 0).unwrap(), 1);
        assert_eq!(a.str("y", ""), "2");
        assert!(a.bool("flag"));
        assert!((a.f64("z", 0.0).unwrap() - 3.5).abs() < 1e-12);
        assert_eq!(a.u64("missing", 7).unwrap(), 7);
    }

    #[test]
    fn repeated_flags_accumulate() {
        let a = Args::parse(&argv("--b one --b two")).unwrap();
        assert_eq!(a.get_all("b"), vec!["one", "two"]);
        assert_eq!(a.get("b"), Some("two"), "last wins for scalar get");
    }

    #[test]
    fn bad_numbers_error() {
        let a = Args::parse(&argv("--n abc")).unwrap();
        assert!(a.u64("n", 0).is_err());
    }
}
