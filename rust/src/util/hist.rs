//! Lock-free bucketed latency histogram.
//!
//! The coordinator's hot paths (router shards, the batch/infer thread)
//! record one latency sample per command; a `Mutex<OnlineStats>` there
//! serializes every shard on one lock. This histogram is a fixed array
//! of `AtomicU64` power-of-two buckets — `record` is two relaxed
//! fetch-adds plus a fetch-max, writers never wait, and readers compute
//! approximate percentiles (p50/p95/p99) from the cumulative bucket
//! counts. A percentile answer is the *upper bound* of the bucket the
//! rank falls in, so it over-reports by at most 2× — fine for
//! microsecond-scale serving telemetry where the magnitude matters,
//! not the third digit.
//!
//! Reads concurrent with writes are racy-but-safe: each counter is
//! individually atomic, so a snapshot may miss in-flight samples but
//! never tears.

use crate::util::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// One bucket per power of two: bucket 0 holds the value 0, bucket
/// `i ≥ 1` holds values in `[2^(i-1), 2^i - 1]`; bucket 64 tops out at
/// `u64::MAX`.
const N_BUCKETS: usize = 65;

/// Lock-free histogram over `u64` samples (microseconds, batch sizes —
/// any non-negative magnitude).
#[derive(Debug)]
pub struct AtomicHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

/// One read-side snapshot of an [`AtomicHistogram`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistSummary {
    pub n: u64,
    pub mean: f64,
    pub max: u64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
}

impl HistSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("mean", Json::Num(self.mean)),
            ("max", Json::Num(self.max as f64)),
            ("p50", Json::Num(self.p50 as f64)),
            ("p95", Json::Num(self.p95 as f64)),
            ("p99", Json::Num(self.p99 as f64)),
        ])
    }
}

impl AtomicHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    fn bucket(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        }
    }

    /// Largest value bucket `i` can hold.
    #[inline]
    fn bucket_hi(i: usize) -> u64 {
        if i >= 64 {
            u64::MAX
        } else if i == 0 {
            0
        } else {
            (1u64 << i) - 1
        }
    }

    /// Record one sample. Wait-free: relaxed atomics only.
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate percentile (`p` in `[0, 1]`): the upper bound of the
    /// first bucket whose cumulative count reaches `ceil(p · n)`.
    /// Returns 0 for an empty histogram.
    pub fn percentile(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((p.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                // Never report past the observed max (the top occupied
                // bucket's upper bound can exceed it).
                return Self::bucket_hi(i).min(self.max());
            }
        }
        self.max()
    }

    pub fn summary(&self) -> HistSummary {
        HistSummary {
            n: self.count(),
            mean: self.mean(),
            max: self.max(),
            p50: self.percentile(0.50),
            p95: self.percentile(0.95),
            p99: self.percentile(0.99),
        }
    }

    /// Fold `other`'s samples into `self` (fleet-level rollup across
    /// per-tenant or per-shard histograms). Racy-but-safe like reads: a
    /// merge concurrent with writers may miss in-flight samples on
    /// either side, but never double-counts what it did observe.
    pub fn merge(&self, other: &AtomicHistogram) {
        for (dst, src) in self.buckets.iter().zip(&other.buckets) {
            let v = src.load(Ordering::Relaxed);
            if v > 0 {
                dst.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = AtomicHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
        let s = h.summary();
        assert_eq!((s.n, s.max, s.p50), (0, 0, 0));
    }

    #[test]
    fn bucket_boundaries() {
        assert_eq!(AtomicHistogram::bucket(0), 0);
        assert_eq!(AtomicHistogram::bucket(1), 1);
        assert_eq!(AtomicHistogram::bucket(2), 2);
        assert_eq!(AtomicHistogram::bucket(3), 2);
        assert_eq!(AtomicHistogram::bucket(4), 3);
        assert_eq!(AtomicHistogram::bucket(u64::MAX), 64);
        assert_eq!(AtomicHistogram::bucket_hi(0), 0);
        assert_eq!(AtomicHistogram::bucket_hi(2), 3);
        assert_eq!(AtomicHistogram::bucket_hi(64), u64::MAX);
    }

    #[test]
    fn percentiles_are_ordered_and_bounded() {
        let h = AtomicHistogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - 500.5).abs() < 1e-9);
        let (p50, p95, p99) = (h.percentile(0.5), h.percentile(0.95), h.percentile(0.99));
        assert!(p50 <= p95 && p95 <= p99 && p99 <= h.max());
        // p50 of 1..=1000 lands in the [512, 1023] bucket, clamped to max.
        assert!(p50 >= 500, "p50 {p50}");
    }

    #[test]
    fn single_value_percentiles_are_exactish() {
        let h = AtomicHistogram::new();
        for _ in 0..100 {
            h.record(64);
        }
        // 64 lives in bucket [64, 127]; clamped to the observed max.
        assert_eq!(h.percentile(0.5), 64);
        assert_eq!(h.percentile(0.99), 64);
        assert_eq!(h.max(), 64);
    }

    #[test]
    fn concurrent_writers_lose_nothing() {
        use std::sync::Arc;
        let h = Arc::new(AtomicHistogram::new());
        let mut tasks = Vec::new();
        for t in 0..4u64 {
            let h = h.clone();
            tasks.push(std::thread::spawn(move || {
                for i in 0..10_000u64 {
                    h.record(t * 1000 + (i % 17));
                }
            }));
        }
        for t in tasks {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }

    #[test]
    fn merge_folds_counts_sum_and_max() {
        let a = AtomicHistogram::new();
        let b = AtomicHistogram::new();
        for v in 1..=100u64 {
            a.record(v);
        }
        for v in 501..=600u64 {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert_eq!(a.max(), 600);
        let total = (1..=100u64).sum::<u64>() + (501..=600u64).sum::<u64>();
        assert!((a.mean() - total as f64 / 200.0).abs() < 1e-9);
        // Percentiles see the combined distribution: p99 lands in b's range.
        assert!(a.percentile(0.99) >= 512, "p99 {}", a.percentile(0.99));
        assert!(a.percentile(0.25) <= 127, "p25 {}", a.percentile(0.25));
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let a = AtomicHistogram::new();
        a.record(42);
        let before = a.summary();
        a.merge(&AtomicHistogram::new());
        assert_eq!(a.summary(), before);
        // Merging *into* an empty histogram copies the distribution.
        let c = AtomicHistogram::new();
        c.merge(&a);
        assert_eq!(c.summary(), before);
    }

    #[test]
    fn merge_saturated_top_bucket_keeps_max() {
        let a = AtomicHistogram::new();
        let b = AtomicHistogram::new();
        a.record(0);
        b.record(u64::MAX);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), u64::MAX);
        assert_eq!(a.percentile(1.0), u64::MAX);
        assert_eq!(a.percentile(0.5), 0);
    }

    #[test]
    fn summary_json_has_all_fields() {
        let h = AtomicHistogram::new();
        h.record(10);
        let j = h.summary().to_json();
        for k in ["n", "mean", "max", "p50", "p95", "p99"] {
            assert!(j.get(k).is_some(), "{k}");
        }
    }
}
