//! Small shared utilities, all implemented in-tree for the offline
//! build: deterministic RNG, aggregate statistics, a lock-free
//! bucketed latency histogram, a JSON parser/serializer, a CLI
//! argument parser, a micro-benchmark harness, and an RAII temp dir
//! for tests.

pub mod bench;
pub mod cli;
pub mod hist;
pub mod json;
mod rng;
mod stats;
pub mod testdir;

pub use hist::{AtomicHistogram, HistSummary};
pub use json::Json;
pub use rng::XorShift64;
pub use stats::{geomean, mean, OnlineStats};
pub use testdir::TestDir;
