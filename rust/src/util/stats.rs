//! Aggregate statistics used by the evaluation harness: the paper
//! reports IPC and PCIe improvements as *geometric* means and the page
//! hit rate as an arithmetic mean (§1, §7.4).

/// Geometric mean of strictly positive values; returns 0 for empty
/// input and ignores non-positive entries (they would make the
/// product undefined — the harness never produces them, but a ratio
/// of 0 from a degenerate run must not poison a whole table).
pub fn geomean(values: &[f64]) -> f64 {
    let logs: Vec<f64> = values.iter().copied().filter(|v| *v > 0.0).map(f64::ln).collect();
    if logs.is_empty() {
        return 0.0;
    }
    (logs.iter().sum::<f64>() / logs.len() as f64).exp()
}

/// Arithmetic mean; 0 for empty input.
pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        0.0
    } else {
        values.iter().sum::<f64>() / values.len() as f64
    }
}

/// Streaming mean/min/max accumulator (used by coordinator telemetry).
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl OnlineStats {
    pub fn push(&mut self, v: f64) {
        if self.n == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.n += 1;
        self.sum += v;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn geomean_skips_nonpositive() {
        assert!((geomean(&[0.0, 4.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn online_stats() {
        let mut s = OnlineStats::default();
        for v in [3.0, 1.0, 2.0] {
            s.push(v);
        }
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
    }
}
