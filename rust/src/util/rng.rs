//! Deterministic xorshift64* RNG.
//!
//! The simulator must be bit-reproducible across runs and platforms
//! (trace-gen feeds model training; the eval tables must be stable),
//! so we avoid `rand` and use a tiny, seeded generator.

/// xorshift64* — fast, deterministic, good-enough statistical quality
/// for workload input generation (not cryptographic).
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        // 0 is a fixed point of xorshift; nudge it.
        Self { state: seed.max(1).wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = XorShift64::new(7);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = r.below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn unit_in_half_open_interval() {
        let mut r = XorShift64::new(3);
        for _ in 0..1000 {
            let u = r.unit();
            assert!((0.0..1.0).contains(&u));
        }
    }
}
