//! `repro` — leader binary for the UVM-prefetching reproduction.
//!
//! ```text
//! repro trace-gen  [--out traces] [--benchmarks a --benchmarks b]
//!                  [--limit N] [--scale F] [--max-instructions N]
//! repro simulate   [--benchmark B] [--prefetcher P] [--backend K]
//!                  [--precision T] [--artifacts DIR] [--model M]
//!                  [--scale F]
//!                  [--max-instructions N] [--prediction-us F]
//!                  [--config FILE] [--oversubscribe R] [--eviction P]
//!                  [--telemetry FILE]
//!                    --oversubscribe: resident fraction of the
//!                    workload footprint, in (0, 1]; 1.0 (default) =
//!                    no oversubscription. --eviction: lru | random |
//!                    freq | prefetch-aware | learned. --telemetry:
//!                    write the structured-telemetry document
//!                    (fault-lifecycle spans, rollup series,
//!                    prediction post-mortem — schema telemetry/v1,
//!                    Chrome-trace compatible) to FILE; off by
//!                    default, and metrics are byte-identical either
//!                    way (tests/ab_identity.rs pins that).
//! repro inspect    FILE [--out results]
//!                    render a telemetry/v1 document: prefetch outcome
//!                    table, timeline, cross-checks against the
//!                    embedded metrics snapshot; writes
//!                    BENCH_telemetry.json (schema bench_telemetry/v1)
//!                    and fails on cross-check violations (CI gate).
//! repro train      [--arch native|transformer]
//!                  [--workload B | --benchmarks a --benchmarks b]
//!                  [--out artifacts] [--epochs N] [--batch N]
//!                  [--limit N] [--history-len N] [--classes N]
//!                  [--pcs N] [--page-buckets N] [--hidden N]
//!                  [--embed-pc N] [--embed-page N] [--embed-delta N]
//!                  [--d-model N] [--heads N] [--layers N] [--d-ff N]
//!                  [--lr F] [--optimizer adam|sgd] [--int4]
//!                  [--scale F] [--max-instructions N] [--seed S]
//!                    trains a pure-Rust backend offline and writes
//!                    params + vocab + manifest (arch as selected);
//!                    the report includes params + FLOPs/inference.
//! repro analyze    [--workload B] [--out results] [--max-maps N]
//!                  [+ the train corpus/model flags above]
//!                    trains BOTH archs on the same corpus/seed,
//!                    extracts per-head attention entropy/locality
//!                    profiles over held-out windows, reports the
//!                    transformer-vs-native cost table and per-tensor
//!                    int4 quantization error; writes
//!                    BENCH_compare.json (schema bench_compare/v1).
//! repro eval       <pairs|table10|table11|fig10|fig11|fig12|summary|oversub|all>
//!                  [--backend K] [--precision T] [--artifacts DIR]
//!                  [--out results]
//!                  [--scale F] [--max-instructions N] [--no-pjrt]
//!                  [--benchmarks a,b] [--trace-dir DIR]
//!                  oversub only: [--ratios 1.0,0.75,0.5,0.375,0.25]
//!                  [--evictions lru,random,freq,prefetch-aware,learned]
//!                  [--prefetchers none,tree,uvmsmart,dl]
//!                  ("all" covers the paper artifacts; oversub is its
//!                  own axis and must be requested explicitly)
//! repro golden     <check|update> [--path ci/golden_metrics.json]
//! repro perf       [--smoke] [--out BENCH_sim.json]
//!                  [--check ci/perf_baseline.json] [--update]
//!                    simulator-throughput harness: pinned hot-path
//!                    microbench matrix (fault loop, eviction churn at
//!                    ratio 0.25, TLB shootdown storm) + end-to-end
//!                    representative sweep cells (cells/sec); writes
//!                    BENCH_sim.json (schema bench_sim/v1). --check
//!                    compares against a committed baseline, warn-only
//!                    with 2x tolerance (bootstrap baselines print the
//!                    measured candidates); --update re-pins it.
//!                    --smoke shortens windows for PR CI.
//! repro serve      [--streams N] [--shards K] [--benchmark B]
//!                  [--benchmarks a --benchmarks b] [--backend K]
//!                  [--precision T]
//!                  [--artifacts DIR] [--model M] [--max-faults N]
//!                  [--scale F] [--bypass never|auto|always]
//!                  [--seed S] [--out results] [--metrics-out PREFIX]
//!                    load generator: N tenant fault streams replayed
//!                    concurrently through K router shards + one
//!                    shared batcher; writes BENCH_serve.json.
//!                    --metrics-out: live exporter sidecar — rewrites
//!                    PREFIX.prom (Prometheus text exposition) and
//!                    appends cumulative snapshots to PREFIX.jsonl
//!                    (schema serve_metrics/v1) while the replay runs.
//! repro trace      <ingest FILE... [--name N] | list>
//!                  [--trace-dir traces-ingested]
//!                    ingest: stream-parse accelsim-style kernel
//!                    traces — whitespace `(pc, sm, warp, cta, vaddr
//!                    [, store, compute, kernel, array])` records or
//!                    the GMMU CSV written by trace-gen — normalize
//!                    placement, and cache them under --trace-dir.
//!                    Every cached trace then registers as benchmark
//!                    `trace:<name>` in any subcommand that is given
//!                    the same --trace-dir.
//! repro list       [--trace-dir DIR]
//!                    print the workload registry (all / dense /
//!                    irregular / trace / model name lists) as JSON.
//! repro info       [--artifacts DIR] [--dump-config]
//! ```
//!
//! `--benchmarks` flags accept comma-separated lists and may repeat;
//! workload names come from the registry (`repro list`), including
//! `trace:<name>` entries once a `--trace-dir` is supplied.
//!
//! `--backend K` selects the `dl` policy's predictor: `stride`
//! (pure-Rust frequency vote — the floor), `native` (pure-Rust revised
//! model trained by `repro train`), `transformer` (pure-Rust
//! Transformer reference model trained by
//! `repro train --arch transformer`), or `pjrt` (AOT HLO, needs the
//! `pjrt` cargo feature). Unset, the legacy auto rule applies: pjrt
//! when `--artifacts` is given, stride otherwise. See DESIGN.md §6/§9.
//!
//! `--precision T` selects the inference kernel tier: `exact`
//! (default — the bit-pinned scalar path; golden gate, training and
//! grad checks run here), `fast` (blocked/reassociated f32 GEMM),
//! `int8` / `int4` (integer accumulation straight off the dtype-3
//! quantized store; native backend only). Inference-only: `repro
//! train` and `repro analyze` reject every tier but `exact`.

use anyhow::Result;
use std::path::{Path, PathBuf};
use uvm_prefetch::config::ExperimentConfig;
use uvm_prefetch::eval::report::Table;
use uvm_prefetch::eval::{self, runner::RunOptions};
use uvm_prefetch::predictor::{NativeConfig, Precision};
use uvm_prefetch::runtime::Manifest;
use uvm_prefetch::sim::TraceWriter;
use uvm_prefetch::util::cli::Args;
use uvm_prefetch::util::Json;
use uvm_prefetch::workloads::{trace, WorkloadFamily, WorkloadRegistry};

const USAGE: &str = "repro <trace-gen|simulate|inspect|train|analyze|eval|golden|perf|serve|\
                     trace|list|info> [flags] (see rust/src/main.rs header)";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let cmd = args.positional0(USAGE)?.to_string();
    match cmd.as_str() {
        "trace-gen" => trace_gen(&args),
        "simulate" => simulate(&args),
        "inspect" => inspect_cmd(&args),
        "train" => train(&args),
        "analyze" => analyze(&args),
        "eval" => eval_cmd(&args),
        "golden" => golden(&args),
        "perf" => perf_cmd(&args),
        "serve" => serve(&args),
        "trace" => trace_cmd(&args),
        "list" => list_cmd(&args),
        "info" => info(&args),
        other => anyhow::bail!("unknown command '{other}'\nusage: {USAGE}"),
    }
}

fn opts_from(args: &Args) -> Result<RunOptions> {
    let opts = RunOptions {
        scale: args.f64("scale", 4.0)?,
        max_instructions: args.u64("max-instructions", 2_000_000)?,
        artifacts: args.str("artifacts", ""),
        model: args.str("model", ""),
        seed: args.u64("seed", 0x5eed)?,
        backend: args.str("backend", ""),
        precision: precision_from(args)?,
        trace_dir: args.str("trace-dir", ""),
        benchmarks: benchmarks_from(args),
    };
    // Reject unknown --backend names before any cell runs.
    opts.backend_kind()?;
    Ok(opts)
}

/// Collect `--benchmarks` values: the flag may repeat, and each value
/// may itself be a comma-separated list. Empty = caller's default
/// (usually the full registry).
fn benchmarks_from(args: &Args) -> Vec<String> {
    args.get_all("benchmarks")
        .into_iter()
        .flat_map(|v| v.split(','))
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

/// Parse the `--precision` kernel-tier axis; unknown names fail
/// naming the flag's full domain.
fn precision_from(args: &Args) -> Result<Precision> {
    let name = args.str("precision", "exact");
    Precision::parse(&name).ok_or_else(|| {
        anyhow::anyhow!("--precision '{name}' (expected exact | fast | int8 | int4)")
    })
}

fn trace_gen(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.str("out", "traces"));
    std::fs::create_dir_all(&out)?;
    let limit = args.u64("limit", 400_000)?;
    let scale = args.f64("scale", 1.0)?;
    let mut opts = opts_from(args)?;
    opts.scale = scale;
    opts.max_instructions = args.u64("max-instructions", 60_000_000)?;
    let registry = opts.registry()?;
    let names: Vec<String> = if opts.benchmarks.is_empty() {
        registry.all().into_iter().map(str::to_string).collect()
    } else {
        opts.benchmarks.clone()
    };
    for name in names {
        // `trace:` names are valid here too; ':' is fine in a path.
        let path = out.join(format!("{name}.csv"));
        let writer = TraceWriter::create(&path, limit)?;
        // Trace under the tree prefetcher: the paper collects traces
        // from the GMMU of the existing (tree-based) runtime, so the
        // hit/miss flags reflect that baseline.
        let m = eval::runner::run_benchmark_with(&name, "tree", &opts, |e| e, Some(writer))?;
        println!(
            "trace-gen {name}: accesses={} faults={} → {}",
            m.mem_accesses,
            m.far_faults,
            path.display()
        );
    }
    registry_json(&registry).write_file(&out.join("benchmarks.json"))?;
    Ok(())
}

/// The registry's name lists as JSON — written next to generated
/// traces as `benchmarks.json` and printed by `repro list`, so both
/// always reflect what is actually registered (builtins *and* any
/// ingested `trace:` entries).
fn registry_json(registry: &WorkloadRegistry) -> Json {
    let names = |v: Vec<&str>| Json::arr(v.into_iter().map(Json::str));
    Json::obj(vec![
        ("all", names(registry.all())),
        ("dense", names(registry.family(WorkloadFamily::Dense))),
        ("irregular", names(registry.family(WorkloadFamily::Irregular))),
        ("trace", names(registry.family(WorkloadFamily::Trace))),
        ("model", names(registry.model())),
    ])
}

fn simulate(args: &Args) -> Result<()> {
    let benchmark = args.str("benchmark", "addvectors");
    let prefetcher = args.str("prefetcher", "tree");
    let prediction_us = args.f64("prediction-us", 1.0)?;
    // Resident fraction of the *workload footprint*, not a multiplier
    // on the raw config bytes: 1.0 (default) = no oversubscription;
    // 0.5 = only half the footprint fits. Domain (0, 1]. Left unset,
    // a `--config` file's own oversub_ratio is honoured.
    let oversubscribe: Option<f64> = match args.get("oversubscribe") {
        None => None,
        Some(_) => Some(args.f64("oversubscribe", 1.0)?),
    };
    if let Some(r) = oversubscribe {
        if !(r > 0.0 && r <= 1.0) {
            anyhow::bail!(
                "--oversubscribe must be in (0, 1]: it is the resident fraction of the workload \
                 footprint (1.0 = no oversubscription), got {r}"
            );
        }
    }
    let eviction = args.str("eviction", "");
    let config: Option<ExperimentConfig> = match args.get("config") {
        Some(p) => Some(ExperimentConfig::from_file(Path::new(p))?),
        None => None,
    };
    let telemetry: Option<PathBuf> = args.get("telemetry").map(PathBuf::from);
    let opts = opts_from(args)?;
    let m = eval::runner::run_benchmark_instrumented(
        &benchmark,
        &prefetcher,
        &opts,
        move |mut e| {
            if let Some(b) = config {
                e = b;
            }
            e.runtime.prediction_latency_cycles = e.sim.us_to_cycles(prediction_us);
            if let Some(r) = oversubscribe {
                e.sim.oversub_ratio = r;
            }
            if !eviction.is_empty() {
                e.sim.eviction_policy = eviction;
            }
            e
        },
        None,
        telemetry.as_deref(),
    )?;
    println!("benchmark={benchmark} prefetcher={prefetcher}");
    println!("{}", m.summary());
    if let Some(p) = telemetry {
        println!("telemetry: {} (render with `repro inspect {}`)", p.display(), p.display());
    }
    Ok(())
}

/// `repro inspect FILE` — render a telemetry/v1 document written by
/// `repro simulate --telemetry` and cross-check it against the
/// embedded metrics snapshot (see `telemetry/inspect.rs`). Writes
/// `BENCH_telemetry.json` to `--out` plus a CWD copy, and errors when
/// a cross-check fails — the CI smoke job gates on that.
fn inspect_cmd(args: &Args) -> Result<()> {
    let file = args
        .positional
        .get(1)
        .map(PathBuf::from)
        .ok_or_else(|| anyhow::anyhow!("inspect needs a telemetry file: repro inspect FILE"))?;
    let out = PathBuf::from(args.str("out", "results"));
    std::fs::create_dir_all(&out)?;
    let rendered = uvm_prefetch::telemetry::inspect::inspect_file(&file, &out)?;
    println!("{rendered}");
    Ok(())
}

/// Shared corpus + model flags for `repro train` and `repro analyze`.
fn train_opts_from(
    args: &Args,
    benchmark: String,
    default_out: &str,
) -> Result<uvm_prefetch::eval::train::TrainOptions> {
    use uvm_prefetch::eval::train::{ModelArch, TrainOptions};
    use uvm_prefetch::predictor::nn::OptKind;
    use uvm_prefetch::predictor::TransformerConfig;

    let defaults = TrainOptions::default();
    let arch = {
        let name = args.str("arch", defaults.arch.as_str());
        ModelArch::parse(&name)
            .ok_or_else(|| anyhow::anyhow!("--arch '{name}' (expected native | transformer)"))?
    };
    let optimizer = {
        let name = args.str("optimizer", defaults.native.optimizer.as_str());
        OptKind::parse(&name)
            .ok_or_else(|| anyhow::anyhow!("--optimizer '{name}' (expected adam | sgd)"))?
    };
    let lr = args.f64("lr", defaults.native.lr as f64)? as f32;
    let seed = args.u64("seed", defaults.native.seed)?;
    let d_model = args.usize("d-model", defaults.transformer.d_model)?;
    let n_heads = args.usize("heads", defaults.transformer.n_heads)?;
    let n_layers = args.usize("layers", defaults.transformer.n_layers)?;
    let d_ff = args.usize("d-ff", defaults.transformer.d_ff)?;
    // Validate here so bad flags fail with a CLI error, not the model
    // constructor's assert.
    anyhow::ensure!(
        d_model > 0 && n_heads > 0 && n_layers > 0 && d_ff > 0,
        "--d-model/--heads/--layers/--d-ff must all be > 0"
    );
    anyhow::ensure!(
        d_model % n_heads == 0,
        "--d-model {d_model} must be divisible by --heads {n_heads}"
    );
    Ok(TrainOptions {
        benchmark,
        out: PathBuf::from(args.str("out", default_out)),
        epochs: args.usize("epochs", defaults.epochs)?,
        batch: args.usize("batch", defaults.batch)?,
        max_windows: args.usize("limit", defaults.max_windows)?,
        history_len: args.usize("history-len", defaults.history_len)?,
        classes: args.usize("classes", defaults.classes)?,
        pcs: args.usize("pcs", defaults.pcs)?,
        page_buckets: args.u64("page-buckets", defaults.page_buckets as u64)? as u32,
        int4: args.bool("int4"),
        arch,
        native: NativeConfig {
            hidden: args.usize("hidden", defaults.native.hidden)?,
            d_pc: args.usize("embed-pc", defaults.native.d_pc)?,
            d_page: args.usize("embed-page", defaults.native.d_page)?,
            d_delta: args.usize("embed-delta", defaults.native.d_delta)?,
            lr,
            optimizer,
            seed,
        },
        transformer: TransformerConfig { d_model, n_heads, n_layers, d_ff, lr, optimizer, seed },
        run: {
            let run = opts_from(args)?;
            // Training and grad paths are pinned to the exact kernels;
            // the faster tiers are inference-only.
            anyhow::ensure!(
                run.precision.is_exact(),
                "--precision {} is not allowed on `repro train` / `repro analyze` — training is \
                 pinned to the exact kernels; drop the flag or pass --precision exact",
                run.precision.as_str()
            );
            run
        },
    })
}

/// `repro train` — offline training of a pure-Rust backend (one model
/// per requested workload, all merged into one artifacts manifest).
fn train(args: &Args) -> Result<()> {
    use uvm_prefetch::eval::train::train_model;

    let names: Vec<String> = {
        let given = benchmarks_from(args);
        if given.is_empty() {
            vec![args.str("workload", "streamtriad")]
        } else {
            given
        }
    };
    for name in names {
        let t = train_opts_from(args, name, "artifacts")?;
        let r = train_model(&t)?;
        println!(
            "train[{}/{}]: {} train / {} eval windows, {} classes — {} params, {} FLOPs/inf — \
             loss {:.4} → {:.4}, top-1 {} {:.2}% vs stride {:.2}% — saved {}",
            r.benchmark,
            r.arch,
            r.n_train,
            r.n_eval,
            r.n_classes,
            r.n_params,
            r.flops_per_inference,
            r.first_epoch_loss,
            r.last_epoch_loss,
            r.arch,
            r.model_top1 * 100.0,
            r.stride_top1 * 100.0,
            r.params_path.display()
        );
    }
    Ok(())
}

/// `repro analyze` — the attention-interpretability subsystem: train
/// the Transformer reference model AND the native model on the same
/// corpus/seed, profile the attention heads over held-out windows,
/// and write the comparison record (`BENCH_compare.json`). See
/// `eval/analyze.rs` and DESIGN.md §9.
fn analyze(args: &Args) -> Result<()> {
    use uvm_prefetch::eval::analyze::{analyze as run_analyze, AnalyzeOptions};

    let defaults = AnalyzeOptions::default();
    let out = PathBuf::from(args.str("out", "results"));
    let mut train = train_opts_from(args, args.str("workload", "streamtriad"), "results")?;
    train.out = out.clone();
    let opts = AnalyzeOptions {
        train,
        out: out.clone(),
        max_maps: args.usize("max-maps", defaults.max_maps)?,
    };
    let r = run_analyze(&opts)?;
    println!("{}", r.to_table().to_markdown());
    println!("{}", r.heads_table().to_markdown());
    println!("{}", r.postmortem_table().to_markdown());
    println!(
        "analyze[{}]: transformer top-1 {:.2}% vs native {:.2}% (stride floor {:.2}%) — cost \
         ratio {:.1}× params, {:.1}× FLOPs — {}",
        r.benchmark,
        r.transformer.top1 * 100.0,
        r.native.top1 * 100.0,
        r.stride_top1 * 100.0,
        r.params_ratio,
        r.flops_ratio,
        out.join("BENCH_compare.json").display()
    );
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "eval needs a target: pairs|table10|table11|fig10|fig11|fig12|summary|oversub|all"
            )
        })?;
    let out = PathBuf::from(args.str("out", "results"));
    std::fs::create_dir_all(&out)?;
    let mut opts = opts_from(args)?;
    if opts.artifacts.is_empty() && !args.bool("no-pjrt") {
        opts.artifacts = "artifacts".to_string();
    }
    if args.bool("no-pjrt") {
        opts.artifacts = String::new();
    }
    let run = |name: &str| -> Result<Table> {
        match name {
            "pairs" => eval::pairs(&opts, &out),
            "table10" => eval::table10(&opts, &out),
            "table11" => eval::table11(&opts, &out),
            "fig10" => eval::fig10(&opts, &out),
            "fig11" => eval::fig11(&opts, &out),
            "fig12" => eval::fig12(&opts, &out),
            "summary" => eval::summary(&opts, &out),
            "oversub" => eval::oversub(&opts, &out, &oversub_grid_from(args)?),
            other => anyhow::bail!("unknown eval target '{other}'"),
        }
    };
    let targets: Vec<&str> = if which == "all" {
        vec!["table10", "table11", "fig11", "fig12", "fig10", "summary"]
    } else {
        vec![which]
    };
    for t in targets {
        let table = run(t)?;
        println!("{}", table.to_markdown());
    }
    Ok(())
}

/// Parse the `repro eval oversub` axes; every axis defaults to the
/// full grid.
fn oversub_grid_from(args: &Args) -> Result<eval::OversubGrid> {
    use uvm_prefetch::sim::eviction;
    let mut grid = eval::OversubGrid::default();
    if let Some(list) = args.get("ratios") {
        grid.ratios = list
            .split(',')
            .map(|s| s.trim().parse::<f64>().map_err(|e| anyhow::anyhow!("--ratios '{s}': {e}")))
            .collect::<Result<Vec<f64>>>()?;
        for &r in &grid.ratios {
            if !(r > 0.0 && r <= 1.0) {
                anyhow::bail!("--ratios entries must be in (0, 1], got {r}");
            }
        }
    }
    if let Some(list) = args.get("evictions") {
        grid.evictions = list.split(',').map(|s| s.trim().to_string()).collect();
        for ev in &grid.evictions {
            eviction::build(ev, 0)?; // name validation
        }
    }
    if let Some(list) = args.get("prefetchers") {
        grid.prefetchers = list.split(',').map(|s| s.trim().to_string()).collect();
    }
    let benches = benchmarks_from(args);
    if !benches.is_empty() {
        grid.benchmarks = benches;
    }
    Ok(grid)
}

/// CI golden-metrics gate: `repro golden <check|update>` (see
/// `eval::golden` and ci/golden_metrics.json).
fn golden(args: &Args) -> Result<()> {
    let mode = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("golden needs a mode: check|update"))?;
    let path = PathBuf::from(args.str("path", "ci/golden_metrics.json"));
    match mode {
        "check" => eval::golden::check(&path),
        "update" => eval::golden::update(&path),
        other => anyhow::bail!("unknown golden mode '{other}' (expected check|update)"),
    }
}

fn perf_cmd(args: &Args) -> Result<()> {
    let opts = eval::perf::PerfOptions {
        smoke: args.bool("smoke"),
        out: PathBuf::from(args.str("out", "BENCH_sim.json")),
        check: args.get("check").map(PathBuf::from),
        update: args.bool("update"),
    };
    if opts.update && opts.check.is_none() {
        anyhow::bail!("perf --update needs --check <baseline.json> to know what to pin");
    }
    eval::perf::perf(&opts)
}

fn info(args: &Args) -> Result<()> {
    if args.bool("dump-config") {
        println!("{}", ExperimentConfig::default().to_json().to_string());
        return Ok(());
    }
    let artifacts = args.str("artifacts", "artifacts");
    let manifest = Manifest::load(Path::new(&artifacts))?;
    println!("artifacts v{} — {} models:", manifest.version, manifest.models.len());
    for (name, e) in &manifest.models {
        println!(
            "  {name:<14} arch={:<12} batch={} seq={} classes={} params={}",
            e.arch, e.batch, e.seq_len, e.n_classes, e.n_params
        );
    }
    Ok(())
}

/// `repro serve` — the serving load generator: replay N interleaved
/// tenant fault streams through the sharded multi-tenant coordinator
/// and record serving telemetry as `BENCH_serve.json` (see
/// `eval/serve.rs`).
fn serve(args: &Args) -> Result<()> {
    use uvm_prefetch::config::BypassMode;
    use uvm_prefetch::eval::serve as srv;

    let defaults = srv::ServeOptions::default();
    let benchmarks: Vec<String> = {
        let given = benchmarks_from(args);
        if given.is_empty() {
            vec![args.str("benchmark", "addvectors")]
        } else {
            given
        }
    };
    let bypass = {
        let name = args.str("bypass", defaults.bypass.as_str());
        BypassMode::parse(&name)
            .ok_or_else(|| anyhow::anyhow!("--bypass '{name}' (expected never | auto | always)"))?
    };
    let opts = srv::ServeOptions {
        benchmarks,
        streams: args.usize("streams", defaults.streams)?,
        shards: args.usize("shards", defaults.shards)?,
        max_faults: args.usize("max-faults", defaults.max_faults)?,
        bypass,
        metrics_out: args.get("metrics-out").map(PathBuf::from),
        run: RunOptions {
            scale: args.f64("scale", 0.1)?,
            artifacts: args.str("artifacts", ""),
            model: args.str("model", ""),
            seed: args.u64("seed", 0x5eed)?,
            backend: args.str("backend", ""),
            max_instructions: args.u64("max-instructions", 2_000_000)?,
            precision: precision_from(args)?,
            trace_dir: args.str("trace-dir", ""),
            benchmarks: Vec::new(),
        },
    };
    opts.run.backend_kind()?; // reject unknown --backend before any work

    let r = srv::run(&opts)?;
    let out = PathBuf::from(args.str("out", "results"));
    srv::write_bench_serve(&r, &out.join("BENCH_serve.json"))?;
    // CWD copy, like BENCH_eval.json — the per-PR serving perf record.
    if let Err(e) = srv::write_bench_serve(&r, Path::new("BENCH_serve.json")) {
        eprintln!("serve: could not write ./BENCH_serve.json: {e}");
    }
    if r.dropped_commands > 0 {
        eprintln!(
            "serve: WARNING — {} command(s) dropped (command channel closed mid-run); every \
             reported count and latency is a LOWER BOUND on the work the pipeline produced",
            r.dropped_commands
        );
    }
    if let Some(prefix) = &opts.metrics_out {
        println!(
            "serve: metrics exported to {0}.prom (Prometheus) and {0}.jsonl (snapshots)",
            prefix.display()
        );
    }

    println!(
        "serve[{}/{}]: {} streams × {} shard(s) — {} accesses ({} misses) → {} commands in \
         {:.1} ms ({:.1} faults/ms, {:.1} accesses/ms)",
        r.backend,
        r.precision,
        r.streams,
        r.shards,
        r.accesses,
        r.misses,
        r.commands,
        r.wall_ms,
        r.faults_per_ms,
        r.accesses_per_ms,
    );
    println!(
        "serve: {} batches, mean batch {:.2}, batch p95 {} — e2e latency µs p50={} p95={} \
         p99={} (n={}), dropped={}",
        r.batches,
        r.mean_batch,
        r.batch_sizes.p95,
        r.latency_us.p50,
        r.latency_us.p95,
        r.latency_us.p99,
        r.latency_us.n,
        r.dropped_commands,
    );
    for t in &r.tenants {
        println!(
            "serve:   tenant {} [{}]: {} accesses ({} misses) → {} commands ({} migrate, \
             {} predicted), p99 {} µs",
            t.tenant,
            t.benchmark,
            t.accesses,
            t.misses,
            t.commands,
            t.migrates,
            t.predicted,
            t.latency_us.p99,
        );
    }
    Ok(())
}

/// `repro trace <ingest|list>` — the trace-ingestion frontend: parse
/// accelsim-style kernel traces, normalize their (sm, warp) placement
/// against the simulated GPU, and cache them (plus a manifest) under
/// `--trace-dir`. Cached traces register as `trace:<name>` benchmarks
/// in every subcommand given the same `--trace-dir`. See DESIGN.md
/// §10 for the record grammar.
fn trace_cmd(args: &Args) -> Result<()> {
    let mode = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("trace needs a mode: ingest|list"))?;
    let dir = PathBuf::from(args.str("trace-dir", "traces-ingested"));
    match mode {
        "ingest" => {
            let files: Vec<PathBuf> = args.positional[2..].iter().map(PathBuf::from).collect();
            anyhow::ensure!(
                !files.is_empty(),
                "trace ingest needs at least one trace file: repro trace ingest FILE... \
                 [--trace-dir DIR] [--name N]"
            );
            let name = args.get("name");
            anyhow::ensure!(
                name.is_none() || files.len() == 1,
                "--name applies to a single file, got {}",
                files.len()
            );
            // Placement is normalized against the same default GPU
            // shape every simulation uses (config::SimConfig).
            let cfg = ExperimentConfig::default().sim;
            for f in &files {
                let r = trace::ingest(f, &dir, name, &cfg)?;
                println!(
                    "trace ingest {}: {} records → {} warp streams, {} ops, {} pages — cached \
                     {} (run with --benchmarks trace:{} --trace-dir {})",
                    f.display(),
                    r.records,
                    r.tasks,
                    r.ops,
                    r.footprint_pages,
                    r.cached.display(),
                    r.name,
                    dir.display(),
                );
            }
            Ok(())
        }
        "list" => {
            let entries = trace::load_manifest(&dir)?;
            if entries.is_empty() {
                println!("no ingested traces under {}", dir.display());
            }
            for e in &entries {
                println!(
                    "trace:{} — {} records, {} warp streams, {} pages ({})",
                    e.name,
                    e.records,
                    e.tasks,
                    e.footprint_pages,
                    dir.join(&e.file).display(),
                );
            }
            Ok(())
        }
        other => anyhow::bail!("unknown trace mode '{other}' (expected ingest|list)"),
    }
}

/// `repro list` — print the workload registry as JSON (same shape as
/// the `benchmarks.json` trace-gen writes). Pass `--trace-dir` to
/// include ingested `trace:` entries.
fn list_cmd(args: &Args) -> Result<()> {
    let dir = args.str("trace-dir", "");
    let registry = if dir.is_empty() {
        WorkloadRegistry::builtin()
    } else {
        WorkloadRegistry::with_trace_dir(Path::new(&dir))?
    };
    println!("{}", registry_json(&registry).to_string());
    Ok(())
}
