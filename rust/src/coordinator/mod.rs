//! Async serving front (vLLM-router-style): a tokio service that
//! consumes a stream of far-fault events, routes them through the
//! clustering/history/batching pipeline, runs PJRT inference on a
//! blocking worker, and emits prefetch commands plus live telemetry.
//!
//! The simulator uses the synchronous path in [`crate::prefetch::dl`]
//! directly (deterministic simulated time); this module is the
//! *deployment* shape — `repro serve` replays a trace file through it
//! and the `e2e_prefetch` example drives it end-to-end.

pub mod router;
pub mod service;
pub mod stats;

pub use router::{FaultEvent, PrefetchCommand, Router};
pub use service::{CoordinatorHandle, CoordinatorService};
pub use stats::CoordinatorStats;
