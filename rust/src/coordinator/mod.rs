//! Sharded multi-tenant serving front (vLLM-router-style): a stream
//! of tenant-tagged far-fault events is hashed by (tenant, cluster)
//! onto N router shards — each shard owning its own history tables —
//! which feed one shared size/deadline batcher so windows from
//! different tenants and shards coalesce into real inference batches;
//! prefetch commands come back tenant-tagged, with lock-free
//! end-to-end latency telemetry per tenant and aggregate.
//!
//! The simulator uses the synchronous path in [`crate::prefetch::dl`]
//! directly (deterministic simulated time); this module is the
//! *deployment* shape — `repro serve --streams N --shards K` replays
//! interleaved tenant fault streams through it (see
//! [`crate::eval::serve`]).

pub mod router;
pub mod service;
pub mod stats;

pub use router::{shard_of, tenant_cluster_key, FaultEvent, PrefetchCommand, Router};
pub use service::{
    CoordinatorHandle, CoordinatorService, FaultSender, ShutdownReport, SpawnOptions,
};
pub use stats::{CommandKind, CoordinatorStats, TenantStats};
