//! The serving pipeline: fault events in, prefetch commands out —
//! sharded and multi-tenant.
//!
//! Topology (one OS thread per router shard plus one batch/infer
//! thread, bounded sync channels — backpressure propagates to the
//! fault producers):
//!
//! ```text
//!                ┌─► router shard 0 ─┐
//! FaultSender ───┼─► router shard 1 ─┼─► shared batch+infer thread
//!  (hash of      │        …          │   (size/deadline batching,
//!   tenant+      └─► router shard K ─┘    one batched forward per
//!   cluster key)        │                 flush, windows from all
//!                       │                 shards/tenants coalesce)
//!                       └── block prefetches ──► commands ◄── predictions
//! ```
//!
//! Every fault is timestamped on entry ([`FaultSender::send`]); the
//! instant a command is handed to the command channel the end-to-end
//! latency is recorded per tenant and aggregate
//! ([`CoordinatorStats`]). Per-tenant command *content* is
//! deterministic for a given input stream and independent of the shard
//! count: a cluster (tenant + SM + warp) lives wholly on one shard, and
//! the predictor backends answer each window statelessly, so only the
//! cross-tenant interleaving varies with scheduling.
//!
//! The simulator uses the synchronous path in [`crate::prefetch::dl`]
//! directly (deterministic simulated time); this service is the
//! *deployment* shape — `repro serve --streams N --shards K` replays
//! interleaved tenant fault streams through it and
//! [`crate::eval::serve`] reports the telemetry as `BENCH_serve.json`.

use crate::config::RuntimeConfig;
use crate::coordinator::router::{shard_of, FaultEvent, PrefetchCommand, Router};
use crate::coordinator::stats::{CommandKind, CoordinatorStats};
use crate::predictor::{DeltaVocab, Prediction, PredictorBackend, Window};
use crate::types::{PageNum, TenantId};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SendError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Deployment knobs for [`CoordinatorService::spawn`] (channel bounds
/// are per instance so tests can shrink them to force backpressure).
#[derive(Debug, Clone)]
pub struct SpawnOptions {
    /// Number of router shards (≥ 1).
    pub shards: usize,
    /// Telemetry slots for per-tenant stats (ids beyond this clamp to
    /// the last slot).
    pub max_tenants: usize,
    /// Per-shard fault queue bound (producers block when full).
    pub fault_queue: usize,
    /// Shared inference queue bound.
    pub infer_queue: usize,
    /// Command queue bound.
    pub command_queue: usize,
    /// Flush a partial inference batch once its oldest window waited
    /// this long.
    pub flush_after: Duration,
}

impl Default for SpawnOptions {
    fn default() -> Self {
        Self {
            shards: 1,
            max_tenants: 1,
            fault_queue: 1024,
            infer_queue: 1024,
            command_queue: 65536,
            flush_after: Duration::from_micros(200),
        }
    }
}

/// A fault event plus its coordinator-entry timestamp (the zero point
/// of the end-to-end latency measurement).
struct TimedFault {
    ev: FaultEvent,
    enqueued: Instant,
}

/// Cloneable fault-ingress handle: hashes each event's (tenant,
/// cluster) to its owning shard and sends on that shard's bounded
/// channel (blocking when full — backpressure reaches the producer).
/// Load generators hold one clone per producer thread.
#[derive(Clone)]
pub struct FaultSender {
    shards: Vec<SyncSender<TimedFault>>,
}

impl FaultSender {
    /// Deliver one event to its shard. Errors only when the service
    /// has shut down (the event is handed back).
    pub fn send(&self, ev: FaultEvent) -> Result<(), SendError<FaultEvent>> {
        let shard = shard_of(&ev, self.shards.len());
        self.shards[shard]
            .send(TimedFault { ev, enqueued: Instant::now() })
            .map_err(|e| SendError(e.0.ev))
    }
}

/// What [`CoordinatorHandle::shutdown`] returns: the drained commands
/// plus the backpressure/drop counters that used to vanish into
/// `let _ = send(…)` discards.
pub struct ShutdownReport {
    /// Commands still in flight at shutdown, drained in channel order.
    pub commands: Vec<PrefetchCommand>,
    /// Commands that were produced but could not be delivered
    /// (receiver gone / channel closed). Every command of the work in
    /// flight when the channel died is counted; the pipeline then
    /// stops routing, so queued *events* that never became commands
    /// are not — nonzero means the consumer lost at least this much
    /// work silently.
    pub dropped_commands: u64,
    /// Full telemetry (latency histograms, per-tenant counters).
    pub stats: Arc<CoordinatorStats>,
}

/// Handle returned by [`CoordinatorService::spawn`].
pub struct CoordinatorHandle {
    sender: FaultSender,
    pub commands_rx: Receiver<PrefetchCommand>,
    pub stats: Arc<CoordinatorStats>,
    tasks: Vec<JoinHandle<()>>,
}

impl CoordinatorHandle {
    /// A cloneable ingress handle (one per producer thread).
    pub fn sender(&self) -> FaultSender {
        self.sender.clone()
    }

    /// Send one event from the owning thread (see [`FaultSender`]).
    pub fn send(&self, ev: FaultEvent) -> Result<(), SendError<FaultEvent>> {
        self.sender.send(ev)
    }

    /// Drop the command receiver (tests: force subsequent sends to
    /// fail so the drop accounting is observable).
    pub fn close_commands(&mut self) {
        let (_tx, rx) = std::sync::mpsc::sync_channel(1);
        self.commands_rx = rx;
    }

    /// Close the input, drain remaining commands, and join the
    /// pipeline threads. Producers holding [`FaultSender`] clones keep
    /// the input open until they drop them; the drain loop keeps the
    /// command channel moving meanwhile, so shutdown cannot deadlock
    /// against a blocked producer.
    pub fn shutdown(self) -> ShutdownReport {
        let CoordinatorHandle { sender, commands_rx, stats, tasks } = self;
        drop(sender);
        let mut commands = Vec::new();
        while let Ok(c) = commands_rx.recv() {
            commands.push(c);
        }
        for t in tasks {
            let _ = t.join();
        }
        let dropped = stats.dropped_commands.load(std::sync::atomic::Ordering::Relaxed);
        ShutdownReport { commands, dropped_commands: dropped, stats }
    }
}

/// One inference request flowing a router shard → infer.
struct InferReq {
    window: Window,
    anchor: PageNum,
    tenant: TenantId,
    enqueued: Instant,
}

fn us_since(t: Instant) -> u64 {
    t.elapsed().as_micros().min(u64::MAX as u128) as u64
}

pub struct CoordinatorService;

impl CoordinatorService {
    /// Spawn the sharded pipeline: `sopts.shards` router shards (each
    /// owning its own [`Router`] and therefore its own history tables)
    /// feeding one shared batch+infer thread.
    pub fn spawn(
        vocab: DeltaVocab,
        mut backend: Box<dyn PredictorBackend>,
        rcfg: &RuntimeConfig,
        sopts: &SpawnOptions,
    ) -> CoordinatorHandle {
        let shards = sopts.shards.max(1);
        let stats = Arc::new(CoordinatorStats::with_tenants(sopts.max_tenants.max(1)));
        let (infer_tx, infer_rx) = std::sync::mpsc::sync_channel::<InferReq>(sopts.infer_queue);
        let (cmd_tx, commands_rx) =
            std::sync::mpsc::sync_channel::<PrefetchCommand>(sopts.command_queue);
        let batch_size = rcfg.batch_size.max(1);
        let flush_after = sopts.flush_after;

        let mut senders = Vec::with_capacity(shards);
        let mut tasks = Vec::with_capacity(shards + 1);

        // Router shards.
        for shard in 0..shards {
            let (tx, rx) = std::sync::mpsc::sync_channel::<TimedFault>(sopts.fault_queue);
            senders.push(tx);
            let mut router = Router::new(vocab.clone(), rcfg);
            let st = stats.clone();
            let cmd = cmd_tx.clone();
            let inf = infer_tx.clone();
            let task = std::thread::Builder::new()
                .name(format!("uvm-router-{shard}"))
                .spawn(move || {
                    while let Ok(TimedFault { ev, enqueued }) = rx.recv() {
                        CoordinatorStats::inc(&st.faults, 1);
                        if ev.miss {
                            // Score the tenant's recent predictions
                            // against the realized fault stream — the
                            // accuracy-over-time series in the metrics
                            // exporter (DESIGN.md §13).
                            st.tenant(ev.tenant).note_fault_page(ev.page);
                        }
                        let out = router.route(&ev);
                        CoordinatorStats::inc(&st.block_prefetches, out.block.len() as u64);
                        // A dead command channel ends the shard, but
                        // every command this event produced is counted
                        // as dropped first — the counter must not
                        // understate the loss for the work in hand.
                        let mut dead = false;
                        // Hits only feed the history — no migration command.
                        if !out.block.is_empty() {
                            let c =
                                PrefetchCommand::Migrate { tenant: ev.tenant, pages: out.block };
                            if cmd.send(c).is_ok() {
                                st.record_command(
                                    ev.tenant,
                                    CommandKind::Migrate,
                                    us_since(enqueued),
                                );
                            } else {
                                CoordinatorStats::inc(&st.dropped_commands, 1);
                                dead = true;
                            }
                        }
                        // Memory-management verbs ride the same command
                        // channel as migrations: a lazy Discard for the
                        // block a streaming cluster just left behind, a
                        // one-shot ReadMostly Advise for ping-pong pages.
                        if let Some(pages) = out.discard {
                            let c =
                                PrefetchCommand::Discard { tenant: ev.tenant, pages, lazy: true };
                            if !dead && cmd.send(c).is_ok() {
                                st.record_command(
                                    ev.tenant,
                                    CommandKind::Discard,
                                    us_since(enqueued),
                                );
                            } else {
                                CoordinatorStats::inc(&st.dropped_commands, 1);
                                dead = true;
                            }
                        }
                        if let Some((pages, hint)) = out.advise {
                            let c = PrefetchCommand::Advise { tenant: ev.tenant, pages, hint };
                            if !dead && cmd.send(c).is_ok() {
                                st.record_command(
                                    ev.tenant,
                                    CommandKind::Advise,
                                    us_since(enqueued),
                                );
                            } else {
                                CoordinatorStats::inc(&st.dropped_commands, 1);
                                dead = true;
                            }
                        }
                        if let Some(page) = out.bypass_page {
                            CoordinatorStats::inc(&st.bypasses, 1);
                            let c = PrefetchCommand::Predicted { tenant: ev.tenant, page };
                            if !dead && cmd.send(c).is_ok() {
                                st.tenant(ev.tenant).note_predicted_page(page);
                                st.record_command(
                                    ev.tenant,
                                    CommandKind::Predicted,
                                    us_since(enqueued),
                                );
                            } else {
                                CoordinatorStats::inc(&st.dropped_commands, 1);
                                dead = true;
                            }
                        }
                        if dead {
                            break;
                        }
                        if let Some((_key, window)) = out.window {
                            let req = InferReq {
                                window,
                                anchor: ev.page,
                                tenant: ev.tenant,
                                enqueued,
                            };
                            if inf.send(req).is_err() {
                                break;
                            }
                        }
                    }
                })
                .expect("spawn router shard thread");
            tasks.push(task);
        }
        // Only the shard clones keep the infer channel open; likewise
        // the command channel is held by the shards + infer thread.
        drop(infer_tx);

        // Shared batch + infer thread: windows from every shard and
        // tenant coalesce into one size/deadline batch, answered by a
        // single batched forward.
        let st = stats.clone();
        let vocab_infer = vocab;
        let infer_task = std::thread::Builder::new()
            .name("uvm-infer".into())
            .spawn(move || {
                let mut pending: Vec<InferReq> = Vec::with_capacity(batch_size);
                while let Ok(first) = infer_rx.recv() {
                    pending.push(first);
                    let deadline = Instant::now() + flush_after;
                    while pending.len() < batch_size {
                        let left = deadline.saturating_duration_since(Instant::now());
                        match infer_rx.recv_timeout(left) {
                            Ok(r) => pending.push(r),
                            Err(RecvTimeoutError::Timeout) => break,
                            // `pending` holds at least `first`; flush
                            // it, then the outer recv() observes the
                            // closed channel and ends the loop.
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                    let batch: Vec<InferReq> = pending.drain(..).collect();
                    let windows: Vec<Window> = batch.iter().map(|r| r.window.clone()).collect();
                    let t0 = Instant::now();
                    let classes = backend.predict(&windows);
                    st.record_batch(us_since(t0), batch.len());
                    CoordinatorStats::inc(&st.predictions, classes.len() as u64);
                    // A dead command channel ends the thread — after
                    // every command of this batch has been counted as
                    // dropped (the counter must cover the whole batch,
                    // not just the first failure).
                    let mut dead = false;
                    for (class, req) in classes.into_iter().zip(batch) {
                        match vocab_infer.decode(class) {
                            Prediction::Delta(d) => {
                                let target = req.anchor as i64 + d;
                                if target >= 0 && d != 0 {
                                    let c = PrefetchCommand::Predicted {
                                        tenant: req.tenant,
                                        page: target as PageNum,
                                    };
                                    if !dead && cmd_tx.send(c).is_ok() {
                                        st.tenant(req.tenant)
                                            .note_predicted_page(target as PageNum);
                                        st.record_command(
                                            req.tenant,
                                            CommandKind::Predicted,
                                            us_since(req.enqueued),
                                        );
                                    } else {
                                        CoordinatorStats::inc(&st.dropped_commands, 1);
                                        dead = true;
                                    }
                                }
                            }
                            Prediction::Oov => CoordinatorStats::inc(&st.oov, 1),
                        }
                    }
                    if dead {
                        return;
                    }
                }
            })
            .expect("spawn infer thread");
        tasks.push(infer_task);

        CoordinatorHandle { sender: FaultSender { shards: senders }, commands_rx, stats, tasks }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BypassMode;
    use crate::predictor::{ConstantBackend, DeltaVocab};
    use crate::types::AccessOrigin;

    fn event(page: u64, at: u64) -> FaultEvent {
        FaultEvent {
            at,
            pc: 0x44,
            page,
            origin: AccessOrigin { sm: 0, warp: 0, cta: 0, tpc: 0, kernel_id: 0 },
            miss: true,
            tenant: 0,
        }
    }

    fn migrates(cmds: &[PrefetchCommand]) -> usize {
        cmds.iter().filter(|c| matches!(c, PrefetchCommand::Migrate { .. })).count()
    }

    #[test]
    fn end_to_end_pipeline_with_constant_backend() {
        let vocab = DeltaVocab::synthetic(vec![5, 9], 2);
        let rcfg = RuntimeConfig {
            history_len: 2,
            batch_size: 2,
            bypass: BypassMode::Never,
            ..Default::default()
        };
        // Always class 1 → delta 9.
        let backend = Box::new(ConstantBackend { class: 1, n_classes: vocab.n_classes() });
        let handle =
            CoordinatorService::spawn(vocab, backend, &rcfg, &SpawnOptions::default());

        for (i, page) in [100u64, 101, 102, 103].iter().enumerate() {
            handle.send(event(*page, i as u64)).unwrap();
        }
        let report = handle.shutdown();
        let cmds = report.commands;

        assert_eq!(migrates(&cmds), 4, "one block migration per fault");
        assert_eq!(report.dropped_commands, 0);
        let mut predicted: Vec<u64> = cmds
            .iter()
            .filter_map(|c| match c {
                PrefetchCommand::Predicted { page, .. } => Some(*page),
                _ => None,
            })
            .collect();
        predicted.sort();
        // Windows full from fault #3 onward (history_len=2): anchors
        // 102 and 103 each get +9.
        assert_eq!(predicted, vec![111, 112]);
        // Latency was recorded for every delivered command.
        assert_eq!(report.stats.latency_summary().n, cmds.len() as u64);
    }

    #[test]
    fn oov_predictions_are_counted_not_emitted() {
        let vocab = DeltaVocab::synthetic(vec![5], 2);
        let rcfg = RuntimeConfig {
            history_len: 2,
            batch_size: 1,
            bypass: BypassMode::Never,
            ..Default::default()
        };
        let n_classes = vocab.n_classes();
        let backend = Box::new(ConstantBackend { class: 1, n_classes }); // OOV
        let handle =
            CoordinatorService::spawn(vocab, backend, &rcfg, &SpawnOptions::default());
        for (i, page) in [1u64, 2, 3, 4].iter().enumerate() {
            handle.send(event(*page, i as u64)).unwrap();
        }
        let stats = handle.stats.clone();
        let cmds = handle.shutdown().commands;
        assert!(cmds.iter().all(|c| matches!(c, PrefetchCommand::Migrate { .. })));
        assert!(stats.oov.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    }

    #[test]
    fn bypass_path_emits_without_backend() {
        let vocab = DeltaVocab::synthetic(vec![1], 2);
        let rcfg = RuntimeConfig {
            history_len: 2,
            batch_size: 4,
            bypass: BypassMode::Always,
            ..Default::default()
        };
        let backend = Box::new(ConstantBackend { class: 0, n_classes: 2 });
        let handle =
            CoordinatorService::spawn(vocab, backend, &rcfg, &SpawnOptions::default());
        for (i, page) in [10u64, 11, 12, 13].iter().enumerate() {
            handle.send(event(*page, i as u64)).unwrap();
        }
        let stats = handle.stats.clone();
        let cmds = handle.shutdown().commands;
        let predicted = cmds
            .iter()
            .filter(|c| matches!(c, PrefetchCommand::Predicted { .. }))
            .count();
        assert!(predicted >= 1, "bypass produced predictions");
        assert!(stats.bypasses.load(std::sync::atomic::Ordering::Relaxed) >= 1);
        assert_eq!(
            stats.predictions.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "model never invoked under Always bypass"
        );
    }

    #[test]
    fn sharded_spawn_preserves_per_fault_migrations() {
        let vocab = DeltaVocab::synthetic(vec![1, 2], 3);
        let rcfg = RuntimeConfig {
            history_len: 3,
            batch_size: 4,
            bypass: BypassMode::Never,
            ..Default::default()
        };
        let n_classes = vocab.n_classes();
        let backend = Box::new(ConstantBackend { class: 0, n_classes });
        let sopts = SpawnOptions { shards: 4, max_tenants: 2, ..Default::default() };
        let handle = CoordinatorService::spawn(vocab, backend, &rcfg, &sopts);
        // Two tenants × two warps ⇒ four clusters spread over shards.
        let mut sent = 0usize;
        for i in 0..40u64 {
            let mut ev = event(100 + i, i);
            ev.origin.warp = (i % 2) as u16;
            ev.tenant = (i % 4 > 1) as u32;
            handle.send(ev).unwrap();
            sent += 1;
        }
        let report = handle.shutdown();
        assert_eq!(migrates(&report.commands), sent, "one Migrate per miss across shards");
        assert_eq!(report.dropped_commands, 0);
        // Both tenants got commands, and the tags partition them.
        let t0 = report.commands.iter().filter(|c| c.tenant() == 0).count();
        let t1 = report.commands.iter().filter(|c| c.tenant() == 1).count();
        assert!(t0 > 0 && t1 > 0);
        assert_eq!(t0 + t1, report.commands.len());
    }

    #[test]
    fn dropped_commands_are_counted_when_receiver_goes_away() {
        let vocab = DeltaVocab::synthetic(vec![1], 2);
        let rcfg = RuntimeConfig {
            history_len: 2,
            batch_size: 1,
            bypass: BypassMode::Never,
            ..Default::default()
        };
        let backend = Box::new(ConstantBackend { class: 0, n_classes: 2 });
        let mut handle =
            CoordinatorService::spawn(vocab, backend, &rcfg, &SpawnOptions::default());
        handle.close_commands();
        // Sends may start failing once the shard notices the closed
        // command channel and exits — ignore those errors.
        for i in 0..50u64 {
            let _ = handle.send(event(i, i));
        }
        let report = handle.shutdown();
        assert!(report.dropped_commands >= 1, "drop went unnoticed");
        assert!(report.commands.is_empty(), "receiver was replaced before draining");
    }
}
