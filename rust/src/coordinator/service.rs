//! The serving pipeline: fault events in, prefetch commands out.
//!
//! Topology (one OS thread per stage, bounded sync channels —
//! backpressure propagates to the fault producer):
//!
//! ```text
//! faults ─► router thread ─► batch+infer thread (size/deadline
//!              │               batching, synchronous PJRT)
//!              └── block prefetches ──► commands ◄── predicted pages
//! ```
//!
//! The simulator uses the synchronous path in [`crate::prefetch::dl`]
//! directly (deterministic simulated time); this service is the
//! *deployment* shape — `repro serve` replays a fault stream through
//! it and the `e2e_prefetch` example drives it end to end.

use crate::config::RuntimeConfig;
use crate::coordinator::router::{FaultEvent, PrefetchCommand, Router};
use crate::coordinator::stats::CoordinatorStats;
use crate::predictor::{DeltaVocab, PredictorBackend, Prediction, Window};
use crate::types::PageNum;
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Handle returned by [`CoordinatorService::spawn`].
pub struct CoordinatorHandle {
    pub faults_tx: SyncSender<FaultEvent>,
    pub commands_rx: Receiver<PrefetchCommand>,
    pub stats: Arc<CoordinatorStats>,
    tasks: Vec<JoinHandle<()>>,
}

impl CoordinatorHandle {
    /// Close the input, drain remaining commands, and join the
    /// pipeline threads. Returns the drained commands.
    pub fn shutdown(self) -> Vec<PrefetchCommand> {
        let CoordinatorHandle { faults_tx, commands_rx, stats: _, tasks } = self;
        drop(faults_tx);
        let mut rest = Vec::new();
        while let Ok(c) = commands_rx.recv() {
            rest.push(c);
        }
        for t in tasks {
            let _ = t.join();
        }
        rest
    }
}

/// One inference request flowing router → infer.
struct InferReq {
    window: Window,
    anchor: PageNum,
}

pub struct CoordinatorService;

impl CoordinatorService {
    /// Spawn the two-stage pipeline.
    pub fn spawn(
        mut router: Router,
        mut backend: Box<dyn PredictorBackend>,
        rcfg: &RuntimeConfig,
    ) -> CoordinatorHandle {
        let stats = Arc::new(CoordinatorStats::default());
        let vocab: DeltaVocab = router.vocab().clone();
        let (faults_tx, faults_rx) = std::sync::mpsc::sync_channel::<FaultEvent>(1024);
        let (infer_tx, infer_rx) = std::sync::mpsc::sync_channel::<InferReq>(1024);
        let (cmd_tx, commands_rx) = std::sync::mpsc::sync_channel::<PrefetchCommand>(65536);
        let batch_size = rcfg.batch_size.max(1);
        let flush_after = Duration::from_micros(200);

        // Router thread.
        let st = stats.clone();
        let cmd = cmd_tx.clone();
        let route_task = std::thread::Builder::new()
            .name("uvm-router".into())
            .spawn(move || {
                while let Ok(ev) = faults_rx.recv() {
                    CoordinatorStats::inc(&st.faults, 1);
                    let out = router.route(&ev);
                    CoordinatorStats::inc(&st.block_prefetches, out.block.len() as u64);
                    // Hits only feed the history — no migration command.
                    if !out.block.is_empty()
                        && cmd.send(PrefetchCommand::Migrate(out.block)).is_err()
                    {
                        break;
                    }
                    if let Some(page) = out.bypass_page {
                        CoordinatorStats::inc(&st.bypasses, 1);
                        let _ = cmd.send(PrefetchCommand::Predicted { page, batched: 1 });
                    }
                    if let Some((_key, window)) = out.window {
                        if infer_tx.send(InferReq { window, anchor: ev.page }).is_err() {
                            break;
                        }
                    }
                }
            })
            .expect("spawn router thread");

        // Batch + infer thread.
        let st = stats.clone();
        let infer_task = std::thread::Builder::new()
            .name("uvm-infer".into())
            .spawn(move || {
                let mut pending: Vec<InferReq> = Vec::with_capacity(batch_size);
                'outer: while let Ok(first) = infer_rx.recv() {
                    pending.push(first);
                    let deadline = Instant::now() + flush_after;
                    while pending.len() < batch_size {
                        let left = deadline.saturating_duration_since(Instant::now());
                        match infer_rx.recv_timeout(left) {
                            Ok(r) => pending.push(r),
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => {
                                if pending.is_empty() {
                                    break 'outer;
                                }
                                break;
                            }
                        }
                    }
                    let batch: Vec<InferReq> = pending.drain(..).collect();
                    let windows: Vec<Window> = batch.iter().map(|r| r.window.clone()).collect();
                    let n = batch.len();
                    let t0 = Instant::now();
                    let classes = backend.predict(&windows);
                    st.record_batch_latency(t0.elapsed().as_secs_f64() * 1e6);
                    CoordinatorStats::inc(&st.batches, 1);
                    CoordinatorStats::inc(&st.predictions, classes.len() as u64);
                    for (class, req) in classes.into_iter().zip(batch) {
                        match vocab.decode(class) {
                            Prediction::Delta(d) => {
                                let target = req.anchor as i64 + d;
                                if target >= 0 && d != 0 {
                                    if cmd_tx
                                        .send(PrefetchCommand::Predicted {
                                            page: target as PageNum,
                                            batched: n,
                                        })
                                        .is_err()
                                    {
                                        return;
                                    }
                                }
                            }
                            Prediction::Oov => CoordinatorStats::inc(&st.oov, 1),
                        }
                    }
                }
            })
            .expect("spawn infer thread");

        CoordinatorHandle { faults_tx, commands_rx, stats, tasks: vec![route_task, infer_task] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BypassMode;
    use crate::predictor::{ConstantBackend, DeltaVocab};
    use crate::types::AccessOrigin;

    fn event(page: u64, at: u64) -> FaultEvent {
        FaultEvent {
            at,
            pc: 0x44,
            page,
            origin: AccessOrigin { sm: 0, warp: 0, cta: 0, tpc: 0, kernel_id: 0 },
            miss: true,
        }
    }

    #[test]
    fn end_to_end_pipeline_with_constant_backend() {
        let vocab = DeltaVocab::synthetic(vec![5, 9], 2);
        let rcfg = RuntimeConfig {
            history_len: 2,
            batch_size: 2,
            bypass: BypassMode::Never,
            ..Default::default()
        };
        let router = Router::new(vocab.clone(), &rcfg);
        // Always class 1 → delta 9.
        let backend = Box::new(ConstantBackend { class: 1, n_classes: vocab.n_classes() });
        let handle = CoordinatorService::spawn(router, backend, &rcfg);

        for (i, page) in [100u64, 101, 102, 103].iter().enumerate() {
            handle.faults_tx.send(event(*page, i as u64)).unwrap();
        }
        let cmds = handle.shutdown();

        let migrates = cmds.iter().filter(|c| matches!(c, PrefetchCommand::Migrate(_))).count();
        assert_eq!(migrates, 4, "one block migration per fault");
        let mut predicted: Vec<u64> = cmds
            .iter()
            .filter_map(|c| match c {
                PrefetchCommand::Predicted { page, .. } => Some(*page),
                _ => None,
            })
            .collect();
        predicted.sort();
        // Windows full from fault #3 onward (history_len=2): anchors
        // 102 and 103 each get +9.
        assert_eq!(predicted, vec![111, 112]);
    }

    #[test]
    fn oov_predictions_are_counted_not_emitted() {
        let vocab = DeltaVocab::synthetic(vec![5], 2);
        let rcfg = RuntimeConfig {
            history_len: 2,
            batch_size: 1,
            bypass: BypassMode::Never,
            ..Default::default()
        };
        let router = Router::new(vocab.clone(), &rcfg);
        let backend = Box::new(ConstantBackend { class: 1, n_classes: vocab.n_classes() }); // OOV
        let handle = CoordinatorService::spawn(router, backend, &rcfg);
        for (i, page) in [1u64, 2, 3, 4].iter().enumerate() {
            handle.faults_tx.send(event(*page, i as u64)).unwrap();
        }
        let stats = handle.stats.clone();
        let cmds = handle.shutdown();
        assert!(cmds.iter().all(|c| matches!(c, PrefetchCommand::Migrate(_))));
        assert!(stats.oov.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    }

    #[test]
    fn bypass_path_emits_without_backend() {
        let vocab = DeltaVocab::synthetic(vec![1], 2);
        let rcfg = RuntimeConfig {
            history_len: 2,
            batch_size: 4,
            bypass: BypassMode::Always,
            ..Default::default()
        };
        let router = Router::new(vocab.clone(), &rcfg);
        let backend = Box::new(ConstantBackend { class: 0, n_classes: 2 });
        let handle = CoordinatorService::spawn(router, backend, &rcfg);
        for (i, page) in [10u64, 11, 12, 13].iter().enumerate() {
            handle.faults_tx.send(event(*page, i as u64)).unwrap();
        }
        let stats = handle.stats.clone();
        let cmds = handle.shutdown();
        let predicted = cmds
            .iter()
            .filter(|c| matches!(c, PrefetchCommand::Predicted { .. }))
            .count();
        assert!(predicted >= 1, "bypass produced predictions");
        assert!(stats.bypasses.load(std::sync::atomic::Ordering::Relaxed) >= 1);
        assert_eq!(
            stats.predictions.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "model never invoked under Always bypass"
        );
    }
}
