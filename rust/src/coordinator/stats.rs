//! Live coordinator telemetry, shared by every shard and the infer
//! thread. Entirely lock-free: counters are relaxed `AtomicU64`s and
//! the latency/batch distributions are [`AtomicHistogram`]s, so a
//! shard never blocks a sibling to record a sample (the old
//! `Mutex<OnlineStats>` serialized the whole pipeline on one lock).
//!
//! Latency is measured end to end — from the instant a fault enters
//! the coordinator ([`crate::coordinator::FaultSender::send`]) to the
//! instant its command is handed to the command channel — and recorded
//! both aggregate and per tenant.

use crate::types::{PageNum, TenantId};
use crate::util::{AtomicHistogram, HistSummary};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Depth of the per-tenant recent-prediction ring that scores
/// predictions against subsequent faults. Deep enough to cover the
/// command pipeline between a `Predicted` emission and the tenant's
/// next few faults; a prediction older than this is counted as a miss
/// by omission (accuracy is a lower bound, like `dropped_commands`).
const RECENT_PRED_CAP: usize = 64;

/// Which command a shard delivered — the per-tenant counter it bumps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommandKind {
    Migrate,
    Predicted,
    Advise,
    Discard,
}

/// Per-tenant slice of the telemetry.
#[derive(Debug, Default)]
pub struct TenantStats {
    /// Commands emitted for this tenant (migrates + predicted +
    /// advises + discards).
    pub commands: AtomicU64,
    pub migrates: AtomicU64,
    pub predicted: AtomicU64,
    pub advises: AtomicU64,
    pub discards: AtomicU64,
    /// `Predicted` pages later demanded by this tenant's fault stream
    /// (scored through the recent-prediction ring) — the live accuracy
    /// numerator the metrics exporter reports over time.
    pub pred_hits: AtomicU64,
    /// End-to-end fault→command latency, microseconds.
    pub latency_us: AtomicHistogram,
    /// Ring of recently predicted pages awaiting a matching fault.
    /// A `Mutex` off the per-sample hot path: it is touched once per
    /// `Predicted` command / per fault, never per access, and shards
    /// only contend on their own tenant's ring.
    recent_pred: Mutex<VecDeque<PageNum>>,
}

impl TenantStats {
    /// Note a page the coordinator just told this tenant to prefetch.
    pub fn note_predicted_page(&self, page: PageNum) {
        let mut ring = self.recent_pred.lock().expect("recent_pred lock");
        if ring.len() == RECENT_PRED_CAP {
            ring.pop_front();
        }
        ring.push_back(page);
    }

    /// Score an incoming fault against the recent predictions: a match
    /// consumes the ring entry and counts a prediction hit.
    pub fn note_fault_page(&self, page: PageNum) -> bool {
        let mut ring = self.recent_pred.lock().expect("recent_pred lock");
        if let Some(i) = ring.iter().position(|&p| p == page) {
            ring.remove(i);
            drop(ring);
            self.pred_hits.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }
}

#[derive(Debug)]
pub struct CoordinatorStats {
    pub faults: AtomicU64,
    pub block_prefetches: AtomicU64,
    pub predictions: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of inference batch sizes (mean batch = this / `batches`).
    pub batched_windows: AtomicU64,
    pub bypasses: AtomicU64,
    pub oov: AtomicU64,
    /// Commands that could not be delivered (command channel gone) —
    /// the silent `let _ = send(…)` failure mode, now counted and
    /// surfaced through `CoordinatorHandle::shutdown`.
    pub dropped_commands: AtomicU64,
    /// Wall-clock model batch latency, microseconds.
    pub batch_latency_us: AtomicHistogram,
    /// Inference batch size distribution.
    pub batch_sizes: AtomicHistogram,
    /// Aggregate end-to-end fault→command latency, microseconds.
    pub fault_to_cmd_us: AtomicHistogram,
    tenants: Vec<TenantStats>,
}

impl CoordinatorStats {
    /// Telemetry sized for `n` tenants (ids ≥ `n` clamp to the last
    /// slot rather than panic — an unknown tenant must not take the
    /// pipeline down).
    pub fn with_tenants(n: usize) -> Self {
        Self {
            faults: AtomicU64::new(0),
            block_prefetches: AtomicU64::new(0),
            predictions: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_windows: AtomicU64::new(0),
            bypasses: AtomicU64::new(0),
            oov: AtomicU64::new(0),
            dropped_commands: AtomicU64::new(0),
            batch_latency_us: AtomicHistogram::new(),
            batch_sizes: AtomicHistogram::new(),
            fault_to_cmd_us: AtomicHistogram::new(),
            tenants: (0..n.max(1)).map(|_| TenantStats::default()).collect(),
        }
    }

    pub fn inc(counter: &AtomicU64, by: u64) {
        counter.fetch_add(by, Ordering::Relaxed);
    }

    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// The tenant's telemetry slot (ids beyond capacity share the last
    /// slot).
    pub fn tenant(&self, t: TenantId) -> &TenantStats {
        &self.tenants[(t as usize).min(self.tenants.len() - 1)]
    }

    /// Record one delivered command: aggregate + per-tenant counters
    /// and the end-to-end latency sample.
    pub fn record_command(&self, tenant: TenantId, kind: CommandKind, latency_us: u64) {
        self.fault_to_cmd_us.record(latency_us);
        let t = self.tenant(tenant);
        t.commands.fetch_add(1, Ordering::Relaxed);
        let counter = match kind {
            CommandKind::Migrate => &t.migrates,
            CommandKind::Predicted => &t.predicted,
            CommandKind::Advise => &t.advises,
            CommandKind::Discard => &t.discards,
        };
        counter.fetch_add(1, Ordering::Relaxed);
        t.latency_us.record(latency_us);
    }

    /// Record one model batch: wall latency (µs) and size.
    pub fn record_batch(&self, latency_us: u64, size: usize) {
        self.batch_latency_us.record(latency_us);
        self.batch_sizes.record(size as u64);
        Self::inc(&self.batches, 1);
        Self::inc(&self.batched_windows, size as u64);
    }

    /// Mean inference batch size so far (0 when no batch ran).
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_windows.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn latency_summary(&self) -> HistSummary {
        self.fault_to_cmd_us.summary()
    }

    pub fn snapshot(&self) -> String {
        let lat = self.fault_to_cmd_us.summary();
        let bat = self.batch_latency_us.summary();
        format!(
            "faults={} block_pf={} predictions={} batches={} mean_batch={:.2} bypass={} oov={} \
             dropped={} batch_lat_us(mean={:.1} p95={} n={}) e2e_us(p50={} p95={} p99={} n={})",
            self.faults.load(Ordering::Relaxed),
            self.block_prefetches.load(Ordering::Relaxed),
            self.predictions.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch(),
            self.bypasses.load(Ordering::Relaxed),
            self.oov.load(Ordering::Relaxed),
            self.dropped_commands.load(Ordering::Relaxed),
            bat.mean,
            bat.p95,
            bat.n,
            lat.p50,
            lat.p95,
            lat.p99,
            lat.n,
        )
    }
}

impl Default for CoordinatorStats {
    fn default() -> Self {
        Self::with_tenants(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let s = CoordinatorStats::default();
        CoordinatorStats::inc(&s.faults, 3);
        s.record_batch(120, 4);
        s.record_batch(80, 2);
        assert_eq!(s.mean_batch(), 3.0);
        let snap = s.snapshot();
        assert!(snap.contains("faults=3"), "{snap}");
        assert!(snap.contains("mean=100.0"), "{snap}");
        assert!(snap.contains("mean_batch=3.00"), "{snap}");
    }

    #[test]
    fn per_tenant_commands_and_clamping() {
        let s = CoordinatorStats::with_tenants(2);
        s.record_command(0, CommandKind::Migrate, 10);
        s.record_command(1, CommandKind::Predicted, 20);
        s.record_command(99, CommandKind::Predicted, 30); // clamps to the last slot
        assert_eq!(s.tenant(0).migrates.load(Ordering::Relaxed), 1);
        assert_eq!(s.tenant(1).predicted.load(Ordering::Relaxed), 2);
        assert_eq!(s.tenant(1).commands.load(Ordering::Relaxed), 2);
        assert_eq!(s.fault_to_cmd_us.count(), 3);
        assert_eq!(s.latency_summary().n, 3);
    }

    #[test]
    fn advise_and_discard_have_their_own_counters() {
        let s = CoordinatorStats::with_tenants(2);
        s.record_command(0, CommandKind::Advise, 5);
        s.record_command(0, CommandKind::Discard, 6);
        s.record_command(0, CommandKind::Discard, 7);
        let t = s.tenant(0);
        assert_eq!(t.advises.load(Ordering::Relaxed), 1);
        assert_eq!(t.discards.load(Ordering::Relaxed), 2);
        assert_eq!(t.commands.load(Ordering::Relaxed), 3, "all kinds count as commands");
        assert_eq!(t.migrates.load(Ordering::Relaxed), 0);
        assert_eq!(t.predicted.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn prediction_hit_ring_scores_and_caps() {
        let s = CoordinatorStats::with_tenants(1);
        let t = s.tenant(0);
        t.note_predicted_page(5);
        assert!(t.note_fault_page(5), "predicted page faulting scores a hit");
        assert!(!t.note_fault_page(5), "a hit consumes the ring entry");
        assert_eq!(t.pred_hits.load(Ordering::Relaxed), 1);
        // Overflow evicts the oldest prediction (lower-bound accuracy).
        for p in 0..(RECENT_PRED_CAP as u64 + 1) {
            t.note_predicted_page(p);
        }
        assert!(!t.note_fault_page(0), "oldest entry displaced at capacity");
        assert!(t.note_fault_page(RECENT_PRED_CAP as u64));
    }

    #[test]
    fn default_is_single_tenant() {
        let s = CoordinatorStats::default();
        assert_eq!(s.n_tenants(), 1);
        s.record_command(5, CommandKind::Migrate, 1); // must not panic
        assert_eq!(s.tenant(0).commands.load(Ordering::Relaxed), 1);
    }
}
