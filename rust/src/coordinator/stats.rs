//! Live coordinator telemetry (shared across the async tasks).

use crate::util::OnlineStats;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Debug, Default)]
pub struct CoordinatorStats {
    pub faults: AtomicU64,
    pub block_prefetches: AtomicU64,
    pub predictions: AtomicU64,
    pub batches: AtomicU64,
    pub bypasses: AtomicU64,
    pub oov: AtomicU64,
    /// Wall-clock batch latency in microseconds.
    pub batch_latency_us: Mutex<OnlineStats>,
}

impl CoordinatorStats {
    pub fn inc(counter: &AtomicU64, by: u64) {
        counter.fetch_add(by, Ordering::Relaxed);
    }

    pub fn record_batch_latency(&self, us: f64) {
        self.batch_latency_us.lock().unwrap().push(us);
    }

    pub fn snapshot(&self) -> String {
        let lat = self.batch_latency_us.lock().unwrap();
        format!(
            "faults={} block_pf={} predictions={} batches={} bypass={} oov={} \
             batch_lat_us(mean={:.1} min={:.1} max={:.1} n={})",
            self.faults.load(Ordering::Relaxed),
            self.block_prefetches.load(Ordering::Relaxed),
            self.predictions.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.bypasses.load(Ordering::Relaxed),
            self.oov.load(Ordering::Relaxed),
            lat.mean(),
            lat.min,
            lat.max,
            lat.n,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let s = CoordinatorStats::default();
        CoordinatorStats::inc(&s.faults, 3);
        s.record_batch_latency(120.0);
        s.record_batch_latency(80.0);
        let snap = s.snapshot();
        assert!(snap.contains("faults=3"), "{snap}");
        assert!(snap.contains("mean=100.0"), "{snap}");
    }
}
