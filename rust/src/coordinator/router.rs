//! Fault routing: cluster key computation, history update, window
//! extraction, bypass decision — the synchronous brain shared by the
//! async service. (The sim-side `DlPrefetcher` embeds the same
//! pipeline; the router exposes it for streaming deployments.)
//!
//! Multi-tenancy: every [`FaultEvent`] carries a [`TenantId`]. The
//! router mixes the tenant into the cluster key
//! ([`tenant_cluster_key`]) so two tenants replaying identical
//! workloads never share a history, and the sharded service uses the
//! same mixed key to pick a shard ([`shard_of`]) — a cluster therefore
//! lives wholly on one shard and its history stays coherent no matter
//! how tenant streams interleave.

use crate::config::{BypassMode, RuntimeConfig};
use crate::predictor::engine::featurize_window;
use crate::predictor::history::HistoryTable;
use crate::predictor::{ClusterBy, ClusterKey, DeltaVocab, Window};
use crate::types::{bb_base, AccessOrigin, AdviseHint, Cycle, PageNum, TenantId, PAGES_PER_BB};
use std::collections::{HashMap, HashSet};

/// Delta-distribution convergence a cluster needs before the basic
/// block it streamed past is declared dead and emitted as a lazy
/// `Discard` (mirrors the sim-side `DlPrefetcher` threshold).
const DISCARD_CONVERGENCE: f64 = 0.75;

/// Convergence of a *delta-0* cluster — the same page missing over and
/// over is CPU/GPU ping-pong, answered once per cluster with a
/// read-mostly `Advise` (a host duplicate stops the bouncing).
const ADVISE_CONVERGENCE: f64 = 0.75;

/// A GMMU access delivered to the coordinator. Every access extends
/// the cluster history (the predictor windows over the full access
/// stream — Figure 3's Hit/Miss feature); only misses (`miss = true`)
/// trigger migration + prediction.
#[derive(Debug, Clone, Copy)]
pub struct FaultEvent {
    pub at: Cycle,
    pub pc: u64,
    pub page: PageNum,
    pub origin: AccessOrigin,
    pub miss: bool,
    /// Which client stream this access belongs to (0 in single-tenant
    /// deployments — the simulator path and the old `serve` shape).
    pub tenant: TenantId,
}

/// What the coordinator tells the migration engine to do. Commands are
/// tenant-tagged and fully ordered (`Ord`) so per-tenant multisets can
/// be compared across shard counts — the content, per tenant, is
/// deterministic for a given input stream; only cross-tenant order may
/// vary with thread scheduling.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub enum PrefetchCommand {
    /// Migrate these pages now (basic-block floor).
    Migrate { tenant: TenantId, pages: Vec<PageNum> },
    /// Migrate one predicted page (model answer).
    Predicted { tenant: TenantId, page: PageNum },
    /// Attach a memory-usage hint (`cudaMemAdvise` modeled) to pages.
    Advise { tenant: TenantId, pages: Vec<PageNum>, hint: AdviseHint },
    /// Hand pages back without writeback (`UvmDiscardAsync` modeled
    /// when `lazy`).
    Discard { tenant: TenantId, pages: Vec<PageNum>, lazy: bool },
}

impl PrefetchCommand {
    pub fn tenant(&self) -> TenantId {
        match self {
            PrefetchCommand::Migrate { tenant, .. } => *tenant,
            PrefetchCommand::Predicted { tenant, .. } => *tenant,
            PrefetchCommand::Advise { tenant, .. } => *tenant,
            PrefetchCommand::Discard { tenant, .. } => *tenant,
        }
    }
}

/// Fold a tenant id into a cluster key (splitmix64-style finalizer) so
/// per-tenant clusters occupy disjoint key ranges regardless of the
/// underlying [`ClusterBy`] mode. Deterministic: same (tenant, key) ⇒
/// same mixed key on every run and platform.
pub fn tenant_cluster_key(tenant: TenantId, key: ClusterKey) -> ClusterKey {
    let mut z = key.0 ^ (tenant as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    ClusterKey(z ^ (z >> 31))
}

/// Which router shard owns this event's cluster. Uses the same
/// (SM, warp) clustering + tenant mixing as [`Router::route`], so
/// every event of a cluster lands on the same shard and the shard's
/// `HistoryTable` sees the full per-cluster stream.
pub fn shard_of(ev: &FaultEvent, shards: usize) -> usize {
    debug_assert!(shards > 0);
    let key = tenant_cluster_key(ev.tenant, ClusterBy::SmWarp.key(&ev.origin, ev.pc));
    (key.0 % shards.max(1) as u64) as usize
}

/// Result of routing one fault.
#[derive(Debug)]
pub struct RouteOutcome {
    /// Basic-block pages to migrate immediately.
    pub block: Vec<PageNum>,
    /// A model-ready window, if the cluster history is full and the
    /// bypass did not fire.
    pub window: Option<(ClusterKey, Window)>,
    /// Bypass answer, if the cluster's delta distribution converged.
    pub bypass_page: Option<PageNum>,
    /// One-shot read-mostly hint for the faulting block, when the
    /// cluster's history converged on delta 0 (ping-pong signature).
    pub advise: Option<(Vec<PageNum>, AdviseHint)>,
    /// Previous basic block to lazily hand back, when the cluster
    /// streamed forward past it with a converged positive delta.
    pub discard: Option<Vec<PageNum>>,
}

pub struct Router {
    cluster_by: ClusterBy,
    history: HistoryTable<ClusterKey>,
    vocab: DeltaVocab,
    bypass: BypassMode,
    bypass_convergence: f64,
    /// Basic block of each cluster's previous miss — the lazy-discard
    /// candidate once the cluster streams past it. Keyed lookups only.
    last_bb: HashMap<ClusterKey, PageNum>,
    /// Clusters that already received their one-shot read-mostly
    /// advise.
    advised: HashSet<ClusterKey>,
    pub faults_routed: u64,
    pub windows_emitted: u64,
    pub bypasses: u64,
}

impl Router {
    pub fn new(vocab: DeltaVocab, rcfg: &RuntimeConfig) -> Self {
        Self {
            cluster_by: ClusterBy::SmWarp,
            history: HistoryTable::new(vocab.history_len.max(1)),
            vocab,
            bypass: rcfg.bypass,
            bypass_convergence: rcfg.bypass_convergence,
            last_bb: HashMap::new(),
            advised: HashSet::new(),
            faults_routed: 0,
            windows_emitted: 0,
            bypasses: 0,
        }
    }

    pub fn vocab(&self) -> &DeltaVocab {
        &self.vocab
    }

    pub fn route(&mut self, ev: &FaultEvent) -> RouteOutcome {
        let key = tenant_cluster_key(ev.tenant, self.cluster_by.key(&ev.origin, ev.pc));
        self.history.push(key, ev.pc, ev.page, ev.at);
        if !ev.miss {
            // Hits only feed the history.
            return RouteOutcome {
                block: Vec::new(),
                window: None,
                bypass_page: None,
                advise: None,
                discard: None,
            };
        }
        self.faults_routed += 1;

        let bb = bb_base(ev.page);
        let block: Vec<PageNum> =
            (bb..bb + PAGES_PER_BB).filter(|&p| p != ev.page).collect();
        let prev_bb = self.last_bb.insert(key, bb);

        let cluster = self.history.get_mut(&key).expect("pushed above");
        let dominant = cluster.dominant_delta();

        // Streamed past the previous block with a converged forward
        // delta: the block is dead weight, hand it back lazily. All
        // state is per-cluster, so the emission is shard-invariant.
        let discard = match prev_bb {
            Some(prev)
                if prev < bb
                    && dominant.is_some_and(|(d, c)| d > 0 && c >= DISCARD_CONVERGENCE) =>
            {
                Some((prev..prev + PAGES_PER_BB).filter(|&p| p != ev.page).collect())
            }
            _ => None,
        };
        // Converged delta-0 miss stream: the same page keeps coming
        // back — CPU/GPU ping-pong. Answer once per cluster with a
        // read-mostly duplicate of the faulting block.
        let advise = if !self.advised.contains(&key)
            && dominant.is_some_and(|(d, c)| d == 0 && c >= ADVISE_CONVERGENCE)
        {
            self.advised.insert(key);
            Some(((bb..bb + PAGES_PER_BB).collect(), AdviseHint::ReadMostly))
        } else {
            None
        };

        if cluster.full_window().is_none() {
            return RouteOutcome { block, window: None, bypass_page: None, advise, discard };
        }

        let do_bypass = match self.bypass {
            BypassMode::Always => true,
            BypassMode::Never => false,
            BypassMode::Auto => dominant
                .map(|(_, c)| c >= self.bypass_convergence)
                .unwrap_or(false),
        };
        if do_bypass {
            self.bypasses += 1;
            let page = dominant
                .map(|(d, _)| ev.page as i64 + d)
                .filter(|&p| p >= 0)
                .map(|p| p as PageNum);
            return RouteOutcome { block, window: None, bypass_page: page, advise, discard };
        }

        self.windows_emitted += 1;
        let toks = cluster.full_window().expect("checked above");
        let window = featurize_window(&self.vocab, toks);
        RouteOutcome { block, window: Some((key, window)), bypass_page: None, advise, discard }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::DeltaVocab;

    fn event(page: u64, at: u64) -> FaultEvent {
        FaultEvent {
            at,
            pc: 0x44,
            page,
            origin: AccessOrigin { sm: 0, warp: 0, cta: 0, tpc: 0, kernel_id: 0 },
            miss: true,
            tenant: 0,
        }
    }

    fn router(bypass: BypassMode) -> Router {
        let vocab = DeltaVocab::synthetic(vec![1, 2], 3);
        let rcfg = RuntimeConfig { bypass, bypass_convergence: 0.9, ..Default::default() };
        Router::new(vocab, &rcfg)
    }

    #[test]
    fn emits_block_always_window_when_full() {
        let mut r = router(BypassMode::Never);
        for (i, p) in [0u64, 1, 2].iter().enumerate() {
            let out = r.route(&event(*p, i as u64));
            assert_eq!(out.block.len(), 15);
            assert!(out.window.is_none(), "history not full yet");
        }
        let out = r.route(&event(3, 3));
        assert!(out.window.is_some(), "3 deltas accumulated");
        assert_eq!(out.window.unwrap().1.tokens.len(), 3);
    }

    #[test]
    fn bypass_fires_on_converged_stream() {
        let mut r = router(BypassMode::Auto);
        for i in 0..6u64 {
            r.route(&event(i, i));
        }
        let out = r.route(&event(6, 6));
        assert_eq!(out.bypass_page, Some(7), "dominant delta 1 applied");
        assert!(out.window.is_none());
        assert!(r.bypasses >= 1);
    }

    #[test]
    fn separate_warps_route_to_separate_clusters() {
        let mut r = router(BypassMode::Never);
        for i in 0..4u64 {
            r.route(&event(i, i));
        }
        let mut ev = event(100, 10);
        ev.origin.warp = 9;
        let out = r.route(&ev);
        assert!(out.window.is_none(), "fresh cluster has no history");
    }

    #[test]
    fn separate_tenants_route_to_separate_clusters() {
        let mut r = router(BypassMode::Never);
        // Tenant 0 fills its cluster history.
        for i in 0..4u64 {
            r.route(&event(i, i));
        }
        assert!(r.route(&event(4, 4)).window.is_some());
        // Same (sm, warp, pc) under a different tenant starts cold.
        let mut ev = event(100, 10);
        ev.tenant = 1;
        let out = r.route(&ev);
        assert!(out.window.is_none(), "tenant 1 has no history yet");
    }

    #[test]
    fn tenant_key_mixing_is_deterministic_and_disjoint() {
        let base = ClusterKey(0x42);
        assert_eq!(tenant_cluster_key(3, base), tenant_cluster_key(3, base));
        assert_ne!(tenant_cluster_key(0, base), tenant_cluster_key(1, base));
    }

    #[test]
    fn shard_assignment_is_stable_per_cluster() {
        let ev = event(7, 0);
        let s = shard_of(&ev, 4);
        assert!(s < 4);
        // Same cluster (tenant, sm, warp) ⇒ same shard, whatever the page.
        let ev2 = event(9_999, 5);
        assert_eq!(shard_of(&ev2, 4), s);
        // One shard ⇒ everything maps to 0.
        assert_eq!(shard_of(&ev, 1), 0);
    }

    #[test]
    fn command_tenant_accessor() {
        let m = PrefetchCommand::Migrate { tenant: 7, pages: vec![1] };
        let p = PrefetchCommand::Predicted { tenant: 9, page: 4 };
        let a = PrefetchCommand::Advise { tenant: 3, pages: vec![2], hint: AdviseHint::ReadMostly };
        let d = PrefetchCommand::Discard { tenant: 5, pages: vec![8], lazy: true };
        assert_eq!(m.tenant(), 7);
        assert_eq!(p.tenant(), 9);
        assert_eq!(a.tenant(), 3);
        assert_eq!(d.tenant(), 5);
    }

    /// The shard-determinism multiset tests sort mixed command vectors
    /// — `Ord` must cover every variant and produce a stable total
    /// order.
    #[test]
    fn commands_sort_stably_across_all_variants() {
        use crate::types::PreferredLocation;
        let mut cmds = vec![
            PrefetchCommand::Discard { tenant: 1, pages: vec![8], lazy: true },
            PrefetchCommand::Advise {
                tenant: 1,
                pages: vec![2],
                hint: AdviseHint::PreferredLocation(PreferredLocation::Device),
            },
            PrefetchCommand::Predicted { tenant: 1, page: 4 },
            PrefetchCommand::Migrate { tenant: 1, pages: vec![1] },
            PrefetchCommand::Advise { tenant: 1, pages: vec![2], hint: AdviseHint::ReadMostly },
            PrefetchCommand::Discard { tenant: 1, pages: vec![8], lazy: false },
        ];
        let mut twice = cmds.clone();
        cmds.sort();
        twice.sort();
        assert_eq!(cmds, twice);
        // Declaration order: Migrate < Predicted < Advise < Discard.
        assert!(matches!(cmds[0], PrefetchCommand::Migrate { .. }));
        assert!(matches!(cmds[1], PrefetchCommand::Predicted { .. }));
        assert!(matches!(cmds[2], PrefetchCommand::Advise { .. }));
        assert!(matches!(cmds[5], PrefetchCommand::Discard { .. }));
    }

    #[test]
    fn streaming_cluster_emits_discard_for_previous_block() {
        let mut r = router(BypassMode::Never);
        for i in 0..8u64 {
            let out = r.route(&event(i, i));
            assert!(out.discard.is_none(), "still inside block 0");
        }
        // Crossing into block 1 with a converged +1 stream hands the
        // previous block back.
        let out = r.route(&event(16, 16));
        let discard = out.discard.expect("bb advance on a converged stream");
        assert_eq!(discard.len(), 16);
        assert!(discard.iter().all(|&p| p < 16));
        // No new bb advance ⇒ no new discard.
        assert!(r.route(&event(17, 17)).discard.is_none());
    }

    #[test]
    fn ping_pong_cluster_gets_one_read_mostly_advise() {
        let mut r = router(BypassMode::Never);
        assert!(r.route(&event(5, 0)).advise.is_none(), "no deltas yet");
        // Second miss on the same page: delta-0 convergence = 1.0.
        let out = r.route(&event(5, 1));
        let (pages, hint) = out.advise.expect("delta-0 convergence");
        assert_eq!(hint, AdviseHint::ReadMostly);
        assert_eq!(pages, (0..16).collect::<Vec<PageNum>>());
        // One-shot per cluster.
        for i in 2..6u64 {
            assert!(r.route(&event(5, i)).advise.is_none(), "advise is one-shot");
        }
    }
}
