//! The unified workload-source registry.
//!
//! Every way of producing a [`WorkloadInstance`] — the 11 dense
//! paper kernels, the UVMBench-style irregular generators, and traces
//! ingested by `repro trace ingest` — enters the simulator through one
//! API: a [`WorkloadSource`] looked up by name in a
//! [`WorkloadRegistry`]. The eval axes (sweep, oversub, train, serve,
//! analyze) query the registry instead of a closed name list, so a
//! freshly ingested trace is immediately sweepable with no per-axis
//! special-casing (DESIGN.md §10).
//!
//! Sources are kept in *registration order* (dense suite in the
//! canonical Tables 10/11 row order, then the irregular trio, then
//! traces in manifest order), so grid layouts and the positional
//! U-vs-R pairing stay stable across releases.

use crate::config::SimConfig;
use crate::workloads::common::Builder;
use crate::workloads::{trace, WorkloadInstance};
use std::collections::HashMap;
use std::path::Path;

/// Access-pattern family of a workload source — the coarse taxonomy
/// grids are narrowed by (`registry.family(...)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadFamily {
    /// The paper's Fig. 6 loop nests: streaming, matvec, stencil,
    /// wavefront, two-phase.
    Dense,
    /// Data-dependent access patterns (graph traversal, sparse matvec,
    /// hash join) where locality-based prefetching breaks down.
    Irregular,
    /// Replayed `(pc, sm, warp, cta, vaddr)` streams ingested by
    /// `repro trace ingest` (names carry the `trace:` prefix).
    Trace,
}

impl WorkloadFamily {
    pub fn as_str(&self) -> &'static str {
        match self {
            WorkloadFamily::Dense => "dense",
            WorkloadFamily::Irregular => "irregular",
            WorkloadFamily::Trace => "trace",
        }
    }
}

/// One way of producing a workload. `build` must be deterministic in
/// `(cfg, seed, scale)` — the parallel sweep executor relies on it.
pub trait WorkloadSource: Send + Sync {
    /// Registry key (and `WorkloadInstance::name`). Trace sources use
    /// the `trace:<name>` convention so the BENCH_eval.json `source`
    /// tag is derivable from the name alone (see [`source_tag`]).
    fn name(&self) -> &str;
    fn family(&self) -> WorkloadFamily;
    fn build(&self, cfg: &SimConfig, seed: u64, scale: f64) -> anyhow::Result<WorkloadInstance>;
}

/// BENCH_eval.json `source` tag for a benchmark name: `"trace"` for
/// ingested traces (the `trace:` naming convention), `"builtin"` for
/// everything else. Pure function of the name so telemetry tagging
/// needs no registry lookup.
pub fn source_tag(name: &str) -> &'static str {
    if name.starts_with(trace::TRACE_PREFIX) {
        "trace"
    } else {
        "builtin"
    }
}

/// A generator-backed source: thin adapter from the per-benchmark
/// `build(Builder) -> WorkloadInstance` functions to the trait.
struct BuiltinSource {
    name: &'static str,
    family: WorkloadFamily,
    build: fn(Builder) -> WorkloadInstance,
}

impl WorkloadSource for BuiltinSource {
    fn name(&self) -> &str {
        self.name
    }
    fn family(&self) -> WorkloadFamily {
        self.family
    }
    fn build(&self, cfg: &SimConfig, seed: u64, scale: f64) -> anyhow::Result<WorkloadInstance> {
        Ok((self.build)(Builder::new(cfg, seed, scale)))
    }
}

/// The built-in generators, in canonical grid order: the paper's 11
/// dense kernels (Tables 10/11 row order), then the irregular trio.
const BUILTINS: &[(&str, WorkloadFamily, fn(Builder) -> WorkloadInstance)] = &[
    ("addvectors", WorkloadFamily::Dense, crate::workloads::addvectors::build),
    ("atax", WorkloadFamily::Dense, crate::workloads::atax::build),
    ("backprop", WorkloadFamily::Dense, crate::workloads::backprop::build),
    ("bicg", WorkloadFamily::Dense, crate::workloads::bicg::build),
    ("hotspot", WorkloadFamily::Dense, crate::workloads::hotspot::build),
    ("mvt", WorkloadFamily::Dense, crate::workloads::mvt::build),
    ("nw", WorkloadFamily::Dense, crate::workloads::nw::build),
    ("pathfinder", WorkloadFamily::Dense, crate::workloads::pathfinder::build),
    ("srad_v2", WorkloadFamily::Dense, crate::workloads::srad_v2::build),
    ("streamtriad", WorkloadFamily::Dense, crate::workloads::streamtriad::build),
    ("conv2d", WorkloadFamily::Dense, crate::workloads::conv2d::build),
    ("bfs", WorkloadFamily::Irregular, crate::workloads::bfs::build),
    ("spmv", WorkloadFamily::Irregular, crate::workloads::spmv::build),
    ("hash_join", WorkloadFamily::Irregular, crate::workloads::hash_join::build),
];

/// The dense subset used by the model-quality tables (Tables 1–8):
/// everything but the two kernels the paper leaves out of them.
const MODEL_SUBSET: &[&str] = &[
    "addvectors",
    "atax",
    "backprop",
    "bicg",
    "hotspot",
    "mvt",
    "nw",
    "pathfinder",
    "srad_v2",
];

/// Name-indexed collection of [`WorkloadSource`]s, in registration
/// order.
pub struct WorkloadRegistry {
    sources: Vec<Box<dyn WorkloadSource>>,
    index: HashMap<String, usize>,
}

impl WorkloadRegistry {
    /// Registry of every built-in generator (dense + irregular), no
    /// trace entries.
    pub fn builtin() -> Self {
        let mut r = Self { sources: Vec::new(), index: HashMap::new() };
        for &(name, family, build) in BUILTINS {
            r.register(Box::new(BuiltinSource { name, family, build }))
                .expect("builtin names are unique");
        }
        r
    }

    /// Built-ins plus every trace recorded in `dir`'s manifest
    /// (written by `repro trace ingest --trace-dir`).
    pub fn with_trace_dir(dir: &Path) -> anyhow::Result<Self> {
        let mut r = Self::builtin();
        for src in trace::trace_sources(dir)? {
            r.register(Box::new(src))?;
        }
        Ok(r)
    }

    /// Add a source; duplicate names are an error (the `trace:` prefix
    /// keeps ingested traces from shadowing built-ins).
    pub fn register(&mut self, src: Box<dyn WorkloadSource>) -> anyhow::Result<()> {
        let name = src.name().to_string();
        anyhow::ensure!(
            !self.index.contains_key(&name),
            "workload source '{name}' is already registered"
        );
        self.index.insert(name, self.sources.len());
        self.sources.push(src);
        Ok(())
    }

    /// Resolve spelling aliases kept for compatibility (the paper
    /// writes 2DCONV for the convolution kernel).
    fn resolve_key<'a>(&self, name: &'a str) -> &'a str {
        match name {
            "2dconv" => "conv2d",
            other => other,
        }
    }

    /// Look a source up by name (alias-aware); `None` when unknown.
    pub fn get(&self, name: &str) -> Option<&dyn WorkloadSource> {
        self.index.get(self.resolve_key(name)).map(|&i| self.sources[i].as_ref())
    }

    /// The unknown-name error, listing every registered name (trace
    /// entries included) so typos are self-diagnosing.
    pub fn unknown(&self, name: &str) -> anyhow::Error {
        anyhow::anyhow!(
            "unknown benchmark '{name}' (registered: {})",
            self.all().join(", ")
        )
    }

    /// Build a workload by name.
    pub fn build(
        &self,
        name: &str,
        cfg: &SimConfig,
        seed: u64,
        scale: f64,
    ) -> anyhow::Result<WorkloadInstance> {
        match self.get(name) {
            Some(src) => src.build(cfg, seed, scale),
            None => Err(self.unknown(name)),
        }
    }

    /// Every registered name, in registration (= grid) order.
    pub fn all(&self) -> Vec<&str> {
        self.sources.iter().map(|s| s.name()).collect()
    }

    /// Registered names of one family, in registration order.
    pub fn family(&self, family: WorkloadFamily) -> Vec<&str> {
        self.sources.iter().filter(|s| s.family() == family).map(|s| s.name()).collect()
    }

    /// The model-quality subset (Tables 1–8 rows): the registered
    /// dense kernels the paper trains per-benchmark predictors for.
    pub fn model(&self) -> Vec<&str> {
        self.sources
            .iter()
            .map(|s| s.name())
            .filter(|n| MODEL_SUBSET.contains(n))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_has_grid_order_and_families() {
        let r = WorkloadRegistry::builtin();
        let all = r.all();
        assert_eq!(all.len(), 14);
        assert_eq!(&all[..3], &["addvectors", "atax", "backprop"]);
        assert_eq!(&all[11..], &["bfs", "spmv", "hash_join"]);
        assert_eq!(r.family(WorkloadFamily::Dense).len(), 11);
        assert_eq!(r.family(WorkloadFamily::Irregular), vec!["bfs", "spmv", "hash_join"]);
        assert!(r.family(WorkloadFamily::Trace).is_empty());
        assert_eq!(r.model().len(), 9);
    }

    #[test]
    fn alias_resolves_and_unknown_lists_names() {
        let r = WorkloadRegistry::builtin();
        assert!(r.get("2dconv").is_some(), "paper spelling of conv2d");
        let err = r.build("nope", &SimConfig::default(), 0, 1.0).unwrap_err().to_string();
        assert!(err.contains("unknown benchmark 'nope'"), "{err}");
        assert!(err.contains("bfs") && err.contains("conv2d"), "{err}");
    }

    #[test]
    fn duplicate_registration_rejected() {
        let mut r = WorkloadRegistry::builtin();
        let dup = Box::new(BuiltinSource {
            name: "atax",
            family: WorkloadFamily::Dense,
            build: crate::workloads::atax::build,
        });
        assert!(r.register(dup).is_err());
    }

    #[test]
    fn source_tag_follows_naming_convention() {
        assert_eq!(source_tag("atax"), "builtin");
        assert_eq!(source_tag("bfs"), "builtin");
        assert_eq!(source_tag("trace:sample"), "trace");
    }
}
