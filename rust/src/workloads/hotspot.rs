//! Hotspot (Rodinia) — a 2-D thermal stencil: each output cell reads
//! its 4-neighborhood of `temp` plus `power`. Two pyramid iterations
//! with the in/out roles swapped.
//!
//! The stencil's per-cluster delta alphabet is wide (row ±1 page,
//! array-to-array jumps, iteration swaps), which is why Hotspot is the
//! paper's weakest prediction row (Table 1: 0.77 top-1) while still
//! gaining hit rate from the learned policy (Table 10: 0.61 → 0.84).

use super::common::{pc, Builder, COALESCE_BYTES};
use super::WorkloadInstance;

pub fn build(mut b: Builder) -> WorkloadInstance {
    let n = b.scaled(1024, 32); // N×N grid; one row = N*4 bytes
    let temp_a = b.alloc(n * n * 4);
    let temp_b = b.alloc(n * n * 4);
    let power = b.alloc(n * n * 4);
    let row = n * 4;

    // 6 pyramid iterations (the Rodinia default runs many; enough
    // to exercise the repeated-phase pattern and fill the corpus).
    for iter in 0..6u16 {
        let (src, dst) = if iter % 2 == 0 { (&temp_a, &temp_b) } else { (&temp_b, &temp_a) };
        for (worker, (r0, rows)) in b.split(n).into_iter().enumerate() {
            let cta = (worker / 4) as u32;
            for r in r0..r0 + rows {
                let rm = r.saturating_sub(1);
                let rp = (r + 1).min(n - 1);
                for g in 0..row / COALESCE_BYTES {
                    let off = g * COALESCE_BYTES;
                    b.load(worker, pc(iter, 0), src, r * row + off, 1, cta, iter);
                    b.load(worker, pc(iter, 1), src, rm * row + off, 1, cta, iter);
                    b.load(worker, pc(iter, 2), src, rp * row + off, 1, cta, iter);
                    b.load(worker, pc(iter, 3), &power, r * row + off, 2, cta, iter);
                    b.store(worker, pc(iter, 4), dst, r * row + off, 3, cta, iter);
                }
            }
        }
    }
    b.finish("hotspot")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::workloads::common::Builder;

    #[test]
    fn stencil_reads_three_rows_per_group() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.1));
        let ops = &wl.tasks[5].ops; // a middle worker (interior rows)
        // First 5 ops: src r, src r-1, src r+1, power, dst.
        let ids: Vec<u8> = ops.iter().take(5).map(|o| o.access.array_id).collect();
        assert_eq!(&ids[..3], &[0, 0, 0].as_slice()[..], "three src-row reads");
        assert_eq!(ids[3], 2, "power read");
        assert_eq!(ids[4], 1, "dst write");
    }

    #[test]
    fn second_iteration_swaps_buffers() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.1));
        let t = &wl.tasks[0];
        let k1_store = t.ops.iter().find(|o| o.kernel_id == 1 && o.access.is_store).unwrap();
        assert_eq!(k1_store.access.array_id, 0, "iteration 1 writes back into temp_a");
    }
}
