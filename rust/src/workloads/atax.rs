//! ATAX (Polybench) — `y = Aᵀ(A·x)`.
//!
//! Two kernels over the same N×M matrix:
//! * kernel 0 (`tmp = A·x`): row sweep — warps walk rows of A
//!   sequentially (page delta +1 within a row), with the small `x`
//!   vector resident after first touch;
//! * kernel 1 (`y = Aᵀ·tmp`): column sweep — each step jumps a full
//!   row stride, so the page delta is constant at `M*4/4096` pages.
//!
//! The column sweep is the paper's "dominant delta" showcase (§5.3:
//! delta 16384 bytes = 4 pages covers 99.26 % of ATAX's vocabulary);
//! with M = 2048 our dominant delta is 2 pages at a similar ratio.

use super::common::{pc, Builder, COALESCE_BYTES};
use super::WorkloadInstance;

pub fn build(mut b: Builder) -> WorkloadInstance {
    let n = b.scaled(2048, 32).max(1024); // rows (≥1024 keeps the row stride ≥ 1 page)
    let m = b.scaled(2048, 32).max(1024); // cols
    let a = b.alloc(n * m * 4);
    let x = b.alloc(m * 4);
    let y = b.alloc(n * 4);
    let tmp = b.alloc(n * 4);

    // Kernel 0: tmp = A·x, one row per work item.
    for (worker, (r0, rows)) in b.split(n).into_iter().enumerate() {
        let cta = (worker / 4) as u32;
        for r in r0..r0 + rows {
            for g in 0..m * 4 / COALESCE_BYTES {
                b.load(worker, pc(0, 0), &a, r * m * 4 + g * COALESCE_BYTES, 1, cta, 0);
                // x is re-read every 4 groups (register-tiled).
                if g % 4 == 0 {
                    b.load(worker, pc(0, 1), &x, g * COALESCE_BYTES % (m * 4), 1, cta, 0);
                }
            }
            b.store(worker, pc(0, 2), &tmp, r * 4 / COALESCE_BYTES * COALESCE_BYTES, 2, cta, 0);
        }
    }

    // Kernel 1: y = Aᵀ·tmp, one 32-column group per work item; each
    // group walks all rows — the constant-row-stride column sweep.
    for (worker, (g0, groups)) in b.split(m * 4 / COALESCE_BYTES).into_iter().enumerate() {
        let cta = (worker / 4) as u32;
        for g in g0..g0 + groups {
            for r in 0..n {
                b.load(worker, pc(1, 0), &a, r * m * 4 + g * COALESCE_BYTES, 1, cta, 1);
                if r % 8 == 0 {
                    b.load(worker, pc(1, 1), &tmp, r * 4 / COALESCE_BYTES * COALESCE_BYTES, 1, cta, 1);
                }
            }
            b.store(worker, pc(1, 2), &y, g * COALESCE_BYTES % (n * 4), 2, cta, 1);
        }
    }
    b.finish("atax")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::types::page_of;
    use crate::workloads::common::Builder;
    use std::collections::HashMap;

    #[test]
    fn column_sweep_has_dominant_page_delta() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.25));
        // Collect kernel-1 A-array page deltas per warp.
        let mut counts: HashMap<i64, u64> = HashMap::new();
        for t in &wl.tasks {
            let pages: Vec<u64> = t
                .ops
                .iter()
                .filter(|o| o.kernel_id == 1 && o.access.array_id == 0)
                .map(|o| page_of(o.access.vaddr))
                .collect();
            for w in pages.windows(2) {
                *counts.entry(w[1] as i64 - w[0] as i64).or_insert(0) += 1;
            }
        }
        let total: u64 = counts.values().sum();
        let max = counts.values().max().copied().unwrap_or(0);
        assert!(
            max as f64 / total as f64 > 0.9,
            "dominant delta should cover >90%: {:?}",
            counts
        );
    }

    #[test]
    fn has_two_kernels() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.1));
        let mut kernels: Vec<u16> =
            wl.tasks.iter().flat_map(|t| t.ops.iter().map(|o| o.kernel_id)).collect();
        kernels.dedup();
        assert!(kernels.contains(&0) && kernels.contains(&1));
    }
}
