//! Hash join — build/probe over a seeded hash table with scattered
//! probes (UVMBench's database family).
//!
//! Kernel 0 (build) streams the build relation and *stores* each
//! tuple's slot at a hashed (splitmix64-mixed) bucket — sequential
//! reads, scattered writes. Kernel 1 (probe) streams the probe
//! relation, gathers the hashed bucket, and on a match (~1/3 of
//! probes) dereferences back into the build table — a two-level
//! data-dependent indirection with no exploitable stride.

use super::common::{pc, Builder};
use super::WorkloadInstance;

pub fn build(mut b: Builder) -> WorkloadInstance {
    let nb = b.scaled(65_536, 32); // build-side tuples
    let np = nb * 2; // probe-side tuples
    let nh = (nb * 2).next_power_of_two(); // hash-table slots

    let build_t = b.alloc(nb * 4);
    let hash = b.alloc(nh * 4);
    let probe = b.alloc(np * 4);
    let out = b.alloc(np * 4);

    let key_seed = b.rng.next_u64();
    // splitmix64 finalizer: key -> uniformly mixed bucket.
    let bucket = |key: u64| -> u64 {
        let mut z = key.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        (z ^ (z >> 31)) & (nh - 1)
    };

    // Kernel 0: build — stream the relation, scatter into the table.
    for (worker, (i0, cnt)) in b.split(nb).into_iter().enumerate() {
        let cta = (worker / 4) as u32;
        for i in i0..i0 + cnt {
            b.load(worker, pc(0, 0), &build_t, i * 4, 1, cta, 0);
            b.store(worker, pc(0, 1), &hash, bucket(key_seed ^ i) * 4, 1, cta, 0);
        }
    }

    // Kernel 1: probe — keys drawn (mixed, deterministic) from 3× the
    // build key space, so about a third of the probes hit the table.
    for (worker, (j0, cnt)) in b.split(np).into_iter().enumerate() {
        let cta = (worker / 4) as u32;
        for j in j0..j0 + cnt {
            b.load(worker, pc(1, 0), &probe, j * 4, 1, cta, 1);
            let tuple = (key_seed ^ j).wrapping_mul(0x2545F4914F6CDD1D) % (nb * 3);
            b.load(worker, pc(1, 1), &hash, bucket(key_seed ^ tuple) * 4, 1, cta, 1);
            if tuple < nb {
                // Match: second indirection back into the build table.
                b.load(worker, pc(1, 2), &build_t, tuple * 4, 2, cta, 1);
            }
            b.store(worker, pc(1, 3), &out, j * 4, 1, cta, 1);
        }
    }
    b.finish("hash_join")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::types::page_of;
    use crate::workloads::common::Builder;
    use std::collections::HashSet;

    #[test]
    fn has_build_and_probe_kernels() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.05));
        let kernels: HashSet<u16> =
            wl.tasks.iter().flat_map(|t| t.ops.iter().map(|o| o.kernel_id)).collect();
        assert_eq!(kernels, HashSet::from([0, 1]));
    }

    #[test]
    fn hash_accesses_scatter_while_streams_stay_sequential() {
        let wl = super::build(Builder::new(&SimConfig::default(), 2, 0.5));
        let probe_site = crate::workloads::common::pc(1, 1);
        let stream_site = crate::workloads::common::pc(1, 0);
        let mut hash_pages = HashSet::new();
        let mut stream_deltas = HashSet::new();
        for t in &wl.tasks {
            let mut prev = None;
            for o in t.ops.iter().filter(|o| o.access.pc == probe_site) {
                hash_pages.insert(page_of(o.access.vaddr));
            }
            for o in t.ops.iter().filter(|o| o.access.pc == stream_site) {
                let p = page_of(o.access.vaddr) as i64;
                if let Some(q) = prev {
                    stream_deltas.insert(p - q);
                }
                prev = Some(p);
            }
        }
        // The mixed gather sprays across the whole table (64 pages at
        // this scale)...
        assert!(hash_pages.len() > 16, "hash gather hit only {} pages", hash_pages.len());
        // ...while the relation stream stays a narrow-delta walk.
        assert!(stream_deltas.len() <= 2, "stream deltas: {stream_deltas:?}");
    }
}
