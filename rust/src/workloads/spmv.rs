//! SpMV — CSR sparse matrix-vector multiply, row-skewed
//! (UVMBench's sparse-algebra family).
//!
//! `y = A·x` with A in CSR: per row, `rowptr`/`colidx`/`vals` stream
//! sequentially, but the gather `x[colidx[e]]` jumps wherever the
//! nonzero sits — hub-biased (r² sampling) so a few columns stay hot
//! while the tail scatters. Row lengths follow a clamped power law and
//! rows are split contiguously across warps, so warp op counts are
//! *skewed* (unlike the dense suite's near-uniform split) — the
//! load-imbalance signature of real sparse kernels.

use super::common::{pc, Builder};
use super::WorkloadInstance;

pub fn build(mut b: Builder) -> WorkloadInstance {
    let n = b.scaled(32_768, 32);
    let len_cap = 256.min(n / 2).max(1);

    // Power-law row lengths (nnz per row), clamped to keep the matrix
    // bounded; the skew is what unbalances the row split below.
    let mut lens = Vec::with_capacity(n as usize);
    let mut nnz = 0u64;
    for _ in 0..n {
        let u = b.rng.unit();
        let l = ((2.0 / (1.0 - u * 0.999)).powf(1.2) as u64).clamp(2, len_cap);
        lens.push(l);
        nnz += l;
    }
    let mut starts = Vec::with_capacity(n as usize);
    let mut s = 0u64;
    for &l in &lens {
        starts.push(s);
        s += l;
    }

    let rowptr = b.alloc((n + 1) * 4);
    let colidx = b.alloc(nnz * 4);
    let vals = b.alloc(nnz * 4);
    let x = b.alloc(n * 4);
    let y = b.alloc(n * 4);

    // One contiguous row range per warp; row-length skew makes the
    // ranges cost wildly different op counts.
    for (worker, (r0, rows)) in b.split(n).into_iter().enumerate() {
        let cta = (worker / 4) as u32;
        for r in r0..r0 + rows {
            b.load(worker, pc(0, 0), &rowptr, r * 4, 1, cta, 0);
            let (e0, l) = (starts[r as usize], lens[r as usize]);
            let mut e = 0;
            while e < l {
                // One coalesced group of up to 32 nonzeros: sequential
                // colidx/vals reads, then the scattered x gather.
                b.load(worker, pc(0, 1), &colidx, (e0 + e) * 4, 1, cta, 0);
                b.load(worker, pc(0, 2), &vals, (e0 + e) * 4, 1, cta, 0);
                let u = b.rng.unit();
                let colv = ((u * u * n as f64) as u64).min(n - 1);
                b.load(worker, pc(0, 3), &x, colv * 4, 2, cta, 0);
                e += (l - e).min(32);
            }
            b.store(worker, pc(0, 4), &y, r * 4, 1, cta, 0);
        }
    }
    b.finish("spmv")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::workloads::common::Builder;

    #[test]
    fn row_skew_unbalances_warp_op_counts() {
        let wl = super::build(Builder::new(&SimConfig::default(), 1, 0.1));
        let counts: Vec<usize> = wl.tasks.iter().map(|t| t.ops.len()).collect();
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(
            *max as f64 > *min as f64 * 1.2,
            "power-law rows should skew warp loads: min {min}, max {max}"
        );
    }

    #[test]
    fn touches_all_five_arrays() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.05));
        let mut arrays: Vec<u8> =
            wl.tasks.iter().flat_map(|t| t.ops.iter().map(|o| o.access.array_id)).collect();
        arrays.sort_unstable();
        arrays.dedup();
        assert_eq!(arrays, vec![0, 1, 2, 3, 4]);
    }
}
