//! Trace-ingestion workload frontend (`repro trace ingest`).
//!
//! Parses accelsim-style kernel trace files — the
//! `(pc, sm, warp, cta, vaddr)` tuple stream the GMMU observes, the
//! same granularity `sim/trace.rs` emits — into [`WorkloadInstance`]s,
//! so real-application traces run through every eval axis exactly like
//! the built-in generators (DESIGN.md §10).
//!
//! Grammar (whitespace-separated, one record per line):
//!
//! ```text
//! line      := record | "-" directive | "#" comment | blank
//! record    := pc sm warp cta vaddr [store [compute [kernel [array]]]]
//! directive := key "=" value        ; "-workload name = x", "-trace version = 1"
//! ```
//!
//! `pc` and `vaddr` accept decimal or `0x` hex; the optional columns
//! default to `store=0 compute=1 kernel=0 array=255`. Files whose
//! first line is the `repro trace-gen` CSV header are auto-detected
//! and read in that column layout (`vaddr = page << 12`).
//!
//! The parse is streaming (one `BufRead` line at a time — no full-file
//! materialization) and every error names the file, the 1-based line,
//! and the offending column, matching the serve-replay CSV convention
//! in [`crate::eval::serve`]. Ingestion normalizes `(sm, warp)`
//! placement to the machine, caches the canonical form under
//! `--trace-dir`, and records it in `manifest.json`
//! (schema `trace_manifest/v1`); the cached entries register in the
//! [`WorkloadRegistry`](crate::workloads::WorkloadRegistry) as
//! `trace:<name>` sources.

use crate::config::SimConfig;
use crate::sim::sm::WarpOp;
use crate::sim::trace::TRACE_HEADER;
use crate::types::MemAccess;
use crate::util::Json;
use crate::workloads::registry::{WorkloadFamily, WorkloadSource};
use crate::workloads::{WarpTask, WorkloadInstance};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

/// Registry-name prefix for ingested traces (`trace:<name>`).
pub const TRACE_PREFIX: &str = "trace:";

/// Canonical trace-format version accepted by the parser.
pub const TRACE_VERSION: u64 = 1;

/// `manifest.json` schema tag.
pub const MANIFEST_SCHEMA: &str = "trace_manifest/v1";

const COLUMNS: &[&str] =
    &["pc", "sm", "warp", "cta", "vaddr", "store", "compute", "kernel", "array"];

/// A parsed trace: per-`(sm, warp)` op streams in first-appearance
/// order (which is what defines task order after placement).
pub struct ParsedTrace {
    /// Bare name (no `trace:` prefix): the `-workload name` directive
    /// when present, else the file stem.
    pub name: String,
    pub tasks: Vec<((u16, u16), Vec<WarpOp>)>,
    /// Record lines parsed (comments/directives excluded).
    pub records: u64,
}

struct Rec {
    sm: u16,
    warp: u16,
    op: WarpOp,
}

fn parse_u64(tok: &str) -> std::result::Result<u64, std::num::ParseIntError> {
    match tok.strip_prefix("0x").or_else(|| tok.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => tok.parse(),
    }
}

/// One whitespace-separated record; errors name the 1-based column and
/// its field name.
fn parse_record(line: &str) -> Result<Rec> {
    let toks: Vec<&str> = line.split_whitespace().collect();
    if toks.len() < 5 {
        bail!("expected at least 5 fields (pc sm warp cta vaddr), got {}", toks.len());
    }
    if toks.len() > COLUMNS.len() {
        bail!(
            "expected at most {} fields ({}), got {}",
            COLUMNS.len(),
            COLUMNS.join(" "),
            toks.len()
        );
    }
    let field = |i: usize, max: u64| -> Result<u64> {
        let tok = toks[i];
        let v = parse_u64(tok)
            .map_err(|e| anyhow!("column {} ({}) '{tok}': {e}", i + 1, COLUMNS[i]))?;
        if v > max {
            bail!("column {} ({}) '{tok}': exceeds max {max}", i + 1, COLUMNS[i]);
        }
        Ok(v)
    };
    let pc = field(0, u64::MAX)?;
    let sm = field(1, u16::MAX as u64)? as u16;
    let warp = field(2, u16::MAX as u64)? as u16;
    let cta = field(3, u32::MAX as u64)? as u32;
    let vaddr = field(4, u64::MAX)?;
    let is_store = if toks.len() > 5 { field(5, 1)? == 1 } else { false };
    let compute = if toks.len() > 6 { field(6, u32::MAX as u64)? as u32 } else { 1 };
    let kernel_id = if toks.len() > 7 { field(7, u16::MAX as u64)? as u16 } else { 0 };
    let array_id = if toks.len() > 8 { field(8, u8::MAX as u64)? as u8 } else { u8::MAX };
    Ok(Rec {
        sm,
        warp,
        op: WarpOp {
            compute,
            access: MemAccess { pc, vaddr, array_id, is_store },
            cta,
            kernel_id,
        },
    })
}

/// One `repro trace-gen` CSV row (`TRACE_HEADER` layout). The CSV
/// records pages, not byte addresses, so `vaddr = page << 12`; the
/// store flag is not recorded there and defaults to a load.
fn parse_csv_record(line: &str) -> Result<Rec> {
    let cols: Vec<&str> = line.split(',').collect();
    let names: Vec<&str> = TRACE_HEADER.split(',').collect();
    if cols.len() != names.len() {
        bail!("expected {} CSV columns ({TRACE_HEADER}), got {}", names.len(), cols.len());
    }
    let field = |i: usize, max: u64| -> Result<u64> {
        let tok = cols[i];
        let v: u64 = tok
            .parse()
            .map_err(|e| anyhow!("column {} ({}) '{tok}': {e}", i + 1, names[i]))?;
        if v > max {
            bail!("column {} ({}) '{tok}': exceeds max {max}", i + 1, names[i]);
        }
        Ok(v)
    };
    Ok(Rec {
        sm: field(3, u16::MAX as u64)? as u16,
        warp: field(4, u16::MAX as u64)? as u16,
        op: WarpOp {
            compute: 1,
            access: MemAccess {
                pc: field(1, u64::MAX)?,
                vaddr: field(2, (1u64 << 52) - 1)? << 12,
                array_id: field(8, u8::MAX as u64)? as u8,
                is_store: false,
            },
            cta: field(5, u32::MAX as u64)? as u32,
            kernel_id: field(7, u16::MAX as u64)? as u16,
        },
    })
}

/// Streaming parse of a trace file in either accepted layout.
pub fn parse_trace_file(path: &Path) -> Result<ParsedTrace> {
    let file = std::fs::File::open(path).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    let reader = BufReader::new(file);
    let mut name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "trace".to_string());
    let mut tasks: Vec<((u16, u16), Vec<WarpOp>)> = Vec::new();
    let mut slot: HashMap<(u16, u16), usize> = HashMap::new();
    let mut records = 0u64;
    let mut csv = false;
    for (idx, line) in reader.lines().enumerate() {
        let lineno = idx + 1;
        let line = line.map_err(|e| anyhow!("{} line {lineno}: {e}", path.display()))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        if idx == 0 && t == TRACE_HEADER {
            csv = true;
            continue;
        }
        if let Some(rest) = t.strip_prefix('-') {
            let (k, v) = rest.split_once('=').ok_or_else(|| {
                anyhow!("{} line {lineno}: directive '-{rest}' needs a '= value'", path.display())
            })?;
            match k.trim() {
                "workload name" => name = v.trim().to_string(),
                "trace version" => {
                    let ver: u64 = v.trim().parse().map_err(|e| {
                        anyhow!(
                            "{} line {lineno}: trace version '{}': {e}",
                            path.display(),
                            v.trim()
                        )
                    })?;
                    if ver != TRACE_VERSION {
                        bail!(
                            "{} line {lineno}: unsupported trace version {ver} (this parser \
                             reads version {TRACE_VERSION})",
                            path.display()
                        );
                    }
                }
                // Foreign directives (accelsim headers carry many) are
                // ignored rather than rejected.
                _ => {}
            }
            continue;
        }
        let rec = if csv { parse_csv_record(t) } else { parse_record(t) }
            .map_err(|e| anyhow!("{} line {lineno}: {e}", path.display()))?;
        records += 1;
        let key = (rec.sm, rec.warp);
        let ti = *slot.entry(key).or_insert_with(|| {
            tasks.push((key, Vec::new()));
            tasks.len() - 1
        });
        tasks[ti].1.push(rec.op);
    }
    if records == 0 {
        bail!("{}: no trace records (expected 'pc sm warp cta vaddr …' lines)", path.display());
    }
    Ok(ParsedTrace { name, tasks, records })
}

/// Fit parsed streams onto the machine: `(sm, warp)` pairs are kept
/// verbatim when every pair is in bounds; otherwise *all* pairs are
/// remapped in first-appearance order onto slot `k` →
/// `(k % n_sms, k / n_sms)` (the same round-robin rasterization the
/// generators use). Pairs are unique by construction (first-appearance
/// grouping), so no two tasks ever collide on one warp slot.
pub fn place(tasks: Vec<((u16, u16), Vec<WarpOp>)>, cfg: &SimConfig) -> Result<Vec<WarpTask>> {
    let slots = cfg.n_sms as usize * cfg.warps_per_sm as usize;
    anyhow::ensure!(
        tasks.len() <= slots,
        "trace has {} distinct (sm, warp) streams but the machine has only {slots} warp slots \
         ({} SMs × {} warps)",
        tasks.len(),
        cfg.n_sms,
        cfg.warps_per_sm
    );
    let fits = tasks.iter().all(|((sm, warp), _)| *sm < cfg.n_sms && *warp < cfg.warps_per_sm);
    Ok(tasks
        .into_iter()
        .enumerate()
        .map(|(k, ((sm, warp), ops))| {
            let (sm, warp) = if fits {
                (sm, warp)
            } else {
                ((k % cfg.n_sms as usize) as u16, (k / cfg.n_sms as usize) as u16)
            };
            WarpTask { sm, warp, ops }
        })
        .collect())
}

/// Serialize a workload in the canonical trace format. Parsing the
/// result back (and placing it on the same machine) reproduces
/// `wl.tasks` exactly — the round-trip contract
/// `rust/tests/workload_sources.rs` pins.
pub fn write_workload_trace(wl: &WorkloadInstance, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    let mut out = BufWriter::with_capacity(1 << 20, file);
    writeln!(out, "# uvm_prefetch workload trace: {}", COLUMNS.join(" "))?;
    writeln!(out, "-trace version = {TRACE_VERSION}")?;
    writeln!(out, "-workload name = {}", wl.name)?;
    for t in &wl.tasks {
        for op in &t.ops {
            writeln!(
                out,
                "{:#x} {} {} {} {:#x} {} {} {} {}",
                op.access.pc,
                t.sm,
                t.warp,
                op.cta,
                op.access.vaddr,
                op.access.is_store as u8,
                op.compute,
                op.kernel_id,
                op.access.array_id
            )?;
        }
    }
    out.flush()?;
    Ok(())
}

/// One `manifest.json` entry — a cached, normalized trace.
#[derive(Debug, Clone)]
pub struct TraceManifestEntry {
    /// Bare name; registers as `trace:<name>`.
    pub name: String,
    /// Cached canonical trace file, relative to the trace dir.
    pub file: String,
    pub records: u64,
    pub tasks: u64,
    pub footprint_pages: u64,
}

/// Load a trace dir's manifest; a missing file is an empty manifest
/// (the dir just hasn't been ingested into yet).
pub fn load_manifest(dir: &Path) -> Result<Vec<TraceManifestEntry>> {
    let path = dir.join("manifest.json");
    if !path.exists() {
        return Ok(Vec::new());
    }
    let j = Json::parse_file(&path)?;
    let schema = j.get("schema").and_then(Json::as_str).unwrap_or("");
    anyhow::ensure!(
        schema == MANIFEST_SCHEMA,
        "{}: schema '{schema}' (expected {MANIFEST_SCHEMA})",
        path.display()
    );
    let need = |e: &Json, k: &str| -> Result<Json> {
        e.get(k).cloned().ok_or_else(|| anyhow!("{}: trace entry missing '{k}'", path.display()))
    };
    let mut out = Vec::new();
    for e in j.get("traces").and_then(Json::as_arr).unwrap_or(&[]) {
        out.push(TraceManifestEntry {
            name: need(e, "name")?
                .as_str()
                .ok_or_else(|| anyhow!("{}: 'name' must be a string", path.display()))?
                .to_string(),
            file: need(e, "file")?
                .as_str()
                .ok_or_else(|| anyhow!("{}: 'file' must be a string", path.display()))?
                .to_string(),
            records: need(e, "records")?.as_u64().unwrap_or(0),
            tasks: need(e, "tasks")?.as_u64().unwrap_or(0),
            footprint_pages: need(e, "footprint_pages")?.as_u64().unwrap_or(0),
        });
    }
    Ok(out)
}

fn save_manifest(dir: &Path, entries: &[TraceManifestEntry]) -> Result<()> {
    let traces = entries.iter().map(|e| {
        Json::obj(vec![
            ("name", Json::str(&e.name)),
            ("file", Json::str(&e.file)),
            ("records", Json::Num(e.records as f64)),
            ("tasks", Json::Num(e.tasks as f64)),
            ("footprint_pages", Json::Num(e.footprint_pages as f64)),
        ])
    });
    Json::obj(vec![("schema", Json::str(MANIFEST_SCHEMA)), ("traces", Json::arr(traces))])
        .write_file(&dir.join("manifest.json"))
}

/// What `repro trace ingest` reports per file.
#[derive(Debug)]
pub struct IngestReport {
    /// Bare trace name (registers as `trace:<name>`).
    pub name: String,
    /// Cached canonical trace path.
    pub cached: PathBuf,
    pub records: u64,
    pub tasks: u64,
    pub ops: u64,
    pub footprint_pages: u64,
}

/// Ingest one trace file: streaming parse → placement normalization
/// against `cfg` → canonical cache file under `trace_dir` → manifest
/// update (re-ingesting a name replaces its entry). The manifest is
/// kept name-sorted so registry order is stable across re-ingests.
pub fn ingest(
    file: &Path,
    trace_dir: &Path,
    name_override: Option<&str>,
    cfg: &SimConfig,
) -> Result<IngestReport> {
    let parsed = parse_trace_file(file)?;
    let mut name = name_override.map(|s| s.to_string()).unwrap_or(parsed.name);
    if let Some(bare) = name.strip_prefix(TRACE_PREFIX) {
        // Re-ingesting a cached trace must not stack prefixes.
        name = bare.to_string();
    }
    anyhow::ensure!(
        !name.is_empty()
            && name.chars().all(|c| c.is_ascii_alphanumeric() || "-_.".contains(c)),
        "trace name '{name}' (use letters, digits, '-', '_', '.'; override with --name)"
    );
    let tasks = place(parsed.tasks, cfg)?;
    let total_ops: u64 = tasks.iter().map(|t| t.ops.len() as u64).sum();
    let wl = WorkloadInstance { name: format!("{TRACE_PREFIX}{name}"), tasks, total_ops };

    std::fs::create_dir_all(trace_dir)
        .map_err(|e| anyhow!("{}: {e}", trace_dir.display()))?;
    let file_name = format!("{name}.trace");
    let cached = trace_dir.join(&file_name);
    write_workload_trace(&wl, &cached)?;

    let mut entries = load_manifest(trace_dir)?;
    entries.retain(|e| e.name != name);
    entries.push(TraceManifestEntry {
        name: name.clone(),
        file: file_name,
        records: parsed.records,
        tasks: wl.tasks.len() as u64,
        footprint_pages: wl.footprint_pages(),
    });
    entries.sort_by(|a, b| a.name.cmp(&b.name));
    save_manifest(trace_dir, &entries)?;

    Ok(IngestReport {
        name,
        cached,
        records: parsed.records,
        tasks: wl.tasks.len() as u64,
        ops: total_ops,
        footprint_pages: wl.footprint_pages(),
    })
}

/// A cached ingested trace, replayed verbatim: `seed` and `scale` are
/// ignored by design (a recorded stream has fixed content — that is
/// also what makes trace cells trivially byte-deterministic).
pub struct TraceSource {
    name: String,
    path: PathBuf,
}

impl WorkloadSource for TraceSource {
    fn name(&self) -> &str {
        &self.name
    }
    fn family(&self) -> WorkloadFamily {
        WorkloadFamily::Trace
    }
    fn build(&self, cfg: &SimConfig, _seed: u64, _scale: f64) -> Result<WorkloadInstance> {
        let parsed = parse_trace_file(&self.path)?;
        let tasks = place(parsed.tasks, cfg)?;
        let total_ops: u64 = tasks.iter().map(|t| t.ops.len() as u64).sum();
        Ok(WorkloadInstance { name: self.name.clone(), tasks, total_ops })
    }
}

/// Trace sources recorded in `dir`'s manifest, in manifest order.
pub fn trace_sources(dir: &Path) -> Result<Vec<TraceSource>> {
    Ok(load_manifest(dir)?
        .into_iter()
        .map(|e| TraceSource {
            name: format!("{TRACE_PREFIX}{}", e.name),
            path: dir.join(&e.file),
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TestDir;

    fn write(path: &Path, text: &str) {
        std::fs::write(path, text).unwrap();
    }

    #[test]
    fn parses_minimal_and_full_records() {
        let dir = TestDir::new();
        let p = dir.file("t.trace");
        write(
            &p,
            "# comment\n-trace version = 1\n0x1000 0 0 0 0x40000000\n\
             0x1008 0 1 0 0x40001000 1 3 2 7\n",
        );
        let t = parse_trace_file(&p).unwrap();
        assert_eq!(t.records, 2);
        assert_eq!(t.tasks.len(), 2, "two (sm, warp) streams");
        let op = &t.tasks[1].1[0];
        assert!(op.access.is_store);
        assert_eq!(op.compute, 3);
        assert_eq!(op.kernel_id, 2);
        assert_eq!(op.access.array_id, 7);
        let first = &t.tasks[0].1[0];
        assert!(!first.access.is_store, "store defaults to 0");
        assert_eq!(first.compute, 1, "compute defaults to 1");
        assert_eq!(first.access.array_id, u8::MAX, "array defaults to unknown");
    }

    #[test]
    fn errors_carry_file_line_and_column() {
        let dir = TestDir::new();
        let p = dir.file("bad.trace");
        write(&p, "0x1000 0 0 0 0x40000000\n0x1008 zz 0 0 0x40001000\n");
        let err = parse_trace_file(&p).unwrap_err().to_string();
        assert!(err.contains("bad.trace"), "{err}");
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("column 2 (sm)"), "{err}");

        write(&p, "0x1000 0 0\n");
        let err = parse_trace_file(&p).unwrap_err().to_string();
        assert!(err.contains("line 1") && err.contains("at least 5 fields"), "{err}");

        write(&p, "0x1000 99999 0 0 0x40000000\n");
        let err = parse_trace_file(&p).unwrap_err().to_string();
        assert!(err.contains("exceeds max"), "{err}");
    }

    #[test]
    fn empty_trace_rejected() {
        let dir = TestDir::new();
        let p = dir.file("empty.trace");
        write(&p, "# nothing here\n");
        assert!(parse_trace_file(&p).unwrap_err().to_string().contains("no trace records"));
    }

    #[test]
    fn placement_keeps_in_bounds_pairs_and_remaps_oversized() {
        let cfg = SimConfig::default();
        let op = WarpOp {
            compute: 1,
            access: MemAccess { pc: 1, vaddr: 4096, array_id: 0, is_store: false },
            cta: 0,
            kernel_id: 0,
        };
        let fit = place(vec![((3, 5), vec![op])], &cfg).unwrap();
        assert_eq!((fit[0].sm, fit[0].warp), (3, 5), "in-bounds placement kept verbatim");
        // An out-of-bounds SM forces the round-robin remap.
        let moved = place(vec![((cfg.n_sms + 7, 5), vec![op]), ((0, 1), vec![op])], &cfg).unwrap();
        assert_eq!((moved[0].sm, moved[0].warp), (0, 0));
        assert_eq!((moved[1].sm, moved[1].warp), (1, 0));
    }

    #[test]
    fn ingest_writes_cache_and_manifest_and_replaces() {
        let dir = TestDir::new();
        let src = dir.file("app.trace");
        write(&src, "0x10 0 0 0 0x40000000\n0x18 0 0 0 0x40001000\n");
        let cfg = SimConfig::default();
        let r = ingest(&src, &dir.path().join("cache"), None, &cfg).unwrap();
        assert_eq!(r.name, "app");
        assert_eq!((r.records, r.ops, r.tasks), (2, 2, 1));
        let m = load_manifest(&dir.path().join("cache")).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].footprint_pages, 2);
        // Re-ingest under the same name replaces, not duplicates.
        ingest(&src, &dir.path().join("cache"), Some("app"), &cfg).unwrap();
        assert_eq!(load_manifest(&dir.path().join("cache")).unwrap().len(), 1);
        // The cached file parses back through the registered source.
        let srcs = trace_sources(&dir.path().join("cache")).unwrap();
        assert_eq!(srcs.len(), 1);
        assert_eq!(srcs[0].name(), "trace:app");
        let wl = srcs[0].build(&cfg, 0, 1.0).unwrap();
        assert_eq!(wl.total_ops, 2);
        assert_eq!(wl.name, "trace:app");
    }

    #[test]
    fn trace_gen_csv_layout_autodetected() {
        let dir = TestDir::new();
        let p = dir.file("gen.csv");
        write(
            &p,
            &format!("{TRACE_HEADER}\n5,4096,262144,1,2,3,0,0,1,1\n9,4104,262145,1,2,3,0,0,1,0\n"),
        );
        let t = parse_trace_file(&p).unwrap();
        assert_eq!(t.records, 2);
        assert_eq!(t.tasks.len(), 1);
        assert_eq!(t.tasks[0].0, (1, 2));
        assert_eq!(t.tasks[0].1[0].access.vaddr, 262144 << 12);
    }
}
