//! Pathfinder (Rodinia) — grid dynamic programming: row `r+1`'s cost
//! is computed from row `r`, one full row per kernel iteration.
//!
//! Per-warp, the pattern is streaming within a row followed by a huge
//! constant jump (`cols * 4` bytes) at each row switch — hot sets are
//! disjoint across iterations, which is why the tree prefetcher's hit
//! rate collapses (Table 10: 0.59) while the learned policy, which can
//! represent the row-stride delta, reaches 0.99.

use super::common::{pc, Builder, COALESCE_BYTES};
use super::WorkloadInstance;

pub fn build(mut b: Builder) -> WorkloadInstance {
    let cols = b.scaled(512 * 1024, 32 * b.n_workers() as u64);
    let rows = 16u64;
    let wall = b.alloc(rows * cols * 4); // 20 MB at default scale
    let result = b.alloc(cols * 4);

    for iter in 0..rows - 1 {
        let k = iter as u16;
        for (worker, (g0, groups)) in b.split(cols * 4 / COALESCE_BYTES).into_iter().enumerate() {
            let cta = (worker / 4) as u32;
            for g in g0..g0 + groups {
                let off = g * COALESCE_BYTES;
                // Read the next wall row, read+write the running result.
                b.load(worker, pc(0, 0), &wall, (iter + 1) * cols * 4 + off, 1, cta, k);
                b.load(worker, pc(0, 1), &result, off, 1, cta, k);
                b.store(worker, pc(0, 2), &result, off, 2, cta, k);
            }
        }
    }
    b.finish("pathfinder")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::types::page_of;
    use crate::workloads::common::Builder;

    #[test]
    fn row_switch_jumps_by_row_stride() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.1));
        let t = &wl.tasks[0];
        // Wall accesses within one iteration are contiguous; across
        // iterations they jump by cols*4 bytes.
        let wall_pages: Vec<u64> = t
            .ops
            .iter()
            .filter(|o| o.access.array_id == 0)
            .map(|o| page_of(o.access.vaddr))
            .collect();
        let deltas: Vec<i64> =
            wall_pages.windows(2).map(|w| w[1] as i64 - w[0] as i64).collect();
        let big_jumps = deltas.iter().filter(|&&d| d > 1).count();
        assert!(big_jumps >= 1, "at least one row-switch jump: {deltas:?}");
        // All big jumps are the same magnitude (constant row stride).
        let firsts: Vec<i64> = deltas.iter().copied().filter(|&d| d > 1).collect();
        assert!(firsts.windows(2).all(|w| w[0] == w[1]), "{firsts:?}");
    }
}
