//! AddVectors — the canonical streaming kernel (`C[i] = A[i] + B[i]`).
//!
//! Every warp owns a contiguous element range and walks it in 128-byte
//! coalesced steps, touching A, B and C in lockstep. Per-cluster page
//! deltas are dominated by the ±array-spacing jumps and the +1-page
//! stride every 32 steps — the regular, highly-learnable pattern
//! behind the paper's 0.98 f1 (Table 1).

use super::common::{pc, Builder, COALESCE_BYTES};
use super::WorkloadInstance;

pub fn build(mut b: Builder) -> WorkloadInstance {
    // 4M floats per array = 16 MB × 3 arrays.
    let n = b.scaled(4 * 1024 * 1024, 32 * b.n_workers() as u64);
    let a = b.alloc(n * 4);
    let bb = b.alloc(n * 4);
    let c = b.alloc(n * 4);

    let ranges = b.split(n * 4 / COALESCE_BYTES);
    for (worker, (start, len)) in ranges.into_iter().enumerate() {
        let cta = (worker / 4) as u32;
        for g in start..start + len {
            let off = g * COALESCE_BYTES;
            b.load(worker, pc(0, 0), &a, off, 2, cta, 0);
            b.load(worker, pc(0, 1), &bb, off, 2, cta, 0);
            b.store(worker, pc(0, 2), &c, off, 3, cta, 0);
        }
    }
    b.finish("addvectors")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::workloads::common::Builder;

    #[test]
    fn streams_are_contiguous_per_array() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.1));
        let t = &wl.tasks[0];
        // Accesses to array 0 must advance by exactly 128 bytes.
        let a0: Vec<u64> =
            t.ops.iter().filter(|o| o.access.array_id == 0).map(|o| o.access.vaddr).collect();
        for w in a0.windows(2) {
            assert_eq!(w[1] - w[0], 128);
        }
        assert!(a0.len() > 10);
    }

    #[test]
    fn three_arrays_interleaved() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.1));
        let ids: Vec<u8> =
            wl.tasks[0].ops.iter().take(6).map(|o| o.access.array_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 0, 1, 2]);
    }
}
