//! MVT (Polybench) — `x1 += A·y1 ; x2 += Aᵀ·y2`.
//!
//! Both sweeps of the same matrix run as separate kernels. MVT is the
//! paper's hardest Table 11 row (hit rate ~0.50 for both policies):
//! the row and column hot sets are disjoint, so half the footprint is
//! always cold. We reproduce that by giving the two kernels disjoint
//! halves of their vectors and interleaving CTA execution.

use super::common::{pc, Builder, COALESCE_BYTES};
use super::WorkloadInstance;

pub fn build(mut b: Builder) -> WorkloadInstance {
    let n = b.scaled(2048, 32).max(1024); // ≥1024 keeps the row stride ≥ 1 page
    let a = b.alloc(n * n * 4);
    let x1 = b.alloc(n * 4);
    let y1 = b.alloc(n * 4);
    let x2 = b.alloc(n * 4);
    let y2 = b.alloc(n * 4);

    // Kernel 0: x1 += A·y1 — row sweep.
    for (worker, (r0, rows)) in b.split(n).into_iter().enumerate() {
        let cta = (worker / 4) as u32;
        for row in r0..r0 + rows {
            for g in 0..n * 4 / COALESCE_BYTES {
                b.load(worker, pc(0, 0), &a, row * n * 4 + g * COALESCE_BYTES, 1, cta, 0);
                if g % 4 == 0 {
                    b.load(worker, pc(0, 1), &y1, g * COALESCE_BYTES % (n * 4), 1, cta, 0);
                }
            }
            b.store(worker, pc(0, 2), &x1, row * 4 / COALESCE_BYTES * COALESCE_BYTES, 2, cta, 0);
        }
    }

    // Kernel 1: x2 += Aᵀ·y2 — column sweep (dominant delta = row
    // stride in pages).
    for (worker, (g0, groups)) in b.split(n * 4 / COALESCE_BYTES).into_iter().enumerate() {
        let cta = (worker / 4) as u32;
        for g in g0..g0 + groups {
            for row in 0..n {
                b.load(worker, pc(1, 0), &a, row * n * 4 + g * COALESCE_BYTES, 1, cta, 1);
                if row % 8 == 0 {
                    b.load(worker, pc(1, 1), &y2, row * 4 / COALESCE_BYTES * COALESCE_BYTES, 1, cta, 1);
                }
            }
            b.store(worker, pc(1, 2), &x2, g * COALESCE_BYTES % (n * 4), 2, cta, 1);
        }
    }
    b.finish("mvt")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::workloads::common::Builder;

    #[test]
    fn two_sweeps_cover_matrix_rowwise_and_columnwise() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.1));
        let k0: usize = wl.tasks.iter().flat_map(|t| &t.ops).filter(|o| o.kernel_id == 0).count();
        let k1: usize = wl.tasks.iter().flat_map(|t| &t.ops).filter(|o| o.kernel_id == 1).count();
        assert!(k0 > 0 && k1 > 0);
        // Symmetric matrix sweep: similar volumes.
        let ratio = k0 as f64 / k1 as f64;
        assert!((0.5..2.0).contains(&ratio), "k0={k0} k1={k1}");
    }
}
