//! BFS — frontier-driven breadth-first traversal over a seeded
//! power-law graph (UVMBench's graph-analytics family).
//!
//! Level-synchronous CSR BFS: each frontier node's warp reads its row
//! extent, then walks its edge list — `col[e]` streams sequentially,
//! but the `dist[v]` visited-check lands wherever the edge points.
//! Edge targets are hub-biased (r² sampling), so a few high-degree
//! pages stay hot while the long tail scatters across the whole `dist`
//! array: the data-dependent pattern locality-based prefetchers cannot
//! anticipate. Unreachable components restart the frontier (forest
//! traversal), so every node is expanded exactly once.

use super::common::{pc, Builder};
use super::WorkloadInstance;

pub fn build(mut b: Builder) -> WorkloadInstance {
    let n = b.scaled(65_536, 32);
    let deg_cap = 64.min(n / 4).max(1);

    // Power-law out-degrees (heavy tail, clamped): sum = edge count m.
    let mut degrees = Vec::with_capacity(n as usize);
    let mut m = 0u64;
    for _ in 0..n {
        let u = b.rng.unit();
        let d = ((1.0 / (1.0 - u * 0.999)).powf(1.3) as u64).clamp(1, deg_cap);
        degrees.push(d);
        m += d;
    }
    let mut starts = Vec::with_capacity(n as usize);
    let mut s = 0u64;
    for &d in &degrees {
        starts.push(s);
        s += d;
    }
    // Hub-biased edge targets: r² sampling concentrates in-edges on
    // low-numbered nodes (the "hubs") with a scattered tail.
    let mut adj = Vec::with_capacity(m as usize);
    for _ in 0..m {
        let r = b.rng.unit();
        adj.push(((r * r * n as f64) as u64).min(n - 1));
    }

    let row = b.alloc((n + 1) * 4); // CSR row extents
    let col = b.alloc(m * 4); // edge targets
    let dist = b.alloc(n * 4); // BFS level per node
    let frontier = b.alloc(n * 4); // next-frontier append buffer

    let n_workers = b.n_workers();
    let mut visited = vec![false; n as usize];
    let mut current: Vec<u64> = vec![0];
    visited[0] = true;
    let mut next: Vec<u64> = Vec::new();
    let mut appended = 0u64; // frontier write cursor (wraps)
    let mut restart_from = 1usize; // forward-only forest-restart scan

    loop {
        if current.is_empty() {
            // Forest restart: seed the next unvisited node. The scan
            // cursor only moves forward, so restarts are O(n) total.
            while restart_from < n as usize && visited[restart_from] {
                restart_from += 1;
            }
            if restart_from >= n as usize {
                break;
            }
            visited[restart_from] = true;
            current.push(restart_from as u64);
        }
        next.clear();
        for (i, &u) in current.iter().enumerate() {
            let worker = i % n_workers;
            let cta = (worker / 4) as u32;
            b.load(worker, pc(0, 0), &row, u * 4, 2, cta, 0);
            let (e0, d) = (starts[u as usize], degrees[u as usize]);
            for e in e0..e0 + d {
                let v = adj[e as usize];
                b.load(worker, pc(0, 1), &col, e * 4, 1, cta, 0);
                // The visited-check is the scattered, data-dependent read.
                b.load(worker, pc(0, 2), &dist, v * 4, 1, cta, 0);
                if !visited[v as usize] {
                    visited[v as usize] = true;
                    b.store(worker, pc(0, 3), &dist, v * 4, 1, cta, 0);
                    b.store(worker, pc(0, 4), &frontier, (appended % n) * 4, 1, cta, 0);
                    appended += 1;
                    next.push(v);
                }
            }
        }
        std::mem::swap(&mut current, &mut next);
    }
    b.finish("bfs")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::types::page_of;
    use crate::workloads::common::Builder;
    use std::collections::HashSet;

    #[test]
    fn expands_every_node_exactly_once() {
        let cfg = SimConfig::default();
        let wl = super::build(Builder::new(&cfg, 1, 0.05));
        // One row-extent read per node expansion; node count = scaled n.
        let expansions: u64 = wl
            .tasks
            .iter()
            .flat_map(|t| t.ops.iter())
            .filter(|o| o.access.pc == crate::workloads::common::pc(0, 0))
            .count() as u64;
        let n = Builder::new(&cfg, 1, 0.05).scaled(65_536, 32);
        assert_eq!(expansions, n);
    }

    #[test]
    fn visited_checks_scatter_across_pages() {
        let wl = super::build(Builder::new(&SimConfig::default(), 3, 0.5));
        let site = crate::workloads::common::pc(0, 2);
        let mut deltas = HashSet::new();
        for t in &wl.tasks {
            let pages: Vec<u64> = t
                .ops
                .iter()
                .filter(|o| o.access.pc == site)
                .map(|o| page_of(o.access.vaddr))
                .collect();
            for w in pages.windows(2) {
                deltas.insert(w[1] as i64 - w[0] as i64);
            }
        }
        // A frontier traversal has no dominant stride — the delta
        // vocabulary is wide (contrast atax's >90% single delta).
        assert!(deltas.len() > 8, "only {} distinct deltas", deltas.len());
    }
}
