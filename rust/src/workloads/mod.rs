//! Benchmark workload generators and the workload-source registry.
//!
//! The paper evaluates 11 memory-intensive kernels from Rodinia,
//! Polybench and Lonestar, run under UVM on GPGPU-Sim (§7.1). We have
//! no CUDA toolchain or GPGPU-Sim here, so each benchmark is
//! reimplemented as a *page-access-pattern generator*: the per-warp
//! sequence of coalesced device-memory accesses the kernel's loop nest
//! produces, at the same granularity the GMMU observes. That sequence
//! — (PC, SM, warp, CTA, page) tuples — is everything the paper's
//! predictors ever see (Figure 3), so the substitution preserves the
//! learning problem exactly (see DESIGN.md §2).
//!
//! Pattern families, matching the paper's Fig. 6 taxonomy plus the
//! UVMBench-style irregular extension (DESIGN.md §10):
//! * streaming — AddVectors, StreamTriad, 2DCONV, Pathfinder
//! * dominant-delta matvec (row/column sweeps) — ATAX, BICG, MVT
//! * stencil — Hotspot, Srad-v2
//! * wavefront — NW
//! * two-phase (disjoint hot sets between kernels) — Backprop
//! * irregular (data-dependent, no exploitable stride) — BFS, SpMV,
//!   hash join
//!
//! Every producer of a [`WorkloadInstance`] — the dense kernels above,
//! the irregular trio, and traces ingested by `repro trace ingest` —
//! is a [`WorkloadSource`] looked up by name in a [`WorkloadRegistry`]
//! (see [`registry`]); the eval axes query the registry rather than a
//! closed name list.

pub mod addvectors;
pub mod atax;
pub mod backprop;
pub mod bfs;
pub mod bicg;
pub mod common;
pub mod conv2d;
pub mod hash_join;
pub mod hotspot;
pub mod mvt;
pub mod nw;
pub mod pathfinder;
pub mod registry;
pub mod spmv;
pub mod srad_v2;
pub mod streamtriad;
pub mod trace;

pub use registry::{source_tag, WorkloadFamily, WorkloadRegistry, WorkloadSource};

use crate::sim::sm::WarpOp;
use crate::types::{page_of, SmId, WarpId};

/// One warp's full instruction stream, placed on an (SM, warp) slot.
#[derive(Debug, PartialEq)]
pub struct WarpTask {
    pub sm: SmId,
    pub warp: WarpId,
    pub ops: Vec<WarpOp>,
}

/// A generated workload ready to load into the simulator.
#[derive(Debug, PartialEq)]
pub struct WorkloadInstance {
    pub name: String,
    pub tasks: Vec<WarpTask>,
    pub total_ops: u64,
}

impl WorkloadInstance {
    /// Total memory instructions across all warps.
    pub fn n_accesses(&self) -> u64 {
        self.tasks.iter().map(|t| t.ops.len() as u64).sum()
    }

    /// Total instructions (compute + memory).
    pub fn n_instructions(&self) -> u64 {
        self.tasks
            .iter()
            .flat_map(|t| t.ops.iter())
            .map(|op| op.compute as u64 + 1)
            .sum()
    }

    /// Distinct 4 KB pages the workload touches — the footprint the
    /// oversubscription ratio (`SimConfig::oversub_ratio`) is a
    /// fraction of. One full pass over the op streams; only computed
    /// for oversubscribed runs.
    pub fn footprint_pages(&self) -> u64 {
        let mut pages = std::collections::HashSet::new();
        for t in &self.tasks {
            for op in &t.ops {
                pages.insert(page_of(op.access.vaddr));
            }
        }
        pages.len() as u64
    }
}

/// Canonical dense benchmark list (paper §7, Tables 10/11 rows).
#[deprecated(note = "query WorkloadRegistry::builtin().family(WorkloadFamily::Dense) instead")]
pub const ALL_BENCHMARKS: &[&str] = &[
    "addvectors",
    "atax",
    "backprop",
    "bicg",
    "hotspot",
    "mvt",
    "nw",
    "pathfinder",
    "srad_v2",
    "streamtriad",
    "conv2d",
];

/// The 9 benchmarks used in the model-quality tables (Tables 1–8).
#[deprecated(note = "query WorkloadRegistry::builtin().model() instead")]
pub const MODEL_BENCHMARKS: &[&str] = &[
    "addvectors",
    "atax",
    "backprop",
    "bicg",
    "hotspot",
    "mvt",
    "nw",
    "pathfinder",
    "srad_v2",
];

/// Build a benchmark by name. `scale` multiplies the problem size
/// (1.0 = default sizes tuned for minutes-long full-suite runs);
/// `seed` feeds input-dependent components.
#[deprecated(note = "use WorkloadRegistry::builtin().build(...) (or with_trace_dir for traces)")]
pub fn build(
    name: &str,
    cfg: &crate::config::SimConfig,
    seed: u64,
    scale: f64,
) -> anyhow::Result<WorkloadInstance> {
    WorkloadRegistry::builtin().build(name, cfg, seed, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn registry() -> WorkloadRegistry {
        WorkloadRegistry::builtin()
    }

    #[test]
    fn all_benchmarks_build_and_are_nonempty() {
        let cfg = SimConfig::default();
        let r = registry();
        for name in r.all() {
            let wl = r.build(name, &cfg, 1, 0.1).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(wl.n_accesses() > 100, "{name} has {} accesses", wl.n_accesses());
            assert!(!wl.tasks.is_empty(), "{name}");
            // Every task placed within the machine.
            for t in &wl.tasks {
                assert!(t.sm < cfg.n_sms, "{name}");
                assert!(t.warp < cfg.warps_per_sm, "{name}");
            }
        }
    }

    #[test]
    fn footprint_counts_distinct_pages() {
        let cfg = SimConfig::default();
        let wl = registry().build("addvectors", &cfg, 1, 0.1).unwrap();
        let fp = wl.footprint_pages();
        assert!(fp > 0 && fp <= wl.n_accesses(), "footprint {fp} bounded by accesses");
        assert_eq!(fp, wl.footprint_pages(), "pure function of the instance");
    }

    #[test]
    fn unknown_benchmark_errors() {
        assert!(registry().build("nope", &SimConfig::default(), 0, 1.0).is_err());
    }

    #[test]
    fn deterministic_generation() {
        let cfg = SimConfig::default();
        let r = registry();
        let a = r.build("atax", &cfg, 7, 0.1).unwrap();
        let b = r.build("atax", &cfg, 7, 0.1).unwrap();
        assert_eq!(a.n_accesses(), b.n_accesses());
        let pa: Vec<u64> = a.tasks[0].ops.iter().map(|o| o.access.vaddr).collect();
        let pb: Vec<u64> = b.tasks[0].ops.iter().map(|o| o.access.vaddr).collect();
        assert_eq!(pa, pb);
    }

    #[test]
    fn benchmarks_use_distinct_address_regions_per_array() {
        let cfg = SimConfig::default();
        let wl = registry().build("addvectors", &cfg, 0, 0.1).unwrap();
        // Three arrays → accesses must span ≥ 3 distinct 1 GB regions.
        use std::collections::HashSet;
        let regions: HashSet<u64> = wl
            .tasks
            .iter()
            .flat_map(|t| t.ops.iter())
            .map(|o| o.access.vaddr >> 30)
            .collect();
        assert!(regions.len() >= 3, "regions: {regions:?}");
    }

    /// The deprecated shims must stay behaviourally identical to the
    /// registry for one release so pinned goldens keep their meaning.
    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_match_registry() {
        let cfg = SimConfig::default();
        let r = registry();
        assert_eq!(ALL_BENCHMARKS.to_vec(), r.family(WorkloadFamily::Dense));
        assert_eq!(MODEL_BENCHMARKS.to_vec(), r.model());
        let a = build("atax", &cfg, 7, 0.1).unwrap();
        let b = r.build("atax", &cfg, 7, 0.1).unwrap();
        assert_eq!(a, b, "shim build() must stay registry-identical");
    }
}
