//! StreamTriad — the STREAM benchmark's triad kernel
//! (`A[i] = B[i] + s * C[i]`), the second pure-streaming workload of
//! the paper's evaluation set (Table 10/11 only — not in the model
//! tables). Larger working set than AddVectors, same structure.

use super::common::{pc, Builder, COALESCE_BYTES};
use super::WorkloadInstance;

pub fn build(mut b: Builder) -> WorkloadInstance {
    // 6M floats per array = 24 MB × 3.
    let n = b.scaled(6 * 1024 * 1024, 32 * b.n_workers() as u64);
    let a = b.alloc(n * 4);
    let bb = b.alloc(n * 4);
    let c = b.alloc(n * 4);

    let ranges = b.split(n * 4 / COALESCE_BYTES);
    for (worker, (start, len)) in ranges.into_iter().enumerate() {
        let cta = (worker / 4) as u32;
        for g in start..start + len {
            let off = g * COALESCE_BYTES;
            b.load(worker, pc(0, 0), &bb, off, 2, cta, 0);
            b.load(worker, pc(0, 1), &c, off, 4, cta, 0); // fma latency
            b.store(worker, pc(0, 2), &a, off, 2, cta, 0);
        }
    }
    b.finish("streamtriad")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::workloads::common::Builder;

    #[test]
    fn loads_then_store_per_group() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.05));
        let ops = &wl.tasks[0].ops;
        assert!(!ops[0].access.is_store);
        assert!(!ops[1].access.is_store);
        assert!(ops[2].access.is_store);
    }
}
