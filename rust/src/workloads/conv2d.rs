//! 2DCONV (Polybench) — 3×3 convolution over an N×N image.
//!
//! Streaming stencil: each output row reads three input rows. Appears
//! only in the system-level tables (Table 10/11, Figs 10/12), like
//! StreamTriad.

use super::common::{pc, Builder, COALESCE_BYTES};
use super::WorkloadInstance;

pub fn build(mut b: Builder) -> WorkloadInstance {
    let n = b.scaled(2048, 32);
    let input = b.alloc(n * n * 4);
    let output = b.alloc(n * n * 4);
    let row = n * 4;

    // Polybench drives the kernel from a timing loop — 3 invocations.
    for rep in 0..3u16 {
        for (worker, (r0, rows)) in b.split(n).into_iter().enumerate() {
            let cta = (worker / 4) as u32;
            for r in r0..r0 + rows {
                let rm = r.saturating_sub(1);
                let rp = (r + 1).min(n - 1);
                for g in 0..row / COALESCE_BYTES {
                    let off = g * COALESCE_BYTES;
                    b.load(worker, pc(rep, 0), &input, rm * row + off, 1, cta, rep);
                    b.load(worker, pc(rep, 1), &input, r * row + off, 1, cta, rep);
                    b.load(worker, pc(rep, 2), &input, rp * row + off, 2, cta, rep);
                    b.store(worker, pc(rep, 3), &output, r * row + off, 3, cta, rep);
                }
            }
        }
    }
    b.finish("conv2d")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::workloads::common::Builder;

    #[test]
    fn reads_three_input_rows_per_output() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.1));
        let loads: usize = wl
            .tasks
            .iter()
            .flat_map(|t| &t.ops)
            .filter(|o| !o.access.is_store)
            .count();
        let stores: usize = wl
            .tasks
            .iter()
            .flat_map(|t| &t.ops)
            .filter(|o| o.access.is_store)
            .count();
        assert_eq!(loads, stores * 3);
    }
}
