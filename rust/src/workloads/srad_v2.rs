//! SRAD v2 (Rodinia) — speckle-reducing anisotropic diffusion over an
//! N×N image: kernel 0 computes the diffusion coefficient `c` from the
//! image's 4-neighborhood; kernel 1 updates the image from `c`'s
//! neighborhood. Two iterations.
//!
//! A two-kernel stencil with a large array count (image + coefficient
//! + 4 derivative planes) — per-cluster sequences interleave seven
//! address streams, rewarding the attention model (Table 8: 0.97 f1).

use super::common::{pc, Builder, COALESCE_BYTES};
use super::WorkloadInstance;

pub fn build(mut b: Builder) -> WorkloadInstance {
    let n = b.scaled(1024, 32);
    let image = b.alloc(n * n * 4);
    let c = b.alloc(n * n * 4);
    let dn = b.alloc(n * n * 4);
    let ds = b.alloc(n * n * 4);
    let row = n * 4;

    for iter in 0..4u64 {
        // Kernel 0 (srad_cuda_1): read J's neighborhood, write c + dN/dS.
        let k0 = (iter * 2) as u16;
        for (worker, (r0, rows)) in b.split(n).into_iter().enumerate() {
            let cta = (worker / 4) as u32;
            for r in r0..r0 + rows {
                let rm = r.saturating_sub(1);
                let rp = (r + 1).min(n - 1);
                for g in 0..row / COALESCE_BYTES {
                    let off = g * COALESCE_BYTES;
                    b.load(worker, pc(k0, 0), &image, r * row + off, 1, cta, k0);
                    b.load(worker, pc(k0, 1), &image, rm * row + off, 1, cta, k0);
                    b.load(worker, pc(k0, 2), &image, rp * row + off, 1, cta, k0);
                    b.store(worker, pc(k0, 3), &dn, r * row + off, 1, cta, k0);
                    b.store(worker, pc(k0, 4), &ds, r * row + off, 1, cta, k0);
                    b.store(worker, pc(k0, 5), &c, r * row + off, 2, cta, k0);
                }
            }
        }
        // Kernel 1 (srad_cuda_2): read c's neighborhood + dN/dS, update J.
        let k1 = (iter * 2 + 1) as u16;
        for (worker, (r0, rows)) in b.split(n).into_iter().enumerate() {
            let cta = (worker / 4) as u32;
            for r in r0..r0 + rows {
                let rp = (r + 1).min(n - 1);
                for g in 0..row / COALESCE_BYTES {
                    let off = g * COALESCE_BYTES;
                    b.load(worker, pc(k1, 0), &c, r * row + off, 1, cta, k1);
                    b.load(worker, pc(k1, 1), &c, rp * row + off, 1, cta, k1);
                    b.load(worker, pc(k1, 2), &dn, r * row + off, 1, cta, k1);
                    b.load(worker, pc(k1, 3), &ds, r * row + off, 1, cta, k1);
                    b.store(worker, pc(k1, 4), &image, r * row + off, 3, cta, k1);
                }
            }
        }
    }
    b.finish("srad_v2")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::workloads::common::Builder;

    #[test]
    fn eight_kernel_phases() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.1));
        let mut kernels: Vec<u16> =
            wl.tasks.iter().flat_map(|t| t.ops.iter().map(|o| o.kernel_id)).collect();
        kernels.sort();
        kernels.dedup();
        assert_eq!(kernels, vec![0, 1, 2, 3, 4, 5, 6, 7], "4 iterations x 2 kernels");
    }

    #[test]
    fn kernel1_writes_image_kernel0_writes_c() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.1));
        let stores = |k: u16| -> Vec<u8> {
            let mut v: Vec<u8> = wl
                .tasks
                .iter()
                .flat_map(|t| &t.ops)
                .filter(|o| o.kernel_id == k && o.access.is_store)
                .map(|o| o.access.array_id)
                .collect();
            v.sort();
            v.dedup();
            v
        };
        assert_eq!(stores(0), vec![1, 2, 3], "c, dN, dS");
        assert_eq!(stores(1), vec![0], "image only");
    }
}
