//! Shared machinery for the workload generators: virtual array
//! allocation, CTA/warp placement, and per-warp op-stream assembly.

use crate::config::SimConfig;
use crate::sim::sm::WarpOp;
use crate::types::{CtaId, MemAccess, SmId, VAddr, WarpId};
use crate::util::XorShift64;
use crate::workloads::{WarpTask, WorkloadInstance};

/// Coalesced access width: 32 threads × 4-byte elements.
pub const COALESCE_BYTES: u64 = 128;

/// A managed (`cudaMallocManaged`-style) array in the unified address
/// space. Arrays are placed 1 GiB apart so each lives in its own page
/// and 2 MB-chunk universe (feature `In` of Figure 3 = `id`).
#[derive(Debug, Clone, Copy)]
pub struct ManagedArray {
    pub id: u8,
    pub base: VAddr,
    pub bytes: u64,
}

impl ManagedArray {
    /// Byte address of element `idx` (4-byte elements).
    #[inline]
    pub fn elem(&self, idx: u64) -> VAddr {
        debug_assert!(idx * 4 < self.bytes, "idx {idx} out of array {}", self.id);
        self.base + idx * 4
    }
}

/// Allocates managed arrays and assembles warp programs.
pub struct Builder {
    pub n_sms: u16,
    /// Warp slots used per SM. The paper's SMs support 64 warps; the
    /// generators use 16 so each stream is long enough for 30-token
    /// windows while still exercising inter-warp interleaving.
    pub warps_used: u16,
    pub rng: XorShift64,
    pub scale: f64,
    next_base: VAddr,
    next_array: u8,
    streams: Vec<Vec<WarpOp>>,
}

impl Builder {
    pub fn new(cfg: &SimConfig, seed: u64, scale: f64) -> Self {
        let warps_used = 16.min(cfg.warps_per_sm);
        let n_workers = cfg.n_sms as usize * warps_used as usize;
        Self {
            n_sms: cfg.n_sms,
            warps_used,
            rng: XorShift64::new(seed),
            scale: scale.max(0.01),
            next_base: 1 << 30,
            next_array: 0,
            streams: (0..n_workers).map(|_| Vec::new()).collect(),
        }
    }

    /// Scale an element count, keeping it a multiple of `align`.
    pub fn scaled(&self, n: u64, align: u64) -> u64 {
        let s = ((n as f64 * self.scale) as u64).max(align);
        s / align * align
    }

    /// Allocate a managed array of `bytes` bytes.
    pub fn alloc(&mut self, bytes: u64) -> ManagedArray {
        let a = ManagedArray { id: self.next_array, base: self.next_base, bytes };
        self.next_array += 1;
        self.next_base += 1 << 30; // 1 GiB spacing
        a
    }

    pub fn n_workers(&self) -> usize {
        self.streams.len()
    }

    /// Append one op to worker `w`'s stream.
    #[inline]
    pub fn push(
        &mut self,
        worker: usize,
        pc: u64,
        addr: VAddr,
        array: &ManagedArray,
        is_store: bool,
        compute: u32,
        cta: CtaId,
        kernel_id: u16,
    ) {
        self.streams[worker].push(WarpOp {
            compute,
            access: MemAccess { pc, vaddr: addr, array_id: array.id, is_store },
            cta,
            kernel_id,
        });
    }

    /// Convenience: one coalesced load.
    #[inline]
    pub fn load(
        &mut self,
        worker: usize,
        pc: u64,
        array: &ManagedArray,
        byte_off: u64,
        compute: u32,
        cta: CtaId,
        kernel_id: u16,
    ) {
        self.push(worker, pc, array.base + byte_off, array, false, compute, cta, kernel_id);
    }

    /// Convenience: one coalesced store.
    #[inline]
    pub fn store(
        &mut self,
        worker: usize,
        pc: u64,
        array: &ManagedArray,
        byte_off: u64,
        compute: u32,
        cta: CtaId,
        kernel_id: u16,
    ) {
        self.push(worker, pc, array.base + byte_off, array, true, compute, cta, kernel_id);
    }

    /// Place worker streams on (SM, warp) slots: worker `w` lands on
    /// SM `w % n_sms`, warp slot `w / n_sms` — the round-robin CTA
    /// rasterization GPUs use.
    pub fn finish(self, name: &str) -> WorkloadInstance {
        let mut tasks = Vec::new();
        let mut total_ops = 0u64;
        for (w, ops) in self.streams.into_iter().enumerate() {
            if ops.is_empty() {
                continue;
            }
            total_ops += ops.len() as u64;
            tasks.push(WarpTask {
                sm: (w % self.n_sms as usize) as SmId,
                warp: (w / self.n_sms as usize) as WarpId,
                ops,
            });
        }
        WorkloadInstance { name: name.to_string(), tasks, total_ops }
    }

    /// Split `n_items` contiguous work items across all workers;
    /// returns per-worker `(start, len)` ranges.
    pub fn split(&self, n_items: u64) -> Vec<(u64, u64)> {
        let w = self.n_workers() as u64;
        let per = n_items / w;
        let rem = n_items % w;
        let mut out = Vec::with_capacity(w as usize);
        let mut start = 0;
        for i in 0..w {
            let len = per + u64::from(i < rem);
            out.push((start, len));
            start += len;
        }
        out
    }
}

/// Encode a PC for kernel `k`, static load/store site `site`.
#[inline]
pub fn pc(kernel: u16, site: u16) -> u64 {
    0x1000 + ((kernel as u64) << 12) + (site as u64) * 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    #[test]
    fn arrays_are_gigabyte_spaced() {
        let mut b = Builder::new(&SimConfig::default(), 0, 1.0);
        let a0 = b.alloc(1024);
        let a1 = b.alloc(1024);
        assert_eq!(a1.base - a0.base, 1 << 30);
        assert_eq!(a0.id, 0);
        assert_eq!(a1.id, 1);
    }

    #[test]
    fn split_covers_everything_exactly_once() {
        let b = Builder::new(&SimConfig::default(), 0, 1.0);
        let ranges = b.split(1000);
        let total: u64 = ranges.iter().map(|r| r.1).sum();
        assert_eq!(total, 1000);
        // Contiguous, non-overlapping.
        let mut expect = 0;
        for (s, l) in ranges {
            assert_eq!(s, expect);
            expect = s + l;
        }
    }

    #[test]
    fn finish_drops_empty_streams_and_places_in_bounds() {
        let cfg = SimConfig::default();
        let mut b = Builder::new(&cfg, 0, 1.0);
        let a = b.alloc(4096);
        b.load(3, pc(0, 0), &a, 0, 2, 0, 0);
        let wl = b.finish("t");
        assert_eq!(wl.tasks.len(), 1);
        assert_eq!(wl.tasks[0].sm, 3 % cfg.n_sms);
    }

    #[test]
    fn scaled_respects_alignment() {
        let b = Builder::new(&SimConfig::default(), 0, 0.3);
        assert_eq!(b.scaled(1000, 32) % 32, 0);
        assert!(b.scaled(10, 32) >= 32, "never below one aligned unit");
    }
}
