//! BICG (Polybench) — the BiCGStab sub-kernels `s = Aᵀ·r` and
//! `q = A·p` over the same N×M matrix.
//!
//! Mirror image of ATAX: the *column* sweep runs first, then the row
//! sweep. Another member of the paper's dominant-delta family (§5.3;
//! Table 11 BICG is the paper's §7.5 PCIe case study).

use super::common::{pc, Builder, COALESCE_BYTES};
use super::WorkloadInstance;

pub fn build(mut b: Builder) -> WorkloadInstance {
    let n = b.scaled(2048, 32).max(1024); // ≥1024 keeps the row stride ≥ 1 page
    let m = b.scaled(2048, 32).max(1024);
    let a = b.alloc(n * m * 4);
    let r = b.alloc(n * 4);
    let s = b.alloc(m * 4);
    let p = b.alloc(m * 4);
    let q = b.alloc(n * 4);

    // Kernel 0: s = Aᵀ·r — column sweep (dominant constant delta).
    for (worker, (g0, groups)) in b.split(m * 4 / COALESCE_BYTES).into_iter().enumerate() {
        let cta = (worker / 4) as u32;
        for g in g0..g0 + groups {
            for row in 0..n {
                b.load(worker, pc(0, 0), &a, row * m * 4 + g * COALESCE_BYTES, 1, cta, 0);
                if row % 8 == 0 {
                    b.load(worker, pc(0, 1), &r, row * 4 / COALESCE_BYTES * COALESCE_BYTES, 1, cta, 0);
                }
            }
            b.store(worker, pc(0, 2), &s, g * COALESCE_BYTES % (m * 4), 2, cta, 0);
        }
    }

    // Kernel 1: q = A·p — row sweep.
    for (worker, (r0, rows)) in b.split(n).into_iter().enumerate() {
        let cta = (worker / 4) as u32;
        for row in r0..r0 + rows {
            for g in 0..m * 4 / COALESCE_BYTES {
                b.load(worker, pc(1, 0), &a, row * m * 4 + g * COALESCE_BYTES, 1, cta, 1);
                if g % 4 == 0 {
                    b.load(worker, pc(1, 1), &p, g * COALESCE_BYTES % (m * 4), 1, cta, 1);
                }
            }
            b.store(worker, pc(1, 2), &q, row * 4 / COALESCE_BYTES * COALESCE_BYTES, 2, cta, 1);
        }
    }
    b.finish("bicg")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::workloads::common::Builder;

    #[test]
    fn column_sweep_runs_first() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.1));
        let first_kernel = wl.tasks[0].ops.first().unwrap().kernel_id;
        assert_eq!(first_kernel, 0);
        // Kernel 0's A accesses jump by a row stride each step.
        let a_addrs: Vec<u64> = wl.tasks[0]
            .ops
            .iter()
            .filter(|o| o.kernel_id == 0 && o.access.array_id == 0)
            .take(3)
            .map(|o| o.access.vaddr)
            .collect();
        let stride = a_addrs[1] - a_addrs[0];
        assert_eq!(a_addrs[2] - a_addrs[1], stride);
        assert!(stride >= 4096, "column sweep strides at least a page");
    }
}
