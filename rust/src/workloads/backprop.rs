//! Backprop (Rodinia) — two-layer neural-network training: a forward
//! pass (`layerforward`) and a weight update (`adjust_weights`) over a
//! `IN × HID` weight matrix.
//!
//! The canonical "disjoint hot pages between consecutive kernels"
//! workload (§1, §2.3): kernel 0 streams `input` + `w`, kernel 1
//! streams `w_delta` + `w` with a different PC set and access mix —
//! exactly the phase change that defeats locality-only prefetching
//! and that the paper's Table 10 shows the learned policy fixing
//! (hit rate 0.74 → 0.96).

use super::common::{pc, Builder, COALESCE_BYTES};
use super::WorkloadInstance;

pub fn build(mut b: Builder) -> WorkloadInstance {
    let input_n = b.scaled(256 * 1024, 1024); // input units
    let hid = 16u64;
    let input = b.alloc(input_n * 4);
    let w = b.alloc(input_n * hid * 4); // 8 MB at default scale
    let w_delta = b.alloc(input_n * hid * 4);
    let hidden = b.alloc(hid * 4);

    // Kernel 0: layerforward — each work item owns an input range;
    // per 32-input group: load the inputs, then walk the 16-wide
    // weight rows (16 × 32 × 4 B = 2 KB = 16 coalesced accesses).
    for (worker, (g0, groups)) in b.split(input_n / 32).into_iter().enumerate() {
        let cta = (worker / 4) as u32;
        for g in g0..g0 + groups {
            b.load(worker, pc(0, 0), &input, g * COALESCE_BYTES, 1, cta, 0);
            let row_base = g * 32 * hid * 4;
            for k in 0..(32 * hid * 4) / COALESCE_BYTES {
                b.load(worker, pc(0, 1), &w, row_base + k * COALESCE_BYTES, 1, cta, 0);
            }
            b.store(worker, pc(0, 2), &hidden, 0, 4, cta, 0);
        }
    }

    // Kernel 1: adjust_weights — stream w_delta and read-modify-write
    // w (different PCs, load-store mix).
    for (worker, (g0, groups)) in b.split(input_n * hid * 4 / COALESCE_BYTES).into_iter().enumerate()
    {
        let cta = (worker / 4) as u32;
        for g in g0..g0 + groups {
            let off = g * COALESCE_BYTES;
            b.load(worker, pc(1, 0), &w_delta, off, 1, cta, 1);
            b.load(worker, pc(1, 1), &w, off, 1, cta, 1);
            b.store(worker, pc(1, 2), &w, off, 2, cta, 1);
        }
    }
    b.finish("backprop")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::workloads::common::Builder;
    use std::collections::HashSet;

    #[test]
    fn kernels_have_disjoint_pc_sets() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.1));
        let pcs = |k: u16| -> HashSet<u64> {
            wl.tasks
                .iter()
                .flat_map(|t| &t.ops)
                .filter(|o| o.kernel_id == k)
                .map(|o| o.access.pc)
                .collect()
        };
        assert!(pcs(0).is_disjoint(&pcs(1)));
    }

    #[test]
    fn kernel1_touches_w_delta_never_touched_by_kernel0() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.1));
        let arrays = |k: u16| -> HashSet<u8> {
            wl.tasks
                .iter()
                .flat_map(|t| &t.ops)
                .filter(|o| o.kernel_id == k)
                .map(|o| o.access.array_id)
                .collect()
        };
        assert!(arrays(0).contains(&1), "kernel0 reads w");
        assert!(!arrays(0).contains(&2), "kernel0 never reads w_delta");
        assert!(arrays(1).contains(&2), "kernel1 streams w_delta");
    }
}
