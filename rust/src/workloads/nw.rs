//! Needleman-Wunsch (Rodinia) — dynamic-programming sequence
//! alignment, processed as anti-diagonal wavefronts of 16×16 blocks
//! over the score matrix plus a reference matrix.
//!
//! Block (bi, bj) depends on (bi-1, bj) and (bi, bj-1), so blocks on
//! the same anti-diagonal run concurrently. From a warp's point of
//! view the page deltas alternate between within-block row strides and
//! diagonal block jumps whose magnitude *changes every diagonal* —
//! order information the self-attention path genuinely needs (the
//! paper's Table 4: NW drops from 0.96 to 0.74 top-1 without it).

use super::common::{pc, Builder, COALESCE_BYTES};
use super::WorkloadInstance;

const BLOCK: u64 = 16;

pub fn build(mut b: Builder) -> WorkloadInstance {
    let n = b.scaled(1024, BLOCK * 32); // matrix side (ints)
    let items = b.alloc((n + 1) * (n + 1) * 4);
    let reference = b.alloc(n * n * 4);
    let nb = n / BLOCK; // blocks per side
    let row = (n + 1) * 4;
    let n_workers = b.n_workers() as u64;

    // Forward wavefront over anti-diagonals d = 0 .. 2*nb-2.
    for d in 0..2 * nb - 1 {
        let lo = d.saturating_sub(nb - 1);
        let hi = d.min(nb - 1);
        for (idx, bi) in (lo..=hi).enumerate() {
            let bj = d - bi;
            let worker = ((idx as u64 + d * 7) % n_workers) as usize;
            let cta = (d * nb + bi) as u32;
            // Each block: 16 rows × (score row segment + reference
            // segment + score writeback).
            for r in 0..BLOCK {
                let items_off = (bi * BLOCK + r + 1) * row + (bj * BLOCK + 1) * 4;
                let ref_off = (bi * BLOCK + r) * n * 4 + bj * BLOCK * 4;
                let seg = items_off / COALESCE_BYTES * COALESCE_BYTES;
                b.load(worker, pc(0, 0), &items, seg, 1, cta, 0);
                b.load(worker, pc(0, 1), &reference, ref_off / COALESCE_BYTES * COALESCE_BYTES, 2, cta, 0);
                b.store(worker, pc(0, 2), &items, seg, 3, cta, 0);
            }
        }
    }
    b.finish("nw")
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::types::page_of;
    use crate::workloads::common::Builder;
    use std::collections::HashMap;

    #[test]
    fn delta_alphabet_is_wide() {
        // Wavefront traversal must produce many distinct page deltas
        // (unlike the matvec benchmarks).
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.5));
        let mut counts: HashMap<i64, u64> = HashMap::new();
        for t in &wl.tasks {
            let pages: Vec<u64> =
                t.ops.iter().map(|o| page_of(o.access.vaddr)).collect();
            for w in pages.windows(2) {
                *counts.entry(w[1] as i64 - w[0] as i64).or_insert(0) += 1;
            }
        }
        let total: u64 = counts.values().sum();
        let max = counts.values().max().copied().unwrap();
        assert!(counts.len() >= 8, "only {} deltas", counts.len());
        assert!((max as f64 / total as f64) < 0.9, "no overwhelming dominant delta");
    }

    #[test]
    fn wavefront_covers_all_blocks_once() {
        let wl = super::build(Builder::new(&SimConfig::default(), 0, 0.5));
        let stores: usize =
            wl.tasks.iter().flat_map(|t| &t.ops).filter(|o| o.access.is_store).count();
        // nb² blocks × 16 rows of writeback.
        let n = Builder::new(&SimConfig::default(), 0, 0.5).scaled(1024, 16 * 32);
        let nb = n / 16;
        assert_eq!(stores as u64, nb * nb * 16);
    }
}
