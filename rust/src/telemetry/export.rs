//! Serving-plane metrics exporter: snapshot the lock-free
//! [`CoordinatorStats`] into Prometheus text exposition and a JSON
//! record (DESIGN.md §13).
//!
//! `repro serve --metrics-out PREFIX` runs a small exporter thread
//! that periodically rewrites `PREFIX.prom` (the current exposition —
//! point a Prometheus file-sd scrape or `promtool` at it) and appends
//! one JSON line per tick to `PREFIX.jsonl`. Counters are cumulative,
//! so the JSONL file *is* the per-tenant accuracy-over-time rollup:
//! successive lines differenced give per-interval rates, which is
//! exactly what ROADMAP item 4's drift detection needs.
//!
//! Snapshots are taken with relaxed loads while shards are still
//! writing — each value is internally consistent, cross-counter skew
//! of a few in-flight commands is inherent and documented (the same
//! lower-bound semantics as `dropped_commands`).

use crate::coordinator::stats::CoordinatorStats;
use crate::util::{HistSummary, Json};
use std::fmt::Write as _;
use std::sync::atomic::Ordering;

pub const SERVE_METRICS_SCHEMA: &str = "serve_metrics/v1";

fn quantiles(w: &mut String, name: &str, s: &HistSummary) {
    let _ = writeln!(w, "# TYPE {name} summary");
    for (q, v) in [("0.5", s.p50), ("0.95", s.p95), ("0.99", s.p99)] {
        let _ = writeln!(w, "{name}{{quantile=\"{q}\"}} {v}");
    }
    let _ = writeln!(w, "{name}_sum {}", (s.mean * s.n as f64).round() as u64);
    let _ = writeln!(w, "{name}_count {}", s.n);
}

/// Prometheus text exposition (format 0.0.4) of the full coordinator
/// state, per-tenant series included.
pub fn prometheus_text(stats: &CoordinatorStats, elapsed_ms: u64) -> String {
    let mut w = String::new();
    let counters: [(&str, u64); 8] = [
        ("uvm_serve_faults_total", stats.faults.load(Ordering::Relaxed)),
        ("uvm_serve_block_prefetches_total", stats.block_prefetches.load(Ordering::Relaxed)),
        ("uvm_serve_predictions_total", stats.predictions.load(Ordering::Relaxed)),
        ("uvm_serve_batches_total", stats.batches.load(Ordering::Relaxed)),
        ("uvm_serve_batched_windows_total", stats.batched_windows.load(Ordering::Relaxed)),
        ("uvm_serve_bypasses_total", stats.bypasses.load(Ordering::Relaxed)),
        ("uvm_serve_oov_total", stats.oov.load(Ordering::Relaxed)),
        ("uvm_serve_dropped_commands_total", stats.dropped_commands.load(Ordering::Relaxed)),
    ];
    for (name, v) in counters {
        let _ = writeln!(w, "# TYPE {name} counter");
        let _ = writeln!(w, "{name} {v}");
    }
    let _ = writeln!(w, "# TYPE uvm_serve_uptime_ms gauge");
    let _ = writeln!(w, "uvm_serve_uptime_ms {elapsed_ms}");
    quantiles(&mut w, "uvm_serve_e2e_latency_us", &stats.fault_to_cmd_us.summary());
    quantiles(&mut w, "uvm_serve_batch_latency_us", &stats.batch_latency_us.summary());
    quantiles(&mut w, "uvm_serve_batch_size", &stats.batch_sizes.summary());
    for metric in
        ["commands", "migrates", "predicted", "advises", "discards", "prediction_hits"]
    {
        let _ = writeln!(w, "# TYPE uvm_serve_tenant_{metric}_total counter");
        for t in 0..stats.n_tenants() {
            let ts = stats.tenant(t as u32);
            let v = match metric {
                "commands" => ts.commands.load(Ordering::Relaxed),
                "migrates" => ts.migrates.load(Ordering::Relaxed),
                "predicted" => ts.predicted.load(Ordering::Relaxed),
                "advises" => ts.advises.load(Ordering::Relaxed),
                "discards" => ts.discards.load(Ordering::Relaxed),
                _ => ts.pred_hits.load(Ordering::Relaxed),
            };
            let _ = writeln!(w, "uvm_serve_tenant_{metric}_total{{tenant=\"{t}\"}} {v}");
        }
    }
    w
}

/// One cumulative JSON snapshot (a line of the `PREFIX.jsonl` series).
pub fn snapshot_json(stats: &CoordinatorStats, elapsed_ms: u64) -> Json {
    let tenants = (0..stats.n_tenants()).map(|t| {
        let ts = stats.tenant(t as u32);
        let predicted = ts.predicted.load(Ordering::Relaxed);
        let hits = ts.pred_hits.load(Ordering::Relaxed);
        Json::obj(vec![
            ("tenant", Json::num(t as f64)),
            ("commands", Json::num(ts.commands.load(Ordering::Relaxed) as f64)),
            ("migrates", Json::num(ts.migrates.load(Ordering::Relaxed) as f64)),
            ("predicted", Json::num(predicted as f64)),
            ("advises", Json::num(ts.advises.load(Ordering::Relaxed) as f64)),
            ("discards", Json::num(ts.discards.load(Ordering::Relaxed) as f64)),
            ("prediction_hits", Json::num(hits as f64)),
            (
                "accuracy",
                Json::num(if predicted == 0 { 0.0 } else { hits as f64 / predicted as f64 }),
            ),
            ("latency_us", ts.latency_us.summary().to_json()),
        ])
    });
    Json::obj(vec![
        ("schema", Json::str(SERVE_METRICS_SCHEMA)),
        ("elapsed_ms", Json::num(elapsed_ms as f64)),
        ("faults", Json::num(stats.faults.load(Ordering::Relaxed) as f64)),
        ("block_prefetches", Json::num(stats.block_prefetches.load(Ordering::Relaxed) as f64)),
        ("predictions", Json::num(stats.predictions.load(Ordering::Relaxed) as f64)),
        ("batches", Json::num(stats.batches.load(Ordering::Relaxed) as f64)),
        ("mean_batch", Json::num(stats.mean_batch())),
        ("bypasses", Json::num(stats.bypasses.load(Ordering::Relaxed) as f64)),
        ("oov", Json::num(stats.oov.load(Ordering::Relaxed) as f64)),
        (
            "dropped_commands",
            Json::num(stats.dropped_commands.load(Ordering::Relaxed) as f64),
        ),
        ("e2e_latency_us", stats.fault_to_cmd_us.summary().to_json()),
        ("batch_latency_us", stats.batch_latency_us.summary().to_json()),
        ("tenants", Json::arr(tenants)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::stats::CommandKind;

    #[test]
    fn prometheus_text_has_counters_and_tenant_series() {
        let s = CoordinatorStats::with_tenants(2);
        CoordinatorStats::inc(&s.faults, 7);
        s.record_command(1, CommandKind::Predicted, 15);
        s.tenant(1).note_predicted_page(42);
        assert!(s.tenant(1).note_fault_page(42));
        let text = prometheus_text(&s, 1234);
        assert!(text.contains("uvm_serve_faults_total 7"), "{text}");
        assert!(text.contains("uvm_serve_tenant_predicted_total{tenant=\"1\"} 1"), "{text}");
        assert!(
            text.contains("uvm_serve_tenant_prediction_hits_total{tenant=\"1\"} 1"),
            "{text}"
        );
        assert!(text.contains("uvm_serve_uptime_ms 1234"), "{text}");
        assert!(text.contains("uvm_serve_e2e_latency_us{quantile=\"0.95\"}"), "{text}");
        // Every line is either a comment or `name[{labels}] value`.
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split_whitespace().count() == 2,
                "malformed exposition line: {line}"
            );
        }
    }

    #[test]
    fn snapshot_json_reports_per_tenant_accuracy() {
        let s = CoordinatorStats::with_tenants(1);
        s.record_command(0, CommandKind::Predicted, 5);
        s.record_command(0, CommandKind::Predicted, 5);
        s.tenant(0).note_predicted_page(9);
        s.tenant(0).note_predicted_page(10);
        assert!(s.tenant(0).note_fault_page(10));
        assert!(!s.tenant(0).note_fault_page(11));
        let doc = snapshot_json(&s, 50);
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(SERVE_METRICS_SCHEMA));
        let t0 = &doc.get("tenants").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(t0.get("predicted").and_then(Json::as_u64), Some(2));
        assert_eq!(t0.get("prediction_hits").and_then(Json::as_u64), Some(1));
        assert_eq!(t0.get("accuracy").and_then(Json::as_f64), Some(0.5));
    }
}
