//! `repro inspect <telemetry-file>`: terminal rendering + the
//! `BENCH_telemetry.json` record (schema `bench_telemetry/v1`).
//!
//! The inspector is the consumer-side half of the telemetry contract:
//! it re-derives the headline aggregates from the *event* data and
//! cross-checks them against the metrics snapshot embedded in the
//! file — `used + late` must equal `Metrics::prefetch_used` exactly
//! (those two outcomes are precisely the spans whose first use
//! incremented the counter), and the per-bucket hit-rate series must
//! integrate back to `Metrics::page_hit_rate()` within 1e-9. A
//! telemetry pipeline that cannot reproduce its own aggregates is
//! lying somewhere; the checks make that loud.

use super::{BENCH_TELEMETRY_SCHEMA, TELEMETRY_SCHEMA};
use crate::util::Json;
use anyhow::{anyhow, Context};
use std::path::Path;

/// Maximum timeline rows rendered; longer series merge adjacent
/// buckets.
const MAX_ROWS: usize = 40;
const BAR_WIDTH: usize = 40;

/// Parsed + cross-checked telemetry document.
pub struct Inspection {
    pub benchmark: String,
    pub bucket_cycles: u64,
    pub n_trace_events: usize,
    /// (name, count) in schema order, `unresolved` last.
    pub outcomes: Vec<(String, u64)>,
    pub dropped_faults: u64,
    pub dropped_prefetches: u64,
    pub prefetch_used: u64,
    pub used_plus_late: u64,
    pub hitrate_series: f64,
    pub hitrate_metrics: f64,
    /// Per-row (bucket start cycle, accesses, hits) after downsampling.
    pub timeline: Vec<(u64, u64, u64)>,
}

fn series_pairs(doc: &Json, name: &str) -> anyhow::Result<Vec<(u64, u64)>> {
    let arr = doc
        .get("series")
        .and_then(|s| s.get(name))
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("telemetry file has no series.{name}"))?;
    arr.iter()
        .map(|p| {
            let pair = p.as_arr().filter(|v| v.len() == 2);
            pair.and_then(|v| Some((v[0].as_u64()?, v[1].as_u64()?)))
                .ok_or_else(|| anyhow!("series.{name}: malformed [t, v] pair"))
        })
        .collect()
}

fn metric_u64(doc: &Json, name: &str) -> anyhow::Result<u64> {
    doc.get("metrics")
        .and_then(|m| m.get(name))
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("telemetry file has no metrics.{name}"))
}

impl Inspection {
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let doc = Json::parse_file(path)
            .with_context(|| format!("reading telemetry file {}", path.display()))?;
        let schema = doc.get("schema").and_then(Json::as_str).unwrap_or("");
        if schema != TELEMETRY_SCHEMA {
            anyhow::bail!(
                "{}: schema '{schema}' is not '{TELEMETRY_SCHEMA}' (is this a --telemetry file?)",
                path.display()
            );
        }
        let outcomes_obj = doc.get("outcomes").ok_or_else(|| anyhow!("no outcomes object"))?;
        let mut outcomes = Vec::new();
        for name in ["used", "late", "evicted_unused", "discarded", "unresolved"] {
            let n = outcomes_obj
                .get(name)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("outcomes.{name} missing"))?;
            outcomes.push((name.to_string(), n));
        }
        let used_plus_late = outcomes[0].1 + outcomes[1].1;

        let accesses = series_pairs(&doc, "accesses")?;
        let hits = series_pairs(&doc, "hits")?;
        let acc_total: u64 = accesses.iter().map(|&(_, v)| v).sum();
        let hit_total: u64 = hits.iter().map(|&(_, v)| v).sum();
        let hitrate_series =
            if acc_total == 0 { 0.0 } else { hit_total as f64 / acc_total as f64 };
        let hitrate_metrics = doc
            .get("metrics")
            .and_then(|m| m.get("page_hit_rate"))
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("no metrics.page_hit_rate"))?;

        // Merge the two series onto one row grid (hits is never longer
        // than accesses — every hit is an access), then downsample.
        let mut rows: Vec<(u64, u64, u64)> = accesses
            .iter()
            .enumerate()
            .map(|(i, &(t, a))| (t, a, hits.get(i).map(|&(_, h)| h).unwrap_or(0)))
            .collect();
        if rows.len() > MAX_ROWS {
            let merge = rows.len().div_ceil(MAX_ROWS);
            rows = rows
                .chunks(merge)
                .map(|c| {
                    let t = c[0].0;
                    let a = c.iter().map(|r| r.1).sum();
                    let h = c.iter().map(|r| r.2).sum();
                    (t, a, h)
                })
                .collect();
        }

        Ok(Self {
            benchmark: doc.get("benchmark").and_then(Json::as_str).unwrap_or("?").to_string(),
            bucket_cycles: doc.get("bucket_cycles").and_then(Json::as_u64).unwrap_or(0),
            n_trace_events: doc
                .get("traceEvents")
                .and_then(Json::as_arr)
                .map(|a| a.len())
                .unwrap_or(0),
            outcomes,
            dropped_faults: doc
                .get("dropped_spans")
                .and_then(|d| d.get("faults"))
                .and_then(Json::as_u64)
                .unwrap_or(0),
            dropped_prefetches: doc
                .get("dropped_spans")
                .and_then(|d| d.get("prefetches"))
                .and_then(Json::as_u64)
                .unwrap_or(0),
            prefetch_used: metric_u64(&doc, "prefetch_used")?,
            used_plus_late,
            hitrate_series,
            hitrate_metrics,
            timeline: rows,
        })
    }

    /// `used + late` spans must account for every counted first use.
    pub fn used_matches(&self) -> bool {
        self.used_plus_late == self.prefetch_used
    }

    /// Series integral vs the metrics aggregate (1e-9 tolerance).
    pub fn hitrate_integrates(&self) -> bool {
        (self.hitrate_series - self.hitrate_metrics).abs() <= 1e-9
    }

    /// Terminal report: outcome breakdown table, cross-checks, and the
    /// hit-rate timeline.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let total: u64 = self.outcomes.iter().map(|&(_, n)| n).sum();
        s.push_str(&format!(
            "telemetry: {} ({} trace events, bucket = {} cycles)\n",
            self.benchmark, self.n_trace_events, self.bucket_cycles
        ));
        if self.dropped_faults + self.dropped_prefetches > 0 {
            s.push_str(&format!(
                "  note: span rings saturated (dropped {} fault / {} prefetch spans); \
                 counts remain exact\n",
                self.dropped_faults, self.dropped_prefetches
            ));
        }
        s.push_str("prefetch outcomes:\n");
        for (name, n) in &self.outcomes {
            let pct = if total == 0 { 0.0 } else { 100.0 * *n as f64 / total as f64 };
            s.push_str(&format!("  {name:<16} {n:>10}  {pct:>5.1}%\n"));
        }
        s.push_str(&format!(
            "checks:\n  used+late == prefetch_used: {} ({} vs {})\n",
            if self.used_matches() { "OK" } else { "FAIL" },
            self.used_plus_late,
            self.prefetch_used
        ));
        s.push_str(&format!(
            "  hit-rate integral: {} (series {:.9} vs metrics {:.9})\n",
            if self.hitrate_integrates() { "OK" } else { "FAIL" },
            self.hitrate_series,
            self.hitrate_metrics
        ));
        s.push_str("hit rate per bucket:\n");
        for &(t, a, h) in &self.timeline {
            let rate = if a == 0 { 0.0 } else { h as f64 / a as f64 };
            let fill = (rate * BAR_WIDTH as f64).round() as usize;
            s.push_str(&format!(
                "  {t:>12} |{}{}| {rate:.3} ({h}/{a})\n",
                "#".repeat(fill.min(BAR_WIDTH)),
                "-".repeat(BAR_WIDTH - fill.min(BAR_WIDTH)),
            ));
        }
        s
    }

    /// The `bench_telemetry/v1` record.
    pub fn bench_json(&self) -> Json {
        let outcomes =
            self.outcomes.iter().map(|(k, n)| (k.as_str(), Json::num(*n as f64))).collect();
        Json::obj(vec![
            ("schema", Json::str(BENCH_TELEMETRY_SCHEMA)),
            ("benchmark", Json::str(&self.benchmark)),
            ("bucket_cycles", Json::num(self.bucket_cycles as f64)),
            ("n_trace_events", Json::num(self.n_trace_events as f64)),
            ("outcomes", Json::obj(outcomes)),
            (
                "dropped_spans",
                Json::obj(vec![
                    ("faults", Json::num(self.dropped_faults as f64)),
                    ("prefetches", Json::num(self.dropped_prefetches as f64)),
                ]),
            ),
            (
                "checks",
                Json::obj(vec![
                    ("used_matches", Json::Bool(self.used_matches())),
                    ("hitrate_integrates", Json::Bool(self.hitrate_integrates())),
                    ("used_plus_late", Json::num(self.used_plus_late as f64)),
                    ("prefetch_used", Json::num(self.prefetch_used as f64)),
                    ("hitrate_series", Json::num(self.hitrate_series)),
                    ("hitrate_metrics", Json::num(self.hitrate_metrics)),
                ]),
            ),
        ])
    }
}

/// CLI entry: load, render, write `BENCH_telemetry.json` under
/// `out_dir` (plus the CWD copy every bench writer leaves), and fail
/// the process if a cross-check fails — `make inspect-smoke` gates on
/// it.
pub fn inspect_file(path: &Path, out_dir: &Path) -> anyhow::Result<String> {
    let insp = Inspection::load(path)?;
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let bench = insp.bench_json();
    bench.write_file(&out_dir.join("BENCH_telemetry.json"))?;
    bench.write_file(Path::new("BENCH_telemetry.json"))?;
    let rendered = insp.render();
    if !insp.used_matches() || !insp.hitrate_integrates() {
        anyhow::bail!("telemetry cross-checks FAILED:\n{rendered}");
    }
    Ok(rendered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Metrics;
    use crate::telemetry::{FaultSpan, PrefetchOutcome, SimTelemetry};
    use crate::util::TestDir;

    /// Build a sink whose events agree with a hand-made metrics
    /// snapshot, write it, and inspect the file end to end.
    fn write_consistent(dir: &TestDir) -> std::path::PathBuf {
        let path = dir.path().join("tel.json");
        let mut t = SimTelemetry::new(Some(path.clone()), "unit", 1000);
        let mut m = Metrics::default();
        for i in 0..8u64 {
            let hit = i % 2 == 0;
            t.on_access(i * 500, hit);
            m.mem_accesses += 1;
            if hit {
                m.page_hits += 1;
            }
        }
        t.on_fault(FaultSpan {
            at: 10,
            service_at: 110,
            start: 110,
            arrival: 700,
            page: 1,
            pc: 0,
            sm: 0,
            refault: false,
        });
        m.far_faults += 1;
        t.on_prefetch_issued(2, 10, 700, 1300);
        t.on_prefetch_issued(3, 10, 1300, 1900);
        t.on_prefetch_issued(4, 10, 1900, 2500);
        m.prefetch_transfers += 3;
        t.resolve_prefetch(2, 1400, PrefetchOutcome::Used);
        t.resolve_prefetch(3, 1000, PrefetchOutcome::Late);
        m.prefetch_used += 2;
        t.resolve_prefetch(4, 3000, PrefetchOutcome::EvictedUnused);
        m.evicted_unused_prefetches += 1;
        m.evictions += 1;
        t.write(&m).unwrap();
        path
    }

    #[test]
    fn inspect_roundtrip_checks_pass() {
        let dir = TestDir::new();
        let path = write_consistent(&dir);
        let insp = Inspection::load(&path).unwrap();
        let (ul, pu) = (insp.used_plus_late, insp.prefetch_used);
        assert!(insp.used_matches(), "used+late {ul} vs prefetch_used {pu}");
        assert!(insp.hitrate_integrates());
        assert_eq!(insp.outcomes[2], ("evicted_unused".to_string(), 1));
        let rendered = insp.render();
        assert!(rendered.contains("used+late == prefetch_used: OK"), "{rendered}");
        assert!(rendered.contains("hit-rate integral: OK"), "{rendered}");
    }

    #[test]
    fn inspect_file_writes_bench_record() {
        let dir = TestDir::new();
        let path = write_consistent(&dir);
        let out = dir.path().join("results");
        let rendered = inspect_file(&path, &out).unwrap();
        assert!(rendered.contains("prefetch outcomes"));
        let bench = Json::parse_file(&out.join("BENCH_telemetry.json")).unwrap();
        assert_eq!(bench.get("schema").and_then(Json::as_str), Some(BENCH_TELEMETRY_SCHEMA));
        let checks = bench.get("checks").unwrap();
        assert_eq!(checks.get("used_matches").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn wrong_schema_rejected() {
        let dir = TestDir::new();
        let path = dir.path().join("not_tel.json");
        Json::obj(vec![("schema", Json::str("bench_eval/v1"))]).write_file(&path).unwrap();
        let err = Inspection::load(&path).unwrap_err().to_string();
        assert!(err.contains("telemetry/v1"), "{err}");
    }
}
