//! Structured telemetry: fault-lifecycle spans, time-series rollups,
//! prediction post-mortems and the serving metrics exporter
//! (DESIGN.md §13).
//!
//! Everything in this module is **strictly observer**: the simulator
//! owns an `Option<Box<SimTelemetry>>` that is `None` unless `repro
//! simulate --telemetry FILE` asked for it, every hook sits behind
//! that one check, and no telemetry state feeds back into any
//! scheduling, eviction or prediction decision. Telemetry-off runs are
//! byte-identical to pre-telemetry builds — `tests/ab_identity.rs`
//! pins that invariant, and a second test pins that the telemetry
//! *file itself* is byte-deterministic for a fixed seed (events carry
//! simulated cycles, never wall-clock).
//!
//! Three event families (ISSUE 10):
//! * **fault-lifecycle spans** ([`FaultSpan`], [`PrefetchSpan`]) —
//!   per-fault fault→service→link-grant→arrival cycle timestamps, and
//!   per-prefetch terminal outcomes ([`PrefetchOutcome`]), collected
//!   in bounded rings and drained to a Chrome-trace-compatible file;
//! * **time-series rollups** ([`rollup::Rollup`],
//!   [`rollup::GaugeRollup`]) — per-bucket accesses/hits/faults/
//!   prefetch-issues/occupancy on the same bucket grid as the PCIe
//!   byte series;
//! * **prediction post-mortems** ([`Postmortem`]) — per-(cluster,
//!   PC-bucket) top-1 accuracy attribution from the DL prefetcher.
//!
//! The serving plane reuses none of the simulator sink: its exporter
//! ([`export`]) snapshots the lock-free
//! [`CoordinatorStats`](crate::coordinator::stats::CoordinatorStats)
//! into Prometheus text exposition + JSONL.

pub mod export;
pub mod inspect;
pub mod rollup;
pub mod sink;

pub use rollup::{GaugeRollup, Rollup};
pub use sink::SimTelemetry;

use crate::types::{Cycle, PageNum};
use crate::util::Json;
use std::collections::BTreeMap;

/// Schema tag of the `--telemetry` output file.
pub const TELEMETRY_SCHEMA: &str = "telemetry/v1";
/// Schema tag of the `repro inspect` bench record.
pub const BENCH_TELEMETRY_SCHEMA: &str = "bench_telemetry/v1";

/// Terminal outcome of one prefetch transfer.
///
/// `Used` and `Late` together partition `Metrics::prefetch_used`
/// (`Late` = the page was *demanded while still in flight* — the
/// coalesced-fault arm — so it was used, just not soon enough to hide
/// the transfer). `EvictedUnused` mirrors
/// `Metrics::evicted_unused_prefetches`; `Discarded` covers prefetched
/// pages handed back by the discard verbs before first use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefetchOutcome {
    Used,
    Late,
    EvictedUnused,
    Discarded,
}

impl PrefetchOutcome {
    pub const ALL: [PrefetchOutcome; 4] =
        [Self::Used, Self::Late, Self::EvictedUnused, Self::Discarded];

    pub fn as_str(self) -> &'static str {
        match self {
            Self::Used => "used",
            Self::Late => "late",
            Self::EvictedUnused => "evicted_unused",
            Self::Discarded => "discarded",
        }
    }

    pub fn index(self) -> usize {
        match self {
            Self::Used => 0,
            Self::Late => 1,
            Self::EvictedUnused => 2,
            Self::Discarded => 3,
        }
    }
}

/// One far-fault lifecycle: observed → serviceable (fault-handling
/// latency paid) → link grant → page resident.
#[derive(Debug, Clone, Copy)]
pub struct FaultSpan {
    /// Cycle the access observed the missing page (post-TLB-walk).
    pub at: Cycle,
    /// Cycle the migration became eligible (`at` + far-fault cycles).
    pub service_at: Cycle,
    /// Cycle the serialized link started serving the page.
    pub start: Cycle,
    /// Cycle the page became resident.
    pub arrival: Cycle,
    pub page: PageNum,
    pub pc: u64,
    pub sm: u16,
    /// The page had been resident before and was evicted/discarded.
    pub refault: bool,
}

/// One prefetch transfer: issue → link grant → arrival → terminal
/// outcome (None while unresolved at end of run).
#[derive(Debug, Clone, Copy)]
pub struct PrefetchSpan {
    pub page: PageNum,
    /// Cycle the decision was applied (transfer requested).
    pub issued_at: Cycle,
    pub start: Cycle,
    pub arrival: Cycle,
    pub outcome: Option<PrefetchOutcome>,
    /// Cycle the outcome was decided (0 while unresolved).
    pub outcome_at: Cycle,
}

/// One dynamic inference batch of the DL prefetcher: oldest enqueue →
/// flush → results mature.
#[derive(Debug, Clone, Copy)]
pub struct BatchEvent {
    /// Enqueue cycle of the oldest request in the batch.
    pub enqueued_at: Cycle,
    /// Cycle the batch was flushed into the model.
    pub run_at: Cycle,
    /// Cycle the predictions matured (`run_at` + prediction latency).
    pub ready_at: Cycle,
    pub size: u32,
    /// Predictions in this batch that decoded to the OOV class.
    pub oov: u32,
}

/// Per-(cluster, PC-bucket) prediction accuracy cell.
#[derive(Debug, Clone, Copy, Default)]
pub struct PostmortemCell {
    pub predictions: u64,
    pub correct: u64,
    pub oov: u64,
}

/// Top-1 accuracy attribution from the DL prefetcher: which access
/// streams (cluster) at which code sites (PC bucket) the deployed
/// model actually predicts, and where it loses. Keys are
/// `(cluster key, pc & !0xF)`; the BTreeMap keeps the report order
/// deterministic.
#[derive(Debug, Clone, Default)]
pub struct Postmortem {
    pub cells: BTreeMap<(u64, u64), PostmortemCell>,
}

/// PC-bucket granularity: 16-byte code regions, coarse enough to
/// aggregate unrolled bodies, fine enough to separate kernels.
pub fn pc_bucket(pc: u64) -> u64 {
    pc & !0xF
}

impl Postmortem {
    /// Record one resolved prediction (the cluster's next access
    /// either matched the predicted delta or did not).
    pub fn record(&mut self, cluster: u64, pc_bucket: u64, correct: bool) {
        let c = self.cells.entry((cluster, pc_bucket)).or_default();
        c.predictions += 1;
        if correct {
            c.correct += 1;
        }
    }

    /// Record one OOV answer (no page predicted, nothing to resolve).
    pub fn record_oov(&mut self, cluster: u64, pc_bucket: u64) {
        self.cells.entry((cluster, pc_bucket)).or_default().oov += 1;
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Deterministic JSON array, one object per cell in key order.
    pub fn to_json(&self) -> Json {
        Json::arr(self.cells.iter().map(|(&(cluster, pcb), c)| {
            Json::obj(vec![
                ("cluster", Json::num(cluster as f64)),
                ("pc_bucket", Json::num(pcb as f64)),
                ("predictions", Json::num(c.predictions as f64)),
                ("correct", Json::num(c.correct as f64)),
                ("oov", Json::num(c.oov as f64)),
            ])
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_names_and_indices_are_stable() {
        for (i, o) in PrefetchOutcome::ALL.iter().enumerate() {
            assert_eq!(o.index(), i);
        }
        assert_eq!(PrefetchOutcome::Late.as_str(), "late");
    }

    #[test]
    fn postmortem_accumulates_and_serializes_in_key_order() {
        let mut p = Postmortem::default();
        p.record(7, pc_bucket(0x35), true);
        p.record(7, pc_bucket(0x3f), false); // same 16-byte bucket
        p.record_oov(2, 0x40);
        let c = p.cells[&(7, 0x30)];
        assert_eq!((c.predictions, c.correct, c.oov), (2, 1, 0));
        let json = p.to_json().to_string();
        // BTreeMap order: cluster 2 before cluster 7.
        assert!(json.find("\"cluster\":2").unwrap() < json.find("\"cluster\":7").unwrap());
    }
}
