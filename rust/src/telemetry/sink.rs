//! The simulator-side telemetry sink (DESIGN.md §13).
//!
//! Owned by the engine as `Option<Box<SimTelemetry>>` — `None` (the
//! default) costs one pointer-null check per hook site and allocates
//! nothing, honoring the §12 allocation-free fault loop. The sink
//! never hands state back to the engine: hooks take `&mut self` plus
//! plain values, and every collection is bounded (`SPAN_CAP` per span
//! family, drop-newest with a dropped counter) so a pathological run
//! cannot balloon memory. All timestamps are simulated cycles — the
//! output file is a pure function of the (workload, config, seed)
//! triple and therefore byte-deterministic (pinned by
//! `tests/ab_identity.rs`).
//!
//! The file layout is the Chrome trace-event *object form* —
//! `{"traceEvents": [...], ...}` — which chrome://tracing and Perfetto
//! load directly (extra top-level keys are ignored by the viewers);
//! one simulated cycle is rendered as one microsecond. The extra keys
//! carry the rollup series, the outcome breakdown, the prediction
//! post-mortem and a metrics snapshot that lets `repro inspect`
//! cross-check the spans against the end-of-run aggregates.

use super::{
    BatchEvent, FaultSpan, GaugeRollup, Postmortem, PrefetchOutcome, PrefetchSpan, Rollup,
    TELEMETRY_SCHEMA,
};
use crate::sim::Metrics;
use crate::types::{Cycle, PageNum};
use crate::util::Json;
use std::collections::HashMap;
use std::path::PathBuf;

/// Per-family span ring capacity. Beyond it spans are counted but not
/// stored (`dropped_spans` in the output) — rollups and outcome
/// counters keep exact totals regardless.
pub const SPAN_CAP: usize = 1 << 16;

#[derive(Debug)]
pub struct SimTelemetry {
    /// Output path; `None` = collect but never write (the perf
    /// harness's overhead probe).
    path: Option<PathBuf>,
    benchmark: String,
    bucket_cycles: Cycle,
    faults: Vec<FaultSpan>,
    dropped_faults: u64,
    prefetches: Vec<PrefetchSpan>,
    dropped_prefetches: u64,
    /// Page → open prefetch span (value = stored index, `None` when the
    /// span fell past `SPAN_CAP`). At most one open span per page: the
    /// engine never re-issues a prefetch for a known page.
    open: HashMap<PageNum, Option<u32>>,
    outcome_counts: [u64; 4],
    accesses: Rollup,
    hits: Rollup,
    fault_series: Rollup,
    prefetch_issues: Rollup,
    evictions: Rollup,
    discards: Rollup,
    occupancy: GaugeRollup,
    batches: Vec<BatchEvent>,
    postmortem: Option<Postmortem>,
}

impl SimTelemetry {
    pub fn new(path: Option<PathBuf>, benchmark: &str, bucket_cycles: Cycle) -> Self {
        Self {
            path,
            benchmark: benchmark.to_string(),
            bucket_cycles,
            faults: Vec::new(),
            dropped_faults: 0,
            prefetches: Vec::new(),
            dropped_prefetches: 0,
            open: HashMap::new(),
            outcome_counts: [0; 4],
            accesses: Rollup::new(bucket_cycles),
            hits: Rollup::new(bucket_cycles),
            fault_series: Rollup::new(bucket_cycles),
            prefetch_issues: Rollup::new(bucket_cycles),
            evictions: Rollup::new(bucket_cycles),
            discards: Rollup::new(bucket_cycles),
            occupancy: GaugeRollup::new(bucket_cycles),
            batches: Vec::new(),
            postmortem: None,
        }
    }

    /// One counted memory access (call exactly where
    /// `Metrics::mem_accesses` increments, with the same hit flag as
    /// `Metrics::page_hits`, so the per-bucket hit-rate series
    /// integrates back to `Metrics::page_hit_rate()` exactly).
    pub fn on_access(&mut self, at: Cycle, hit: bool) {
        self.accesses.add(at, 1);
        if hit {
            self.hits.add(at, 1);
        }
    }

    pub fn on_fault(&mut self, span: FaultSpan) {
        self.fault_series.add(span.at, 1);
        if self.faults.len() < SPAN_CAP {
            self.faults.push(span);
        } else {
            self.dropped_faults += 1;
        }
    }

    pub fn on_prefetch_issued(
        &mut self,
        page: PageNum,
        issued_at: Cycle,
        start: Cycle,
        arrival: Cycle,
    ) {
        self.prefetch_issues.add(issued_at, 1);
        let slot = if self.prefetches.len() < SPAN_CAP {
            self.prefetches.push(PrefetchSpan {
                page,
                issued_at,
                start,
                arrival,
                outcome: None,
                outcome_at: 0,
            });
            Some((self.prefetches.len() - 1) as u32)
        } else {
            self.dropped_prefetches += 1;
            None
        };
        self.open.insert(page, slot);
    }

    /// Attach the terminal outcome to the page's open prefetch span, if
    /// any — a no-op for pages that were never prefetched or whose
    /// span already resolved (e.g. eviction of a used prefetch).
    pub fn resolve_prefetch(&mut self, page: PageNum, at: Cycle, outcome: PrefetchOutcome) {
        if let Some(slot) = self.open.remove(&page) {
            self.outcome_counts[outcome.index()] += 1;
            if let Some(i) = slot {
                let s = &mut self.prefetches[i as usize];
                s.outcome = Some(outcome);
                s.outcome_at = at;
            }
        }
    }

    pub fn on_eviction(&mut self, at: Cycle) {
        self.evictions.add(at, 1);
    }

    pub fn on_discard(&mut self, at: Cycle, pages: u64) {
        self.discards.add(at, pages);
    }

    pub fn set_occupancy(&mut self, at: Cycle, live_pages: u64) {
        self.occupancy.set(at, live_pages);
    }

    pub fn set_batches(&mut self, batches: Vec<BatchEvent>) {
        self.batches = batches;
    }

    pub fn set_postmortem(&mut self, pm: Option<Postmortem>) {
        self.postmortem = pm;
    }

    pub fn outcome_count(&self, o: PrefetchOutcome) -> u64 {
        self.outcome_counts[o.index()]
    }

    /// Prefetches still unresolved (in flight, or resident-unused at
    /// end of run).
    pub fn unresolved(&self) -> u64 {
        self.open.len() as u64
    }

    fn series_json(s: &[(Cycle, u64)]) -> Json {
        Json::arr(
            s.iter()
                .map(|&(t, v)| Json::arr([Json::num(t as f64), Json::num(v as f64)])),
        )
    }

    fn trace_events(&self) -> Json {
        let mut evs = Vec::new();
        for f in &self.faults {
            evs.push(Json::obj(vec![
                ("name", Json::str(if f.refault { "refault" } else { "fault" })),
                ("cat", Json::str("fault")),
                ("ph", Json::str("X")),
                ("ts", Json::num(f.at as f64)),
                ("dur", Json::num(f.arrival.saturating_sub(f.at) as f64)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(f.sm as f64)),
                (
                    "args",
                    Json::obj(vec![
                        ("page", Json::num(f.page as f64)),
                        ("pc", Json::num(f.pc as f64)),
                        ("service_at", Json::num(f.service_at as f64)),
                        ("link_start", Json::num(f.start as f64)),
                    ]),
                ),
            ]));
        }
        for p in &self.prefetches {
            evs.push(Json::obj(vec![
                ("name", Json::str("prefetch")),
                ("cat", Json::str("prefetch")),
                ("ph", Json::str("X")),
                ("ts", Json::num(p.issued_at as f64)),
                ("dur", Json::num(p.arrival.saturating_sub(p.issued_at) as f64)),
                ("pid", Json::num(2.0)),
                ("tid", Json::num(0.0)),
                (
                    "args",
                    Json::obj(vec![
                        ("page", Json::num(p.page as f64)),
                        ("link_start", Json::num(p.start as f64)),
                        (
                            "outcome",
                            match p.outcome {
                                Some(o) => Json::str(o.as_str()),
                                None => Json::str("unresolved"),
                            },
                        ),
                        ("outcome_at", Json::num(p.outcome_at as f64)),
                    ]),
                ),
            ]));
        }
        for b in &self.batches {
            evs.push(Json::obj(vec![
                ("name", Json::str("predict_batch")),
                ("cat", Json::str("predict")),
                ("ph", Json::str("X")),
                ("ts", Json::num(b.enqueued_at as f64)),
                ("dur", Json::num(b.ready_at.saturating_sub(b.enqueued_at) as f64)),
                ("pid", Json::num(3.0)),
                ("tid", Json::num(0.0)),
                (
                    "args",
                    Json::obj(vec![
                        ("run_at", Json::num(b.run_at as f64)),
                        ("size", Json::num(b.size as f64)),
                        ("oov", Json::num(b.oov as f64)),
                    ]),
                ),
            ]));
        }
        Json::arr(evs)
    }

    /// The full telemetry document (`telemetry/v1`).
    pub fn to_json(&self, m: &Metrics) -> Json {
        let outcomes = Json::obj(
            PrefetchOutcome::ALL
                .iter()
                .map(|o| (o.as_str(), Json::num(self.outcome_counts[o.index()] as f64)))
                .chain([("unresolved", Json::num(self.unresolved() as f64))])
                .collect(),
        );
        let series = Json::obj(vec![
            ("accesses", Self::series_json(&self.accesses.series())),
            ("hits", Self::series_json(&self.hits.series())),
            ("faults", Self::series_json(&self.fault_series.series())),
            ("prefetch_issues", Self::series_json(&self.prefetch_issues.series())),
            ("evictions", Self::series_json(&self.evictions.series())),
            ("discards", Self::series_json(&self.discards.series())),
            ("occupancy", Self::series_json(&self.occupancy.series())),
        ]);
        let metrics = Json::obj(vec![
            ("instructions", Json::num(m.instructions as f64)),
            ("cycles", Json::num(m.cycles as f64)),
            ("mem_accesses", Json::num(m.mem_accesses as f64)),
            ("page_hits", Json::num(m.page_hits as f64)),
            ("far_faults", Json::num(m.far_faults as f64)),
            ("refaults", Json::num(m.refaults as f64)),
            ("prefetch_transfers", Json::num(m.prefetch_transfers as f64)),
            ("prefetch_used", Json::num(m.prefetch_used as f64)),
            ("evicted_unused_prefetches", Json::num(m.evicted_unused_prefetches as f64)),
            ("evictions", Json::num(m.evictions as f64)),
            ("discards", Json::num(m.discards as f64)),
            ("lazy_discard_reclaims", Json::num(m.lazy_discard_reclaims as f64)),
            ("page_hit_rate", Json::num(m.page_hit_rate())),
            ("accuracy", Json::num(m.accuracy())),
        ]);
        Json::obj(vec![
            ("schema", Json::str(TELEMETRY_SCHEMA)),
            ("benchmark", Json::str(&self.benchmark)),
            ("bucket_cycles", Json::num(self.bucket_cycles as f64)),
            ("displayTimeUnit", Json::str("ms")),
            ("traceEvents", self.trace_events()),
            ("outcomes", outcomes),
            (
                "dropped_spans",
                Json::obj(vec![
                    ("faults", Json::num(self.dropped_faults as f64)),
                    ("prefetches", Json::num(self.dropped_prefetches as f64)),
                ]),
            ),
            ("series", series),
            (
                "postmortem",
                match &self.postmortem {
                    Some(pm) => pm.to_json(),
                    None => Json::arr([]),
                },
            ),
            ("metrics", metrics),
        ])
    }

    /// Serialize to the configured path (no-op for a path-less sink).
    pub fn write(&self, m: &Metrics) -> std::io::Result<()> {
        match &self.path {
            Some(p) => self.to_json(m).write_file(p),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sink() -> SimTelemetry {
        SimTelemetry::new(None, "test", 1000)
    }

    #[test]
    fn outcome_resolution_tracks_open_spans() {
        let mut t = sink();
        t.on_prefetch_issued(7, 10, 12, 500);
        t.on_prefetch_issued(8, 10, 520, 900);
        assert_eq!(t.unresolved(), 2);
        t.resolve_prefetch(7, 600, PrefetchOutcome::Used);
        t.resolve_prefetch(8, 700, PrefetchOutcome::EvictedUnused);
        // Re-resolving or resolving a never-prefetched page is a no-op.
        t.resolve_prefetch(7, 800, PrefetchOutcome::Discarded);
        t.resolve_prefetch(99, 800, PrefetchOutcome::Used);
        assert_eq!(t.outcome_count(PrefetchOutcome::Used), 1);
        assert_eq!(t.outcome_count(PrefetchOutcome::EvictedUnused), 1);
        assert_eq!(t.outcome_count(PrefetchOutcome::Discarded), 0);
        assert_eq!(t.unresolved(), 0);
        assert_eq!(t.prefetches[0].outcome, Some(PrefetchOutcome::Used));
        assert_eq!(t.prefetches[0].outcome_at, 600);
    }

    #[test]
    fn hit_series_integrates_to_hit_rate() {
        let mut t = sink();
        for i in 0..10u64 {
            t.on_access(i * 700, i % 2 == 0);
        }
        assert_eq!(t.accesses.total(), 10);
        assert_eq!(t.hits.total(), 5);
    }

    #[test]
    fn document_is_chrome_trace_object_form() {
        let mut t = sink();
        t.on_fault(FaultSpan {
            at: 5,
            service_at: 105,
            start: 105,
            arrival: 600,
            page: 3,
            pc: 0x40,
            sm: 2,
            refault: false,
        });
        t.on_prefetch_issued(4, 6, 600, 1100);
        let m = Metrics::default();
        let doc = t.to_json(&m);
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(TELEMETRY_SCHEMA));
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("ph").and_then(Json::as_str), Some("X"));
        let out = doc.get("outcomes").unwrap();
        assert_eq!(out.get("unresolved").and_then(Json::as_f64), Some(1.0));
        // Round-trips through the in-tree parser.
        let again = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(again.to_string(), doc.to_string());
    }
}
