//! Uniform time-series accumulators (DESIGN.md §13).
//!
//! [`Rollup`] generalizes the one-off PCIe byte histogram the
//! interconnect has carried since the first simulator commit: a dense
//! vector of per-bucket sums over simulated cycles. Every time-series
//! metric — bytes on the link, accesses, page hits, faults, prefetch
//! issues — is now the *same* accumulator, so bucket boundaries agree
//! across series by construction (one `bucket_cycles` for the whole
//! run) and the Fig. 11 bandwidth timeline, the hit-rate timeline and
//! the fault-rate timeline can be overlaid without resampling.
//!
//! [`GaugeRollup`] is the level-triggered sibling for sampled state
//! (device occupancy): it keeps the *last* value observed per bucket
//! and forward-fills gaps at read time, because a gauge that nobody
//! sampled did not go to zero — it just did not change.

use crate::types::Cycle;

/// Dense per-bucket counter series over simulated time.
#[derive(Debug, Clone)]
pub struct Rollup {
    bucket_cycles: Cycle,
    buckets: Vec<u64>,
}

impl Rollup {
    pub fn new(bucket_cycles: Cycle) -> Self {
        assert!(bucket_cycles > 0);
        Self { bucket_cycles, buckets: Vec::new() }
    }

    pub fn bucket_cycles(&self) -> Cycle {
        self.bucket_cycles
    }

    /// Add `v` to the bucket containing cycle `at`.
    pub fn add(&mut self, at: Cycle, v: u64) {
        let b = (at / self.bucket_cycles) as usize;
        if self.buckets.len() <= b {
            self.buckets.resize(b + 1, 0);
        }
        self.buckets[b] += v;
    }

    /// Spread `v` uniformly over the buckets spanned by `[start, done)`,
    /// with the division remainder charged to the first bucket so the
    /// series total stays exact. This is the interconnect's original
    /// byte-histogram arithmetic verbatim — the swap to `Rollup` must
    /// leave `pcie_series` byte-identical (the A/B gate pins it).
    pub fn spread(&mut self, start: Cycle, done: Cycle, v: u64) {
        let first = (start / self.bucket_cycles) as usize;
        let last = ((done.saturating_sub(1)) / self.bucket_cycles) as usize;
        if self.buckets.len() <= last {
            self.buckets.resize(last + 1, 0);
        }
        let n = (last - first + 1) as u64;
        for b in first..=last {
            self.buckets[b] += v / n;
        }
        self.buckets[first] += v % n;
    }

    /// `(bucket start cycle, sum)` pairs, one per bucket from cycle 0
    /// through the last touched bucket (untouched buckets read 0).
    pub fn series(&self) -> Vec<(Cycle, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, &b)| (i as Cycle * self.bucket_cycles, b))
            .collect()
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }
}

/// Last-value-per-bucket series for sampled state (occupancy).
#[derive(Debug, Clone)]
pub struct GaugeRollup {
    bucket_cycles: Cycle,
    buckets: Vec<Option<u64>>,
}

impl GaugeRollup {
    pub fn new(bucket_cycles: Cycle) -> Self {
        assert!(bucket_cycles > 0);
        Self { bucket_cycles, buckets: Vec::new() }
    }

    /// Record the gauge reading `v` at cycle `at`; later samples in the
    /// same bucket win (the bucket reports its closing value).
    pub fn set(&mut self, at: Cycle, v: u64) {
        let b = (at / self.bucket_cycles) as usize;
        if self.buckets.len() <= b {
            self.buckets.resize(b + 1, None);
        }
        self.buckets[b] = Some(v);
    }

    /// Forward-filled `(bucket start cycle, value)` series: buckets
    /// with no sample repeat the previous bucket's value (0 before the
    /// first sample).
    pub fn series(&self) -> Vec<(Cycle, u64)> {
        let mut cur = 0u64;
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, v)| {
                if let Some(v) = v {
                    cur = *v;
                }
                (i as Cycle * self.bucket_cycles, cur)
            })
            .collect()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_accumulates_per_bucket() {
        let mut r = Rollup::new(100);
        r.add(0, 5);
        r.add(99, 5);
        r.add(100, 7);
        assert_eq!(r.series(), vec![(0, 10), (100, 7)]);
        assert_eq!(r.total(), 17);
    }

    #[test]
    fn spread_preserves_totals_with_remainder_in_first_bucket() {
        let mut r = Rollup::new(1000);
        // Spans buckets 0..=2 (cycles 500..2500): 100/3 = 33 each,
        // remainder 1 to the first.
        r.spread(500, 2500, 100);
        assert_eq!(r.series(), vec![(0, 34), (1000, 33), (2000, 33)]);
        assert_eq!(r.total(), 100);
    }

    #[test]
    fn spread_matches_interconnect_edge_cases() {
        let mut r = Rollup::new(1000);
        // done == start + 1 lands wholly in start's bucket (the
        // interconnect's minimum one-cycle occupancy).
        r.spread(5, 6, 0);
        assert_eq!(r.total(), 0);
        assert_eq!(r.len(), 1);
        // Exact bucket boundary: [0, 1000) touches only bucket 0.
        r.spread(0, 1000, 10);
        assert_eq!(r.series()[0], (0, 10));
    }

    #[test]
    fn gauge_forward_fills() {
        let mut g = GaugeRollup::new(10);
        g.set(0, 3);
        g.set(35, 8);
        // Bucket 1..=2 carry bucket 0's closing value forward.
        assert_eq!(g.series(), vec![(0, 3), (10, 3), (20, 3), (30, 8)]);
        // Later sample in the same bucket wins.
        g.set(36, 9);
        assert_eq!(g.series()[3], (30, 9));
    }
}
