//! Oversubscription study (the regime UVMSmart was designed for,
//! paper §2.3): cap device memory to a fraction of the workload
//! footprint and watch eviction/thrashing behaviour under each
//! prefetch × eviction policy pair.
//!
//! This drives the same machinery as `repro eval oversub`:
//! `SimConfig::oversub_ratio` (resident fraction of the footprint),
//! the pluggable eviction policies of `sim/eviction.rs`, and the
//! occupancy signal that lets uvmsmart/dl throttle near capacity.
//!
//! ```sh
//! cargo run --release --example oversubscription
//! ```

use uvm_prefetch::eval::runner::{run_benchmark_with, RunOptions};
use uvm_prefetch::sim::ALL_EVICTION_POLICIES;

fn main() -> anyhow::Result<()> {
    let opts = RunOptions {
        scale: 2.0, // 64 MB matrix = 16 k pages working set
        max_instructions: 2_000_000,
        ..Default::default()
    };
    println!("ATAX with device memory capped to a fraction of the footprint\n");
    println!(
        "{:<7} {:<15} {:<10} {:>10} {:>8} {:>8} {:>8} {:>9} {:>10}",
        "ratio", "eviction", "policy", "cycles", "hit", "faults", "refault", "evictions", "wasted-pf"
    );
    for ratio in [1.0f64, 0.75, 0.5] {
        let evictions: &[&str] = if ratio >= 1.0 { &["lru"] } else { ALL_EVICTION_POLICIES };
        for eviction in evictions {
            for policy in ["tree", "uvmsmart", "dl"] {
                let ev = eviction.to_string();
                let m = run_benchmark_with(
                    "atax",
                    policy,
                    &opts,
                    move |mut e| {
                        e.sim.oversub_ratio = ratio;
                        e.sim.eviction_policy = ev;
                        e
                    },
                    None,
                )?;
                println!(
                    "{:<7} {:<15} {:<10} {:>10} {:>8.4} {:>8} {:>8} {:>9} {:>10}",
                    format!("{:.2}", ratio),
                    eviction,
                    policy,
                    m.cycles,
                    m.page_hit_rate(),
                    m.far_faults,
                    m.refaults,
                    m.evictions,
                    m.evicted_unused_prefetches,
                );
            }
        }
    }
    println!("\nExpected shape: under pressure the pressure-blind tree policy");
    println!("evicts its own prefetches (wasted-pf ↑ — the paper's thrashing");
    println!("story); uvmsmart suppresses promotions and dl narrows its block");
    println!("floor once occupancy crosses the threshold; prefetch-aware");
    println!("eviction absorbs the damage into never-used prefetched pages.");
    Ok(())
}
