//! Oversubscription study (the regime UVMSmart was designed for,
//! paper §2.3): shrink device memory below the working set and watch
//! eviction/thrashing behaviour under each policy.
//!
//! The paper's main evaluation runs *without* oversubscription (§7.1);
//! this example exercises the machinery the adaptive baseline carries
//! for it: LRU eviction, TLB shootdown, UVMSmart's
//! promotion-suppression under memory pressure, and the
//! "aggressive prefetching causes thrashing" effect (§1).
//!
//! ```sh
//! cargo run --release --example oversubscription
//! ```

use uvm_prefetch::eval::runner::{run_benchmark_with, RunOptions};

fn main() -> anyhow::Result<()> {
    let opts = RunOptions {
        scale: 2.0, // 64 MB matrix = 16 k pages working set
        max_instructions: 2_000_000,
        ..Default::default()
    };
    println!("ATAX with device memory at a fraction of the working set\n");
    println!(
        "{:<10} {:<10} {:>10} {:>8} {:>9} {:>10} {:>14}",
        "capacity", "policy", "cycles", "hit", "faults", "evictions", "wasted-pf"
    );
    // Device capacity as a fraction of 1 GiB: 100 % holds the whole
    // working set; 3 % (~32 MB) and 1.5 % (~16 MB) force eviction.
    for frac in [1.0f64, 0.03, 0.015] {
        for policy in ["tree", "uvmsmart", "dl"] {
            let m = run_benchmark_with(
                "atax",
                policy,
                &opts,
                |mut e| {
                    e.sim.device_mem_bytes = ((1u64 << 30) as f64 * frac) as u64;
                    e
                },
                None,
            )?;
            println!(
                "{:<10} {:<10} {:>10} {:>8.4} {:>9} {:>10} {:>14}",
                format!("{:.1}%", frac * 100.0),
                policy,
                m.cycles,
                m.page_hit_rate(),
                m.far_faults,
                m.evictions,
                m.evicted_unused_prefetches,
            );
        }
    }
    println!("\nExpected shape: under pressure, the aggressive tree policy");
    println!("evicts its own prefetches (wasted-pf ↑, the paper's thrashing");
    println!("story); uvmsmart suppresses promotions; dl prefetches less.");
    Ok(())
}
